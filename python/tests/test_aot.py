"""AOT path: lower → HLO text → recompile with xla_client → same numbers.

This closes the loop the Rust runtime depends on: if the HLO text artifact
executes correctly under xla_client here, `HloModuleProto::from_text_file`
on the Rust side sees identical semantics.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def hlo_text():
    return aot.lower_locality()


def test_hlo_text_structure(hlo_text):
    assert "ENTRY" in hlo_text
    assert "s32[32,4096]" in hlo_text  # both inputs
    assert "f32[32,32]" in hlo_text  # sharing matrix output


def test_hlo_text_roundtrip_numerics(hlo_text):
    rng = np.random.default_rng(42)
    lines = rng.integers(0, 1 << 24, size=(32, 4096), dtype=np.int32)
    valid = np.ones((32, 4096), np.int32)
    valid[30:, :] = 0  # padding rows

    # Reference through the live jax pipeline.
    want = model.export_fn(jnp.asarray(lines), jnp.asarray(valid))

    # Execution through the HLO text artifact, exactly as Rust will run it
    # (parse text -> HloModule -> compile). jaxlib's Client only compiles
    # StableHLO directly, so bridge parsed-HLO -> StableHLO for the test.
    from jax._src import xla_bridge

    backend = xla_bridge.get_backend("cpu")
    hlo_module = xc._xla.hlo_module_from_text(hlo_text)
    stablehlo = xc._xla.mlir.hlo_to_stablehlo(
        hlo_module.as_serialized_hlo_module_proto()
    )
    exe = backend.compile_and_load(
        stablehlo, backend.devices()[:1], xc.CompileOptions()
    )
    outs = exe.execute_sharded(
        [backend.buffer_from_pyval(x) for x in (lines, valid)]
    )
    arrays = [np.asarray(o[0]) for o in outs.disassemble_into_single_device_arrays()]

    assert len(arrays) == 4
    for got, ref in zip(arrays, want):
        np.testing.assert_allclose(got, np.asarray(ref), rtol=1e-6)


def test_aot_writes_artifact(tmp_path):
    out = tmp_path / "locality.hlo.txt"
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out", str(out)]
    try:
        aot.main()
    finally:
        sys.argv = argv
    assert out.exists() and out.stat().st_size > 1000
    meta = json.loads((tmp_path / "locality.meta.json").read_text())
    assert meta["num_cores"] == 30
    assert meta["outputs"][0]["shape"] == [32, 32]
