"""Model-level pipeline vs exact numpy set arithmetic on hashed values."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def numpy_hash(lines: np.ndarray, nbits: int) -> np.ndarray:
    """Bit-exact numpy twin of model.hash_lines (u32 wrap-around)."""
    h = lines.astype(np.uint32)
    h = (h ^ (h >> np.uint32(16))) * np.uint32(0x7FEB352D)
    h = (h ^ (h >> np.uint32(15))) * np.uint32(0x846CA68B)
    h = h ^ (h >> np.uint32(16))
    return (h % np.uint32(nbits)).astype(np.int64)


def exact_metrics(lines: np.ndarray, valid: np.ndarray, nbits: int):
    """Exact set arithmetic on the hashed buckets (the ground truth)."""
    c = lines.shape[0]
    hashed = numpy_hash(lines, nbits)
    sets = [set(hashed[i][valid[i] != 0].tolist()) for i in range(c)]
    s = np.zeros((c, c), np.float64)
    for i in range(c):
        for j in range(c):
            s[i, j] = len(sets[i] & sets[j])
    sizes = np.array([len(x) for x in sets], np.float64)
    union = len(set().union(*sets)) if sets else 0
    total = sizes.sum()
    active = sum(1 for x in sets if x)  # padding rows don't dilute
    score = (s.sum() - total) / max(total * max(active - 1, 1), 1.0)
    repl = total / max(union, 1.0)
    return s, sizes, score, repl


def test_hash_matches_ref():
    lines = jnp.asarray(np.arange(-5, 1000, 7, dtype=np.int32)).reshape(1, -1)
    a = model.hash_lines(lines, 512)
    b = ref.hash_lines_ref(lines, 512)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hash_matches_numpy():
    lines = np.arange(0, 4096, dtype=np.int32).reshape(4, 1024)
    got = np.asarray(model.hash_lines(jnp.asarray(lines), 8192))
    want = numpy_hash(lines, 8192)
    np.testing.assert_array_equal(got.astype(np.int64), want)


def exact_raw_metrics(lines: np.ndarray, valid: np.ndarray):
    """Exact set arithmetic on the *raw* line values (no hashing) — the
    quantity the collision-corrected estimator approximates."""
    c = lines.shape[0]
    sets = [set(lines[i][valid[i] != 0].tolist()) for i in range(c)]
    sizes = np.array([len(x) for x in sets], np.float64)
    inter = np.zeros((c, c))
    for i in range(c):
        for j in range(c):
            inter[i, j] = len(sets[i] & sets[j])
    union = len(set().union(*sets)) if sets else 0
    total = sizes.sum()
    active = sum(1 for x in sets if x)
    score = (inter.sum() - np.trace(inter)) / max(total * max(active - 1, 1), 1.0)
    repl = total / max(union, 1)
    return score, repl, sizes


def test_pipeline_matches_exact_sets():
    rng = np.random.default_rng(7)
    c, t, nbits = 8, 128, 4096
    lines = rng.integers(0, 10_000, size=(c, t), dtype=np.int32)
    valid = (rng.random((c, t)) < 0.9).astype(np.int32)
    s, sizes, score, repl = model.locality_metrics(
        jnp.asarray(lines), jnp.asarray(valid), nbits=nbits, tile_k=256
    )
    # S is the raw bucket-sharing matrix: exact on hashed values.
    es, esizes, _, _ = exact_metrics(lines, valid, nbits)
    np.testing.assert_allclose(np.asarray(s), es, atol=0)
    # sizes/score/repl are collision-corrected: compare against exact sets
    # of *raw* lines within estimator tolerance.
    rscore, rrepl, rsizes = exact_raw_metrics(lines, valid)
    np.testing.assert_allclose(np.asarray(sizes), rsizes, rtol=0.05)
    np.testing.assert_allclose(float(score), rscore, atol=0.03)
    np.testing.assert_allclose(float(repl), rrepl, rtol=0.1)


def test_pipeline_matches_jnp_ref():
    rng = np.random.default_rng(11)
    lines = rng.integers(0, 1 << 20, size=(16, 256), dtype=np.int32)
    valid = np.ones((16, 256), np.int32)
    got = model.locality_metrics(
        jnp.asarray(lines), jnp.asarray(valid), nbits=2048, tile_k=256
    )
    want = ref.locality_metrics_ref(jnp.asarray(lines), jnp.asarray(valid), 2048)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)


def test_disjoint_traces_score_zero():
    # Each core touches a private range -> locality_score == 0 (modulo hash
    # collisions, which we avoid by keeping footprints tiny vs nbits).
    c, t = 8, 32
    lines = np.zeros((c, t), np.int32)
    for i in range(c):
        lines[i] = np.arange(t) + i * 1_000_000
    valid = np.ones((c, t), np.int32)
    _, _, score, repl = model.locality_metrics(
        jnp.asarray(lines), jnp.asarray(valid), nbits=65536, tile_k=512
    )
    assert float(score) < 0.01
    assert 0.99 < float(repl) < 1.05


def test_identical_traces_score_one():
    c, t = 8, 64
    lines = np.tile(np.arange(t, dtype=np.int32) * 13, (c, 1))
    valid = np.ones((c, t), np.int32)
    _, _, score, repl = model.locality_metrics(
        jnp.asarray(lines), jnp.asarray(valid), nbits=8192, tile_k=512
    )
    np.testing.assert_allclose(float(score), 1.0, rtol=1e-6)
    np.testing.assert_allclose(float(repl), float(c), rtol=1e-3)


def test_masked_rows_are_inert():
    # Padding rows (mask = 0) must not contribute anywhere — this is what
    # lets the AOT artifact carry 32 rows for 30 real cores.
    rng = np.random.default_rng(13)
    lines = rng.integers(0, 1 << 16, size=(8, 64), dtype=np.int32)
    valid = np.ones((8, 64), np.int32)
    valid[6:, :] = 0
    s, sizes, _, _ = model.locality_metrics(
        jnp.asarray(lines), jnp.asarray(valid), nbits=4096, tile_k=512
    )
    s = np.asarray(s)
    assert np.all(s[6:, :] == 0) and np.all(s[:, 6:] == 0)
    assert np.all(np.asarray(sizes)[6:] == 0)


def test_export_fn_shapes():
    args = model.export_example_args()
    lines = jnp.zeros(args[0].shape, args[0].dtype)
    valid = jnp.zeros(args[1].shape, args[1].dtype)
    s, sizes, score, repl = model.export_fn(lines, valid)
    assert s.shape == (model.PADDED_CORES, model.PADDED_CORES)
    assert sizes.shape == (model.PADDED_CORES,)
    assert score.shape == (1,) and repl.shape == (1,)


@settings(deadline=None, max_examples=10)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    c=st.sampled_from([4, 8, 16]),
    t=st.sampled_from([32, 128]),
    share=st.floats(min_value=0.0, max_value=1.0),
)
def test_score_tracks_injected_sharing(seed, c, t, share):
    """Injecting a shared pool of lines must move the score monotonically-ish:
    we only assert the exact-set oracle agreement, which subsumes it."""
    rng = np.random.default_rng(seed)
    shared_pool = rng.integers(0, 1 << 10, size=t, dtype=np.int32)
    lines = np.zeros((c, t), np.int32)
    for i in range(c):
        private = rng.integers(0, 1 << 30, size=t, dtype=np.int32)
        take_shared = rng.random(t) < share
        lines[i] = np.where(take_shared, shared_pool, private)
    valid = np.ones((c, t), np.int32)
    nbits = 8192
    got = model.locality_metrics(
        jnp.asarray(lines), jnp.asarray(valid), nbits=nbits, tile_k=512
    )
    es, _, _, _ = exact_metrics(lines, valid, nbits)
    np.testing.assert_allclose(np.asarray(got[0]), es, atol=0)
    rscore, rrepl, _ = exact_raw_metrics(lines, valid)
    np.testing.assert_allclose(float(got[2]), rscore, atol=0.04)
    np.testing.assert_allclose(float(got[3]), rrepl, rtol=0.12)
