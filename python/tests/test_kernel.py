"""Pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes and tile sizes; exact equality is expected because
all counts are small integers held in f32.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import locality, ref

jax.config.update("jax_platform_name", "cpu")


def random_bitmaps(rng, c, nbits, density=0.2):
    return (rng.random((c, nbits)) < density).astype(np.float32)


class TestSignatureMatmul:
    def test_small_exact(self):
        rng = np.random.default_rng(0)
        b = random_bitmaps(rng, 8, 256)
        got = locality.signature_matmul(jnp.asarray(b), tile_k=64)
        want = ref.signature_matmul_ref(jnp.asarray(b))
        np.testing.assert_allclose(got, want, rtol=0, atol=0)

    def test_default_export_shape(self):
        rng = np.random.default_rng(1)
        b = random_bitmaps(rng, 32, 8192, density=0.3)
        got = locality.signature_matmul(jnp.asarray(b))
        want = ref.signature_matmul_ref(jnp.asarray(b))
        np.testing.assert_allclose(got, want, rtol=0, atol=0)

    def test_symmetry_and_diag(self):
        rng = np.random.default_rng(2)
        b = random_bitmaps(rng, 16, 512)
        s = np.asarray(locality.signature_matmul(jnp.asarray(b), tile_k=128))
        np.testing.assert_array_equal(s, s.T)
        np.testing.assert_array_equal(np.diagonal(s), b.sum(axis=1))

    def test_zero_bitmaps(self):
        b = jnp.zeros((8, 256), jnp.float32)
        s = locality.signature_matmul(b, tile_k=64)
        np.testing.assert_array_equal(np.asarray(s), 0.0)

    def test_identical_rows_saturate(self):
        # All cores touch the same lines -> S is rank-1, every entry = popcount.
        row = (np.arange(512) % 3 == 0).astype(np.float32)
        b = jnp.asarray(np.tile(row, (8, 1)))
        s = np.asarray(locality.signature_matmul(b, tile_k=128))
        np.testing.assert_array_equal(s, row.sum())

    def test_rejects_misaligned_tile(self):
        b = jnp.zeros((8, 300), jnp.float32)
        with pytest.raises(ValueError, match="multiple of tile_k"):
            locality.signature_matmul(b, tile_k=128)

    @settings(deadline=None, max_examples=20)
    @given(
        c_log=st.integers(min_value=3, max_value=5),
        k_tiles=st.integers(min_value=1, max_value=8),
        tile_k=st.sampled_from([64, 128, 256]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        density=st.floats(min_value=0.0, max_value=1.0),
    )
    def test_matches_ref_swept(self, c_log, k_tiles, tile_k, seed, density):
        c = 1 << c_log
        nbits = k_tiles * tile_k
        rng = np.random.default_rng(seed)
        b = random_bitmaps(rng, c, nbits, density)
        got = locality.signature_matmul(jnp.asarray(b), tile_k=tile_k)
        want = ref.signature_matmul_ref(jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


class TestUnionPopcount:
    def test_small_exact(self):
        rng = np.random.default_rng(3)
        b = random_bitmaps(rng, 8, 256)
        got = locality.union_popcount(jnp.asarray(b), tile_k=64)
        want = ref.union_popcount_ref(jnp.asarray(b))
        np.testing.assert_allclose(float(got), float(want))

    def test_disjoint_rows_sum(self):
        # Disjoint signatures: union = sum of popcounts.
        b = np.zeros((4, 256), np.float32)
        for i in range(4):
            b[i, i * 64 : i * 64 + 10] = 1.0
        got = float(locality.union_popcount(jnp.asarray(b), tile_k=64))
        assert got == 40.0

    def test_identical_rows(self):
        row = (np.arange(512) % 5 == 0).astype(np.float32)
        b = jnp.asarray(np.tile(row, (8, 1)))
        got = float(locality.union_popcount(b, tile_k=128))
        assert got == float(row.sum())

    @settings(deadline=None, max_examples=20)
    @given(
        c=st.integers(min_value=1, max_value=32),
        k_tiles=st.integers(min_value=1, max_value=6),
        tile_k=st.sampled_from([64, 256]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref_swept(self, c, k_tiles, tile_k, seed):
        nbits = k_tiles * tile_k
        rng = np.random.default_rng(seed)
        b = random_bitmaps(rng, c, nbits, 0.3)
        got = float(locality.union_popcount(jnp.asarray(b), tile_k=tile_k))
        want = float(ref.union_popcount_ref(jnp.asarray(b)))
        assert got == want
