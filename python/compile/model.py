"""L2 JAX model: the inter-core locality analytics pipeline.

The paper classifies applications into high / low inter-core locality “based
on the amount of replicated data across all cores” (§IV).  This module is
that classifier as a compute graph:

    raw per-core cache-line traces (i32[C, T] + validity mask)
      → mix-hash into NBITS buckets
      → per-core {0,1} occupancy signatures  (plain jnp scatter)
      → core×core sharing matrix S = B @ Bᵀ   (Pallas MXU kernel)
      → union popcount                        (Pallas reduce kernel)
      → locality score + replication factor

It is lowered ONCE by :mod:`compile.aot` to HLO text; the Rust coordinator
executes the artifact through PJRT to classify workloads and to cross-check
the simulator's replication statistics.  Python never runs at sim time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import locality as kernels

# Export-time shapes (see DESIGN.md §8): 30 SIMT cores padded to 32 rows so
# Pallas tiles stay 8-aligned; 4096 sampled line ids per core; 8192 hash
# buckets keep the collision rate ≈ T/NBITS ≤ 0.5 per bucket at full mask.
NUM_CORES = 30
PADDED_CORES = 32
TRACE_LEN = 4096
NBITS = 8192


def hash_lines(lines: jnp.ndarray, nbits: int) -> jnp.ndarray:
    """Map raw cache-line ids to hash buckets with a 32-bit mix hash.

    The exact sequence (xor-shift + two odd multiplies) is the lowering of
    ``murmur3``'s finalizer variant; it must stay bit-identical to
    :func:`compile.kernels.ref.hash_lines_ref` and to the Rust-side
    ``trace::signature::hash_line`` so the simulator can reproduce the
    artifact's bucketing exactly.
    """
    h = lines.astype(jnp.uint32)
    h = (h ^ (h >> 16)) * jnp.uint32(0x7FEB352D)
    h = (h ^ (h >> 15)) * jnp.uint32(0x846CA68B)
    h = h ^ (h >> 16)
    return (h % jnp.uint32(nbits)).astype(jnp.int32)


def build_signatures(
    lines: jnp.ndarray, valid: jnp.ndarray, nbits: int
) -> jnp.ndarray:
    """Scatter hashed line ids into f32[C, NBITS] occupancy bitmaps.

    Stays in plain jnp: one-hot scatter lowers to an XLA scatter-max which
    fuses well, and it is O(C·T) next to the O(C²·NBITS) matmul hot-spot.
    """
    c, _ = lines.shape
    hashed = hash_lines(lines, nbits)
    bitmaps = jnp.zeros((c, nbits), jnp.float32)
    rows = jnp.broadcast_to(jnp.arange(c)[:, None], lines.shape)
    return bitmaps.at[rows, hashed].max(valid.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("nbits", "tile_k"))
def locality_metrics(
    lines: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    nbits: int = NBITS,
    tile_k: int = kernels.DEFAULT_TILE_K,
):
    """Full pipeline: traces → (S, sizes, locality_score, replication_factor).

    Args:
      lines: i32[C, T] cache-line ids sampled per core (C may be any
        multiple of 8; the AOT export pads 30 → 32 with masked rows).
      valid: i32/f32/bool[C, T] — 1 where ``lines`` holds a real sample.

    Returns:
      S                  f32[C, C] bucket-sharing matrix.
      sizes              f32[C]    per-core signature popcounts.
      locality_score     f32[]     mean replicated fraction, in [0, 1].
      replication_factor f32[]     Σ sizes / |∪ signatures|, in [1, C].
    """
    b = build_signatures(lines, valid, nbits)
    s = kernels.signature_matmul(b, tile_k=tile_k)
    raw_sizes = jnp.diagonal(s)
    union_pc = kernels.union_popcount(b, tile_k=tile_k)

    # Hash-bucket collision correction (linear counting, Whang et al.):
    # a set of d distinct lines fills ~NBITS·(1 - e^(-d/NBITS)) buckets, so
    # d ≈ -NBITS·ln(1 - popcount/NBITS).  Without this, workloads whose
    # footprint approaches NBITS report inflated sharing.
    lc = lambda pc: linear_count(pc, nbits)
    sizes = lc(raw_sizes)
    union = lc(union_pc)
    # Pairwise intersections via inclusion–exclusion on corrected sizes:
    # |A∩B| ≈ lc(pcA) + lc(pcB) - lc(pcA + pcB - pc(A∧B)).
    pc_i = raw_sizes[:, None]
    pc_j = raw_sizes[None, :]
    pair_union_pc = pc_i + pc_j - s
    inter = lc(pc_i) + lc(pc_j) - lc(pair_union_pc)
    inter = jnp.maximum(inter, 0.0)

    total = jnp.sum(sizes)
    off_diag = jnp.sum(inter) - jnp.sum(jnp.diagonal(inter))
    # Denominator uses *active* cores (rows with any valid sample), so the
    # padding rows the AOT export carries (30 real cores in 32 rows) do not
    # dilute the score.
    active = jnp.sum((jnp.max(valid, axis=1) > 0).astype(jnp.float32))
    locality_score = off_diag / jnp.maximum(total * jnp.maximum(active - 1.0, 1.0), 1.0)
    replication_factor = total / jnp.maximum(union, 1.0)
    return s, sizes, locality_score, replication_factor


def linear_count(popcount, nbits: int):
    """Distinct-count estimate from an occupancy popcount (clamped)."""
    frac = jnp.clip(popcount / nbits, 0.0, 1.0 - 1.0 / nbits)
    return -nbits * jnp.log1p(-frac)


def export_fn(lines: jnp.ndarray, valid: jnp.ndarray):
    """The exact function AOT-lowered to ``artifacts/locality.hlo.txt``.

    Fixed shapes: lines i32[32, 4096], valid i32[32, 4096].  Rows 30..31
    are padding — the Rust caller zeroes their masks.  Returned as a tuple
    (the Rust loader unwraps with ``to_tuple``); scalars are reshaped to
    [1] because PJRT literals round-trip rank-1 most portably.
    """
    s, sizes, score, repl = locality_metrics(lines, valid, nbits=NBITS)
    return (s, sizes, score.reshape((1,)), repl.reshape((1,)))


def export_example_args():
    """ShapeDtypeStructs matching :func:`export_fn`'s AOT signature."""
    spec_lines = jax.ShapeDtypeStruct((PADDED_CORES, TRACE_LEN), jnp.int32)
    spec_valid = jax.ShapeDtypeStruct((PADDED_CORES, TRACE_LEN), jnp.int32)
    return spec_lines, spec_valid
