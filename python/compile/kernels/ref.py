"""Pure-jnp oracles for the Pallas kernels and the locality pipeline.

Everything here is the *reference semantics*; pytest asserts the Pallas
kernels and the exported model against these with ``assert_allclose``.
"""

from __future__ import annotations

import jax.numpy as jnp


def signature_matmul_ref(bitmaps: jnp.ndarray) -> jnp.ndarray:
    """S = B @ B^T in plain jnp (the contraction the MXU kernel tiles)."""
    return jnp.dot(bitmaps, bitmaps.T, preferred_element_type=jnp.float32)


def union_popcount_ref(bitmaps: jnp.ndarray) -> jnp.ndarray:
    """Popcount of the column-wise OR of 0/1 signature rows."""
    return jnp.sum(jnp.max(bitmaps, axis=0))


def hash_lines_ref(lines: jnp.ndarray, nbits: int) -> jnp.ndarray:
    """Reference of the multiplicative mix hash used by the model.

    Must stay bit-identical to :func:`compile.model.hash_lines` — tests
    build exact oracles on the *hashed* values, so any drift is caught.
    """
    h = lines.astype(jnp.uint32)
    h = (h ^ (h >> 16)) * jnp.uint32(0x7FEB352D)
    h = (h ^ (h >> 15)) * jnp.uint32(0x846CA68B)
    h = h ^ (h >> 16)
    return (h % jnp.uint32(nbits)).astype(jnp.int32)


def build_signatures_ref(
    lines: jnp.ndarray, valid: jnp.ndarray, nbits: int
) -> jnp.ndarray:
    """f32[C, NBITS] occupancy bitmaps from i32[C, T] line ids + masks."""
    c, _ = lines.shape
    hashed = hash_lines_ref(lines, nbits)
    bitmaps = jnp.zeros((c, nbits), jnp.float32)
    rows = jnp.broadcast_to(jnp.arange(c)[:, None], lines.shape)
    return bitmaps.at[rows, hashed].max(valid.astype(jnp.float32))


def locality_metrics_ref(lines: jnp.ndarray, valid: jnp.ndarray, nbits: int):
    """Reference of the whole L2 pipeline (see compile.model for the spec).

    Returns (S, sizes, locality_score, replication_factor):
      S                  f32[C, C] sharing matrix over hash buckets.
      sizes              f32[C]    per-core signature popcounts (diag of S).
      locality_score     f32[]     off-diagonal mass / (C-1)·total — the
                                   average fraction of a core's working set
                                   replicated in each other core, in [0, 1].
      replication_factor f32[]     Σ sizes / |union| — 1.0 means fully
                                   disjoint working sets, C means all cores
                                   touch the same lines.
    """
    b = build_signatures_ref(lines, valid, nbits)
    s = signature_matmul_ref(b)
    raw_sizes = jnp.diagonal(s)
    union_pc = union_popcount_ref(b)

    def lc(pc):
        frac = jnp.clip(pc / nbits, 0.0, 1.0 - 1.0 / nbits)
        return -nbits * jnp.log1p(-frac)

    sizes = lc(raw_sizes)
    union = lc(union_pc)
    pc_i = raw_sizes[:, None]
    pc_j = raw_sizes[None, :]
    inter = jnp.maximum(lc(pc_i) + lc(pc_j) - lc(pc_i + pc_j - s), 0.0)
    total = jnp.sum(sizes)
    off_diag = jnp.sum(inter) - jnp.sum(jnp.diagonal(inter))
    active = jnp.sum((jnp.max(valid, axis=1) > 0).astype(jnp.float32))
    locality_score = off_diag / jnp.maximum(total * jnp.maximum(active - 1.0, 1.0), 1.0)
    replication_factor = total / jnp.maximum(union, 1.0)
    return s, sizes, locality_score, replication_factor
