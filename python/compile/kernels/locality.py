"""L1 Pallas kernel: tiled bitmap-signature matmul for inter-core locality.

The paper classifies applications by the amount of replicated data across
GPU cores (§IV).  The analytics pipeline casts sharing-set intersection as
a dense matmul over hashed occupancy bitmaps: each core's cache-line set
becomes a {0,1}^NBITS signature row of ``B`` and the core×core sharing
matrix is ``S = B @ B^T`` — ``S[i, j]`` counts hash buckets touched by both
core ``i`` and core ``j`` (collision-corrected upstream).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): a CUDA implementation
would do warp-per-pair set intersection in shared memory; on TPU we instead
feed the MXU a blocked matmul.  BlockSpec keeps a (C×TILE_K) panel of ``B``
resident in VMEM and walks the K (bit) dimension on the grid, accumulating
into a C×C f32 tile that lives in the output block across grid steps.

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default K-tile: 512 f32 lanes × 32 cores × 4 B = 64 KiB per operand panel,
# comfortably inside VMEM with double buffering (DESIGN.md §8).
DEFAULT_TILE_K = 512


def _signature_matmul_kernel(b_ref, bt_ref, out_ref):
    """One grid step: accumulate a K-panel's contribution to S = B @ B^T.

    b_ref  : (C, TILE_K) panel of the signature matrix.
    bt_ref : (TILE_K, C) panel of its transpose (same data, pre-transposed
             at the jnp level so the MXU sees a plain [M,K]x[K,N] contraction
             with no in-kernel transpose).
    out_ref: (C, C) accumulator tile, revisited by every grid step.
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        b_ref[...], bt_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tile_k",))
def signature_matmul(bitmaps: jax.Array, *, tile_k: int = DEFAULT_TILE_K) -> jax.Array:
    """Compute the core×core sharing matrix ``S = B @ B^T``.

    Args:
      bitmaps: f32[C, NBITS] 0/1 occupancy signatures, one row per core.
        ``C`` should be a multiple of 8 and ``NBITS`` a multiple of
        ``tile_k`` (the model layer pads; see :mod:`compile.model`).
      tile_k: K-dimension block size (static).

    Returns:
      f32[C, C] with ``S[i, j] = <B[i], B[j]>`` — exact popcounts of the
      bucket intersections (f32 is exact for counts < 2**24).
    """
    c, nbits = bitmaps.shape
    if nbits % tile_k != 0:
        raise ValueError(f"NBITS={nbits} must be a multiple of tile_k={tile_k}")
    grid = (nbits // tile_k,)
    bt = bitmaps.T  # materialized once at the XLA level, outside the kernel

    return pl.pallas_call(
        _signature_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, tile_k), lambda k: (0, k)),
            pl.BlockSpec((tile_k, c), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((c, c), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, c), jnp.float32),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(bitmaps, bt)


def _union_popcount_kernel(b_ref, acc_ref):
    """Grid step: accumulate per-panel column-OR popcount.

    The union of all cores' signatures is the column-wise max (bitmaps are
    0/1); its popcount is the estimated distinct-line count.  Each grid
    step reduces its K-panel to a single partial sum held in a (1, 1)
    accumulator block.
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    panel = b_ref[...]
    acc_ref[...] += jnp.sum(jnp.max(panel, axis=0, keepdims=True), keepdims=True)[
        :, :1
    ]


@functools.partial(jax.jit, static_argnames=("tile_k",))
def union_popcount(bitmaps: jax.Array, *, tile_k: int = DEFAULT_TILE_K) -> jax.Array:
    """Popcount of the OR of all signature rows: estimated union size.

    Returns f32[] — the number of hash buckets touched by *any* core.
    """
    c, nbits = bitmaps.shape
    if nbits % tile_k != 0:
        raise ValueError(f"NBITS={nbits} must be a multiple of tile_k={tile_k}")
    grid = (nbits // tile_k,)
    out = pl.pallas_call(
        _union_popcount_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((c, tile_k), lambda k: (0, k))],
        out_specs=pl.BlockSpec((1, 1), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(bitmaps)
    return out[0, 0]
