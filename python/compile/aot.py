"""AOT-lower the locality analytics model to HLO text for the Rust loader.

Interchange format is HLO **text**, not serialized HloModuleProto: jax ≥0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out ../artifacts/locality.hlo.txt
Run from ``python/`` (the Makefile does).  Python runs ONCE here; the Rust
binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_locality() -> str:
    lowered = jax.jit(model.export_fn).lower(*model.export_example_args())
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out",
        default="../artifacts/locality.hlo.txt",
        help="output path for the HLO text artifact",
    )
    args = ap.parse_args()

    text = lower_locality()
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)

    # Sidecar metadata the Rust runtime sanity-checks at load time.
    meta = {
        "artifact": "locality",
        "num_cores": model.NUM_CORES,
        "padded_cores": model.PADDED_CORES,
        "trace_len": model.TRACE_LEN,
        "nbits": model.NBITS,
        "inputs": [
            {"name": "lines", "dtype": "i32", "shape": [model.PADDED_CORES, model.TRACE_LEN]},
            {"name": "valid", "dtype": "i32", "shape": [model.PADDED_CORES, model.TRACE_LEN]},
        ],
        "outputs": [
            {"name": "sharing_matrix", "dtype": "f32", "shape": [model.PADDED_CORES, model.PADDED_CORES]},
            {"name": "sizes", "dtype": "f32", "shape": [model.PADDED_CORES]},
            {"name": "locality_score", "dtype": "f32", "shape": [1]},
            {"name": "replication_factor", "dtype": "f32", "shape": [1]},
        ],
    }
    meta_path = os.path.splitext(args.out)[0].replace(".hlo", "") + ".meta.json"
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)

    print(f"wrote {len(text)} chars to {args.out}")
    print(f"wrote metadata to {meta_path}")


if __name__ == "__main__":
    main()
