//! Config-system integration: file-driven runs, seed isolation, and
//! geometry edge cases through the full engine.

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::engine::run_workload;
use ata_cache::trace::synth;

#[test]
fn config_file_drives_a_simulation() {
    let mut cfg = GpuConfig::tiny(L1ArchKind::Ata);
    cfg.l1.latency = 48; // non-default, must survive the file round trip
    cfg.seed = 777;
    let path = std::env::temp_dir().join("ata_itest_cfg.json");
    let path = path.to_str().unwrap();
    cfg.save(path).unwrap();
    let loaded = GpuConfig::load(path).unwrap();
    std::fs::remove_file(path).ok();
    assert_eq!(loaded, cfg);

    let wl = synth::locality_knob(0.5, 0.25).workload(&loaded);
    let r = run_workload(&loaded, &wl);
    assert!(r.cycles > 0);
    // Higher L1 latency must show in the stage metric.
    assert!(r.l1_stage_mean_latency >= 48.0);
}

#[test]
fn seed_changes_workload_but_not_validity() {
    let mut a = GpuConfig::tiny(L1ArchKind::Private);
    let mut b = GpuConfig::tiny(L1ArchKind::Private);
    a.seed = 1;
    b.seed = 2;
    let wa = synth::locality_knob(0.5, 0.25).workload(&a);
    let wb = synth::locality_knob(0.5, 0.25).workload(&b);
    let ra = run_workload(&a, &wa);
    let rb = run_workload(&b, &wb);
    // Different seeds → different traces → (almost surely) different cycles,
    // but the same instruction count scale and valid stats.
    assert_eq!(ra.insts > 0, rb.insts > 0);
    assert_ne!(
        (ra.cycles, ra.l1.local_hits),
        (rb.cycles, rb.l1.local_hits),
        "different seeds should perturb the run"
    );
}

#[test]
fn single_cluster_and_many_cluster_geometries_work() {
    for (cores, clusters) in [(4usize, 1usize), (8, 8), (12, 4)] {
        let mut cfg = GpuConfig::tiny(L1ArchKind::Ata);
        cfg.cores = cores;
        cfg.clusters = clusters;
        cfg.sharing.ata_comparator_groups = cfg.cores_per_cluster().max(1);
        cfg.validate().unwrap();
        let wl = synth::locality_knob(0.7, 0.2).workload(&cfg);
        let r = run_workload(&cfg, &wl);
        assert!(r.cycles > 0, "{cores}/{clusters}");
        if clusters == cores {
            assert_eq!(
                r.l1.remote_hits, 0,
                "single-core clusters cannot share ({cores}/{clusters})"
            );
        }
    }
}

#[test]
fn bigger_l1_raises_hit_rate() {
    let app = synth::locality_knob(0.3, 0.3);
    let mut small = GpuConfig::tiny(L1ArchKind::Private);
    small.l1.size_bytes = 4 * 1024;
    small.l1.assoc = 8;
    let mut big = GpuConfig::tiny(L1ArchKind::Private);
    big.l1.size_bytes = 64 * 1024;
    big.l1.assoc = 64;
    let rs = run_workload(&small, &app.workload(&small));
    let rb = run_workload(&big, &app.workload(&big));
    assert!(
        rb.l1.hit_rate() > rs.l1.hit_rate(),
        "64K ({:.3}) must beat 4K ({:.3})",
        rb.l1.hit_rate(),
        rs.l1.hit_rate()
    );
}

#[test]
fn l2_latency_knob_shows_in_load_latency() {
    let app = synth::pure_streaming().scaled(0.3);
    let mut fast = GpuConfig::tiny(L1ArchKind::Private);
    fast.l2.latency = 50;
    let mut slow = GpuConfig::tiny(L1ArchKind::Private);
    slow.l2.latency = 400;
    let rf = run_workload(&fast, &app.workload(&fast));
    let rs = run_workload(&slow, &app.workload(&slow));
    assert!(
        rs.l1_mean_load_latency > rf.l1_mean_load_latency + 100.0,
        "L2 latency must dominate miss-heavy loads: {} vs {}",
        rs.l1_mean_load_latency,
        rf.l1_mean_load_latency
    );
}

#[test]
fn dram_clock_scaling_speeds_up_memory() {
    let app = synth::pure_streaming().scaled(0.3);
    let mut slow = GpuConfig::tiny(L1ArchKind::Private);
    slow.dram.clock_ghz = 1.0;
    let mut fast = GpuConfig::tiny(L1ArchKind::Private);
    fast.dram.clock_ghz = 7.0;
    let r_slow = run_workload(&slow, &app.workload(&slow));
    let r_fast = run_workload(&fast, &app.workload(&fast));
    assert!(
        r_fast.cycles < r_slow.cycles,
        "faster DRAM must shorten a streaming run: {} vs {}",
        r_fast.cycles,
        r_slow.cycles
    );
}
