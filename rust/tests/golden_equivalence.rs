//! Golden-equivalence fixtures for the transaction-pipeline refactor.
//!
//! Seeded workloads on the four pre-existing organizations must produce
//! byte-identical core metrics (cycles, instruction counts, every L1
//! hit/miss/reject counter, per-class contention totals, DRAM/NoC
//! traffic) against the blessed fixture in
//! `rust/tests/fixtures/golden_pr3.json`.
//!
//! Blessing protocol: when the fixture file is absent, the test writes it
//! (into the source tree via `CARGO_MANIFEST_DIR`) and passes with a
//! notice — run the suite once and commit the file.  Until the fixture is
//! committed the comparison cannot run on a fresh checkout, so CI emits a
//! "gate unarmed" warning when the file is untracked (see the
//! golden-equivalence step in `.github/workflows/ci.yml`).  From then on
//! any timing or accounting drift in the shared pipeline fails this test
//! byte-for-byte; delete the fixture deliberately (and say why in the PR)
//! to re-bless after an intentional model change.  The refactor itself
//! was verified by construction (each policy preserves the pre-refactor
//! reservation and accounting order); this fixture pins that behaviour
//! for every PR after it.
//!
//! The fifth organization (`ata-bypass`) is deliberately NOT part of the
//! golden set — `L1ArchKind::PAPER` is the fixture universe.
//!
//! Since the execution-layer refactor the fixture also pins the
//! **parallel runner**: a `"runner"` section records the core metrics of
//! a multi-threaded sweep.  Parallel results are byte-identical to
//! serial ones (asserted directly below), so the fixture blesses
//! identically on any host regardless of core count — and any future
//! drift between the worker pool and a serial loop fails the gate.

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::coordinator::Sweep;
use ata_cache::engine::Engine;
use ata_cache::stats::ResourceClass;
use ata_cache::trace::synth;
use ata_cache::util::json::Json;

const FIXTURE: &str = concat!(
    env!("CARGO_MANIFEST_DIR"),
    "/rust/tests/fixtures/golden_pr3.json"
);

/// The two pinned workloads: a mixed-sharing kernel with writes, and the
/// convergent hammer (decoupled's worst case).  Both are generated from
/// the config's fixed seed, so the request streams are bit-reproducible.
fn workloads() -> Vec<ata_cache::trace::AppModel> {
    vec![
        synth::locality_knob(0.8, 0.4),
        synth::convergent_hammer().scaled(0.25),
    ]
}

/// Integer-only core metrics of one run (floats are derived from these;
/// keeping the fixture integral makes byte-identity trivially portable).
fn run_metrics(arch: L1ArchKind, app: &ata_cache::trace::AppModel) -> Json {
    let cfg = GpuConfig::tiny(arch);
    let wl = app.workload(&cfg);
    let r = Engine::new(&cfg).run(&wl).unwrap();
    let mut contention: Vec<(&str, Json)> = ResourceClass::ALL
        .iter()
        .map(|&c| (c.name(), r.contention.get(c).into()))
        .collect();
    contention.push(("total", r.contention.total().into()));
    Json::obj(vec![
        ("arch", arch.name().into()),
        ("app", r.app.as_str().into()),
        ("cycles", r.cycles.into()),
        ("insts", r.insts.into()),
        ("loads", r.loads.into()),
        ("l1", r.l1.to_json()),
        ("contention", Json::obj(contention)),
        ("l1_max_load_latency", r.l1_max_load_latency.into()),
        ("l1_stage_max_latency", r.l1_stage_max_latency.into()),
        ("noc_flits", r.noc_flits.into()),
        ("dram_reads", r.dram_reads.into()),
        ("dram_writes", r.dram_writes.into()),
    ])
}

/// The fixture's sweep: the golden workloads on the paper organizations,
/// run through the execution layer with `threads` workers.
fn golden_sweep(threads: usize) -> Sweep {
    Sweep {
        cfg: GpuConfig::tiny(L1ArchKind::Private),
        archs: L1ArchKind::PAPER.to_vec(),
        apps: workloads(),
        scale: 1.0,
        threads,
    }
}

/// Core metrics of a *parallel* sweep (threads = 4), in submission
/// order.  Byte-identical to a serial sweep by the runner's ordering
/// contract, so this section is host-independent.
fn runner_metrics() -> Json {
    let results = golden_sweep(4).run();
    Json::arr(
        results
            .results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("arch", r.arch.as_str().into()),
                    ("app", r.app.as_str().into()),
                    ("cycles", r.cycles.into()),
                    ("insts", r.insts.into()),
                    ("contention_total", r.contention.total().into()),
                ])
            })
            .collect(),
    )
}

fn golden() -> String {
    let mut runs = Vec::new();
    for arch in L1ArchKind::PAPER {
        for app in &workloads() {
            runs.push(run_metrics(arch, app));
        }
    }
    Json::obj(vec![
        ("fixture", "golden_pr3".into()),
        ("config", "tiny".into()),
        ("runs", Json::arr(runs)),
        ("runner", runner_metrics()),
    ])
    .pretty()
}

#[test]
fn golden_metrics_match_blessed_fixture() {
    let current = golden();
    match std::fs::read_to_string(FIXTURE) {
        Ok(blessed) => {
            assert_eq!(
                current, blessed,
                "core metrics drifted from the blessed fixture \
                 ({FIXTURE}).\nIf the change is intentional, delete the \
                 fixture, re-run the suite to re-bless, and explain the \
                 drift in the PR."
            );
        }
        Err(_) => {
            std::fs::create_dir_all(std::path::Path::new(FIXTURE).parent().unwrap())
                .expect("creating fixtures dir");
            std::fs::write(FIXTURE, &current).expect("writing fixture");
            eprintln!("golden_equivalence: blessed new fixture at {FIXTURE} — commit it");
        }
    }
}

#[test]
fn golden_metrics_are_deterministic() {
    // The fixture protocol is only sound if a rerun is byte-identical.
    let a = golden();
    let b = golden();
    assert_eq!(a, b, "golden metrics must be bit-reproducible");
}

#[test]
fn parallel_sweep_is_byte_identical_to_serial() {
    // The runner section of the fixture is only host-independent if the
    // worker pool's output is byte-identical to a serial run — assert
    // the full serialized sweep, not just headline counters.
    let serial = golden_sweep(1).run();
    let parallel = golden_sweep(4).run();
    assert_eq!(
        serial.to_json().pretty(),
        parallel.to_json().pretty(),
        "JobRunner output must not depend on worker count"
    );
}

#[test]
fn l1_hit_miss_classes_partition_accesses() {
    // Structural cross-check on the golden set: every access lands in
    // exactly one outcome class (the trait-level invariant the pipeline
    // must preserve), modulo the historical ATA double-count of a miss
    // that merges inside the miss path.
    for arch in L1ArchKind::PAPER {
        let cfg = GpuConfig::tiny(arch);
        let wl = synth::locality_knob(0.8, 0.4).workload(&cfg);
        let r = Engine::new(&cfg).run(&wl).unwrap();
        let classes = r.l1.local_hits
            + r.l1.remote_hits
            + r.l1.sector_misses
            + r.l1.misses
            + r.l1.mshr_merges
            + r.l1.writes;
        assert!(
            classes >= r.l1.accesses,
            "{arch:?}: outcome classes {classes} must cover accesses {}",
            r.l1.accesses
        );
        assert!(
            classes <= r.l1.accesses + r.l1.mshr_merges,
            "{arch:?}: over-count beyond merge overlap ({classes} vs {})",
            r.l1.accesses
        );
    }
}
