//! End-to-end contention-accounting tests: the per-resource stall
//! breakdown must exist for all four L1 organizations, reconcile with the
//! end-to-end latency sums, attribute per core, and show ATA's probe
//! filtering as strictly fewer remote-path stall cycles than
//! remote-sharing on a high-locality workload.

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::core::{WarpInst, WarpProgram};
use ata_cache::engine::{Engine, KernelSpec, Workload};
use ata_cache::l1arch::{self, L1Arch};
use ata_cache::l2::MemSystem;
use ata_cache::mem::{AccessKind, MemRequest, MemTxn};
use ata_cache::stats::ResourceClass;
use ata_cache::testkit::{check, int_range, vec_of};

/// A load-only kernel: every core runs `warps` warps, each reading the
/// given line set (rotated per core/warp so first-touch ownership spreads)
/// in loads of `coalesce` lines each, `rounds` times over.
fn shared_load_kernel(
    cores: usize,
    warps: usize,
    lines: &[u64],
    rounds: usize,
    coalesce: usize,
) -> KernelSpec {
    KernelSpec {
        name: "k".into(),
        programs: (0..cores)
            .map(|c| {
                (0..warps)
                    .map(|w| {
                        let mut insts = Vec::new();
                        for r in 0..rounds {
                            let rot = (c * warps + w + r) % lines.len().max(1);
                            let mut order: Vec<u64> = lines.to_vec();
                            order.rotate_left(rot);
                            for group in order.chunks(coalesce) {
                                insts.push(WarpInst::Load(
                                    group.iter().map(|&l| (l, 0b1111)).collect(),
                                ));
                            }
                            insts.push(WarpInst::Alu(2));
                        }
                        WarpProgram::new(insts)
                    })
                    .collect()
            })
            .collect(),
    }
}

/// Single-request loads: with one request per load instruction, every
/// queued cycle lies on exactly one tracked load's sequential path, so
/// Σ(queued) ≤ Σ(load latency) is structurally guaranteed.  (Coalesced
/// multi-request loads can queue concurrently on disjoint resources while
/// the tracker records one latency for the group — the bound would not be
/// exact.)
fn load_only_workload(cfg: &GpuConfig, lines: &[u64]) -> Workload {
    Workload {
        name: "contended".into(),
        kernels: vec![shared_load_kernel(cfg.cores, 4, lines, 2, 1)],
    }
}

/// Acceptance: every organization emits a breakdown, per-core attribution
/// sums to the aggregate, and — on a load-only workload — the breakdown
/// total is bounded by the sum of end-to-end load latencies (every queued
/// cycle delays exactly one load along its sequential path).
#[test]
fn property_breakdown_reconciles_with_latency_sums() {
    let gen = vec_of(int_range(0, 63), int_range(8, 24));
    check("contention-reconciles", 0xC0A7E, 8, &gen, |lines| {
        for arch in L1ArchKind::ALL {
            let cfg = GpuConfig::tiny(arch);
            let wl = load_only_workload(&cfg, lines);
            let mut eng = Engine::new(&cfg);
            let r = eng.run(&wl).unwrap();
            let con = eng.contention();
            // Per-core attribution partitions the aggregate exactly.
            let core_sum: u64 = con.per_core().iter().map(|b| b.total()).sum();
            if core_sum != con.total().total() {
                return Err(format!(
                    "{arch:?}: per-core sum {core_sum} != total {}",
                    con.total().total()
                ));
            }
            // A fresh engine's per-run delta is the cumulative breakdown.
            if r.contention != *con.total() {
                return Err(format!("{arch:?}: SimResult breakdown != engine breakdown"));
            }
            // Reconciliation with end-to-end latency: with load-only,
            // single-request instructions every queued cycle lies on
            // exactly one load's sequential path, so
            // Σ queued ≤ Σ (load latency).
            let latency_sum = r.l1_mean_load_latency * r.loads as f64;
            if r.contention.total() as f64 > latency_sum + 1.0 {
                return Err(format!(
                    "{arch:?}: breakdown total {} exceeds latency sum {latency_sum}",
                    r.contention.total()
                ));
            }
            if r.loads == 0 {
                return Err(format!("{arch:?}: workload issued no loads"));
            }
        }
        Ok(())
    });
}

/// The contended tiny workload must actually produce nonzero stalls on
/// every organization (otherwise the breakdown is vacuous).
#[test]
fn breakdown_is_nonzero_for_all_archs_under_convergent_load() {
    let lines: Vec<u64> = (0..16).collect();
    for arch in L1ArchKind::ALL {
        let cfg = GpuConfig::tiny(arch);
        let wl = load_only_workload(&cfg, &lines);
        let r = Engine::new(&cfg).run(&wl).unwrap();
        assert!(
            r.contention.total() > 0,
            "{arch:?} must report stall cycles under convergent load: {:?}",
            r.contention
        );
    }
}

/// Acceptance: on a high-locality workload ATA's probe filtering must
/// produce strictly fewer remote-path (intra-cluster fabric) stall cycles
/// than remote-sharing's probe broadcasts — the paper's core claim,
/// restated in contention cycles rather than IPC.
#[test]
fn ata_has_strictly_fewer_remote_path_stalls_than_remote_sharing() {
    let mk_cfg = |arch| {
        let mut cfg = GpuConfig::tiny(arch);
        cfg.cores = 4;
        cfg.clusters = 1;
        cfg.sharing.ata_comparator_groups = 4;
        // Keep remote copies remote so the sharing fabric stays hot for
        // the whole run (both organizations symmetrically).
        cfg.sharing.fill_local_on_remote_hit = false;
        cfg.validate().unwrap();
        cfg
    };
    let lines: Vec<u64> = (0..16).collect();

    let cfg_a = mk_cfg(L1ArchKind::Ata);
    let wl = Workload {
        name: "high-locality".into(),
        kernels: vec![shared_load_kernel(cfg_a.cores, 4, &lines, 4, 2)],
    };
    let ata = Engine::new(&cfg_a).run(&wl).unwrap();

    let cfg_r = mk_cfg(L1ArchKind::RemoteSharing);
    let rem = Engine::new(&cfg_r).run(&wl).unwrap();

    assert_eq!(ata.l1.probes_sent, 0, "ATA never probes");
    assert!(rem.l1.probes_sent > 0, "remote-sharing probes on every miss");
    assert!(
        ata.l1.remote_hits > 0 && rem.l1.remote_hits > 0,
        "both must actually exercise the sharing path: ata {:?} rem {:?}",
        ata.l1,
        rem.l1
    );
    assert!(
        ata.contention.remote_path() < rem.contention.remote_path(),
        "ATA remote-path stalls ({}) must be strictly below remote-sharing ({}): \
         probe broadcasts are filtered out",
        ata.contention.remote_path(),
        rem.contention.remote_path()
    );
}

/// Regression: a saturated MSHR pool must delay dispatch on the ATA miss
/// path exactly like the private/common path — stalls counted as rejects
/// and attributed to the `mshr-full` class.
#[test]
fn mshr_saturation_stalls_ata_and_private_identically() {
    let mk_cfg = |arch| {
        let mut cfg = GpuConfig::tiny(arch);
        cfg.l1.mshr_entries = 2;
        cfg.validate().unwrap();
        cfg
    };
    let load = |id: u64, line: u64| MemRequest {
        id,
        core: 0,
        warp: 0,
        inst: id,
        line,
        sectors: 0b1111,
        kind: AccessKind::Load,
        issue_cycle: 0,
    };
    let n = 8u64;
    let mut results = Vec::new();
    for arch in [L1ArchKind::Private, L1ArchKind::Ata] {
        let cfg = mk_cfg(arch);
        let mut l1 = l1arch::build(&cfg);
        let mut mem = MemSystem::new(&cfg);
        // Distinct far-apart lines, all issued at cycle 0 from one core:
        // misses 3..n find the 2-entry pool full and must stall.
        for i in 0..n {
            l1arch::access_once(l1.as_mut(), &load(i, i * 1024), 0, &mut mem);
        }
        let stats = *l1.stats();
        let stalls = l1.contention().total().get(ResourceClass::MshrFull);
        assert_eq!(stats.misses, n, "{arch:?}");
        assert!(
            stats.rejects >= n - cfg.l1.mshr_entries as u64,
            "{arch:?}: misses beyond the pool must reject ({} rejects)",
            stats.rejects
        );
        assert!(stalls > 0, "{arch:?}: MSHR-full stalls must be attributed");
        assert_eq!(
            l1.contention().per_core()[0].get(ResourceClass::MshrFull),
            stalls,
            "{arch:?}: stalls belong to the issuing core"
        );
        results.push((stats.rejects, stalls));
    }
    assert_eq!(
        results[0].0, results[1].0,
        "private and ATA must reject identically under a saturated pool"
    );
}

/// Finite-buffer backpressure: with a tiny NoC input buffer, a burst of
/// misses from one core must stall at the injection port, retry at the
/// drain cycle, and attribute the wait to the NoC link class.
#[test]
fn noc_backpressure_stalls_are_finite_and_attributed() {
    let mut cfg = GpuConfig::tiny(L1ArchKind::Private);
    cfg.noc.in_buffer_flits = 4;
    cfg.validate().unwrap();
    let mut mem = MemSystem::new(&cfg);
    let req = |id: u64, line: u64| MemRequest {
        id,
        core: 0,
        warp: 0,
        inst: id,
        line,
        sectors: 0b1111,
        kind: AccessKind::Load,
        issue_cycle: 0,
    };
    let mut last = 0;
    for i in 0..32 {
        let mut txn = MemTxn::new(req(i, i * 512), 0);
        last = last.max(mem.fetch(&mut txn, 0));
    }
    assert!(last > 0);
    assert!(
        mem.stats.backpressure_stalls > 0,
        "a 4-flit buffer must backpressure a 32-miss burst"
    );
    assert!(
        mem.contention().total().get(ResourceClass::NocLink) > 0,
        "the stall must be charged to the NoC link class"
    );
    assert_eq!(
        mem.contention().per_core()[0].get(ResourceClass::NocLink),
        mem.contention().total().get(ResourceClass::NocLink),
        "all of it belongs to the bursting core"
    );
}
