//! Property tests over simulator invariants, via the in-tree testkit.

use ata_cache::cache::{Probe, SectoredCache, TagArray};
use ata_cache::mem::decode;
use ata_cache::noc::Islip;
use ata_cache::resource::{Calendar, Server};
use ata_cache::testkit::{check, int_range, one_of, vec_of, Gen};
use ata_cache::util::rng::Pcg32;

#[test]
fn property_address_decode_roundtrips() {
    let gen = vec_of(int_range(0, u32::MAX as u64), int_range(64, 128));
    check("decode-roundtrip", 0xA11CE, 50, &gen, |lines| {
        for &line in lines {
            for sets in [1usize, 2, 8, 64, 512] {
                let s = decode::set_index(line, sets);
                let t = decode::tag(line, sets);
                if decode::line_from(t, s, sets) != line {
                    return Err(format!("line {line} sets {sets} failed"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn property_tag_array_never_stores_duplicates() {
    // After any sequence of fills, a line appears in at most one way of
    // one set.
    let gen = vec_of(int_range(0, 63), int_range(50, 300));
    check("tag-no-dups", 0xBEEF, 30, &gen, |fills| {
        let mut ta = TagArray::new(4, 4);
        for &line in fills {
            ta.fill(line, 0b1111);
        }
        let mut resident = ta.resident_lines();
        let before = resident.len();
        resident.dedup();
        if resident.len() != before {
            return Err("duplicate resident line".into());
        }
        if before > 16 {
            return Err(format!("occupancy {before} exceeds capacity"));
        }
        Ok(())
    });
}

#[test]
fn property_fill_then_peek_hits() {
    // After fill(line, s), peek(line, s) is a full hit — under arbitrary
    // interleavings with other fills.
    let pair = Gen::new(|rng: &mut Pcg32| (rng.next_below(128) as u64, (rng.next_below(15) + 1) as u8));
    let gen = vec_of(pair, int_range(20, 200));
    check("fill-peek-hit", 0xF1A7, 40, &gen, |ops| {
        let mut c = SectoredCache::new(8, 4, 8, 8);
        for &(line, sectors) in ops {
            c.fill(line, sectors);
            match c.peek(line, sectors) {
                Probe::Hit { .. } => {}
                other => return Err(format!("{line}/{sectors:#b}: {other:?}")),
            }
        }
        Ok(())
    });
}

#[test]
fn property_calendar_never_double_books() {
    // Reservations with identical occupancy must never overlap.
    let op = Gen::new(|rng: &mut Pcg32| {
        (rng.next_below(2000) as u64, (rng.next_below(6) + 1) as u32)
    });
    let gen = vec_of(op, int_range(50, 400));
    check("calendar-disjoint", 0xCA1, 30, &gen, |ops| {
        let mut cal = Calendar::new();
        let mut granted: Vec<(u64, u64)> = Vec::new();
        for &(now, occ) in ops {
            let g = cal.reserve(now, occ);
            if g.grant < now {
                return Err(format!("grant {} before request time {now}", g.grant));
            }
            if g.queued != g.grant - now {
                return Err(format!("queued {} != grant delay {}", g.queued, g.grant - now));
            }
            let iv = (g.grant, g.grant + occ as u64);
            for &(s, e) in &granted {
                if iv.0 < e && s < iv.1 {
                    return Err(format!("overlap: {iv:?} vs {:?}", (s, e)));
                }
            }
            granted.push(iv);
        }
        Ok(())
    });
}

#[test]
fn property_calendar_drain_cycle_is_earliest_admission() {
    // drain_cycle must return the earliest cycle at which the backlog has
    // fallen to the limit — the finite-buffer retry point.
    let op = Gen::new(|rng: &mut Pcg32| {
        (rng.next_below(500) as u64, (rng.next_below(8) + 1) as u32)
    });
    let gen = vec_of(op, int_range(20, 120));
    check("calendar-drain", 0xD4A1, 30, &gen, |ops| {
        let mut cal = Calendar::new();
        for &(now, occ) in ops {
            cal.reserve(now, occ);
        }
        for limit in [0u64, 3, 10, 50] {
            for now in [0u64, 100, 400] {
                let t = cal.drain_cycle(now, limit);
                if t < now {
                    return Err(format!("drain {t} before now {now}"));
                }
                if cal.backlog(t) > limit {
                    return Err(format!(
                        "backlog {} at drain point {t} exceeds limit {limit}",
                        cal.backlog(t)
                    ));
                }
                if t > now && cal.backlog(t - 1) <= limit {
                    return Err(format!("drain {t} is not the earliest admission"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn property_calendar_matches_server_on_monotone_feeds() {
    let gen = vec_of(int_range(0, 3), int_range(20, 200));
    check("calendar-fifo", 0x5E4, 30, &gen, |gaps| {
        let mut cal = Calendar::new();
        let mut srv = Server::new();
        let mut now = 0u64;
        for &gap in gaps {
            now += gap;
            let a = cal.reserve(now, 3);
            let b = srv.reserve(now, 3);
            if a != b {
                return Err(format!("at {now}: calendar {a:?} vs server {b:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn property_islip_is_a_matching() {
    // Every arbitration result is a valid matching: no output granted
    // twice, no input matched twice, and matches only where requested.
    let pattern = Gen::new(|rng: &mut Pcg32| {
        let wants: Vec<Vec<bool>> = (0..8)
            .map(|_| (0..6).map(|_| rng.chance(0.3)).collect())
            .collect();
        wants
    });
    let gen = vec_of(pattern, int_range(5, 30));
    check("islip-matching", 0x151, 20, &gen, |rounds| {
        let mut arb = Islip::new(8, 6);
        for wants in rounds {
            let m = arb.arbitrate(wants, 2);
            let mut out_used = [false; 6];
            for (i, slot) in m.iter().enumerate() {
                if let Some(o) = slot {
                    if !wants[i][*o] {
                        return Err(format!("grant without request: {i}->{o}"));
                    }
                    if out_used[*o] {
                        return Err(format!("output {o} double-granted"));
                    }
                    out_used[*o] = true;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn property_mshr_waiter_conservation() {
    // Every allocated/merged request comes back exactly once on fill.
    use ata_cache::cache::{Mshr, MshrOutcome};
    use ata_cache::mem::{AccessKind, MemRequest};
    let op = Gen::new(|rng: &mut Pcg32| (rng.next_below(16) as u64, rng.chance(0.3)));
    let gen = vec_of(op, int_range(30, 150));
    check("mshr-conservation", 0x3141, 30, &gen, |ops| {
        let mut mshr = Mshr::new(8, 4);
        let mut accepted = 0u64;
        let mut returned = 0u64;
        for (i, &(line, do_fill)) in ops.iter().enumerate() {
            if do_fill {
                returned += mshr.fill(line).len() as u64;
            } else {
                let req = MemRequest {
                    id: i as u64,
                    core: 0,
                    warp: 0,
                    inst: i as u64,
                    line,
                    sectors: 1,
                    kind: AccessKind::Load,
                    issue_cycle: 0,
                };
                match mshr.allocate(req) {
                    MshrOutcome::Allocated | MshrOutcome::Merged => accepted += 1,
                    MshrOutcome::Full => {}
                }
            }
        }
        // Drain the rest.
        for line in 0..16u64 {
            returned += mshr.fill(line).len() as u64;
        }
        if accepted != returned {
            return Err(format!("accepted {accepted} != returned {returned}"));
        }
        Ok(())
    });
}

#[test]
fn property_aggregated_probe_equals_individual_probes() {
    use ata_cache::config::{GpuConfig, L1ArchKind};
    use ata_cache::l1arch::ata_tag::AggregatedTagArray;
    use ata_cache::l1arch::common::CoreL1;

    let op = Gen::new(|rng: &mut Pcg32| (rng.next_below(4) as usize, rng.next_below(96) as u64));
    let gen = vec_of(op, int_range(50, 250));
    check("ata-union", 0xA6A, 20, &gen, |fills| {
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let mut cluster: Vec<CoreL1> = (0..4).map(|_| CoreL1::new(&cfg)).collect();
        for &(c, line) in fills {
            cluster[c].cache.fill(line, 0b1111);
        }
        for line in 0..96u64 {
            let agg = AggregatedTagArray::probe(&cluster, 0, line, 0b1111);
            for idx in 1..4 {
                let hit = matches!(cluster[idx].cache.peek(line, 0b1111), Probe::Hit { .. });
                let in_agg = agg.holders & (1 << idx) != 0;
                if hit != in_agg {
                    return Err(format!("cache {idx} line {line}: {hit} vs {in_agg}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn property_locality_knob_orders_scores() {
    use ata_cache::config::{GpuConfig, L1ArchKind};
    use ata_cache::trace::signature::{exact_locality, sample_core_traces};
    use ata_cache::trace::synth;
    let gen = one_of(vec![(0.1f64, 0.7f64), (0.0, 0.5), (0.2, 0.9), (0.3, 0.8)]);
    check("knob-order", 0x10CA1, 6, &gen, |&(lo, hi)| {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let score = |s: f64| {
            let wl = synth::locality_knob(s, 0.3).workload(&cfg);
            exact_locality(&sample_core_traces(&wl, cfg.cores, 4096)).0
        };
        let (a, b) = (score(lo), score(hi));
        if a > b {
            return Err(format!("knob {lo}->{a:.3} vs {hi}->{b:.3} not ordered"));
        }
        Ok(())
    });
}
