//! Differential tests for the O(1) cluster residency index.
//!
//! The index replaces the brute-force union-of-peeks probe in the ATA
//! organizations; its correctness depends on *every* tag-array mutation
//! flowing through the `PipelineCtx` helpers (the mutation-point
//! invariant of `l1arch::residency`).  These tests attack that invariant
//! three ways:
//!
//! 1. a fuzz harness drives thousands of random fill / evict / dirty /
//!    invalidate sequences through the helpers and asserts, request by
//!    request, that the index-backed probe equals the brute-force
//!    [`AggregatedTagArray::probe`] result (the extended, standalone
//!    version of `probe_equals_union_of_individual_peeks`);
//! 2. the index is audited against a from-scratch rebuild of the cluster
//!    caches' true residency after every fuzz run;
//! 3. whole-sweep byte-identity: the simulated-metrics JSON of a sweep
//!    (and a multi-app co-run) must not change by one byte when the
//!    index is switched off — only wall clock may move.

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::coordinator::Sweep;
use ata_cache::engine::Engine;
use ata_cache::l1arch::ata_tag::AggregatedTagArray;
use ata_cache::l1arch::residency::ResidencyIndex;
use ata_cache::l1arch::{FabricNeeds, PipelineCtx};
use ata_cache::mem::SectorMask;
use ata_cache::trace::{co_workload, synth};
use ata_cache::util::rng::Pcg32;

/// A pipeline context with live aggregated tags + residency index for a
/// given cluster geometry.
fn ctx(cores: usize, clusters: usize) -> (PipelineCtx, GpuConfig) {
    let mut cfg = GpuConfig::tiny(L1ArchKind::Ata);
    cfg.cores = cores;
    cfg.clusters = clusters;
    cfg.sharing.ata_comparator_groups = cfg.cores_per_cluster().max(4);
    cfg.validate().expect("fuzz geometry must validate");
    let needs = FabricNeeds {
        xbar: true,
        aggregated_tags: true,
        ..FabricNeeds::default()
    };
    (PipelineCtx::new(&cfg, needs), cfg)
}

/// Compare the index-backed probe against the brute-force scan for every
/// (core, line, sectors) triple drawn by the caller.
fn assert_probe_parity(p: &PipelineCtx, cfg: &GpuConfig, line: u64, sectors: SectorMask) {
    let cpc = cfg.cores_per_cluster();
    for cluster in 0..cfg.clusters {
        let base = cluster * cpc;
        for local in 0..cpc {
            let brute =
                AggregatedTagArray::probe(&p.cores[base..base + cpc], local, line, sectors);
            let (holders, dirty) = p.residency[cluster].probe(line, sectors, local);
            assert_eq!(
                (brute.holders, brute.dirty),
                (holders, dirty),
                "cluster {cluster} local {local} line {line} sectors {sectors:#b}"
            );
        }
    }
}

#[test]
fn fuzz_index_probe_equals_brute_force_union_of_peeks() {
    // Thousands of random mutations over several cluster geometries;
    // parity is checked against fresh random probes after every step
    // batch, and the whole index is audited against a rebuild at the end.
    for (cores, clusters, seed) in [(8usize, 2usize, 1u64), (8, 1, 2), (12, 3, 3), (4, 1, 4)] {
        let (mut p, cfg) = ctx(cores, clusters);
        let mut rng = Pcg32::new(0xD1FF ^ seed, seed);
        let lines = 160u32; // small universe → heavy eviction traffic
        for step in 0..3000 {
            let core = rng.next_below(cores as u32) as usize;
            let line = rng.next_below(lines) as u64;
            let sectors = (rng.next_below(15) + 1) as SectorMask;
            match rng.next_below(10) {
                // Fills dominate: they exercise install, extension, and
                // (on a full set) clean/dirty eviction in one helper.
                0..=5 => {
                    p.fill_tags(core, line, sectors);
                }
                6..=7 => {
                    p.mark_dirty_tags(core, line, sectors);
                }
                8 => {
                    p.invalidate_tags(core, line);
                }
                _ => {
                    // A write-allocate pair, as store_local performs it.
                    p.fill_tags(core, line, sectors);
                    p.mark_dirty_tags(core, line, sectors);
                }
            }
            if step % 7 == 0 {
                let probe_line = rng.next_below(lines) as u64;
                let probe_sectors = (rng.next_below(15) + 1) as SectorMask;
                assert_probe_parity(&p, &cfg, probe_line, probe_sectors);
            }
        }
        // Exhaustive parity sweep + structural audit at the end.
        for line in 0..lines as u64 {
            assert_probe_parity(&p, &cfg, line, 0b1111);
            assert_probe_parity(&p, &cfg, line, 0b0001);
            assert_probe_parity(&p, &cfg, line, 0b0110);
        }
        let cpc = cfg.cores_per_cluster();
        for cluster in 0..cfg.clusters {
            let audit = ResidencyIndex::rebuilt_from(
                &p.cores[cluster * cpc..(cluster + 1) * cpc],
                cfg.l1.sectors_per_line(),
            );
            assert!(
                p.residency[cluster].same_residency(&audit),
                "cluster {cluster}: incremental index drifted from true residency \
                 ({cores} cores / {clusters} clusters)"
            );
        }
    }
}

#[test]
fn index_survives_total_invalidation() {
    let (mut p, cfg) = ctx(8, 2);
    for core in 0..8 {
        for line in 0..32u64 {
            p.fill_tags(core, line, 0b1111);
        }
    }
    assert!(p.residency.iter().map(ResidencyIndex::lines).sum::<usize>() > 0);
    for core in 0..8 {
        for line in 0..64u64 {
            p.invalidate_tags(core, line);
        }
    }
    assert_eq!(
        p.residency.iter().map(ResidencyIndex::lines).sum::<usize>(),
        0,
        "a fully invalidated cluster must leave an empty index"
    );
    for line in 0..64u64 {
        assert_probe_parity(&p, &cfg, line, 0b1111);
    }
}

/// The acceptance referee: sweep JSON (all paper organizations × two
/// seeded workloads, through the parallel execution layer) byte-identical
/// with the index on vs off.
#[test]
fn sweep_json_is_byte_identical_with_index_on_and_off() {
    let run = |residency: bool| {
        let mut cfg = GpuConfig::tiny(L1ArchKind::Private);
        cfg.sharing.residency_index = residency;
        Sweep {
            cfg,
            archs: L1ArchKind::ALL.to_vec(),
            apps: vec![
                synth::locality_knob(0.8, 0.4),
                synth::convergent_hammer().scaled(0.25),
            ],
            scale: 1.0,
            threads: 2,
        }
        .run()
        .to_json()
        .pretty()
    };
    assert_eq!(
        run(true),
        run(false),
        "sweep metrics must not depend on sharing.residency_index"
    );
}

/// Same referee for the co-execution path (`Engine::run_multi`), whose
/// store and fill traffic exercises the mutation helpers under sharing.
#[test]
fn multi_json_is_byte_identical_with_index_on_and_off() {
    let run = |residency: bool| {
        let mut cfg = GpuConfig::tiny(L1ArchKind::Ata);
        cfg.sharing.residency_index = residency;
        let models = vec![
            synth::locality_knob(0.7, 0.5),
            synth::convergent_hammer().scaled(0.25),
        ];
        let multi = co_workload(&cfg, &models, &[4, 4], false).expect("co-workload");
        Engine::new(&cfg).run_multi(&multi).unwrap().to_json().pretty()
    };
    assert_eq!(
        run(true),
        run(false),
        "co-run metrics must not depend on sharing.residency_index"
    );
}
