//! Determinism properties of the execution layer (`exec`): every sweep
//! surface must serialize byte-identically regardless of worker count.
//! (The runner's submission-order-despite-completion-order property is
//! unit-tested next to the runner itself, in `exec::runner`.)

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::coordinator::{CoSchedSweep, Sweep};
use ata_cache::exec::{job_seed, JobRunner, ScenarioGrid};
use ata_cache::trace::synth;

fn test_apps() -> Vec<ata_cache::trace::AppModel> {
    vec![
        synth::locality_knob(0.8, 0.25),
        synth::pure_streaming().scaled(0.25),
    ]
}

#[test]
fn sweep_json_is_byte_identical_across_thread_counts() {
    let sweep = |threads: usize| Sweep {
        cfg: GpuConfig::tiny(L1ArchKind::Private),
        archs: vec![L1ArchKind::Private, L1ArchKind::DecoupledSharing, L1ArchKind::Ata],
        apps: test_apps(),
        scale: 1.0,
        threads,
    };
    let serial = sweep(1).run().to_json().pretty();
    for threads in [2, 4, 7] {
        let parallel = sweep(threads).run().to_json().pretty();
        assert_eq!(serial, parallel, "sweep output drifted at threads={threads}");
    }
}

#[test]
fn cosched_json_is_byte_identical_across_thread_counts() {
    let sweep = |threads: usize| CoSchedSweep {
        cfg: GpuConfig::tiny(L1ArchKind::Private),
        archs: vec![L1ArchKind::Private, L1ArchKind::Ata],
        apps: test_apps(),
        scale: 1.0,
        threads,
        share_address_space: false,
    };
    let serial = sweep(1).run().to_json().pretty();
    let parallel = sweep(4).run().to_json().pretty();
    assert_eq!(
        serial, parallel,
        "cosched output must be byte-identical for any worker count"
    );
}

#[test]
fn grid_jobs_and_seeds_do_not_depend_on_runner_configuration() {
    // Seeds derive from (grid_seed, job_index) at construction time —
    // before any worker exists — so they are trivially identical however
    // the grid is later run.  Pin that, plus the derivation itself.
    let grid = ScenarioGrid::new(
        GpuConfig::tiny(L1ArchKind::Private),
        vec![L1ArchKind::Private, L1ArchKind::Ata],
        test_apps(),
        0.5,
    );
    let jobs = grid.jobs();
    for (i, job) in jobs.iter().enumerate() {
        assert_eq!(job.seed, job_seed(grid.cfg.seed, i));
        assert_eq!(job.cfg.seed, grid.cfg.seed, "workload recipes keep the grid seed");
    }
    // Running the same grid's jobs with different worker counts yields
    // identical per-job results (the engine consumes only the job).
    let a = JobRunner::new(1).run(&jobs);
    let b = JobRunner::new(4).run(&jobs);
    for (x, y) in a.iter().zip(&b) {
        let (x, y) = (x.clone().into_solo(), y.clone().into_solo());
        assert_eq!(x.cycles, y.cycles, "{}/{}", x.arch, x.app);
        assert_eq!(x.insts, y.insts);
        assert_eq!(x.l1.local_hits, y.l1.local_hits);
        assert_eq!(x.contention, y.contention);
    }
}
