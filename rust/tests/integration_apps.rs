//! Application-level integration: the ten workload models through the
//! full stack, checking the paper's figure-level shapes at reduced scale.

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::coordinator::Sweep;
use ata_cache::engine::run_workload;
use ata_cache::trace::{apps, LocalityClass};
use ata_cache::util::json::Json;

#[test]
fn every_app_runs_on_every_arch() {
    for app in apps::all_apps() {
        let small = app.scaled(0.15);
        for arch in L1ArchKind::ALL {
            let cfg = GpuConfig::paper(arch);
            let r = run_workload(&cfg, &small.workload(&cfg));
            assert!(r.cycles > 0 && r.insts > 0, "{}/{:?}", app.name, arch);
            assert_eq!(r.kernels.len(), app.kernels.len());
            assert!(r.l1.accesses > 0);
        }
    }
}

#[test]
fn fig8_shape_holds_at_reduced_scale() {
    // The coarse orderings of Fig 8 (cheap version of the bench).
    let sweep = Sweep::fig8(0.25);
    let r = sweep.run();

    // ATA ≥ decoupled overall on both classes.
    for class in [LocalityClass::High, LocalityClass::Low] {
        let ata = r.class_geomean_ipc(L1ArchKind::Ata, class);
        let dec = r.class_geomean_ipc(L1ArchKind::DecoupledSharing, class);
        assert!(ata > dec, "{class:?}: ata {ata} vs decoupled {dec}");
    }
    // ATA never collapses below private by more than a few percent.
    for app in apps::all_app_names() {
        let n = r.norm_ipc(L1ArchKind::Ata, app).unwrap();
        assert!(n > 0.93, "ATA must not lose badly on {app}: {n}");
    }
    // SN hurts decoupled (narrow hot weight sets); conv3d's loss shows at
    // the bench's full scale (fig8_ipc) — intensity-dependent.
    let n = r.norm_ipc(L1ArchKind::DecoupledSharing, "SN").unwrap();
    assert!(n < 1.0, "decoupled should lose on SN: {n}");
}

#[test]
fn fig10_latency_ordering_holds() {
    let sweep = Sweep::fig8(0.25);
    let r = sweep.run();
    let mut dec_sum = 0.0;
    let mut ata_sum = 0.0;
    for app in apps::all_app_names() {
        dec_sum += r.norm_latency(L1ArchKind::DecoupledSharing, app).unwrap();
        ata_sum += r.norm_latency(L1ArchKind::Ata, app).unwrap();
    }
    let dec_avg = dec_sum / 10.0;
    let ata_avg = ata_sum / 10.0;
    assert!(
        dec_avg > ata_avg,
        "decoupled latency ({dec_avg:.2}x) must exceed ATA ({ata_avg:.2}x)"
    );
    assert!(dec_avg > 1.15, "decoupled adds substantial latency: {dec_avg:.2}x");
    assert!(ata_avg < 1.5, "ATA latency stays near private: {ata_avg:.2}x");
}

#[test]
fn hit_rates_follow_table1_column1() {
    // Shared organizations must beat the private cache's hit rate on
    // high-locality apps (Table I column 1).
    let sweep = Sweep::paper(0.25);
    let r = sweep.run();
    for app in ["SN", "hotspot", "conv3d"] {
        let p = r.get(L1ArchKind::Private, app).unwrap().l1.hit_rate();
        let a = r.get(L1ArchKind::Ata, app).unwrap().l1.hit_rate();
        assert!(a > p, "{app}: ATA hit {a:.3} must beat private {p:.3}");
    }
}

#[test]
fn l2_bandwidth_demand_drops_with_sharing() {
    // Table I column 5: sharing architectures demand less L2 bandwidth on
    // high-locality apps (misses filtered by remote hits).
    let sweep = Sweep::paper(0.25);
    let r = sweep.run();
    for app in ["SN", "hotspot", "b+tree"] {
        let p = r.get(L1ArchKind::Private, app).unwrap().noc_flits;
        let a = r.get(L1ArchKind::Ata, app).unwrap().noc_flits;
        assert!(
            a < p,
            "{app}: ATA L2 traffic {a} must undercut private {p}"
        );
    }
}

#[test]
fn srad_reduction_kernels_crater_under_decoupled() {
    let cfg_p = GpuConfig::paper(L1ArchKind::Private);
    let cfg_d = GpuConfig::paper(L1ArchKind::DecoupledSharing);
    // Full-ish intensity: the convergence effect is load-dependent.
    let app = apps::app("sradv1").unwrap().scaled(0.5);
    let base = run_workload(&cfg_p, &app.workload(&cfg_p));
    let dec = run_workload(&cfg_d, &app.workload(&cfg_d));
    // The three reduction kernels must be among decoupled's worst.
    let norm: Vec<f64> = base
        .kernels
        .iter()
        .zip(&dec.kernels)
        .map(|(b, d)| d.ipc() / b.ipc().max(1e-12))
        .collect();
    let avg_conv: f64 = [4, 9, 14].iter().map(|&i| norm[i]).sum::<f64>() / 3.0;
    let avg_rest: f64 = norm
        .iter()
        .enumerate()
        .filter(|(i, _)| ![4usize, 9, 14].contains(i))
        .map(|(_, &x)| x)
        .sum::<f64>()
        / 13.0;
    assert!(
        avg_conv < avg_rest,
        "reduction kernels (avg {avg_conv:.3}) must underperform streaming ones (avg {avg_rest:.3}) under decoupled"
    );
}

#[test]
fn results_json_roundtrips() {
    let cfg = GpuConfig::paper(L1ArchKind::Ata);
    let app = apps::app("lud").unwrap().scaled(0.15);
    let r = run_workload(&cfg, &app.workload(&cfg));
    let parsed = Json::parse(&r.to_json().to_string()).unwrap();
    assert_eq!(parsed.get("app").unwrap().as_str(), Some("lud"));
    assert_eq!(
        parsed.get("kernels").unwrap().as_arr().unwrap().len(),
        r.kernels.len()
    );
    assert!(parsed.path("l1.accesses").unwrap().as_u64().unwrap() > 0);
}
