//! Referee for the `ata-sim lint` pass (`rust/src/analysis/`).
//!
//! Each rule gets a positive fixture (the report must flag it) and a
//! negative fixture (allowlisted path, compliant shape, or a justified
//! suppression — the report must stay clean), plus the
//! suppression-requires-justification case and a meta-test asserting
//! the live repository itself is lint-clean, which is the contract the
//! CI gate enforces.
//!
//! Fixtures are in-memory [`Workspace`]s: the linter is a pure function
//! of (paths, sources, manifest), so no tempdirs are needed.

use ata_cache::analysis::{run_lint, LintReport, RuleId, Workspace};

fn lint_one(path: &str, src: &str) -> LintReport {
    Workspace::from_sources(&[(path, src)]).lint()
}

fn slugs(r: &LintReport) -> Vec<&str> {
    r.findings.iter().map(|f| f.rule.slug()).collect()
}

// -- manifest-decl ----------------------------------------------------------

#[test]
fn manifest_decl_flags_undeclared_harness_files() {
    let toml = "[[test]]\nname = \"a\"\npath = \"rust/tests/a.rs\"\n\n[[bench]]\nname = \"b\"\npath = \"rust/benches/b.rs\"\n";
    let mut ws = Workspace::from_sources(&[
        ("rust/tests/a.rs", "fn x() {}"),
        ("rust/benches/b.rs", "fn x() {}"),
        ("examples/c.rs", "fn main() {}"),
    ]);
    ws.cargo_toml = Some(toml.to_string());
    let r = ws.lint();
    assert!(!r.is_clean());
    assert_eq!(slugs(&r), vec!["manifest-decl"]);
    assert_eq!(r.findings[0].file, "examples/c.rs");
    assert!(r.findings[0].excerpt.contains("[[example]]"));
}

#[test]
fn manifest_decl_passes_fully_declared_workspace() {
    let toml = "[[test]]\nname = \"a\"\npath = \"rust/tests/a.rs\"\n\n[[example]]\nname = \"c\"\npath = \"examples/c.rs\"\n";
    let mut ws = Workspace::from_sources(&[
        ("rust/tests/a.rs", "fn x() {}"),
        ("rust/tests/fixtures/data.rs", "fn not_a_target() {}"),
        ("examples/c.rs", "fn main() {}"),
    ]);
    ws.cargo_toml = Some(toml.to_string());
    assert!(ws.lint().is_clean(), "{:?}", ws.lint().findings);
}

// -- wall-clock -------------------------------------------------------------

#[test]
fn wall_clock_flags_instant_in_simulation_code() {
    let src = "use std::time::Instant;\nfn f() -> u64 { Instant::now().elapsed().as_nanos() as u64 }\n";
    let r = lint_one("rust/src/engine/clock.rs", src);
    assert_eq!(slugs(&r), vec!["wall-clock", "wall-clock"]);
    assert_eq!(r.findings[0].line, 1);
}

#[test]
fn wall_clock_allows_bench_dirs_and_harness() {
    let src = "use std::time::Instant;\nfn f() { let _t = Instant::now(); }\n";
    assert!(lint_one("rust/benches/fig8_ipc.rs", src).is_clean());
    assert!(lint_one("rust/src/bench_harness.rs", src).is_clean());
    // Mentions in docs and strings are not wall-clock reads.
    let prose = "//! Instant would break determinism.\nfn f() { log(\"SystemTime\"); }\n";
    assert!(lint_one("rust/src/engine/clock.rs", prose).is_clean());
}

// -- unordered-iter-serialize ----------------------------------------------

#[test]
fn unordered_iteration_in_to_json_flagged() {
    let src = "struct S { lanes: FxHashMap<u32, u64> }\nimpl S {\n    pub fn to_json(&self) -> Json {\n        let mut v = Vec::new();\n        for (k, c) in &self.lanes {\n            v.push((k, c));\n        }\n        Json::arr(v)\n    }\n}\n";
    let r = lint_one("rust/src/stats/lanes.rs", src);
    assert_eq!(slugs(&r), vec!["unordered-iter-serialize"]);
    assert_eq!(r.findings[0].line, 5);
}

#[test]
fn sorted_iteration_and_non_serialize_paths_pass() {
    let sorted = "struct S { lanes: FxHashMap<u32, u64> }\nimpl S {\n    pub fn to_json(&self) -> Json {\n        let mut v: Vec<_> = self.lanes.iter().collect();\n        v.sort();\n        Json::arr(v)\n    }\n}\n";
    assert!(lint_one("rust/src/stats/lanes.rs", sorted).is_clean());
    // Iterating outside a to_json body is not this rule's business.
    let elsewhere = "struct S { lanes: FxHashMap<u32, u64> }\nimpl S {\n    fn total(&self) -> u64 { self.lanes.values().sum() }\n}\n";
    assert!(lint_one("rust/src/stats/lanes.rs", elsewhere).is_clean());
}

// -- grant-discipline -------------------------------------------------------

#[test]
fn dropped_and_grant_only_reservations_flagged() {
    let src = "fn access(p: &mut P) {\n    p.banks.reserve(bank, now, 1);\n    let done = p.port.reserve(now, flits).grant;\n    let g = p.mshr.occupy_until(start, fill);\n    schedule(g.grant);\n    let _ = p.bus.reserve(now, 2);\n    finish(done);\n}\n";
    let r = lint_one("rust/src/l1arch/x.rs", src);
    assert_eq!(
        slugs(&r),
        vec![
            "grant-discipline",
            "grant-discipline",
            "grant-discipline",
            "grant-discipline"
        ],
        "{:?}",
        r.findings
    );
}

#[test]
fn charged_tail_and_test_reservations_pass() {
    let src = "impl Banked {\n    fn reserve(&mut self, bank: usize, now: u64, occ: u32) -> Grant {\n        self.banks[bank].reserve(now, occ)\n    }\n    fn access(&mut self, txn: &mut Txn, con: &mut Ledger) {\n        let g = self.reserve(0, txn.now(), 1);\n        txn.charge(con, ResourceClass::L1DataBank, g.queued);\n        txn.serve(g.grant + 1);\n    }\n}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn raw() { let mut c = Calendar::new(); c.reserve(0, 5); }\n}\n";
    assert!(lint_one("rust/src/resource/x.rs", src).is_clean());
}

#[test]
fn justified_suppression_silences_grant_finding() {
    let src = "fn probe(p: &mut P) {\n    // lint: allow(grant-discipline) — occupancy-only reservation; the stall is charged at dispatch\n    p.cores[peer].banks.reserve(bank, probe_done, 1);\n}\n";
    assert!(lint_one("rust/src/l1arch/x.rs", src).is_clean());
}

// -- tag-mutation-helper ----------------------------------------------------

#[test]
fn direct_tag_mutation_flagged_outside_helper_files() {
    let src = "fn evict(c: &mut CoreL1) {\n    c.cache.tags.invalidate(line);\n    c.cache.fill(line, sectors);\n}\n";
    let r = lint_one("rust/src/l1arch/helper.rs", src);
    assert_eq!(slugs(&r), vec!["tag-mutation-helper", "tag-mutation-helper"]);
}

#[test]
fn tag_mutation_allowed_in_pipeline_and_tests() {
    let src = "fn fill_tags(&mut self, owner: usize) {\n    self.cores[owner].cache.fill(line, sectors);\n}\n";
    assert!(lint_one("rust/src/l1arch/pipeline.rs", src).is_clean());
    let test_src = "#[cfg(test)]\nmod tests {\n    fn seed(c: &mut CoreL1) { c.cache.fill(7, 0b1111); }\n}\n";
    assert!(lint_one("rust/src/l1arch/other.rs", test_src).is_clean());
    // An unrelated .fill() (MSHR bookkeeping) is not a tag mutation.
    let mshr = "fn land(m: &mut M) { m.mshr.fill(line); }\n";
    assert!(lint_one("rust/src/l1arch/other.rs", mshr).is_clean());
}

// -- stats-exclusion --------------------------------------------------------

#[test]
fn telemetry_fields_in_result_json_flagged() {
    let src = "impl SimResult {\n    pub fn to_json(&self) -> Json {\n        Json::obj(vec![(\"jumps\", self.events.jumps.into())])\n    }\n}\n";
    let r = lint_one("rust/src/stats/x.rs", src);
    assert_eq!(slugs(&r), vec!["stats-exclusion"]);
}

#[test]
fn telemetry_types_may_serialize_themselves() {
    let src = "impl EventStats {\n    pub fn to_json(&self) -> Json {\n        Json::obj(vec![(\"jumps\", self.jumps.into())])\n    }\n}\nimpl ResidencyStats {\n    pub fn to_json(&self) -> Json {\n        Json::obj(vec![(\"index_probes\", self.index_probes.into())])\n    }\n}\n";
    assert!(lint_one("rust/src/stats/x.rs", src).is_clean());
}

#[test]
fn renamed_telemetry_fields_are_tracked_from_struct_defs() {
    // A field the canonical list does not know about, declared on
    // EventStats in the same workspace, must still be flagged elsewhere.
    let stats = "pub struct EventStats {\n    pub wakeups_coalesced: u64,\n}\n";
    let sink = "impl SimResult {\n    pub fn to_json(&self) -> Json {\n        Json::obj(vec![(\"w\", self.events.wakeups_coalesced.into())])\n    }\n}\n";
    let ws = Workspace::from_sources(&[
        ("rust/src/stats/mod.rs", stats),
        ("rust/src/stats/sink.rs", sink),
    ]);
    let r = ws.lint();
    assert_eq!(slugs(&r), vec!["stats-exclusion"]);
}

// -- shard-confinement ------------------------------------------------------

#[test]
fn thread_use_outside_exec_and_shard_module_flagged() {
    let src = "use std::thread;\nfn f() { thread::spawn(|| {}); }\n";
    let r = lint_one("rust/src/engine/mod.rs", src);
    assert_eq!(slugs(&r), vec!["shard-confinement", "shard-confinement"]);
    assert_eq!(r.findings[0].line, 1);
    assert_eq!(r.findings[1].line, 2);
    // The NoC is simulation code too — same verdict.
    assert_eq!(
        slugs(&lint_one("rust/src/noc/mod.rs", "fn f() { std::thread::yield_now(); }\n")),
        vec!["shard-confinement"]
    );
}

#[test]
fn thread_use_allowed_in_execution_layer_and_shard_module() {
    let src = "use std::thread;\nfn pool() { thread::scope(|s| { s.spawn(|| {}); }); }\n";
    assert!(lint_one("rust/src/exec/runner.rs", src).is_clean());
    assert!(lint_one("rust/src/engine/shard.rs", src).is_clean());
    // The L2 walk pool (PR 9's slice-parallel B2 fan-out) is the third
    // allowed zone — but only that exact file, not the rest of l2/.
    assert!(lint_one("rust/src/l2/walk.rs", src).is_clean());
    assert_eq!(
        slugs(&lint_one("rust/src/l2/mod.rs", "fn f() { std::thread::yield_now(); }\n")),
        vec!["shard-confinement"]
    );
    // Prose, strings, and thread-ish identifiers are not threading.
    let benign = "//! One thread per shard.\nfn f(threads: usize) { log(\"std::thread\"); let thread_pool_size = threads; }\n";
    assert!(lint_one("rust/src/engine/mod.rs", benign).is_clean());
    // Test modules may thread (skip_tests), e.g. to race an invariant.
    let test_src = "#[cfg(test)]\nmod tests {\n    fn f() { std::thread::yield_now(); }\n}\n";
    assert!(lint_one("rust/src/engine/mod.rs", test_src).is_clean());
}

#[test]
fn justified_suppression_silences_shard_confinement() {
    let src = "fn f() {\n    // lint: allow(shard-confinement) — sizing a worker pool; no simulation state crosses threads\n    let n = std::thread::available_parallelism();\n}\n";
    assert!(lint_one("rust/src/engine/mod.rs", src).is_clean());
}

// -- sim-panic --------------------------------------------------------------

#[test]
fn panic_unwrap_expect_in_simulation_core_flagged() {
    let src = "fn tick(q: &mut Q) {\n    let head = q.pop().unwrap();\n    let lat = q.latency.expect(\"latency set\");\n    if lat == 0 { panic!(\"zero-latency event\"); }\n    serve(head, lat);\n}\n";
    for path in [
        "rust/src/engine/mod.rs",
        "rust/src/l2/mod.rs",
        "rust/src/l1arch/decode.rs",
        "rust/src/dram/mod.rs",
    ] {
        let r = lint_one(path, src);
        assert_eq!(slugs(&r), vec!["sim-panic", "sim-panic", "sim-panic"], "{path}");
    }
}

#[test]
fn sim_panic_scope_test_regions_and_infallible_combinators_pass() {
    let src = "fn tick(q: &mut Q) { q.pop().unwrap(); }\n";
    // Outside the simulation core: the exec layer owns catch_unwind
    // containment and the CLI owns usage errors — not this rule's scope.
    assert!(lint_one("rust/src/exec/runner.rs", src).is_clean());
    assert!(lint_one("rust/src/main.rs", src).is_clean());
    assert!(lint_one("rust/tests/failure_determinism.rs", src).is_clean());
    // Test regions inside core files may unwrap freely.
    let test_src = "#[cfg(test)]\nmod tests {\n    fn t(q: &mut Q) { q.pop().unwrap(); panic!(\"boom\"); }\n}\n";
    assert!(lint_one("rust/src/engine/mod.rs", test_src).is_clean());
    // Non-unwinding combinators and `panic` prose never trip it.
    let benign = "fn tick(q: &mut Q) -> u64 {\n    let m = panic_message(q.err());\n    q.pop().unwrap_or(0) + q.lat.unwrap_or_else(|| m.len() as u64)\n}\n";
    assert!(lint_one("rust/src/l2/mod.rs", benign).is_clean());
}

#[test]
fn justified_suppression_silences_sim_panic() {
    let src = "fn drain(s: &mut S) {\n    // lint: allow(sim-panic) — slot guaranteed occupied: scheduled one epoch earlier\n    let ev = s.slots.take().unwrap();\n    serve(ev);\n}\n";
    assert!(lint_one("rust/src/engine/mod.rs", src).is_clean());
}

// -- suppression-justification ----------------------------------------------

#[test]
fn suppression_without_justification_is_itself_a_finding() {
    let src = "use std::time::Instant; // lint: allow(wall-clock)\n";
    let r = lint_one("rust/src/engine/clock.rs", src);
    assert_eq!(slugs(&r), vec!["suppression-justification"]);
    assert!(r.findings[0].excerpt.contains("no justification"));
}

#[test]
fn suppression_naming_unknown_rule_is_a_finding() {
    let src = "fn f() {} // lint: allow(wallclock) — typo in the slug\n";
    let r = lint_one("rust/src/engine/clock.rs", src);
    assert_eq!(slugs(&r), vec!["suppression-justification"]);
    assert!(r.findings[0].excerpt.contains("wallclock"));
}

#[test]
fn suppression_only_covers_its_own_rule_and_line() {
    // A wall-clock suppression must not silence a grant finding, and a
    // trailing suppression must not leak to the next line.
    let src = "fn f(p: &mut P) {\n    p.banks.reserve(0, 0, 1); // lint: allow(wall-clock) — wrong rule\n    p.banks.reserve(0, 0, 1); // lint: allow(grant-discipline) — right rule, right line\n}\nuse std::time::Instant;\n";
    let r = lint_one("rust/src/l1arch/x.rs", src);
    assert_eq!(slugs(&r), vec!["grant-discipline", "wall-clock"]);
    assert_eq!(r.findings[0].line, 2);
    assert_eq!(r.findings[1].line, 5);
}

// -- report surfaces --------------------------------------------------------

#[test]
fn report_json_carries_the_ci_grepped_fields() {
    let r = lint_one("rust/src/engine/clock.rs", "use std::time::Instant;\n");
    let text = r.to_json().pretty();
    assert!(text.contains("\"findings\""));
    assert!(text.contains("\"rules_checked\""));
    assert!(text.contains("\"wall-clock\""));
    assert_eq!(r.rules_checked.len(), RuleId::ALL.len());
    for id in RuleId::ALL {
        assert!(
            r.rules_checked.contains(&id.slug()),
            "missing {} in rules_checked",
            id.slug()
        );
    }
}

// -- the repo itself --------------------------------------------------------

#[test]
fn live_repository_is_lint_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let r = run_lint(root).expect("walking the repo");
    assert!(
        r.files_scanned > 40,
        "suspiciously few files scanned: {}",
        r.files_scanned
    );
    assert!(
        r.is_clean(),
        "live repo has lint findings:\n{}",
        r.render()
    );
}
