//! Differential byte-identity tests for the slice-parallel memory walk.
//!
//! `engine.mem_workers` is a host-performance knob: above 1 the phase-B2
//! slice walk fans out across persistent worker threads that each own a
//! contiguous run of L2 slices, at 1 the coordinator walks every slice
//! itself.  Either way the B1 front end, the DRAM admission sub-phase,
//! and the B3 finish pass run in canonical request order, so nothing
//! simulated may depend on the worker count — these tests are the
//! referee:
//!
//! 1. a differential fuzz runs seeded synthetic apps over every
//!    registered L1 organization and asserts the full metrics JSON is
//!    byte-identical at 2, 3, and 4 workers vs the serial walk — and
//!    that the identity survives composition with the cluster-sharded
//!    loop (`engine.shards`), the *other* host-parallelism axis;
//! 2. the same identity holds for the co-execution path
//!    ([`Engine::run_multi`]), including an over-provisioned request
//!    the walk pool clamps to the slice count;
//! 3. a worst-case partition ([`slice_skew_scenario`]: every fetch
//!    descriptor lands on one slice, so one worker does all the work
//!    while its siblings idle) proves the identity is not vacuous —
//!    descriptor scatter, same-epoch merge resolution, and the
//!    canonical DRAM sub-phase all run under maximal skew.

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::engine::{Engine, Workload};
use ata_cache::testkit::{check, int_range, slice_skew_scenario, vec_of};
use ata_cache::trace::{co_workload, synth};

/// Run one workload at a given (mem_workers, shards) pair and return the
/// result JSON.
fn run_with(cfg: &GpuConfig, wl: &Workload, mem_workers: usize, shards: usize) -> String {
    let mut cfg = cfg.clone();
    cfg.engine.mem_workers = mem_workers;
    cfg.engine.shards = shards;
    Engine::new(&cfg).run(wl).to_json().pretty()
}

/// Differential fuzz: seeded synthetic apps × every organization, full
/// metrics JSON byte-identical at every worker count, solo and composed
/// with the sharded engine loop.
#[test]
fn property_metrics_identical_at_any_worker_count() {
    // Each case draws [sharing, intensity, seed] and runs all archs.
    let gen = vec_of(int_range(0, 99), int_range(3, 3));
    check("memwalk-identity", 0x3A11C, 3, &gen, |draw| {
        let sharing = draw[0] as f64 / 100.0;
        let intensity = 0.15 + draw[1] as f64 / 400.0;
        let app = synth::locality_knob(sharing, intensity).scaled(0.3);
        for arch in L1ArchKind::ALL {
            let mut cfg = GpuConfig::tiny(arch);
            cfg.seed = 0x5EED ^ draw[2];
            let wl = app.workload(&cfg);
            let baseline = run_with(&cfg, &wl, 1, 1);
            for workers in [2usize, 3, 4] {
                let json = run_with(&cfg, &wl, workers, 1);
                if json != baseline {
                    return Err(format!(
                        "{arch:?}: metrics JSON depends on engine.mem_workers={workers} \
                         (sharing={sharing:.2} intensity={intensity:.2})"
                    ));
                }
            }
            // The two host-parallelism axes must compose: sharded
            // clusters feeding a fanned-out walk, still the same bytes.
            for shards in [1usize, 2] {
                let json = run_with(&cfg, &wl, 2, shards);
                if json != baseline {
                    return Err(format!(
                        "{arch:?}: metrics JSON depends on mem_workers=2 x shards={shards} \
                         (sharing={sharing:.2} intensity={intensity:.2})"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// The co-execution referee: partitioned lanes over a shared memory
/// system, byte-identical at any worker count — including an
/// over-provisioned request the pool clamps to the slice count.
#[test]
fn multi_json_is_byte_identical_at_any_worker_count() {
    let run = |mem_workers: usize| {
        let mut cfg = GpuConfig::tiny(L1ArchKind::Ata);
        cfg.engine.mem_workers = mem_workers;
        let models = vec![
            synth::locality_knob(0.7, 0.5),
            synth::convergent_hammer().scaled(0.25),
        ];
        let multi = co_workload(&cfg, &models, &[4, 4], false).expect("co-workload");
        Engine::new(&cfg).run_multi(&multi).unwrap().to_json().pretty()
    };
    let baseline = run(1);
    assert_eq!(
        run(3),
        baseline,
        "co-run metrics must not depend on engine.mem_workers"
    );
    assert_eq!(
        run(64),
        baseline,
        "over-provisioning must clamp to the slice count, not drift"
    );
}

/// The non-vacuity referee: every load decodes to one L2 slice, so one
/// walk worker owns every fetch descriptor while the others idle, and
/// the second streaming pass stacks same-epoch merges on the hammered
/// slice.  The fanned-out run must match the serial bytes under this
/// maximal skew, for both the worker counts that leave siblings empty.
#[test]
fn slice_skewed_traffic_is_byte_identical() {
    let (cfg, wl) = slice_skew_scenario(L1ArchKind::Ata);

    let r_serial = Engine::new(&cfg).run(&wl).unwrap();
    // The scenario must really stress the walk, or the byte-identity
    // below proves nothing.
    assert!(r_serial.dram_reads > 0, "no cold miss reached DRAM");
    assert!(r_serial.loads > 0, "scenario issued no loads");

    for workers in [2usize, 4] {
        let mut cfg_w = cfg.clone();
        cfg_w.engine.mem_workers = workers;
        let r_w = Engine::new(&cfg_w).run(&wl).unwrap();
        assert_eq!(
            r_w.to_json().pretty(),
            r_serial.to_json().pretty(),
            "slice-skewed metrics must not depend on engine.mem_workers={workers}"
        );
    }

    // And under the composed axes: the skewed walk inside the sharded
    // engine loop.
    let mut cfg_both = cfg.clone();
    cfg_both.engine.mem_workers = 4;
    cfg_both.engine.shards = 2;
    let r_both = Engine::new(&cfg_both).run(&wl).unwrap();
    assert_eq!(
        r_both.to_json().pretty(),
        r_serial.to_json().pretty(),
        "slice-skewed metrics must not depend on mem_workers x shards"
    );
}
