//! Differential referee for the fault-isolation layer: a poisoned grid
//! (one deadlocking job, one panicking job) must complete around its
//! failures, report them as typed data with stable diagnostic
//! snapshots, and serialize byte-identically at any `--threads` and
//! `--shards` — the determinism contract extended to failures.  Resume
//! from a completed-job manifest must reproduce the fresh run's bytes.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;

use ata_cache::config::{FaultKind, GpuConfig, L1ArchKind};
use ata_cache::coordinator::{Sweep, SweepResults};
use ata_cache::engine::{panic_message, Engine, SimError};
use ata_cache::exec::{job_seed, manifest_line, parse_manifest, JobOutput, JobRunner, SimJob};
use ata_cache::testkit::{deadlock_scenario, livelock_scenario};
use ata_cache::trace::synth;

fn tiny_sweep(threads: usize, shards: usize) -> Sweep {
    let mut cfg = GpuConfig::tiny(L1ArchKind::Private);
    cfg.engine.shards = shards;
    Sweep {
        cfg,
        archs: vec![L1ArchKind::Private, L1ArchKind::Ata],
        apps: vec![synth::locality_knob(0.8, 0.25), synth::pure_streaming().scaled(0.25)],
        scale: 1.0,
        threads,
    }
}

/// Materialize the sweep's jobs and poison two of them: the second job
/// deadlocks (a typed engine failure with a snapshot), the third
/// panics before simulating anything (exercising `catch_unwind`
/// containment).  Mirrors the CLI's `--inject` surface.
fn poisoned_run(threads: usize, shards: usize) -> SweepResults {
    let sweep = tiny_sweep(threads, shards);
    let mut jobs = sweep.grid().jobs();
    assert_eq!(jobs.len(), 4);
    jobs[1].cfg.engine.fault = FaultKind::Deadlock;
    jobs[2].cfg.engine.fault = FaultKind::Panic;
    sweep.run_jobs(&jobs, None, None)
}

#[test]
fn poisoned_grid_completes_with_typed_failures() {
    let r = poisoned_run(4, 1);
    // The two healthy jobs completed normally...
    assert_eq!(r.results.len(), 2);
    assert!(r.get(L1ArchKind::Private, "synth[s=0.80]").is_some());
    assert!(r.get(L1ArchKind::Ata, "synth[stream]").is_some());
    // ...and the two poisoned ones landed as typed data, in submission
    // order, instead of taking the sweep down.
    assert_eq!(r.failures.len(), 2, "{:?}", r.failures);
    let dead = &r.failures[0];
    assert_eq!(dead.job, "base/private/synth[stream]");
    assert_eq!(dead.kind, "deadlock");
    let snap = dead.snapshot.as_ref().expect("deadlock carries a snapshot");
    assert!(snap.cores_blocked > 0, "{snap:?}");
    assert_eq!(snap.cores_total, 8);
    let panicked = &r.failures[1];
    assert_eq!(panicked.job, "base/ata/synth[s=0.80]");
    assert_eq!(panicked.kind, "worker-panic");
    assert!(panicked.message.contains("injected fault: panic"), "{}", panicked.message);
    assert!(panicked.snapshot.is_none(), "a panic has no simulated state to snapshot");
    // Deterministic failures fail the serial retry too — `degraded`
    // (jobs that *recovered* on retry) must stay empty.
    assert!(r.degraded.is_empty(), "{:?}", r.degraded);
}

#[test]
fn failure_bytes_are_identical_across_threads_and_shards() {
    let baseline = poisoned_run(1, 1).to_json().pretty();
    for (threads, shards) in [(4, 1), (1, 2), (4, 2)] {
        let other = poisoned_run(threads, shards).to_json().pretty();
        assert_eq!(
            baseline, other,
            "poisoned grid drifted at threads={threads} shards={shards}"
        );
    }
}

#[test]
fn panicking_job_preserves_every_other_result() {
    // A panic-armed job among healthy ones, straight on the runner (the
    // layer under the sweep): the others' outputs are untouched.
    let cfg = GpuConfig::tiny(L1ArchKind::Ata);
    let wl = synth::locality_knob(0.8, 0.25).workload(&cfg);
    let mut poisoned_cfg = cfg.clone();
    poisoned_cfg.engine.fault = FaultKind::Panic;
    let jobs = vec![
        SimJob::solo("a", cfg.clone(), job_seed(cfg.seed, 0), wl.clone()),
        SimJob::solo("boom", poisoned_cfg, job_seed(cfg.seed, 1), wl.clone()),
        SimJob::solo("c", cfg.clone(), job_seed(cfg.seed, 2), wl.clone()),
    ];
    let outs = JobRunner::new(2).run(&jobs);
    assert_eq!(outs.len(), 3);
    let direct = Engine::new(&cfg).run(&wl).unwrap();
    for i in [0usize, 2] {
        let r = outs[i].clone().into_solo();
        assert_eq!(r.cycles, direct.cycles, "job {i} disturbed by its neighbor's panic");
        assert_eq!(r.insts, direct.insts);
    }
    let failed = outs[1].failure().expect("the poisoned job failed");
    assert_eq!(failed.kind, "worker-panic");
}

#[test]
fn run_map_reraises_the_first_failure_with_its_original_text() {
    // The generic fan-out has no failure-as-data shape, so it re-raises —
    // but only after every item ran, and with the original panic text
    // (the lossy slot-unwrap chain this replaced masked it).
    let runner = JobRunner::new(2);
    let items: Vec<u32> = (0..8).collect();
    let completed = Mutex::new(0u32);
    let err = catch_unwind(AssertUnwindSafe(|| {
        runner.run_map(&items, |_, &x| {
            if x == 3 {
                panic!("injected map failure on {x}");
            }
            *completed.lock().unwrap() += 1;
            x
        })
    }))
    .expect_err("a panicking item must re-raise");
    assert!(panic_message(err.as_ref()).contains("injected map failure on 3"));
    assert_eq!(*completed.lock().unwrap(), 7, "the other items all completed first");
}

#[test]
fn resume_from_manifest_reproduces_the_fresh_run_byte_for_byte() {
    let sweep = tiny_sweep(2, 1);
    let mut jobs = sweep.grid().jobs();
    jobs[1].cfg.engine.fault = FaultKind::Deadlock;
    jobs[2].cfg.engine.fault = FaultKind::Panic;

    // Fresh run, writing the manifest through the observer (in
    // completion order — resume is label-keyed, so order is free).
    let lines: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let writer = |job: &SimJob, out: &JobOutput| {
        lines.lock().unwrap().push(manifest_line(&job.label, out));
    };
    let fresh = sweep.run_jobs(&jobs, None, Some(&writer));
    let manifest = lines.into_inner().unwrap().join("\n");
    let cache = parse_manifest(&manifest);
    assert_eq!(cache.len(), 4, "every job (failures included) lands in the manifest");

    // Resumed run: every job short-circuits on the cache — the observer
    // must never fire — and the serialized output is byte-identical.
    let recompute_guard = |job: &SimJob, _out: &JobOutput| {
        panic!("job '{}' was recomputed despite a complete resume cache", job.label)
    };
    let resumed = sweep.run_jobs(&jobs, Some(&cache), Some(&recompute_guard));
    assert_eq!(fresh.to_json().pretty(), resumed.to_json().pretty());
}

#[test]
fn livelock_snapshot_is_identical_across_shard_counts() {
    let (cfg, wl) = livelock_scenario(L1ArchKind::Ata);
    let seq = Engine::new(&cfg).run(&wl).expect_err("livelock must abort");
    let mut cfg2 = cfg.clone();
    cfg2.engine.shards = 2;
    let sharded = Engine::new(&cfg2).run(&wl).expect_err("livelock must abort sharded too");
    match (&seq, &sharded) {
        (SimError::Livelock { snap: a, why: wa }, SimError::Livelock { snap: b, why: wb }) => {
            assert_eq!(a, b, "sharded snapshot drifted from sequential");
            assert_eq!(wa, wb);
        }
        other => panic!("expected two livelocks, got {other:?}"),
    }
}

#[test]
fn deadlock_snapshot_is_identical_across_shard_counts() {
    let (cfg, wl) = deadlock_scenario(L1ArchKind::Ata);
    let seq = Engine::new(&cfg).run(&wl).expect_err("deadlock must abort");
    let mut cfg2 = cfg.clone();
    cfg2.engine.shards = 2;
    let sharded = Engine::new(&cfg2).run(&wl).expect_err("deadlock must abort sharded too");
    match (&seq, &sharded) {
        (SimError::Deadlock(a), SimError::Deadlock(b)) => {
            assert_eq!(a, b, "sharded snapshot drifted from sequential");
        }
        other => panic!("expected two deadlocks, got {other:?}"),
    }
}
