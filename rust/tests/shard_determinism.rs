//! Differential byte-identity tests for the cluster-sharded engine loop.
//!
//! `engine.shards` is a host-performance knob: with it above 1 the
//! engine splits its clusters across persistent worker threads that run
//! between deterministic epoch barriers, with it at 1 the original
//! sequential loop runs untouched.  Nothing simulated may depend on the
//! shard count — these tests are the referee:
//!
//! 1. a differential fuzz runs seeded synthetic apps over every
//!    registered L1 organization on a four-cluster config and asserts
//!    the full metrics JSON is byte-identical at 2, 3, and 4 shards vs
//!    the sequential loop;
//! 2. the same identity holds for the co-execution path
//!    ([`Engine::run_multi`]), including over-sharded requests that the
//!    engine clamps to the cluster count;
//! 3. a traffic-heavy scenario ([`cross_shard_scenario`]) proves the
//!    identity is not vacuous: remote/ATA sharing hits and DRAM-bound
//!    misses both occur, and the shard telemetry shows transactions
//!    leaving their shards (egress) and fill wakes returning through
//!    the ingress FIFOs — while staying out of the result JSON.

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::engine::{Engine, Workload};
use ata_cache::stats::ShardStats;
use ata_cache::testkit::{check, cross_shard_scenario, int_range, vec_of};
use ata_cache::trace::{co_workload, synth};

/// Run one workload at a given shard count and return the result JSON
/// plus the engine's shard telemetry.
fn run_with_shards(cfg: &GpuConfig, wl: &Workload, shards: usize) -> (String, ShardStats) {
    let mut cfg = cfg.clone();
    cfg.engine.shards = shards;
    let mut eng = Engine::new(&cfg);
    let r = eng.run(wl);
    (r.to_json().pretty(), eng.shard_stats())
}

/// A 12-core / 4-cluster config so shard counts 2, 3, and 4 each
/// produce a distinct cluster partition (on [`GpuConfig::tiny`]'s 2
/// clusters the engine would clamp 3 and 4 back to 2 and the fuzz
/// would test the same split three times).
fn four_cluster_cfg(arch: L1ArchKind) -> GpuConfig {
    let mut cfg = GpuConfig::tiny(arch);
    cfg.cores = 12;
    cfg.clusters = 4;
    cfg.validate().expect("four-cluster fuzz config");
    cfg
}

/// Differential fuzz: seeded synthetic apps × every organization, full
/// metrics JSON byte-identical at every shard count.
#[test]
fn property_metrics_identical_at_any_shard_count() {
    // Each case draws [sharing, intensity, seed] and runs all archs.
    let gen = vec_of(int_range(0, 99), int_range(3, 3));
    check("shard-identity", 0x5AAD5, 4, &gen, |draw| {
        let sharing = draw[0] as f64 / 100.0;
        let intensity = 0.15 + draw[1] as f64 / 400.0;
        let app = synth::locality_knob(sharing, intensity).scaled(0.3);
        for arch in L1ArchKind::ALL {
            let mut cfg = four_cluster_cfg(arch);
            cfg.seed = 0x5EED ^ draw[2];
            let wl = app.workload(&cfg);
            let (baseline, seq_stats) = run_with_shards(&cfg, &wl, 1);
            if seq_stats != ShardStats::default() {
                return Err(format!(
                    "{arch:?}: the sequential loop touched shard telemetry: {seq_stats:?}"
                ));
            }
            for n in [2usize, 3, 4] {
                let (json, stats) = run_with_shards(&cfg, &wl, n);
                if json != baseline {
                    return Err(format!(
                        "{arch:?}: metrics JSON depends on engine.shards={n} \
                         (sharing={sharing:.2} intensity={intensity:.2})"
                    ));
                }
                if stats.shard_count != n as u64 {
                    return Err(format!(
                        "{arch:?}: asked for {n} shards, telemetry saw {}",
                        stats.shard_count
                    ));
                }
            }
        }
        Ok(())
    });
}

/// The co-execution referee: partitioned lanes over a shared memory
/// system, byte-identical at any shard count — including an
/// over-sharded request the engine clamps to the cluster count.
#[test]
fn multi_json_is_byte_identical_at_any_shard_count() {
    let run = |shards: usize| {
        let mut cfg = GpuConfig::tiny(L1ArchKind::Ata);
        cfg.engine.shards = shards;
        let models = vec![
            synth::locality_knob(0.7, 0.5),
            synth::convergent_hammer().scaled(0.25),
        ];
        let multi = co_workload(&cfg, &models, &[4, 4], false).expect("co-workload");
        Engine::new(&cfg).run_multi(&multi).unwrap().to_json().pretty()
    };
    let baseline = run(1);
    assert_eq!(
        run(2),
        baseline,
        "co-run metrics must not depend on engine.shards"
    );
    assert_eq!(
        run(64),
        baseline,
        "over-sharding must clamp to the cluster count, not drift"
    );
}

/// The non-vacuity referee: a scenario engineered so cluster-mates
/// share lines (remote/ATA hits — intra-cluster by construction, since
/// sharding is cluster-aligned) while every warp also streams cold
/// misses through the shared L2/DRAM walk.  The sharded run must match
/// the sequential bytes AND its telemetry must show real cross-shard
/// flow: transactions leaving their shard for the memory system and
/// fill wakes coming back through the ingress FIFOs.
#[test]
fn cross_shard_traffic_is_byte_identical_and_counted() {
    let (cfg, wl) = cross_shard_scenario(L1ArchKind::Ata);

    let mut cfg_seq = cfg.clone();
    cfg_seq.engine.shards = 1;
    let mut eng_seq = Engine::new(&cfg_seq);
    let r_seq = eng_seq.run(&wl).unwrap();
    assert_eq!(
        eng_seq.shard_stats(),
        ShardStats::default(),
        "sequential loop must not touch shard telemetry"
    );
    // The scenario must really exercise both traffic classes, or the
    // byte-identity below proves nothing.
    assert!(r_seq.l1.remote_hits > 0, "no sharing hit between cluster-mates");
    assert!(r_seq.dram_reads > 0, "no cold miss reached DRAM");

    let mut cfg_sh = cfg;
    cfg_sh.engine.shards = 2;
    let mut eng_sh = Engine::new(&cfg_sh);
    let r_sh = eng_sh.run(&wl).unwrap();
    assert_eq!(
        r_sh.to_json().pretty(),
        r_seq.to_json().pretty(),
        "cross-shard-heavy metrics must not depend on engine.shards"
    );
    let s = eng_sh.shard_stats();
    assert_eq!(s.shard_count, 2);
    assert!(s.epochs > 0, "sharded loop ran no epochs");
    assert!(s.egress_txns > 0, "no transaction left its shard for the shared walk");
    assert!(s.ingress_wakes > 0, "no fill wake returned through an ingress FIFO");
    // Same exclusion contract as EventStats/ResidencyStats: host
    // telemetry never serializes into results.
    let js = r_sh.to_json().to_string();
    assert!(
        !js.contains("egress_txns") && !js.contains("ingress_wakes"),
        "shard telemetry leaked into result JSON"
    );
}
