//! Differential byte-identity tests for the event-driven engine clock.
//!
//! `engine.event_driven` is a host-performance knob: with it on the
//! clock jumps straight to the next-event horizon, with it off the
//! engine ticks cycle by cycle as a reference.  Nothing simulated may
//! depend on which mode ran — these tests are the referee:
//!
//! 1. a differential fuzz runs seeded synthetic apps over every
//!    registered L1 organization and asserts the full metrics JSON is
//!    byte-identical on vs off;
//! 2. the same identity holds through the parallel execution layer
//!    (a threaded [`Sweep`]) and the co-execution path
//!    ([`Engine::run_multi`]);
//! 3. a reconciliation pin re-runs the latency-sum property of
//!    `integration_contention.rs` in both modes: the contention ledger
//!    is charged analytically at reservation time, so skipped intervals
//!    must neither add nor lose a single queued cycle.

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::coordinator::Sweep;
use ata_cache::core::{WarpInst, WarpProgram};
use ata_cache::engine::{Engine, KernelSpec, SWEEP_PERIOD, Workload};
use ata_cache::testkit::{check, int_range, sweep_crossing_scenario, vec_of};
use ata_cache::trace::{co_workload, synth};

/// Run one workload in both clock modes and return the two result JSONs
/// plus the on-mode engine telemetry sanity already applied.
fn run_both(cfg: &GpuConfig, wl: &Workload) -> (String, String) {
    let mut cfg_on = cfg.clone();
    cfg_on.engine.event_driven = true;
    let mut cfg_off = cfg.clone();
    cfg_off.engine.event_driven = false;
    let mut eng_on = Engine::new(&cfg_on);
    let r_on = eng_on.run(wl);
    let mut eng_off = Engine::new(&cfg_off);
    let r_off = eng_off.run(wl);
    // Telemetry invariants that hold for every workload: a fresh
    // engine's simulated-cycle count telescopes to the reported total,
    // and the reference clock never skips.
    assert_eq!(eng_on.event_stats().cycles_simulated, r_on.cycles);
    assert_eq!(eng_off.event_stats().skipped(), 0);
    (r_on.to_json().pretty(), r_off.to_json().pretty())
}

/// Differential fuzz: seeded synthetic apps × every organization, full
/// metrics JSON byte-identical with the event clock on vs off.
#[test]
fn property_metrics_identical_event_driven_on_and_off() {
    // Each case draws [sharing, intensity, seed] and runs all archs.
    let gen = vec_of(int_range(0, 99), int_range(3, 3));
    check("event-clock-identity", 0xE7D1F, 5, &gen, |draw| {
        let sharing = draw[0] as f64 / 100.0;
        let intensity = 0.15 + draw[1] as f64 / 400.0;
        let app = synth::locality_knob(sharing, intensity).scaled(0.3);
        for arch in L1ArchKind::ALL {
            let mut cfg = GpuConfig::tiny(arch);
            cfg.seed = 0xA11CE ^ draw[2];
            let wl = app.workload(&cfg);
            let (on, off) = run_both(&cfg, &wl);
            if on != off {
                return Err(format!(
                    "{arch:?}: metrics JSON depends on engine.event_driven \
                     (sharing={sharing:.2} intensity={intensity:.2})"
                ));
            }
        }
        Ok(())
    });
}

/// The acceptance referee through the execution layer: a threaded sweep
/// over all paper organizations and two seeded workloads must be
/// byte-identical with the event clock on vs off.
#[test]
fn sweep_json_is_byte_identical_event_driven_on_and_off() {
    let run = |event_driven: bool| {
        let mut cfg = GpuConfig::tiny(L1ArchKind::Private);
        cfg.engine.event_driven = event_driven;
        Sweep {
            cfg,
            archs: L1ArchKind::ALL.to_vec(),
            apps: vec![
                synth::locality_knob(0.8, 0.4),
                synth::convergent_hammer().scaled(0.25),
            ],
            scale: 1.0,
            threads: 2,
        }
        .run()
        .to_json()
        .pretty()
    };
    assert_eq!(
        run(true),
        run(false),
        "sweep metrics must not depend on engine.event_driven"
    );
}

/// Same referee for the co-execution path (`Engine::run_multi`), whose
/// shared memory system and per-app accounting must agree in both modes.
#[test]
fn multi_json_is_byte_identical_event_driven_on_and_off() {
    let run = |event_driven: bool| {
        let mut cfg = GpuConfig::tiny(L1ArchKind::Ata);
        cfg.engine.event_driven = event_driven;
        let models = vec![
            synth::locality_knob(0.7, 0.5),
            synth::convergent_hammer().scaled(0.25),
        ];
        let multi = co_workload(&cfg, &models, &[4, 4], false).expect("co-workload");
        Engine::new(&cfg).run_multi(&multi).unwrap().to_json().pretty()
    };
    assert_eq!(
        run(true),
        run(false),
        "co-run metrics must not depend on engine.event_driven"
    );
}

/// The sweep-timing referee: the engine periodically sweeps the L1/L2
/// in-flight maps, and L2 treats a *stale* in-flight entry differently
/// from an *absent* one (merge-window hit vs full DRAM trip), so the
/// sweep's simulated time is metric-visible.  This run crosses the
/// [`SWEEP_PERIOD`] boundary (asserted, not assumed) under L2 eviction
/// pressure with post-boundary re-reads — the exact shape where a
/// clock-cadence-dependent sweep cycle would make the two modes drift.
#[test]
fn sweep_boundary_crossing_run_is_byte_identical() {
    let (cfg, wl) = sweep_crossing_scenario(L1ArchKind::Ata);
    let mut cfg_on = cfg.clone();
    cfg_on.engine.event_driven = true;
    let mut cfg_off = cfg;
    cfg_off.engine.event_driven = false;
    let mut eng_on = Engine::new(&cfg_on);
    let r_on = eng_on.run(&wl).unwrap();
    // The scenario must really cross at least one sweep boundary while
    // the event clock jumps — otherwise this referee is vacuous.
    assert!(
        r_on.cycles > SWEEP_PERIOD,
        "scenario too short to cross the sweep boundary: {} <= {SWEEP_PERIOD}",
        r_on.cycles
    );
    assert!(
        eng_on.event_stats().skipped() > 0,
        "the stall-heavy run must exercise clock jumps"
    );
    // Some re-reads must take the absent-entry DRAM path (their
    // in-flight entries were swept); if every re-read merged into a
    // stale entry the sweep would be invisible and the run would prove
    // nothing about its timing.
    assert!(
        r_on.dram_reads > r_on.loads / 2,
        "no post-sweep re-read reached DRAM (reads {}, loads {}): \
         the sweep was not metric-visible in this run",
        r_on.dram_reads,
        r_on.loads
    );
    let mut eng_off = Engine::new(&cfg_off);
    let r_off = eng_off.run(&wl).unwrap();
    assert_eq!(eng_off.event_stats().skipped(), 0);
    assert_eq!(
        r_on.to_json().pretty(),
        r_off.to_json().pretty(),
        "metrics across a sweep boundary must not depend on engine.event_driven"
    );
}

/// Single-request load-only kernel, the shape under which every queued
/// cycle lies on exactly one tracked load's sequential path (see
/// `integration_contention.rs` for the structural argument).
fn load_only_workload(cfg: &GpuConfig, lines: &[u64]) -> Workload {
    let kernel = KernelSpec {
        name: "k".into(),
        programs: (0..cfg.cores)
            .map(|c| {
                (0..4usize)
                    .map(|w| {
                        let mut insts = Vec::new();
                        for r in 0..2usize {
                            let rot = (c * 4 + w + r) % lines.len().max(1);
                            let mut order: Vec<u64> = lines.to_vec();
                            order.rotate_left(rot);
                            for &line in &order {
                                insts.push(WarpInst::Load(vec![(line, 0b1111)]));
                            }
                            insts.push(WarpInst::Alu(2));
                        }
                        WarpProgram::new(insts)
                    })
                    .collect()
            })
            .collect(),
    };
    Workload {
        name: "contended".into(),
        kernels: vec![kernel],
    }
}

/// The reconciliation pin: skipped intervals are batch-charged into the
/// same ledger the reference clock fills in cycle by cycle, so the
/// breakdown must be identical in both modes AND the latency-sum bound
/// (Σ queued ≤ Σ load latency) must hold in both.
#[test]
fn property_batch_charges_reconcile_with_latency_sums_in_both_modes() {
    let gen = vec_of(int_range(0, 63), int_range(8, 24));
    check("event-clock-reconciles", 0xBA7C4, 6, &gen, |lines| {
        for arch in L1ArchKind::ALL {
            for event_driven in [true, false] {
                let mut cfg = GpuConfig::tiny(arch);
                cfg.engine.event_driven = event_driven;
                let wl = load_only_workload(&cfg, lines);
                let mut eng = Engine::new(&cfg);
                let r = eng.run(&wl).unwrap();
                if r.loads == 0 {
                    return Err(format!("{arch:?}: workload issued no loads"));
                }
                let latency_sum = r.l1_mean_load_latency * r.loads as f64;
                if r.contention.total() as f64 > latency_sum + 1.0 {
                    return Err(format!(
                        "{arch:?} event_driven={event_driven}: breakdown total {} \
                         exceeds latency sum {latency_sum}",
                        r.contention.total()
                    ));
                }
            }
            // And the two modes must agree byte for byte on this shape
            // too (the breakdown is part of the result JSON).
            let cfg = GpuConfig::tiny(arch);
            let wl = load_only_workload(&cfg, lines);
            let (on, off) = run_both(&cfg, &wl);
            if on != off {
                return Err(format!("{arch:?}: contended metrics depend on the clock mode"));
            }
        }
        Ok(())
    });
}
