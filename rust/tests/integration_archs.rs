//! Cross-architecture integration tests: the paper's qualitative claims
//! must hold end-to-end through the full engine.

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::coordinator::Sweep;
use ata_cache::engine::{run_workload, Engine};
use ata_cache::trace::synth;

fn sweep(archs: Vec<L1ArchKind>, apps: Vec<ata_cache::trace::AppModel>) -> ata_cache::coordinator::SweepResults {
    Sweep {
        cfg: GpuConfig::paper(L1ArchKind::Private),
        archs,
        apps,
        scale: 1.0,
        threads: 4,
    }
    .run()
}

#[test]
fn ata_matches_private_when_nothing_is_shared() {
    // §III-A: "for applications with low inter-core locality … ATA-Cache
    // is almost equivalent to the private cache".
    let r = sweep(
        vec![L1ArchKind::Private, L1ArchKind::Ata],
        vec![synth::pure_streaming().scaled(0.5)],
    );
    let n = r.norm_ipc(L1ArchKind::Ata, "synth[stream]").unwrap();
    assert!(
        (0.97..=1.05).contains(&n),
        "zero-sharing ATA must track private: {n}"
    );
    let ata = r.get(L1ArchKind::Ata, "synth[stream]").unwrap();
    assert_eq!(ata.l1.remote_hits, 0, "nothing to share");
}

#[test]
fn ata_beats_both_baselines_at_high_sharing() {
    let r = sweep(
        vec![
            L1ArchKind::Private,
            L1ArchKind::RemoteSharing,
            L1ArchKind::DecoupledSharing,
            L1ArchKind::Ata,
        ],
        vec![synth::locality_knob(0.9, 0.5)],
    );
    let app = "synth[s=0.90]";
    let ata = r.norm_ipc(L1ArchKind::Ata, app).unwrap();
    let dec = r.norm_ipc(L1ArchKind::DecoupledSharing, app).unwrap();
    let rem = r.norm_ipc(L1ArchKind::RemoteSharing, app).unwrap();
    assert!(ata > 1.0, "ATA must profit from sharing: {ata}");
    assert!(ata > dec, "ATA {ata} must beat decoupled {dec}");
    assert!(ata > rem, "ATA {ata} must beat remote-sharing {rem}");
}

#[test]
fn ata_exploits_sharing_monotonically() {
    let apps: Vec<_> = [0.0, 0.5, 0.95]
        .iter()
        .map(|&s| synth::locality_knob(s, 0.4))
        .collect();
    let names: Vec<&str> = apps.iter().map(|a| a.name).collect();
    let r = sweep(vec![L1ArchKind::Private, L1ArchKind::Ata], apps);
    let n0 = r.norm_ipc(L1ArchKind::Ata, names[0]).unwrap();
    let n2 = r.norm_ipc(L1ArchKind::Ata, names[2]).unwrap();
    assert!(
        n2 > n0 + 0.02,
        "ATA gain must grow with sharing: {n0} -> {n2}"
    );
}

#[test]
fn decoupled_craters_on_convergent_hammer() {
    let r = sweep(
        vec![L1ArchKind::Private, L1ArchKind::DecoupledSharing, L1ArchKind::Ata],
        vec![synth::convergent_hammer()],
    );
    let app = "synth[hammer]";
    let dec = r.norm_ipc(L1ArchKind::DecoupledSharing, app).unwrap();
    let ata = r.norm_ipc(L1ArchKind::Ata, app).unwrap();
    assert!(
        ata > dec,
        "convergence is decoupled's worst case: ata {ata} vs dec {dec}"
    );
    let d = r.get(L1ArchKind::DecoupledSharing, app).unwrap();
    assert!(
        d.l1.bank_conflict_cycles + d.l1.sharing_net_cycles > 0,
        "hammer must create serialization"
    );
}

#[test]
fn remote_sharing_pays_probe_critical_path() {
    // Global misses under remote-sharing must show a longer L1 stage than
    // under private (probe round trip before L2 dispatch).
    let r = sweep(
        vec![L1ArchKind::Private, L1ArchKind::RemoteSharing],
        vec![synth::pure_streaming().scaled(0.5)],
    );
    let lat = r.norm_latency(L1ArchKind::RemoteSharing, "synth[stream]").unwrap();
    assert!(lat > 1.1, "probe round trip must inflate miss path: {lat}x");
    let rem = r.get(L1ArchKind::RemoteSharing, "synth[stream]").unwrap();
    assert!(rem.l1.probes_sent > 0);
}

#[test]
fn engine_is_deterministic_across_archs_and_threads() {
    for arch in L1ArchKind::ALL {
        let cfg = GpuConfig::paper(arch);
        let wl = synth::locality_knob(0.6, 0.25).workload(&cfg);
        let a = run_workload(&cfg, &wl);
        let b = run_workload(&cfg, &wl);
        assert_eq!(a.cycles, b.cycles, "{arch:?} must be deterministic");
        assert_eq!(a.insts, b.insts);
        assert_eq!(a.l1.local_hits, b.l1.local_hits);
        assert_eq!(a.l1.remote_hits, b.l1.remote_hits);
    }
}

#[test]
fn replication_audit_private_vs_ata_vs_decoupled() {
    // After a fully-shared workload: private replicates everywhere,
    // decoupled holds exactly one copy, ATA replicates on use.
    let mk = || synth::convergent_hammer().scaled(0.5);
    let hot_line = 0u64; // hottest shared line lives at SHARED_BASE

    let cfg = GpuConfig::paper(L1ArchKind::Private);
    let mut eng = Engine::new(&cfg);
    eng.run(&mk().workload(&cfg)).unwrap();
    let priv_holders = (0..30).filter(|&c| eng.resident_lines(c).contains(&hot_line)).count();

    let cfg = GpuConfig::paper(L1ArchKind::DecoupledSharing);
    let mut eng = Engine::new(&cfg);
    eng.run(&mk().workload(&cfg)).unwrap();
    let dec_holders = (0..30).filter(|&c| eng.resident_lines(c).contains(&hot_line)).count();

    let cfg = GpuConfig::paper(L1ArchKind::Ata);
    let mut eng = Engine::new(&cfg);
    eng.run(&mk().workload(&cfg)).unwrap();
    let ata_holders = (0..30).filter(|&c| eng.resident_lines(c).contains(&hot_line)).count();

    assert!(priv_holders >= 25, "private replicates: {priv_holders}/30");
    assert!(dec_holders <= 3, "decoupled: one copy per cluster: {dec_holders}");
    assert!(ata_holders >= 25, "ATA replicates on use: {ata_holders}");
}

#[test]
fn stores_do_not_leak_across_archs() {
    // Write-heavy workload: every arch must finish and count writes.
    let mut app = synth::locality_knob(0.5, 0.3);
    app.kernels[0].write_fraction = 0.5;
    for arch in L1ArchKind::ALL {
        let cfg = GpuConfig::paper(arch);
        let r = run_workload(&cfg, &app.workload(&cfg));
        assert!(r.l1.writes > 0, "{arch:?} must process writes");
        assert!(r.cycles > 0);
    }
}

#[test]
fn dirty_remote_fallbacks_only_with_writeback_policy() {
    use ata_cache::config::WritePolicy;
    let mut app = synth::locality_knob(0.9, 0.3);
    app.kernels[0].write_fraction = 0.3;

    let mut cfg = GpuConfig::paper(L1ArchKind::Ata);
    cfg.l1.write_policy = WritePolicy::WriteBackLocal;
    let wb = run_workload(&cfg, &app.workload(&cfg));

    cfg.l1.write_policy = WritePolicy::WriteThrough;
    let wt = run_workload(&cfg, &app.workload(&cfg));

    assert!(wb.l1.dirty_remote_fallbacks > 0, "write-back-local creates dirty remotes");
    assert_eq!(wt.l1.dirty_remote_fallbacks, 0, "write-through never has dirty lines");
}
