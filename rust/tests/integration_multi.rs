//! Co-execution integration tests: determinism across thread counts,
//! per-app stat attribution, and the cross-application sharing behaviour
//! of the four L1 organizations under spatial multitasking.

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::coordinator::CoSchedSweep;
use ata_cache::engine::Engine;
use ata_cache::trace::{co_workload, synth};

fn tiny_pair(arch: L1ArchKind) -> (GpuConfig, ata_cache::engine::MultiWorkload) {
    let cfg = GpuConfig::tiny(arch);
    let a = synth::locality_knob(0.8, 0.25);
    let b = synth::pure_streaming().scaled(0.25);
    let multi = co_workload(&cfg, &[a, b], &[4, 4], false).unwrap();
    (cfg, multi)
}

#[test]
fn all_four_archs_co_execute_to_completion() {
    for arch in L1ArchKind::ALL {
        let (cfg, multi) = tiny_pair(arch);
        let r = Engine::new(&cfg).run_multi(&multi).unwrap();
        assert_eq!(r.arch, arch.name(), "arch recorded");
        assert_eq!(r.apps.len(), 2);
        for app in &r.apps {
            assert!(app.insts > 0, "{}: {} issued nothing", arch.name(), app.name);
            assert!(app.finish_cycle > 0);
            assert!(app.ipc() > 0.0);
        }
    }
}

#[test]
fn per_app_attribution_sums_to_global_totals() {
    for arch in [L1ArchKind::Private, L1ArchKind::Ata] {
        let (cfg, multi) = tiny_pair(arch);
        let r = Engine::new(&cfg).run_multi(&multi).unwrap();
        assert_eq!(
            r.insts,
            r.apps.iter().map(|a| a.insts).sum::<u64>(),
            "{}: instruction attribution must partition the total",
            arch.name()
        );
        assert_eq!(
            r.l1.accesses,
            r.apps.iter().map(|a| a.requests).sum::<u64>(),
            "{}: every L1 access belongs to exactly one app",
            arch.name()
        );
        assert_eq!(
            r.cycles,
            r.apps.iter().map(|a| a.finish_cycle).max().unwrap(),
            "{}: the co-run ends when the last app finishes",
            arch.name()
        );
        // Per-kernel attribution nests inside per-app attribution.
        for app in &r.apps {
            assert_eq!(
                app.insts,
                app.kernels.iter().map(|k| k.insts).sum::<u64>(),
                "kernel insts sum to app insts"
            );
        }
    }
}

#[test]
fn co_execution_is_deterministic_across_runs_and_thread_counts() {
    // The co-run itself is single-threaded and deterministic; the sweep
    // around it must stay deterministic for any worker count.
    let sweep = |threads: usize| CoSchedSweep {
        cfg: GpuConfig::tiny(L1ArchKind::Private),
        archs: vec![L1ArchKind::Private, L1ArchKind::Ata],
        apps: vec![synth::locality_knob(0.8, 0.25), synth::pure_streaming().scaled(0.25)],
        scale: 1.0,
        threads,
        share_address_space: false,
    };
    let a = sweep(1).run();
    let b = sweep(4).run();
    assert_eq!(a.pairs.len(), b.pairs.len());
    for (x, y) in a.pairs.iter().zip(&b.pairs) {
        assert_eq!((x.i, x.j), (y.i, y.j));
        assert_eq!(x.result.cycles, y.result.cycles);
        assert_eq!(x.result.insts, y.result.insts);
        assert_eq!(x.result.l1.local_hits, y.result.l1.local_hits);
        assert_eq!(x.result.l1.remote_hits, y.result.l1.remote_hits);
        for (ax, ay) in x.result.apps.iter().zip(&y.result.apps) {
            assert_eq!(ax.finish_cycle, ay.finish_cycle);
            assert_eq!(ax.mean_load_latency, ay.mean_load_latency);
        }
    }
    for (x, y) in a.solos.iter().zip(&b.solos) {
        assert_eq!(x.result.cycles, y.result.cycles);
    }
}

#[test]
fn cross_app_sharing_becomes_remote_hits_on_ata_but_not_private() {
    // Two single-core instances of a high-sharing app in ONE cluster,
    // sharing the address space (read-shared input).  Every line one
    // app's core fills can only be remote-hit by the *other* app, so any
    // remote hit is cross-application by construction.
    let mut cfg = GpuConfig::tiny(L1ArchKind::Ata);
    cfg.cores = 2;
    cfg.clusters = 1;
    cfg.sharing.ata_comparator_groups = 2;
    cfg.validate().unwrap();
    let app = synth::locality_knob(0.9, 0.5);
    let multi = co_workload(&cfg, &[app.clone(), app.clone()], &[1, 1], true).unwrap();
    let ata = Engine::new(&cfg).run_multi(&multi).unwrap();
    assert!(
        ata.l1.remote_hits + ata.l1.mshr_merges > 0,
        "cross-app sharing must be exploited: {:?}",
        ata.l1
    );

    let mut cfg_p = cfg.clone();
    cfg_p.l1_arch = L1ArchKind::Private;
    let private = Engine::new(&cfg_p).run_multi(&multi).unwrap();
    assert_eq!(private.l1.remote_hits, 0, "private caches cannot share");
    assert!(
        ata.l1.misses <= private.l1.misses,
        "ATA must not add misses: {} vs {}",
        ata.l1.misses,
        private.l1.misses
    );

    // With disjoint address spaces the same pairing shares nothing.
    let isolated = co_workload(&cfg, &[app.clone(), app], &[1, 1], false).unwrap();
    let iso = Engine::new(&cfg).run_multi(&isolated).unwrap();
    assert_eq!(iso.l1.remote_hits, 0, "isolated apps must not share lines");
}

#[test]
fn solo_baseline_brackets_co_run_interference() {
    // Sanity on the slowdown metric: co-running with a streaming app
    // must not *speed up* the victim beyond noise, and the slowdown
    // lookups must be populated for every (victim, co-runner) pair.
    let sweep = CoSchedSweep {
        cfg: GpuConfig::tiny(L1ArchKind::Private),
        archs: vec![L1ArchKind::Private],
        apps: vec![synth::locality_knob(0.8, 0.25), synth::pure_streaming().scaled(0.25)],
        scale: 1.0,
        threads: 2,
        share_address_space: false,
    };
    let r = sweep.run();
    for x in 0..2 {
        for y in 0..2 {
            let s = r.slowdown(L1ArchKind::Private, x, y).unwrap();
            assert!(
                s > 0.95,
                "co-running cannot meaningfully speed up {x} vs {y}: {s}"
            );
            assert!(s < 100.0, "slowdown out of range: {s}");
        }
    }
}
