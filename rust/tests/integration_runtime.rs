//! Locality-runtime integration: the analytics pipeline classifying the
//! actual workload models, cross-checked against the simulator-side
//! replication audit.  Runs against the native pipeline; when an AOT
//! metadata sidecar exists under `artifacts/` its shapes are honoured.

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::engine::Engine;
use ata_cache::runtime::LocalityAnalyzer;
use ata_cache::trace::signature::{exact_locality, sample_core_traces};
use ata_cache::trace::{apps, LocalityClass};

fn analyzer() -> LocalityAnalyzer {
    LocalityAnalyzer::load("artifacts").expect("analyzer loads")
}

#[test]
fn artifact_classifies_all_ten_apps_like_the_paper() {
    let an = analyzer();
    let cfg = GpuConfig::paper(L1ArchKind::Private);
    let mut high_scores: Vec<f32> = Vec::new();
    let mut low_scores: Vec<f32> = Vec::new();
    for app in apps::all_apps() {
        let traces = sample_core_traces(&app.workload(&cfg), cfg.cores, an.meta().trace_len);
        let report = an.analyze(&traces).unwrap();
        match app.class {
            LocalityClass::High => high_scores.push(report.locality_score),
            LocalityClass::Low => low_scores.push(report.locality_score),
        }
    }
    let min_high = high_scores.iter().cloned().fold(f32::MAX, f32::min);
    let max_low = low_scores.iter().cloned().fold(f32::MIN, f32::max);
    assert!(
        min_high > max_low,
        "classes must separate: min(high)={min_high} max(low)={max_low}"
    );
}

#[test]
fn artifact_score_tracks_exact_sets_on_app_traces() {
    let an = analyzer();
    let cfg = GpuConfig::paper(L1ArchKind::Private);
    for name in ["SN", "doitgen", "hotspot"] {
        let app = apps::app(name).unwrap();
        let traces = sample_core_traces(&app.workload(&cfg), cfg.cores, an.meta().trace_len);
        let report = an.analyze(&traces).unwrap();
        let (exact, exact_repl) = exact_locality(&traces);
        assert!(
            (report.locality_score as f64 - exact).abs() < 0.05,
            "{name}: artifact {} vs exact {exact}",
            report.locality_score
        );
        assert!(
            (report.replication_factor as f64 - exact_repl).abs() / exact_repl < 0.15,
            "{name}: repl {} vs exact {exact_repl}",
            report.replication_factor
        );
    }
}

#[test]
fn artifact_replication_matches_simulator_cache_audit() {
    // End-to-end cross-check: run the hammer workload on the private
    // simulator, audit which cores hold replicated lines, and confirm the
    // artifact's replication factor agrees in direction (hammer >> stream).
    let an = analyzer();
    let cfg = GpuConfig::paper(L1ArchKind::Private);

    let hammer = ata_cache::trace::synth::convergent_hammer();
    let stream = ata_cache::trace::synth::pure_streaming();

    let t_hammer = sample_core_traces(&hammer.workload(&cfg), cfg.cores, an.meta().trace_len);
    let t_stream = sample_core_traces(&stream.workload(&cfg), cfg.cores, an.meta().trace_len);
    let r_hammer = an.analyze(&t_hammer).unwrap();
    let r_stream = an.analyze(&t_stream).unwrap();
    // hammer: 16 shared + 64 private lines/core -> repl ≈ 2400/1936 ≈ 1.24;
    // stream: fully disjoint -> repl ≈ 1.0.
    assert!(
        (r_stream.replication_factor - 1.0).abs() < 0.05,
        "stream must be replication-free: {}",
        r_stream.replication_factor
    );
    assert!(
        r_hammer.replication_factor > r_stream.replication_factor + 0.2,
        "hammer {} vs stream {}",
        r_hammer.replication_factor,
        r_stream.replication_factor
    );

    // The simulator's tag-array audit must agree: hammer's hot line is
    // replicated in (almost) every private cache.
    let mut eng = Engine::new(&cfg);
    eng.run(&hammer.scaled(0.5).workload(&cfg)).unwrap();
    let holders = (0..cfg.cores)
        .filter(|&c| eng.resident_lines(c).contains(&0u64))
        .count();
    assert!(holders >= 25, "hot line replicated in {holders}/30 caches");
}
