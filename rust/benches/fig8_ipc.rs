//! Bench: regenerate Fig 8 — overall IPC of remote-sharing,
//! decoupled-sharing and ATA-Cache normalized to the private cache, for
//! all ten applications, plus the paper's headline averages.
//!
//!     cargo bench --bench fig8_ipc [-- --quick]

use ata_cache::bench_harness::{bench_prelude, sim_throughput};
use ata_cache::config::L1ArchKind;
use ata_cache::coordinator::Sweep;
use ata_cache::stats::RunTotals;
use ata_cache::trace::{apps, LocalityClass};
use ata_cache::util::table::{pct_delta, Table};
use std::time::Instant;

fn main() {
    let quick = bench_prelude("fig8_ipc — overall performance (paper Fig 8)");
    let scale = if quick { 0.25 } else { 0.5 };

    let t0 = Instant::now();
    let sweep = Sweep::paper(scale);
    let results = sweep.run();
    let host = t0.elapsed().as_secs_f64();

    let mut t = Table::new("Fig 8 — IPC normalized to private").header(&[
        "app", "class", "remote", "decoupled", "ata",
    ]);
    for app in apps::all_apps() {
        t.row(vec![
            app.name.to_string(),
            format!("{:?}", app.class),
            format!("{:.3}", results.norm_ipc(L1ArchKind::RemoteSharing, app.name).unwrap()),
            format!("{:.3}", results.norm_ipc(L1ArchKind::DecoupledSharing, app.name).unwrap()),
            format!("{:.3}", results.norm_ipc(L1ArchKind::Ata, app.name).unwrap()),
        ]);
    }
    println!("{}", t.render());

    let ata_high = results.class_geomean_ipc(L1ArchKind::Ata, LocalityClass::High);
    let ata_low = results.class_geomean_ipc(L1ArchKind::Ata, LocalityClass::Low);
    let dec_low = results.class_geomean_ipc(L1ArchKind::DecoupledSharing, LocalityClass::Low);
    println!("ATA on high-locality apps:       {} (paper: +12.0%)", pct_delta(ata_high));
    println!("ATA on low-locality apps:        {} (paper: no impairment)", pct_delta(ata_low));
    println!(
        "ATA vs decoupled on low-locality: {} (paper: +22.9%)",
        pct_delta(ata_low / dec_low)
    );

    // Order-preserving per-job totals (results arrive in submission
    // order from the execution layer).
    let mut totals = RunTotals::default();
    for r in &results.results {
        totals.absorb_sim(r);
    }
    println!(
        "\nhost: {:.1}s wall over {} jobs, {:.2}M simulated cycles/s aggregate",
        host,
        totals.runs,
        sim_throughput(totals.cycles, host) / 1e6
    );
}
