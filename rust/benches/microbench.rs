//! Component microbenchmarks + the simulator-throughput baseline used by
//! the performance pass (EXPERIMENTS.md §Perf).
//!
//! * tag array / MSHR / calendar / iSLIP op rates,
//! * detailed iSLIP crossbar vs reservation twin (model-agreement check),
//! * DRAM model service rate,
//! * end-to-end engine throughput (simulated cycles per host second),
//! * engine-clock A/B: event-driven vs cycle-by-cycle reference on the
//!   testkit stall-heavy scenario (EXPERIMENTS.md §Perf P4).
//!
//!     cargo bench --bench microbench [-- --quick]

use ata_cache::bench_harness::{bench_prelude, measure, sim_throughput};
use ata_cache::cache::TagArray;
use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::dram::Dram;
use ata_cache::engine::Engine;
use ata_cache::noc::{Crossbar, Islip, Packet, XbarReservation};
use ata_cache::resource::Calendar;
use ata_cache::trace::apps;
use ata_cache::util::rng::Pcg32;
use ata_cache::util::table::Table;

fn main() {
    let quick = bench_prelude("microbench — component rates + engine throughput");
    let n = if quick { 100_000 } else { 1_000_000 };
    let mut t = Table::new("component rates").header(&["component", "ops", "ns/op", "Mops/s"]);
    let mut record = |name: &str, ops: u64, secs: f64| {
        t.row(vec![
            name.to_string(),
            ops.to_string(),
            format!("{:.1}", secs * 1e9 / ops as f64),
            format!("{:.2}", ops as f64 / secs / 1e6),
        ]);
    };

    // Tag array lookups (hit-heavy).
    {
        let mut ta = TagArray::new(8, 64);
        for l in 0..512u64 {
            ta.fill(l, 0b1111);
        }
        let mut rng = Pcg32::new(1, 1);
        let timing = measure(1, 3, || {
            let mut acc = 0u64;
            for _ in 0..n {
                let line = rng.next_below(512) as u64;
                if matches!(ta.peek(line, 0b1111), ata_cache::cache::Probe::Hit { .. }) {
                    acc += 1;
                }
            }
            std::hint::black_box(acc);
        });
        record("tag_array.peek (hit)", n as u64, timing.mean_s);
    }

    // Calendar reservations with mixed past/future times.
    {
        let mut cal = Calendar::new();
        let mut rng = Pcg32::new(2, 2);
        let mut now = 0u64;
        let timing = measure(1, 3, || {
            for _ in 0..n {
                now += (rng.next_below(3)) as u64;
                let t = now + rng.next_below(200) as u64;
                std::hint::black_box(cal.reserve(t, 2));
            }
        });
        record("calendar.reserve", n as u64, timing.mean_s);
    }

    // iSLIP arbitration, 30x24 (the Table II fabric size).
    {
        let mut arb = Islip::new(30, 24);
        let mut rng = Pcg32::new(3, 3);
        let iters = (n / 100).max(1);
        let timing = measure(1, 3, || {
            for _ in 0..iters {
                let wants: Vec<Vec<bool>> = (0..30)
                    .map(|_| (0..24).map(|_| rng.chance(0.2)).collect())
                    .collect();
                std::hint::black_box(arb.arbitrate(&wants, 2));
            }
        });
        record("islip.arbitrate 30x24", iters as u64, timing.mean_s);
    }

    // Aggregated-tag probe at the paper's cluster size (10 caches):
    // O(1) residency-index lookup vs the O(cluster) brute-force scan —
    // the per-request work the residency index removes (EXPERIMENTS.md
    // §Perf, residency-index A/B).
    {
        use ata_cache::l1arch::ata_tag::AggregatedTagArray;
        use ata_cache::l1arch::common::CoreL1;
        use ata_cache::l1arch::ResidencyIndex;
        let cfg = GpuConfig::paper(L1ArchKind::Ata);
        let mut cluster: Vec<CoreL1> = (0..10).map(|_| CoreL1::new(&cfg)).collect();
        let mut index = ResidencyIndex::new();
        let mut rng = Pcg32::new(6, 6);
        for _ in 0..4_000 {
            let h = rng.next_below(10) as usize;
            let line = rng.next_below(2048) as u64;
            let (_, ev) = cluster[h].cache.fill(line, 0b1111);
            if let Some(ev) = ev {
                index.record_evict(h, ev.line);
            }
            index.record_fill(h, line, 0b1111);
        }
        let mut rng2 = Pcg32::new(7, 7);
        let timing = measure(1, 3, || {
            let mut acc = 0u64;
            for _ in 0..n {
                let line = rng2.next_below(2048) as u64;
                // Mirror the real fast path (PipelineCtx::ata_probe):
                // one local peek + one index lookup, so the comparison
                // against the scan row is apples-to-apples.
                if matches!(
                    cluster[0].cache.peek(line, 0b1111),
                    ata_cache::cache::Probe::Hit { .. }
                ) {
                    acc += 1;
                }
                acc += index.probe(line, 0b1111, 0).0.count_ones() as u64;
            }
            std::hint::black_box(acc);
        });
        record("ata probe: residency index (10 caches)", n as u64, timing.mean_s);
        let mut rng3 = Pcg32::new(7, 7);
        let scans = (n / 4).max(1);
        let timing = measure(1, 3, || {
            let mut acc = 0u64;
            for _ in 0..scans {
                let line = rng3.next_below(2048) as u64;
                acc += AggregatedTagArray::probe(&cluster, 0, line, 0b1111)
                    .remote_holder_count() as u64;
            }
            std::hint::black_box(acc);
        });
        record("ata probe: brute-force scan (10 caches)", scans as u64, timing.mean_s);
    }

    // DRAM accesses.
    {
        let cfg = GpuConfig::paper(L1ArchKind::Private);
        let mut dram = Dram::new(&cfg.dram, cfg.core_clock_ghz);
        let mut rng = Pcg32::new(4, 4);
        let mut now = 0u64;
        let timing = measure(1, 3, || {
            for _ in 0..n / 4 {
                now += 2;
                std::hint::black_box(dram.access(rng.next_u32() as u64 & 0xFFFFF, now, 4, false));
            }
        });
        record("dram.access", (n / 4) as u64, timing.mean_s);
    }
    println!("{}", t.render());

    // Detailed iSLIP crossbar vs reservation twin under hotspot traffic.
    {
        let pkts = if quick { 2_000 } else { 20_000 };
        let mut det: Crossbar<u32> = Crossbar::new(8, 4, 1 << 20, 2);
        let mut rng = Pcg32::new(5, 5);
        let dsts: Vec<usize> = (0..pkts).map(|_| (rng.next_below(4)) as usize).collect();
        for (k, &d) in dsts.iter().enumerate() {
            det.offer(k % 8, Packet { dst: d, flits: 4, payload: 0 });
        }
        let mut det_cycles = 0u64;
        let mut got = 0;
        while got < pkts {
            det.tick();
            det_cycles += 1;
            got += det.drain().len();
        }
        let mut res = XbarReservation::new(8, 4, 0, u64::MAX);
        let mut last = 0u64;
        for (k, &d) in dsts.iter().enumerate() {
            last = last.max(res.transfer(k % 8, d, 0, 4).grant);
        }
        println!(
            "crossbar model agreement (hotspot, {pkts} pkts): detailed {det_cycles} cyc vs reservation {last} cyc ({:+.1}%)",
            (last as f64 / det_cycles as f64 - 1.0) * 100.0
        );
    }

    // Engine throughput baseline (the §Perf number).
    {
        let cfg = GpuConfig::paper(L1ArchKind::Ata);
        let app = apps::app("cfd").unwrap().scaled(if quick { 0.25 } else { 0.5 });
        let wl = app.workload(&cfg);
        let timing = measure(1, 3, || {
            let r = Engine::new(&cfg).run(&wl).unwrap();
            std::hint::black_box(r.cycles);
        });
        let r = Engine::new(&cfg).run(&wl).unwrap();
        println!(
            "engine throughput (cfd/ata): {:.2}M simulated cycles/s, {:.2}M requests/s",
            sim_throughput(r.cycles, timing.mean_s) / 1e6,
            wl.total_requests() as f64 / timing.mean_s / 1e6,
        );
    }

    // Engine-clock A/B on the stall-heavy scenario (EXPERIMENTS.md §Perf
    // P4): event-driven jumps vs the cycle-by-cycle reference on a
    // workload that is mostly skippable cycles — the component-level
    // counterpart of the `ata-sim bench` three-way grid.
    {
        let (cfg_on, wl) = ata_cache::testkit::stall_heavy_scenario(L1ArchKind::Ata);
        let mut cfg_off = cfg_on.clone();
        cfg_off.engine.event_driven = false;
        let t_on = measure(1, 3, || {
            let r = Engine::new(&cfg_on).run(&wl).unwrap();
            std::hint::black_box(r.cycles);
        });
        let t_off = measure(1, 3, || {
            let r = Engine::new(&cfg_off).run(&wl).unwrap();
            std::hint::black_box(r.cycles);
        });
        let mut eng = Engine::new(&cfg_on);
        let cycles = eng.run(&wl).unwrap().cycles;
        let ev = eng.event_stats();
        println!(
            "engine clock A/B (stall-heavy/ata): event {:.2}M cyc/s vs reference {:.2}M cyc/s \
             = {:.2}x; skip ratio {:.1}% ({} ticks for {} cycles)",
            sim_throughput(cycles, t_on.mean_s) / 1e6,
            sim_throughput(cycles, t_off.mean_s) / 1e6,
            if t_on.mean_s > 0.0 { t_off.mean_s / t_on.mean_s } else { 0.0 },
            100.0 * ev.skipped() as f64 / ev.cycles_simulated.max(1) as f64,
            ev.cycles_ticked,
            ev.cycles_simulated,
        );
    }
}
