//! Ablation studies over ATA-Cache's design choices (DESIGN.md §3) — the
//! knobs the paper fixes but does not sweep:
//!
//!   A1. comparator-group provisioning (paper: one group per core)
//!   A2. cluster size (paper: 3 clusters of 10)
//!   A3. fill-local-on-remote-hit (paper Fig 7a fills the local cache)
//!   A4. write policy (paper: local write-back with dirty bits)
//!   A5. remote-sharing probe predictor (Ibrahim PACT'19 baseline variant)
//!
//!     cargo bench --bench ablations [-- --quick]

use ata_cache::bench_harness::bench_prelude;
use ata_cache::config::{GpuConfig, L1ArchKind, WritePolicy};
use ata_cache::engine::Engine;
use ata_cache::trace::apps;
use ata_cache::util::table::Table;

fn run(cfg: &GpuConfig, app: &str, scale: f64) -> ata_cache::stats::SimResult {
    let wl = apps::app(app).unwrap().scaled(scale).workload(cfg);
    Engine::new(cfg).run(&wl).unwrap()
}

fn main() {
    let quick = bench_prelude("ablations — ATA design-choice sweeps");
    let scale = if quick { 0.25 } else { 0.5 };
    let app = "SN"; // high-locality app with heavy remote-hit traffic

    let base_private = run(&GpuConfig::paper(L1ArchKind::Private), app, scale);
    let base_ipc = base_private.ipc();

    // A1: comparator groups.
    let mut t = Table::new(&format!("A1 — comparator groups ({app}, norm IPC)"))
        .header(&["groups", "norm IPC", "L1 stage lat"]);
    for groups in [10usize, 5, 2, 1] {
        let mut cfg = GpuConfig::paper(L1ArchKind::Ata);
        // A narrower aggregated tag array arbitrates lookups.
        cfg.sharing.ata_comparator_groups = groups.max(1);
        if cfg.sharing.ata_comparator_groups < cfg.cores_per_cluster() {
            // validation requires groups >= cluster; emulate narrow arrays
            // by scaling the tag latency instead (queueing-equivalent).
            cfg.sharing.ata_comparator_groups = cfg.cores_per_cluster();
            cfg.sharing.ata_tag_latency =
                2 * (cfg.cores_per_cluster() as u32 / groups.max(1) as u32).max(1);
        }
        let r = run(&cfg, app, scale);
        t.row(vec![
            groups.to_string(),
            format!("{:.3}", r.ipc() / base_ipc),
            format!("{:.1}", r.l1_stage_mean_latency),
        ]);
    }
    println!("{}", t.render());

    // A2: cluster size (same 30 cores).
    let mut t = Table::new("A2 — cluster size (30 cores, norm IPC)").header(&[
        "cores/cluster",
        "norm IPC",
        "remote hits",
        "stage lat",
    ]);
    for (cpc, clusters) in [(5usize, 6usize), (6, 5), (10, 3), (15, 2), (30, 1)] {
        let mut cfg = GpuConfig::paper(L1ArchKind::Ata);
        cfg.cores = cpc * clusters;
        cfg.clusters = clusters;
        cfg.sharing.ata_comparator_groups = cpc;
        let r = run(&cfg, app, scale);
        t.row(vec![
            cpc.to_string(),
            format!("{:.3}", r.ipc() / base_ipc),
            r.l1.remote_hits.to_string(),
            format!("{:.1}", r.l1_stage_mean_latency),
        ]);
    }
    println!("{}", t.render());

    // A3: fill local on remote hit.
    let mut t = Table::new("A3 — fill-local-on-remote-hit").header(&[
        "fill_local",
        "norm IPC",
        "local hits",
        "remote hits",
    ]);
    for fill in [true, false] {
        let mut cfg = GpuConfig::paper(L1ArchKind::Ata);
        cfg.sharing.fill_local_on_remote_hit = fill;
        let r = run(&cfg, app, scale);
        t.row(vec![
            fill.to_string(),
            format!("{:.3}", r.ipc() / base_ipc),
            r.l1.local_hits.to_string(),
            r.l1.remote_hits.to_string(),
        ]);
    }
    println!("{}", t.render());

    // A4: write policy.
    let mut t = Table::new("A4 — write policy").header(&[
        "policy",
        "norm IPC",
        "dirty fallbacks",
        "L2 writes",
    ]);
    for (name, wp) in [
        ("write-back-local", WritePolicy::WriteBackLocal),
        ("write-through", WritePolicy::WriteThrough),
    ] {
        let mut cfg = GpuConfig::paper(L1ArchKind::Ata);
        cfg.l1.write_policy = wp;
        let r = run(&cfg, app, scale);
        t.row(vec![
            name.to_string(),
            format!("{:.3}", r.ipc() / base_ipc),
            r.l1.dirty_remote_fallbacks.to_string(),
            r.dram_writes.to_string(),
        ]);
    }
    println!("{}", t.render());

    // A5: remote-sharing probe predictor (baseline-side ablation).
    let mut t = Table::new("A5 — remote-sharing probe predictor (doitgen, norm IPC)").header(&[
        "predictor",
        "accuracy",
        "norm IPC",
        "probes sent",
    ]);
    let base_d = run(&GpuConfig::paper(L1ArchKind::Private), "doitgen", scale).ipc();
    for (on, acc) in [(false, 0.0), (true, 0.5), (true, 0.8), (true, 0.95)] {
        let mut cfg = GpuConfig::paper(L1ArchKind::RemoteSharing);
        cfg.sharing.probe_predictor = on;
        cfg.sharing.predictor_accuracy = acc;
        let r = run(&cfg, "doitgen", scale);
        t.row(vec![
            on.to_string(),
            format!("{acc:.2}"),
            format!("{:.3}", r.ipc() / base_d),
            r.l1.probes_sent.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("(paper context: the PACT'19 predictor recovers part of remote-sharing's");
    println!(" loss on low-locality apps by skipping futile probe round trips)");
}
