//! Bench: regenerate Table I — the qualitative landscape of GPU shared L1
//! caches, with every star derived from measured sweep metrics.
//!
//!     cargo bench --bench table1_landscape [-- --quick]

use ata_cache::bench_harness::bench_prelude;
use ata_cache::config::L1ArchKind;
use ata_cache::coordinator::{landscape, Sweep};
use ata_cache::util::table::Table;

fn main() {
    let quick = bench_prelude("table1_landscape — measured Table I");
    let scale = if quick { 0.25 } else { 0.5 };
    let sweep = Sweep::paper(scale);
    let results = sweep.run();

    // Raw metric table first (the evidence behind the stars).
    let mut raw = Table::new("raw per-architecture metrics").header(&[
        "arch",
        "hit rate",
        "ipc high",
        "ipc low",
        "lat ratio",
        "L2-BW ratio",
        "contention/access",
    ]);
    for &arch in &L1ArchKind::ALL {
        let m = landscape::metrics_for(&results, arch);
        raw.row(vec![
            arch.name().to_string(),
            format!("{:.3}", m.hit_rate),
            format!("{:.3}", m.ipc_high),
            format!("{:.3}", m.ipc_low),
            format!("{:.2}x", m.latency_ratio),
            format!("{:.2}x", m.l2_bw_ratio),
            format!("{:.2}", m.contention_per_access),
        ]);
    }
    println!("{}", raw.render());

    let rows = landscape::build(&results, &L1ArchKind::ALL);
    println!("{}", landscape::render(&rows));

    // The paper's claim: ATA ties-or-wins every column.
    let ata = rows.iter().find(|r| r.arch == L1ArchKind::Ata).unwrap();
    let all_good = [
        ata.hit_rate,
        ata.ipc_high_locality,
        ata.ipc_low_locality,
        ata.l1_latency,
        ata.l2_bandwidth,
        ata.sharing_contention,
    ]
    .iter()
    .all(|&s| s >= 2);
    println!("ATA scores >= 2 stars in every column: {all_good} (paper: best row)");
}
