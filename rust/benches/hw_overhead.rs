//! Bench: regenerate §IV-D — hardware overhead of ATA-Cache's aggregated
//! tag array (crossbar + comparator groups) at 45 nm, plus a cluster-size
//! scaling ablation the paper leaves implicit.
//!
//!     cargo bench --bench hw_overhead

use ata_cache::area::{estimate, Tech45};
use ata_cache::bench_harness::bench_prelude;
use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::util::table::Table;

fn main() {
    bench_prelude("hw_overhead — §IV-D area & leakage @45nm");
    let tech = Tech45::default();

    let cfg = GpuConfig::paper(L1ArchKind::Ata);
    let r = estimate(&cfg, &tech);
    let mut t = Table::new("paper configuration (30 cores, 3 clusters of 10)")
        .header(&["quantity", "measured", "paper"]);
    t.row(vec!["crossbar area".into(), format!("{:.3} mm²", r.crossbar_mm2), "1.02 mm²".into()]);
    t.row(vec![
        "comparator area".into(),
        format!("{:.3} mm²", r.comparator_mm2),
        "0.02 mm²".into(),
    ]);
    t.row(vec!["leakage".into(), format!("{:.2} mW", r.leakage_mw), "5.55 mW".into()]);
    t.row(vec!["comparators".into(), r.comparator_count.to_string(), "-".into()]);
    t.row(vec![
        "die fraction".into(),
        format!("{:.3}%", r.die_fraction * 100.0),
        "negligible".into(),
    ]);
    println!("{}", t.render());

    // Ablation: how does the overhead scale with cluster size?
    let mut ab = Table::new("ablation — overhead vs cluster size (30 cores total)").header(&[
        "cores/cluster",
        "clusters",
        "xbar mm²",
        "cmp mm²",
        "leakage mW",
    ]);
    for (cpc, clusters) in [(5usize, 6usize), (6, 5), (10, 3), (15, 2), (30, 1)] {
        let mut c = GpuConfig::paper(L1ArchKind::Ata);
        c.cores = cpc * clusters;
        c.clusters = clusters;
        c.sharing.ata_comparator_groups = cpc;
        let e = estimate(&c, &tech);
        ab.row(vec![
            cpc.to_string(),
            clusters.to_string(),
            format!("{:.3}", e.crossbar_mm2),
            format!("{:.3}", e.comparator_mm2),
            format!("{:.2}", e.leakage_mw),
        ]);
    }
    println!("{}", ab.render());
    println!("crossbar area grows ~quadratically in cluster size — the reason the");
    println!("paper clusters 30 cores as 3x10 rather than sharing globally.");
}
