//! Bench: regenerate Fig 10 — L1 access latency of the three sharing
//! organizations normalized to the private cache (the paper's §IV-C
//! metric: completion time of the L1 stage for all requests of one load).
//!
//!     cargo bench --bench fig10_l1_latency [-- --quick]

use ata_cache::bench_harness::bench_prelude;
use ata_cache::config::L1ArchKind;
use ata_cache::coordinator::Sweep;
use ata_cache::trace::apps;
use ata_cache::util::table::{BarChart, Table};

fn main() {
    let quick = bench_prelude("fig10_l1_latency — L1 access latency (paper Fig 10)");
    let scale = if quick { 0.25 } else { 0.5 };
    let results = Sweep::paper(scale).run();

    let mut t = Table::new("Fig 10 — L1 access latency normalized to private").header(&[
        "app", "remote", "decoupled", "ata",
    ]);
    let mut chart = BarChart::new("decoupled vs ata latency ratio").baseline(1.0);
    let mut dec_r = Vec::new();
    let mut ata_r = Vec::new();
    for app in apps::all_app_names() {
        let r = results.norm_latency(L1ArchKind::RemoteSharing, app).unwrap();
        let d = results.norm_latency(L1ArchKind::DecoupledSharing, app).unwrap();
        let a = results.norm_latency(L1ArchKind::Ata, app).unwrap();
        dec_r.push(d);
        ata_r.push(a);
        t.row(vec![
            app.to_string(),
            format!("{r:.2}x"),
            format!("{d:.2}x"),
            format!("{a:.2}x"),
        ]);
        chart.bar(&format!("{app:9} dec"), d);
        chart.bar(&format!("{app:9} ata"), a);
    }
    println!("{}", t.render());
    println!("{}", chart.render());

    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let max = |v: &[f64]| v.iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "decoupled: +{:.1}% avg, up to {:.2}x   (paper: +67.2% avg, up to 2.74x)",
        (mean(&dec_r) - 1.0) * 100.0,
        max(&dec_r)
    );
    println!(
        "ata:       +{:.1}% avg                (paper: +6.0% avg)",
        (mean(&ata_r) - 1.0) * 100.0
    );
}
