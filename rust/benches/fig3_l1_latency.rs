//! Bench: regenerate Fig 3 — the motivating observation that the
//! decoupled-sharing cache has a *higher hit rate yet much longer L1
//! latency* than the private cache.
//!
//!     cargo bench --bench fig3_l1_latency [-- --quick]

use ata_cache::bench_harness::bench_prelude;
use ata_cache::config::L1ArchKind;
use ata_cache::coordinator::Sweep;
use ata_cache::trace::apps;
use ata_cache::util::table::Table;

fn main() {
    let quick = bench_prelude("fig3_l1_latency — private vs decoupled (paper Fig 3)");
    let scale = if quick { 0.25 } else { 0.5 };

    let mut sweep = Sweep::paper(scale);
    sweep.archs = vec![L1ArchKind::Private, L1ArchKind::DecoupledSharing];
    let results = sweep.run();

    let mut t = Table::new("Fig 3 — private vs decoupled-sharing").header(&[
        "app",
        "priv hit%",
        "dec hit%",
        "priv L1 lat",
        "dec L1 lat",
        "lat ratio",
    ]);
    let mut hit_up = 0;
    let mut lat_up = 0;
    for app in apps::all_app_names() {
        let p = results.get(L1ArchKind::Private, app).unwrap();
        let d = results.get(L1ArchKind::DecoupledSharing, app).unwrap();
        if d.l1.hit_rate() >= p.l1.hit_rate() {
            hit_up += 1;
        }
        if d.l1_stage_mean_latency > p.l1_stage_mean_latency {
            lat_up += 1;
        }
        t.row(vec![
            app.to_string(),
            format!("{:.1}", p.l1.hit_rate() * 100.0),
            format!("{:.1}", d.l1.hit_rate() * 100.0),
            format!("{:.1}", p.l1_stage_mean_latency),
            format!("{:.1}", d.l1_stage_mean_latency),
            format!("{:.2}x", d.l1_stage_mean_latency / p.l1_stage_mean_latency),
        ]);
    }
    println!("{}", t.render());
    println!("decoupled hit rate >= private on {hit_up}/10 apps (paper: higher)");
    println!("decoupled latency  >  private on {lat_up}/10 apps (paper: much longer)");
}
