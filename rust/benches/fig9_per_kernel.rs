//! Bench: regenerate Fig 9 — per-kernel IPC of two high inter-kernel
//! locality apps (SN, conv3d) and two low ones (HS3D, sradv1), decoupled
//! vs ATA, normalized to private.
//!
//!     cargo bench --bench fig9_per_kernel [-- --quick]

use ata_cache::bench_harness::bench_prelude;
use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::coordinator::SweepResults;
use ata_cache::exec::{JobOutput, JobRunner, ScenarioGrid};
use ata_cache::trace::apps;
use ata_cache::util::table::Table;

fn main() {
    let quick = bench_prelude("fig9_per_kernel — per-kernel IPC (paper Fig 9)");
    let scale = if quick { 0.25 } else { 0.5 };

    // (app, the paper's observation to verify)
    let cases = [
        ("SN", "decoupled degrades several kernels; ATA wins overall"),
        ("conv3d", "ATA >= decoupled on all kernels"),
        ("HS3D", "ATA >= decoupled on all kernels"),
        ("sradv1", "k4/k9/k14 crater under decoupled"),
    ];

    // All twelve (arch × app) runs as one scenario grid on the worker
    // pool — the per-case serial loop this bench used to hand-roll.
    let grid = ScenarioGrid::new(
        GpuConfig::paper(L1ArchKind::Private),
        vec![
            L1ArchKind::Private,
            L1ArchKind::DecoupledSharing,
            L1ArchKind::Ata,
        ],
        cases
            .iter()
            .map(|(app, _)| apps::app(app).unwrap())
            .collect(),
        scale,
    );
    let jobs = grid.jobs();
    let results = SweepResults {
        results: JobRunner::default()
            .run(&jobs)
            .into_iter()
            .map(JobOutput::into_solo)
            .collect(),
        ..Default::default()
    };

    for (app, note) in cases {
        let base = results.get(L1ArchKind::Private, app).unwrap();
        let dec = results.get(L1ArchKind::DecoupledSharing, app).unwrap();
        let ata = results.get(L1ArchKind::Ata, app).unwrap();

        let mut t =
            Table::new(&format!("Fig 9 — {app} ({note})")).header(&["kernel", "decoupled", "ata"]);
        let mut ata_wins = 0;
        for (i, k) in base.kernels.iter().enumerate() {
            let b = k.ipc().max(1e-12);
            let d = dec.kernels[i].ipc() / b;
            let a = ata.kernels[i].ipc() / b;
            if a >= d {
                ata_wins += 1;
            }
            t.row(vec![format!("k{i}"), format!("{d:.3}"), format!("{a:.3}")]);
        }
        println!("{}", t.render());
        println!(
            "  ATA >= decoupled on {ata_wins}/{} kernels; app-level: dec {:.3} / ata {:.3}\n",
            base.kernels.len(),
            dec.ipc() / base.ipc(),
            ata.ipc() / base.ipc()
        );
        if app == "sradv1" {
            for k in [4usize, 9, 14] {
                let b = base.kernels[k].ipc().max(1e-12);
                println!(
                    "  sradv1 k{k}: decoupled {:.3} vs ata {:.3} (paper: decoupled degrades)",
                    dec.kernels[k].ipc() / b,
                    ata.kernels[k].ipc() / b
                );
            }
            println!();
        }
    }
}
