//! Bench: regenerate Fig 9 — per-kernel IPC of two high inter-kernel
//! locality apps (SN, conv3d) and two low ones (HS3D, sradv1), decoupled
//! vs ATA, normalized to private.
//!
//!     cargo bench --bench fig9_per_kernel [-- --quick]

use ata_cache::bench_harness::bench_prelude;
use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::engine::Engine;
use ata_cache::stats::SimResult;
use ata_cache::trace::apps;
use ata_cache::util::table::Table;

fn run(app: &str, arch: L1ArchKind, scale: f64) -> SimResult {
    let cfg = GpuConfig::paper(arch);
    let wl = apps::app(app).unwrap().scaled(scale).workload(&cfg);
    Engine::new(&cfg).run(&wl)
}

fn main() {
    let quick = bench_prelude("fig9_per_kernel — per-kernel IPC (paper Fig 9)");
    let scale = if quick { 0.25 } else { 0.5 };

    // (app, the paper's observation to verify)
    let cases = [
        ("SN", "decoupled degrades several kernels; ATA wins overall"),
        ("conv3d", "ATA >= decoupled on all kernels"),
        ("HS3D", "ATA >= decoupled on all kernels"),
        ("sradv1", "k4/k9/k14 crater under decoupled"),
    ];
    for (app, note) in cases {
        let base = run(app, L1ArchKind::Private, scale);
        let dec = run(app, L1ArchKind::DecoupledSharing, scale);
        let ata = run(app, L1ArchKind::Ata, scale);

        let mut t =
            Table::new(&format!("Fig 9 — {app} ({note})")).header(&["kernel", "decoupled", "ata"]);
        let mut ata_wins = 0;
        for (i, k) in base.kernels.iter().enumerate() {
            let b = k.ipc().max(1e-12);
            let d = dec.kernels[i].ipc() / b;
            let a = ata.kernels[i].ipc() / b;
            if a >= d {
                ata_wins += 1;
            }
            t.row(vec![format!("k{i}"), format!("{d:.3}"), format!("{a:.3}")]);
        }
        println!("{}", t.render());
        println!(
            "  ATA >= decoupled on {ata_wins}/{} kernels; app-level: dec {:.3} / ata {:.3}\n",
            base.kernels.len(),
            dec.ipc() / base.ipc(),
            ata.ipc() / base.ipc()
        );
        if app == "sradv1" {
            for k in [4usize, 9, 14] {
                let b = base.kernels[k].ipc().max(1e-12);
                println!(
                    "  sradv1 k{k}: decoupled {:.3} vs ata {:.3} (paper: decoupled degrades)",
                    dec.kernels[k].ipc() / b,
                    ata.kernels[k].ipc() / b
                );
            }
            println!();
        }
    }
}
