//! Unidirectional ring network — the probe/data fabric of the
//! remote-sharing baseline (Dublish et al.'s L1 Cooperative Caching
//! Network connects core L1s with a lightweight ring).
//!
//! Reservation-mode: each of the N links is a server; a message from stop
//! `a` to stop `b` traverses `hops(a→b)` links in order, paying hop
//! latency plus serialization (`ceil(bytes/width)`) and queueing on every
//! link.  Probes are metadata-sized (1 flit); data replies carry sectors.

use crate::resource::{Calendar, Grant};

#[derive(Debug, Clone)]
pub struct Ring {
    links: Vec<Calendar>,
    hop_latency: u32,
    width_bytes: usize,
    /// Cumulative flit-cycles carried (NoC pressure metric).
    pub flit_cycles: u64,
}

impl Ring {
    pub fn new(stops: usize, hop_latency: u32, width_bytes: usize) -> Self {
        assert!(stops > 1);
        Ring {
            links: (0..stops).map(|_| Calendar::new()).collect(),
            hop_latency,
            width_bytes,
            flit_cycles: 0,
        }
    }

    pub fn stops(&self) -> usize {
        self.links.len()
    }

    /// Hops from `src` to `dst` going around the (unidirectional) ring.
    pub fn hops(&self, src: usize, dst: usize) -> usize {
        let n = self.links.len();
        (dst + n - src) % n
    }

    /// Serialization cycles for a payload.
    pub fn ser_cycles(&self, bytes: usize) -> u32 {
        (bytes.div_ceil(self.width_bytes)).max(1) as u32
    }

    /// Send `bytes` from `src` to `dst` starting at `now`.  Reserves every
    /// traversed link in order (wormhole-ish: the message occupies each
    /// link for its serialization time).  The returned [`Grant`] carries
    /// the arrival cycle (`grant`) and the queueing delay summed over all
    /// traversed links (`queued` — excludes hop latency + serialization).
    pub fn send(&mut self, src: usize, dst: usize, now: u64, bytes: usize) -> Grant {
        let hops = self.hops(src, dst);
        if hops == 0 {
            return Grant::new(now, 0);
        }
        let ser = self.ser_cycles(bytes);
        let mut t = now;
        let mut queued = 0u64;
        let n = self.links.len();
        for h in 0..hops {
            let link = (src + h) % n;
            let g = self.links[link].reserve(t, ser);
            self.flit_cycles += ser as u64;
            queued += g.queued;
            t = g.grant + self.hop_latency as u64;
        }
        // Arrival once the tail clears the final link.
        Grant::new(t + ser as u64 - 1, queued)
    }

    /// Broadcast from `src` to every other stop (a probe that visits all
    /// remote caches); the grant is the cycle the *last* stop receives it,
    /// `queued` the summed link queueing.  This is the full-ring traversal
    /// the remote-sharing design pays on every miss when no predictor
    /// filters it.
    pub fn broadcast(&mut self, src: usize, now: u64, bytes: usize) -> Grant {
        let n = self.links.len();
        let ser = self.ser_cycles(bytes);
        let mut t = now;
        let mut queued = 0u64;
        let mut last_arrival = now;
        for h in 0..n - 1 {
            let link = (src + h) % n;
            let g = self.links[link].reserve(t, ser);
            self.flit_cycles += ser as u64;
            queued += g.queued;
            t = g.grant + self.hop_latency as u64;
            last_arrival = t + ser as u64 - 1;
        }
        Grant::new(last_arrival, queued)
    }

    /// Aggregate queue pressure (cycles of backlog across links).
    pub fn backlog(&self, now: u64) -> u64 {
        self.links.iter().map(|l| l.backlog(now)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hop_count_wraps() {
        let r = Ring::new(10, 1, 32);
        assert_eq!(r.hops(0, 1), 1);
        assert_eq!(r.hops(9, 0), 1);
        assert_eq!(r.hops(3, 3), 0);
        assert_eq!(r.hops(0, 9), 9);
    }

    #[test]
    fn uncontended_latency_scales_with_hops() {
        let mut r = Ring::new(10, 2, 32);
        // 1 hop, 32B = 1 ser cycle: grant 100, +2 hop, tail at +0 -> 102
        let g = r.send(0, 1, 100, 32);
        assert_eq!(g.grant, 102);
        assert_eq!(g.queued, 0, "empty ring has no queueing");
        // 5 hops from fresh ring state:
        let mut r2 = Ring::new(10, 2, 32);
        assert_eq!(r2.send(0, 5, 100, 32).grant, 110);
    }

    #[test]
    fn serialization_adds_for_large_payloads() {
        let mut r = Ring::new(4, 1, 32);
        let small = r.send(0, 1, 0, 32).grant;
        let mut r2 = Ring::new(4, 1, 32);
        let big = r2.send(0, 1, 0, 128).grant; // 4 flits
        assert!(big > small, "128B ({big}) should arrive later than 32B ({small})");
        assert_eq!(big - small, 3, "3 extra serialization cycles");
    }

    #[test]
    fn contention_queues_on_shared_link() {
        let mut r = Ring::new(4, 1, 32);
        let a = r.send(0, 2, 0, 128); // occupies links 0,1
        let b = r.send(0, 2, 0, 128); // queues behind on link 0
        assert!(b.grant > a.grant);
        assert!(b.queued > 0, "second message must report its queueing");
        assert_eq!(a.queued, 0);
    }

    #[test]
    fn broadcast_visits_all_stops() {
        let mut r = Ring::new(10, 2, 32);
        let done = r.broadcast(0, 0, 32).grant;
        // 9 links to traverse: each grant adds >= hop latency.
        assert!(done >= 18, "broadcast done at {done}");
        assert!(r.backlog(0) > 0);
    }

    #[test]
    fn same_stop_send_is_free() {
        let mut r = Ring::new(4, 1, 32);
        assert_eq!(r.send(2, 2, 77, 128), Grant::new(77, 0));
    }
}
