//! iSLIP arbitration (McKeown '99) — the crossbar allocation policy named
//! in Table II ("iSLIP Arbiteration type").
//!
//! Each cycle, inputs with queued cells request their destination outputs;
//! outputs grant round-robin from a per-output pointer; inputs accept
//! round-robin from a per-input pointer.  Pointers advance only when a
//! grant is accepted *in the first iteration*, which is what gives iSLIP
//! its 100%-throughput-under-uniform-traffic property and starvation
//! freedom.  `iterations` extra rounds match leftover ports.

#[derive(Debug, Clone)]
pub struct Islip {
    n_in: usize,
    n_out: usize,
    grant_ptr: Vec<usize>,  // per output
    accept_ptr: Vec<usize>, // per input
}

impl Islip {
    pub fn new(n_in: usize, n_out: usize) -> Self {
        Islip {
            n_in,
            n_out,
            grant_ptr: vec![0; n_out],
            accept_ptr: vec![0; n_in],
        }
    }

    /// One arbitration: `wants[i][j]` = input i has a cell for output j.
    /// Returns `matches[i] = Some(j)` for matched pairs.  Runs `iterations`
    /// iSLIP rounds.
    pub fn arbitrate(&mut self, wants: &[Vec<bool>], iterations: usize) -> Vec<Option<usize>> {
        assert_eq!(wants.len(), self.n_in);
        let mut in_matched: Vec<Option<usize>> = vec![None; self.n_in];
        let mut out_matched: Vec<bool> = vec![false; self.n_out];

        for iter in 0..iterations.max(1) {
            // Grant phase: each unmatched output picks one requesting input.
            let mut grants: Vec<Option<usize>> = vec![None; self.n_out]; // output -> input
            for out in 0..self.n_out {
                if out_matched[out] {
                    continue;
                }
                let start = self.grant_ptr[out];
                for k in 0..self.n_in {
                    let inp = (start + k) % self.n_in;
                    if in_matched[inp].is_none() && wants[inp].get(out).copied().unwrap_or(false) {
                        grants[out] = Some(inp);
                        break;
                    }
                }
            }
            // Accept phase: each input accepts at most one grant.
            let mut accepted_any = false;
            for inp in 0..self.n_in {
                if in_matched[inp].is_some() {
                    continue;
                }
                let start = self.accept_ptr[inp];
                for k in 0..self.n_out {
                    let out = (start + k) % self.n_out;
                    if grants[out] == Some(inp) {
                        in_matched[inp] = Some(out);
                        out_matched[out] = true;
                        accepted_any = true;
                        if iter == 0 {
                            // Pointer update rule: only on first-iteration
                            // accepts (the iSLIP desynchronization trick).
                            self.grant_ptr[out] = (inp + 1) % self.n_in;
                            self.accept_ptr[inp] = (out + 1) % self.n_out;
                        }
                        break;
                    }
                }
            }
            if !accepted_any {
                break;
            }
        }
        in_matched
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wants(n_in: usize, n_out: usize, pairs: &[(usize, usize)]) -> Vec<Vec<bool>> {
        let mut w = vec![vec![false; n_out]; n_in];
        for &(i, j) in pairs {
            w[i][j] = true;
        }
        w
    }

    #[test]
    fn single_request_matches() {
        let mut a = Islip::new(4, 4);
        let m = a.arbitrate(&wants(4, 4, &[(2, 3)]), 1);
        assert_eq!(m[2], Some(3));
        assert!(m.iter().enumerate().all(|(i, x)| i == 2 || x.is_none()));
    }

    #[test]
    fn conflicting_inputs_serialize_fairly() {
        // Inputs 0 and 1 both want output 0: over two cycles each gets one.
        let mut a = Islip::new(2, 2);
        let w = wants(2, 2, &[(0, 0), (1, 0)]);
        let m1 = a.arbitrate(&w, 1);
        let m2 = a.arbitrate(&w, 1);
        let winners: Vec<usize> = [m1, m2]
            .iter()
            .map(|m| m.iter().position(|x| x == &Some(0)).unwrap())
            .collect();
        assert_eq!(winners.len(), 2);
        assert_ne!(winners[0], winners[1], "round-robin must alternate");
    }

    #[test]
    fn never_grants_one_output_to_two_inputs() {
        let mut a = Islip::new(8, 4);
        let mut w = vec![vec![true; 4]; 8]; // everyone wants everything
        for _ in 0..32 {
            let m = a.arbitrate(&w, 2);
            let mut used = [false; 4];
            for out in m.iter().flatten() {
                assert!(!used[*out], "output {out} double-granted");
                used[*out] = true;
            }
            w[0][0] = !w[0][0]; // perturb
        }
    }

    #[test]
    fn multiple_iterations_increase_matching() {
        // Pattern where 1 iteration can leave ports unmatched:
        // in0 wants {0,1}, in1 wants {0}. If out0 grants in0 and in0
        // accepts out0, in1 starves this cycle with 1 iter... construct
        // via pointers: just assert 2-iter matching is >= 1-iter matching
        // over random-ish patterns.
        let mut a1 = Islip::new(4, 4);
        let mut a2 = Islip::new(4, 4);
        let patterns = [
            wants(4, 4, &[(0, 0), (0, 1), (1, 0), (2, 1), (3, 2)]),
            wants(4, 4, &[(0, 3), (1, 3), (2, 3), (3, 3), (3, 0)]),
            wants(4, 4, &[(0, 0), (1, 1), (2, 2), (3, 3)]),
        ];
        for w in &patterns {
            let m1 = a1.arbitrate(w, 1).iter().flatten().count();
            let m2 = a2.arbitrate(w, 4).iter().flatten().count();
            assert!(m2 >= m1, "more iterations can't match fewer");
        }
    }

    #[test]
    fn full_permutation_achieves_full_match() {
        let mut a = Islip::new(4, 4);
        let w = wants(4, 4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let m = a.arbitrate(&w, 4);
        assert_eq!(m.iter().flatten().count(), 4);
    }

    #[test]
    fn no_starvation_under_contention() {
        // 4 inputs all hammering output 0: every input must win within
        // n_in consecutive arbitrations.
        let mut a = Islip::new(4, 2);
        let w = wants(4, 2, &[(0, 0), (1, 0), (2, 0), (3, 0)]);
        let mut last_win = [0usize; 4];
        for round in 1..=40 {
            let m = a.arbitrate(&w, 1);
            for (i, x) in m.iter().enumerate() {
                if x.is_some() {
                    last_win[i] = round;
                }
            }
        }
        for (i, &lw) in last_win.iter().enumerate() {
            assert!(lw >= 36, "input {i} starved (last win round {lw})");
        }
    }
}
