//! Interconnect models.
//!
//! * [`islip`] — the iSLIP arbiter named in Table II.
//! * [`crossbar`] — detailed input-queued crossbar (VOQs, flits,
//!   backpressure) plus the fast reservation twin used on the hot path.
//! * [`ring`] — the probe/data ring of the remote-sharing baseline.

pub mod crossbar;
pub mod islip;
pub mod ring;

pub use crossbar::{Crossbar, Packet, XbarReservation};
pub use islip::Islip;
pub use ring::Ring;
