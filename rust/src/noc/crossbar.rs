//! Cycle-accurate input-queued crossbar with VOQs + iSLIP, and the fast
//! reservation-mode twin used on the simulator hot path.
//!
//! The detailed model (`Crossbar`) implements virtual output queues,
//! finite input/output buffers, flit serialization and per-cycle iSLIP
//! matching — it exists to validate the timing constants of the fast
//! model and to run the NoC ablation bench.  The fast model
//! (`XbarReservation`) compresses the same behaviour into per-port
//! reservation servers: contention shows up as queueing delay on the
//! input and output ports.  `rust/benches/microbench.rs` compares the two
//! under uniform and hotspot traffic.

use std::collections::VecDeque;

use super::islip::Islip;
use crate::resource::{Calendar, Grant};

/// A packet in flight through the detailed crossbar.
#[derive(Debug, Clone, PartialEq)]
pub struct Packet<T> {
    pub dst: usize,
    pub flits: u32,
    pub payload: T,
}

/// Detailed input-queued crossbar.
#[derive(Debug)]
pub struct Crossbar<T> {
    n_in: usize,
    n_out: usize,
    /// Virtual output queues: voq[input][output].
    voq: Vec<Vec<VecDeque<Packet<T>>>>,
    /// Flits queued per input (finite buffer accounting).
    in_occupancy: Vec<usize>,
    in_capacity: usize,
    /// Remaining flits of the packet currently crossing from input i
    /// (iSLIP matches persist until the packet finishes — virtual
    /// cut-through switching).
    active: Vec<Option<(usize, u32)>>, // (output, flits_left)
    /// Outputs already claimed by an active transfer.
    out_busy: Vec<bool>,
    arbiter: Islip,
    iterations: usize,
    /// Delivered packets, drained by the caller each cycle.
    delivered: Vec<(usize, Packet<T>)>,
    /// Cumulative stats.
    pub total_delivered: u64,
    pub total_flit_cycles: u64,
}

impl<T> Crossbar<T> {
    pub fn new(n_in: usize, n_out: usize, in_capacity: usize, iterations: usize) -> Self {
        Crossbar {
            n_in,
            n_out,
            voq: (0..n_in)
                .map(|_| (0..n_out).map(|_| VecDeque::new()).collect())
                .collect(),
            in_occupancy: vec![0; n_in],
            in_capacity,
            active: vec![None; n_in],
            out_busy: vec![false; n_out],
            arbiter: Islip::new(n_in, n_out),
            iterations,
            delivered: Vec::new(),
            total_delivered: 0,
            total_flit_cycles: 0,
        }
    }

    /// Try to enqueue a packet at `input`; false if the input buffer lacks
    /// space (sender must stall — backpressure).
    pub fn offer(&mut self, input: usize, pkt: Packet<T>) -> bool {
        let flits = pkt.flits as usize;
        if self.in_occupancy[input] + flits > self.in_capacity {
            return false;
        }
        self.in_occupancy[input] += flits;
        self.voq[input][pkt.dst].push_back(pkt);
        true
    }

    pub fn input_backlog_flits(&self, input: usize) -> usize {
        self.in_occupancy[input]
    }

    /// Advance one cycle: continue active transfers, run iSLIP for idle
    /// ports, move one flit per matched pair.
    pub fn tick(&mut self) {
        // 1. New matches for idle inputs/outputs.
        let wants: Vec<Vec<bool>> = (0..self.n_in)
            .map(|i| {
                if self.active[i].is_some() {
                    vec![false; self.n_out]
                } else {
                    (0..self.n_out)
                        .map(|o| !self.out_busy[o] && !self.voq[i][o].is_empty())
                        .collect()
                }
            })
            .collect();
        let matches = self.arbiter.arbitrate(&wants, self.iterations);
        for (i, m) in matches.iter().enumerate() {
            if let Some(o) = m {
                if self.active[i].is_none() && !self.out_busy[*o] {
                    let flits = self.voq[i][*o].front().map(|p| p.flits).unwrap();
                    self.active[i] = Some((*o, flits));
                    self.out_busy[*o] = true;
                }
            }
        }
        // 2. Transfer one flit on every active connection.
        for i in 0..self.n_in {
            if let Some((o, left)) = self.active[i] {
                self.total_flit_cycles += 1;
                self.in_occupancy[i] -= 1;
                if left == 1 {
                    let pkt = self.voq[i][o].pop_front().unwrap();
                    self.delivered.push((o, pkt));
                    self.total_delivered += 1;
                    self.active[i] = None;
                    self.out_busy[o] = false;
                } else {
                    self.active[i] = Some((o, left - 1));
                }
            }
        }
    }

    /// Drain packets that completed crossing this cycle.
    pub fn drain(&mut self) -> Vec<(usize, Packet<T>)> {
        std::mem::take(&mut self.delivered)
    }

    pub fn is_idle(&self) -> bool {
        self.active.iter().all(Option::is_none)
            && self.voq.iter().flatten().all(VecDeque::is_empty)
    }
}

/// Fast reservation-mode crossbar: per-input and per-output servers.
/// A transfer of `flits` reserves `flits` cycles of its input port and of
/// its output port; the delivery time is `grant_out + latency`.
#[derive(Debug, Clone)]
pub struct XbarReservation {
    inputs: Vec<Calendar>,
    outputs: Vec<Calendar>,
    latency: u32,
    buffer_limit: u64,
}

impl XbarReservation {
    pub fn new(n_in: usize, n_out: usize, latency: u32, buffer_limit: u64) -> Self {
        XbarReservation {
            inputs: (0..n_in).map(|_| Calendar::new()).collect(),
            outputs: (0..n_out).map(|_| Calendar::new()).collect(),
            latency,
            buffer_limit,
        }
    }

    /// Does the input buffer horizon admit a new packet now?
    pub fn would_accept(&self, input: usize, now: u64) -> bool {
        self.inputs[input].would_accept(now, self.buffer_limit)
    }

    /// Cycles a sender must stall before the finite input buffer admits a
    /// new packet (0 when `would_accept`).  Backpressured senders retry at
    /// `now + admission_delay` instead of reserving into an unbounded
    /// future — see `resource::Calendar::drain_cycle`.
    pub fn admission_delay(&self, input: usize, now: u64) -> u64 {
        self.inputs[input].drain_cycle(now, self.buffer_limit) - now
    }

    /// Reserve a transfer.  The returned [`Grant`] carries the delivery
    /// cycle at the output (`grant`) and the pure queueing delay accrued
    /// on the input and output ports (`queued` — excludes switch latency
    /// and flit serialization).
    pub fn transfer(&mut self, input: usize, output: usize, now: u64, flits: u32) -> Grant {
        let in_grant = self.inputs[input].reserve(now, flits);
        // Head flit reaches the output port once granted + switch latency;
        // the output port then serializes the packet out.
        let at_output = in_grant.grant + self.latency as u64;
        let out_grant = self.outputs[output].reserve(at_output, flits);
        Grant::new(
            out_grant.grant + flits as u64,
            in_grant.queued + out_grant.queued,
        )
    }

    pub fn output_backlog(&self, output: usize, now: u64) -> u64 {
        self.outputs[output].backlog(now)
    }

    /// Diagnostic horizon: the earliest cycle at-or-after `now` at which
    /// *any* port (input or output) still has booked traffic — `None` when
    /// the whole crossbar is idle.  This is the failure-snapshot view
    /// ("is anything still moving through the NoC?"), not a grant bound:
    /// individual ports may grant earlier.
    pub fn next_event(&self, now: u64) -> Option<u64> {
        self.inputs
            .iter()
            .chain(self.outputs.iter())
            .filter_map(|c| c.next_event(now))
            .min()
    }

    /// Pending work on an input port at `now` — together with
    /// [`output_backlog`](Self::output_backlog) this is the read-only
    /// congestion estimate interference-aware policies use (e.g. the
    /// `ata-bypass` organization's holder-pressure check).
    pub fn input_backlog(&self, input: usize, now: u64) -> u64 {
        self.inputs[input].backlog(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detailed_single_packet_latency_is_flit_count() {
        let mut x: Crossbar<u32> = Crossbar::new(2, 2, 64, 1);
        assert!(x.offer(0, Packet { dst: 1, flits: 4, payload: 7 }));
        let mut cycles = 0;
        loop {
            x.tick();
            cycles += 1;
            let d = x.drain();
            if !d.is_empty() {
                assert_eq!(d[0].0, 1);
                assert_eq!(d[0].1.payload, 7);
                break;
            }
            assert!(cycles < 100);
        }
        assert_eq!(cycles, 4, "4 flits take 4 cycles");
    }

    #[test]
    fn detailed_backpressure_rejects_when_full() {
        let mut x: Crossbar<u32> = Crossbar::new(1, 1, 8, 1);
        assert!(x.offer(0, Packet { dst: 0, flits: 6, payload: 0 }));
        assert!(!x.offer(0, Packet { dst: 0, flits: 6, payload: 1 }), "buffer full");
        assert!(x.offer(0, Packet { dst: 0, flits: 2, payload: 2 }), "fits exactly");
    }

    #[test]
    fn detailed_parallel_transfers_dont_serialize() {
        // 0->0 and 1->1 simultaneously: both finish in 4 cycles.
        let mut x: Crossbar<u32> = Crossbar::new(2, 2, 64, 2);
        x.offer(0, Packet { dst: 0, flits: 4, payload: 0 });
        x.offer(1, Packet { dst: 1, flits: 4, payload: 1 });
        for _ in 0..4 {
            x.tick();
        }
        assert_eq!(x.drain().len(), 2);
    }

    #[test]
    fn detailed_output_contention_serializes() {
        // Both inputs target output 0: second packet waits for the first.
        let mut x: Crossbar<u32> = Crossbar::new(2, 1, 64, 1);
        x.offer(0, Packet { dst: 0, flits: 4, payload: 0 });
        x.offer(1, Packet { dst: 0, flits: 4, payload: 1 });
        let mut done = vec![];
        for c in 1..=8 {
            x.tick();
            for (_, p) in x.drain() {
                done.push((c, p.payload));
            }
        }
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].0, 4);
        assert_eq!(done[1].0, 8, "serialized behind the first");
    }

    #[test]
    fn detailed_is_idle_after_draining() {
        let mut x: Crossbar<u32> = Crossbar::new(2, 2, 64, 1);
        assert!(x.is_idle());
        x.offer(0, Packet { dst: 1, flits: 2, payload: 0 });
        assert!(!x.is_idle());
        for _ in 0..4 {
            x.tick();
        }
        x.drain();
        assert!(x.is_idle());
    }

    #[test]
    fn reservation_uncontended_latency() {
        let mut x = XbarReservation::new(2, 2, 3, 512);
        // grant in at 10, out at 13, delivered 13+4=17
        let g = x.transfer(0, 1, 10, 4);
        assert_eq!(g.grant, 17);
        assert_eq!(g.queued, 0, "empty crossbar has no queueing");
    }

    #[test]
    fn reservation_contention_matches_serialization() {
        let mut x = XbarReservation::new(2, 1, 0, 512);
        let d1 = x.transfer(0, 0, 0, 4);
        let d2 = x.transfer(1, 0, 0, 4);
        assert_eq!(d1.grant, 4);
        assert_eq!(d2.grant, 8, "output port serializes like the detailed model");
        assert_eq!(d2.queued, 4, "second packet queued behind the first");
    }

    #[test]
    fn reservation_buffer_horizon() {
        let mut x = XbarReservation::new(1, 1, 0, 8);
        assert!(x.would_accept(0, 0));
        assert_eq!(x.admission_delay(0, 0), 0);
        for _ in 0..3 {
            x.transfer(0, 0, 0, 4);
        }
        assert!(!x.would_accept(0, 0), "12 cycles of backlog > 8 limit");
        let d = x.admission_delay(0, 0);
        assert_eq!(d, 4, "backlog 12 drains to the 8-cycle horizon at t=4");
        assert!(x.would_accept(0, d), "retry at the drain cycle succeeds");
    }

    #[test]
    fn models_agree_on_hotspot_throughput() {
        // N inputs hammer one output with 4-flit packets: both models
        // should deliver ~1 packet per 4 cycles in steady state.
        let n = 4;
        let pkts = 32;
        // Detailed:
        let mut det: Crossbar<u32> = Crossbar::new(n, 1, 1 << 20, 2);
        for k in 0..pkts {
            det.offer(k % n, Packet { dst: 0, flits: 4, payload: 0 });
        }
        let mut cycles = 0u64;
        let mut got = 0;
        while got < pkts {
            det.tick();
            cycles += 1;
            got += det.drain().len();
            assert!(cycles < 10_000);
        }
        // Reservation:
        let mut res = XbarReservation::new(n, 1, 0, 1 << 20);
        let mut last = 0u64;
        for k in 0..pkts {
            last = last.max(res.transfer(k % n, 0, 0, 4).grant);
        }
        let det_rate = cycles as f64;
        let res_rate = last as f64;
        assert!(
            (det_rate - res_rate).abs() / det_rate < 0.15,
            "detailed={det_rate} reservation={res_rate}"
        );
    }
}
