//! Persistent walk workers for phase B2 of the phased memory walk
//! (`--mem-workers`).
//!
//! Each worker exclusively owns a contiguous run of L2 slices for the
//! duration of one [`run`](WalkPool::run) call: the pool *moves* the
//! [`SliceWalk`] units into the worker's job and moves them back when the
//! job returns, so the type system enforces the ownership map — no locks,
//! no shared mutable state.  Descriptors are walked in ascending global
//! index within each worker, and results are scattered back by index, so
//! the outcome is byte-identical to the serial walk regardless of thread
//! scheduling.
//!
//! With `mem_workers <= 1` (the default) no threads are spawned and
//! [`MemSystem::run_walk`](super::MemSystem::run_walk) walks serially on
//! the coordinator.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::engine::{panic_message, SimError};

use super::{FetchDesc, SliceWalk};

/// One B2 work packet: the worker's slice units (moved in and back out),
/// its share of the epoch's descriptors, and their global indices.
#[derive(Debug)]
struct Job {
    units: Vec<SliceWalk>,
    /// Global slice id of `units[0]` (the worker's partition start).
    first_slice: usize,
    descs: Vec<FetchDesc>,
    /// Global descriptor index of each entry in `descs` (ascending).
    idxs: Vec<u32>,
    l2_latency: u64,
}

fn run_job(job: &mut Job) {
    for k in 0..job.descs.len() {
        let d = &mut job.descs[k];
        job.units[d.slice - job.first_slice].walk_one(job.idxs[k], d, job.l2_latency);
    }
}

/// A persistent worker and its two channels (jobs in, results out).
#[derive(Debug)]
struct Lane {
    tx: Sender<Job>,
    rx: Receiver<Job>,
    handle: Option<JoinHandle<()>>,
}

/// The persistent B2 worker pool.  `workers == 1` means no pool: the
/// lanes stay empty and the caller walks serially.
#[derive(Debug)]
pub struct WalkPool {
    workers: usize,
    /// First slice of each worker's contiguous partition
    /// (`starts[0] == 0`); near-equal split, remainder to the leading
    /// workers, mirroring the shard partition.
    starts: Vec<usize>,
    lanes: Vec<Lane>,
}

impl WalkPool {
    pub fn new(requested: usize, n_slices: usize) -> Self {
        let workers = requested.max(1).min(n_slices.max(1));
        let base = n_slices / workers;
        let rem = n_slices % workers;
        let mut starts = Vec::with_capacity(workers);
        let mut at = 0;
        for w in 0..workers {
            starts.push(at);
            at += base + usize::from(w < rem);
        }
        let lanes = if workers <= 1 {
            Vec::new()
        } else {
            (0..workers)
                .map(|w| {
                    let (job_tx, job_rx) = channel::<Job>();
                    let (done_tx, done_rx) = channel::<Job>();
                    let handle = std::thread::Builder::new()
                        .name(format!("ata-memwalk-{w}"))
                        .spawn(move || {
                            while let Ok(mut job) = job_rx.recv() {
                                run_job(&mut job);
                                if done_tx.send(job).is_err() {
                                    break;
                                }
                            }
                        })
                        // lint: allow(sim-panic) — thread spawn at pool construction; an OS refusing threads is unrecoverable
                        .expect("spawn memwalk worker");
                    Lane {
                        tx: job_tx,
                        rx: done_rx,
                        handle: Some(handle),
                    }
                })
                .collect()
        };
        WalkPool {
            workers,
            starts,
            lanes,
        }
    }

    /// Effective worker count (requested, clamped to the slice count).
    pub fn workers(&self) -> usize {
        self.workers
    }

    fn worker_of(&self, slice: usize) -> usize {
        self.starts.partition_point(|&s| s <= slice) - 1
    }

    /// Fan the epoch's descriptors out to the workers and merge the
    /// results back in place.  `walks` is temporarily carved into the
    /// per-worker partitions and is fully restored (same order, same
    /// length) on `Ok`; `descs` entries are updated by global index.
    ///
    /// A worker that panicked (both its channels close when the thread
    /// unwinds) surfaces as [`SimError::WorkerPanic`] with the payload
    /// recovered through the join handle.  On `Err` the slice units moved
    /// into dead jobs are lost — the owning `MemSystem` is poisoned and
    /// must be dropped with the failed engine, which the execution layer
    /// always does.
    pub(super) fn run(
        &mut self,
        walks: &mut Vec<SliceWalk>,
        descs: &mut [FetchDesc],
        l2_latency: u64,
    ) -> Result<(), SimError> {
        debug_assert_eq!(self.lanes.len(), self.workers);

        // Partition the descriptors, preserving ascending global index
        // within each worker.
        let mut batches: Vec<(Vec<FetchDesc>, Vec<u32>)> = (0..self.workers)
            .map(|_| (Vec::new(), Vec::new()))
            .collect();
        for (i, d) in descs.iter().enumerate() {
            let w = self.worker_of(d.slice);
            batches[w].0.push(*d);
            batches[w].1.push(i as u32);
        }

        // Carve the slice units into contiguous per-worker segments
        // (moved out — exclusive ownership, enforced by the move).
        let mut segs: Vec<Vec<SliceWalk>> = Vec::with_capacity(self.workers);
        for w in (1..self.workers).rev() {
            segs.push(walks.split_off(self.starts[w]));
        }
        segs.push(std::mem::take(walks));
        segs.reverse();

        for (w, (units, (batch, idxs))) in segs.drain(..).zip(batches.drain(..)).enumerate() {
            let job = Job {
                units,
                first_slice: self.starts[w],
                descs: batch,
                idxs,
                l2_latency,
            };
            if self.lanes[w].tx.send(job).is_err() {
                return Err(self.worker_died(w));
            }
        }

        // Collect in worker order: slice units reassemble contiguously,
        // descriptors scatter back by global index — deterministic
        // regardless of which worker finished first.
        for w in 0..self.lanes.len() {
            let Ok(mut job) = self.lanes[w].rx.recv() else {
                return Err(self.worker_died(w));
            };
            walks.append(&mut job.units);
            for (d, i) in job.descs.iter().zip(&job.idxs) {
                descs[*i as usize] = *d;
            }
        }
        Ok(())
    }

    /// Reap a dead worker into a typed error.  Walk workers do no
    /// containment of their own: a panic unwinds the thread (closing
    /// both channels, which is how the coordinator notices), and the
    /// payload is recovered here through the join handle.
    fn worker_died(&mut self, w: usize) -> SimError {
        let message = match self.lanes[w].handle.take().map(JoinHandle::join) {
            Some(Err(payload)) => panic_message(payload.as_ref()),
            _ => "memwalk worker exited without a panic payload".to_string(),
        };
        SimError::WorkerPanic {
            what: format!("memwalk worker {w}"),
            message,
        }
    }
}

impl Drop for WalkPool {
    fn drop(&mut self) {
        for lane in self.lanes.drain(..) {
            drop(lane.tx); // worker's recv() errors → clean exit
            while lane.rx.recv().is_ok() {}
            if let Some(h) = lane.handle {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitions_are_contiguous_and_cover_all_slices() {
        let p = WalkPool::new(1, 24);
        assert_eq!(p.workers(), 1);
        assert!(p.lanes.is_empty(), "serial pool spawns no threads");

        let p = WalkPool::new(5, 24);
        assert_eq!(p.workers(), 5);
        assert_eq!(p.starts, vec![0, 5, 10, 15, 20]);
        assert_eq!(p.lanes.len(), 5);
        for s in 0..24 {
            let w = p.worker_of(s);
            assert!(p.starts[w] <= s);
            assert!(w + 1 >= p.starts.len() || s < p.starts[w + 1]);
        }
    }

    #[test]
    fn worker_count_clamps_to_slice_count() {
        assert_eq!(WalkPool::new(64, 4).workers(), 4);
        assert_eq!(WalkPool::new(0, 4).workers(), 1);
    }
}
