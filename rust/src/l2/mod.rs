//! The memory system below L1: cores↔L2 crossbar (Table II interconnect),
//! banked sectored L2 slices (memory-side, 24 × 128 KiB), and the DRAM
//! timing model.
//!
//! Every L1 organization funnels its misses through [`MemSystem::fetch`],
//! which accounts the full round trip: request serialization into the
//! 30×24 crossbar, slice bank access, L2 hit or DRAM service, and the
//! data's return trip.  In-flight line merging (L2 MSHR behaviour) is
//! modeled so duplicate misses to one line don't multiply DRAM traffic.

use crate::cache::{Probe, SectoredCache};
use crate::config::GpuConfig;
use crate::dram::Dram;
use crate::mem::{decode, LineAddr, MemTxn};
use crate::noc::XbarReservation;
use crate::resource::BankedCalendar;
use crate::stats::{ContentionStats, ResourceClass};
use crate::util::fxhash::FxHashMap;

#[derive(Debug, Clone, Copy, Default)]
pub struct L2Stats {
    pub accesses: u64,
    pub hits: u64,
    pub misses: u64,
    pub writes: u64,
    pub writebacks_to_dram: u64,
    /// Flits crossing the cores→L2 and L2→cores crossbar (bandwidth
    /// demand — Table I column 5).
    pub request_flits: u64,
    pub response_flits: u64,
    /// Sum of round-trip latencies for fetches (for mean).
    pub total_fetch_latency: u64,
    pub fetches: u64,
    /// Requests that stalled on a full finite buffer (NoC injection port
    /// or DRAM controller queue) and retried at the backlog-drain cycle.
    pub backpressure_stalls: u64,
}

/// In-flight fill tracking for MSHR-style merging at L2.
#[derive(Debug, Clone, Copy)]
struct InFlight {
    ready: u64,
}

#[derive(Debug)]
pub struct MemSystem {
    /// cores → slices request network and slices → cores response network,
    /// reservation-mode 30×24 / 24×30 crossbars.
    req_net: XbarReservation,
    resp_net: XbarReservation,
    slices: Vec<SectoredCache>,
    /// One access port per slice (the L2 bank).
    slice_ports: BankedCalendar,
    dram: Dram,
    in_flight: FxHashMap<LineAddr, InFlight>,
    pub stats: L2Stats,
    /// Per-core contention attribution for the memory side (NoC links, L2
    /// slice ports, DRAM) — charged to the *requesting* core.
    con: ContentionStats,
    // Geometry/timing captured from config.
    n_slices: usize,
    l2_latency: u32,
    flit_bytes: usize,
    sector_bytes: usize,
    header_flits: u32,
}

impl MemSystem {
    pub fn new(cfg: &GpuConfig) -> Self {
        let buffer_limit = cfg.noc.in_buffer_flits as u64;
        MemSystem {
            req_net: XbarReservation::new(cfg.cores, cfg.l2.slices, cfg.noc.latency, buffer_limit),
            resp_net: XbarReservation::new(cfg.l2.slices, cfg.cores, cfg.noc.latency, buffer_limit),
            slices: (0..cfg.l2.slices)
                .map(|_| {
                    SectoredCache::new(
                        cfg.l2.sets_per_slice(),
                        cfg.l2.assoc,
                        cfg.l2.mshr_entries,
                        cfg.l2.mshr_merges,
                    )
                })
                .collect(),
            slice_ports: BankedCalendar::new(cfg.l2.slices),
            dram: Dram::new(&cfg.dram, cfg.core_clock_ghz),
            in_flight: FxHashMap::default(),
            stats: L2Stats::default(),
            con: ContentionStats::new(cfg.cores),
            n_slices: cfg.l2.slices,
            l2_latency: cfg.l2.latency,
            flit_bytes: cfg.noc.flit_bytes,
            sector_bytes: cfg.l2.sector_bytes,
            header_flits: 1,
        }
    }

    fn data_flits(&self, sectors: u32) -> u32 {
        let bytes = sectors as usize * self.sector_bytes;
        (bytes.div_ceil(self.flit_bytes)) as u32 + self.header_flits
    }

    /// Can core `core` inject a request now? (crossbar input buffer check)
    pub fn would_accept(&self, core: usize, now: u64) -> bool {
        self.req_net.would_accept(core, now)
    }

    /// Full miss round trip for a read transaction: returns the cycle the
    /// fill data arrives back at the requesting L1, stamping the
    /// transaction's `l2_dispatch`/`mem_done` hops along the way.
    ///
    /// The transaction carries the routing split: `txn.endpoint` is the
    /// physical NoC port (where the request enters and the data returns —
    /// the home slice for decoupled-sharing misses), while every queued
    /// cycle — NoC injection backpressure, crossbar ports, the slice
    /// access port, the DRAM controller queue, bank and bus waits, and
    /// the response crossing — is charged to `txn.attr_core` (the
    /// suffering core) via [`MemTxn::charge`], landing in both the
    /// per-core [`ContentionStats`] and the transaction's own breakdown.
    pub fn fetch(&mut self, txn: &mut MemTxn, now: u64) -> u64 {
        let core = txn.endpoint as usize;
        let line = txn.req.line;
        let slice = decode::l2_slice(line, self.n_slices);
        let sectors = txn.fetch_sectors.count_ones().max(1);
        txn.hops.l2_dispatch = now;

        // Finite input buffer: when the core's injection port backlog
        // exceeds the buffer horizon the request stalls *upstream* (in the
        // L1 / MSHR) and retries at the backlog-drain cycle instead of
        // reserving into an unbounded future.
        let stall = self.req_net.admission_delay(core, now);
        if stall > 0 {
            self.stats.backpressure_stalls += 1;
            txn.charge(&mut self.con, ResourceClass::NocLink, stall);
        }
        let start = now + stall;

        // Request crossing (header-only packet for reads).
        self.stats.request_flits += self.header_flits as u64;
        let req_hop = self.req_net.transfer(core, slice, start, self.header_flits);
        txn.charge(&mut self.con, ResourceClass::NocLink, req_hop.queued);
        let at_slice = req_hop.grant;

        // Slice bank port (tag + data pipeline occupancy).
        let port = self.slice_ports.reserve(slice, at_slice, 1);
        txn.charge(&mut self.con, ResourceClass::L2Slice, port.queued);
        let grant = port.grant;

        self.stats.accesses += 1;
        let data_ready = match self.slices[slice].tags.lookup(line, txn.fetch_sectors) {
            Probe::Hit { .. } => {
                self.stats.hits += 1;
                grant + self.l2_latency as u64
            }
            probe => {
                // Sector miss or full miss — check in-flight merge first.
                if let Some(f) = self.in_flight.get(&line) {
                    if f.ready > at_slice {
                        self.stats.hits += 1; // merged: no extra DRAM trip
                        f.ready
                    } else {
                        // Stale entry: the fill landed; treat as hit.
                        self.stats.hits += 1;
                        self.in_flight.remove(&line);
                        grant + self.l2_latency as u64
                    }
                } else {
                    self.stats.misses += 1;
                    let fetch_sectors = match probe {
                        Probe::SectorMiss { missing, .. } => missing.count_ones(),
                        _ => 4, // fetch the whole line on a line miss
                    };
                    // DRAM controller queue backpressure, then the access.
                    let dram_at = grant + self.l2_latency as u64;
                    let (d, dstall) = self.dram.read_gated(line, dram_at, fetch_sectors);
                    if dstall > 0 {
                        self.stats.backpressure_stalls += 1;
                    }
                    txn.charge(&mut self.con, ResourceClass::Dram, dstall + d.queued);
                    let dram_done = d.grant;
                    // Fill the slice; only a dirty victim goes back to
                    // DRAM (fill reports clean victims too — they are
                    // dropped here without write traffic).
                    let (_, evicted) = self.slices[slice].fill(line, 0b1111);
                    if let Some(ev) = evicted.filter(|e| e.needs_writeback()) {
                        self.stats.writebacks_to_dram += 1;
                        self.dram
                            .access(ev.line, dram_done, ev.dirty_sectors.count_ones(), true);
                    }
                    self.in_flight.insert(line, InFlight { ready: dram_done });
                    dram_done
                }
            }
        };

        // Response crossing back to the core with the data sectors.
        let flits = self.data_flits(sectors);
        self.stats.response_flits += flits as u64;
        let resp_hop = self.resp_net.transfer(slice, core, data_ready, flits);
        txn.charge(&mut self.con, ResourceClass::NocLink, resp_hop.queued);
        let at_core = resp_hop.grant;
        txn.hops.mem_done = at_core;

        self.stats.total_fetch_latency += at_core - now;
        self.stats.fetches += 1;
        at_core
    }

    /// Write (write-through store or a dirty-line writeback from an L1):
    /// fire-and-forget — occupies the request network and the slice, data
    /// is absorbed by the L2 (write-allocate).  Queueing is attributed to
    /// the issuing core even though nothing waits on the completion.
    pub fn write(&mut self, core: usize, line: LineAddr, sectors: u32, now: u64) {
        self.write_for(core, line, sectors, now, core)
    }

    /// [`write`](Self::write) with the contention charged to `attr_core`
    /// instead of the injecting port's core — decoupled-sharing victim
    /// writebacks leave through the home slice's port but are caused by
    /// (and charged to) the requesting core.
    pub fn write_for(&mut self, core: usize, line: LineAddr, sectors: u32, now: u64, attr_core: usize) {
        let slice = decode::l2_slice(line, self.n_slices);
        let flits = self.data_flits(sectors);
        let stall = self.req_net.admission_delay(core, now);
        if stall > 0 {
            self.stats.backpressure_stalls += 1;
            self.con.add(attr_core, ResourceClass::NocLink, stall);
        }
        self.stats.request_flits += flits as u64;
        self.stats.writes += 1;
        let hop = self.req_net.transfer(core, slice, now + stall, flits);
        self.con.add(attr_core, ResourceClass::NocLink, hop.queued);
        let port = self.slice_ports.reserve(slice, hop.grant, 1);
        self.con.add(attr_core, ResourceClass::L2Slice, port.queued);
        let grant = port.grant;
        match self.slices[slice].tags.lookup(line, 0) {
            Probe::Hit { .. } | Probe::SectorMiss { .. } => {
                let mask = ((1u16 << sectors.min(4)) - 1) as u8;
                // lint: allow(tag-mutation-helper) — L2 slice tags sit below L1; the residency index never mirrors them
                self.slices[slice].tags.mark_dirty(line, mask);
            }
            Probe::Miss => {
                // Write-allocate without a DRAM read (sectored: the written
                // sectors become valid+dirty).
                let mask = ((1u16 << sectors.min(4)) - 1) as u8;
                let (_, evicted) = self.slices[slice].fill(line, mask);
                // lint: allow(tag-mutation-helper) — L2 slice tags sit below L1; the residency index never mirrors them
                self.slices[slice].tags.mark_dirty(line, mask);
                if let Some(ev) = evicted.filter(|e| e.needs_writeback()) {
                    self.stats.writebacks_to_dram += 1;
                    self.dram.access(
                        ev.line,
                        grant + self.l2_latency as u64,
                        ev.dirty_sectors.count_ones(),
                        true,
                    );
                }
            }
        }
    }

    /// Memory-side per-core contention attribution (combined with the L1
    /// organization's share by [`crate::engine::Engine::contention`]).
    pub fn contention(&self) -> &ContentionStats {
        &self.con
    }

    pub fn mean_fetch_latency(&self) -> f64 {
        if self.stats.fetches == 0 {
            0.0
        } else {
            self.stats.total_fetch_latency as f64 / self.stats.fetches as f64
        }
    }

    pub fn l2_hit_rate(&self) -> f64 {
        if self.stats.accesses == 0 {
            0.0
        } else {
            self.stats.hits as f64 / self.stats.accesses as f64
        }
    }

    /// Total crossbar flits (L2 bandwidth demand metric, Table I).
    pub fn noc_flits(&self) -> u64 {
        self.stats.request_flits + self.stats.response_flits
    }

    pub fn dram_stats(&self) -> crate::dram::DramStats {
        self.dram.stats
    }

    /// Drop stale in-flight entries (bounded memory on long runs).
    pub fn sweep_in_flight(&mut self, now: u64) {
        self.in_flight.retain(|_, f| f.ready > now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, L1ArchKind};
    use crate::mem::{AccessKind, MemRequest};
    use crate::stats::ContentionBreakdown;

    fn req(id: u64, core: u32, line: LineAddr) -> MemRequest {
        MemRequest {
            id,
            core,
            warp: 0,
            inst: 0,
            line,
            sectors: 0b1111,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    fn fetch(m: &mut MemSystem, r: MemRequest, now: u64) -> u64 {
        let mut txn = MemTxn::new(r, now);
        m.fetch(&mut txn, now)
    }

    fn sys() -> MemSystem {
        MemSystem::new(&GpuConfig::tiny(L1ArchKind::Private))
    }

    #[test]
    fn cold_fetch_pays_l2_latency_plus_dram() {
        let mut m = sys();
        let done = fetch(&mut m, req(1, 0, 1000), 0);
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        assert!(done > cfg.l2.latency as u64, "cold miss must include DRAM: {done}");
        assert_eq!(m.stats.misses, 1);
    }

    #[test]
    fn fetch_stamps_hops_and_txn_breakdown() {
        let mut m = sys();
        let mut txn = MemTxn::new(req(1, 0, 1000), 7);
        let done = m.fetch(&mut txn, 7);
        assert_eq!(txn.hops.l2_dispatch, 7);
        assert_eq!(txn.hops.mem_done, done);
        // Cold single fetch: nothing to queue behind.
        assert_eq!(txn.queued.total(), 0);
        // Hammering the same port must charge the transactions.
        let mut worst = ContentionBreakdown::default();
        for i in 0..50 {
            let mut t = MemTxn::new(req(10 + i, 0, 1000), 1000);
            m.fetch(&mut t, 1000);
            worst.merge(&t.queued);
        }
        assert!(worst.total() > 0, "queueing must land on the transactions");
        assert_eq!(
            m.contention().total().total(),
            worst.total(),
            "transaction-accumulated queueing equals the per-core ledger"
        );
    }

    #[test]
    fn second_fetch_hits_in_l2() {
        let mut m = sys();
        let d1 = fetch(&mut m, req(1, 0, 1000), 0);
        let t = d1 + 1000;
        let d2 = fetch(&mut m, req(2, 1, 1000), t) - t;
        assert_eq!(m.stats.hits, 1);
        assert!(
            d2 < d1,
            "L2 hit round trip ({d2}) must beat cold miss ({d1})"
        );
        // An L2 hit still costs ≈ the 188-cycle L2 latency + NoC.
        assert!(d2 >= 188, "hit latency {d2}");
    }

    #[test]
    fn concurrent_same_line_misses_merge() {
        let mut m = sys();
        fetch(&mut m, req(1, 0, 500), 0);
        let before = m.dram_stats().reads;
        fetch(&mut m, req(2, 1, 500), 1); // in flight → merged
        assert_eq!(m.dram_stats().reads, before, "no duplicate DRAM read");
    }

    #[test]
    fn writes_count_flits_and_allocate() {
        let mut m = sys();
        m.write(0, 77, 4, 0);
        assert_eq!(m.stats.writes, 1);
        assert!(m.stats.request_flits > 1, "write carries data flits");
        // Subsequent read of the written line hits in L2.
        let t = 10_000;
        fetch(&mut m, req(1, 0, 77), t);
        assert_eq!(m.stats.hits, 1);
    }

    #[test]
    fn noc_contention_raises_latency_under_load() {
        let mut m = sys();
        // Warm one line so fetches hit in L2 (isolating NoC effects).
        fetch(&mut m, req(0, 0, 42), 0);
        let t0 = 100_000;
        let solo = fetch(&mut m, req(1, 0, 42), t0) - t0;
        // Now hammer the same core's input port at one instant.
        let t1 = 200_000;
        let mut worst = 0;
        for i in 0..50 {
            let d = fetch(&mut m, req(10 + i, 0, 42), t1) - t1;
            worst = worst.max(d);
        }
        assert!(worst > solo, "50 simultaneous fetches must queue: {worst} vs {solo}");
    }

    #[test]
    fn hit_rate_and_mean_latency_metrics() {
        let mut m = sys();
        fetch(&mut m, req(1, 0, 1), 0);
        fetch(&mut m, req(2, 0, 1), 100_000);
        assert!((m.l2_hit_rate() - 0.5).abs() < 1e-9);
        assert!(m.mean_fetch_latency() > 0.0);
    }

    #[test]
    fn sweep_drops_stale_entries() {
        let mut m = sys();
        fetch(&mut m, req(1, 0, 500), 0);
        assert_eq!(m.in_flight.len(), 1);
        m.sweep_in_flight(u64::MAX);
        assert!(m.in_flight.is_empty());
    }
}
