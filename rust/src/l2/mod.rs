//! The memory system below L1: cores↔L2 crossbar (Table II interconnect),
//! banked sectored L2 slices (memory-side, 24 × 128 KiB), and the DRAM
//! timing model.
//!
//! Every L1 organization funnels its misses through here.  The walk is
//! *phased* so the per-slice half can fan out across host threads
//! (`--mem-workers`, [`walk::WalkPool`]) without changing a single
//! simulated metric:
//!
//! * **B1 — front end (canonical order).**  [`MemSystem::begin_fetch`]
//!   retires everything cross-slice: the injection-port admission check
//!   (backpressure is per source core), the cores→slices crossbar
//!   crossing, and the hop stamp.  It resolves the miss into a
//!   slice-bound [`FetchDesc`].
//! * **B2 — slice walk (parallel).**  [`MemSystem::run_walk`] hands each
//!   slice's descriptor batch, in ascending descriptor order, to the
//!   slice's exclusive owner: [`SliceWalk::walk_one`] reserves the slice
//!   port, probes the slice tags, merges onto in-flight fills and
//!   installs misses.  A slice touches only its own state, so any
//!   worker partition produces byte-identical outcomes.
//! * **DRAM sub-phase (canonical order).**  DRAM controllers
//!   (`decode::dram_bank`) interleave at row granularity and therefore
//!   cannot align with slice partitions; DRAM admission stays a serial
//!   canonical sub-phase on the coordinator, finalizing every miss's
//!   fill cycle (and every same-epoch merge onto it).
//! * **B3 — merge (canonical order).**  [`MemSystem::finish_fetch`]
//!   charges the recorded queueing, crosses the response back over the
//!   slices→cores crossbar and stamps the transaction — all statistics
//!   counters move here, in the canonical transaction order.
//!
//! [`MemSystem::fetch`] wraps the three phases into one synchronous call
//! (a single-request epoch) for direct callers and tests.  In-flight
//! line merging (L2 MSHR behaviour) is modeled so duplicate misses to
//! one line don't multiply DRAM traffic.

pub mod walk;

use crate::cache::{Eviction, Probe, SectoredCache};
use crate::config::GpuConfig;
use crate::dram::Dram;
use crate::engine::SimError;
use crate::mem::{decode, LineAddr, MemTxn, SectorMask};
use crate::noc::XbarReservation;
use crate::resource::Calendar;
use crate::stats::{ContentionStats, ResourceClass};
use crate::util::fxhash::FxHashMap;

use walk::WalkPool;

#[derive(Debug, Clone, Copy, Default)]
pub struct L2Stats {
    pub accesses: u64,
    pub hits: u64,
    pub misses: u64,
    pub writes: u64,
    pub writebacks_to_dram: u64,
    /// Flits crossing the cores→L2 and L2→cores crossbar (bandwidth
    /// demand — Table I column 5).
    pub request_flits: u64,
    pub response_flits: u64,
    /// Sum of round-trip latencies for fetches (for mean).
    pub total_fetch_latency: u64,
    pub fetches: u64,
    /// Requests that stalled on a full finite buffer (NoC injection port
    /// or DRAM controller queue) and retried at the backlog-drain cycle.
    pub backpressure_stalls: u64,
}

/// In-flight fill tracking for MSHR-style merging at a slice.  `Pending`
/// exists only *within* an epoch (between B2 and the DRAM sub-phase,
/// which finalizes every entry to `Ready`); it indexes the descriptor
/// that owns the fetch.
#[derive(Debug, Clone, Copy)]
enum Flight {
    Ready(u64),
    Pending(u32),
}

/// What the slice walk concluded about one descriptor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Outcome {
    /// B2 has not run yet.
    Unwalked,
    /// Line (and sectors) present in the slice.
    Hit,
    /// Merged onto a fill from an earlier epoch (ready cycle known).
    Merged,
    /// Stale in-flight entry (fill landed); served like a hit.
    Stale,
    /// Full/sector miss — the DRAM sub-phase owns the fill timing.
    Miss,
    /// Merged onto a miss scheduled earlier in this epoch; resolves to
    /// the owning descriptor's fill cycle in the DRAM sub-phase.
    MergedPending(u32),
}

/// A slice-bound fetch in flight through the phased walk: B1 fills the
/// routing half, B2 the slice half, the DRAM sub-phase the timing, and
/// B3 consumes it.
#[derive(Debug, Clone, Copy)]
pub struct FetchDesc {
    line: LineAddr,
    slice: usize,
    /// NoC endpoint the response returns to.
    endpoint: usize,
    fetch_sectors: SectorMask,
    /// Sectors the response carries (for flit accounting).
    resp_sectors: u32,
    /// Cycle the request reached the slice (B1's crossbar grant).
    at_slice: u64,
    outcome: Outcome,
    port_queued: u64,
    port_grant: u64,
    /// Sectors a DRAM read must bring in (miss only).
    fetch_count: u32,
    /// Dirty slice victim of the B2 fill (miss only).
    victim: Option<Eviction>,
    dram_queued: u64,
    /// Cycle the data is ready at the slice (set by B2 for hits/merges,
    /// by the DRAM sub-phase for misses).
    data_ready: u64,
}

/// One L2 slice's exclusively-owned state: its sectored cache, its
/// access port and its share of the in-flight merge table.  During B2 a
/// walk worker owns a contiguous run of these outright; nothing in here
/// is shared across slices.
#[derive(Debug)]
pub struct SliceWalk {
    cache: SectoredCache,
    /// The slice's access port (tag + data pipeline occupancy).
    port: Calendar,
    in_flight: FxHashMap<LineAddr, Flight>,
}

impl SliceWalk {
    /// B2 for one descriptor: reserve the slice port, probe the tags,
    /// classify.  Touches only this slice's state and records every
    /// outcome on the descriptor — statistics and contention stay with
    /// the coordinator (B3).
    fn walk_one(&mut self, idx: u32, d: &mut FetchDesc, l2_latency: u64) {
        let port = self.port.reserve(d.at_slice, 1);
        d.port_queued = port.queued;
        d.port_grant = port.grant;
        match self.cache.tags.lookup(d.line, d.fetch_sectors) {
            Probe::Hit { .. } => {
                d.outcome = Outcome::Hit;
                d.data_ready = port.grant + l2_latency;
            }
            probe => match self.in_flight.get(&d.line).copied() {
                Some(Flight::Ready(r)) if r > d.at_slice => {
                    // Merged: no extra DRAM trip.
                    d.outcome = Outcome::Merged;
                    d.data_ready = r;
                }
                Some(Flight::Ready(_)) => {
                    // Stale entry: the fill landed; treat as hit.
                    self.in_flight.remove(&d.line);
                    d.outcome = Outcome::Stale;
                    d.data_ready = port.grant + l2_latency;
                }
                Some(Flight::Pending(owner)) => {
                    // A miss scheduled earlier in this epoch owns the
                    // line — merge unconditionally.  (This is the rule
                    // that keeps B2 independent of DRAM timing and
                    // therefore parallel.)
                    d.outcome = Outcome::MergedPending(owner);
                }
                None => {
                    d.outcome = Outcome::Miss;
                    d.fetch_count = match probe {
                        Probe::SectorMiss { missing, .. } => missing.count_ones(),
                        _ => 4, // fetch the whole line on a line miss
                    };
                    // Fill the slice; only a dirty victim goes back to
                    // DRAM (fill reports clean victims too — they are
                    // dropped here without write traffic).
                    let (_, evicted) = self.cache.fill(d.line, 0b1111);
                    d.victim = evicted.filter(Eviction::needs_writeback);
                    self.in_flight.insert(d.line, Flight::Pending(idx));
                }
            },
        }
    }
}

#[derive(Debug)]
pub struct MemSystem {
    /// cores → slices request network and slices → cores response network,
    /// reservation-mode 30×24 / 24×30 crossbars.
    req_net: XbarReservation,
    resp_net: XbarReservation,
    /// Per-slice state, exclusively owned by one walk worker during B2.
    walks: Vec<SliceWalk>,
    dram: Dram,
    /// The epoch's fetch descriptors in canonical request order.
    descs: Vec<FetchDesc>,
    /// Persistent walk workers (`engine.mem_workers`; 1 = serial walk).
    pool: WalkPool,
    /// Inside a `begin_epoch`/`end_epoch` window: `fetch` is replaced by
    /// the begin/walk/finish split.
    phased: bool,
    pub stats: L2Stats,
    /// Per-core contention attribution for the memory side (NoC links, L2
    /// slice ports, DRAM) — charged to the *requesting* core.
    con: ContentionStats,
    // Geometry/timing captured from config.
    n_slices: usize,
    l2_latency: u32,
    flit_bytes: usize,
    sector_bytes: usize,
    header_flits: u32,
}

impl MemSystem {
    pub fn new(cfg: &GpuConfig) -> Self {
        let buffer_limit = cfg.noc.in_buffer_flits as u64;
        MemSystem {
            req_net: XbarReservation::new(cfg.cores, cfg.l2.slices, cfg.noc.latency, buffer_limit),
            resp_net: XbarReservation::new(cfg.l2.slices, cfg.cores, cfg.noc.latency, buffer_limit),
            walks: (0..cfg.l2.slices)
                .map(|_| SliceWalk {
                    cache: SectoredCache::new(
                        cfg.l2.sets_per_slice(),
                        cfg.l2.assoc,
                        cfg.l2.mshr_entries,
                        cfg.l2.mshr_merges,
                    ),
                    port: Calendar::new(),
                    in_flight: FxHashMap::default(),
                })
                .collect(),
            dram: Dram::new(&cfg.dram, cfg.core_clock_ghz),
            descs: Vec::new(),
            pool: WalkPool::new(cfg.engine.mem_workers, cfg.l2.slices),
            phased: false,
            stats: L2Stats::default(),
            con: ContentionStats::new(cfg.cores),
            n_slices: cfg.l2.slices,
            l2_latency: cfg.l2.latency,
            flit_bytes: cfg.noc.flit_bytes,
            sector_bytes: cfg.l2.sector_bytes,
            header_flits: 1,
        }
    }

    fn data_flits(&self, sectors: u32) -> u32 {
        let bytes = sectors as usize * self.sector_bytes;
        (bytes.div_ceil(self.flit_bytes)) as u32 + self.header_flits
    }

    /// Can core `core` inject a request now? (crossbar input buffer check)
    pub fn would_accept(&self, core: usize, now: u64) -> bool {
        self.req_net.would_accept(core, now)
    }

    /// Enter a phased epoch: L1 organizations defer their misses through
    /// [`begin_fetch`](Self::begin_fetch) until
    /// [`run_walk`](Self::run_walk) and the B3 finish pass run.
    pub fn begin_epoch(&mut self) {
        debug_assert!(!self.phased && self.descs.is_empty());
        self.phased = true;
    }

    /// Close the epoch after every deferred transaction was finished.
    pub fn end_epoch(&mut self) {
        debug_assert!(self.phased);
        self.descs.clear();
        self.phased = false;
    }

    /// Inside a `begin_epoch`/`end_epoch` window?
    pub fn phased(&self) -> bool {
        self.phased
    }

    /// B1: the cross-slice front half of a miss — injection-port
    /// admission (backpressure is per source core), the request
    /// crossing, and the hop stamp.  Returns the descriptor index the
    /// B3 finish pass consumes.
    pub fn begin_fetch(&mut self, txn: &mut MemTxn, now: u64) -> usize {
        let core = txn.endpoint as usize;
        let line = txn.req.line;
        let slice = decode::l2_slice(line, self.n_slices);
        let resp_sectors = txn.fetch_sectors.count_ones().max(1);
        txn.hops.l2_dispatch = now;

        // Finite input buffer: when the core's injection port backlog
        // exceeds the buffer horizon the request stalls *upstream* (in the
        // L1 / MSHR) and retries at the backlog-drain cycle instead of
        // reserving into an unbounded future.
        let stall = self.req_net.admission_delay(core, now);
        if stall > 0 {
            self.stats.backpressure_stalls += 1;
            txn.charge(&mut self.con, ResourceClass::NocLink, stall);
        }
        let start = now + stall;

        // Request crossing (header-only packet for reads).
        self.stats.request_flits += self.header_flits as u64;
        let req_hop = self.req_net.transfer(core, slice, start, self.header_flits);
        txn.charge(&mut self.con, ResourceClass::NocLink, req_hop.queued);

        self.descs.push(FetchDesc {
            line,
            slice,
            endpoint: core,
            fetch_sectors: txn.fetch_sectors,
            resp_sectors,
            at_slice: req_hop.grant,
            outcome: Outcome::Unwalked,
            port_queued: 0,
            port_grant: 0,
            fetch_count: 0,
            victim: None,
            dram_queued: 0,
            data_ready: 0,
        });
        self.descs.len() - 1
    }

    /// B2 + the DRAM sub-phase: walk every descriptor at its slice (fanned
    /// out across the worker pool when `mem_workers > 1`), then finalize
    /// miss timing through the DRAM controllers in canonical order.
    ///
    /// `Err` means a walk worker died ([`SimError::WorkerPanic`]); its
    /// slice units are lost with it, so the `MemSystem` is poisoned and
    /// must be dropped with the failed engine.  The serial path
    /// (`mem_workers <= 1`) is infallible.
    pub fn run_walk(&mut self) -> Result<(), SimError> {
        if self.descs.is_empty() {
            return Ok(());
        }
        let l2l = self.l2_latency as u64;
        if self.pool.workers() <= 1 {
            let (walks, descs) = (&mut self.walks, &mut self.descs);
            for (i, d) in descs.iter_mut().enumerate() {
                walks[d.slice].walk_one(i as u32, d, l2l);
            }
        } else {
            self.pool.run(&mut self.walks, &mut self.descs, l2l)?;
        }
        self.dram_subphase();
        Ok(())
    }

    /// The canonical DRAM sub-phase: every miss pays controller-queue
    /// backpressure and the banked access in ascending descriptor order,
    /// and every same-epoch merge resolves to its owner's fill cycle.
    /// Serial because DRAM banks interleave at row granularity
    /// (`decode::dram_bank`) and cannot align with slice partitions.
    fn dram_subphase(&mut self) {
        for i in 0..self.descs.len() {
            match self.descs[i].outcome {
                Outcome::Miss => {
                    let d = self.descs[i];
                    let dram_at = d.port_grant + self.l2_latency as u64;
                    let (g, dstall) = self.dram.read_gated(d.line, dram_at, d.fetch_count);
                    if dstall > 0 {
                        self.stats.backpressure_stalls += 1;
                    }
                    if let Some(ev) = d.victim {
                        self.stats.writebacks_to_dram += 1;
                        self.dram
                            .access(ev.line, g.grant, ev.dirty_sectors.count_ones(), true);
                    }
                    self.walks[d.slice].in_flight.insert(d.line, Flight::Ready(g.grant));
                    let d = &mut self.descs[i];
                    d.dram_queued = dstall + g.queued;
                    d.data_ready = g.grant;
                }
                Outcome::MergedPending(owner) => {
                    // The owner is always an earlier descriptor, already
                    // finalized by this loop.
                    self.descs[i].data_ready = self.descs[owner as usize].data_ready;
                }
                _ => {}
            }
        }
    }

    /// B3: close one descriptor in canonical transaction order — count
    /// the outcome, charge the recorded queueing, cross the response
    /// back to the endpoint and stamp the transaction.  Returns the
    /// cycle the fill data arrives back at the requesting L1.
    pub fn finish_fetch(&mut self, idx: usize, txn: &mut MemTxn) -> u64 {
        let d = self.descs[idx];
        self.stats.accesses += 1;
        match d.outcome {
            Outcome::Miss => self.stats.misses += 1,
            Outcome::Hit | Outcome::Merged | Outcome::Stale | Outcome::MergedPending(_) => {
                self.stats.hits += 1
            }
            Outcome::Unwalked => unreachable!("finish_fetch before run_walk"),
        }
        txn.charge(&mut self.con, ResourceClass::L2Slice, d.port_queued);
        txn.charge(&mut self.con, ResourceClass::Dram, d.dram_queued);

        // Response crossing back to the core with the data sectors.
        let flits = self.data_flits(d.resp_sectors);
        self.stats.response_flits += flits as u64;
        let resp_hop = self.resp_net.transfer(d.slice, d.endpoint, d.data_ready, flits);
        txn.charge(&mut self.con, ResourceClass::NocLink, resp_hop.queued);
        let at_core = resp_hop.grant;
        txn.hops.mem_done = at_core;

        self.stats.total_fetch_latency += at_core - txn.hops.l2_dispatch;
        self.stats.fetches += 1;
        at_core
    }

    /// Full miss round trip for a read transaction as one synchronous
    /// call — a single-request epoch through the phased walk.  Returns
    /// the cycle the fill data arrives back at the requesting L1,
    /// stamping the transaction's `l2_dispatch`/`mem_done` hops along
    /// the way.
    ///
    /// The transaction carries the routing split: `txn.endpoint` is the
    /// physical NoC port (where the request enters and the data returns —
    /// the home slice for decoupled-sharing misses), while every queued
    /// cycle — NoC injection backpressure, crossbar ports, the slice
    /// access port, the DRAM controller queue, bank and bus waits, and
    /// the response crossing — is charged to `txn.attr_core` (the
    /// suffering core) via [`MemTxn::charge`], landing in both the
    /// per-core [`ContentionStats`] and the transaction's own breakdown.
    pub fn fetch(&mut self, txn: &mut MemTxn, now: u64) -> u64 {
        debug_assert!(
            !self.phased,
            "inside an epoch use begin_fetch/run_walk/finish_fetch"
        );
        debug_assert!(self.descs.is_empty());
        let idx = self.begin_fetch(txn, now);
        // The direct-call path keeps its non-Result signature: a dead
        // pool worker surfacing here re-raises as a panic and is
        // contained by the exec layer's `catch_unwind`, not this stack.
        // lint: allow(sim-panic) — escalation point for non-Result callers; contained at the job boundary
        self.run_walk().expect("memwalk worker died during a direct fetch");
        let at_core = self.finish_fetch(idx, txn);
        self.descs.clear();
        at_core
    }

    /// Write (write-through store or a dirty-line writeback from an L1):
    /// fire-and-forget — occupies the request network and the slice, data
    /// is absorbed by the L2 (write-allocate).  Queueing is attributed to
    /// the issuing core even though nothing waits on the completion.
    pub fn write(&mut self, core: usize, line: LineAddr, sectors: u32, now: u64) {
        self.write_for(core, line, sectors, now, core)
    }

    /// [`write`](Self::write) with the contention charged to `attr_core`
    /// instead of the injecting port's core — decoupled-sharing victim
    /// writebacks leave through the home slice's port but are caused by
    /// (and charged to) the requesting core.
    pub fn write_for(&mut self, core: usize, line: LineAddr, sectors: u32, now: u64, attr_core: usize) {
        let slice = decode::l2_slice(line, self.n_slices);
        let flits = self.data_flits(sectors);
        let stall = self.req_net.admission_delay(core, now);
        if stall > 0 {
            self.stats.backpressure_stalls += 1;
            self.con.add(attr_core, ResourceClass::NocLink, stall);
        }
        self.stats.request_flits += flits as u64;
        self.stats.writes += 1;
        let hop = self.req_net.transfer(core, slice, now + stall, flits);
        self.con.add(attr_core, ResourceClass::NocLink, hop.queued);
        let port = self.walks[slice].port.reserve(hop.grant, 1);
        self.con.add(attr_core, ResourceClass::L2Slice, port.queued);
        let grant = port.grant;
        match self.walks[slice].cache.tags.lookup(line, 0) {
            Probe::Hit { .. } | Probe::SectorMiss { .. } => {
                let mask = ((1u16 << sectors.min(4)) - 1) as u8;
                // lint: allow(tag-mutation-helper) — L2 slice tags sit below L1; the residency index never mirrors them
                self.walks[slice].cache.tags.mark_dirty(line, mask);
            }
            Probe::Miss => {
                // Write-allocate without a DRAM read (sectored: the written
                // sectors become valid+dirty).
                let mask = ((1u16 << sectors.min(4)) - 1) as u8;
                let (_, evicted) = self.walks[slice].cache.fill(line, mask);
                // lint: allow(tag-mutation-helper) — L2 slice tags sit below L1; the residency index never mirrors them
                self.walks[slice].cache.tags.mark_dirty(line, mask);
                if let Some(ev) = evicted.filter(|e| e.needs_writeback()) {
                    self.stats.writebacks_to_dram += 1;
                    self.dram.access(
                        ev.line,
                        grant + self.l2_latency as u64,
                        ev.dirty_sectors.count_ones(),
                        true,
                    );
                }
            }
        }
    }

    /// Memory-side per-core contention attribution (combined with the L1
    /// organization's share by [`crate::engine::Engine::contention`]).
    pub fn contention(&self) -> &ContentionStats {
        &self.con
    }

    pub fn mean_fetch_latency(&self) -> f64 {
        if self.stats.fetches == 0 {
            0.0
        } else {
            self.stats.total_fetch_latency as f64 / self.stats.fetches as f64
        }
    }

    pub fn l2_hit_rate(&self) -> f64 {
        if self.stats.accesses == 0 {
            0.0
        } else {
            self.stats.hits as f64 / self.stats.accesses as f64
        }
    }

    /// Total crossbar flits (L2 bandwidth demand metric, Table I).
    pub fn noc_flits(&self) -> u64 {
        self.stats.request_flits + self.stats.response_flits
    }

    pub fn dram_stats(&self) -> crate::dram::DramStats {
        self.dram.stats
    }

    /// Diagnostic horizon over the whole memory system: the earliest
    /// cycle at-or-after `now` at which any component — either crossbar,
    /// any slice access port, or any DRAM bus — still has booked work.
    /// `None` means the memory side is completely idle, which at a
    /// deadlock *is* the diagnosis (see `engine::FailSnapshot`).
    pub fn next_event(&self, now: u64) -> Option<u64> {
        [
            self.req_net.next_event(now),
            self.resp_net.next_event(now),
            self.walks.iter().filter_map(|w| w.port.next_event(now)).min(),
            self.dram.next_event(now),
        ]
        .into_iter()
        .flatten()
        .min()
    }

    /// In-flight entries across every slice (tests and audits).
    pub fn in_flight_len(&self) -> usize {
        self.walks.iter().map(|w| w.in_flight.len()).sum()
    }

    /// Drop stale in-flight entries (bounded memory on long runs).  Runs
    /// at fixed cycle boundaries on the coordinator, outside any epoch,
    /// so the sweep cadence can never depend on the walk partition.
    pub fn sweep_in_flight(&mut self, now: u64) {
        debug_assert!(!self.phased, "sweep must stay outside the epoch window");
        for w in &mut self.walks {
            w.in_flight.retain(|_, f| match *f {
                Flight::Ready(r) => r > now,
                // Pending never survives past run_walk's DRAM sub-phase;
                // retain defensively rather than hide a logic error.
                Flight::Pending(_) => true,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, L1ArchKind};
    use crate::mem::{AccessKind, MemRequest};
    use crate::stats::ContentionBreakdown;

    fn req(id: u64, core: u32, line: LineAddr) -> MemRequest {
        MemRequest {
            id,
            core,
            warp: 0,
            inst: 0,
            line,
            sectors: 0b1111,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    fn fetch(m: &mut MemSystem, r: MemRequest, now: u64) -> u64 {
        let mut txn = MemTxn::new(r, now);
        m.fetch(&mut txn, now)
    }

    fn sys() -> MemSystem {
        MemSystem::new(&GpuConfig::tiny(L1ArchKind::Private))
    }

    #[test]
    fn cold_fetch_pays_l2_latency_plus_dram() {
        let mut m = sys();
        let done = fetch(&mut m, req(1, 0, 1000), 0);
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        assert!(done > cfg.l2.latency as u64, "cold miss must include DRAM: {done}");
        assert_eq!(m.stats.misses, 1);
    }

    #[test]
    fn fetch_stamps_hops_and_txn_breakdown() {
        let mut m = sys();
        let mut txn = MemTxn::new(req(1, 0, 1000), 7);
        let done = m.fetch(&mut txn, 7);
        assert_eq!(txn.hops.l2_dispatch, 7);
        assert_eq!(txn.hops.mem_done, done);
        // Cold single fetch: nothing to queue behind.
        assert_eq!(txn.queued.total(), 0);
        // Hammering the same port must charge the transactions.
        let mut worst = ContentionBreakdown::default();
        for i in 0..50 {
            let mut t = MemTxn::new(req(10 + i, 0, 1000), 1000);
            m.fetch(&mut t, 1000);
            worst.merge(&t.queued);
        }
        assert!(worst.total() > 0, "queueing must land on the transactions");
        assert_eq!(
            m.contention().total().total(),
            worst.total(),
            "transaction-accumulated queueing equals the per-core ledger"
        );
    }

    #[test]
    fn second_fetch_hits_in_l2() {
        let mut m = sys();
        let d1 = fetch(&mut m, req(1, 0, 1000), 0);
        let t = d1 + 1000;
        let d2 = fetch(&mut m, req(2, 1, 1000), t) - t;
        assert_eq!(m.stats.hits, 1);
        assert!(
            d2 < d1,
            "L2 hit round trip ({d2}) must beat cold miss ({d1})"
        );
        // An L2 hit still costs ≈ the 188-cycle L2 latency + NoC.
        assert!(d2 >= 188, "hit latency {d2}");
    }

    #[test]
    fn concurrent_same_line_misses_merge() {
        let mut m = sys();
        fetch(&mut m, req(1, 0, 500), 0);
        let before = m.dram_stats().reads;
        fetch(&mut m, req(2, 1, 500), 1); // in flight → merged
        assert_eq!(m.dram_stats().reads, before, "no duplicate DRAM read");
    }

    #[test]
    fn writes_count_flits_and_allocate() {
        let mut m = sys();
        m.write(0, 77, 4, 0);
        assert_eq!(m.stats.writes, 1);
        assert!(m.stats.request_flits > 1, "write carries data flits");
        // Subsequent read of the written line hits in L2.
        let t = 10_000;
        fetch(&mut m, req(1, 0, 77), t);
        assert_eq!(m.stats.hits, 1);
    }

    #[test]
    fn noc_contention_raises_latency_under_load() {
        let mut m = sys();
        // Warm one line so fetches hit in L2 (isolating NoC effects).
        fetch(&mut m, req(0, 0, 42), 0);
        let t0 = 100_000;
        let solo = fetch(&mut m, req(1, 0, 42), t0) - t0;
        // Now hammer the same core's input port at one instant.
        let t1 = 200_000;
        let mut worst = 0;
        for i in 0..50 {
            let d = fetch(&mut m, req(10 + i, 0, 42), t1) - t1;
            worst = worst.max(d);
        }
        assert!(worst > solo, "50 simultaneous fetches must queue: {worst} vs {solo}");
    }

    #[test]
    fn hit_rate_and_mean_latency_metrics() {
        let mut m = sys();
        fetch(&mut m, req(1, 0, 1), 0);
        fetch(&mut m, req(2, 0, 1), 100_000);
        assert!((m.l2_hit_rate() - 0.5).abs() < 1e-9);
        assert!(m.mean_fetch_latency() > 0.0);
    }

    #[test]
    fn sweep_drops_stale_entries() {
        let mut m = sys();
        fetch(&mut m, req(1, 0, 500), 0);
        assert_eq!(m.in_flight_len(), 1);
        m.sweep_in_flight(u64::MAX);
        assert_eq!(m.in_flight_len(), 0);
    }

    /// One mixed epoch (misses, same-epoch merges, cross-slice spread)
    /// replayed at several worker counts: every simulated observable —
    /// fill cycles, statistics, contention — must be byte-identical to
    /// the serial walk.  The engine-level twin lives in
    /// `rust/tests/memwalk_determinism.rs`.
    #[test]
    fn phased_epoch_identical_at_any_worker_count() {
        let run = |workers: usize| {
            let mut cfg = GpuConfig::tiny(L1ArchKind::Private);
            cfg.engine.mem_workers = workers;
            let mut m = MemSystem::new(&cfg);
            let mut dones = Vec::new();
            for epoch in 0..3u64 {
                let now = epoch * 50;
                m.begin_epoch();
                let mut open: Vec<(usize, MemTxn)> = Vec::new();
                for i in 0..24u64 {
                    // Lines spread over slices, with repeats for merges.
                    let mut txn = MemTxn::new(req(i, (i % 4) as u32, 100 + i % 9), now);
                    let idx = m.begin_fetch(&mut txn, now);
                    open.push((idx, txn));
                }
                m.run_walk().unwrap();
                for (idx, txn) in open.iter_mut() {
                    dones.push(m.finish_fetch(*idx, txn));
                    dones.push(txn.queued.total());
                }
                m.end_epoch();
            }
            let s = m.stats;
            (
                dones,
                (s.accesses, s.hits, s.misses, s.fetches, s.backpressure_stalls),
                (s.request_flits, s.response_flits, s.total_fetch_latency),
                m.contention().total().total(),
                m.dram_stats().reads,
                m.in_flight_len(),
            )
        };
        let serial = run(1);
        for workers in [2, 3, 4] {
            assert_eq!(run(workers), serial, "mem-workers {workers} drifted");
        }
    }
}
