//! Locality analytics runtime: executes the inter-core locality
//! classification pipeline that `python/compile/model.py` defines
//! (§IV: apps are "classified based on the amount of replicated data
//! across all cores").
//!
//! The pipeline is: per-core sampled cache-line traces → 32-bit mix hash
//! into `nbits` buckets → per-core occupancy signatures → core×core
//! bucket-sharing matrix → linear-counting collision correction → a
//! locality score and a replication factor.
//!
//! The original seed executed the JAX/Pallas AOT artifact
//! (`artifacts/locality.hlo.txt`) through the `xla` PJRT bindings.  That
//! crate is unavailable in the offline build environment, so this module
//! now ships a **native interpreter** of the same compute graph: the hash
//! (`trace::signature::hash_line`), the signature construction, and the
//! linear-counting correction are kept bit-for-bit/f32-for-f32 faithful
//! to the Python model, and the metadata sidecar
//! (`artifacts/locality.meta.json`) is still honoured when present so an
//! AOT-exported artifact's shapes keep driving trace sampling.  The
//! golden-value test in [`crate::trace::signature`] pins the hash against
//! the Python outputs, and the tests below pin score/replication against
//! the exact set-arithmetic oracle.

use std::path::Path;

use crate::mem::LineAddr;
use crate::trace::signature::hash_line;
use crate::trace::LocalityClass;
use crate::util::json::Json;

/// Default shapes, matching `python/compile/model.py` (30 SIMT cores
/// padded to 32 rows, 4096 sampled lines per core, 8192 hash buckets).
pub const DEFAULT_META: ArtifactMeta = ArtifactMeta {
    num_cores: 30,
    padded_cores: 32,
    trace_len: 4096,
    nbits: 8192,
};

/// Runtime failure (artifact metadata malformed, trace shape mismatch).
#[derive(Debug)]
pub struct RuntimeError {
    msg: String,
}

impl RuntimeError {
    fn new(msg: impl Into<String>) -> Self {
        RuntimeError { msg: msg.into() }
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias for runtime operations.
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Shapes of the analytics pipeline.  Read from the artifact metadata
/// sidecar when one exists, [`DEFAULT_META`] otherwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub num_cores: usize,
    pub padded_cores: usize,
    pub trace_len: usize,
    pub nbits: usize,
}

/// Output of one analysis run.
#[derive(Debug, Clone)]
pub struct LocalityReport {
    /// Core×core bucket-sharing matrix (padded_cores²; padding rows zero).
    pub sharing_matrix: Vec<f32>,
    pub padded_cores: usize,
    /// Per-core distinct-line estimates (collision-corrected popcounts).
    pub sizes: Vec<f32>,
    /// Mean replicated fraction, in [0, 1].
    pub locality_score: f32,
    /// Σ sizes / |union|, in [1, C].
    pub replication_factor: f32,
}

impl LocalityReport {
    /// The paper's binary classification.  Threshold chosen in the gap
    /// between the two measured app populations — high-locality apps score
    /// ≥ 0.27, low-locality ones ≤ 0.10 (see EXPERIMENTS.md §Classify).
    pub fn class(&self) -> LocalityClass {
        if self.locality_score >= 0.15 {
            LocalityClass::High
        } else {
            LocalityClass::Low
        }
    }

    /// Bucket-sharing count between cores `a` and `b`.
    pub fn shared_with(&self, a: usize, b: usize) -> f32 {
        self.sharing_matrix[a * self.padded_cores + b]
    }
}

/// The locality-analytics pipeline, ready to analyze traces.
#[derive(Debug, Clone, Copy)]
pub struct LocalityAnalyzer {
    meta: ArtifactMeta,
}

impl LocalityAnalyzer {
    /// Load pipeline shapes from `artifact_dir/locality.meta.json` when it
    /// exists (an AOT export's sidecar), or fall back to [`DEFAULT_META`].
    /// Fails only on a *malformed* sidecar — a missing one is fine.
    pub fn load(artifact_dir: &str) -> Result<Self> {
        let meta_path = Path::new(artifact_dir).join("locality.meta.json");
        if !meta_path.exists() {
            return Ok(LocalityAnalyzer { meta: DEFAULT_META });
        }
        let meta_text = std::fs::read_to_string(&meta_path)
            .map_err(|e| RuntimeError::new(format!("reading {meta_path:?}: {e}")))?;
        let meta_json = Json::parse(&meta_text)
            .map_err(|e| RuntimeError::new(format!("parsing artifact metadata: {e}")))?;
        let field = |k: &str| {
            meta_json
                .get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| RuntimeError::new(format!("metadata missing field '{k}'")))
        };
        let meta = ArtifactMeta {
            num_cores: field("num_cores")?,
            padded_cores: field("padded_cores")?,
            trace_len: field("trace_len")?,
            nbits: field("nbits")?,
        };
        if meta.padded_cores < meta.num_cores || meta.nbits == 0 || meta.trace_len == 0 {
            return Err(RuntimeError::new(format!("inconsistent metadata: {meta:?}")));
        }
        Ok(LocalityAnalyzer { meta })
    }

    pub fn meta(&self) -> ArtifactMeta {
        self.meta
    }

    /// Analyze per-core traces (line addresses; truncated to the
    /// pipeline's fixed `trace_len` per core).
    pub fn analyze(&self, traces: &[Vec<LineAddr>]) -> Result<LocalityReport> {
        let c = self.meta.padded_cores;
        let t = self.meta.trace_len;
        let nbits = self.meta.nbits;
        if traces.len() > c {
            return Err(RuntimeError::new(format!(
                "{} cores exceed pipeline capacity {c}",
                traces.len()
            )));
        }

        // Per-core occupancy signatures as bit vectors over hash buckets.
        let words = (nbits + 63) / 64;
        let mut sigs: Vec<Vec<u64>> = vec![vec![0u64; words]; c];
        let mut active = 0usize;
        for (i, trace) in traces.iter().enumerate() {
            if !trace.is_empty() {
                active += 1;
            }
            for &line in trace.iter().take(t) {
                // The model hashes 32-bit values; fold the 64-bit line the
                // same way the PJRT caller did.
                let folded = (line ^ (line >> 32)) as u32;
                let bucket = hash_line(folded, nbits as u32) as usize;
                sigs[i][bucket / 64] |= 1u64 << (bucket % 64);
            }
        }

        // Raw popcounts and the pairwise bucket-sharing matrix S = B·Bᵀ.
        let popcount = |s: &[u64]| s.iter().map(|w| w.count_ones() as u64).sum::<u64>();
        let raw_sizes: Vec<f32> = sigs.iter().map(|s| popcount(s) as f32).collect();
        let mut sharing = vec![0f32; c * c];
        for i in 0..c {
            for j in 0..c {
                let inter: u64 = sigs[i]
                    .iter()
                    .zip(&sigs[j])
                    .map(|(a, b)| (a & b).count_ones() as u64)
                    .sum();
                sharing[i * c + j] = inter as f32;
            }
        }

        // Linear-counting collision correction (Whang et al.), exactly as
        // in `compile.model.linear_count`.
        let lc = |pc: f32| -> f32 {
            let frac = (pc / nbits as f32).clamp(0.0, 1.0 - 1.0 / nbits as f32);
            -(nbits as f32) * (-frac).ln_1p()
        };
        let sizes: Vec<f32> = raw_sizes.iter().map(|&p| lc(p)).collect();
        let total: f32 = sizes.iter().sum();

        // Pairwise intersections via inclusion–exclusion on corrected
        // sizes: |A∩B| ≈ lc(pcA) + lc(pcB) − lc(pcA + pcB − pc(A∧B)).
        let mut off_diag = 0f32;
        for i in 0..c {
            for j in 0..c {
                if i == j {
                    continue;
                }
                let pair_union = raw_sizes[i] + raw_sizes[j] - sharing[i * c + j];
                let inter = (lc(raw_sizes[i]) + lc(raw_sizes[j]) - lc(pair_union)).max(0.0);
                off_diag += inter;
            }
        }

        // Union popcount over all signatures.
        let mut union_sig = vec![0u64; words];
        for s in &sigs {
            for (u, w) in union_sig.iter_mut().zip(s) {
                *u |= w;
            }
        }
        let union = lc(popcount(&union_sig) as f32);

        let denom = (total * (active as f32 - 1.0).max(1.0)).max(1.0);
        let locality_score = off_diag / denom;
        let replication_factor = total / union.max(1.0);
        Ok(LocalityReport {
            sharing_matrix: sharing,
            padded_cores: c,
            sizes,
            locality_score,
            replication_factor,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_without_artifacts_uses_default_meta() {
        let an = LocalityAnalyzer::load("does/not/exist").unwrap();
        assert_eq!(an.meta(), DEFAULT_META);
        assert_eq!(an.meta().num_cores, 30);
    }

    #[test]
    fn analyze_disjoint_and_shared_traces() {
        let an = LocalityAnalyzer::load("artifacts").unwrap();

        // Disjoint traces → score ~0, replication ~1.
        let disjoint: Vec<Vec<LineAddr>> =
            (0..8).map(|c| (0..64u64).map(|k| c * 1_000_000 + k).collect()).collect();
        let r = an.analyze(&disjoint).unwrap();
        assert!(r.locality_score < 0.02, "score {}", r.locality_score);
        assert!((r.replication_factor - 1.0).abs() < 0.05);
        assert_eq!(r.class(), LocalityClass::Low);

        // Identical traces → high score, replication ≈ #cores.
        let shared: Vec<Vec<LineAddr>> = (0..8).map(|_| (0..64u64).collect()).collect();
        let r2 = an.analyze(&shared).unwrap();
        assert!(r2.locality_score > 0.2, "score {}", r2.locality_score);
        assert!(r2.replication_factor > 6.0);
        assert_eq!(r2.class(), LocalityClass::High);
    }

    #[test]
    fn pipeline_agrees_with_exact_oracle() {
        use crate::trace::signature::exact_locality;
        use crate::util::rng::Pcg32;
        let an = LocalityAnalyzer::load("artifacts").unwrap();
        let mut rng = Pcg32::new(77, 0);
        // Mixed workload: half shared pool, half private.
        let traces: Vec<Vec<LineAddr>> = (0..10)
            .map(|c| {
                (0..256)
                    .map(|_| {
                        if rng.chance(0.5) {
                            rng.next_below(512) as u64
                        } else {
                            (c + 1) as u64 * 1_000_000 + rng.next_below(512) as u64
                        }
                    })
                    .collect()
            })
            .collect();
        let report = an.analyze(&traces).unwrap();
        // Exact metrics on deduped traces (the pipeline dedups via bitmap).
        let deduped: Vec<Vec<LineAddr>> = traces
            .iter()
            .map(|t| {
                let s: std::collections::HashSet<_> = t.iter().copied().collect();
                s.into_iter().collect()
            })
            .collect();
        let (score, repl) = exact_locality(&deduped);
        // Hash-bucket estimate vs exact sets: within a few percent.
        assert!(
            (report.locality_score as f64 - score).abs() < 0.05,
            "pipeline {} vs exact {score}",
            report.locality_score
        );
        assert!(
            (report.replication_factor as f64 - repl).abs() / repl < 0.1,
            "pipeline {} vs exact {repl}",
            report.replication_factor
        );
    }

    #[test]
    fn too_many_traces_is_an_error() {
        let an = LocalityAnalyzer::load("artifacts").unwrap();
        let traces: Vec<Vec<LineAddr>> = (0..40).map(|c| vec![c]).collect();
        assert!(an.analyze(&traces).is_err());
    }
}
