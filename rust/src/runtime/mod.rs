//! PJRT runtime: loads the JAX/Pallas-authored locality analytics
//! artifact (`artifacts/locality.hlo.txt`) and executes it from Rust.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! request-path consumer.  The artifact computes, from per-core sampled
//! cache-line traces, the core×core sharing matrix, per-core working-set
//! sizes, a locality score and a replication factor — the classification
//! step of §IV ("classified based on the amount of replicated data across
//! all cores") plus the cross-check signal for the simulator's own
//! replication audit.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::mem::LineAddr;
use crate::trace::LocalityClass;
use crate::util::json::Json;

/// Shapes baked into the artifact (validated against the metadata
/// sidecar at load time).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub num_cores: usize,
    pub padded_cores: usize,
    pub trace_len: usize,
    pub nbits: usize,
}

/// Output of one artifact execution.
#[derive(Debug, Clone)]
pub struct LocalityReport {
    /// Core×core bucket-sharing matrix (padded_cores²; padding rows zero).
    pub sharing_matrix: Vec<f32>,
    pub padded_cores: usize,
    /// Per-core signature popcounts.
    pub sizes: Vec<f32>,
    /// Mean replicated fraction, in [0, 1].
    pub locality_score: f32,
    /// Σ sizes / |union|, in [1, C].
    pub replication_factor: f32,
}

impl LocalityReport {
    /// The paper's binary classification.  Threshold chosen in the gap
    /// between the two measured app populations — high-locality apps score
    /// ≥ 0.27, low-locality ones ≤ 0.10 (see EXPERIMENTS.md §Classify).
    pub fn class(&self) -> LocalityClass {
        if self.locality_score >= 0.15 {
            LocalityClass::High
        } else {
            LocalityClass::Low
        }
    }

    pub fn shared_with(&self, a: usize, b: usize) -> f32 {
        self.sharing_matrix[a * self.padded_cores + b]
    }
}

/// A loaded, compiled locality-analytics executable.
pub struct LocalityAnalyzer {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

impl std::fmt::Debug for LocalityAnalyzer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LocalityAnalyzer").field("meta", &self.meta).finish()
    }
}

impl LocalityAnalyzer {
    /// Load + compile `artifacts/locality.hlo.txt` (HLO text — the
    /// xla_extension-0.5.1-safe interchange; see python/compile/aot.py).
    pub fn load(artifact_dir: &str) -> Result<Self> {
        let hlo_path = Path::new(artifact_dir).join("locality.hlo.txt");
        let meta_path = Path::new(artifact_dir).join("locality.meta.json");
        let meta_text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} (run `make artifacts`)"))?;
        let meta_json = Json::parse(&meta_text).context("parsing artifact metadata")?;
        let meta = ArtifactMeta {
            num_cores: meta_json.get("num_cores").and_then(Json::as_usize).context("num_cores")?,
            padded_cores: meta_json
                .get("padded_cores")
                .and_then(Json::as_usize)
                .context("padded_cores")?,
            trace_len: meta_json.get("trace_len").and_then(Json::as_usize).context("trace_len")?,
            nbits: meta_json.get("nbits").and_then(Json::as_usize).context("nbits")?,
        };

        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("artifact path not utf-8")?,
        )
        .context("parsing HLO text (run `make artifacts`)")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling locality artifact")?;
        Ok(LocalityAnalyzer { exe, meta })
    }

    pub fn meta(&self) -> ArtifactMeta {
        self.meta
    }

    /// Analyze per-core traces (line addresses; truncated/padded to the
    /// artifact's fixed shape).
    pub fn analyze(&self, traces: &[Vec<LineAddr>]) -> Result<LocalityReport> {
        let c = self.meta.padded_cores;
        let t = self.meta.trace_len;
        if traces.len() > c {
            bail!("{} cores exceed artifact capacity {}", traces.len(), c);
        }
        let mut lines = vec![0i32; c * t];
        let mut valid = vec![0i32; c * t];
        for (i, trace) in traces.iter().enumerate() {
            for (j, &line) in trace.iter().take(t).enumerate() {
                // The artifact hashes 32-bit values; fold the 64-bit line.
                lines[i * t + j] = (line ^ (line >> 32)) as u32 as i32;
                valid[i * t + j] = 1;
            }
        }
        let lines_lit = xla::Literal::vec1(&lines).reshape(&[c as i64, t as i64])?;
        let valid_lit = xla::Literal::vec1(&valid).reshape(&[c as i64, t as i64])?;

        let mut result = self.exe.execute::<xla::Literal>(&[lines_lit, valid_lit])?[0][0]
            .to_literal_sync()?;
        let mut outs = result.decompose_tuple()?;
        if outs.len() != 4 {
            bail!("artifact returned {} outputs, expected 4", outs.len());
        }
        let repl = outs.pop().unwrap().to_vec::<f32>()?[0];
        let score = outs.pop().unwrap().to_vec::<f32>()?[0];
        let sizes = outs.pop().unwrap().to_vec::<f32>()?;
        let sharing = outs.pop().unwrap().to_vec::<f32>()?;
        Ok(LocalityReport {
            sharing_matrix: sharing,
            padded_cores: c,
            sizes,
            locality_score: score,
            replication_factor: repl,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_available() -> bool {
        Path::new("artifacts/locality.hlo.txt").exists()
    }

    #[test]
    fn analyze_disjoint_and_shared_traces() {
        if !artifact_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let an = LocalityAnalyzer::load("artifacts").unwrap();
        assert_eq!(an.meta().num_cores, 30);

        // Disjoint traces → score ~0, replication ~1.
        let disjoint: Vec<Vec<LineAddr>> =
            (0..8).map(|c| (0..64u64).map(|k| c * 1_000_000 + k).collect()).collect();
        let r = an.analyze(&disjoint).unwrap();
        assert!(r.locality_score < 0.02, "score {}", r.locality_score);
        assert!((r.replication_factor - 1.0).abs() < 0.05);
        assert_eq!(r.class(), LocalityClass::Low);

        // Identical traces → high score, replication ≈ #cores.
        let shared: Vec<Vec<LineAddr>> = (0..8).map(|_| (0..64u64).collect()).collect();
        let r2 = an.analyze(&shared).unwrap();
        assert!(r2.locality_score > 0.2, "score {}", r2.locality_score);
        assert!(r2.replication_factor > 6.0);
        assert_eq!(r2.class(), LocalityClass::High);
    }

    #[test]
    fn artifact_agrees_with_exact_oracle() {
        if !artifact_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        use crate::trace::signature::exact_locality;
        use crate::util::rng::Pcg32;
        let an = LocalityAnalyzer::load("artifacts").unwrap();
        let mut rng = Pcg32::new(77, 0);
        // Mixed workload: half shared pool, half private.
        let traces: Vec<Vec<LineAddr>> = (0..10)
            .map(|c| {
                (0..256)
                    .map(|_| {
                        if rng.chance(0.5) {
                            rng.next_below(512) as u64
                        } else {
                            (c + 1) as u64 * 1_000_000 + rng.next_below(512) as u64
                        }
                    })
                    .collect()
            })
            .collect();
        let report = an.analyze(&traces).unwrap();
        // Exact metrics on deduped traces (the artifact dedups via bitmap).
        let deduped: Vec<Vec<LineAddr>> = traces
            .iter()
            .map(|t| {
                let s: std::collections::HashSet<_> = t.iter().copied().collect();
                s.into_iter().collect()
            })
            .collect();
        let (score, repl) = exact_locality(&deduped);
        // Hash-bucket estimate vs exact sets: within a few percent.
        assert!(
            (report.locality_score as f64 - score).abs() < 0.05,
            "artifact {} vs exact {score}",
            report.locality_score
        );
        assert!(
            (report.replication_factor as f64 - repl).abs() / repl < 0.1,
            "artifact {} vs exact {repl}",
            report.replication_factor
        );
    }
}
