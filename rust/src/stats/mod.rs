//! Simulation statistics: IPC, cache hit classes, contention pressure,
//! and the paper's L1-latency metric (completion time of all requests of
//! a single load instruction, §IV-C).

use crate::util::fxhash::FxHashMap;
use crate::util::json::Json;

/// The shared-resource classes whose queueing delay the simulator
/// attributes (the CIAO-style decomposition of inter-thread interference;
/// see PAPERS.md).  Every reservation in the memory hierarchy charges its
/// queued cycles to exactly one of these classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ResourceClass {
    /// L1 tag-pipeline bank occupancy (miss-path tag probes).
    L1TagBank,
    /// L1 data-array bank serialization (the paper's bank-conflict
    /// mechanism — the decoupled-sharing pathology of Fig. 3).
    L1DataBank,
    /// ATA aggregated-tag comparator-group arbitration (§III-B).
    AtaComparator,
    /// Intra-cluster sharing fabric: the decoupled/ATA crossbar ports and
    /// the remote-sharing probe/data ring.
    ClusterXbar,
    /// Cores ↔ L2 interconnect ports, including finite-input-buffer
    /// backpressure stalls.
    NocLink,
    /// L2 slice access-port serialization.
    L2Slice,
    /// DRAM bank-ready waits, data-bus queueing, and controller-queue
    /// backpressure stalls.
    Dram,
    /// Dispatch stalls because the L1 MSHR pool was full.
    MshrFull,
}

impl ResourceClass {
    pub const COUNT: usize = 8;
    pub const ALL: [ResourceClass; ResourceClass::COUNT] = [
        ResourceClass::L1TagBank,
        ResourceClass::L1DataBank,
        ResourceClass::AtaComparator,
        ResourceClass::ClusterXbar,
        ResourceClass::NocLink,
        ResourceClass::L2Slice,
        ResourceClass::Dram,
        ResourceClass::MshrFull,
    ];

    pub fn name(self) -> &'static str {
        match self {
            ResourceClass::L1TagBank => "l1-tag-bank",
            ResourceClass::L1DataBank => "l1-data-bank",
            ResourceClass::AtaComparator => "ata-comparator",
            ResourceClass::ClusterXbar => "cluster-xbar",
            ResourceClass::NocLink => "noc-link",
            ResourceClass::L2Slice => "l2-slice",
            ResourceClass::Dram => "dram",
            ResourceClass::MshrFull => "mshr-full",
        }
    }
}

/// Queued cycles per resource class — the per-resource stall breakdown of
/// the paper's Fig. 3 / Fig. 11 style analysis (where do private, shared,
/// remote and ATA organizations burn their cycles).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ContentionBreakdown {
    cycles: [u64; ResourceClass::COUNT],
}

impl ContentionBreakdown {
    #[inline]
    pub fn add(&mut self, class: ResourceClass, cycles: u64) {
        self.cycles[class as usize] += cycles;
    }

    #[inline]
    pub fn get(&self, class: ResourceClass) -> u64 {
        self.cycles[class as usize]
    }

    /// Total queued cycles across all resource classes.
    pub fn total(&self) -> u64 {
        self.cycles.iter().sum()
    }

    /// Stall cycles on the remote path — the intra-cluster sharing fabric
    /// (probe ring / cluster crossbar) a request crosses to reach another
    /// core's data.  The paper's headline claim is that ATA's probe
    /// filtering strictly shrinks this relative to remote-sharing.
    pub fn remote_path(&self) -> u64 {
        self.get(ResourceClass::ClusterXbar)
    }

    pub fn merge(&mut self, other: &ContentionBreakdown) {
        for (a, b) in self.cycles.iter_mut().zip(other.cycles.iter()) {
            *a += b;
        }
    }

    /// Counters accumulated since `before` (per-run reporting on a warm
    /// engine).  Counters are monotone, so plain subtraction is safe.
    pub fn delta(&self, before: &ContentionBreakdown) -> ContentionBreakdown {
        let mut out = ContentionBreakdown::default();
        for (i, o) in out.cycles.iter_mut().enumerate() {
            *o = self.cycles[i] - before.cycles[i];
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = ResourceClass::ALL
            .iter()
            .map(|&c| (c.name(), self.get(c).into()))
            .collect();
        fields.push(("total", self.total().into()));
        Json::obj(fields)
    }

    /// Inverse of [`to_json`](Self::to_json) (the resume manifest path).
    /// Lenient: absent classes read as 0; the serialized `total` is
    /// ignored and re-derived from the per-class counters.
    pub fn from_json(j: &Json) -> ContentionBreakdown {
        let mut out = ContentionBreakdown::default();
        for &c in &ResourceClass::ALL {
            out.cycles[c as usize] = j.get(c.name()).and_then(Json::as_u64).unwrap_or(0);
        }
        out
    }
}

/// Per-core contention attribution: one [`ContentionBreakdown`] per
/// requesting core plus the aggregate.  Components charge the *suffering*
/// core (the one whose request queued), so `Engine::run_multi` can roll
/// cores up into application lanes and show which resource one app steals
/// from another.
#[derive(Debug, Clone, Default)]
pub struct ContentionStats {
    per_core: Vec<ContentionBreakdown>,
    total: ContentionBreakdown,
}

impl ContentionStats {
    pub fn new(cores: usize) -> Self {
        ContentionStats {
            per_core: vec![ContentionBreakdown::default(); cores],
            total: ContentionBreakdown::default(),
        }
    }

    /// Charge `cycles` of queueing on `class` to `core`.  Zero-cycle adds
    /// are accepted (and free) so call sites stay branchless.
    #[inline]
    pub fn add(&mut self, core: usize, class: ResourceClass, cycles: u64) {
        if cycles > 0 {
            self.per_core[core].add(class, cycles);
            self.total.add(class, cycles);
        }
    }

    pub fn total(&self) -> &ContentionBreakdown {
        &self.total
    }

    pub fn per_core(&self) -> &[ContentionBreakdown] {
        &self.per_core
    }

    /// Sum of the breakdowns of cores `[first, first + count)` — an
    /// application lane's share under spatial multitasking.
    pub fn lane_total(&self, first: usize, count: usize) -> ContentionBreakdown {
        let mut out = ContentionBreakdown::default();
        for c in &self.per_core[first..first + count] {
            out.merge(c);
        }
        out
    }

    /// Element-wise accumulate (combining the L1 organization's stats with
    /// the memory system's).  Both sides must cover the same core count.
    pub fn absorb(&mut self, other: &ContentionStats) {
        debug_assert_eq!(self.per_core.len(), other.per_core.len());
        for (a, b) in self.per_core.iter_mut().zip(other.per_core.iter()) {
            a.merge(b);
        }
        self.total.merge(&other.total);
    }

    /// Counters accumulated since `before` (per-run deltas on a warm
    /// engine).
    pub fn delta(&self, before: &ContentionStats) -> ContentionStats {
        debug_assert_eq!(self.per_core.len(), before.per_core.len());
        ContentionStats {
            per_core: self
                .per_core
                .iter()
                .zip(before.per_core.iter())
                .map(|(a, b)| a.delta(b))
                .collect(),
            total: self.total.delta(&before.total),
        }
    }
}

/// Per-L1-organization counters (aggregated over the whole GPU).
#[derive(Debug, Clone, Copy, Default)]
pub struct L1Stats {
    pub accesses: u64,
    /// Full hits in the requesting core's local cache.
    pub local_hits: u64,
    /// Hits served from another cluster cache (remote/decoupled/ATA).
    pub remote_hits: u64,
    /// Sector misses (line present, sectors missing).
    pub sector_misses: u64,
    /// Full line misses that went to L2.
    pub misses: u64,
    pub writes: u64,
    /// Requests rejected for structural hazards (MSHR full, queue full) —
    /// each costs the core a retry cycle.
    pub rejects: u64,
    /// Cycles of queueing delay accumulated at L1 data banks (bank
    /// conflict serialization — the decoupled-sharing pathology).
    pub bank_conflict_cycles: u64,
    /// Cycles of queueing at the intra-cluster crossbar / ring.
    pub sharing_net_cycles: u64,
    /// Probe messages sent (remote-sharing NoC pressure).
    pub probes_sent: u64,
    /// Remote read fell back to L2 because the remote copy was dirty
    /// (§III-C).
    pub dirty_remote_fallbacks: u64,
    /// Remote hits deliberately redirected to L2 because the holder's
    /// data banks / fabric ports were contended (the `ata-bypass`
    /// organization's CIAO-style interference-aware bypass).  A side
    /// tally: each bypassed access still lands in the `misses` outcome
    /// class.
    pub bypasses: u64,
    /// Lines filled into a cache.
    pub fills: u64,
    /// MSHR merges (request piggybacked on an in-flight miss).
    pub mshr_merges: u64,
}

impl L1Stats {
    /// Counters accumulated since `before` (for per-run reporting on a
    /// reused engine).  Destructures exhaustively so adding a field
    /// without updating the delta is a compile error.
    pub fn delta(&self, before: &L1Stats) -> L1Stats {
        let L1Stats {
            accesses,
            local_hits,
            remote_hits,
            sector_misses,
            misses,
            writes,
            rejects,
            bank_conflict_cycles,
            sharing_net_cycles,
            probes_sent,
            dirty_remote_fallbacks,
            bypasses,
            fills,
            mshr_merges,
        } = *self;
        L1Stats {
            accesses: accesses - before.accesses,
            local_hits: local_hits - before.local_hits,
            remote_hits: remote_hits - before.remote_hits,
            sector_misses: sector_misses - before.sector_misses,
            misses: misses - before.misses,
            writes: writes - before.writes,
            rejects: rejects - before.rejects,
            bank_conflict_cycles: bank_conflict_cycles - before.bank_conflict_cycles,
            sharing_net_cycles: sharing_net_cycles - before.sharing_net_cycles,
            probes_sent: probes_sent - before.probes_sent,
            dirty_remote_fallbacks: dirty_remote_fallbacks - before.dirty_remote_fallbacks,
            bypasses: bypasses - before.bypasses,
            fills: fills - before.fills,
            mshr_merges: mshr_merges - before.mshr_merges,
        }
    }

    /// Accumulate another run's counters (aggregating per-job results in
    /// submission order — see [`RunTotals`]).  Exhaustive destructure so
    /// a new field without a merge is a compile error.
    pub fn merge(&mut self, other: &L1Stats) {
        let L1Stats {
            accesses,
            local_hits,
            remote_hits,
            sector_misses,
            misses,
            writes,
            rejects,
            bank_conflict_cycles,
            sharing_net_cycles,
            probes_sent,
            dirty_remote_fallbacks,
            bypasses,
            fills,
            mshr_merges,
        } = *other;
        self.accesses += accesses;
        self.local_hits += local_hits;
        self.remote_hits += remote_hits;
        self.sector_misses += sector_misses;
        self.misses += misses;
        self.writes += writes;
        self.rejects += rejects;
        self.bank_conflict_cycles += bank_conflict_cycles;
        self.sharing_net_cycles += sharing_net_cycles;
        self.probes_sent += probes_sent;
        self.dirty_remote_fallbacks += dirty_remote_fallbacks;
        self.bypasses += bypasses;
        self.fills += fills;
        self.mshr_merges += mshr_merges;
    }

    pub fn hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            return 0.0;
        }
        (self.local_hits + self.remote_hits) as f64 / self.accesses as f64
    }

    pub fn local_hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            return 0.0;
        }
        self.local_hits as f64 / self.accesses as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("accesses", self.accesses.into()),
            ("local_hits", self.local_hits.into()),
            ("remote_hits", self.remote_hits.into()),
            ("sector_misses", self.sector_misses.into()),
            ("misses", self.misses.into()),
            ("writes", self.writes.into()),
            ("rejects", self.rejects.into()),
            ("bank_conflict_cycles", self.bank_conflict_cycles.into()),
            ("sharing_net_cycles", self.sharing_net_cycles.into()),
            ("probes_sent", self.probes_sent.into()),
            ("dirty_remote_fallbacks", self.dirty_remote_fallbacks.into()),
            ("bypasses", self.bypasses.into()),
            ("fills", self.fills.into()),
            ("mshr_merges", self.mshr_merges.into()),
            ("hit_rate", self.hit_rate().into()),
        ])
    }

    /// Inverse of [`to_json`](Self::to_json) (the resume manifest path).
    /// Absent counters read as 0; `hit_rate` is re-derived.
    pub fn from_json(j: &Json) -> L1Stats {
        let n = |k: &str| j.get(k).and_then(Json::as_u64).unwrap_or(0);
        L1Stats {
            accesses: n("accesses"),
            local_hits: n("local_hits"),
            remote_hits: n("remote_hits"),
            sector_misses: n("sector_misses"),
            misses: n("misses"),
            writes: n("writes"),
            rejects: n("rejects"),
            bank_conflict_cycles: n("bank_conflict_cycles"),
            sharing_net_cycles: n("sharing_net_cycles"),
            probes_sent: n("probes_sent"),
            dirty_remote_fallbacks: n("dirty_remote_fallbacks"),
            bypasses: n("bypasses"),
            fills: n("fills"),
            mshr_merges: n("mshr_merges"),
        }
    }
}

/// Host-performance telemetry of the cluster residency index (the O(1)
/// replacement for the O(cluster) aggregated-tag probe scan).
///
/// Deliberately **not** part of [`SimResult`]/[`MultiResult`] JSON:
/// result JSON must be byte-identical whether the index is on or off
/// (`sharing.residency_index` changes only wall clock), and these
/// counters obviously differ between the two modes.  `ata-sim run`
/// prints them to stderr, and white-box tests read them, through
/// [`L1Arch::residency_stats`](crate::l1arch::L1Arch::residency_stats).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Probes answered by the O(1) index (the fast path).
    pub index_probes: u64,
    /// Probes answered by the O(cluster) brute-force scan (index off).
    pub scan_probes: u64,
    /// Index mutations applied (fills + evictions + dirty markings).
    pub index_ops: u64,
    /// Resident-line entries across all cluster indexes right now.
    pub index_lines: u64,
    /// High-water mark of `index_lines` (bounds index memory).
    pub peak_lines: u64,
}

impl ResidencyStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("index_probes", self.index_probes.into()),
            ("scan_probes", self.scan_probes.into()),
            ("index_ops", self.index_ops.into()),
            ("index_lines", self.index_lines.into()),
            ("peak_lines", self.peak_lines.into()),
        ])
    }
}

/// Host-performance telemetry of the event-driven engine clock.
///
/// Like [`ResidencyStats`], deliberately **not** part of
/// [`SimResult`]/[`MultiResult`] JSON: result JSON must be byte-identical
/// whether `engine.event_driven` is on or off (the flag changes only wall
/// clock), and these counters obviously differ between the two modes.
/// `ata-sim run` prints them to stderr, and white-box tests read them,
/// through [`Engine::event_stats`](crate::engine::Engine::event_stats).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EventStats {
    /// Engine-loop iterations — cycles at which the cores were actually
    /// ticked.
    pub cycles_ticked: u64,
    /// Simulated cycles the clock covered (equals the cycle counts in the
    /// result JSON).  `cycles_simulated > cycles_ticked` means the
    /// event-driven path skipped provably idle cycles; with the flag off
    /// the two are equal.
    pub cycles_simulated: u64,
    /// Clock advances that jumped more than one cycle.
    pub jumps: u64,
    /// Largest single clock advance observed.
    pub max_jump: u64,
}

impl EventStats {
    /// Record one clock advance of `step >= 1` cycles.
    #[inline]
    pub fn record_advance(&mut self, step: u64) {
        self.cycles_ticked += 1;
        self.cycles_simulated += step;
        if step > 1 {
            self.jumps += 1;
            self.max_jump = self.max_jump.max(step);
        }
    }

    /// Cycles the event-driven path never ticked (0 in reference mode).
    pub fn skipped(&self) -> u64 {
        self.cycles_simulated.saturating_sub(self.cycles_ticked)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cycles_ticked", self.cycles_ticked.into()),
            ("cycles_simulated", self.cycles_simulated.into()),
            ("jumps", self.jumps.into()),
            ("max_jump", self.max_jump.into()),
        ])
    }
}

/// Host-performance telemetry of the sharded cycle loop
/// (`engine.shards > 1`).
///
/// Like [`ResidencyStats`] and [`EventStats`], deliberately **not** part
/// of [`SimResult`]/[`MultiResult`] JSON: result JSON must be
/// byte-identical at any shard count (`engine.shards` changes only wall
/// clock), and these counters are zero whenever the unsharded loop runs.
/// `ata-sim run` prints them to stderr, and white-box tests read them,
/// through [`Engine::shard_stats`](crate::engine::Engine::shard_stats).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Effective shard count of the last sharded run (requested shards
    /// clamped to the cluster count); 0 if no sharded loop ever ran.
    pub shard_count: u64,
    /// Synchronization epochs executed (one per engine-loop iteration:
    /// parallel tick → serial memory walk → parallel drain).
    pub epochs: u64,
    /// Memory transactions that crossed a shard boundary at the epoch
    /// barrier: requests leaving a shard's private L1 state for the
    /// shared NoC→L2→DRAM walk (the `MemTxn` serialization cut).
    pub egress_txns: u64,
    /// Completion wake-ups routed back through per-shard ingress FIFOs
    /// and drained in shard-major order at the barrier.
    pub ingress_wakes: u64,
    /// Host nanoseconds the coordinator spent in the parallel tick phase
    /// (phase 1, barrier to barrier) across all epochs.  Together with
    /// `walk_ns` this splits each epoch's wall time into the part
    /// `--shards` parallelizes and the part `--mem-workers` attacks.
    pub tick_ns: u64,
    /// Host nanoseconds the coordinator spent in the memory-walk phase
    /// (phase 2: B1 front end, per-slice walk, B3 finish) across all
    /// epochs — the Amdahl term the slice-parallel walk shrinks.
    pub walk_ns: u64,
}

impl ShardStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard_count", self.shard_count.into()),
            ("epochs", self.epochs.into()),
            ("egress_txns", self.egress_txns.into()),
            ("ingress_wakes", self.ingress_wakes.into()),
            ("tick_ns", self.tick_ns.into()),
            ("walk_ns", self.walk_ns.into()),
        ])
    }
}

/// Tracks the paper's L1 latency metric: for each *load instruction*, the
/// time from issue until **all** of its coalesced requests complete.
#[derive(Debug, Default)]
pub struct LoadLatencyTracker {
    /// (core, warp, inst) → (outstanding, issue_cycle, latest_completion)
    open: FxHashMap<(u32, u32, u64), (u32, u64, u64)>,
    pub completed_loads: u64,
    pub total_latency: u64,
    pub max_latency: u64,
    /// Histogram in power-of-two latency buckets [1,2), [2,4), ...
    pub histogram: [u64; 24],
}

impl LoadLatencyTracker {
    /// Register a load instruction with `n_requests` at `issue_cycle`.
    pub fn issue(&mut self, core: u32, warp: u32, inst: u64, n_requests: u32, issue_cycle: u64) {
        debug_assert!(n_requests > 0);
        self.open
            .insert((core, warp, inst), (n_requests, issue_cycle, issue_cycle));
    }

    /// One request of the load completed at `cycle`.  When this was the
    /// last outstanding request, returns the whole-load completion cycle
    /// (the warp's wake time); otherwise `None`.
    pub fn complete_one(&mut self, core: u32, warp: u32, inst: u64, cycle: u64) -> Option<u64> {
        let key = (core, warp, inst);
        let Some(entry) = self.open.get_mut(&key) else {
            debug_assert!(false, "completion for unknown load {key:?}");
            return None;
        };
        entry.0 -= 1;
        entry.2 = entry.2.max(cycle);
        if entry.0 == 0 {
            let (_, issued, done) = self.open.remove(&key).unwrap();
            let lat = done.saturating_sub(issued).max(1);
            self.completed_loads += 1;
            self.total_latency += lat;
            self.max_latency = self.max_latency.max(lat);
            let bucket = (64 - (lat.max(1)).leading_zeros() as usize - 1).min(23);
            self.histogram[bucket] += 1;
            Some(done)
        } else {
            None
        }
    }

    pub fn mean(&self) -> f64 {
        if self.completed_loads == 0 {
            0.0
        } else {
            self.total_latency as f64 / self.completed_loads as f64
        }
    }

    pub fn outstanding(&self) -> usize {
        self.open.len()
    }
}

/// Aggregate per-hop latency, read off completed [`crate::mem::MemTxn`]
/// transactions (the Fig. 3 decomposition as measured data): how long
/// transactions waited in the tag front-end, how long the L1 stage took,
/// and how long the memory system below L1 served misses — plus the
/// transaction-accumulated queueing breakdown as a cross-check against
/// the per-core [`ContentionStats`] ledger.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HopStats {
    /// Transactions recorded.
    pub txns: u64,
    /// Σ cycles from issue to tag-pipeline resolution.
    pub tag_wait_cycles: u64,
    /// Σ cycles from issue to L1-stage completion (§IV-C).
    pub l1_stage_cycles: u64,
    /// Transactions that dispatched a fetch below L1.
    pub mem_trips: u64,
    /// Σ cycles from L2 dispatch to fill arrival (misses only).
    pub mem_service_cycles: u64,
    /// Σ per-transaction accumulated queueing (subset of the per-core
    /// contention ledger: fire-and-forget writebacks charge the ledger
    /// directly and never ride a transaction).
    pub queued: ContentionBreakdown,
}

impl HopStats {
    /// Fold one finished transaction's hops into the aggregate.
    pub fn record(&mut self, hops: &crate::mem::HopTimes, queued: &ContentionBreakdown) {
        self.txns += 1;
        self.tag_wait_cycles += hops.tag_done.saturating_sub(hops.issue);
        self.l1_stage_cycles += hops.l1_done.saturating_sub(hops.issue);
        if hops.l2_dispatch > 0 {
            self.mem_trips += 1;
            self.mem_service_cycles += hops.mem_done.saturating_sub(hops.l2_dispatch);
        }
        self.queued.merge(queued);
    }

    /// Counters accumulated since `before` (per-run reporting on a warm
    /// engine).  Destructures exhaustively so a new field without a delta
    /// is a compile error.
    pub fn delta(&self, before: &HopStats) -> HopStats {
        let HopStats {
            txns,
            tag_wait_cycles,
            l1_stage_cycles,
            mem_trips,
            mem_service_cycles,
            queued,
        } = *self;
        HopStats {
            txns: txns - before.txns,
            tag_wait_cycles: tag_wait_cycles - before.tag_wait_cycles,
            l1_stage_cycles: l1_stage_cycles - before.l1_stage_cycles,
            mem_trips: mem_trips - before.mem_trips,
            mem_service_cycles: mem_service_cycles - before.mem_service_cycles,
            queued: queued.delta(&before.queued),
        }
    }

    /// Accumulate another run's hop aggregate (per-job merging in
    /// submission order).  Exhaustive destructure like [`Self::delta`].
    pub fn merge(&mut self, other: &HopStats) {
        let HopStats {
            txns,
            tag_wait_cycles,
            l1_stage_cycles,
            mem_trips,
            mem_service_cycles,
            queued,
        } = *other;
        self.txns += txns;
        self.tag_wait_cycles += tag_wait_cycles;
        self.l1_stage_cycles += l1_stage_cycles;
        self.mem_trips += mem_trips;
        self.mem_service_cycles += mem_service_cycles;
        self.queued.merge(&queued);
    }

    pub fn mean_l1_stage(&self) -> f64 {
        if self.txns == 0 {
            0.0
        } else {
            self.l1_stage_cycles as f64 / self.txns as f64
        }
    }

    pub fn mean_mem_service(&self) -> f64 {
        if self.mem_trips == 0 {
            0.0
        } else {
            self.mem_service_cycles as f64 / self.mem_trips as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("txns", self.txns.into()),
            ("tag_wait_cycles", self.tag_wait_cycles.into()),
            ("l1_stage_cycles", self.l1_stage_cycles.into()),
            ("mem_trips", self.mem_trips.into()),
            ("mem_service_cycles", self.mem_service_cycles.into()),
            ("mean_l1_stage", self.mean_l1_stage().into()),
            ("mean_mem_service", self.mean_mem_service().into()),
            ("queued", self.queued.to_json()),
        ])
    }

    /// Inverse of [`to_json`](Self::to_json) (the resume manifest path).
    /// The means are re-derived from the serialized sums.
    pub fn from_json(j: &Json) -> HopStats {
        let n = |k: &str| j.get(k).and_then(Json::as_u64).unwrap_or(0);
        HopStats {
            txns: n("txns"),
            tag_wait_cycles: n("tag_wait_cycles"),
            l1_stage_cycles: n("l1_stage_cycles"),
            mem_trips: n("mem_trips"),
            mem_service_cycles: n("mem_service_cycles"),
            queued: j
                .get("queued")
                .map(ContentionBreakdown::from_json)
                .unwrap_or_default(),
        }
    }
}

/// Per-kernel performance record (Fig 9's unit of comparison).
#[derive(Debug, Clone, Default)]
pub struct KernelStats {
    pub name: String,
    pub cycles: u64,
    pub insts: u64,
    /// Full load latency (includes L2/DRAM service).
    pub l1_mean_latency: f64,
    /// The paper's §IV-C L1 access latency (stage completion).
    pub l1_stage_latency: f64,
    pub l1_hit_rate: f64,
}

impl KernelStats {
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.insts as f64 / self.cycles as f64
        }
    }

    /// Inverse of the inline kernel objects in [`SimResult::to_json`] /
    /// [`AppCoStats::to_json`] (the latter omits `l1_hit_rate`, which
    /// then reads as 0 — exactly what that surface serialized).
    pub fn from_json(j: &Json) -> KernelStats {
        let f = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        KernelStats {
            name: j.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
            cycles: j.get("cycles").and_then(Json::as_u64).unwrap_or(0),
            insts: j.get("insts").and_then(Json::as_u64).unwrap_or(0),
            l1_mean_latency: f("l1_mean_latency"),
            l1_stage_latency: f("l1_stage_latency"),
            l1_hit_rate: f("l1_hit_rate"),
        }
    }
}

/// Whole-simulation result bundle.
#[derive(Debug, Clone, Default)]
pub struct SimResult {
    pub app: String,
    pub arch: String,
    pub cycles: u64,
    pub insts: u64,
    pub l1: L1Stats,
    /// Completed load instructions (denominator of the mean latencies).
    pub loads: u64,
    pub l1_mean_load_latency: f64,
    pub l1_max_load_latency: u64,
    /// The paper's §IV-C metric: completion of the L1 access stage.
    pub l1_stage_mean_latency: f64,
    pub l1_stage_max_latency: u64,
    pub l2_hit_rate: f64,
    pub l2_mean_fetch_latency: f64,
    pub noc_flits: u64,
    pub dram_reads: u64,
    pub dram_writes: u64,
    /// Per-resource stall breakdown accumulated over the run (Fig. 3 /
    /// Fig. 11 style contention decomposition).
    pub contention: ContentionBreakdown,
    /// Per-hop latency decomposition read off the run's transactions.
    pub hops: HopStats,
    pub kernels: Vec<KernelStats>,
    /// Wall-clock seconds the simulation took.  A host-performance
    /// metric, deliberately **excluded** from [`SimResult::to_json`]:
    /// result JSON is part of the execution layer's determinism contract
    /// (byte-identical for any `--threads` value), and wall clock is
    /// not.  `ata-sim bench` reports it explicitly.
    pub host_seconds: f64,
}

impl SimResult {
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.insts as f64 / self.cycles as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("app", self.app.as_str().into()),
            ("arch", self.arch.as_str().into()),
            ("cycles", self.cycles.into()),
            ("insts", self.insts.into()),
            ("ipc", self.ipc().into()),
            ("l1", self.l1.to_json()),
            ("loads", self.loads.into()),
            ("l1_mean_load_latency", self.l1_mean_load_latency.into()),
            ("l1_max_load_latency", self.l1_max_load_latency.into()),
            ("l1_stage_mean_latency", self.l1_stage_mean_latency.into()),
            ("l1_stage_max_latency", self.l1_stage_max_latency.into()),
            ("l2_hit_rate", self.l2_hit_rate.into()),
            ("l2_mean_fetch_latency", self.l2_mean_fetch_latency.into()),
            ("noc_flits", self.noc_flits.into()),
            ("dram_reads", self.dram_reads.into()),
            ("dram_writes", self.dram_writes.into()),
            ("contention", self.contention.to_json()),
            ("hops", self.hops.to_json()),
            (
                "kernels",
                Json::arr(
                    self.kernels
                        .iter()
                        .map(|k| {
                            Json::obj(vec![
                                ("name", k.name.as_str().into()),
                                ("cycles", k.cycles.into()),
                                ("insts", k.insts.into()),
                                ("ipc", k.ipc().into()),
                                ("l1_mean_latency", k.l1_mean_latency.into()),
                                ("l1_stage_latency", k.l1_stage_latency.into()),
                                ("l1_hit_rate", k.l1_hit_rate.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Inverse of [`to_json`](Self::to_json) — what `--resume` uses to
    /// reconstruct a completed job from its manifest line.  Derived
    /// fields (`ipc`, per-kernel `ipc`) are re-derived from the restored
    /// counters, and `host_seconds` — excluded from the JSON by the
    /// determinism contract — reads as 0.0, so a reconstructed result
    /// re-serializes byte-identically to the fresh one.
    pub fn from_json(j: &Json) -> SimResult {
        let n = |k: &str| j.get(k).and_then(Json::as_u64).unwrap_or(0);
        let f = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let s = |k: &str| j.get(k).and_then(Json::as_str).unwrap_or_default().to_string();
        SimResult {
            app: s("app"),
            arch: s("arch"),
            cycles: n("cycles"),
            insts: n("insts"),
            l1: j.get("l1").map(L1Stats::from_json).unwrap_or_default(),
            loads: n("loads"),
            l1_mean_load_latency: f("l1_mean_load_latency"),
            l1_max_load_latency: n("l1_max_load_latency"),
            l1_stage_mean_latency: f("l1_stage_mean_latency"),
            l1_stage_max_latency: n("l1_stage_max_latency"),
            l2_hit_rate: f("l2_hit_rate"),
            l2_mean_fetch_latency: f("l2_mean_fetch_latency"),
            noc_flits: n("noc_flits"),
            dram_reads: n("dram_reads"),
            dram_writes: n("dram_writes"),
            contention: j
                .get("contention")
                .map(ContentionBreakdown::from_json)
                .unwrap_or_default(),
            hops: j.get("hops").map(HopStats::from_json).unwrap_or_default(),
            kernels: j
                .get("kernels")
                .and_then(Json::as_arr)
                .map(|ks| ks.iter().map(KernelStats::from_json).collect())
                .unwrap_or_default(),
            host_seconds: 0.0,
        }
    }
}

/// Per-application slice of a co-execution run (see
/// [`crate::engine::Engine::run_multi`]): instruction/cycle/latency
/// attribution for the cores one application owns.
///
/// Invariants (checked by the co-execution integration tests):
/// Σ `insts` over apps equals the global instruction count,
/// Σ `requests` equals the shared L1's access count, and
/// max `finish_cycle` equals the global cycle count.
#[derive(Debug, Clone, Default)]
pub struct AppCoStats {
    pub name: String,
    /// First global core id of the app's partition.
    pub first_core: usize,
    /// Number of cores the app ran on.
    pub cores: usize,
    /// Cycle at which the app's last kernel completed (relative to the
    /// co-execution start).
    pub finish_cycle: u64,
    pub insts: u64,
    /// Completed load instructions issued by this app's cores.
    pub loads: u64,
    /// Mean full load latency (issue → data at core) for this app.
    pub mean_load_latency: f64,
    /// Mean L1-stage latency (§IV-C metric) for this app.
    pub stage_mean_latency: f64,
    /// Memory requests this app's cores fed into the shared L1.
    pub requests: u64,
    /// Per-resource stall breakdown over this app's cores: the queueing
    /// this app suffered on each shared resource during the co-run.
    /// Compared against the app's solo baseline this shows *which*
    /// resource a co-runner steals (see
    /// [`crate::coordinator::CoSchedResults::stolen_breakdown`]).
    pub contention: ContentionBreakdown,
    /// Per-kernel breakdown.  L1 hit rates are not attributable per app
    /// (the L1 organization's counters are shared), so
    /// [`KernelStats::l1_hit_rate`] is reported as 0 here.
    pub kernels: Vec<KernelStats>,
}

impl AppCoStats {
    /// IPC over the app's own residency window (its cores were idle after
    /// `finish_cycle`, so the window is the fair denominator).
    pub fn ipc(&self) -> f64 {
        if self.finish_cycle == 0 {
            0.0
        } else {
            self.insts as f64 / self.finish_cycle as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("first_core", self.first_core.into()),
            ("cores", self.cores.into()),
            ("finish_cycle", self.finish_cycle.into()),
            ("insts", self.insts.into()),
            ("ipc", self.ipc().into()),
            ("loads", self.loads.into()),
            ("mean_load_latency", self.mean_load_latency.into()),
            ("stage_mean_latency", self.stage_mean_latency.into()),
            ("requests", self.requests.into()),
            ("contention", self.contention.to_json()),
            (
                "kernels",
                Json::arr(
                    self.kernels
                        .iter()
                        .map(|k| {
                            Json::obj(vec![
                                ("name", k.name.as_str().into()),
                                ("cycles", k.cycles.into()),
                                ("insts", k.insts.into()),
                                ("ipc", k.ipc().into()),
                                ("l1_mean_latency", k.l1_mean_latency.into()),
                                ("l1_stage_latency", k.l1_stage_latency.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Inverse of [`to_json`](Self::to_json) (the resume manifest path);
    /// `ipc` is re-derived from `insts`/`finish_cycle`.
    pub fn from_json(j: &Json) -> AppCoStats {
        let n = |k: &str| j.get(k).and_then(Json::as_u64).unwrap_or(0);
        let f = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        AppCoStats {
            name: j.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
            first_core: j.get("first_core").and_then(Json::as_usize).unwrap_or(0),
            cores: j.get("cores").and_then(Json::as_usize).unwrap_or(0),
            finish_cycle: n("finish_cycle"),
            insts: n("insts"),
            loads: n("loads"),
            mean_load_latency: f("mean_load_latency"),
            stage_mean_latency: f("stage_mean_latency"),
            requests: n("requests"),
            contention: j
                .get("contention")
                .map(ContentionBreakdown::from_json)
                .unwrap_or_default(),
            kernels: j
                .get("kernels")
                .and_then(Json::as_arr)
                .map(|ks| ks.iter().map(KernelStats::from_json).collect())
                .unwrap_or_default(),
        }
    }
}

/// Whole co-execution result bundle: global counters over the shared
/// memory system plus per-application attribution.
#[derive(Debug, Clone, Default)]
pub struct MultiResult {
    /// Workload name (usually `"appA+appB"`).
    pub name: String,
    pub arch: String,
    /// Cycle at which the *last* application finished.
    pub cycles: u64,
    pub insts: u64,
    /// Shared-L1 counters accumulated over all applications.
    pub l1: L1Stats,
    pub l2_hit_rate: f64,
    pub l2_mean_fetch_latency: f64,
    pub noc_flits: u64,
    pub dram_reads: u64,
    pub dram_writes: u64,
    /// Per-resource stall breakdown over the whole co-run (Σ of the
    /// per-app breakdowns plus any stalls on idle-core resources).
    pub contention: ContentionBreakdown,
    /// Per-hop latency decomposition over the whole co-run's transactions.
    pub hops: HopStats,
    pub apps: Vec<AppCoStats>,
    /// Wall-clock seconds the simulation took.  Excluded from
    /// [`MultiResult::to_json`] for the same reason as
    /// [`SimResult::host_seconds`]: result JSON must be byte-identical
    /// across `--threads` values.
    pub host_seconds: f64,
}

impl MultiResult {
    /// Aggregate IPC over the whole co-execution window.
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.insts as f64 / self.cycles as f64
        }
    }

    /// First app slice with the given name (lanes keep registry names;
    /// look up by index for self-pairs).
    pub fn app(&self, name: &str) -> Option<&AppCoStats> {
        self.apps.iter().find(|a| a.name == name)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("arch", self.arch.as_str().into()),
            ("cycles", self.cycles.into()),
            ("insts", self.insts.into()),
            ("ipc", self.ipc().into()),
            ("l1", self.l1.to_json()),
            ("l2_hit_rate", self.l2_hit_rate.into()),
            ("l2_mean_fetch_latency", self.l2_mean_fetch_latency.into()),
            ("noc_flits", self.noc_flits.into()),
            ("dram_reads", self.dram_reads.into()),
            ("dram_writes", self.dram_writes.into()),
            ("contention", self.contention.to_json()),
            ("hops", self.hops.to_json()),
            ("apps", Json::arr(self.apps.iter().map(AppCoStats::to_json).collect())),
        ])
    }

    /// Inverse of [`to_json`](Self::to_json) — see
    /// [`SimResult::from_json`] for the roundtrip contract
    /// (`host_seconds` reads as 0.0, derived fields are re-derived).
    pub fn from_json(j: &Json) -> MultiResult {
        let n = |k: &str| j.get(k).and_then(Json::as_u64).unwrap_or(0);
        let f = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let s = |k: &str| j.get(k).and_then(Json::as_str).unwrap_or_default().to_string();
        MultiResult {
            name: s("name"),
            arch: s("arch"),
            cycles: n("cycles"),
            insts: n("insts"),
            l1: j.get("l1").map(L1Stats::from_json).unwrap_or_default(),
            l2_hit_rate: f("l2_hit_rate"),
            l2_mean_fetch_latency: f("l2_mean_fetch_latency"),
            noc_flits: n("noc_flits"),
            dram_reads: n("dram_reads"),
            dram_writes: n("dram_writes"),
            contention: j
                .get("contention")
                .map(ContentionBreakdown::from_json)
                .unwrap_or_default(),
            hops: j.get("hops").map(HopStats::from_json).unwrap_or_default(),
            apps: j
                .get("apps")
                .and_then(Json::as_arr)
                .map(|a| a.iter().map(AppCoStats::from_json).collect())
                .unwrap_or_default(),
            host_seconds: 0.0,
        }
    }
}

/// Order-preserving aggregate over per-job results.
///
/// The execution layer ([`crate::exec`]) returns job results in
/// submission order; merging them must keep that contract — totals
/// accumulate in the order given, and nothing is sorted, re-weighted, or
/// deduplicated on the way through.  Used by `ata-sim bench` and the
/// figure drivers to report grid-level throughput.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RunTotals {
    /// Results absorbed.
    pub runs: u64,
    /// Σ simulated cycles.
    pub cycles: u64,
    /// Σ instructions.
    pub insts: u64,
    /// Σ host wall-clock seconds (the *sum* of per-job timings — under a
    /// parallel runner this exceeds elapsed wall time by the achieved
    /// speedup).
    pub host_seconds: f64,
}

impl RunTotals {
    pub fn absorb_sim(&mut self, r: &SimResult) {
        self.runs += 1;
        self.cycles += r.cycles;
        self.insts += r.insts;
        self.host_seconds += r.host_seconds;
    }

    pub fn absorb_multi(&mut self, r: &MultiResult) {
        self.runs += 1;
        self.cycles += r.cycles;
        self.insts += r.insts;
        self.host_seconds += r.host_seconds;
    }

    /// Aggregate IPC over the absorbed runs.
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.insts as f64 / self.cycles as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("runs", self.runs.into()),
            ("cycles", self.cycles.into()),
            ("insts", self.insts.into()),
            ("ipc", self.ipc().into()),
            ("host_seconds", self.host_seconds.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_combines_local_and_remote() {
        let s = L1Stats {
            accesses: 10,
            local_hits: 5,
            remote_hits: 2,
            ..Default::default()
        };
        assert!((s.hit_rate() - 0.7).abs() < 1e-12);
        assert!((s.local_hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(L1Stats::default().hit_rate(), 0.0);
    }

    #[test]
    fn load_tracker_waits_for_all_requests() {
        let mut t = LoadLatencyTracker::default();
        t.issue(0, 1, 7, 3, 100);
        assert_eq!(t.complete_one(0, 1, 7, 120), None);
        assert_eq!(t.complete_one(0, 1, 7, 180), None);
        assert_eq!(
            t.complete_one(0, 1, 7, 150),
            Some(180),
            "last completion finishes the load at the max cycle"
        );
        assert_eq!(t.completed_loads, 1);
        // Latency = max completion (180) - issue (100)
        assert_eq!(t.total_latency, 80);
        assert_eq!(t.outstanding(), 0);
    }

    #[test]
    fn load_tracker_mean_and_histogram() {
        let mut t = LoadLatencyTracker::default();
        t.issue(0, 0, 1, 1, 0);
        t.complete_one(0, 0, 1, 32);
        t.issue(0, 0, 2, 1, 0);
        t.complete_one(0, 0, 2, 96);
        assert_eq!(t.mean(), 64.0);
        assert_eq!(t.max_latency, 96);
        assert_eq!(t.histogram[5], 1, "32 in [32,64)");
        assert_eq!(t.histogram[6], 1, "96 in [64,128)");
    }

    #[test]
    fn kernel_ipc() {
        let k = KernelStats {
            name: "k0".into(),
            cycles: 1000,
            insts: 750,
            ..Default::default()
        };
        assert!((k.ipc() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn sim_result_json_is_parseable() {
        let r = SimResult {
            app: "b+tree".into(),
            arch: "ata".into(),
            cycles: 100,
            insts: 80,
            ..Default::default()
        };
        let j = r.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("app").unwrap().as_str(), Some("b+tree"));
        assert!((parsed.get("ipc").unwrap().as_f64().unwrap() - 0.8).abs() < 1e-9);
    }

    #[test]
    fn contention_breakdown_accumulates_and_deltas() {
        let mut b = ContentionBreakdown::default();
        b.add(ResourceClass::L1DataBank, 10);
        b.add(ResourceClass::Dram, 5);
        b.add(ResourceClass::Dram, 2);
        assert_eq!(b.get(ResourceClass::Dram), 7);
        assert_eq!(b.total(), 17);
        assert_eq!(b.remote_path(), 0);
        b.add(ResourceClass::ClusterXbar, 3);
        assert_eq!(b.remote_path(), 3);

        let before = {
            let mut x = ContentionBreakdown::default();
            x.add(ResourceClass::Dram, 4);
            x
        };
        let d = b.delta(&before);
        assert_eq!(d.get(ResourceClass::Dram), 3);
        assert_eq!(d.get(ResourceClass::L1DataBank), 10);
        assert_eq!(d.total(), b.total() - 4);

        let j = Json::parse(&b.to_json().to_string()).unwrap();
        assert_eq!(j.get("dram").unwrap().as_u64(), Some(7));
        assert_eq!(j.get("total").unwrap().as_u64(), Some(20));
    }

    #[test]
    fn contention_stats_attributes_per_core_and_lanes() {
        let mut c = ContentionStats::new(4);
        c.add(0, ResourceClass::NocLink, 5);
        c.add(1, ResourceClass::NocLink, 7);
        c.add(3, ResourceClass::MshrFull, 2);
        c.add(2, ResourceClass::Dram, 0); // zero adds are free no-ops
        assert_eq!(c.total().total(), 14);
        assert_eq!(c.per_core()[1].get(ResourceClass::NocLink), 7);
        assert_eq!(c.per_core()[2].total(), 0);
        // Lane rollup: cores [0, 2) vs [2, 4).
        assert_eq!(c.lane_total(0, 2).get(ResourceClass::NocLink), 12);
        assert_eq!(c.lane_total(2, 2).get(ResourceClass::MshrFull), 2);
        // Per-core sums reconcile with the aggregate.
        let mut sum = ContentionBreakdown::default();
        for b in c.per_core() {
            sum.merge(b);
        }
        assert_eq!(sum, *c.total());

        // absorb + delta round-trip.
        let snapshot = c.clone();
        let mut more = ContentionStats::new(4);
        more.add(0, ResourceClass::Dram, 9);
        c.absorb(&more);
        let d = c.delta(&snapshot);
        assert_eq!(d.total().total(), 9);
        assert_eq!(d.per_core()[0].get(ResourceClass::Dram), 9);
    }

    #[test]
    fn hop_stats_record_and_delta() {
        use crate::mem::HopTimes;
        let mut h = HopStats::default();
        let mut q = ContentionBreakdown::default();
        q.add(ResourceClass::Dram, 4);
        // A miss: issue 10, tags at 12, stage at 45, dispatched 14,
        // fill back at 300, done 301.
        h.record(
            &HopTimes {
                issue: 10,
                tag_done: 12,
                l1_done: 45,
                l2_dispatch: 14,
                mem_done: 300,
                done: 301,
            },
            &q,
        );
        // A hit: no memory trip.
        h.record(
            &HopTimes {
                issue: 20,
                tag_done: 20,
                l1_done: 55,
                l2_dispatch: 0,
                mem_done: 0,
                done: 55,
            },
            &ContentionBreakdown::default(),
        );
        assert_eq!(h.txns, 2);
        assert_eq!(h.tag_wait_cycles, 2);
        assert_eq!(h.l1_stage_cycles, 35 + 35);
        assert_eq!(h.mem_trips, 1);
        assert_eq!(h.mem_service_cycles, 286);
        assert_eq!(h.queued.get(ResourceClass::Dram), 4);
        assert_eq!(h.mean_l1_stage(), 35.0);
        assert_eq!(h.mean_mem_service(), 286.0);

        let before = HopStats {
            txns: 1,
            tag_wait_cycles: 2,
            l1_stage_cycles: 35,
            mem_trips: 1,
            mem_service_cycles: 286,
            queued: q,
        };
        let d = h.delta(&before);
        assert_eq!(d.txns, 1);
        assert_eq!(d.mem_trips, 0);
        assert_eq!(d.queued.total(), 0);
        let j = Json::parse(&h.to_json().to_string()).unwrap();
        assert_eq!(j.get("txns").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("mem_trips").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn l1_stats_merge_accumulates_every_counter() {
        let a = L1Stats {
            accesses: 10,
            local_hits: 4,
            misses: 6,
            fills: 6,
            ..Default::default()
        };
        let b = L1Stats {
            accesses: 3,
            local_hits: 3,
            bypasses: 1,
            ..Default::default()
        };
        let mut m = a;
        m.merge(&b);
        assert_eq!(m.accesses, 13);
        assert_eq!(m.local_hits, 7);
        assert_eq!(m.misses, 6);
        assert_eq!(m.bypasses, 1);
        // merge is delta's inverse: (a + b) - b == a.
        assert_eq!(m.delta(&b).accesses, a.accesses);
    }

    #[test]
    fn run_totals_absorb_in_order_without_reordering() {
        let mk = |cycles, insts, host| SimResult {
            cycles,
            insts,
            host_seconds: host,
            ..Default::default()
        };
        let results = [mk(100, 50, 0.5), mk(300, 300, 1.5)];
        let mut t = RunTotals::default();
        for r in &results {
            t.absorb_sim(r);
        }
        assert_eq!(t.runs, 2);
        assert_eq!(t.cycles, 400);
        assert_eq!(t.insts, 350);
        assert!((t.host_seconds - 2.0).abs() < 1e-12);
        assert!((t.ipc() - 0.875).abs() < 1e-12);
        // Absorption order must not matter for the totals (merging never
        // re-weights), and the multi path agrees with the sim path.
        let mut rev = RunTotals::default();
        for r in results.iter().rev() {
            rev.absorb_sim(r);
        }
        assert_eq!(t, rev);
        let mut multi = RunTotals::default();
        multi.absorb_multi(&MultiResult {
            cycles: 400,
            insts: 350,
            host_seconds: 2.0,
            ..Default::default()
        });
        assert_eq!(multi.cycles, t.cycles);
        let j = Json::parse(&t.to_json().to_string()).unwrap();
        assert_eq!(j.get("runs").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn result_json_has_no_wall_clock_fields() {
        // Result JSON is part of the determinism contract (byte-identical
        // across --threads values); host wall time must not leak into it.
        let r = SimResult {
            host_seconds: 1.23,
            ..Default::default()
        };
        assert!(Json::parse(&r.to_json().to_string())
            .unwrap()
            .get("host_seconds")
            .is_none());
        let m = MultiResult {
            host_seconds: 1.23,
            ..Default::default()
        };
        assert!(Json::parse(&m.to_json().to_string())
            .unwrap()
            .get("host_seconds")
            .is_none());
    }

    #[test]
    fn residency_stats_serialize_but_stay_out_of_results() {
        let s = ResidencyStats {
            index_probes: 10,
            scan_probes: 0,
            index_ops: 7,
            index_lines: 3,
            peak_lines: 5,
        };
        let j = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(j.get("index_probes").unwrap().as_u64(), Some(10));
        assert_eq!(j.get("peak_lines").unwrap().as_u64(), Some(5));
        // The determinism contract: result JSON must not carry index
        // telemetry (it differs between index-on and index-off runs).
        let r = SimResult::default().to_json().to_string();
        assert!(!r.contains("index_probes") && !r.contains("residency"));
    }

    #[test]
    fn event_stats_serialize_but_stay_out_of_results() {
        let mut s = EventStats::default();
        s.record_advance(1);
        s.record_advance(40);
        s.record_advance(7);
        assert_eq!(s.cycles_ticked, 3);
        assert_eq!(s.cycles_simulated, 48);
        assert_eq!(s.jumps, 2);
        assert_eq!(s.max_jump, 40);
        assert_eq!(s.skipped(), 45);
        let j = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(j.get("cycles_ticked").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("max_jump").unwrap().as_u64(), Some(40));
        // The determinism contract: result JSON must not carry engine-clock
        // telemetry (it differs between event-driven and reference runs).
        let r = SimResult::default().to_json().to_string();
        assert!(!r.contains("cycles_ticked") && !r.contains("max_jump"));
        let m = MultiResult::default().to_json().to_string();
        assert!(!m.contains("cycles_ticked") && !m.contains("max_jump"));
    }

    #[test]
    fn shard_stats_serialize_but_stay_out_of_results() {
        let s = ShardStats {
            shard_count: 3,
            epochs: 1000,
            egress_txns: 42,
            ingress_wakes: 17,
            tick_ns: 5_000,
            walk_ns: 12_000,
        };
        let j = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(j.get("shard_count").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("ingress_wakes").unwrap().as_u64(), Some(17));
        assert_eq!(j.get("tick_ns").unwrap().as_u64(), Some(5_000));
        assert_eq!(j.get("walk_ns").unwrap().as_u64(), Some(12_000));
        // The determinism contract: result JSON must not carry shard
        // telemetry (it is zero for unsharded runs and nonzero otherwise).
        let r = SimResult::default().to_json().to_string();
        assert!(!r.contains("shard_count") && !r.contains("ingress_wakes"));
        let m = MultiResult::default().to_json().to_string();
        assert!(!m.contains("shard_count") && !m.contains("egress_txns"));
    }

    #[test]
    fn resource_class_names_are_unique() {
        let mut names: Vec<&str> = ResourceClass::ALL.iter().map(|c| c.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), ResourceClass::COUNT);
    }

    #[test]
    fn multi_result_json_and_per_app_ipc() {
        let r = MultiResult {
            name: "a+b".into(),
            arch: "ata".into(),
            cycles: 200,
            insts: 300,
            apps: vec![
                AppCoStats {
                    name: "a".into(),
                    finish_cycle: 100,
                    insts: 150,
                    ..Default::default()
                },
                AppCoStats {
                    name: "b".into(),
                    finish_cycle: 200,
                    insts: 150,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        assert!((r.ipc() - 1.5).abs() < 1e-12);
        assert!((r.app("a").unwrap().ipc() - 1.5).abs() < 1e-12);
        assert!((r.app("b").unwrap().ipc() - 0.75).abs() < 1e-12);
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str(), Some("a+b"));
        assert_eq!(
            parsed.get("apps").unwrap().as_arr().unwrap().len(),
            2,
            "both app slices serialized"
        );
    }
}
