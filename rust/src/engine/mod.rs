//! The cycle engine: wires SIMT cores, an L1 organization, and the memory
//! system together and runs multi-kernel workloads to completion.
//!
//! Cores are ticked cycle-by-cycle; memory timing is resolved through the
//! reservation model, so warp wake-ups arrive through a calendar heap and
//! idle stretches (every warp blocked on memory) are fast-forwarded —
//! the common case for memory-bound GPU workloads.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

use crate::config::GpuConfig;
use crate::core::{IssueBatch, SimtCore, WarpProgram};
use crate::l1arch::{self, L1Arch};
use crate::l2::MemSystem;
use crate::stats::{KernelStats, LoadLatencyTracker, SimResult};

/// One kernel launch: a set of warp programs per core.
#[derive(Debug, Clone, Default)]
pub struct KernelSpec {
    pub name: String,
    /// `programs[core]` = warp programs for that core.
    pub programs: Vec<Vec<WarpProgram>>,
}

/// A whole application: an ordered list of kernels (Fig 9's unit
/// structure).
#[derive(Debug, Clone, Default)]
pub struct Workload {
    pub name: String,
    pub kernels: Vec<KernelSpec>,
}

impl Workload {
    pub fn total_requests(&self) -> u64 {
        self.kernels
            .iter()
            .flat_map(|k| k.programs.iter().flatten())
            .map(WarpProgram::request_count)
            .sum()
    }
}

/// Safety valve: a kernel that exceeds this many cycles aborts the run
/// (deadlock guard for tests; real runs never get close).
const MAX_KERNEL_CYCLES: u64 = 500_000_000;

pub struct Engine {
    cfg: GpuConfig,
    l1: Box<dyn L1Arch>,
    mem: MemSystem,
    /// Full load latency (issue → data at core, including L2/DRAM).
    tracker: LoadLatencyTracker,
    /// The paper's §IV-C metric: issue → L1-stage completion.
    stage_tracker: LoadLatencyTracker,
    cycle: u64,
    /// (wake_cycle, core, warp) calendar.
    wakes: BinaryHeap<Reverse<(u64, u32, u32)>>,
    total_insts: u64,
}

impl Engine {
    pub fn new(cfg: &GpuConfig) -> Self {
        cfg.validate().expect("invalid GPU config");
        Engine {
            cfg: cfg.clone(),
            l1: l1arch::build(cfg),
            mem: MemSystem::new(cfg),
            tracker: LoadLatencyTracker::default(),
            stage_tracker: LoadLatencyTracker::default(),
            cycle: 0,
            wakes: BinaryHeap::new(),
            total_insts: 0,
        }
    }

    /// Run a full workload; caches stay warm across kernels.
    pub fn run(&mut self, workload: &Workload) -> SimResult {
        let host_start = Instant::now();
        let mut kernels = Vec::with_capacity(workload.kernels.len());
        for k in &workload.kernels {
            kernels.push(self.run_kernel(k));
        }
        let l1 = *self.l1.stats();
        SimResult {
            app: workload.name.clone(),
            arch: self.l1.kind().name().to_string(),
            cycles: self.cycle,
            insts: self.total_insts,
            l1,
            l1_mean_load_latency: self.tracker.mean(),
            l1_max_load_latency: self.tracker.max_latency,
            l1_stage_mean_latency: self.stage_tracker.mean(),
            l1_stage_max_latency: self.stage_tracker.max_latency,
            l2_hit_rate: self.mem.l2_hit_rate(),
            l2_mean_fetch_latency: self.mem.mean_fetch_latency(),
            noc_flits: self.mem.noc_flits(),
            dram_reads: self.mem.dram_stats().reads,
            dram_writes: self.mem.dram_stats().writes,
            kernels,
            host_seconds: host_start.elapsed().as_secs_f64(),
        }
    }

    /// Replication audit: per-core resident lines (used by integration
    /// tests and the locality cross-check example).
    pub fn resident_lines(&self, core: usize) -> Vec<crate::mem::LineAddr> {
        self.l1.resident_lines(core)
    }

    pub fn l1_stats(&self) -> crate::stats::L1Stats {
        *self.l1.stats()
    }

    fn run_kernel(&mut self, spec: &KernelSpec) -> KernelStats {
        assert_eq!(
            spec.programs.len(),
            self.cfg.cores,
            "kernel '{}' must provide programs for every core",
            spec.name
        );
        let start_cycle = self.cycle;
        let start_insts = self.total_insts;
        let start_loads = self.tracker.completed_loads;
        let start_lat = self.tracker.total_latency;
        let start_stage_loads = self.stage_tracker.completed_loads;
        let start_stage_lat = self.stage_tracker.total_latency;
        let l1_before = *self.l1.stats();

        let mut cores: Vec<SimtCore> = spec
            .programs
            .iter()
            .enumerate()
            .map(|(c, progs)| SimtCore::new(c as u32, &self.cfg, progs.clone()))
            .collect();
        // Leftover wakes from a previous kernel cannot exist: kernels run
        // to completion.
        debug_assert!(self.wakes.is_empty());

        let mut batch = IssueBatch::default();
        let mut last_sweep = self.cycle;
        loop {
            let now = self.cycle;

            // 1. Deliver due wake-ups.
            while let Some(&Reverse((t, core, warp))) = self.wakes.peek() {
                if t > now {
                    break;
                }
                self.wakes.pop();
                cores[core as usize].load_complete(warp, t);
            }

            // 2. Tick every core; collect issued requests.
            batch.requests.clear();
            batch.insts_issued = 0;
            for core in cores.iter_mut() {
                core.tick(now, &mut batch);
            }
            self.total_insts += batch.insts_issued;

            // 3. Feed requests through the L1 organization.
            let mut prev_group: Option<(u32, u32, u64)> = None;
            for (req, group_n) in batch.requests.iter() {
                if *group_n > 0 {
                    // A load: register its instruction group on first sight.
                    let key = (req.core, req.warp, req.inst);
                    if prev_group != Some(key) {
                        self.tracker.issue(req.core, req.warp, req.inst, *group_n, now);
                        self.stage_tracker.issue(req.core, req.warp, req.inst, *group_n, now);
                        prev_group = Some(key);
                    }
                }
                let res = self.l1.access(req, now, &mut self.mem);
                if *group_n > 0 {
                    self.stage_tracker
                        .complete_one(req.core, req.warp, req.inst, res.l1_stage_done);
                    if let Some(load_done) =
                        self.tracker.complete_one(req.core, req.warp, req.inst, res.done)
                    {
                        self.wakes.push(Reverse((load_done.max(now + 1), req.core, req.warp)));
                    }
                }
            }

            // 4. Termination / advance.
            if cores.iter().all(SimtCore::all_done) {
                break;
            }
            // Fast-forward across globally idle stretches (post-tick
            // hints are O(1) per core).
            let next_ready = cores
                .iter()
                .map(SimtCore::next_event_hint)
                .min()
                .unwrap_or(u64::MAX);
            let next_wake = self.wakes.peek().map(|Reverse((t, _, _))| *t).unwrap_or(u64::MAX);
            let next = next_ready.min(next_wake).max(now + 1);
            if next == u64::MAX {
                panic!(
                    "kernel '{}' deadlocked at cycle {now}: no ready warps, no wakes",
                    spec.name
                );
            }
            self.cycle = next;

            if self.cycle - last_sweep > 65_536 {
                self.l1.sweep(self.cycle);
                self.mem.sweep_in_flight(self.cycle);
                last_sweep = self.cycle;
            }
            if self.cycle - start_cycle > MAX_KERNEL_CYCLES {
                panic!("kernel '{}' exceeded {MAX_KERNEL_CYCLES} cycles", spec.name);
            }
        }

        // Count stall statistics into the result via core drop.
        let l1_after = *self.l1.stats();
        let loads = self.tracker.completed_loads - start_loads;
        let lat = self.tracker.total_latency - start_lat;
        let stage_loads = self.stage_tracker.completed_loads - start_stage_loads;
        let stage_lat = self.stage_tracker.total_latency - start_stage_lat;
        let acc = l1_after.accesses - l1_before.accesses;
        let hits = (l1_after.local_hits + l1_after.remote_hits)
            - (l1_before.local_hits + l1_before.remote_hits);
        KernelStats {
            name: spec.name.clone(),
            cycles: self.cycle - start_cycle,
            insts: self.total_insts - start_insts,
            l1_mean_latency: if loads == 0 { 0.0 } else { lat as f64 / loads as f64 },
            l1_stage_latency: if stage_loads == 0 {
                0.0
            } else {
                stage_lat as f64 / stage_loads as f64
            },
            l1_hit_rate: if acc == 0 { 0.0 } else { hits as f64 / acc as f64 },
        }
    }
}

/// Convenience: run `workload` under `arch` on the paper GPU config.
pub fn run_workload(cfg: &GpuConfig, workload: &Workload) -> SimResult {
    Engine::new(cfg).run(workload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::L1ArchKind;
    use crate::core::WarpInst;

    /// A kernel where every core's single warp loads `lines` then does ALU.
    fn simple_kernel(cfg: &GpuConfig, lines_per_core: impl Fn(usize) -> Vec<u64>) -> KernelSpec {
        KernelSpec {
            name: "k".into(),
            programs: (0..cfg.cores)
                .map(|c| {
                    let lines = lines_per_core(c);
                    let insts: Vec<WarpInst> = lines
                        .chunks(2)
                        .map(|ch| WarpInst::Load(ch.iter().map(|&l| (l, 0b1111)).collect()))
                        .chain(std::iter::once(WarpInst::Alu(8)))
                        .collect();
                    vec![WarpProgram::new(insts)]
                })
                .collect(),
        }
    }

    #[test]
    fn runs_to_completion_and_counts() {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let wl = Workload {
            name: "t".into(),
            kernels: vec![simple_kernel(&cfg, |c| vec![c as u64 * 100, c as u64 * 100 + 1])],
        };
        let r = run_workload(&cfg, &wl);
        assert!(r.cycles > 0);
        // 1 load inst + 8 ALU per core.
        assert_eq!(r.insts, cfg.cores as u64 * 9);
        assert_eq!(r.l1.accesses, cfg.cores as u64 * 2);
        assert!(r.ipc() > 0.0);
        assert_eq!(r.kernels.len(), 1);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let wl = Workload {
            name: "t".into(),
            kernels: vec![
                simple_kernel(&cfg, |c| (0..8).map(|k| (c as u64 * 31 + k) % 64).collect()),
                simple_kernel(&cfg, |c| (0..8).map(|k| (c as u64 * 17 + k) % 64).collect()),
            ],
        };
        let a = run_workload(&cfg, &wl);
        let b = run_workload(&cfg, &wl);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.insts, b.insts);
        assert_eq!(a.l1.local_hits, b.l1.local_hits);
        assert_eq!(a.l1_mean_load_latency, b.l1_mean_load_latency);
    }

    #[test]
    fn shared_lines_become_remote_hits_on_ata() {
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        // Every core loads the same two lines; cluster mates should hit
        // remotely (or locally after fills).
        let wl = Workload {
            name: "t".into(),
            kernels: vec![simple_kernel(&cfg, |_| vec![7, 8])],
        };
        let r = run_workload(&cfg, &wl);
        assert!(
            r.l1.remote_hits + r.l1.mshr_merges > 0,
            "sharing must be exploited: {:?}",
            r.l1
        );
        // Far fewer L2 trips than the private equivalent.
        let cfg_p = GpuConfig::tiny(L1ArchKind::Private);
        let r_p = run_workload(&cfg_p, &wl);
        assert!(r.l1.misses <= r_p.l1.misses);
    }

    #[test]
    fn multi_kernel_keeps_caches_warm() {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let k = simple_kernel(&cfg, |c| vec![c as u64]);
        let wl = Workload {
            name: "t".into(),
            kernels: vec![k.clone(), k],
        };
        let r = run_workload(&cfg, &wl);
        assert_eq!(r.kernels.len(), 2);
        // Second kernel re-reads the same line: all hits.
        assert!(r.kernels[1].l1_hit_rate > 0.9, "{:?}", r.kernels[1]);
        assert!(r.kernels[1].l1_mean_latency < r.kernels[0].l1_mean_latency);
    }

    #[test]
    fn fast_forward_skips_idle_cycles_without_breaking_ipc() {
        // One warp, one cold load: cycles ≈ miss latency, not 1.
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let wl = Workload {
            name: "t".into(),
            kernels: vec![simple_kernel(&cfg, |c| vec![c as u64 * 1000])],
        };
        let r = run_workload(&cfg, &wl);
        assert!(r.cycles > 100, "a cold DRAM miss takes hundreds of cycles");
        assert!(r.cycles < 100_000, "but the engine must not crawl");
    }

    #[test]
    fn load_latency_metric_reflects_misses_vs_hits() {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let cold = Workload {
            name: "cold".into(),
            kernels: vec![simple_kernel(&cfg, |c| vec![c as u64 * 50])],
        };
        let r1 = run_workload(&cfg, &cold);
        assert!(
            r1.l1_mean_load_latency > cfg.l2.latency as f64,
            "cold loads include L2+DRAM: {}",
            r1.l1_mean_load_latency
        );
    }
}
