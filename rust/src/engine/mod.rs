//! The cycle engine: wires SIMT cores, an L1 organization, and the memory
//! system together and runs multi-kernel workloads to completion.
//!
//! Memory timing is resolved analytically through the reservation model at
//! the moment a request is issued, so every future completion lands in the
//! wake calendar up front.  The clock therefore advances **event-driven**
//! (`engine.event_driven`, default on): when no core can issue this cycle,
//! `now` jumps straight to the next-event horizon — the min over every
//! core's issue hint and the earliest pending wake — skipping the idle
//! stretch entirely.  Contention is charged at reservation time
//! (`Grant::queued` / `MemTxn::charge`), which makes the stall ledger a
//! pure function of the request stream, independent of tick cadence: the
//! skipped interval's charges were already booked in one batch when the
//! blocking reservations were made.  Flipping the flag off selects the
//! cycle-by-cycle reference mode (`now + 1` every iteration) that the
//! differential harness (`rust/tests/event_determinism.rs`, the bench A/B,
//! and the CI cmp smoke) compares against: all simulated metrics must be
//! byte-identical, only wall clock may move.  [`Engine::event_stats`]
//! exposes skip telemetry (never folded into result JSON).
//!
//! Two execution modes share the machinery:
//!
//! * [`Engine::run`] — one application occupies every core (the paper's
//!   evaluation setup).
//! * [`Engine::run_multi`] — N applications co-execute on disjoint
//!   [`CorePartition`]s while *sharing* the L1 organization, NoC, L2 and
//!   DRAM, so inter-application interference (and ATA's filtering of it)
//!   becomes measurable.  Each app advances through its own kernel
//!   sequence independently inside one cycle loop, and statistics are
//!   attributed per app ([`AppCoStats`]).
//!
//! **Threading contract.**  [`Workload`], [`MultiWorkload`] and the
//! [`Engine`] itself are `Send` (every component down to the
//! `Box<dyn L1Arch>` carries the bound), which is what lets the
//! execution layer ([`crate::exec`]) construct self-contained jobs on
//! the submitting thread and run one engine per job on a worker pool.
//! An engine is *not* `Sync`: it is owned and driven by exactly one
//! worker; determinism comes from the simulation being a pure function
//! of (config, workload), never from synchronization.
//!
//! **Sharding.**  `engine.shards > 1` splits one run's clusters across
//! host threads between deterministic epoch barriers (the `shard`
//! module), with the shared memory walk kept in canonical order on the
//! coordinator.  The sequential loops below remain the reference:
//! `--shards N` output is byte-identical to `--shards 1` (pinned by
//! `rust/tests/shard_determinism.rs` and the CI cmp smoke), and
//! [`Engine::shard_stats`] exposes the sharded loop's host telemetry.
//!
//! **Slice-parallel memory walk.**  Every loop processes each cycle's
//! request batch as one phased epoch: a B1 front-end pass on the
//! coordinator in canonical request order ([`MemSystem::begin_epoch`] /
//! `L1Arch::access`, which defers misses into per-slice fetch
//! descriptors), the walk ([`MemSystem::run_walk`] — fanned out across
//! `engine.mem_workers` persistent threads when > 1, each owning a
//! contiguous run of L2 slices), then a B3 finish pass (`L1Arch::finish`)
//! in the same canonical order.  `--mem-workers N` output is
//! byte-identical to `--mem-workers 1` at any `--shards` setting (pinned
//! by `rust/tests/memwalk_determinism.rs` and the CI cmp smoke).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
// lint: allow(wall-clock) — host-side run-duration telemetry and the opt-in --job-timeout-s watchdog; never in result JSON
use std::time::{Duration, Instant};

/// Per-run deltas of the shared memory-system counters (see
/// [`Engine::mem_deltas`]).
struct MemDeltas {
    l2_hit_rate: f64,
    l2_mean_fetch_latency: f64,
    noc_flits: u64,
    dram_reads: u64,
    dram_writes: u64,
}

use crate::config::{FaultKind, GpuConfig};
use crate::core::{CorePartition, IssueBatch, SimtCore, WarpProgram};
use crate::l1arch::{self, L1Arch};
use crate::l2::MemSystem;
use crate::mem::{LineAddr, MemTxn};
use crate::stats::{
    AppCoStats, ContentionStats, EventStats, HopStats, KernelStats, LoadLatencyTracker,
    MultiResult, ShardStats, SimResult,
};

mod error;
mod shard;

pub use error::{panic_message, FailSnapshot, SimError};

/// One kernel launch: a set of warp programs per core.
#[derive(Debug, Clone, Default)]
pub struct KernelSpec {
    pub name: String,
    /// `programs[core]` = warp programs for that core.  In solo runs the
    /// outer index is the global core id; inside an [`AppLane`] it is the
    /// partition-local core index.
    pub programs: Vec<Vec<WarpProgram>>,
}

impl KernelSpec {
    /// Shift every line address in the kernel by `delta` (see
    /// [`Workload::offset_lines`]).
    pub fn offset_lines(&mut self, delta: LineAddr) {
        for programs in &mut self.programs {
            for p in programs {
                p.offset_lines(delta);
            }
        }
    }
}

/// A whole application: an ordered list of kernels (Fig 9's unit
/// structure).
#[derive(Debug, Clone, Default)]
pub struct Workload {
    pub name: String,
    pub kernels: Vec<KernelSpec>,
}

impl Workload {
    /// Total coalesced memory requests the workload will issue.
    pub fn total_requests(&self) -> u64 {
        self.kernels
            .iter()
            .flat_map(|k| k.programs.iter().flatten())
            .map(WarpProgram::request_count)
            .sum()
    }

    /// Shift every line address by `delta`.  Co-execution uses this to
    /// give each application a disjoint virtual address space; pass a
    /// shared offset (or zero) to model read-shared segments between
    /// applications instead.
    pub fn offset_lines(&mut self, delta: LineAddr) {
        for k in &mut self.kernels {
            k.offset_lines(delta);
        }
    }
}

/// One co-executing application: its kernel sequence plus the core
/// partition it owns.  `kernels[*].programs` are indexed by
/// partition-local core (length must equal `partition.count`).
#[derive(Debug, Clone, Default)]
pub struct AppLane {
    pub name: String,
    pub kernels: Vec<KernelSpec>,
    pub partition: CorePartition,
}

/// A co-execution workload: N applications on disjoint core partitions,
/// sharing the memory system below the cores.  Built by hand or via
/// [`crate::trace::co_workload`].
#[derive(Debug, Clone, Default)]
pub struct MultiWorkload {
    /// Display name, conventionally `"appA+appB"`.
    pub name: String,
    pub lanes: Vec<AppLane>,
}

impl MultiWorkload {
    /// Check partition disjointness and program shapes against `cfg`.
    pub fn validate(&self, cfg: &GpuConfig) -> Result<(), String> {
        if self.lanes.is_empty() {
            return Err("multi-workload has no lanes".into());
        }
        let mut used = vec![false; cfg.cores];
        for lane in &self.lanes {
            if lane.kernels.is_empty() {
                return Err(format!("lane '{}' has no kernels", lane.name));
            }
            if lane.partition.count == 0 || lane.partition.end() > cfg.cores {
                return Err(format!(
                    "lane '{}' partition [{}, {}) outside the {}-core GPU",
                    lane.name,
                    lane.partition.first,
                    lane.partition.end(),
                    cfg.cores
                ));
            }
            for c in lane.partition.first..lane.partition.end() {
                if used[c] {
                    return Err(format!("core {c} assigned to two lanes"));
                }
                used[c] = true;
            }
            for k in &lane.kernels {
                if k.programs.len() != lane.partition.count {
                    return Err(format!(
                        "lane '{}' kernel '{}' has {} core programs for a {}-core partition",
                        lane.name,
                        k.name,
                        k.programs.len(),
                        lane.partition.count
                    ));
                }
            }
        }
        Ok(())
    }

    /// Total coalesced memory requests across all lanes.
    pub fn total_requests(&self) -> u64 {
        self.lanes
            .iter()
            .flat_map(|l| &l.kernels)
            .flat_map(|k| k.programs.iter().flatten())
            .map(WarpProgram::request_count)
            .sum()
    }
}

/// Safety valve: a kernel that exceeds this many cycles aborts the run
/// with [`SimError::Livelock`] (real runs never get close).
const MAX_KERNEL_CYCLES: u64 = 500_000_000;

/// Forward-progress watchdog: if this many consecutive loop epochs
/// advance the clock without retiring a single instruction anywhere, the
/// run aborts as [`SimError::Livelock`].  The threshold is deliberately
/// enormous next to any legitimate stall (a full DRAM round trip is a few
/// hundred cycles, and in reference mode every idle cycle is an epoch),
/// and `LIVELOCK_EPOCHS * PHANTOM_WAKE_STRIDE` stays below
/// [`MAX_KERNEL_CYCLES`] so the watchdog — with its richer snapshot —
/// always fires before the blunt cycle valve on an injected livelock.
const LIVELOCK_EPOCHS: u64 = 200_000;

/// The opt-in host wall-clock budget is polled once every
/// `DEADLINE_EPOCH_MASK + 1` loop epochs (power of two for a branchless
/// mask test): responsive at second-granularity budgets, invisible in
/// profiles.
const DEADLINE_EPOCH_MASK: u64 = 0xFFF;

/// Stride of the phantom re-wakes injected by [`FaultKind::Livelock`]:
/// each due wake is bounced `PHANTOM_WAKE_STRIDE` cycles forward instead
/// of being delivered, so the clock advances forever while nothing
/// retires — the exact signature the watchdog exists to catch.
const PHANTOM_WAKE_STRIDE: u64 = 1024;

/// `u64::MAX` horizons mean "no such event": map them to `None` so the
/// snapshot serializes them as `null` instead of a lossy f64 sentinel.
fn horizon_opt(h: u64) -> Option<u64> {
    (h != u64::MAX).then_some(h)
}

/// Period of the stale-entry sweep over the L1/L2 in-flight maps.
///
/// Sweeps fire at the fixed boundaries `run_start + k * SWEEP_PERIOD`,
/// never at clock-cadence-dependent cycles: [`MemSystem::fetch`] treats
/// a stale in-flight entry differently from an absent one (merge-window
/// hit vs a full DRAM trip with fills and evictions), so *when* a sweep
/// runs is metric-visible and must be identical with
/// `engine.event_driven` on and off.  Public so the differential tests
/// can size workloads that provably cross a boundary.
pub const SWEEP_PERIOD: u64 = 65_537;

/// Mutable per-lane execution state of one co-executing application,
/// shared by the sequential [`Engine::run_multi`] loop and the sharded
/// `shard::multi_loop`.
struct LaneRun {
    kernel_idx: usize,
    /// Cores of the currently active kernel (empty once done — and empty
    /// for the whole run under the sharded loop, which owns the cores in
    /// per-shard slots instead).
    cores: Vec<SimtCore>,
    done: bool,
    finish_cycle: u64,
    insts: u64,
    requests: u64,
    tracker: LoadLatencyTracker,
    stage_tracker: LoadLatencyTracker,
    kernels_out: Vec<KernelStats>,
    k_start_cycle: u64,
    k_start_insts: u64,
    k_start_loads: u64,
    k_start_lat: u64,
    k_start_stage_loads: u64,
    k_start_stage_lat: u64,
}

impl LaneRun {
    /// Fresh lane state with kernel 0 launched.
    fn start(lane: &AppLane, cfg: &GpuConfig, start_cycle: u64) -> LaneRun {
        LaneRun {
            kernel_idx: 0,
            cores: launch_lane(lane, 0, cfg),
            done: false,
            finish_cycle: 0,
            insts: 0,
            requests: 0,
            tracker: LoadLatencyTracker::default(),
            stage_tracker: LoadLatencyTracker::default(),
            kernels_out: Vec::new(),
            k_start_cycle: start_cycle,
            k_start_insts: 0,
            k_start_loads: 0,
            k_start_lat: 0,
            k_start_stage_loads: 0,
            k_start_stage_lat: 0,
        }
    }

    /// Close the books on the lane's current kernel at cycle `now`.
    /// Hit classes are counted in the shared L1 and cannot be attributed
    /// to one lane, so `l1_hit_rate` is reported as 0 here.
    fn finish_kernel(&mut self, spec: &KernelSpec, now: u64) {
        let loads = self.tracker.completed_loads - self.k_start_loads;
        let lat = self.tracker.total_latency - self.k_start_lat;
        let stage_loads = self.stage_tracker.completed_loads - self.k_start_stage_loads;
        let stage_lat = self.stage_tracker.total_latency - self.k_start_stage_lat;
        self.kernels_out.push(KernelStats {
            name: spec.name.clone(),
            cycles: now - self.k_start_cycle,
            insts: self.insts - self.k_start_insts,
            l1_mean_latency: if loads == 0 { 0.0 } else { lat as f64 / loads as f64 },
            l1_stage_latency: if stage_loads == 0 {
                0.0
            } else {
                stage_lat as f64 / stage_loads as f64
            },
            l1_hit_rate: 0.0,
        });
    }

    /// Re-baseline the per-kernel counters for the next kernel, which
    /// starts issuing at `now + 1` (the one-cycle launch boundary).
    fn begin_kernel(&mut self, now: u64) {
        self.k_start_cycle = now;
        self.k_start_insts = self.insts;
        self.k_start_loads = self.tracker.completed_loads;
        self.k_start_lat = self.tracker.total_latency;
        self.k_start_stage_loads = self.stage_tracker.completed_loads;
        self.k_start_stage_lat = self.stage_tracker.total_latency;
    }
}

/// Launch a lane's kernel `kernel_idx`: one fresh core per partition
/// slot, addressed by its global core id.
fn launch_lane(lane: &AppLane, kernel_idx: usize, cfg: &GpuConfig) -> Vec<SimtCore> {
    lane.kernels[kernel_idx]
        .programs
        .iter()
        .enumerate()
        .map(|(j, progs)| SimtCore::new(lane.partition.global(j) as u32, cfg, progs.clone()))
        .collect()
}

pub struct Engine {
    cfg: GpuConfig,
    l1: Box<dyn L1Arch>,
    mem: MemSystem,
    /// Full load latency (issue → data at core, including L2/DRAM).
    tracker: LoadLatencyTracker,
    /// The paper's §IV-C metric: issue → L1-stage completion.
    stage_tracker: LoadLatencyTracker,
    /// Per-hop latency decomposition read off every transaction
    /// (cumulative over the engine's lifetime; results report deltas).
    hops: HopStats,
    cycle: u64,
    /// (wake_cycle, core, warp) calendar.
    wakes: BinaryHeap<Reverse<(u64, u32, u32)>>,
    total_insts: u64,
    /// Clock-advance telemetry (ticked vs simulated cycles); host data
    /// only, never part of result JSON.
    events: EventStats,
    /// Sharded-loop telemetry (epochs, cross-shard traffic); host data
    /// only, never part of result JSON.
    shard_stats: ShardStats,
    /// `FaultKind::Deadlock` arming: true from run start until the first
    /// completion wake has been swallowed.
    fault_deadlock_armed: bool,
    /// Host wall-clock deadline of the current run, set from
    /// `engine.job_timeout_s` at run start (`None` = no budget).
    // lint: allow(wall-clock) — opt-in --job-timeout-s watchdog; never in result JSON
    deadline: Option<Instant>,
}

impl Engine {
    /// Infallible constructor for direct callers (tests, examples) that
    /// treat a bad config as a programming error.  Grid execution goes
    /// through [`Engine::try_new`] so a malformed job becomes a
    /// [`SimError::InvalidConfig`] entry instead of a crash.
    pub fn new(cfg: &GpuConfig) -> Self {
        // lint: allow(sim-panic) — deliberate fail-fast facade over try_new
        Engine::try_new(cfg).expect("invalid GPU config")
    }

    /// Fallible constructor: a config that fails validation returns
    /// [`SimError::InvalidConfig`] instead of panicking.
    pub fn try_new(cfg: &GpuConfig) -> Result<Self, SimError> {
        cfg.validate()
            .map_err(|e| SimError::InvalidConfig(e.to_string()))?;
        Ok(Engine {
            cfg: cfg.clone(),
            l1: l1arch::build(cfg),
            mem: MemSystem::new(cfg),
            tracker: LoadLatencyTracker::default(),
            stage_tracker: LoadLatencyTracker::default(),
            hops: HopStats::default(),
            cycle: 0,
            wakes: BinaryHeap::new(),
            total_insts: 0,
            events: EventStats::default(),
            shard_stats: ShardStats::default(),
            fault_deadlock_armed: false,
            deadline: None,
        })
    }

    /// Arm the configured fault injection and the host wall-clock budget
    /// for a run that is about to start.  `FaultKind::Panic` fires here —
    /// before any simulation state is touched — to exercise the
    /// `catch_unwind` containment in the execution layer.
    fn begin_run(&mut self) {
        self.fault_deadlock_armed = self.cfg.engine.fault == FaultKind::Deadlock;
        if self.cfg.engine.fault == FaultKind::Panic {
            // lint: allow(sim-panic) — FaultKind::Panic exists to exercise panic containment
            panic!("injected fault: panic");
        }
        self.deadline = (self.cfg.engine.job_timeout_s > 0).then(|| {
            // lint: allow(wall-clock) — opt-in --job-timeout-s watchdog; never in result JSON
            Instant::now() + Duration::from_secs(self.cfg.engine.job_timeout_s)
        });
    }

    /// True when the opt-in `--job-timeout-s` budget has expired.  Called
    /// at a coarse epoch cadence (`DEADLINE_EPOCH_MASK`) so the clock
    /// syscall never shows up in profiles.
    fn host_budget_expired(&self) -> bool {
        // lint: allow(wall-clock) — opt-in --job-timeout-s watchdog; never in result JSON
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Host-timeout error for the current run phase.
    fn host_timeout(&self, what: String) -> SimError {
        SimError::HostTimeout {
            what,
            seconds: self.cfg.engine.job_timeout_s,
        }
    }

    /// Diagnostic snapshot over an explicit set of live cores (the solo
    /// kernel loop and, filtered to active lanes, the multi loop).  The
    /// sharded loops build the identical snapshot from their per-shard
    /// slots (`shard::snapshot`), so the serialized failure is
    /// byte-identical at any `--shards` setting.
    fn snapshot<'a>(
        &self,
        what: String,
        now: u64,
        live_cores: impl Iterator<Item = &'a SimtCore>,
    ) -> FailSnapshot {
        let mut cores_total = 0;
        let mut cores_blocked = 0;
        let mut next_core = u64::MAX;
        for core in live_cores {
            cores_total += 1;
            if !core.all_done() {
                cores_blocked += 1;
            }
            next_core = next_core.min(core.next_event_hint());
        }
        FailSnapshot {
            what,
            cycle: now,
            cores_total,
            cores_blocked,
            insts_retired: self.total_insts,
            wake_depth: self.wakes.len() as u64,
            next_core_event: horizon_opt(next_core),
            next_wake: self.wakes.peek().map(|Reverse((t, _, _))| *t),
            mem_horizon: self.mem.next_event(now),
        }
    }

    /// Effective shard count for this engine's config: `engine.shards`
    /// clamped to `[1, clusters]`.  Shards own whole clusters, so more
    /// shards than clusters cannot exist — over-sharding is legal in the
    /// config and simply clamps.  `1` selects the sequential reference
    /// loops below.
    fn effective_shards(&self) -> usize {
        self.cfg.engine.shards.clamp(1, self.cfg.clusters)
    }

    /// Compute the next clock value from the next-event horizon.
    ///
    /// `horizon` is the min over every core's issue hint and the earliest
    /// pending wake; `u64::MAX` means no core can ever progress — a
    /// deadlock, reported by the caller.  With `engine.event_driven` the
    /// clock jumps straight to the horizon (never less than `now + 1`);
    /// in reference mode it advances one cycle regardless, ticking
    /// through stretches the event-driven path proves idle.  Either way
    /// the advance is recorded in the [`EventStats`] telemetry.
    #[inline]
    fn advance(&mut self, now: u64, horizon: u64) {
        let next = if self.cfg.engine.event_driven {
            horizon.max(now + 1)
        } else {
            now + 1
        };
        self.events.record_advance(next - now);
        self.cycle = next;
    }

    /// Run a full workload; caches stay warm across kernels.
    ///
    /// Every reported metric is a *per-run delta*: on a reused (warm)
    /// engine the result describes only this run, mirroring
    /// [`Engine::run_multi`].  The latency trackers are reset at run
    /// start (no loads can be outstanding between runs), so means and
    /// maxima are per-run too.
    ///
    /// On `Err` the engine's simulation state is poisoned (outstanding
    /// loads, undelivered wakes): drop it and build a fresh engine for
    /// the next run.  The execution layer always does.
    pub fn run(&mut self, workload: &Workload) -> Result<SimResult, SimError> {
        let host_start = Instant::now(); // lint: allow(wall-clock) — stderr-only host span, excluded from SimResult
        self.begin_run();
        let start_cycle = self.cycle;
        let start_insts = self.total_insts;
        debug_assert_eq!(self.tracker.outstanding(), 0);
        debug_assert_eq!(self.stage_tracker.outstanding(), 0);
        self.tracker = LoadLatencyTracker::default();
        self.stage_tracker = LoadLatencyTracker::default();
        let l1_before = *self.l1.stats();
        let l2_before = self.mem.stats;
        let dram_before = self.mem.dram_stats();
        let noc_before = self.mem.noc_flits();
        let con_before = self.contention();
        let hops_before = self.hops;

        let mut kernels = Vec::with_capacity(workload.kernels.len());
        for k in &workload.kernels {
            kernels.push(self.run_kernel(k)?);
        }

        let l1 = self.l1.stats().delta(&l1_before);
        let md = self.mem_deltas(&l2_before, dram_before, noc_before);
        let contention = *self.contention().delta(&con_before).total();
        let hops = self.hops.delta(&hops_before);
        Ok(SimResult {
            app: workload.name.clone(),
            arch: self.l1.kind().name().to_string(),
            cycles: self.cycle - start_cycle,
            insts: self.total_insts - start_insts,
            l1,
            loads: self.tracker.completed_loads,
            l1_mean_load_latency: self.tracker.mean(),
            l1_max_load_latency: self.tracker.max_latency,
            l1_stage_mean_latency: self.stage_tracker.mean(),
            l1_stage_max_latency: self.stage_tracker.max_latency,
            l2_hit_rate: md.l2_hit_rate,
            l2_mean_fetch_latency: md.l2_mean_fetch_latency,
            noc_flits: md.noc_flits,
            dram_reads: md.dram_reads,
            dram_writes: md.dram_writes,
            contention,
            hops,
            kernels,
            host_seconds: host_start.elapsed().as_secs_f64(),
        })
    }

    /// Per-run deltas of the shared memory-system counters against a
    /// snapshot taken at run start (used identically by [`Engine::run`]
    /// and [`Engine::run_multi`]).
    fn mem_deltas(
        &self,
        l2_before: &crate::l2::L2Stats,
        dram_before: crate::dram::DramStats,
        noc_before: u64,
    ) -> MemDeltas {
        let l2 = self.mem.stats;
        let accesses = l2.accesses - l2_before.accesses;
        let hits = l2.hits - l2_before.hits;
        let fetches = l2.fetches - l2_before.fetches;
        let fetch_latency = l2.total_fetch_latency - l2_before.total_fetch_latency;
        let dram = self.mem.dram_stats();
        MemDeltas {
            l2_hit_rate: if accesses == 0 {
                0.0
            } else {
                hits as f64 / accesses as f64
            },
            l2_mean_fetch_latency: if fetches == 0 {
                0.0
            } else {
                fetch_latency as f64 / fetches as f64
            },
            noc_flits: self.mem.noc_flits() - noc_before,
            dram_reads: dram.reads - dram_before.reads,
            dram_writes: dram.writes - dram_before.writes,
        }
    }

    /// End-to-end per-core contention attribution: the L1 organization's
    /// share (tag/data banks, comparators, intra-cluster fabric, MSHR
    /// stalls) combined with the memory system's (NoC links, L2 slices,
    /// DRAM).  Counters are cumulative over the engine's lifetime; take
    /// deltas for per-run reporting.
    pub fn contention(&self) -> ContentionStats {
        let mut c = self.l1.contention().clone();
        c.absorb(self.mem.contention());
        c
    }

    /// Run N applications concurrently on disjoint core partitions.
    ///
    /// All lanes start at cycle 0 and advance through their own kernel
    /// sequences independently: when a lane's kernel finishes at cycle
    /// `T`, its next kernel starts issuing at `T + 1` (a one-cycle
    /// launch boundary) while the other lanes keep running — no barrier
    /// between lanes.  The L1 organization, NoC, L2 and DRAM are shared,
    /// so lanes contend exactly as co-scheduled applications would —
    /// including cross-app remote L1 hits when lanes share lines inside
    /// one cluster.
    ///
    /// Relation to [`Engine::run`]: for a single-kernel lane the timing
    /// is bit-identical to a solo run with the other cores idle (tested
    /// below); across kernel boundaries the solo path launches at `T`
    /// rather than `T + 1`, so a K-kernel lane finishes at most `K - 1`
    /// cycles later than the equivalent solo run.
    ///
    /// Determinism: for a fixed config and workload the result is
    /// bit-identical across runs (lanes are ticked in declaration order,
    /// cores in partition order within each lane, and the wake calendar
    /// orders ties by (cycle, core, warp)).
    pub fn run_multi(&mut self, multi: &MultiWorkload) -> Result<MultiResult, SimError> {
        let host_start = Instant::now(); // lint: allow(wall-clock) — stderr-only host span, excluded from MultiResult
        if let Err(e) = multi.validate(&self.cfg) {
            return Err(SimError::InvalidConfig(format!("invalid multi-workload: {e}")));
        }
        self.begin_run();
        debug_assert!(self.wakes.is_empty());
        let start_cycle = self.cycle;

        let mut lanes: Vec<LaneRun> = multi
            .lanes
            .iter()
            .map(|lane| LaneRun::start(lane, &self.cfg, start_cycle))
            .collect();

        let l1_before = *self.l1.stats();
        let l2_before = self.mem.stats;
        let dram_before = self.mem.dram_stats();
        let noc_before = self.mem.noc_flits();
        let con_before = self.contention();
        let hops_before = self.hops;
        // Deadlock guard: the co-run may legitimately span many kernels
        // per lane, so scale the solo path's per-kernel budget.
        let total_kernels: u64 = multi.lanes.iter().map(|l| l.kernels.len() as u64).sum();
        let max_cycles = MAX_KERNEL_CYCLES.saturating_mul(total_kernels.max(1));
        let n_shards = self.effective_shards();
        if n_shards > 1 {
            shard::multi_loop(self, multi, &mut lanes, start_cycle, max_cycles, n_shards)?;
        } else {
            // Global core id → lane index (usize::MAX for idle cores).
            let mut owner = vec![usize::MAX; self.cfg.cores];
            for (li, lane) in multi.lanes.iter().enumerate() {
                for c in lane.partition.first..lane.partition.end() {
                    owner[c] = li;
                }
            }
            let mut batch = IssueBatch::default();
            let mut open = Vec::new();
            let mut last_sweep = self.cycle;
            let mut stuck_epochs: u64 = 0;
            let mut last_insts = self.total_insts;
            let mut epoch: u64 = 0;
            loop {
                let now = self.cycle;

                // 1. Deliver due wake-ups to the owning lane's core.
                while let Some(&Reverse((t, core, warp))) = self.wakes.peek() {
                    if t > now {
                        break;
                    }
                    self.wakes.pop();
                    if self.cfg.engine.fault == FaultKind::Livelock {
                        // Injected livelock: the load never completes —
                        // its wake keeps bouncing forward, so the clock
                        // advances while nothing retires.
                        self.wakes.push(Reverse((now + PHANTOM_WAKE_STRIDE, core, warp)));
                        continue;
                    }
                    let li = owner[core as usize];
                    let local = multi.lanes[li].partition.local(core as usize);
                    lanes[li].cores[local].load_complete(warp, t);
                }

                // 2. Tick every active lane's cores; attribute issued insts.
                batch.requests.clear();
                batch.insts_issued = 0;
                for lane in lanes.iter_mut() {
                    if lane.done {
                        continue;
                    }
                    let before = batch.insts_issued;
                    for core in lane.cores.iter_mut() {
                        core.tick(now, &mut batch);
                    }
                    lane.insts += batch.insts_issued - before;
                }
                self.total_insts += batch.insts_issued;

                // 3. Feed requests through the shared L1 organization as
                //    one phased memory-walk epoch (B1 front end in
                //    canonical order, per-slice walk, B3 finish in the
                //    same order), tracking load latencies per lane.
                self.mem.begin_epoch();
                open.clear();
                let mut prev_group: Option<(u32, u32, u64)> = None;
                for (req, group_n) in batch.requests.iter() {
                    let lane = &mut lanes[owner[req.core as usize]];
                    lane.requests += 1;
                    if *group_n > 0 {
                        let key = (req.core, req.warp, req.inst);
                        if prev_group != Some(key) {
                            lane.tracker.issue(req.core, req.warp, req.inst, *group_n, now);
                            lane.stage_tracker.issue(req.core, req.warp, req.inst, *group_n, now);
                            prev_group = Some(key);
                        }
                    }
                    let mut txn = MemTxn::new(*req, now);
                    self.l1.access(&mut txn, &mut self.mem);
                    open.push((txn, *group_n));
                }
                self.mem.run_walk()?;
                for (mut txn, group_n) in open.drain(..) {
                    self.l1.finish(&mut txn, &mut self.mem);
                    self.hops.record(&txn.hops, &txn.queued);
                    if group_n > 0 {
                        let (core, warp, inst) = (txn.req.core, txn.req.warp, txn.req.inst);
                        let lane = &mut lanes[owner[core as usize]];
                        lane.stage_tracker
                            .complete_one(core, warp, inst, txn.l1_stage_done());
                        if let Some(load_done) =
                            lane.tracker.complete_one(core, warp, inst, txn.done())
                        {
                            if self.fault_deadlock_armed {
                                // Injected deadlock: swallow the first
                                // completion wake; its warp blocks forever.
                                self.fault_deadlock_armed = false;
                            } else {
                                self.wakes.push(Reverse((load_done.max(now + 1), core, warp)));
                            }
                        }
                    }
                }
                self.mem.end_epoch();

                // 4. Kernel completion: advance finished lanes independently.
                for (li, lane) in lanes.iter_mut().enumerate() {
                    if lane.done || !lane.cores.iter().all(SimtCore::all_done) {
                        continue;
                    }
                    let spec = &multi.lanes[li].kernels[lane.kernel_idx];
                    lane.finish_kernel(spec, now);
                    lane.kernel_idx += 1;
                    if lane.kernel_idx < multi.lanes[li].kernels.len() {
                        lane.cores = launch_lane(&multi.lanes[li], lane.kernel_idx, &self.cfg);
                        lane.begin_kernel(now);
                    } else {
                        lane.done = true;
                        lane.finish_cycle = now - start_cycle;
                        lane.cores.clear();
                    }
                }

                // 5. Termination / advance.
                if lanes.iter().all(|l| l.done) {
                    break;
                }
                let next_ready = lanes
                    .iter()
                    .filter(|l| !l.done)
                    .flat_map(|l| l.cores.iter().map(SimtCore::next_event_hint))
                    .min()
                    .unwrap_or(u64::MAX);
                let next_wake =
                    self.wakes.peek().map(|Reverse((t, _, _))| *t).unwrap_or(u64::MAX);
                let horizon = next_ready.min(next_wake);
                if horizon == u64::MAX {
                    let live = lanes.iter().filter(|l| !l.done).flat_map(|l| l.cores.iter());
                    return Err(SimError::Deadlock(self.snapshot(
                        format!("co-execution '{}'", multi.name),
                        now,
                        live,
                    )));
                }
                // Forward-progress watchdog — identical detection order in
                // the sharded loop, so snapshots match at any shard count.
                if self.total_insts == last_insts {
                    stuck_epochs += 1;
                    if stuck_epochs >= LIVELOCK_EPOCHS {
                        let live =
                            lanes.iter().filter(|l| !l.done).flat_map(|l| l.cores.iter());
                        let snap = self.snapshot(
                            format!("co-execution '{}'", multi.name),
                            now,
                            live,
                        );
                        return Err(SimError::Livelock {
                            snap,
                            why: format!(
                                "no instruction retired for {LIVELOCK_EPOCHS} consecutive epochs"
                            ),
                        });
                    }
                } else {
                    last_insts = self.total_insts;
                    stuck_epochs = 0;
                }
                self.advance(now, horizon);

                // Stale-entry sweep at fixed boundaries: both clock modes
                // visit the same (boundary, threshold) pairs no matter how
                // the clock advanced, so the L2 in-flight merge window can
                // never depend on `engine.event_driven`.  A jump crossing
                // several boundaries replays each one; earlier sweeps are
                // subsumed by later ones (pure `ready > t` filters), but
                // stepping keeps `last_sweep` mode-independent.
                while self.cycle - last_sweep >= SWEEP_PERIOD {
                    last_sweep += SWEEP_PERIOD;
                    self.l1.sweep(last_sweep);
                    self.mem.sweep_in_flight(last_sweep);
                }
                if self.cycle - start_cycle > max_cycles {
                    let live = lanes.iter().filter(|l| !l.done).flat_map(|l| l.cores.iter());
                    let snap = self.snapshot(
                        format!("co-execution '{}'", multi.name),
                        self.cycle,
                        live,
                    );
                    return Err(SimError::Livelock {
                        snap,
                        why: format!("exceeded the {max_cycles}-cycle safety valve"),
                    });
                }
                epoch += 1;
                if epoch & DEADLINE_EPOCH_MASK == 0 && self.host_budget_expired() {
                    return Err(self.host_timeout(format!("co-execution '{}'", multi.name)));
                }
            }
        }

        // Every reported metric is a *per-run delta*, so a reused (warm)
        // engine yields results that describe only this co-execution.
        let l1 = self.l1.stats().delta(&l1_before);
        let md = self.mem_deltas(&l2_before, dram_before, noc_before);
        let con = self.contention().delta(&con_before);

        let apps: Vec<AppCoStats> = multi
            .lanes
            .iter()
            .zip(&lanes)
            .map(|(spec, run)| AppCoStats {
                name: spec.name.clone(),
                first_core: spec.partition.first,
                cores: spec.partition.count,
                finish_cycle: run.finish_cycle,
                insts: run.insts,
                loads: run.tracker.completed_loads,
                mean_load_latency: run.tracker.mean(),
                stage_mean_latency: run.stage_tracker.mean(),
                requests: run.requests,
                // Which resources this app's cores stalled on during the
                // co-run — compare against the solo baseline to see what a
                // co-runner steals.
                contention: con.lane_total(spec.partition.first, spec.partition.count),
                kernels: run.kernels_out.clone(),
            })
            .collect();

        Ok(MultiResult {
            name: multi.name.clone(),
            arch: self.l1.kind().name().to_string(),
            cycles: self.cycle - start_cycle,
            insts: apps.iter().map(|a| a.insts).sum(),
            l1,
            l2_hit_rate: md.l2_hit_rate,
            l2_mean_fetch_latency: md.l2_mean_fetch_latency,
            noc_flits: md.noc_flits,
            dram_reads: md.dram_reads,
            dram_writes: md.dram_writes,
            contention: *con.total(),
            hops: self.hops.delta(&hops_before),
            apps,
            host_seconds: host_start.elapsed().as_secs_f64(),
        })
    }

    /// Replication audit: per-core resident lines (used by integration
    /// tests and the locality cross-check example).
    pub fn resident_lines(&self, core: usize) -> Vec<crate::mem::LineAddr> {
        self.l1.resident_lines(core)
    }

    pub fn l1_stats(&self) -> crate::stats::L1Stats {
        *self.l1.stats()
    }

    /// Residency-index telemetry of the underlying L1 organization
    /// (zeros for organizations without an index, or with
    /// `sharing.residency_index` off).  Host-performance data only —
    /// never folded into result JSON (see
    /// [`crate::stats::ResidencyStats`]).
    pub fn residency_stats(&self) -> crate::stats::ResidencyStats {
        self.l1.residency_stats()
    }

    /// Clock-advance telemetry, cumulative over the engine's lifetime:
    /// how many cycles were actually ticked vs simulated, and the jump
    /// profile.  `cycles_simulated > cycles_ticked` proves the
    /// event-driven path skipped idle cycles; in reference mode
    /// (`engine.event_driven = false`) the two are equal.
    /// Host-performance data only — never folded into result JSON (see
    /// [`crate::stats::EventStats`]).
    pub fn event_stats(&self) -> EventStats {
        self.events
    }

    /// Sharded-loop telemetry, cumulative over the engine's lifetime:
    /// effective shard count of the last sharded run, synchronization
    /// epochs executed, and cross-shard traffic (egress transactions
    /// into the shared memory walk, completion wakes routed through the
    /// per-shard ingress FIFOs).  All zeros when every run used the
    /// sequential loop.  Host-performance data only — never folded into
    /// result JSON (see [`crate::stats::ShardStats`]).
    pub fn shard_stats(&self) -> ShardStats {
        self.shard_stats
    }

    fn run_kernel(&mut self, spec: &KernelSpec) -> Result<KernelStats, SimError> {
        if spec.programs.len() != self.cfg.cores {
            return Err(SimError::InvalidConfig(format!(
                "kernel '{}' provides {} core programs for a {}-core GPU",
                spec.name,
                spec.programs.len(),
                self.cfg.cores
            )));
        }
        let start_cycle = self.cycle;
        let start_insts = self.total_insts;
        let start_loads = self.tracker.completed_loads;
        let start_lat = self.tracker.total_latency;
        let start_stage_loads = self.stage_tracker.completed_loads;
        let start_stage_lat = self.stage_tracker.total_latency;
        let l1_before = *self.l1.stats();

        let mut cores: Vec<SimtCore> = spec
            .programs
            .iter()
            .enumerate()
            .map(|(c, progs)| SimtCore::new(c as u32, &self.cfg, progs.clone()))
            .collect();
        // Leftover wakes from a previous kernel cannot exist: kernels run
        // to completion.
        debug_assert!(self.wakes.is_empty());

        let n_shards = self.effective_shards();
        if n_shards > 1 {
            shard::kernel_loop(self, spec, cores, n_shards)?;
        } else {
            let mut batch = IssueBatch::default();
            let mut open = Vec::new();
            let mut last_sweep = self.cycle;
            let mut stuck_epochs: u64 = 0;
            let mut last_insts = self.total_insts;
            let mut epoch: u64 = 0;
            loop {
                let now = self.cycle;

                // 1. Deliver due wake-ups.
                while let Some(&Reverse((t, core, warp))) = self.wakes.peek() {
                    if t > now {
                        break;
                    }
                    self.wakes.pop();
                    if self.cfg.engine.fault == FaultKind::Livelock {
                        // Injected livelock: bounce the wake forward
                        // forever instead of delivering it.
                        self.wakes.push(Reverse((now + PHANTOM_WAKE_STRIDE, core, warp)));
                        continue;
                    }
                    cores[core as usize].load_complete(warp, t);
                }

                // 2. Tick every core; collect issued requests.
                batch.requests.clear();
                batch.insts_issued = 0;
                for core in cores.iter_mut() {
                    core.tick(now, &mut batch);
                }
                self.total_insts += batch.insts_issued;

                // 3. Feed requests through the L1 organization as one
                //    phased memory-walk epoch: the B1 front-end pass in
                //    canonical request order, the (possibly fanned-out)
                //    per-slice walk, then the B3 finish pass in the same
                //    order.
                self.mem.begin_epoch();
                open.clear();
                let mut prev_group: Option<(u32, u32, u64)> = None;
                for (req, group_n) in batch.requests.iter() {
                    if *group_n > 0 {
                        // A load: register its instruction group on first sight.
                        let key = (req.core, req.warp, req.inst);
                        if prev_group != Some(key) {
                            self.tracker.issue(req.core, req.warp, req.inst, *group_n, now);
                            self.stage_tracker.issue(req.core, req.warp, req.inst, *group_n, now);
                            prev_group = Some(key);
                        }
                    }
                    let mut txn = MemTxn::new(*req, now);
                    self.l1.access(&mut txn, &mut self.mem);
                    open.push((txn, *group_n));
                }
                self.mem.run_walk()?;
                for (mut txn, group_n) in open.drain(..) {
                    self.l1.finish(&mut txn, &mut self.mem);
                    self.hops.record(&txn.hops, &txn.queued);
                    if group_n > 0 {
                        let (core, warp, inst) = (txn.req.core, txn.req.warp, txn.req.inst);
                        self.stage_tracker
                            .complete_one(core, warp, inst, txn.l1_stage_done());
                        if let Some(load_done) =
                            self.tracker.complete_one(core, warp, inst, txn.done())
                        {
                            if self.fault_deadlock_armed {
                                // Injected deadlock: swallow the first
                                // completion wake; its warp blocks forever.
                                self.fault_deadlock_armed = false;
                            } else {
                                self.wakes.push(Reverse((load_done.max(now + 1), core, warp)));
                            }
                        }
                    }
                }
                self.mem.end_epoch();

                // 4. Termination / advance.
                if cores.iter().all(SimtCore::all_done) {
                    break;
                }
                // Next-event horizon: the earliest core issue hint or pending
                // wake (post-tick hints are O(1) per core).  The event-driven
                // clock jumps there; reference mode still computes it so the
                // deadlock guard is identical in both modes.
                let next_ready = cores
                    .iter()
                    .map(SimtCore::next_event_hint)
                    .min()
                    .unwrap_or(u64::MAX);
                let next_wake =
                    self.wakes.peek().map(|Reverse((t, _, _))| *t).unwrap_or(u64::MAX);
                let horizon = next_ready.min(next_wake);
                if horizon == u64::MAX {
                    return Err(SimError::Deadlock(self.snapshot(
                        format!("kernel '{}'", spec.name),
                        now,
                        cores.iter(),
                    )));
                }
                // Forward-progress watchdog — identical detection order in
                // the sharded loop, so snapshots match at any shard count.
                if self.total_insts == last_insts {
                    stuck_epochs += 1;
                    if stuck_epochs >= LIVELOCK_EPOCHS {
                        let snap =
                            self.snapshot(format!("kernel '{}'", spec.name), now, cores.iter());
                        return Err(SimError::Livelock {
                            snap,
                            why: format!(
                                "no instruction retired for {LIVELOCK_EPOCHS} consecutive epochs"
                            ),
                        });
                    }
                } else {
                    last_insts = self.total_insts;
                    stuck_epochs = 0;
                }
                self.advance(now, horizon);

                // Fixed-boundary stale-entry sweep — see the run_multi loop
                // for why the boundaries must be clock-cadence-independent.
                while self.cycle - last_sweep >= SWEEP_PERIOD {
                    last_sweep += SWEEP_PERIOD;
                    self.l1.sweep(last_sweep);
                    self.mem.sweep_in_flight(last_sweep);
                }
                if self.cycle - start_cycle > MAX_KERNEL_CYCLES {
                    let snap = self.snapshot(
                        format!("kernel '{}'", spec.name),
                        self.cycle,
                        cores.iter(),
                    );
                    return Err(SimError::Livelock {
                        snap,
                        why: format!("exceeded the {MAX_KERNEL_CYCLES}-cycle safety valve"),
                    });
                }
                epoch += 1;
                if epoch & DEADLINE_EPOCH_MASK == 0 && self.host_budget_expired() {
                    return Err(self.host_timeout(format!("kernel '{}'", spec.name)));
                }
            }
        }

        // Per-core stall counters die with the cores here: they are
        // host telemetry (see `SimtCore::stall_cycles`), never results.
        let l1_after = *self.l1.stats();
        let loads = self.tracker.completed_loads - start_loads;
        let lat = self.tracker.total_latency - start_lat;
        let stage_loads = self.stage_tracker.completed_loads - start_stage_loads;
        let stage_lat = self.stage_tracker.total_latency - start_stage_lat;
        let acc = l1_after.accesses - l1_before.accesses;
        let hits = (l1_after.local_hits + l1_after.remote_hits)
            - (l1_before.local_hits + l1_before.remote_hits);
        Ok(KernelStats {
            name: spec.name.clone(),
            cycles: self.cycle - start_cycle,
            insts: self.total_insts - start_insts,
            l1_mean_latency: if loads == 0 { 0.0 } else { lat as f64 / loads as f64 },
            l1_stage_latency: if stage_loads == 0 {
                0.0
            } else {
                stage_lat as f64 / stage_loads as f64
            },
            l1_hit_rate: if acc == 0 { 0.0 } else { hits as f64 / acc as f64 },
        })
    }
}

/// Convenience: run `workload` under `arch` on the paper GPU config.
/// Panics on simulation failure — direct callers (tests, examples) treat
/// a failing run as a bug; grid execution goes through [`crate::exec`].
pub fn run_workload(cfg: &GpuConfig, workload: &Workload) -> SimResult {
    // lint: allow(sim-panic) — deliberate fail-fast facade over Engine::run
    Engine::new(cfg).run(workload).expect("simulation failed")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::L1ArchKind;
    use crate::core::WarpInst;

    /// A kernel where every core's single warp loads `lines` then does ALU.
    fn simple_kernel(cfg: &GpuConfig, lines_per_core: impl Fn(usize) -> Vec<u64>) -> KernelSpec {
        KernelSpec {
            name: "k".into(),
            programs: (0..cfg.cores)
                .map(|c| {
                    let lines = lines_per_core(c);
                    let insts: Vec<WarpInst> = lines
                        .chunks(2)
                        .map(|ch| WarpInst::Load(ch.iter().map(|&l| (l, 0b1111)).collect()))
                        .chain(std::iter::once(WarpInst::Alu(8)))
                        .collect();
                    vec![WarpProgram::new(insts)]
                })
                .collect(),
        }
    }

    #[test]
    fn runs_to_completion_and_counts() {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let wl = Workload {
            name: "t".into(),
            kernels: vec![simple_kernel(&cfg, |c| vec![c as u64 * 100, c as u64 * 100 + 1])],
        };
        let r = run_workload(&cfg, &wl);
        assert!(r.cycles > 0);
        // 1 load inst + 8 ALU per core.
        assert_eq!(r.insts, cfg.cores as u64 * 9);
        assert_eq!(r.l1.accesses, cfg.cores as u64 * 2);
        assert!(r.ipc() > 0.0);
        assert_eq!(r.kernels.len(), 1);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let wl = Workload {
            name: "t".into(),
            kernels: vec![
                simple_kernel(&cfg, |c| (0..8).map(|k| (c as u64 * 31 + k) % 64).collect()),
                simple_kernel(&cfg, |c| (0..8).map(|k| (c as u64 * 17 + k) % 64).collect()),
            ],
        };
        let a = run_workload(&cfg, &wl);
        let b = run_workload(&cfg, &wl);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.insts, b.insts);
        assert_eq!(a.l1.local_hits, b.l1.local_hits);
        assert_eq!(a.l1_mean_load_latency, b.l1_mean_load_latency);
    }

    #[test]
    fn shared_lines_become_remote_hits_on_ata() {
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        // Every core loads the same two lines; cluster mates should hit
        // remotely (or locally after fills).
        let wl = Workload {
            name: "t".into(),
            kernels: vec![simple_kernel(&cfg, |_| vec![7, 8])],
        };
        let r = run_workload(&cfg, &wl);
        assert!(
            r.l1.remote_hits + r.l1.mshr_merges > 0,
            "sharing must be exploited: {:?}",
            r.l1
        );
        // Far fewer L2 trips than the private equivalent.
        let cfg_p = GpuConfig::tiny(L1ArchKind::Private);
        let r_p = run_workload(&cfg_p, &wl);
        assert!(r.l1.misses <= r_p.l1.misses);
    }

    #[test]
    fn multi_kernel_keeps_caches_warm() {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let k = simple_kernel(&cfg, |c| vec![c as u64]);
        let wl = Workload {
            name: "t".into(),
            kernels: vec![k.clone(), k],
        };
        let r = run_workload(&cfg, &wl);
        assert_eq!(r.kernels.len(), 2);
        // Second kernel re-reads the same line: all hits.
        assert!(r.kernels[1].l1_hit_rate > 0.9, "{:?}", r.kernels[1]);
        assert!(r.kernels[1].l1_mean_latency < r.kernels[0].l1_mean_latency);
    }

    #[test]
    fn warm_engine_reports_per_run_deltas() {
        // Regression for per-run delta accounting: running the same
        // workload twice on ONE engine must report each run's own
        // counters (not cumulative totals), with no zero-divisions in the
        // mean latencies, and the deltas must partition the cumulative
        // counters exactly.
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let wl = Workload {
            name: "t".into(),
            kernels: vec![simple_kernel(&cfg, |c| {
                (0..8).map(|k| (c as u64 * 13 + k) % 32).collect()
            })],
        };
        let mut eng = Engine::new(&cfg);
        let r1 = eng.run(&wl).unwrap();
        let r2 = eng.run(&wl).unwrap();
        // Count-based metrics are workload properties — identical runs.
        assert_eq!(r1.insts, r2.insts);
        assert_eq!(r1.l1.accesses, r2.l1.accesses);
        assert_eq!(r1.loads, r2.loads);
        assert!(r1.loads > 0);
        // Deltas partition the engine's cumulative counters.
        assert_eq!(
            eng.l1_stats().accesses,
            r1.l1.accesses + r2.l1.accesses,
            "per-run deltas must sum to the cumulative total"
        );
        let mut merged = r1.contention;
        merged.merge(&r2.contention);
        assert_eq!(
            *eng.contention().total(),
            merged,
            "contention deltas must partition the cumulative breakdown"
        );
        // Timing metrics are per-run: the warm second run cannot be slower
        // than the cold first, and no mean divides by zero.
        assert!(r2.cycles > 0 && r2.cycles <= r1.cycles);
        assert!(r2.l1_mean_load_latency.is_finite() && r2.l1_mean_load_latency >= 1.0);
        assert!(r2.l1_stage_mean_latency.is_finite());
        assert!(r2.l1.local_hits >= r1.l1.local_hits, "warm caches hit more");
        // Determinism: a second engine reproduces both runs bit-identically
        // (including the new contention breakdown).
        let mut eng2 = Engine::new(&cfg);
        let b1 = eng2.run(&wl).unwrap();
        let b2 = eng2.run(&wl).unwrap();
        assert_eq!(r1.cycles, b1.cycles);
        assert_eq!(r2.cycles, b2.cycles);
        assert_eq!(r1.l1_mean_load_latency, b1.l1_mean_load_latency);
        assert_eq!(r2.l1_mean_load_latency, b2.l1_mean_load_latency);
        assert_eq!(r1.contention, b1.contention);
        assert_eq!(r2.contention, b2.contention);
        assert_eq!(r2.l1.local_hits, b2.l1.local_hits);
    }

    #[test]
    fn residency_index_answers_probes_without_changing_results() {
        // The tentpole contract: flipping `sharing.residency_index` moves
        // only wall clock — the result JSON is byte-identical — while the
        // telemetry proves the fast path actually engaged.
        let cfg_on = GpuConfig::tiny(L1ArchKind::Ata);
        let mut cfg_off = cfg_on.clone();
        cfg_off.sharing.residency_index = false;
        let wl = Workload {
            name: "t".into(),
            kernels: vec![
                simple_kernel(&cfg_on, |c| (0..8).map(|k| (c as u64 * 31 + k) % 64).collect()),
                simple_kernel(&cfg_on, |c| (0..8).map(|k| (c as u64 * 17 + k) % 64).collect()),
            ],
        };
        let mut e_on = Engine::new(&cfg_on);
        let r_on = e_on.run(&wl).unwrap();
        let mut e_off = Engine::new(&cfg_off);
        let r_off = e_off.run(&wl).unwrap();
        assert_eq!(
            r_on.to_json().pretty(),
            r_off.to_json().pretty(),
            "simulated metrics must not depend on the residency index"
        );
        let s_on = e_on.residency_stats();
        assert!(s_on.index_probes > 0, "index path must serve ATA probes");
        assert_eq!(s_on.scan_probes, 0);
        assert!(s_on.index_ops > 0 && s_on.peak_lines > 0);
        let s_off = e_off.residency_stats();
        assert_eq!(s_off.index_probes, 0);
        assert!(s_off.scan_probes > 0, "scan path must serve when off");
        assert_eq!(s_off.index_lines, 0, "no index is maintained when off");
    }

    #[test]
    fn event_driven_jumps_without_changing_results() {
        // The tentpole contract: flipping `engine.event_driven` moves only
        // wall clock — the result JSON is byte-identical — while the
        // telemetry proves the event-driven clock actually jumped and the
        // reference clock actually ticked every cycle.
        let cfg_on = GpuConfig::tiny(L1ArchKind::Ata);
        let mut cfg_off = cfg_on.clone();
        cfg_off.engine.event_driven = false;
        let wl = Workload {
            name: "t".into(),
            kernels: vec![
                simple_kernel(&cfg_on, |c| (0..8).map(|k| (c as u64 * 31 + k) % 64).collect()),
                simple_kernel(&cfg_on, |c| (0..8).map(|k| (c as u64 * 17 + k) % 64).collect()),
            ],
        };
        let mut e_on = Engine::new(&cfg_on);
        let r_on = e_on.run(&wl).unwrap();
        let mut e_off = Engine::new(&cfg_off);
        let r_off = e_off.run(&wl).unwrap();
        assert_eq!(
            r_on.to_json().pretty(),
            r_off.to_json().pretty(),
            "simulated metrics must not depend on engine.event_driven"
        );
        let s_on = e_on.event_stats();
        assert_eq!(s_on.cycles_simulated, r_on.cycles, "telemetry covers the run");
        assert!(
            s_on.cycles_ticked < s_on.cycles_simulated,
            "a cold-miss workload must let the clock jump: {s_on:?}"
        );
        assert!(s_on.jumps > 0 && s_on.max_jump > 1);
        let s_off = e_off.event_stats();
        assert_eq!(
            s_off.cycles_ticked, s_off.cycles_simulated,
            "reference mode ticks every cycle: {s_off:?}"
        );
        assert_eq!(s_off.jumps, 0);
        assert_eq!(s_off.skipped(), 0);
    }

    #[test]
    fn sharded_engine_matches_sequential() {
        // The tentpole contract: `engine.shards` moves only wall clock —
        // the result JSON is byte-identical at any shard count — while
        // the telemetry proves the sharded loop actually ran.
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let mut cfg_sh = cfg.clone();
        cfg_sh.engine.shards = 4; // tiny has 2 clusters: clamps to 2
        let wl = Workload {
            name: "t".into(),
            kernels: vec![
                simple_kernel(&cfg, |c| (0..8).map(|k| (c as u64 * 31 + k) % 64).collect()),
                simple_kernel(&cfg, |c| (0..8).map(|k| (c as u64 * 17 + k) % 64).collect()),
            ],
        };
        let mut e_seq = Engine::new(&cfg);
        let r_seq = e_seq.run(&wl).unwrap();
        let mut e_sh = Engine::new(&cfg_sh);
        let r_sh = e_sh.run(&wl).unwrap();
        assert_eq!(
            r_sh.to_json().pretty(),
            r_seq.to_json().pretty(),
            "simulated metrics must not depend on engine.shards"
        );
        assert_eq!(e_seq.shard_stats(), ShardStats::default());
        let s = e_sh.shard_stats();
        assert_eq!(s.shard_count, 2, "tiny GPU clamps 4 shards to its 2 clusters");
        assert!(s.epochs > 0);
        assert!(s.ingress_wakes > 0, "loads must complete through the ingress FIFOs");
        assert!(s.egress_txns > 0, "cold misses must cross into the shared L2 walk");
    }

    #[test]
    fn sharded_multi_matches_sequential() {
        // Co-execution under the sharded loop: lanes keep their own
        // trackers and kernel progression on the coordinator while the
        // shards own the cores — the multi result JSON must stay
        // byte-identical, including per-kernel and per-app attribution.
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let mut cfg_sh = cfg.clone();
        cfg_sh.engine.shards = 2;
        let mk = |salt: u64| {
            lane_kernel(4, move |c| (0..8).map(|k| (salt + c as u64 * 31 + k) % 64).collect())
        };
        let multi = MultiWorkload {
            name: "a+b".into(),
            lanes: vec![
                AppLane {
                    name: "a".into(),
                    kernels: vec![mk(0), mk(5)],
                    partition: CorePartition { first: 0, count: 4 },
                },
                AppLane {
                    name: "b".into(),
                    kernels: vec![mk(17)],
                    partition: CorePartition { first: 4, count: 4 },
                },
            ],
        };
        let r_seq = Engine::new(&cfg).run_multi(&multi).unwrap();
        let mut e_sh = Engine::new(&cfg_sh);
        let r_sh = e_sh.run_multi(&multi).unwrap();
        assert_eq!(
            r_sh.to_json().pretty(),
            r_seq.to_json().pretty(),
            "co-execution must not depend on engine.shards"
        );
        let s = e_sh.shard_stats();
        assert_eq!(s.shard_count, 2);
        assert!(s.epochs > 0 && s.ingress_wakes > 0);
    }

    #[test]
    fn memwalk_engine_matches_serial() {
        // The tentpole contract: `engine.mem_workers` moves only wall
        // clock — the result JSON is byte-identical at any worker count
        // (the pool clamps over-provisioning to the L2 slice count).
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let mut cfg_w = cfg.clone();
        cfg_w.engine.mem_workers = 8; // tiny has 4 L2 slices: clamps to 4
        let wl = Workload {
            name: "t".into(),
            kernels: vec![
                simple_kernel(&cfg, |c| (0..8).map(|k| (c as u64 * 31 + k) % 64).collect()),
                simple_kernel(&cfg, |c| (0..8).map(|k| (c as u64 * 17 + k) % 64).collect()),
            ],
        };
        let mut e_seq = Engine::new(&cfg);
        let r_seq = e_seq.run(&wl).unwrap();
        let r_w = Engine::new(&cfg_w).run(&wl).unwrap();
        assert_eq!(
            r_w.to_json().pretty(),
            r_seq.to_json().pretty(),
            "simulated metrics must not depend on engine.mem_workers"
        );
        // The serial engine keeps the phased epochs but spawns no pool and
        // touches no shard telemetry.
        assert_eq!(e_seq.shard_stats(), ShardStats::default());
    }

    #[test]
    fn memwalk_composes_with_shards() {
        // The two host-parallelism axes stack: sharded clusters feeding a
        // fanned-out slice walk must still match the doubly-serial run.
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let mut cfg_both = cfg.clone();
        cfg_both.engine.shards = 2;
        cfg_both.engine.mem_workers = 3; // uneven split of tiny's 4 slices
        let mk = |salt: u64| {
            lane_kernel(4, move |c| (0..8).map(|k| (salt + c as u64 * 31 + k) % 64).collect())
        };
        let multi = MultiWorkload {
            name: "a+b".into(),
            lanes: vec![
                AppLane {
                    name: "a".into(),
                    kernels: vec![mk(0), mk(5)],
                    partition: CorePartition { first: 0, count: 4 },
                },
                AppLane {
                    name: "b".into(),
                    kernels: vec![mk(17)],
                    partition: CorePartition { first: 4, count: 4 },
                },
            ],
        };
        let r_seq = Engine::new(&cfg).run_multi(&multi).unwrap();
        let mut e_both = Engine::new(&cfg_both);
        let r_both = e_both.run_multi(&multi).unwrap();
        assert_eq!(
            r_both.to_json().pretty(),
            r_seq.to_json().pretty(),
            "shards x mem_workers must not change co-execution metrics"
        );
        let s = e_both.shard_stats();
        assert_eq!(s.shard_count, 2);
        assert!(s.walk_ns > 0, "the sharded loop must time the walk phase");
    }

    #[test]
    fn fast_forward_skips_idle_cycles_without_breaking_ipc() {
        // One warp, one cold load: cycles ≈ miss latency, not 1.
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let wl = Workload {
            name: "t".into(),
            kernels: vec![simple_kernel(&cfg, |c| vec![c as u64 * 1000])],
        };
        let r = run_workload(&cfg, &wl);
        assert!(r.cycles > 100, "a cold DRAM miss takes hundreds of cycles");
        assert!(r.cycles < 100_000, "but the engine must not crawl");
    }

    /// A lane kernel where partition-local core `c` runs one warp loading
    /// `lines(c)` then a short ALU tail.
    fn lane_kernel(cores: usize, lines: impl Fn(usize) -> Vec<u64>) -> KernelSpec {
        KernelSpec {
            name: "k".into(),
            programs: (0..cores)
                .map(|c| {
                    let insts: Vec<WarpInst> = lines(c)
                        .chunks(2)
                        .map(|ch| WarpInst::Load(ch.iter().map(|&l| (l, 0b1111)).collect()))
                        .chain(std::iter::once(WarpInst::Alu(4)))
                        .collect();
                    vec![WarpProgram::new(insts)]
                })
                .collect(),
        }
    }

    #[test]
    fn multi_single_lane_matches_padded_solo_run() {
        // One lane on half the cores must behave exactly like a solo run
        // whose remaining cores are idle: same shared memory system, same
        // request stream, same timing.
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let k = lane_kernel(4, |c| vec![c as u64 * 64, c as u64 * 64 + 1]);
        let multi = MultiWorkload {
            name: "solo".into(),
            lanes: vec![AppLane {
                name: "a".into(),
                kernels: vec![k.clone()],
                partition: CorePartition { first: 0, count: 4 },
            }],
        };
        let mr = Engine::new(&cfg).run_multi(&multi).unwrap();

        let mut padded = k;
        padded.programs.resize(cfg.cores, Vec::new());
        let sr = Engine::new(&cfg)
            .run(&Workload {
                name: "solo".into(),
                kernels: vec![padded],
            })
            .unwrap();
        assert_eq!(mr.cycles, sr.cycles);
        assert_eq!(mr.insts, sr.insts);
        assert_eq!(mr.l1.accesses, sr.l1.accesses);
        assert_eq!(mr.apps[0].finish_cycle, sr.cycles);
        assert_eq!(mr.apps[0].insts, sr.insts);
    }

    #[test]
    fn multi_lanes_advance_kernels_independently() {
        // Lane a: two short kernels. Lane b: one long kernel. Lane a's
        // second kernel must launch while b is still running, and both
        // finish cycles must be attributed separately.
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let short = lane_kernel(4, |c| vec![c as u64 * 8]);
        let long = lane_kernel(4, |c| (0..16).map(|k| 4096 + c as u64 * 100 + k).collect());
        let multi = MultiWorkload {
            name: "a+b".into(),
            lanes: vec![
                AppLane {
                    name: "a".into(),
                    kernels: vec![short.clone(), short],
                    partition: CorePartition { first: 0, count: 4 },
                },
                AppLane {
                    name: "b".into(),
                    kernels: vec![long],
                    partition: CorePartition { first: 4, count: 4 },
                },
            ],
        };
        let r = Engine::new(&cfg).run_multi(&multi).unwrap();
        assert_eq!(r.apps[0].kernels.len(), 2, "lane a ran both kernels");
        assert_eq!(r.apps[1].kernels.len(), 1);
        assert_eq!(
            r.cycles,
            r.apps.iter().map(|a| a.finish_cycle).max().unwrap(),
            "global cycles = last lane's finish"
        );
        // Attribution: requests sum to the shared-L1 access count.
        assert_eq!(
            r.l1.accesses,
            r.apps.iter().map(|a| a.requests).sum::<u64>()
        );
        assert_eq!(r.insts, r.apps.iter().map(|a| a.insts).sum::<u64>());
    }

    #[test]
    fn multi_is_deterministic_across_runs() {
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let mk = |salt: u64| lane_kernel(4, move |c| (0..8).map(|k| (salt + c as u64 * 31 + k) % 64).collect());
        let multi = MultiWorkload {
            name: "a+b".into(),
            lanes: vec![
                AppLane {
                    name: "a".into(),
                    kernels: vec![mk(0)],
                    partition: CorePartition { first: 0, count: 4 },
                },
                AppLane {
                    name: "b".into(),
                    kernels: vec![mk(17)],
                    partition: CorePartition { first: 4, count: 4 },
                },
            ],
        };
        let a = Engine::new(&cfg).run_multi(&multi).unwrap();
        let b = Engine::new(&cfg).run_multi(&multi).unwrap();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.insts, b.insts);
        assert_eq!(a.l1.local_hits, b.l1.local_hits);
        assert_eq!(a.l1.remote_hits, b.l1.remote_hits);
        assert_eq!(a.apps[0].mean_load_latency, b.apps[0].mean_load_latency);
        assert_eq!(a.apps[1].finish_cycle, b.apps[1].finish_cycle);
    }

    #[test]
    fn multi_validate_rejects_bad_shapes() {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let k = lane_kernel(4, |c| vec![c as u64]);
        let lane = |first: usize| AppLane {
            name: "x".into(),
            kernels: vec![k.clone()],
            partition: CorePartition { first, count: 4 },
        };
        // Overlapping partitions:
        let overlap = MultiWorkload {
            name: "m".into(),
            lanes: vec![lane(0), lane(2)],
        };
        assert!(overlap.validate(&cfg).is_err());
        // Out of range:
        let oob = MultiWorkload {
            name: "m".into(),
            lanes: vec![lane(6)],
        };
        assert!(oob.validate(&cfg).is_err());
        // Program count mismatch:
        let mut bad = MultiWorkload {
            name: "m".into(),
            lanes: vec![lane(0)],
        };
        bad.lanes[0].kernels[0].programs.pop();
        assert!(bad.validate(&cfg).is_err());
        // Valid:
        let good = MultiWorkload {
            name: "m".into(),
            lanes: vec![lane(0), lane(4)],
        };
        assert!(good.validate(&cfg).is_ok());
    }

    #[test]
    fn offset_lines_shifts_the_address_space() {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let mut wl = Workload {
            name: "t".into(),
            kernels: vec![simple_kernel(&cfg, |c| vec![c as u64])],
        };
        let before = wl.total_requests();
        wl.offset_lines(1 << 34);
        assert_eq!(wl.total_requests(), before, "offset preserves structure");
        let all_shifted = wl.kernels.iter().flat_map(|k| k.programs.iter().flatten()).all(|p| {
            p.touched_lines().iter().all(|&l| l >= (1 << 34))
        });
        assert!(all_shifted);
    }

    #[test]
    fn hop_stats_reconcile_with_counters() {
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let wl = Workload {
            name: "t".into(),
            kernels: vec![simple_kernel(&cfg, |c| {
                (0..8).map(|k| (c as u64 * 13 + k) % 32).collect()
            })],
        };
        let mut eng = Engine::new(&cfg);
        let r = eng.run(&wl).unwrap();
        // Every access opened exactly one transaction.
        assert_eq!(r.hops.txns, r.l1.accesses);
        assert!(r.hops.mem_trips > 0, "cold run must dispatch misses");
        assert!(
            r.hops.mean_mem_service() > cfg.l2.latency as f64,
            "memory service includes the L2 round trip: {}",
            r.hops.mean_mem_service()
        );
        // The transaction-accumulated queueing is a subset of the per-core
        // ledger (fire-and-forget writebacks never ride a transaction).
        assert!(r.hops.queued.total() <= r.contention.total());
        // Warm second run: per-run hop deltas, no carry-over.
        let r2 = eng.run(&wl).unwrap();
        assert_eq!(r2.hops.txns, r2.l1.accesses);
        assert!(r2.hops.mem_trips < r.hops.mem_trips, "warm caches fetch less");
    }

    #[test]
    fn load_latency_metric_reflects_misses_vs_hits() {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let cold = Workload {
            name: "cold".into(),
            kernels: vec![simple_kernel(&cfg, |c| vec![c as u64 * 50])],
        };
        let r1 = run_workload(&cfg, &cold);
        assert!(
            r1.l1_mean_load_latency > cfg.l2.latency as f64,
            "cold loads include L2+DRAM: {}",
            r1.l1_mean_load_latency
        );
    }

    #[test]
    fn injected_deadlock_returns_typed_error_with_snapshot() {
        let mut cfg = GpuConfig::tiny(L1ArchKind::Private);
        cfg.engine.fault = crate::config::FaultKind::Deadlock;
        let wl = Workload {
            name: "t".into(),
            kernels: vec![simple_kernel(&cfg, |c| vec![c as u64 * 100])],
        };
        let err = Engine::new(&cfg).run(&wl).unwrap_err();
        let SimError::Deadlock(snap) = &err else {
            panic!("expected a deadlock, got {err}");
        };
        assert_eq!(err.kind(), "deadlock");
        assert_eq!(snap.what, "kernel 'k'");
        assert_eq!(snap.cores_total, cfg.cores as u64);
        assert!(snap.cores_blocked >= 1, "the starved warp's core is blocked");
        assert!(snap.next_wake.is_none(), "a deadlock has no pending wakes");

        // The sharded loop detects the same deadlock with a byte-identical
        // snapshot (detection order is pinned across loop variants).
        let mut cfg_sh = cfg.clone();
        cfg_sh.engine.shards = 2;
        let err_sh = Engine::new(&cfg_sh).run(&wl).unwrap_err();
        assert_eq!(err_sh.snapshot(), Some(snap));
    }

    #[test]
    fn injected_livelock_trips_the_forward_progress_watchdog() {
        let mut cfg = GpuConfig::tiny(L1ArchKind::Private);
        cfg.engine.fault = crate::config::FaultKind::Livelock;
        let wl = Workload {
            name: "t".into(),
            kernels: vec![simple_kernel(&cfg, |c| vec![c as u64 * 100])],
        };
        let err = Engine::new(&cfg).run(&wl).unwrap_err();
        let SimError::Livelock { snap, why } = &err else {
            panic!("expected a livelock, got {err}");
        };
        assert!(why.contains("no instruction retired"), "{why}");
        assert!(
            snap.cycle > LIVELOCK_EPOCHS,
            "the clock kept advancing while nothing retired: {}",
            snap.cycle
        );
        assert!(snap.next_wake.is_some(), "phantom wakes keep the heap alive");
        assert!(snap.insts_retired > 0, "warps issued their loads first");
    }

    #[test]
    fn invalid_configs_and_shapes_are_typed_errors() {
        let mut bad = GpuConfig::tiny(L1ArchKind::Private);
        bad.cores = 0;
        let err = Engine::try_new(&bad).unwrap_err();
        assert_eq!(err.kind(), "invalid-config");

        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let mut wl = Workload {
            name: "t".into(),
            kernels: vec![simple_kernel(&cfg, |c| vec![c as u64])],
        };
        wl.kernels[0].programs.pop();
        let err = Engine::new(&cfg).run(&wl).unwrap_err();
        assert_eq!(err.kind(), "invalid-config");
        assert!(err.to_string().contains("core programs"), "{err}");
    }

    #[test]
    fn fault_injection_leaves_clean_runs_untouched() {
        // FaultKind::None must be metric-invisible: the failure knobs can
        // abort a run, never change one that completes.
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let wl = Workload {
            name: "t".into(),
            kernels: vec![simple_kernel(&cfg, |c| {
                (0..8).map(|k| (c as u64 * 31 + k) % 64).collect()
            })],
        };
        let mut with_budget = cfg.clone();
        with_budget.engine.job_timeout_s = 3600;
        let a = run_workload(&cfg, &wl);
        let b = run_workload(&with_budget, &wl);
        assert_eq!(a.to_json().pretty(), b.to_json().pretty());
    }
}
