//! The sharded cycle loop: one big simulation fanned out across host
//! cores, byte-identical at any shard count.
//!
//! `engine.shards > 1` splits the GPU's clusters into contiguous shards.
//! Each shard owns its clusters' cores outright — SIMT issue state, the
//! per-shard wake calendar, and (through cluster alignment) the residency
//! index and remote/ATA probe domain of those clusters — and ticks them
//! on its own host thread.  The shared walk below L1 (NoC → L2 → DRAM and
//! the L1 organization's tag/data state) stays on the coordinator,
//! serialized in the *canonical request order* of the unsharded loop.
//! That split is the `MemTxn` serialization cut: everything up to request
//! creation is core-local and parallel; everything from `l1.access` on is
//! shared and sequential.
//!
//! # Epoch structure
//!
//! One engine-loop iteration (one *epoch*, covering exactly the simulated
//! cycles the unsharded loop would cover in one iteration) runs three
//! phases separated by barriers:
//!
//! 1. **Tick (parallel).**  Every shard delivers its due wakes and ticks
//!    its own cores into per-core issue batches.  `SimtCore::tick` and
//!    `load_complete` touch only core-local state, so shards share
//!    nothing in this phase.
//! 2. **Memory walk (phased).**  The coordinator locks every shard and
//!    replays the per-core batches through the shared L1 organization and
//!    memory system as one phased epoch: the B1 front-end pass and the B3
//!    finish pass run serially in exactly the order the unsharded loop
//!    would have — shard-major == ascending global core id for solo runs,
//!    lane-major (declaration order, then partition order) for
//!    co-execution — while the per-slice walk between them may fan out
//!    across `engine.mem_workers` threads ([`MemSystem::run_walk`]).
//!    Completion wake-ups are routed into the *owning* shard's ingress
//!    FIFO instead of a global calendar.
//! 3. **Drain + horizon (parallel).**  Every shard drains its ingress
//!    FIFO into its local wake heap and computes its next-event horizon —
//!    the min over its own cores' issue hints and its wake calendar, the
//!    per-shard form of the event-driven horizon of PR 6.  The
//!    coordinator reduces the shard horizons to the global one and
//!    advances the clock exactly as [`Engine::advance`] always has.
//!
//! # The three determinism rules
//!
//! Byte-identity of the result JSON at any `--shards` value (the
//! non-negotiable referee, pinned by `rust/tests/shard_determinism.rs`)
//! follows from three rules the implementation never bends:
//!
//! 1. **Shared state mutates in canonical order only.**  `l1.access`,
//!    the trackers, and the Grant/contention ledger run on the
//!    coordinator in the unsharded loop's request order, so request *k*
//!    sees exactly the MSHR/fill/reservation state it would have seen
//!    unsharded, and queued cycles keep attributing to the requesting
//!    core no matter which shard ticked it.
//! 2. **Wakes stay with their owner.**  A completion wake targets the
//!    issuing core, whose shard owns it end to end; per-shard heaps order
//!    ties by the same `(cycle, core, warp)` key as the global calendar,
//!    so delivery order to any single core is unchanged.
//! 3. **Time is reduced, never raced.**  `min` over per-shard horizons
//!    equals the global horizon (every pending wake lives in exactly one
//!    shard), the coordinator alone advances the clock, and the
//!    fixed-boundary sweeps replay on the coordinator at the same
//!    cycles as the unsharded loop.
//!
//! Within one epoch no shard reads another shard's state at all, so the
//! phase-1/phase-3 thread schedule cannot influence any simulated metric
//! — only wall clock.  The serial B1/B3 passes bound the speedup (Amdahl
//! on the request stream); `--mem-workers` attacks exactly that wall by
//! fanning the per-slice walk out of the serial section, and
//! [`Engine::shard_stats`]'s `tick_ns`/`walk_ns` split measures how much
//! of each epoch the wall still eats.  Both knobs stay opt-in (`--shards`
//! and `--mem-workers` default to 1) until a toolchain-equipped session
//! measures the crossover against the barrier cost.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Barrier, Mutex, MutexGuard, PoisonError};
// lint: allow(wall-clock) — per-epoch phase telemetry (ShardStats.tick_ns/walk_ns), stderr-only
use std::time::Instant;

use crate::config::{FaultKind, GpuConfig};
use crate::core::{IssueBatch, SimtCore};
use crate::mem::MemTxn;

use super::{
    horizon_opt, launch_lane, panic_message, Engine, FailSnapshot, KernelSpec, LaneRun,
    MultiWorkload, SimError, DEADLINE_EPOCH_MASK, LIVELOCK_EPOCHS, MAX_KERNEL_CYCLES,
    PHANTOM_WAKE_STRIDE, SWEEP_PERIOD,
};

/// Everything one shard owns: a contiguous range of the GPU's cores (on
/// cluster boundaries), their wake calendar, the ingress FIFO cross-epoch
/// traffic arrives through, and the per-core issue batches the serial
/// memory walk consumes.
struct ShardState {
    /// Global core id of the first owned core.
    first_core: usize,
    /// Owned cores, indexed by `global - first_core`.  `None` = the core
    /// is idle this run (unassigned, or its lane finished) — exactly the
    /// cores the unsharded loop would not tick.
    cores: Vec<Option<SimtCore>>,
    /// Per-shard wake calendar, ordered by the same `(cycle, core, warp)`
    /// key as the unsharded engine's global calendar.
    wakes: BinaryHeap<Reverse<(u64, u32, u32)>>,
    /// Completion wakes routed here by the serial memory walk; drained
    /// into `wakes` at the epoch barrier (phase 3).
    ingress: Vec<(u64, u32, u32)>,
    /// One issue batch per owned core slot, refilled every epoch.
    batches: Vec<IssueBatch>,
    /// Per-shard next-event horizon computed in phase 3: min over the
    /// owned cores' issue hints and the local wake calendar.
    horizon: u64,
    /// `FaultKind::Livelock` is armed for this run: due wakes bounce
    /// forward instead of being delivered (mirrors the sequential loops'
    /// injection site, which is also wake delivery).
    livelock: bool,
}

impl ShardState {
    /// Phase 1: deliver due wakes to the owning cores, then tick every
    /// owned core into its per-core batch.  Touches only shard-local
    /// state (rule 2: wakes stay with their owner).
    fn tick_epoch(&mut self, now: u64) {
        while let Some(&Reverse((t, core, warp))) = self.wakes.peek() {
            if t > now {
                break;
            }
            self.wakes.pop();
            if self.livelock {
                // Injected livelock: bounce the wake forward forever
                // instead of delivering it.
                self.wakes.push(Reverse((now + PHANTOM_WAKE_STRIDE, core, warp)));
                continue;
            }
            self.cores[core as usize - self.first_core]
                .as_mut()
                // lint: allow(sim-panic) — ownership invariant (rule 2); a violation is a bug, contained by the worker's catch_unwind
                .expect("wake delivered to a vacant core slot")
                .load_complete(warp, t);
        }
        for (slot, batch) in self.cores.iter_mut().zip(self.batches.iter_mut()) {
            batch.requests.clear();
            batch.insts_issued = 0;
            if let Some(core) = slot.as_mut() {
                core.tick(now, batch);
            }
        }
    }

    /// Phase 3: absorb the ingress FIFO into the wake calendar and
    /// compute this shard's next-event horizon.
    fn drain_and_horizon(&mut self) {
        for wake in self.ingress.drain(..) {
            self.wakes.push(Reverse(wake));
        }
        let next_ready = self
            .cores
            .iter()
            .flatten()
            .map(SimtCore::next_event_hint)
            .min()
            .unwrap_or(u64::MAX);
        let next_wake = self.wakes.peek().map(|Reverse((t, _, _))| *t).unwrap_or(u64::MAX);
        self.horizon = next_ready.min(next_wake);
    }

    /// All owned cores finished (vacant slots count as done, mirroring
    /// the unsharded loop, which simply has no such core to tick).
    fn all_done(&self) -> bool {
        self.cores.iter().flatten().all(SimtCore::all_done)
    }
}

/// Split `cfg.cores` (as `slots`, indexed by global core id) into
/// `n_shards` cluster-aligned shards: shard `i` owns a contiguous run of
/// `clusters / n_shards` clusters, the remainder going one each to the
/// leading shards.  Shard-major core order therefore equals ascending
/// global core order — the canonical solo order for free.
fn build_shards(
    slots: Vec<Option<SimtCore>>,
    cfg: &GpuConfig,
    n_shards: usize,
) -> Vec<Mutex<ShardState>> {
    debug_assert!((2..=cfg.clusters).contains(&n_shards));
    debug_assert_eq!(slots.len(), cfg.cores);
    let cpc = cfg.cores_per_cluster();
    let base = cfg.clusters / n_shards;
    let rem = cfg.clusters % n_shards;
    let mut slots = slots.into_iter();
    let mut first_cluster = 0;
    (0..n_shards)
        .map(|i| {
            let n_clusters = base + usize::from(i < rem);
            let n_cores = n_clusters * cpc;
            let first_core = first_cluster * cpc;
            first_cluster += n_clusters;
            ShardState {
                first_core,
                cores: slots.by_ref().take(n_cores).collect(),
                wakes: BinaryHeap::new(),
                ingress: Vec::new(),
                batches: (0..n_cores).map(|_| IssueBatch::default()).collect(),
                horizon: u64::MAX,
                livelock: cfg.engine.fault == FaultKind::Livelock,
            }
        })
        .map(Mutex::new)
        .collect()
}

/// Global core id → `(shard index, shard-local slot)` for every core,
/// derived from the same split as [`build_shards`].
fn core_locations(shards: &[Mutex<ShardState>], cores: usize) -> Vec<(usize, usize)> {
    let mut loc = vec![(usize::MAX, usize::MAX); cores];
    for (si, sh) in shards.iter().enumerate() {
        let sh = lock_clean(sh);
        for local in 0..sh.cores.len() {
            loc[sh.first_core + local] = (si, local);
        }
    }
    loc
}

/// Lock a shard, recovering from poison: a panicking phase body is
/// contained (`catch_unwind`) and reported as [`SimError::WorkerPanic`],
/// after which the shard state is only read for teardown — the poison
/// flag carries no information the failure record doesn't.
fn lock_clean(m: &Mutex<ShardState>) -> MutexGuard<'_, ShardState> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// First-failure latch for panics contained in any phase body, worker or
/// coordinator.  Only the first recorded failure is reported (a second
/// panic is almost always a casualty of the first).
struct WorkerFailure {
    hit: AtomicBool,
    message: Mutex<Option<(String, String)>>,
}

impl WorkerFailure {
    fn new() -> Self {
        WorkerFailure {
            hit: AtomicBool::new(false),
            message: Mutex::new(None),
        }
    }

    fn record(&self, what: &str, payload: &(dyn std::any::Any + Send)) {
        let mut slot = self.message.lock().unwrap_or_else(PoisonError::into_inner);
        if slot.is_none() {
            *slot = Some((what.to_string(), panic_message(payload)));
        }
        drop(slot);
        self.hit.store(true, Ordering::Release);
    }

    fn take(&self) -> SimError {
        let slot = self.message.lock().unwrap_or_else(PoisonError::into_inner);
        let (what, message) = slot
            .clone()
            .unwrap_or_else(|| ("shard worker".to_string(), "unrecorded failure".to_string()));
        SimError::WorkerPanic { what, message }
    }
}

/// The worker side of the barrier choreography.  Four waits per epoch:
/// tick-go (shutdown checked), tick-done, drain-go (shutdown checked),
/// drain-done.  The coordinator owns shard 0 and participates in every
/// wait, so the barrier counts `n_shards` threads total.
///
/// Containment: each phase body runs under `catch_unwind`, so a panic in
/// one shard's tick or drain never unwinds across the barrier — the
/// worker records the failure, keeps honoring the barrier cadence (work
/// skipped), and exits through the normal stop-flag path once the
/// coordinator notices and shuts the epoch down.
fn worker(
    shard: &Mutex<ShardState>,
    barrier: &Barrier,
    stop: &AtomicBool,
    clock: &AtomicU64,
    failed: &WorkerFailure,
) {
    loop {
        barrier.wait(); // tick-go
        if stop.load(Ordering::Acquire) {
            return;
        }
        if !failed.hit.load(Ordering::Acquire) {
            let now = clock.load(Ordering::Acquire);
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| lock_clean(shard).tick_epoch(now))) {
                failed.record("shard worker (tick)", p.as_ref());
            }
        }
        barrier.wait(); // tick-done; the coordinator runs the serial walk
        barrier.wait(); // drain-go
        if stop.load(Ordering::Acquire) {
            return;
        }
        if !failed.hit.load(Ordering::Acquire) {
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| lock_clean(shard).drain_and_horizon()))
            {
                failed.record("shard worker (drain)", p.as_ref());
            }
        }
        barrier.wait(); // drain-done
    }
}

/// Lock every shard in shard-major order for the serial phase.  The
/// workers are parked on the drain-go barrier, so the locks are
/// uncontended; they exist to satisfy the borrow checker across the
/// scoped-thread boundary, not to arbitrate.
fn lock_all<'a>(shards: &'a [Mutex<ShardState>]) -> Vec<MutexGuard<'a, ShardState>> {
    shards.iter().map(lock_clean).collect()
}

/// Diagnostic snapshot over the per-shard slots — field-for-field the
/// same picture `Engine::snapshot` takes of the sequential loops' cores,
/// at the same detection point of the same epoch, so a failing run
/// serializes identically at any `--shards` setting.
fn snapshot(
    eng: &Engine,
    shards: &[Mutex<ShardState>],
    what: String,
    now: u64,
) -> FailSnapshot {
    let mut cores_total = 0;
    let mut cores_blocked = 0;
    let mut wake_depth = 0;
    let mut next_core = u64::MAX;
    let mut next_wake = u64::MAX;
    for m in shards {
        let g = lock_clean(m);
        for core in g.cores.iter().flatten() {
            cores_total += 1;
            if !core.all_done() {
                cores_blocked += 1;
            }
            next_core = next_core.min(core.next_event_hint());
        }
        wake_depth += g.wakes.len() as u64;
        if let Some(Reverse((t, _, _))) = g.wakes.peek() {
            next_wake = next_wake.min(*t);
        }
    }
    FailSnapshot {
        what,
        cycle: now,
        cores_total,
        cores_blocked,
        insts_retired: eng.total_insts,
        wake_depth,
        next_core_event: horizon_opt(next_core),
        next_wake: horizon_opt(next_wake),
        mem_horizon: eng.mem.next_event(now),
    }
}

/// Release the workers into shutdown: they re-check `stop` right after
/// the next barrier they are parked on.
fn release_and_stop(barrier: &Barrier, stop: &AtomicBool) {
    stop.store(true, Ordering::Release);
    barrier.wait();
}

/// The sharded replacement for [`Engine::run_kernel`]'s cycle loop
/// (solo mode).  Entered with freshly launched `cores` for every global
/// core; leaves the engine in exactly the state the unsharded loop would:
/// clock at the kernel's finish cycle, trackers/L1/memory/hops advanced
/// by the same request stream in the same order.
pub(super) fn kernel_loop(
    eng: &mut Engine,
    spec: &KernelSpec,
    cores: Vec<SimtCore>,
    n_shards: usize,
) -> Result<(), SimError> {
    let start_cycle = eng.cycle;
    let shards = build_shards(cores.into_iter().map(Some).collect(), &eng.cfg, n_shards);
    eng.shard_stats.shard_count = n_shards as u64;
    let barrier = Barrier::new(n_shards);
    let stop = AtomicBool::new(false);
    let clock = AtomicU64::new(eng.cycle);
    let failed = WorkerFailure::new();
    let mut last_sweep = eng.cycle;
    let mut open: Vec<(usize, MemTxn, u32)> = Vec::new();
    let mut stuck_epochs: u64 = 0;
    let mut last_insts = eng.total_insts;
    let mut epoch: u64 = 0;

    let run = std::thread::scope(|s| -> Result<(), SimError> { // lint: allow(shard-confinement) — the shard module's own worker fan-out
        for sh in shards.iter().skip(1) {
            let (barrier, stop, clock, failed) = (&barrier, &stop, &clock, &failed);
            s.spawn(move || worker(sh, barrier, stop, clock, failed));
        }
        loop {
            let now = eng.cycle;
            clock.store(now, Ordering::Release);
            let t_tick = Instant::now(); // lint: allow(wall-clock) — stderr-only phase telemetry (ShardStats)
            barrier.wait(); // tick-go
            if let Err(p) =
                catch_unwind(AssertUnwindSafe(|| lock_clean(&shards[0]).tick_epoch(now)))
            {
                failed.record("shard coordinator (tick)", p.as_ref());
            }
            barrier.wait(); // tick-done
            eng.shard_stats.tick_ns += t_tick.elapsed().as_nanos() as u64;
            if failed.hit.load(Ordering::Acquire) {
                release_and_stop(&barrier, &stop); // workers park next at drain-go
                return Err(failed.take());
            }

            // Memory walk as one phased epoch — rule 1: shared state
            // mutates in canonical (ascending global core) order.  The B1
            // front end and B3 finish run here on the coordinator; only
            // the per-slice walk between them fans out (`mem_workers`).
            // The whole phase is contained: a panic anywhere in the walk
            // becomes a WorkerPanic through the stop-flag shutdown, never
            // an unwind across the barrier that would hang the workers.
            let t_walk = Instant::now(); // lint: allow(wall-clock) — stderr-only phase telemetry (ShardStats)
            let walk = catch_unwind(AssertUnwindSafe(|| -> Result<bool, SimError> {
                let mut guards = lock_all(&shards);
                eng.mem.begin_epoch();
                open.clear();
                let mut prev_group: Option<(u32, u32, u64)> = None;
                for (si, g) in guards.iter().enumerate() {
                    for batch in g.batches.iter() {
                        eng.total_insts += batch.insts_issued;
                        for (req, group_n) in batch.requests.iter() {
                            if *group_n > 0 {
                                let key = (req.core, req.warp, req.inst);
                                if prev_group != Some(key) {
                                    eng.tracker.issue(req.core, req.warp, req.inst, *group_n, now);
                                    eng.stage_tracker
                                        .issue(req.core, req.warp, req.inst, *group_n, now);
                                    prev_group = Some(key);
                                }
                            }
                            let mut txn = MemTxn::new(*req, now);
                            eng.l1.access(&mut txn, &mut eng.mem);
                            open.push((si, txn, *group_n));
                        }
                    }
                }
                eng.mem.run_walk()?;
                for (si, mut txn, group_n) in open.drain(..) {
                    eng.l1.finish(&mut txn, &mut eng.mem);
                    eng.hops.record(&txn.hops, &txn.queued);
                    if txn.hops.l2_dispatch > 0 {
                        eng.shard_stats.egress_txns += 1;
                    }
                    if group_n > 0 {
                        let (core, warp, inst) = (txn.req.core, txn.req.warp, txn.req.inst);
                        eng.stage_tracker.complete_one(core, warp, inst, txn.l1_stage_done());
                        if let Some(load_done) =
                            eng.tracker.complete_one(core, warp, inst, txn.done())
                        {
                            if eng.fault_deadlock_armed {
                                // Injected deadlock: swallow the first
                                // completion wake (canonical order makes
                                // it the same wake the sequential loop
                                // swallows); its warp blocks forever.
                                eng.fault_deadlock_armed = false;
                            } else {
                                // Rule 2: the wake returns to the issuing
                                // core's own shard, via its ingress FIFO.
                                guards[si].ingress.push((load_done.max(now + 1), core, warp));
                                eng.shard_stats.ingress_wakes += 1;
                            }
                        }
                    }
                }
                eng.mem.end_epoch();
                Ok(guards.iter().all(|g| g.all_done()))
            }));
            eng.shard_stats.epochs += 1;
            eng.shard_stats.walk_ns += t_walk.elapsed().as_nanos() as u64;
            let finished = match walk {
                Ok(Ok(done)) => done,
                Ok(Err(e)) => {
                    release_and_stop(&barrier, &stop); // workers park next at drain-go
                    return Err(e);
                }
                Err(p) => {
                    failed.record("shard coordinator (memory walk)", p.as_ref());
                    release_and_stop(&barrier, &stop); // workers park next at drain-go
                    return Err(failed.take());
                }
            };

            if finished {
                release_and_stop(&barrier, &stop); // drain-go doubles as shutdown
                return Ok(());
            }
            barrier.wait(); // drain-go
            if let Err(p) =
                catch_unwind(AssertUnwindSafe(|| lock_clean(&shards[0]).drain_and_horizon()))
            {
                failed.record("shard coordinator (drain)", p.as_ref());
            }
            barrier.wait(); // drain-done
            if failed.hit.load(Ordering::Acquire) {
                release_and_stop(&barrier, &stop); // workers park next at tick-go
                return Err(failed.take());
            }

            // Rule 3: time is reduced, never raced — min over per-shard
            // horizons equals the unsharded global horizon.
            let horizon = shards
                .iter()
                .map(|m| lock_clean(m).horizon)
                .min()
                .unwrap_or(u64::MAX);
            if horizon == u64::MAX {
                let snap = snapshot(eng, &shards, format!("kernel '{}'", spec.name), now);
                release_and_stop(&barrier, &stop); // park point is tick-go
                return Err(SimError::Deadlock(snap));
            }
            // Forward-progress watchdog — identical detection order to the
            // sequential loop, so snapshots match at any shard count.
            if eng.total_insts == last_insts {
                stuck_epochs += 1;
                if stuck_epochs >= LIVELOCK_EPOCHS {
                    let snap = snapshot(eng, &shards, format!("kernel '{}'", spec.name), now);
                    release_and_stop(&barrier, &stop); // park point is tick-go
                    return Err(SimError::Livelock {
                        snap,
                        why: format!(
                            "no instruction retired for {LIVELOCK_EPOCHS} consecutive epochs"
                        ),
                    });
                }
            } else {
                last_insts = eng.total_insts;
                stuck_epochs = 0;
            }
            eng.advance(now, horizon);
            while eng.cycle - last_sweep >= SWEEP_PERIOD {
                last_sweep += SWEEP_PERIOD;
                eng.l1.sweep(last_sweep);
                eng.mem.sweep_in_flight(last_sweep);
            }
            if eng.cycle - start_cycle > MAX_KERNEL_CYCLES {
                let snap = snapshot(eng, &shards, format!("kernel '{}'", spec.name), eng.cycle);
                release_and_stop(&barrier, &stop); // park point is tick-go
                return Err(SimError::Livelock {
                    snap,
                    why: format!("exceeded the {MAX_KERNEL_CYCLES}-cycle safety valve"),
                });
            }
            epoch += 1;
            if epoch & DEADLINE_EPOCH_MASK == 0 && eng.host_budget_expired() {
                release_and_stop(&barrier, &stop); // park point is tick-go
                return Err(eng.host_timeout(format!("kernel '{}'", spec.name)));
            }
        }
    });
    if run.is_ok() {
        debug_assert!(shards.iter().all(|m| {
            let g = lock_clean(m);
            g.wakes.is_empty() && g.ingress.is_empty()
        }));
    }
    run
}

/// The sharded replacement for [`Engine::run_multi`]'s cycle loop.  Lane
/// bookkeeping (trackers, kernel progression, per-lane attribution) stays
/// on the coordinator; only core ownership moves into the shards.  Cores
/// are stored in global slots so lanes may span shard boundaries freely —
/// the serial walk reconstructs the unsharded loop's lane-major request
/// order from the per-core batches.
pub(super) fn multi_loop(
    eng: &mut Engine,
    multi: &MultiWorkload,
    lanes: &mut [LaneRun],
    start_cycle: u64,
    max_cycles: u64,
    n_shards: usize,
) -> Result<(), SimError> {
    // Move every lane's cores into global slots (lane.cores stays empty
    // for the rest of the run, exactly like a finished lane's would).
    let mut slots: Vec<Option<SimtCore>> = (0..eng.cfg.cores).map(|_| None).collect();
    for (li, lane) in lanes.iter_mut().enumerate() {
        let partition = multi.lanes[li].partition;
        for (j, core) in lane.cores.drain(..).enumerate() {
            slots[partition.global(j)] = Some(core);
        }
    }
    let shards = build_shards(slots, &eng.cfg, n_shards);
    let loc = core_locations(&shards, eng.cfg.cores);
    eng.shard_stats.shard_count = n_shards as u64;
    let barrier = Barrier::new(n_shards);
    let stop = AtomicBool::new(false);
    let clock = AtomicU64::new(eng.cycle);
    let failed = WorkerFailure::new();
    let mut last_sweep = eng.cycle;
    let mut open: Vec<(usize, usize, MemTxn, u32)> = Vec::new();
    let mut stuck_epochs: u64 = 0;
    let mut last_insts = eng.total_insts;
    let mut epoch: u64 = 0;

    let run = std::thread::scope(|s| -> Result<(), SimError> { // lint: allow(shard-confinement) — the shard module's own worker fan-out
        for sh in shards.iter().skip(1) {
            let (barrier, stop, clock, failed) = (&barrier, &stop, &clock, &failed);
            s.spawn(move || worker(sh, barrier, stop, clock, failed));
        }
        loop {
            let now = eng.cycle;
            clock.store(now, Ordering::Release);
            let t_tick = Instant::now(); // lint: allow(wall-clock) — stderr-only phase telemetry (ShardStats)
            barrier.wait(); // tick-go
            if let Err(p) =
                catch_unwind(AssertUnwindSafe(|| lock_clean(&shards[0]).tick_epoch(now)))
            {
                failed.record("shard coordinator (tick)", p.as_ref());
            }
            barrier.wait(); // tick-done
            eng.shard_stats.tick_ns += t_tick.elapsed().as_nanos() as u64;
            if failed.hit.load(Ordering::Acquire) {
                release_and_stop(&barrier, &stop); // workers park next at drain-go
                return Err(failed.take());
            }

            // The whole serial phase (attribution, walk, lane completion)
            // is contained — see kernel_loop for the shutdown choreography.
            let t_walk = Instant::now(); // lint: allow(wall-clock) — stderr-only phase telemetry (ShardStats)
            let walk = catch_unwind(AssertUnwindSafe(|| -> Result<bool, SimError> {
            let mut guards = lock_all(&shards);

            // Attribute issued instructions per lane (the unsharded loop
            // tallies them during the tick; the totals are identical).
            for (li, lane) in lanes.iter_mut().enumerate() {
                if lane.done {
                    continue;
                }
                let partition = multi.lanes[li].partition;
                for j in 0..partition.count {
                    let (si, local) = loc[partition.global(j)];
                    let issued = guards[si].batches[local].insts_issued;
                    lane.insts += issued;
                    eng.total_insts += issued;
                }
            }

            // Memory walk as one phased epoch, in canonical lane-major
            // order: lanes in declaration order, cores in partition
            // order, requests in issue order — byte-for-byte the
            // unsharded request stream through both the B1 front end and
            // the B3 finish pass.
            eng.mem.begin_epoch();
            open.clear();
            let mut prev_group: Option<(u32, u32, u64)> = None;
            for (li, lane) in lanes.iter_mut().enumerate() {
                if lane.done {
                    continue;
                }
                let partition = multi.lanes[li].partition;
                for j in 0..partition.count {
                    let (si, local) = loc[partition.global(j)];
                    for (req, group_n) in guards[si].batches[local].requests.iter() {
                        lane.requests += 1;
                        if *group_n > 0 {
                            let key = (req.core, req.warp, req.inst);
                            if prev_group != Some(key) {
                                lane.tracker.issue(req.core, req.warp, req.inst, *group_n, now);
                                lane.stage_tracker
                                    .issue(req.core, req.warp, req.inst, *group_n, now);
                                prev_group = Some(key);
                            }
                        }
                        let mut txn = MemTxn::new(*req, now);
                        eng.l1.access(&mut txn, &mut eng.mem);
                        open.push((li, si, txn, *group_n));
                    }
                }
            }
            eng.mem.run_walk()?;
            for (li, si, mut txn, group_n) in open.drain(..) {
                eng.l1.finish(&mut txn, &mut eng.mem);
                eng.hops.record(&txn.hops, &txn.queued);
                if txn.hops.l2_dispatch > 0 {
                    eng.shard_stats.egress_txns += 1;
                }
                if group_n > 0 {
                    let lane = &mut lanes[li];
                    let (core, warp, inst) = (txn.req.core, txn.req.warp, txn.req.inst);
                    lane.stage_tracker.complete_one(core, warp, inst, txn.l1_stage_done());
                    if let Some(load_done) = lane.tracker.complete_one(core, warp, inst, txn.done())
                    {
                        if eng.fault_deadlock_armed {
                            // Injected deadlock: swallow the first
                            // completion wake (same wake as the sequential
                            // loop — canonical order); its warp blocks
                            // forever.
                            eng.fault_deadlock_armed = false;
                        } else {
                            guards[si].ingress.push((load_done.max(now + 1), core, warp));
                            eng.shard_stats.ingress_wakes += 1;
                        }
                    }
                }
            }
            eng.mem.end_epoch();

            // Kernel completion per lane, in declaration order — the
            // coordinator owns relaunch, so new cores appear in their
            // shard's slots before the horizon phase reads them.
            for (li, lane) in lanes.iter_mut().enumerate() {
                let partition = multi.lanes[li].partition;
                let lane_done = |guards: &[MutexGuard<ShardState>]| {
                    (0..partition.count).all(|j| {
                        let (si, local) = loc[partition.global(j)];
                        guards[si].cores[local]
                            .as_ref()
                            // lint: allow(sim-panic) — ownership invariant; a violation is a bug, contained by the coordinator's catch_unwind
                            .expect("active lane core slot vacated")
                            .all_done()
                    })
                };
                if lane.done || !lane_done(&guards) {
                    continue;
                }
                let spec = &multi.lanes[li].kernels[lane.kernel_idx];
                lane.finish_kernel(spec, now);
                lane.kernel_idx += 1;
                if lane.kernel_idx < multi.lanes[li].kernels.len() {
                    let fresh = launch_lane(&multi.lanes[li], lane.kernel_idx, &eng.cfg);
                    for (j, core) in fresh.into_iter().enumerate() {
                        let (si, local) = loc[partition.global(j)];
                        guards[si].cores[local] = Some(core);
                    }
                    lane.begin_kernel(now);
                } else {
                    lane.done = true;
                    lane.finish_cycle = now - start_cycle;
                    for j in 0..partition.count {
                        let (si, local) = loc[partition.global(j)];
                        guards[si].cores[local] = None;
                    }
                }
            }

            Ok(lanes.iter().all(|l| l.done))
            }));
            eng.shard_stats.epochs += 1;
            eng.shard_stats.walk_ns += t_walk.elapsed().as_nanos() as u64;
            let finished = match walk {
                Ok(Ok(done)) => done,
                Ok(Err(e)) => {
                    release_and_stop(&barrier, &stop); // workers park next at drain-go
                    return Err(e);
                }
                Err(p) => {
                    failed.record("shard coordinator (memory walk)", p.as_ref());
                    release_and_stop(&barrier, &stop); // workers park next at drain-go
                    return Err(failed.take());
                }
            };

            if finished {
                release_and_stop(&barrier, &stop); // drain-go doubles as shutdown
                return Ok(());
            }
            barrier.wait(); // drain-go
            if let Err(p) =
                catch_unwind(AssertUnwindSafe(|| lock_clean(&shards[0]).drain_and_horizon()))
            {
                failed.record("shard coordinator (drain)", p.as_ref());
            }
            barrier.wait(); // drain-done
            if failed.hit.load(Ordering::Acquire) {
                release_and_stop(&barrier, &stop); // workers park next at tick-go
                return Err(failed.take());
            }

            let horizon = shards
                .iter()
                .map(|m| lock_clean(m).horizon)
                .min()
                .unwrap_or(u64::MAX);
            if horizon == u64::MAX {
                let snap =
                    snapshot(eng, &shards, format!("co-execution '{}'", multi.name), now);
                release_and_stop(&barrier, &stop); // park point is tick-go
                return Err(SimError::Deadlock(snap));
            }
            // Forward-progress watchdog — identical detection order to the
            // sequential loop, so snapshots match at any shard count.
            if eng.total_insts == last_insts {
                stuck_epochs += 1;
                if stuck_epochs >= LIVELOCK_EPOCHS {
                    let snap =
                        snapshot(eng, &shards, format!("co-execution '{}'", multi.name), now);
                    release_and_stop(&barrier, &stop); // park point is tick-go
                    return Err(SimError::Livelock {
                        snap,
                        why: format!(
                            "no instruction retired for {LIVELOCK_EPOCHS} consecutive epochs"
                        ),
                    });
                }
            } else {
                last_insts = eng.total_insts;
                stuck_epochs = 0;
            }
            eng.advance(now, horizon);
            while eng.cycle - last_sweep >= SWEEP_PERIOD {
                last_sweep += SWEEP_PERIOD;
                eng.l1.sweep(last_sweep);
                eng.mem.sweep_in_flight(last_sweep);
            }
            if eng.cycle - start_cycle > max_cycles {
                let snap = snapshot(
                    eng,
                    &shards,
                    format!("co-execution '{}'", multi.name),
                    eng.cycle,
                );
                release_and_stop(&barrier, &stop); // park point is tick-go
                return Err(SimError::Livelock {
                    snap,
                    why: format!("exceeded the {max_cycles}-cycle safety valve"),
                });
            }
            epoch += 1;
            if epoch & DEADLINE_EPOCH_MASK == 0 && eng.host_budget_expired() {
                release_and_stop(&barrier, &stop); // park point is tick-go
                return Err(eng.host_timeout(format!("co-execution '{}'", multi.name)));
            }
        }
    });
    if run.is_ok() {
        debug_assert!(shards.iter().all(|m| {
            let g = lock_clean(m);
            g.wakes.is_empty() && g.ingress.is_empty()
        }));
    }
    run
}
