//! Typed simulation failures and their deterministic diagnostic snapshot.
//!
//! A pathological configuration must surface as **data**, not kill the
//! process: `Engine::run`/`run_multi` return `Result<_, SimError>` and the
//! execution layer ([`crate::exec`]) converts an `Err` into a
//! `JobOutput::Failed` slot that serializes into the result JSON's
//! `failures` array.  The snapshot is a pure function of the simulated
//! state at the moment the failure was detected, so the failure path
//! inherits the repo's byte-identity contract: the same error for the
//! same job serializes identically at any `--threads`/`--shards`/
//! `--mem-workers` (deterministic failures under parallel execution are
//! re-derived by the serial degradation retry — see
//! `exec::JobRunner::run_grid`).
//!
//! The one deliberately non-deterministic variant is
//! [`SimError::HostTimeout`]: it fires on the host wall clock
//! (`--job-timeout-s`, opt-in, default off), so its presence depends on
//! the machine.  Everything else is simulated-state-only.

use crate::util::json::Json;

/// A deterministic picture of the simulation at the moment a failure was
/// detected.  Every field is derived from simulated state (never host
/// state), so two runs of the same job produce byte-identical snapshots.
///
/// The horizon fields answer "what was the engine waiting for": the
/// earliest core issue hint, the earliest pending wake, and the earliest
/// busy interval anywhere in the memory system (via the `next_event(now)`
/// accessors every resource grew in PR 6).  `None` serializes as `null`
/// and means "no such event exists" (e.g. at a true deadlock every
/// horizon is `null` — that absence *is* the diagnosis).
#[derive(Debug, Clone, PartialEq)]
pub struct FailSnapshot {
    /// What was running: `"kernel 'k'"` or `"co-execution 'a+b'"`.
    pub what: String,
    /// Simulated cycle at detection.
    pub cycle: u64,
    /// Cores participating in the run (active lanes only, co-execution).
    pub cores_total: u64,
    /// Cores that still have unfinished warps — the blocked set.
    pub cores_blocked: u64,
    /// Instructions retired by the engine up to detection.
    pub insts_retired: u64,
    /// Pending entries across the wake calendar(s).
    pub wake_depth: u64,
    /// Earliest core issue hint, if any core can ever issue again.
    pub next_core_event: Option<u64>,
    /// Earliest pending wake, if the calendar is non-empty.
    pub next_wake: Option<u64>,
    /// Earliest busy interval in the memory system (NoC/L2/DRAM), if any.
    pub mem_horizon: Option<u64>,
}

fn opt_u64_json(v: Option<u64>) -> Json {
    match v {
        Some(x) => x.into(),
        None => Json::Null,
    }
}

fn opt_u64_from(j: &Json, key: &str) -> Option<u64> {
    j.get(key).and_then(Json::as_u64)
}

impl FailSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("what", self.what.as_str().into()),
            ("cycle", self.cycle.into()),
            ("cores_total", self.cores_total.into()),
            ("cores_blocked", self.cores_blocked.into()),
            ("insts_retired", self.insts_retired.into()),
            ("wake_depth", self.wake_depth.into()),
            ("next_core_event", opt_u64_json(self.next_core_event)),
            ("next_wake", opt_u64_json(self.next_wake)),
            ("mem_horizon", opt_u64_json(self.mem_horizon)),
        ])
    }

    /// Lenient inverse of [`to_json`](Self::to_json): absent numeric
    /// fields default to zero, absent horizons to `None`, so a manifest
    /// from an older build still loads.
    pub fn from_json(j: &Json) -> FailSnapshot {
        let num = |k: &str| j.get(k).and_then(Json::as_u64).unwrap_or(0);
        FailSnapshot {
            what: j.get("what").and_then(Json::as_str).unwrap_or_default().to_string(),
            cycle: num("cycle"),
            cores_total: num("cores_total"),
            cores_blocked: num("cores_blocked"),
            insts_retired: num("insts_retired"),
            wake_depth: num("wake_depth"),
            next_core_event: opt_u64_from(j, "next_core_event"),
            next_wake: opt_u64_from(j, "next_wake"),
            mem_horizon: opt_u64_from(j, "mem_horizon"),
        }
    }
}

/// Why a simulation run could not complete.  Returned by
/// `Engine::run`/`run_multi`; never panicked out of the engine.
#[derive(Debug, Clone, PartialEq)]
pub enum SimError {
    /// No core can ever issue again and no wake is pending: the
    /// next-event horizon is `u64::MAX`.
    Deadlock(FailSnapshot),
    /// The clock is advancing but nothing retires: either the
    /// forward-progress watchdog fired (`why` names the epoch budget) or
    /// the run blew through the cycle safety valve.
    Livelock { snap: FailSnapshot, why: String },
    /// A host worker thread (shard worker, mem-walk worker, or the shard
    /// coordinator's own epoch body) panicked; the panic was contained
    /// at the stop-flag boundary instead of unwinding the process.
    WorkerPanic { what: String, message: String },
    /// The configuration or workload failed validation.
    InvalidConfig(String),
    /// The opt-in host wall-clock budget (`--job-timeout-s`) expired.
    /// Inherently host-dependent — the only non-deterministic variant.
    HostTimeout { what: String, seconds: u64 },
}

impl SimError {
    /// Stable machine-readable failure class (the `kind` field of a
    /// serialized `JobError`).
    pub fn kind(&self) -> &'static str {
        match self {
            SimError::Deadlock(_) => "deadlock",
            SimError::Livelock { .. } => "livelock",
            SimError::WorkerPanic { .. } => "worker-panic",
            SimError::InvalidConfig(_) => "invalid-config",
            SimError::HostTimeout { .. } => "host-timeout",
        }
    }

    /// The diagnostic snapshot, for the variants that carry one.
    pub fn snapshot(&self) -> Option<&FailSnapshot> {
        match self {
            SimError::Deadlock(s) => Some(s),
            SimError::Livelock { snap, .. } => Some(snap),
            _ => None,
        }
    }
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Deadlock(s) => write!(
                f,
                "{} deadlocked at cycle {}: no ready warps, no wakes",
                s.what, s.cycle
            ),
            SimError::Livelock { snap, why } => {
                write!(f, "{} livelocked at cycle {}: {}", snap.what, snap.cycle, why)
            }
            SimError::WorkerPanic { what, message } => {
                write!(f, "{what} panicked: {message}")
            }
            // Construction sites pass self-describing messages (the
            // `ConfigError` Display already leads with "invalid config:"),
            // so no extra prefix here.
            SimError::InvalidConfig(m) => write!(f, "{m}"),
            SimError::HostTimeout { what, seconds } => {
                write!(f, "{what} exceeded the host wall-clock budget of {seconds}s")
            }
        }
    }
}

impl std::error::Error for SimError {}

/// Render a `catch_unwind` payload as text (panic messages are almost
/// always `String` or `&str`; anything else gets a stable placeholder).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap() -> FailSnapshot {
        FailSnapshot {
            what: "kernel 'k'".into(),
            cycle: 1234,
            cores_total: 8,
            cores_blocked: 3,
            insts_retired: 77,
            wake_depth: 0,
            next_core_event: None,
            next_wake: None,
            mem_horizon: Some(2000),
        }
    }

    #[test]
    fn snapshot_roundtrips_through_json() {
        let s = snap();
        let j = s.to_json();
        assert_eq!(FailSnapshot::from_json(&j), s);
        // Absent horizons serialize as null, not as a sentinel number.
        let text = j.to_string();
        assert!(text.contains("\"next_wake\":null"), "{text}");
        assert!(text.contains("\"mem_horizon\":2000"), "{text}");
    }

    #[test]
    fn snapshot_serialization_is_byte_stable() {
        // parse → reprint must be the identity (the resume path depends
        // on it): integral values print as i64, null stays null.
        let text = snap().to_json().to_string();
        let re = Json::parse(&text).unwrap().to_string();
        assert_eq!(text, re);
    }

    #[test]
    fn display_messages_name_the_failure_site() {
        let e = SimError::Deadlock(snap());
        assert_eq!(e.kind(), "deadlock");
        let msg = e.to_string();
        assert!(msg.contains("kernel 'k'") && msg.contains("cycle 1234"), "{msg}");

        let e = SimError::Livelock {
            snap: snap(),
            why: "no instruction retired for 10 epochs".into(),
        };
        assert_eq!(e.kind(), "livelock");
        assert!(e.to_string().contains("no instruction retired"), "{e}");

        let e = SimError::WorkerPanic {
            what: "shard worker".into(),
            message: "boom".into(),
        };
        assert_eq!(e.kind(), "worker-panic");
        assert!(e.to_string().contains("boom"));

        assert_eq!(SimError::InvalidConfig("x".into()).kind(), "invalid-config");
        let e = SimError::HostTimeout {
            what: "kernel 'k'".into(),
            seconds: 5,
        };
        assert_eq!(e.kind(), "host-timeout");
        assert!(e.to_string().contains("5s"));
    }

    #[test]
    fn panic_payloads_render_as_text() {
        let p: Box<dyn std::any::Any + Send> = Box::new("literal".to_string());
        assert_eq!(panic_message(p.as_ref()), "literal");
        let p: Box<dyn std::any::Any + Send> = Box::new("static");
        assert_eq!(panic_message(p.as_ref()), "static");
        let p: Box<dyn std::any::Any + Send> = Box::new(42u32);
        assert_eq!(panic_message(p.as_ref()), "non-string panic payload");
    }
}
