//! Minimal benchmark harness (`criterion` is unavailable offline).
//!
//! Each `cargo bench` target is a `harness = false` binary that uses
//! [`Bench`] for wall-clock measurement and the table/chart renderers to
//! print the same rows/series the paper's tables and figures report.

use std::time::Instant;

/// Timing statistics over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: u32,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Timing {
    pub fn per_iter_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
}

/// Measure `f` after `warmup` throwaway runs.
pub fn measure<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut total = 0.0;
    let mut min_s = f64::MAX;
    let mut max_s: f64 = 0.0;
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        total += dt;
        min_s = min_s.min(dt);
        max_s = max_s.max(dt);
    }
    Timing {
        iters: iters.max(1),
        mean_s: total / iters.max(1) as f64,
        min_s,
        max_s,
    }
}

/// Standard bench preamble: prints the target name and returns whether
/// `--quick` was passed (benches downscale workloads accordingly).
pub fn bench_prelude(name: &str) -> bool {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("ATA_BENCH_QUICK").is_ok();
    println!("\n################################################################");
    println!("# bench: {name}{}", if quick { "  [quick mode]" } else { "" });
    println!("################################################################");
    quick
}

/// Simulated-cycles-per-host-second throughput metric.
pub fn sim_throughput(cycles: u64, host_seconds: f64) -> f64 {
    if host_seconds <= 0.0 {
        0.0
    } else {
        cycles as f64 / host_seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iterations() {
        let mut n = 0;
        let t = measure(2, 5, || n += 1);
        assert_eq!(n, 7, "2 warmup + 5 timed");
        assert_eq!(t.iters, 5);
        assert!(t.min_s <= t.mean_s && t.mean_s <= t.max_s);
    }

    #[test]
    fn throughput_math() {
        assert_eq!(sim_throughput(1000, 0.5), 2000.0);
        assert_eq!(sim_throughput(1000, 0.0), 0.0);
    }
}
