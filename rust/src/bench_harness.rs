//! Minimal benchmark harness (`criterion` is unavailable offline).
//!
//! Each `cargo bench` target is a `harness = false` binary that uses
//! [`Bench`] for wall-clock measurement and the table/chart renderers to
//! print the same rows/series the paper's tables and figures report.

use std::time::Instant;

use crate::util::json::Json;

/// Timing statistics over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: u32,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Timing {
    pub fn per_iter_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
}

/// Measure `f` after `warmup` throwaway runs.
pub fn measure<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut total = 0.0;
    let mut min_s = f64::MAX;
    let mut max_s: f64 = 0.0;
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        total += dt;
        min_s = min_s.min(dt);
        max_s = max_s.max(dt);
    }
    Timing {
        iters: iters.max(1),
        mean_s: total / iters.max(1) as f64,
        min_s,
        max_s,
    }
}

/// Standard bench preamble: prints the target name and returns whether
/// `--quick` was passed (benches downscale workloads accordingly).
pub fn bench_prelude(name: &str) -> bool {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("ATA_BENCH_QUICK").is_ok();
    println!("\n################################################################");
    println!("# bench: {name}{}", if quick { "  [quick mode]" } else { "" });
    println!("################################################################");
    quick
}

/// Simulated-cycles-per-host-second throughput metric.  Note that the
/// event-driven clock makes this a *simulated-time* rate, not a loop
/// rate: a jump over a stalled interval counts all the skipped cycles
/// (they were simulated — analytically), which is exactly why the
/// `ata-sim bench` event A/B shows up in this metric.  Loop-iteration
/// rates live in `stats::EventStats` (`cycles_ticked`).
pub fn sim_throughput(cycles: u64, host_seconds: f64) -> f64 {
    if host_seconds <= 0.0 {
        0.0
    } else {
        cycles as f64 / host_seconds
    }
}

/// Wall-clock comparison of one experiment grid run serially vs. on the
/// parallel execution layer — the `ata-sim bench` evidence that the
/// [`crate::exec::JobRunner`] actually buys throughput *and* stays
/// deterministic.
#[derive(Debug, Clone)]
pub struct SpeedupReport {
    /// Jobs in the grid that was timed.
    pub jobs: usize,
    /// Worker count of the parallel run.
    pub threads: usize,
    pub serial_seconds: f64,
    pub parallel_seconds: f64,
    /// Whether the two runs produced byte-identical canonical output —
    /// the determinism contract, checked on every bench run.
    pub identical: bool,
}

impl SpeedupReport {
    /// Serial wall time over parallel wall time (> 1.0 means the pool
    /// helped; ≈ 1.0 on a single-core runner).
    pub fn speedup(&self) -> f64 {
        if self.parallel_seconds <= 0.0 {
            0.0
        } else {
            self.serial_seconds / self.parallel_seconds
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("jobs", self.jobs.into()),
            ("threads", self.threads.into()),
            ("serial_seconds", self.serial_seconds.into()),
            ("parallel_seconds", self.parallel_seconds.into()),
            ("speedup", self.speedup().into()),
            ("identical", self.identical.into()),
        ])
    }
}

/// Time `run(1)` against `run(threads)` and compare their canonical
/// output byte-for-byte.  `run` receives a worker count and returns the
/// run's canonical serialization (e.g. the sweep's pretty JSON).
pub fn compare_thread_counts<F: FnMut(usize) -> String>(
    jobs: usize,
    threads: usize,
    mut run: F,
) -> SpeedupReport {
    let t0 = Instant::now();
    let serial = run(1);
    let serial_seconds = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let parallel = run(threads);
    let parallel_seconds = t1.elapsed().as_secs_f64();
    SpeedupReport {
        jobs,
        threads,
        serial_seconds,
        parallel_seconds,
        identical: serial == parallel,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iterations() {
        let mut n = 0;
        let t = measure(2, 5, || n += 1);
        assert_eq!(n, 7, "2 warmup + 5 timed");
        assert_eq!(t.iters, 5);
        assert!(t.min_s <= t.mean_s && t.mean_s <= t.max_s);
    }

    #[test]
    fn throughput_math() {
        assert_eq!(sim_throughput(1000, 0.5), 2000.0);
        assert_eq!(sim_throughput(1000, 0.0), 0.0);
    }

    #[test]
    fn speedup_report_compares_and_serializes() {
        let mut calls = Vec::new();
        let rep = compare_thread_counts(5, 4, |threads| {
            calls.push(threads);
            "same-output".to_string()
        });
        assert_eq!(calls, vec![1, 4], "serial first, then parallel");
        assert_eq!(rep.jobs, 5);
        assert_eq!(rep.threads, 4);
        assert!(rep.identical);
        assert!(rep.speedup() >= 0.0);
        let j = Json::parse(&rep.to_json().to_string()).unwrap();
        assert_eq!(j.get("identical").unwrap().as_bool(), Some(true));

        let drift = compare_thread_counts(1, 2, |t| format!("{t}"));
        assert!(!drift.identical, "differing output must be flagged");
    }
}
