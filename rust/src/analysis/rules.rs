//! The contract rules, applied to scrubbed sources.
//!
//! Every rule is a token-level scan over [`lexer::Scrubbed`] text — no
//! type information, no real parse — so each one encodes a deliberately
//! narrow structural pattern plus escape hatches for the shapes it
//! cannot analyze (a `Grant` returned as a tail expression, a
//! destructuring binding).  False negatives are acceptable; false
//! positives are not, because the repo must stay lint-clean and every
//! suppression needs a human justification.

use std::collections::BTreeSet;

use super::lexer::{self, Scrubbed};
use super::registry::{self, RuleId};
use super::report::{Finding, LintReport};

/// One lexed source file, addressed by its repo-relative path.
#[derive(Debug, Clone)]
pub struct SourceFile {
    /// Repo-relative path with `/` separators (e.g. `rust/src/lib.rs`).
    pub path: String,
    pub raw: String,
    pub lex: Scrubbed,
    starts: Vec<usize>,
}

impl SourceFile {
    pub fn new(path: impl Into<String>, raw: impl Into<String>) -> SourceFile {
        let raw = raw.into();
        let lex = lexer::scrub(&raw);
        let starts = lexer::line_starts(&raw);
        SourceFile {
            path: path.into(),
            raw,
            lex,
            starts,
        }
    }

    /// (1-based line, trimmed raw source line) at byte offset `off`.
    fn excerpt_at(&self, off: usize) -> (u32, String) {
        let line = lexer::line_of(&self.starts, off);
        let ls = self.starts[(line - 1) as usize];
        let le = self.raw[ls..]
            .find('\n')
            .map_or(self.raw.len(), |p| ls + p);
        (line, self.raw[ls..le].trim().to_string())
    }

    fn finding(&self, rule: RuleId, off: usize) -> Finding {
        let (line, excerpt) = self.excerpt_at(off);
        Finding {
            rule,
            file: self.path.clone(),
            line,
            excerpt,
        }
    }
}

/// The lintable universe: lexed sources plus the manifest text.
#[derive(Debug, Clone, Default)]
pub struct Workspace {
    pub files: Vec<SourceFile>,
    /// Cargo.toml contents; `manifest-decl` is skipped when absent
    /// (in-memory fixture workspaces without a manifest).
    pub cargo_toml: Option<String>,
}

impl Workspace {
    /// Convenience for tests: a workspace from (path, source) pairs.
    pub fn from_sources(sources: &[(&str, &str)]) -> Workspace {
        Workspace {
            files: sources
                .iter()
                .map(|(p, s)| SourceFile::new(*p, *s))
                .collect(),
            cargo_toml: None,
        }
    }

    fn file(&self, path: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.path == path)
    }

    /// Run every rule, apply suppressions, report.
    pub fn lint(&self) -> LintReport {
        LintReport::new(self.check(), self.files.len())
    }

    /// Raw rule pass + suppression filtering (unsorted findings).
    pub fn check(&self) -> Vec<Finding> {
        let fields = stats_fields(self);
        let mut raw: Vec<Finding> = manifest_decl(self);
        for f in &self.files {
            if registry::applies(RuleId::WallClock, &f.path) {
                raw.extend(wall_clock(f));
            }
            if registry::applies(RuleId::UnorderedIterSerialize, &f.path) {
                raw.extend(unordered_iter_serialize(f));
            }
            if registry::applies(RuleId::GrantDiscipline, &f.path) {
                raw.extend(grant_discipline(f));
            }
            if registry::applies(RuleId::TagMutationHelper, &f.path) {
                raw.extend(tag_mutation_helper(f));
            }
            if registry::applies(RuleId::StatsExclusion, &f.path) {
                raw.extend(stats_exclusion(f, &fields));
            }
            if registry::applies(RuleId::ShardConfinement, &f.path) {
                raw.extend(shard_confinement(f));
            }
            if registry::applies(RuleId::SimPanic, &f.path) {
                raw.extend(sim_panic(f));
            }
        }
        let mut out: Vec<Finding> = raw
            .into_iter()
            .filter(|fd| !self.suppressed(fd))
            .collect();
        // A suppression must name a real rule and carry a justification;
        // violations are findings of their own (and not suppressible —
        // that would recurse).
        for sf in &self.files {
            for s in &sf.lex.suppressions {
                let excerpt = match RuleId::from_slug(&s.rule) {
                    None => format!("unknown rule '{}' in lint suppression", s.rule),
                    Some(_) if !s.justified => {
                        format!("suppression of '{}' has no justification", s.rule)
                    }
                    Some(_) => continue,
                };
                out.push(Finding {
                    rule: RuleId::SuppressionJustification,
                    file: sf.path.clone(),
                    line: s.line,
                    excerpt,
                });
            }
        }
        out
    }

    /// Is `fd` covered by an inline suppression?  A suppression applies
    /// to its own line, and — when it is alone on its line — to the
    /// next line as well.
    fn suppressed(&self, fd: &Finding) -> bool {
        self.file(&fd.file).is_some_and(|sf| {
            sf.lex.suppressions.iter().any(|s| {
                s.rule == fd.rule.slug()
                    && (s.line == fd.line || (s.standalone && s.line + 1 == fd.line))
            })
        })
    }
}

/// True when the whole word `w` sits exactly at `pos`.
fn word_at(s: &str, pos: usize, w: &str) -> bool {
    let b = s.as_bytes();
    if !s[pos..].starts_with(w) {
        return false;
    }
    let before_ok = pos == 0 || !lexer::is_ident_byte(b[pos - 1]);
    let end = pos + w.len();
    let after_ok = end >= b.len() || !lexer::is_ident_byte(b[end]);
    before_ok && after_ok
}

/// Identifier ending at byte `end` (inclusive), walking backwards.
fn ident_ending_at(s: &str, end: usize) -> Option<&str> {
    let b = s.as_bytes();
    if !lexer::is_ident_byte(b[end]) {
        return None;
    }
    let mut start = end;
    while start > 0 && lexer::is_ident_byte(b[start - 1]) {
        start -= 1;
    }
    Some(&s[start..=end])
}

// ---------------------------------------------------------------------------
// Rule 1: manifest-decl
// ---------------------------------------------------------------------------

/// Parse the `[[test]]`/`[[bench]]`/`[[example]]` stanza paths out of
/// Cargo.toml (this crate uses explicit non-default target paths, so
/// every harness file must be declared or it silently never builds).
fn declared_targets(toml: &str) -> BTreeSet<(String, String)> {
    let mut out = BTreeSet::new();
    let mut kind: Option<&str> = None;
    for line in toml.lines() {
        let t = line.trim();
        if t.starts_with("[[") {
            kind = match t {
                "[[test]]" => Some("test"),
                "[[bench]]" => Some("bench"),
                "[[example]]" => Some("example"),
                _ => None,
            };
        } else if t.starts_with('[') {
            kind = None;
        } else if let Some(k) = kind {
            if let Some(rest) = t.strip_prefix("path") {
                let v = rest.trim_start().strip_prefix('=').unwrap_or("").trim();
                let v = v.trim_matches('"');
                if !v.is_empty() {
                    out.insert((k.to_string(), v.to_string()));
                }
            }
        }
    }
    out
}

fn manifest_decl(ws: &Workspace) -> Vec<Finding> {
    let Some(toml) = &ws.cargo_toml else {
        return Vec::new();
    };
    let declared = declared_targets(toml);
    let mut out = Vec::new();
    for f in &ws.files {
        let kind = [
            ("rust/tests/", "test"),
            ("rust/benches/", "bench"),
            ("examples/", "example"),
        ]
        .iter()
        .find_map(|(dir, k)| {
            f.path
                .strip_prefix(dir)
                // Top-level harness files only; subdirectories hold
                // fixtures and shared modules, not targets.
                .filter(|rest| !rest.contains('/'))
                .map(|_| *k)
        });
        let Some(kind) = kind else { continue };
        if !declared.contains(&(kind.to_string(), f.path.clone())) {
            out.push(Finding {
                rule: RuleId::ManifestDecl,
                file: f.path.clone(),
                line: 1,
                excerpt: format!("no [[{kind}]] stanza in Cargo.toml declares {}", f.path),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 2: wall-clock
// ---------------------------------------------------------------------------

fn wall_clock(f: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for w in ["Instant", "SystemTime"] {
        for p in lexer::words(&f.lex.text, w) {
            out.push(f.finding(RuleId::WallClock, p));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 3: unordered-iter-serialize
// ---------------------------------------------------------------------------

/// Byte ranges of `fn to_json(…) … { body }` bodies (braces exclusive).
fn to_json_bodies(t: &str) -> Vec<(usize, usize)> {
    let b = t.as_bytes();
    let mut out = Vec::new();
    for p in lexer::words(t, "to_json") {
        // Definitions only: the previous token must be `fn`.
        let Some(k) = lexer::rskip_ws(t, p) else {
            continue;
        };
        if !(t[..=k].ends_with("fn") && (k < 2 || !lexer::is_ident_byte(b[k - 2]))) {
            continue;
        }
        let open = lexer::skip_ws(t, p + "to_json".len());
        if open >= b.len() || b[open] != b'(' {
            continue;
        }
        let Some(close) = lexer::matching_delim(t, open) else {
            continue;
        };
        let mut j = close + 1;
        while j < b.len() && b[j] != b'{' && b[j] != b';' {
            j += 1;
        }
        if j >= b.len() || b[j] == b';' {
            continue;
        }
        if let Some(end) = lexer::matching_delim(t, j) {
            out.push((j + 1, end));
        }
    }
    out
}

/// Identifiers declared (anywhere in the file) with an unordered
/// map/set type: `name: FxHashMap<…>` fields/params and
/// `name = FxHashMap::default()`-style assignments.
fn map_typed_names(t: &str) -> BTreeSet<String> {
    let b = t.as_bytes();
    let mut names = BTreeSet::new();
    for ty in ["HashMap", "HashSet", "FxHashMap", "FxHashSet"] {
        for p in lexer::words(t, ty) {
            let Some(k) = lexer::rskip_ws(t, p) else {
                continue;
            };
            let ident_end = match b[k] {
                // `name: HashMap<…>` — but not a `::` path segment.
                b':' if !(k > 0 && b[k - 1] == b':') => lexer::rskip_ws(t, k),
                // `name = FxHashMap::default()` — not `==`/`!=`/`<=`/`>=`.
                b'=' if !(k > 0 && matches!(b[k - 1], b'=' | b'!' | b'<' | b'>')) => {
                    lexer::rskip_ws(t, k)
                }
                _ => None,
            };
            if let Some(e) = ident_end {
                if let Some(name) = ident_ending_at(t, e) {
                    if !matches!(name, "let" | "mut" | "pub") {
                        names.insert(name.to_string());
                    }
                }
            }
        }
    }
    names
}

/// Is the iteration at `p` followed by an ordering step?  Looks for a
/// `sort*` call (or a collect into a BTree container) within the
/// iteration's own statement or the one after it.
fn ordered_after(body: &str, p: usize) -> bool {
    let b = body.as_bytes();
    let mut semis = 0;
    let mut end = body.len();
    for (j, &c) in b.iter().enumerate().skip(p) {
        if c == b';' {
            semis += 1;
            if semis == 2 {
                end = j;
                break;
            }
        }
    }
    let w = &body[p..end];
    w.contains("sort") || w.contains("BTreeMap") || w.contains("BTreeSet")
}

/// Is the word at `p` the object of a `for … in` loop?  Walks back
/// over a `&self.cluster.` style receiver chain to find the `in`.
fn preceded_by_in(body: &str, p: usize) -> bool {
    let bb = body.as_bytes();
    let Some(mut k) = lexer::rskip_ws(body, p) else {
        return false;
    };
    loop {
        match bb[k] {
            b'.' => {
                let Some(e) = lexer::rskip_ws(body, k) else {
                    return false;
                };
                if !lexer::is_ident_byte(bb[e]) {
                    return false;
                }
                let mut s = e;
                while s > 0 && lexer::is_ident_byte(bb[s - 1]) {
                    s -= 1;
                }
                match lexer::rskip_ws(body, s) {
                    Some(nk) => k = nk,
                    None => return false,
                }
            }
            b'&' => match lexer::rskip_ws(body, k) {
                Some(nk) => k = nk,
                None => return false,
            },
            _ => break,
        }
    }
    lexer::is_ident_byte(bb[k])
        && body[..=k].ends_with("in")
        && (k < 2 || !lexer::is_ident_byte(bb[k - 2]))
}

fn unordered_iter_serialize(f: &SourceFile) -> Vec<Finding> {
    let t = &f.lex.text;
    let names = map_typed_names(t);
    let mut out = Vec::new();
    for (bs, be) in to_json_bodies(t) {
        let body = &t[bs..be];
        let bb = body.as_bytes();
        for name in &names {
            let mut i = 0;
            while let Some(p) = lexer::find_word(body, i, name) {
                i = p + name.len();
                let mut iterates = false;
                let j = lexer::skip_ws(body, p + name.len());
                if j < bb.len() && bb[j] == b'.' {
                    let w = lexer::skip_ws(body, j + 1);
                    iterates = ["iter", "keys", "values", "into_iter", "drain"]
                        .iter()
                        .any(|m| word_at(body, w, m));
                }
                if !iterates {
                    iterates = preceded_by_in(body, p);
                }
                if iterates && !ordered_after(body, p) {
                    out.push(f.finding(RuleId::UnorderedIterSerialize, bs + p));
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 4: grant-discipline
// ---------------------------------------------------------------------------

enum Binding {
    /// Statement has no `let` at all — the Grant is dropped outright.
    None,
    /// `let _ = …` — explicitly discarded.
    Discard,
    /// `let name = …` — track the binding's later uses.
    Name(String),
    /// Destructuring or otherwise unanalyzable pattern — give up.
    Opaque,
}

fn let_binding(stmt: &str) -> Binding {
    let Some(p) = lexer::find_word(stmt, 0, "let") else {
        return Binding::None;
    };
    let b = stmt.as_bytes();
    let mut j = lexer::skip_ws(stmt, p + 3);
    if word_at(stmt, j, "mut") {
        j = lexer::skip_ws(stmt, j + 3);
    }
    if j >= b.len() {
        return Binding::Opaque;
    }
    if b[j] == b'_' && (j + 1 >= b.len() || !lexer::is_ident_byte(b[j + 1])) {
        return Binding::Discard;
    }
    let start = j;
    while j < b.len() && lexer::is_ident_byte(b[j]) {
        j += 1;
    }
    if j == start {
        return Binding::Opaque; // tuple / struct pattern
    }
    let name = &stmt[start..j];
    let next = lexer::skip_ws(stmt, j);
    // Plain `name =` or `name: Type =` bindings only; `Some(g)`-style
    // patterns fall out here.
    if next < b.len() && (b[next] == b'=' || b[next] == b':') {
        Binding::Name(name.to_string())
    } else {
        Binding::Opaque
    }
}

/// Do the uses of `name` in `region` satisfy the discipline?  True when
/// `.queued` is read, the binding escapes whole (returned / passed /
/// repackaged), or any non-`grant` method runs on it; false when the
/// binding is never used again or only `.grant` is ever read.
fn queued_is_read(region: &str, name: &str) -> bool {
    let bb = region.as_bytes();
    let mut i = 0;
    while let Some(p) = lexer::find_word(region, i, name) {
        i = p + name.len();
        let j = lexer::skip_ws(region, i);
        if j < bb.len() && bb[j] == b'.' {
            let w = lexer::skip_ws(region, j + 1);
            if word_at(region, w, "grant") {
                continue;
            }
            return true; // .queued, or a method that takes the Grant
        }
        return true; // bare escape: returned or passed along whole
    }
    false
}

fn grant_discipline(f: &SourceFile) -> Vec<Finding> {
    let t = &f.lex.text;
    let b = t.as_bytes();
    let skip_tests = registry::spec(RuleId::GrantDiscipline).skip_tests;
    let mut out = Vec::new();
    for meth in ["reserve", "occupy_until"] {
        for p in lexer::words(t, meth) {
            let Some(dot) = lexer::rskip_ws(t, p) else {
                continue;
            };
            if b[dot] != b'.' {
                continue; // `fn reserve(` definitions, not calls
            }
            let open = lexer::skip_ws(t, p + meth.len());
            if open >= b.len() || b[open] != b'(' {
                continue;
            }
            if skip_tests && f.lex.in_test_region(p) {
                continue;
            }
            let Some(close) = lexer::matching_delim(t, open) else {
                continue;
            };
            let after = lexer::skip_ws(t, close + 1);
            if after >= b.len() {
                continue;
            }
            match b[after] {
                b';' => {
                    let stmt_start = t[..p].rfind([';', '{', '}']).map_or(0, |q| q + 1);
                    match let_binding(&t[stmt_start..p]) {
                        Binding::None | Binding::Discard => {
                            out.push(f.finding(RuleId::GrantDiscipline, p));
                        }
                        Binding::Opaque => {}
                        Binding::Name(name) => {
                            let end = lexer::enclosing_block_end(t, after);
                            if !queued_is_read(&t[after..end], &name) {
                                out.push(f.finding(RuleId::GrantDiscipline, p));
                            }
                        }
                    }
                }
                b'.' => {
                    // Chained: `.queued` (or any consuming method) is
                    // fine; chaining `.grant` throws the queueing away.
                    let w = lexer::skip_ws(t, after + 1);
                    if word_at(t, w, "grant") {
                        out.push(f.finding(RuleId::GrantDiscipline, p));
                    }
                }
                // Tail expression, argument, operator operand: the
                // Grant escapes to the caller, whose use is checked at
                // its own site.
                _ => {}
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 5: tag-mutation-helper
// ---------------------------------------------------------------------------

fn tag_mutation_helper(f: &SourceFile) -> Vec<Finding> {
    let t = &f.lex.text;
    let b = t.as_bytes();
    let skip_tests = registry::spec(RuleId::TagMutationHelper).skip_tests;
    const PATS: [(&str, &str); 4] = [
        ("tags", "fill"),
        ("tags", "mark_dirty"),
        ("tags", "invalidate"),
        ("cache", "fill"),
    ];
    let mut out = Vec::new();
    for (recv, meth) in PATS {
        for p in lexer::words(t, meth) {
            let open = lexer::skip_ws(t, p + meth.len());
            if open >= b.len() || b[open] != b'(' {
                continue;
            }
            let Some(dot) = lexer::rskip_ws(t, p) else {
                continue;
            };
            if b[dot] != b'.' {
                continue;
            }
            let Some(r_end) = lexer::rskip_ws(t, dot) else {
                continue;
            };
            if ident_ending_at(t, r_end) != Some(recv) {
                continue;
            }
            if skip_tests && f.lex.in_test_region(p) {
                continue;
            }
            out.push(f.finding(RuleId::TagMutationHelper, p));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 6: stats-exclusion
// ---------------------------------------------------------------------------

/// Canonical host-telemetry field names; unioned with whatever the
/// workspace's `EventStats`/`ResidencyStats`/`ShardStats` struct
/// definitions declare so the rule tracks field renames without an edit
/// here going stale.
const TELEMETRY_FIELDS: [&str; 15] = [
    "cycles_ticked",
    "cycles_simulated",
    "jumps",
    "max_jump",
    "index_probes",
    "scan_probes",
    "index_ops",
    "index_lines",
    "peak_lines",
    "shard_count",
    "epochs",
    "egress_txns",
    "ingress_wakes",
    "tick_ns",
    "walk_ns",
];

const TELEMETRY_STRUCTS: [&str; 3] = ["EventStats", "ResidencyStats", "ShardStats"];

fn stats_fields(ws: &Workspace) -> BTreeSet<String> {
    let mut fields: BTreeSet<String> =
        TELEMETRY_FIELDS.iter().map(|s| s.to_string()).collect();
    for f in &ws.files {
        let t = &f.lex.text;
        let b = t.as_bytes();
        for p in lexer::words(t, "struct") {
            let j = lexer::skip_ws(t, p + "struct".len());
            if !TELEMETRY_STRUCTS.iter().any(|s| word_at(t, j, s)) {
                continue;
            }
            let Some(off) = t[j..].find('{') else { continue };
            let open = j + off;
            let Some(end) = lexer::matching_delim(t, open) else {
                continue;
            };
            let body = &t[open + 1..end];
            for q in lexer::words(body, "pub") {
                let s = lexer::skip_ws(body, q + 3);
                let mut e = s;
                while e < body.len() && lexer::is_ident_byte(b[open + 1 + e]) {
                    e += 1;
                }
                let k = lexer::skip_ws(body, e);
                if e > s && k < body.len() && body.as_bytes()[k] == b':' {
                    fields.insert(body[s..e].to_string());
                }
            }
        }
    }
    fields
}

/// Byte ranges of `impl EventStats { … }` / `impl ResidencyStats { … }`.
fn telemetry_impl_regions(t: &str) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for p in lexer::words(t, "impl") {
        let j = lexer::skip_ws(t, p + 4);
        if !TELEMETRY_STRUCTS.iter().any(|s| word_at(t, j, s)) {
            continue;
        }
        let Some(off) = t[j..].find('{') else { continue };
        let open = j + off;
        if let Some(end) = lexer::matching_delim(t, open) {
            out.push((p, end + 1));
        }
    }
    out
}

fn stats_exclusion(f: &SourceFile, fields: &BTreeSet<String>) -> Vec<Finding> {
    let t = &f.lex.text;
    let exempt = telemetry_impl_regions(t);
    let mut out = Vec::new();
    for (bs, be) in to_json_bodies(t) {
        if exempt.iter().any(|&(a, b)| a <= bs && be <= b) {
            continue; // the telemetry types may serialize themselves
        }
        let body = &t[bs..be];
        for field in fields {
            for p in lexer::words(body, field) {
                out.push(f.finding(RuleId::StatsExclusion, bs + p));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 7: shard-confinement
// ---------------------------------------------------------------------------

/// Flag `thread` used as a path segment (`std::thread`, `thread::scope`,
/// `thread::spawn`, …) outside the execution layer, the engine's shard
/// module, and the L2 walk pool.  Everything else in the simulator must stay
/// single-threaded: determinism comes from the simulation being a pure
/// function of (config, workload), never from synchronization, so an
/// ad-hoc thread anywhere in model code is a byte-identity hazard even
/// when it "only" reads.  Scope is declarative ([`registry`]); genuine
/// host-side exceptions take the usual justified suppression.
fn shard_confinement(f: &SourceFile) -> Vec<Finding> {
    let t = &f.lex.text;
    let skip_tests = registry::spec(RuleId::ShardConfinement).skip_tests;
    let mut out = Vec::new();
    for p in lexer::words(t, "thread") {
        // Path segments only: `threads` counts and prose identifiers
        // (`thread_pool_size`) are not thread spawns.
        let pathlike = t[..p].ends_with("::") || t[p + "thread".len()..].starts_with("::");
        if !pathlike {
            continue;
        }
        if skip_tests && f.lex.in_test_region(p) {
            continue;
        }
        out.push(f.finding(RuleId::ShardConfinement, p));
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 8: sim-panic
// ---------------------------------------------------------------------------

/// Flag `panic!`, `.unwrap()` and `.expect(` in simulation-core code
/// (the engine, L2, L1 architectures, and DRAM — scope is declarative
/// in [`registry`]).  A fault inside a job must surface as a typed
/// `SimError` so the runner can serialize it as data; an unwind is only
/// survivable because `catch_unwind` backstops it, and it throws the
/// diagnostic snapshot away.  Structurally-infallible sites (a slot
/// filled by construction) take the usual justified suppression.
fn sim_panic(f: &SourceFile) -> Vec<Finding> {
    let t = &f.lex.text;
    let b = t.as_bytes();
    let skip_tests = registry::spec(RuleId::SimPanic).skip_tests;
    let mut out = Vec::new();
    for p in lexer::words(t, "panic") {
        // The macro only: `panic_message`, `catch_unwind` prose and
        // doc-comment mentions are scrubbed or fail the word/`!` tests.
        let j = lexer::skip_ws(t, p + "panic".len());
        if j >= b.len() || b[j] != b'!' {
            continue;
        }
        if skip_tests && f.lex.in_test_region(p) {
            continue;
        }
        out.push(f.finding(RuleId::SimPanic, p));
    }
    for meth in ["unwrap", "expect"] {
        for p in lexer::words(t, meth) {
            let Some(dot) = lexer::rskip_ws(t, p) else {
                continue;
            };
            if b[dot] != b'.' {
                continue; // `fn unwrap(` definitions, not call sites
            }
            let open = lexer::skip_ws(t, p + meth.len());
            if open >= b.len() || b[open] != b'(' {
                continue;
            }
            if skip_tests && f.lex.in_test_region(p) {
                continue;
            }
            out.push(f.finding(RuleId::SimPanic, p));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_one(path: &str, src: &str) -> Vec<Finding> {
        Workspace::from_sources(&[(path, src)]).check()
    }

    fn rules_of(findings: &[Finding]) -> Vec<RuleId> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn let_binding_classification() {
        assert!(matches!(let_binding("  let g "), Binding::Opaque));
        assert!(matches!(let_binding("let g ="), Binding::Name(n) if n == "g"));
        assert!(matches!(
            let_binding("let mut total: Grant ="),
            Binding::Name(n) if n == "total"
        ));
        assert!(matches!(let_binding("let _ ="), Binding::Discard));
        assert!(matches!(let_binding("let (a, b) ="), Binding::Opaque));
        assert!(matches!(let_binding("let Some(g) ="), Binding::Opaque));
        assert!(matches!(let_binding("x += 1"), Binding::None));
    }

    #[test]
    fn grant_tail_expression_and_repackaging_pass() {
        let src = "impl S {\n    fn a(&mut self) -> Grant {\n        self.banks[0].reserve(now, 1)\n    }\n    fn b(&mut self) -> Grant {\n        let g = self.p.reserve(now, 1);\n        Grant::new(g.grant + 2, g.queued)\n    }\n}\n";
        assert!(check_one("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn grant_statement_drop_and_grant_chain_flagged() {
        let src = "fn f(p: &mut P) {\n    p.banks.reserve(bank, now, 1);\n    let t = p.port.reserve(now, 1).grant;\n    let g = p.mshr.occupy_until(s, fill);\n    use_only(g.grant);\n}\n";
        let found = rules_of(&check_one("rust/src/x.rs", src));
        assert_eq!(found.len(), 3, "{found:?}");
        assert!(found.iter().all(|r| *r == RuleId::GrantDiscipline));
    }

    #[test]
    fn grant_queued_read_passes_and_tests_are_skipped() {
        let src = "fn f(p: &mut P) {\n    let g = p.banks.reserve(bank, now, 1);\n    txn.charge(&mut con, Class::X, g.queued);\n    serve(g.grant);\n}\n#[cfg(test)]\nmod tests {\n    fn t(p: &mut P) { p.banks.reserve(0, 0, 1); }\n}\n";
        assert!(check_one("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_flagged_outside_allowlist_only() {
        let src = "use std::time::Instant;\nfn f() { let t = Instant::now(); }\n";
        assert_eq!(check_one("rust/src/x.rs", src).len(), 2);
        assert!(check_one("rust/benches/x.rs", src).is_empty());
        assert!(check_one("rust/src/bench_harness.rs", src).is_empty());
        // Doc comments and strings never trip it.
        let doc = "//! Instant is forbidden here.\nfn f() { let s = \"Instant\"; }\n";
        assert!(check_one("rust/src/x.rs", doc).is_empty());
    }

    #[test]
    fn suppression_silences_with_justification() {
        let src = "use std::time::Instant; // lint: allow(wall-clock) — host span, stderr only\nfn f() {}\n";
        assert!(check_one("rust/src/x.rs", src).is_empty());
        let standalone = "// lint: allow(wall-clock) — host span, stderr only\nuse std::time::Instant;\nfn f() {}\n";
        assert!(check_one("rust/src/x.rs", standalone).is_empty());
    }

    #[test]
    fn unjustified_or_unknown_suppressions_are_findings() {
        let src = "use std::time::Instant; // lint: allow(wall-clock)\nfn f() {}\n";
        let found = check_one("rust/src/x.rs", src);
        assert_eq!(rules_of(&found), vec![RuleId::SuppressionJustification]);
        let unk = "fn f() {} // lint: allow(no-such-rule) — because\n";
        let found = check_one("rust/src/x.rs", unk);
        assert_eq!(rules_of(&found), vec![RuleId::SuppressionJustification]);
        assert!(found[0].excerpt.contains("no-such-rule"));
    }

    #[test]
    fn tag_mutation_outside_helpers_flagged() {
        let src = "fn f(c: &mut C) {\n    c.tags.mark_dirty(line, mask);\n    c.cache.fill(line, sectors);\n    c.mshr.fill(line);\n}\n";
        let found = check_one("rust/src/l2/x.rs", src);
        assert_eq!(found.len(), 2, "{found:?}"); // mshr.fill is not a tag mutation
        assert!(check_one("rust/src/l1arch/pipeline.rs", src).is_empty());
    }

    #[test]
    fn unordered_iteration_in_to_json_flagged_sorted_passes() {
        let src = "struct S { m: FxHashMap<u32, u32> }\nimpl S {\n    fn to_json(&self) -> Json {\n        for (k, v) in &self.m { emit(k, v); }\n        Json::Null\n    }\n}\n";
        assert_eq!(
            rules_of(&check_one("rust/src/x.rs", src)),
            vec![RuleId::UnorderedIterSerialize]
        );
        let sorted = "struct S { m: FxHashMap<u32, u32> }\nimpl S {\n    fn to_json(&self) -> Json {\n        let mut v: Vec<_> = self.m.iter().collect();\n        v.sort();\n        Json::Null\n    }\n    fn elsewhere(&self) { for k in self.m.keys() { use_(k); } }\n}\n";
        assert!(check_one("rust/src/x.rs", sorted).is_empty());
    }

    #[test]
    fn stats_fields_in_foreign_to_json_flagged() {
        let src = "impl SimResult {\n    fn to_json(&self) -> Json {\n        obj(vec![(self.cycles_ticked.into())])\n    }\n}\n";
        assert_eq!(
            rules_of(&check_one("rust/src/x.rs", src)),
            vec![RuleId::StatsExclusion]
        );
        let own = "impl EventStats {\n    fn to_json(&self) -> Json {\n        obj(vec![(self.cycles_ticked.into())])\n    }\n}\n";
        assert!(check_one("rust/src/x.rs", own).is_empty());
    }

    #[test]
    fn thread_paths_flagged_outside_exec_and_shard_module() {
        let src = "fn f() {\n    std::thread::scope(|s| { s.spawn(|| {}); });\n    let n = thread::available_parallelism();\n}\n";
        let found = rules_of(&check_one("rust/src/l1arch/mod.rs", src));
        assert_eq!(found.len(), 2, "{found:?}");
        assert!(found.iter().all(|r| *r == RuleId::ShardConfinement));
        // The execution layer, the shard module, and the L2 walk pool are
        // the allowed zones.
        assert!(check_one("rust/src/exec/runner.rs", src).is_empty());
        assert!(check_one("rust/src/engine/shard.rs", src).is_empty());
        assert!(check_one("rust/src/l2/walk.rs", src).is_empty());
        // `threads` counts, prose identifiers, comments and strings are
        // not thread spawns.
        let benign = "//! Uses std::thread::scope internally.\nfn f(threads: usize) -> usize {\n    let thread_pool_size = threads;\n    thread_pool_size\n}\n";
        assert!(check_one("rust/src/l1arch/mod.rs", benign).is_empty());
        // Test regions may exercise harnesses directly.
        let in_test = "fn f() {}\n#[cfg(test)]\nmod tests {\n    fn t() { std::thread::yield_now(); }\n}\n";
        assert!(check_one("rust/src/l1arch/mod.rs", in_test).is_empty());
        // The escape hatch: a justified suppression on the line.
        let sup = "fn f() {\n    std::thread::yield_now(); // lint: allow(shard-confinement) — host-only nicety\n}\n";
        assert!(check_one("rust/src/l1arch/mod.rs", sup).is_empty());
    }

    #[test]
    fn shard_stats_fields_in_foreign_to_json_flagged() {
        let src = "impl SimResult {\n    fn to_json(&self) -> Json {\n        obj(vec![(self.ingress_wakes.into())])\n    }\n}\n";
        assert_eq!(
            rules_of(&check_one("rust/src/x.rs", src)),
            vec![RuleId::StatsExclusion]
        );
        let own = "impl ShardStats {\n    fn to_json(&self) -> Json {\n        obj(vec![(self.epochs.into())])\n    }\n}\n";
        assert!(check_one("rust/src/x.rs", own).is_empty());
        // The PR 9 phase-time counters are telemetry too.
        let ns = "impl SimResult {\n    fn to_json(&self) -> Json {\n        obj(vec![(self.walk_ns.into())])\n    }\n}\n";
        assert_eq!(
            rules_of(&check_one("rust/src/x.rs", ns)),
            vec![RuleId::StatsExclusion]
        );
    }

    #[test]
    fn sim_panic_flagged_in_core_non_test_code_only() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    let v = x.unwrap();\n    let w = x.expect(\"present\");\n    if v == 0 { panic!(\"zero\"); }\n    v + w\n}\n#[cfg(test)]\nmod tests {\n    fn t(x: Option<u32>) { x.unwrap(); }\n}\n";
        let found = rules_of(&check_one("rust/src/engine/mod.rs", src));
        assert_eq!(found.len(), 3, "{found:?}");
        assert!(found.iter().all(|r| *r == RuleId::SimPanic));
        // Outside the positive scope (the exec layer owns catch_unwind,
        // the CLI owns usage errors) the rule stays silent.
        assert!(check_one("rust/src/exec/runner.rs", src).is_empty());
        assert!(check_one("rust/src/util/json.rs", src).is_empty());
    }

    #[test]
    fn sim_panic_skips_fallible_free_shapes_and_suppressions() {
        // unwrap_or / unwrap_or_else / expect_err never unwind; the
        // `panic` word without `!` is panic_message-style prose.
        let benign = "fn f(x: Option<u32>, e: &str) -> u32 {\n    let m = panic_message(e);\n    x.unwrap_or(0) + x.unwrap_or_else(|| m.len() as u32)\n}\n";
        assert!(check_one("rust/src/engine/mod.rs", benign).is_empty());
        // The escape hatch: a justified suppression on its own line
        // covers the next line.
        let sup = "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(sim-panic) — slot filled by construction one phase earlier\n    x.unwrap()\n}\n";
        assert!(check_one("rust/src/engine/mod.rs", sup).is_empty());
    }

    #[test]
    fn manifest_decl_requires_matching_stanza() {
        let toml = "[package]\nname = \"x\"\n\n[[test]]\nname = \"good\"\npath = \"rust/tests/good.rs\"\n";
        let mut ws = Workspace::from_sources(&[
            ("rust/tests/good.rs", "fn main() {}"),
            ("rust/tests/bad.rs", "fn main() {}"),
            ("rust/tests/fixtures/helper.rs", "fn main() {}"),
        ]);
        ws.cargo_toml = Some(toml.to_string());
        let found = ws.check();
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].rule, RuleId::ManifestDecl);
        assert_eq!(found[0].file, "rust/tests/bad.rs");
        // A bench stanza must not satisfy a test file.
        let cross = "[[bench]]\nname = \"bad\"\npath = \"rust/tests/bad.rs\"\n";
        let mut ws2 =
            Workspace::from_sources(&[("rust/tests/bad.rs", "fn main() {}")]);
        ws2.cargo_toml = Some(cross.to_string());
        assert_eq!(ws2.check().len(), 1);
    }
}
