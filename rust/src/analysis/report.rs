//! Finding model and the two output surfaces of `ata-sim lint`: a
//! column-aligned human table and a machine-readable JSON object (the
//! `--json` form CI greps for `"findings"` / `"rules_checked"`).

use crate::util::json::Json;
use crate::util::table::Table;

use super::registry::{RuleId, REGISTRY};

/// One rule violation at one source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: RuleId,
    /// Repo-relative path with `/` separators.
    pub file: String,
    /// 1-based line.
    pub line: u32,
    /// The offending source line (trimmed), or a synthesized message
    /// for repo-level rules like `manifest-decl`.
    pub excerpt: String,
}

impl Finding {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rule", Json::str(self.rule.slug())),
            ("file", Json::str(self.file.as_str())),
            ("line", Json::num(self.line as f64)),
            ("excerpt", Json::str(self.excerpt.as_str())),
        ])
    }
}

/// Result of one full lint pass.
#[derive(Debug, Clone)]
pub struct LintReport {
    /// Unsuppressed findings, sorted by (file, line, rule slug).
    pub findings: Vec<Finding>,
    /// Slugs of every rule the pass evaluated.
    pub rules_checked: Vec<&'static str>,
    pub files_scanned: usize,
}

impl LintReport {
    pub fn new(mut findings: Vec<Finding>, files_scanned: usize) -> LintReport {
        findings.sort_by(|a, b| {
            (a.file.as_str(), a.line, a.rule.slug()).cmp(&(b.file.as_str(), b.line, b.rule.slug()))
        });
        LintReport {
            findings,
            rules_checked: REGISTRY.iter().map(|s| s.id.slug()).collect(),
            files_scanned,
        }
    }

    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "findings",
                Json::arr(self.findings.iter().map(Finding::to_json).collect()),
            ),
            (
                "rules_checked",
                Json::arr(self.rules_checked.iter().map(|s| Json::str(*s)).collect()),
            ),
            ("files_scanned", Json::num(self.files_scanned as f64)),
            ("clean", Json::Bool(self.is_clean())),
        ])
    }

    /// Human-readable rendering: a table of findings (or a one-line
    /// all-clear) plus a summary line.
    pub fn render(&self) -> String {
        if self.is_clean() {
            return format!(
                "lint: clean — {} files scanned, {} rules\n",
                self.files_scanned,
                self.rules_checked.len()
            );
        }
        let mut t = Table::new("lint findings").header(&["rule", "location", "excerpt"]);
        for f in &self.findings {
            let mut excerpt = f.excerpt.clone();
            if excerpt.chars().count() > 72 {
                excerpt = excerpt.chars().take(69).collect::<String>() + "...";
            }
            t.row(vec![
                f.rule.slug().to_string(),
                format!("{}:{}", f.file, f.line),
                excerpt,
            ]);
        }
        format!(
            "{}\n{} finding(s) across {} scanned files\n",
            t.render(),
            self.findings.len(),
            self.files_scanned
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: RuleId, file: &str, line: u32) -> Finding {
        Finding {
            rule,
            file: file.to_string(),
            line,
            excerpt: "let t = Instant::now();".to_string(),
        }
    }

    #[test]
    fn report_sorts_and_serializes_required_fields() {
        let r = LintReport::new(
            vec![
                finding(RuleId::WallClock, "b.rs", 9),
                finding(RuleId::GrantDiscipline, "a.rs", 3),
            ],
            5,
        );
        assert_eq!(r.findings[0].file, "a.rs");
        let j = r.to_json();
        assert_eq!(j.get("findings").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("rules_checked").unwrap().as_arr().unwrap().len(), RuleId::ALL.len());
        assert_eq!(j.get("clean").unwrap().as_bool(), Some(false));
        let f0 = &j.get("findings").unwrap().as_arr().unwrap()[0];
        assert_eq!(f0.get("rule").unwrap().as_str(), Some("grant-discipline"));
        assert_eq!(f0.get("line").unwrap().as_u64(), Some(3));
        assert!(!r.is_clean());
        assert!(r.render().contains("b.rs:9"));
    }

    #[test]
    fn clean_report_renders_one_line() {
        let r = LintReport::new(vec![], 42);
        assert!(r.is_clean());
        assert_eq!(r.to_json().get("clean").unwrap().as_bool(), Some(true));
        assert!(r.render().contains("clean"));
        assert!(r.render().contains("42"));
    }
}
