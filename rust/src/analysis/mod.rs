//! Source-level contract lints (`ata-sim lint`).
//!
//! Six PRs of prose contracts, machine-checked: determinism (no wall
//! clock in result paths, no unordered-map iteration during
//! serialization), contention accounting (every reservation's queued
//! cycles must be charged), the PR 5 mutation-point invariant (tag
//! mutations only through the `PipelineCtx` helpers), the
//! telemetry-exclusion contract (`EventStats`/`ResidencyStats` stay out
//! of result JSON), and the PR 6 manifest lesson (every harness file
//! needs its Cargo.toml stanza, or it silently never runs).
//!
//! The pass is std-only and host-side: it reads sources, never runs
//! them, and cannot perturb simulated metrics.  Rules scan a scrubbed
//! copy of each file ([`lexer`]) so comments and string literals never
//! false-positive.  Intentional exceptions are annotated in place with
//! a justified suppression comment (the `allow(<rule>)` form described
//! in [`lexer::Suppression`]); the suppression itself is linted.
//!
//! Entry points: [`run_lint`] walks a repo root; [`Workspace`] lints an
//! in-memory file set (what the fixture tests use).

pub mod lexer;
pub mod registry;
pub mod report;
pub mod rules;

pub use registry::{applies, spec, RuleId, RuleSpec, Severity, REGISTRY};
pub use report::{Finding, LintReport};
pub use rules::{SourceFile, Workspace};

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Directories scanned for `.rs` sources, relative to the repo root.
pub const SCAN_ROOTS: [&str; 4] = ["rust/src", "rust/tests", "rust/benches", "examples"];

/// Lint the repository rooted at `root`: walk [`SCAN_ROOTS`], read
/// Cargo.toml, run every registered rule.
pub fn run_lint(root: &Path) -> io::Result<LintReport> {
    let mut files = Vec::new();
    for sub in SCAN_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, root, &mut files)?;
        }
    }
    // Deterministic order regardless of directory-entry order.
    files.sort_by(|a, b| a.path.cmp(&b.path));
    let cargo_toml = fs::read_to_string(root.join("Cargo.toml")).ok();
    let ws = Workspace { files, cargo_toml };
    Ok(ws.lint())
}

fn collect_rs(dir: &Path, root: &Path, out: &mut Vec<SourceFile>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, root, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            let raw = fs::read_to_string(&p)?;
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            out.push(SourceFile::new(rel, raw));
        }
    }
    Ok(())
}
