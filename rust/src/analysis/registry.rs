//! Rule registry: identities, severities, and per-rule scope.
//!
//! Each rule guards one contract the repo's PR history established the
//! hard way (see the "Invariants as lints" table in
//! `docs/ARCHITECTURE.md`).  A rule's scope is declarative: exact files
//! and directory prefixes it never applies to (`allow_files` /
//! `allow_dirs`), plus whether `#[cfg(test)] mod` regions are skipped
//! (`skip_tests`) — test code exercises substrate APIs directly and is
//! not part of the accounting contracts.

use std::fmt;

/// Stable identifier of one lint rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum RuleId {
    /// Every file under `rust/tests/`, `rust/benches/`, `examples/` has
    /// a matching `[[test]]`/`[[bench]]`/`[[example]]` Cargo.toml stanza.
    ManifestDecl,
    /// `std::time::{Instant, SystemTime}` only at host-telemetry sites.
    WallClock,
    /// No unordered-map iteration inside a `to_json` body without a sort.
    UnorderedIterSerialize,
    /// Every `.reserve(`/`.occupy_until(` Grant must have its `queued`
    /// cycles read (or the Grant must escape to the caller).
    GrantDiscipline,
    /// Tag-array mutations only through the `PipelineCtx` helpers.
    TagMutationHelper,
    /// `EventStats`/`ResidencyStats` fields never serialize into results.
    StatsExclusion,
    /// `std::thread` only in the execution layer, the engine's shard
    /// module, and the L2 walk pool — simulation code must stay
    /// single-threaded-deterministic.
    ShardConfinement,
    /// No `panic!`/`.unwrap()`/`.expect(` in simulation-core non-test
    /// code: a poisoned job must surface as a typed `SimError`, never
    /// an unwind (`catch_unwind` is the containment backstop, not the
    /// failure path).
    SimPanic,
    /// Suppression comments must be justified and name a real rule.
    SuppressionJustification,
}

impl RuleId {
    pub const ALL: [RuleId; 9] = [
        RuleId::ManifestDecl,
        RuleId::WallClock,
        RuleId::UnorderedIterSerialize,
        RuleId::GrantDiscipline,
        RuleId::TagMutationHelper,
        RuleId::StatsExclusion,
        RuleId::ShardConfinement,
        RuleId::SimPanic,
        RuleId::SuppressionJustification,
    ];

    pub fn slug(self) -> &'static str {
        match self {
            RuleId::ManifestDecl => "manifest-decl",
            RuleId::WallClock => "wall-clock",
            RuleId::UnorderedIterSerialize => "unordered-iter-serialize",
            RuleId::GrantDiscipline => "grant-discipline",
            RuleId::TagMutationHelper => "tag-mutation-helper",
            RuleId::StatsExclusion => "stats-exclusion",
            RuleId::ShardConfinement => "shard-confinement",
            RuleId::SimPanic => "sim-panic",
            RuleId::SuppressionJustification => "suppression-justification",
        }
    }

    pub fn from_slug(s: &str) -> Option<RuleId> {
        RuleId::ALL.into_iter().find(|r| r.slug() == s)
    }
}

impl fmt::Display for RuleId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.slug())
    }
}

/// Finding severity.  Every shipped rule is an error today (the lint
/// exits nonzero); the distinction exists so a future advisory rule
/// does not need a model change.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

/// Declarative scope + metadata for one rule.
#[derive(Debug, Clone, Copy)]
pub struct RuleSpec {
    pub id: RuleId,
    pub severity: Severity,
    pub description: &'static str,
    /// Exact repo-relative paths the rule never applies to.
    pub allow_files: &'static [&'static str],
    /// Repo-relative directory prefixes the rule never applies to.
    pub allow_dirs: &'static [&'static str],
    /// Positive scope: when non-empty, the rule applies *only* under
    /// these directory prefixes (then the allow-lists carve exemptions
    /// out of that).  Empty means repo-wide.
    pub only_dirs: &'static [&'static str],
    /// Skip `#[cfg(test)] mod` regions inside checked files.
    pub skip_tests: bool,
}

pub const REGISTRY: [RuleSpec; 9] = [
    RuleSpec {
        id: RuleId::ManifestDecl,
        severity: Severity::Error,
        description: "test/bench/example file has no Cargo.toml stanza (its harness silently never runs)",
        allow_files: &[],
        allow_dirs: &[],
        only_dirs: &[],
        skip_tests: false,
    },
    RuleSpec {
        id: RuleId::WallClock,
        severity: Severity::Error,
        description: "std::time::{Instant,SystemTime} outside host-telemetry sites (wall clock in a result path breaks byte-identity)",
        allow_files: &["rust/src/bench_harness.rs"],
        allow_dirs: &["rust/benches/"],
        only_dirs: &[],
        skip_tests: false,
    },
    RuleSpec {
        id: RuleId::UnorderedIterSerialize,
        severity: Severity::Error,
        description: "unordered map/set iterated inside a to_json body without a sort (output order is hash-dependent)",
        allow_files: &[],
        allow_dirs: &[],
        only_dirs: &[],
        skip_tests: false,
    },
    RuleSpec {
        id: RuleId::GrantDiscipline,
        severity: Severity::Error,
        description: "reservation Grant dropped or its .queued never read (queued cycles would go uncharged)",
        allow_files: &[],
        allow_dirs: &["rust/tests/", "rust/benches/"],
        only_dirs: &[],
        skip_tests: true,
    },
    RuleSpec {
        id: RuleId::TagMutationHelper,
        severity: Severity::Error,
        description: "direct tag-array mutation outside the PipelineCtx helpers (residency index would go stale)",
        allow_files: &[
            "rust/src/l1arch/pipeline.rs",
            "rust/src/l1arch/residency.rs",
            "rust/src/cache/tag_array.rs",
        ],
        allow_dirs: &["rust/tests/", "rust/benches/"],
        only_dirs: &[],
        skip_tests: true,
    },
    RuleSpec {
        id: RuleId::StatsExclusion,
        severity: Severity::Error,
        description: "host-telemetry stats field serialized in a to_json body (telemetry must stay out of result JSON)",
        allow_files: &[],
        allow_dirs: &[],
        only_dirs: &[],
        skip_tests: false,
    },
    RuleSpec {
        id: RuleId::ShardConfinement,
        severity: Severity::Error,
        description: "std::thread outside the execution layer or the shard/walk modules (ad-hoc threading breaks the determinism contract)",
        allow_files: &["rust/src/engine/shard.rs", "rust/src/l2/walk.rs"],
        allow_dirs: &["rust/src/exec/", "rust/tests/", "rust/benches/"],
        only_dirs: &[],
        skip_tests: true,
    },
    RuleSpec {
        id: RuleId::SimPanic,
        severity: Severity::Error,
        description: "panic!/.unwrap()/.expect( in simulation-core non-test code (faults must surface as typed SimError, not an unwind)",
        allow_files: &[],
        allow_dirs: &["rust/tests/", "rust/benches/"],
        only_dirs: &[
            "rust/src/engine/",
            "rust/src/l2/",
            "rust/src/l1arch/",
            "rust/src/dram/",
        ],
        skip_tests: true,
    },
    RuleSpec {
        id: RuleId::SuppressionJustification,
        severity: Severity::Error,
        description: "lint suppression without a justification, or naming an unknown rule",
        allow_files: &[],
        allow_dirs: &[],
        only_dirs: &[],
        skip_tests: false,
    },
];

/// Spec lookup (every `RuleId` has exactly one registry entry).
pub fn spec(id: RuleId) -> &'static RuleSpec {
    REGISTRY
        .iter()
        .find(|s| s.id == id)
        .expect("registry covers every RuleId")
}

/// Does `rule` apply to the file at repo-relative `path`?
pub fn applies(rule: RuleId, path: &str) -> bool {
    let s = spec(rule);
    if !s.only_dirs.is_empty() && !s.only_dirs.iter().any(|d| path.starts_with(d)) {
        return false;
    }
    !(s.allow_files.contains(&path) || s.allow_dirs.iter().any(|d| path.starts_with(d)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slugs_round_trip_and_registry_is_total() {
        for id in RuleId::ALL {
            assert_eq!(RuleId::from_slug(id.slug()), Some(id));
            assert_eq!(spec(id).id, id);
            assert_eq!(spec(id).severity, Severity::Error);
        }
        assert_eq!(RuleId::from_slug("no-such-rule"), None);
        assert_eq!(REGISTRY.len(), RuleId::ALL.len());
    }

    #[test]
    fn scope_filters_files_and_dirs() {
        assert!(!applies(RuleId::WallClock, "rust/src/bench_harness.rs"));
        assert!(!applies(RuleId::WallClock, "rust/benches/fig8_ipc.rs"));
        assert!(applies(RuleId::WallClock, "rust/src/engine/mod.rs"));
        assert!(!applies(RuleId::TagMutationHelper, "rust/src/l1arch/pipeline.rs"));
        assert!(applies(RuleId::TagMutationHelper, "rust/src/l2/mod.rs"));
        assert!(!applies(RuleId::GrantDiscipline, "rust/tests/lint_rules.rs"));
        assert!(!applies(RuleId::ShardConfinement, "rust/src/exec/runner.rs"));
        assert!(!applies(RuleId::ShardConfinement, "rust/src/engine/shard.rs"));
        assert!(!applies(RuleId::ShardConfinement, "rust/src/l2/walk.rs"));
        assert!(applies(RuleId::ShardConfinement, "rust/src/engine/mod.rs"));
        assert!(applies(RuleId::ShardConfinement, "rust/src/l2/mod.rs"));
        assert!(applies(RuleId::ShardConfinement, "examples/arch_explorer.rs"));
        // sim-panic is positively scoped to the simulation core.
        assert!(applies(RuleId::SimPanic, "rust/src/engine/mod.rs"));
        assert!(applies(RuleId::SimPanic, "rust/src/l2/walk.rs"));
        assert!(applies(RuleId::SimPanic, "rust/src/l1arch/pipeline.rs"));
        assert!(applies(RuleId::SimPanic, "rust/src/dram/mod.rs"));
        assert!(!applies(RuleId::SimPanic, "rust/src/exec/runner.rs"));
        assert!(!applies(RuleId::SimPanic, "rust/src/main.rs"));
        assert!(!applies(RuleId::SimPanic, "rust/tests/failure_determinism.rs"));
        assert!(!applies(RuleId::SimPanic, "examples/quickstart.rs"));
    }
}
