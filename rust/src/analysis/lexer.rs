//! Comment/string-aware source scrubbing for the lint pass.
//!
//! The linter never parses Rust for real.  Every rule instead scans a
//! *scrubbed* copy of the file in which comments and string/char
//! literals have been blanked to spaces — byte-for-byte the same length
//! as the raw text, newlines preserved — so offsets and line numbers
//! stay aligned while doc comments and string contents can never
//! false-positive an identifier scan.  Alongside the scrub the lexer
//! collects the inline suppression comments (the `allow(<rule>)` form,
//! see [`Suppression`]) and the `#[cfg(test)] mod` regions that some
//! rules skip.
//!
//! Handled literal forms: `//`/`///`/`//!` line comments, nested
//! `/* */` block comments, `"…"` strings with escapes, `b"…"` byte
//! strings, `r"…"`/`r#"…"#`/`br#"…"#` raw strings, and `'x'`/`'\n'`
//! char literals (disambiguated from `'lifetime` markers).

/// One inline lint suppression comment.
///
/// Syntax: a line comment whose body is
/// `lint: allow(<rule-slug>) — <justification>` (any of `—`, `-`, `:`
/// may separate the justification).  An empty justification, or a slug
/// no registered rule owns, is reported by the `suppression-justification`
/// rule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Suppression {
    /// 1-based line the comment sits on.
    pub line: u32,
    /// True when the comment is alone on its line; the suppression then
    /// also covers the *next* line (the annotated statement).
    pub standalone: bool,
    /// Rule slug inside `allow(…)` (empty when the comment is malformed).
    pub rule: String,
    /// True when a non-empty justification follows the `allow(…)`.
    pub justified: bool,
}

/// Scrub result for one source file.
#[derive(Debug, Clone)]
pub struct Scrubbed {
    /// Same length as the raw text; comments and string/char literals
    /// replaced by spaces (newlines kept).
    pub text: String,
    /// Inline suppressions parsed from the line comments.
    pub suppressions: Vec<Suppression>,
    /// Byte ranges of `#[cfg(test)] mod … { … }` blocks.
    pub test_regions: Vec<(usize, usize)>,
}

impl Scrubbed {
    pub fn in_test_region(&self, off: usize) -> bool {
        self.test_regions.iter().any(|&(a, b)| a <= off && off < b)
    }
}

pub fn is_ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

fn prev_is_ident(b: &[u8], i: usize) -> bool {
    i > 0 && is_ident_byte(b[i - 1])
}

/// Byte length of a raw-string literal starting at `i` (`r"…"`,
/// `r#"…"#`, `br#"…"#`), or None when `i` does not start one.
fn raw_str_len(b: &[u8], i: usize) -> Option<usize> {
    let mut j = i;
    if j < b.len() && b[j] == b'b' {
        j += 1;
    }
    if j >= b.len() || b[j] != b'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j >= b.len() || b[j] != b'"' {
        return None;
    }
    j += 1;
    while j < b.len() {
        if b[j] == b'"' {
            let mut k = 0;
            while k < hashes && j + 1 + k < b.len() && b[j + 1 + k] == b'#' {
                k += 1;
            }
            if k == hashes {
                return Some(j + 1 + hashes - i);
            }
        }
        j += 1;
    }
    Some(b.len() - i) // unterminated: blank to EOF
}

/// End (exclusive) of a char literal opening at `i`, or None when the
/// quote is a lifetime marker.
fn char_literal_end(b: &[u8], i: usize) -> Option<usize> {
    let n = b.len();
    if i + 1 >= n {
        return None;
    }
    if b[i + 1] == b'\\' {
        // Escaped char ('\n', '\u{1F600}'): bounded scan to the close.
        let mut j = i + 2;
        let limit = (i + 14).min(n);
        while j < limit {
            if b[j] == b'\'' {
                return Some(j + 1);
            }
            j += 1;
        }
        return None;
    }
    // Unescaped: exactly one UTF-8 char then a closing quote; anything
    // else ('static, <'a>) is a lifetime.
    let ch_len = match b[i + 1] {
        c if c < 0x80 => 1,
        c if c >= 0xF0 => 4,
        c if c >= 0xE0 => 3,
        _ => 2,
    };
    let j = i + 1 + ch_len;
    if j < n && b[j] == b'\'' {
        Some(j + 1)
    } else {
        None
    }
}

/// Scrub `src`: blank comments and literals, collect suppressions and
/// `#[cfg(test)] mod` regions.
pub fn scrub(src: &str) -> Scrubbed {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let mut line_comments: Vec<(usize, usize)> = Vec::new();
    let blank = |out: &mut Vec<u8>, from: usize, to: usize| {
        for slot in &mut out[from..to] {
            if *slot != b'\n' {
                *slot = b' ';
            }
        }
    };
    let mut i = 0;
    while i < n {
        match b[i] {
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                let start = i;
                let mut j = i + 2;
                while j < n && b[j] != b'\n' {
                    j += 1;
                }
                line_comments.push((start, j));
                blank(&mut out, start, j);
                i = j;
            }
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                let start = i;
                let mut depth = 1u32;
                let mut j = i + 2;
                while j < n && depth > 0 {
                    if j + 1 < n && b[j] == b'/' && b[j + 1] == b'*' {
                        depth += 1;
                        j += 2;
                    } else if j + 1 < n && b[j] == b'*' && b[j + 1] == b'/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                blank(&mut out, start, j);
                i = j;
            }
            b'r' | b'b' if !prev_is_ident(b, i) && raw_str_len(b, i).is_some() => {
                let len = raw_str_len(b, i).unwrap();
                blank(&mut out, i, i + len);
                i += len;
            }
            b'"' => {
                let start = i;
                let mut j = i + 1;
                while j < n {
                    match b[j] {
                        b'\\' => j += 2,
                        b'"' => {
                            j += 1;
                            break;
                        }
                        _ => j += 1,
                    }
                }
                let j = j.min(n);
                blank(&mut out, start, j);
                i = j;
            }
            b'\'' => {
                if let Some(end) = char_literal_end(b, i) {
                    blank(&mut out, i, end);
                    i = end;
                } else {
                    i += 1;
                }
            }
            _ => i += 1,
        }
    }
    let text = String::from_utf8(out).expect("scrub only writes ASCII spaces");
    let starts = line_starts(src);
    let suppressions = parse_suppressions(src, &line_comments, &starts);
    let test_regions = find_test_regions(&text);
    Scrubbed {
        text,
        suppressions,
        test_regions,
    }
}

fn parse_suppressions(
    src: &str,
    comments: &[(usize, usize)],
    starts: &[usize],
) -> Vec<Suppression> {
    let mut out = Vec::new();
    for &(cstart, cend) in comments {
        // Strip the `//` plus any doc-comment marker, then require the
        // body to *begin* with the lint keyword — a comment that merely
        // mentions the syntax (in backticks, mid-sentence) is prose.
        let body = src[cstart + 2..cend]
            .trim_start_matches(['/', '!'])
            .trim();
        let Some(rest) = body.strip_prefix("lint:") else {
            continue;
        };
        let line = line_of(starts, cstart);
        let line_start = starts[(line - 1) as usize];
        let standalone = src[line_start..cstart].trim().is_empty();
        let (rule, justified) = match rest.trim().strip_prefix("allow(") {
            Some(r) => match r.find(')') {
                Some(p) => {
                    let rule = r[..p].trim().to_string();
                    let just = r[p + 1..].trim_start_matches(|c: char| {
                        c.is_whitespace() || matches!(c, '-' | '—' | ':' | ',')
                    });
                    (rule, !just.trim().is_empty())
                }
                None => (String::new(), false),
            },
            None => (String::new(), false),
        };
        out.push(Suppression {
            line,
            standalone,
            rule,
            justified,
        });
    }
    out
}

/// Expect `tok` at `*j` after optional whitespace; advance past it.
fn expect_tok(s: &str, j: &mut usize, tok: &str) -> bool {
    let b = s.as_bytes();
    while *j < b.len() && b[*j].is_ascii_whitespace() {
        *j += 1;
    }
    if s[*j..].starts_with(tok) {
        // Word tokens must end at a word boundary (`cfg` vs `cfg_attr`).
        let end = *j + tok.len();
        if tok.bytes().all(is_ident_byte) && end < b.len() && is_ident_byte(b[end]) {
            return false;
        }
        *j = end;
        true
    } else {
        false
    }
}

/// Byte ranges of `#[cfg(test)] mod … { … }` blocks in scrubbed text.
fn find_test_regions(scrubbed: &str) -> Vec<(usize, usize)> {
    let b = scrubbed.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while let Some(off) = scrubbed[i..].find('#') {
        let p = i + off;
        i = p + 1;
        let mut j = p + 1;
        if !(expect_tok(scrubbed, &mut j, "[")
            && expect_tok(scrubbed, &mut j, "cfg")
            && expect_tok(scrubbed, &mut j, "(")
            && expect_tok(scrubbed, &mut j, "test")
            && expect_tok(scrubbed, &mut j, ")")
            && expect_tok(scrubbed, &mut j, "]")
            && expect_tok(scrubbed, &mut j, "mod"))
        {
            continue;
        }
        while j < b.len() && b[j].is_ascii_whitespace() {
            j += 1;
        }
        while j < b.len() && is_ident_byte(b[j]) {
            j += 1;
        }
        while j < b.len() && b[j].is_ascii_whitespace() {
            j += 1;
        }
        if j < b.len() && b[j] == b'{' {
            if let Some(end) = matching_delim(scrubbed, j) {
                out.push((p, end + 1));
                i = end;
            }
        }
    }
    out
}

/// Byte offsets where each line begins (index 0 = line 1).
pub fn line_starts(src: &str) -> Vec<usize> {
    std::iter::once(0)
        .chain(
            src.bytes()
                .enumerate()
                .filter(|&(_, c)| c == b'\n')
                .map(|(i, _)| i + 1),
        )
        .collect()
}

/// 1-based line number of byte offset `off`.
pub fn line_of(starts: &[usize], off: usize) -> u32 {
    starts.partition_point(|&s| s <= off) as u32
}

/// Next whole-word occurrence of `w` at or after `from`.
pub fn find_word(s: &str, from: usize, w: &str) -> Option<usize> {
    let b = s.as_bytes();
    let mut i = from;
    while let Some(off) = s[i..].find(w) {
        let p = i + off;
        let before_ok = p == 0 || !is_ident_byte(b[p - 1]);
        let after = p + w.len();
        let after_ok = after >= b.len() || !is_ident_byte(b[after]);
        if before_ok && after_ok {
            return Some(p);
        }
        i = p + 1;
    }
    None
}

/// All whole-word occurrences of `w` in `s`.
pub fn words(s: &str, w: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut i = 0;
    while let Some(p) = find_word(s, i, w) {
        out.push(p);
        i = p + w.len();
    }
    out
}

/// First non-whitespace offset at or after `i`.
pub fn skip_ws(s: &str, mut i: usize) -> usize {
    let b = s.as_bytes();
    while i < b.len() && b[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// Offset of the last non-whitespace byte strictly before `i`, or None.
pub fn rskip_ws(s: &str, i: usize) -> Option<usize> {
    let b = s.as_bytes();
    let mut j = i;
    while j > 0 {
        j -= 1;
        if !b[j].is_ascii_whitespace() {
            return Some(j);
        }
    }
    None
}

/// Matching close delimiter for the `{`/`(`/`[` at `open` (scrubbed
/// text only — literals would otherwise unbalance the count).
pub fn matching_delim(s: &str, open: usize) -> Option<usize> {
    let b = s.as_bytes();
    let (o, c) = match b[open] {
        b'{' => (b'{', b'}'),
        b'(' => (b'(', b')'),
        b'[' => (b'[', b']'),
        _ => return None,
    };
    let mut depth = 0i64;
    for (j, &ch) in b.iter().enumerate().skip(open) {
        if ch == o {
            depth += 1;
        } else if ch == c {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
    }
    None
}

/// End (exclusive-ish: offset of the closing `}`) of the innermost
/// block enclosing `pos`, or `s.len()` when `pos` is at top level.
pub fn enclosing_block_end(s: &str, pos: usize) -> usize {
    let b = s.as_bytes();
    let mut depth = 0i64;
    for (j, &ch) in b.iter().enumerate().skip(pos) {
        if ch == b'{' {
            depth += 1;
        } else if ch == b'}' {
            if depth == 0 {
                return j;
            }
            depth -= 1;
        }
    }
    s.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrub_blanks_comments_and_strings_preserving_layout() {
        let src = "let x = \"Instant\"; // Instant here\nlet y = 1;\n";
        let s = scrub(src);
        assert_eq!(s.text.len(), src.len());
        assert_eq!(s.text.matches('\n').count(), 2);
        assert!(!s.text.contains("Instant"));
        assert!(s.text.contains("let x ="));
        assert!(s.text.contains("let y = 1;"));
    }

    #[test]
    fn scrub_handles_raw_strings_and_nesting() {
        let src = "let a = r#\"has \"quotes\" and .reserve(\"#; /* outer /* inner */ still */ let b = 2;";
        let s = scrub(src);
        assert!(!s.text.contains("reserve"));
        assert!(!s.text.contains("inner"));
        assert!(s.text.contains("let b = 2;"));
    }

    #[test]
    fn char_literals_blank_but_lifetimes_survive() {
        let src = "fn f<'a>(x: &'a str) { let c = '\"'; let d = 'x'; }";
        let s = scrub(src);
        assert!(s.text.contains("<'a>"), "lifetime kept: {}", s.text);
        assert!(s.text.contains("&'a str"));
        assert!(!s.text.contains("'x'"));
        // The '"' char literal must not open a string state.
        assert!(s.text.contains("let d ="));
    }

    #[test]
    fn suppression_parses_rule_and_justification() {
        let src = "x(); // lint: allow(wall-clock) — host telemetry only\ny();\n// lint: allow(grant-discipline)\nz();\n";
        let s = scrub(src);
        assert_eq!(s.suppressions.len(), 2);
        let a = &s.suppressions[0];
        assert_eq!((a.line, a.standalone, a.justified), (1, false, true));
        assert_eq!(a.rule, "wall-clock");
        let b = &s.suppressions[1];
        assert_eq!((b.line, b.standalone, b.justified), (3, true, false));
        assert_eq!(b.rule, "grant-discipline");
    }

    #[test]
    fn prose_mentioning_the_syntax_is_not_a_suppression() {
        let src = "//! Use `lint: allow(rule)` comments to suppress findings.\nfn f() {}\n";
        assert!(scrub(src).suppressions.is_empty());
    }

    #[test]
    fn test_regions_cover_cfg_test_mod() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.reserve(1); }\n}\nfn after() {}\n";
        let s = scrub(src);
        assert_eq!(s.test_regions.len(), 1);
        let p = s.text.find("reserve").unwrap();
        assert!(s.in_test_region(p));
        let q = s.text.find("live").unwrap();
        assert!(!s.in_test_region(q));
        let r = s.text.find("after").unwrap();
        assert!(!s.in_test_region(r));
    }

    #[test]
    fn cfg_attr_is_not_a_test_region() {
        let src = "#[cfg_attr(test, derive(Debug))]\nstruct S;\n#[cfg(test)]\nuse foo;\nfn f() {}\n";
        assert!(scrub(src).test_regions.is_empty());
    }

    #[test]
    fn word_search_respects_boundaries() {
        let s = "reserve reserved my_reserve .reserve(";
        let hits = words(s, "reserve");
        assert_eq!(hits.len(), 2);
        assert_eq!(line_of(&line_starts("a\nb\nc"), 4), 3);
    }
}
