//! `ata-sim` — CLI for the ATA-Cache reproduction.
//!
//! Subcommands:
//!   run        — simulate one application on one L1 organization
//!   multi      — co-execute N applications on partitioned cores
//!   contention — per-resource stall breakdown across L1 organizations
//!   bench      — perf-trajectory baseline: pinned workload per organization
//!   sweep      — architectures × applications sweep (Fig 8 driver)
//!   cosched    — app-pair × architecture interference sweep
//!   classify   — inter-core locality classification pipeline
//!   landscape  — regenerate Table I from a measured sweep
//!   overhead   — §IV-D hardware overhead model
//!   lint       — source-level contract lints (determinism/accounting)
//!   list       — list application models and registered organizations
//!   config     — dump the Table II configuration as JSON

use std::io::Write;
use std::sync::{Mutex, PoisonError};

use ata_cache::analysis;
use ata_cache::area;
use ata_cache::bench_harness::{compare_thread_counts, sim_throughput};
use ata_cache::config::{FaultKind, GpuConfig, L1ArchKind};
use ata_cache::coordinator::{landscape, CoSchedSweep, Sweep};
use ata_cache::core::CorePartition;
use ata_cache::engine::{Engine, MultiWorkload};
use ata_cache::exec::{
    job_seed, manifest_line, parse_manifest, ConfigVariant, JobError, JobOutput, JobRunner,
    ResumeCache, ScenarioGrid, SimJob,
};
use ata_cache::runtime::LocalityAnalyzer;
use ata_cache::stats::{MultiResult, ResourceClass, RunTotals, SimResult};
use ata_cache::trace::signature::{exact_locality, sample_core_traces};
use ata_cache::trace::{apps, co_workload, AppModel, LocalityClass};
use ata_cache::util::cli::{Args, CliError};
use ata_cache::util::json::Json;
use ata_cache::util::table::{pct_delta, BarChart, Table};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("multi") => cmd_multi(&args),
        Some("contention") => cmd_contention(&args),
        Some("bench") => cmd_bench(&args),
        Some("export-trace") => cmd_export_trace(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("cosched") => cmd_cosched(&args),
        Some("classify") => cmd_classify(&args),
        Some("landscape") => cmd_landscape(&args),
        Some("overhead") => cmd_overhead(&args),
        Some("lint") => cmd_lint(&args),
        Some("list") => cmd_list(),
        Some("config") => cmd_config(&args),
        _ => {
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    eprintln!(
        "usage: ata-sim <run|multi|contention|bench|sweep|cosched|classify|landscape|overhead|lint|list|config> [options]
  run       --app <name> | --trace FILE
            --arch <private|remote|decoupled|ata|ata-bypass>
            [--scale F] [--seed N] [--out FILE]
  multi     --apps a,b[,c..] [--partition n,m,..] [--arch X] [--scale F]
            [--share-addr] [--seed N] [--threads N] [--out FILE]
  contention [--apps x,y,.. | --app <name>] [--archs a,b,..] [--scale F]
            [--seed N] [--out FILE]
  bench     [--app <name>] [--scale F] [--seed N] [--threads N] [--shards N]
            [--mem-workers N] [--out FILE=BENCH_pr9.json]
  export-trace --app <name> [--scale F] --out FILE
  sweep     [--archs a,b,..] [--apps x,y,..] [--scale F] [--threads N] [--out FILE]
            [--manifest FILE] [--resume FILE] [--inject kind:label,..]
  cosched   [--archs a,b,..] [--apps x,y,..] [--scale F] [--threads N]
            [--share-addr] [--out FILE] [--manifest FILE] [--resume FILE]
  classify  [--apps x,y,..] [--artifacts DIR]
  landscape [--scale F] [--threads N]
  overhead
  lint      [--json] [--root DIR]
  config    [--out FILE]

--threads defaults to the host's available parallelism; results are
byte-identical for any value (deterministic execution layer).
--residency <on|off> overrides sharing.residency_index (the O(1) ATA
probe index); simulated metrics are byte-identical either way.  `bench`
ignores it: its A/B grid always runs both modes.
--event-driven <on|off> overrides engine.event_driven (clock jumps to
the next-event horizon vs the cycle-by-cycle reference); simulated
metrics are byte-identical either way.  `bench` ignores it too: its
A/B grid always runs both modes.
--shards N overrides engine.shards (cluster-sharded engine loop across
host cores; clamped to the cluster count).  Defaults to 1, the
sequential loop — sharding is opt-in until its barrier cost is
measured.  Results are byte-identical at any shard count.  `bench`
uses it as the shard count of its shards-{1,N} A/B pair.
--mem-workers N overrides engine.mem_workers (slice-parallel memory
walk: per-L2-slice fetch resolution fans out across N persistent
worker threads; clamped to the slice count).  Defaults to 1, the
serial walk — like --shards it is opt-in.  Results are byte-identical
at any worker count and compose with --shards.  `bench` uses it as
the worker count of its mem-workers-{1,N} A/B pair.
--job-timeout-s N arms an opt-in host wall-clock watchdog per engine
run; a stuck job aborts with a typed host-timeout failure instead of
hanging the sweep (0 = off, the default).
Fault isolation: a failing job never aborts a sweep/cosched grid — it
lands in the serialized `failures` array (typed, with a diagnostic
snapshot) and the command exits 3 ('completed with failures'; 1 = hard
error, 2 = usage error).  --manifest FILE appends one JSONL line per
completed job; --resume FILE skips jobs already in such a manifest and
reproduces the fresh run's output byte-for-byte.  --inject
<deadlock|livelock|panic>:<label-substring> (sweep only) arms fault
hooks on matching jobs — a CI/test surface, never a real experiment."
    );
}

/// Every malformed flag value funnels through here: print `error: …`
/// and exit 2, the same contract as the `Args::from_env` arm in
/// [`main`] — scripts see one uniform usage-error path instead of a
/// panic backtrace for some flags and a clean message for others.
fn flag_error(msg: impl std::fmt::Display) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(2)
}

/// Unwrap a typed getter (`--seed`, `--scale`, `--threads`, …) or route
/// its [`CliError`] through [`flag_error`].
fn parsed<T>(r: Result<T, CliError>) -> T {
    r.unwrap_or_else(|e| flag_error(e))
}

/// Resolve one `--arch`/`--archs` entry under the flag-error contract.
fn arch_arg(name: &str) -> L1ArchKind {
    L1ArchKind::from_name(name)
        .unwrap_or_else(|| flag_error(format!("unknown arch '{name}' (see `ata-sim list`)")))
}

/// Resolve one `--apps` entry under the flag-error contract.
fn app_arg(name: &str) -> AppModel {
    apps::app(name)
        .unwrap_or_else(|| flag_error(format!("unknown app '{name}' (see `ata-sim list`)")))
}

fn parse_cfg(args: &Args, arch: L1ArchKind) -> GpuConfig {
    let mut cfg = if let Some(path) = args.get("config") {
        GpuConfig::load(path).unwrap_or_else(|e| flag_error(format!("--config {path}: {e}")))
    } else {
        GpuConfig::paper(arch)
    };
    cfg.l1_arch = arch;
    cfg.seed = parsed(args.get_u64("seed", cfg.seed));
    residency_override(args, &mut cfg);
    event_driven_override(args, &mut cfg);
    shards_override(args, &mut cfg);
    mem_workers_override(args, &mut cfg);
    job_timeout_override(args, &mut cfg);
    cfg
}

/// Apply the global `--residency on|off` override to a config.  Called
/// from every config-construction path (`parse_cfg` and the sweep
/// builders) so the flag is never silently ignored; `bench` alone skips
/// it because its A/B grid sets the flag per variant.
fn residency_override(args: &Args, cfg: &mut GpuConfig) {
    if let Some(v) = args.get("residency") {
        cfg.sharing.residency_index = match v {
            "on" => true,
            "off" => false,
            other => flag_error(format!("--residency expects on|off, got '{other}'")),
        };
    }
}

/// Apply the global `--event-driven on|off` override to a config —
/// the engine-clock twin of [`residency_override`], with the same
/// call-site contract (every config-construction path; `bench` sets the
/// flag per variant instead).
fn event_driven_override(args: &Args, cfg: &mut GpuConfig) {
    if let Some(v) = args.get("event-driven") {
        cfg.engine.event_driven = match v {
            "on" => true,
            "off" => false,
            other => flag_error(format!("--event-driven expects on|off, got '{other}'")),
        };
    }
}

/// Apply the global `--shards N` override to a config — the third knob
/// in the host-strategy family after [`residency_override`] and
/// [`event_driven_override`], with the same call-site contract.  Only
/// set when the option is present so a `--config` file's
/// `engine.shards` survives an override-free invocation; `bench` skips
/// it for the base grid but honours it for the shard variant's N.
fn shards_override(args: &Args, cfg: &mut GpuConfig) {
    if args.get("shards").is_some() {
        cfg.engine.shards = parsed(args.get_shards());
    }
}

/// Apply the global `--mem-workers N` override to a config — the
/// fourth knob in the host-strategy family, with the same call-site
/// contract as [`shards_override`]: only set when the option is
/// present so a `--config` file's `engine.mem_workers` survives an
/// override-free invocation; `bench` skips it for the base grid but
/// honours it for the mem-workers variant's N.
fn mem_workers_override(args: &Args, cfg: &mut GpuConfig) {
    if args.get("mem-workers").is_some() {
        cfg.engine.mem_workers = parsed(args.get_mem_workers());
    }
}

/// Apply the opt-in `--job-timeout-s N` host watchdog to a config —
/// fifth knob in the host-strategy family, same call-site contract.
/// Zero (the default) disables the watchdog; a nonzero budget aborts a
/// stuck run with `SimError::HostTimeout` instead of hanging the sweep.
fn job_timeout_override(args: &Args, cfg: &mut GpuConfig) {
    if args.get("job-timeout-s").is_some() {
        cfg.engine.job_timeout_s = parsed(args.get_u64("job-timeout-s", 0));
    }
}

/// Report a grid's degradations and failures on stderr and map them to
/// the exit code: 0 when clean, 3 — "completed with failures", distinct
/// from 1 (hard error) and 2 (usage error) — when any job failed.  The
/// partial results have already been printed/saved by the time this
/// runs.
fn failures_exit(failures: &[JobError], degraded: &[String]) -> i32 {
    for label in degraded {
        eprintln!("note: '{label}' recovered on the serial degradation retry (host flake?)");
    }
    if failures.is_empty() {
        return 0;
    }
    for f in failures {
        eprintln!("failed: {} [{}]: {}", f.job, f.kind, f.message);
    }
    eprintln!("{} job(s) failed — results above are partial (exit 3)", failures.len());
    3
}

/// Load the `--resume FILE` completed-job manifest when present.
fn resume_cache(args: &Args) -> Option<ResumeCache> {
    args.get("resume").map(|path| match std::fs::read_to_string(path) {
        Ok(text) => parse_manifest(&text),
        Err(e) => flag_error(format!("--resume {path}: {e}")),
    })
}

/// Open the `--manifest FILE` completed-job log (append mode) when
/// present.
fn manifest_sink(args: &Args) -> Option<Mutex<std::fs::File>> {
    args.get("manifest").map(|path| {
        std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map(Mutex::new)
            .unwrap_or_else(|e| flag_error(format!("--manifest {path}: {e}")))
    })
}

/// The manifest observer: one JSONL line per freshly completed job,
/// appended under a lock (workers call this concurrently, in completion
/// order — resume is label-keyed, so line order is irrelevant).
fn manifest_writer(sink: &Mutex<std::fs::File>) -> impl Fn(&SimJob, &JobOutput) + Sync + '_ {
    move |job, out| {
        let mut f = sink.lock().unwrap_or_else(PoisonError::into_inner);
        let _ = writeln!(f, "{}", manifest_line(&job.label, out));
    }
}

/// Arm `--inject <deadlock|livelock|panic>:<label-substring>[,..]` fault
/// hooks on the matching jobs.  A test/CI surface: it proves the grid
/// completes *around* failing jobs (the poisoned-grid smoke) — real
/// experiments never set it.
fn apply_injections(args: &Args, jobs: &mut [SimJob]) {
    for spec in args.get_list("inject") {
        let Some((kind, needle)) = spec.split_once(':') else {
            flag_error(format!("--inject expects kind:label-substring, got '{spec}'"));
        };
        let Some(fault) = FaultKind::from_name(kind) else {
            flag_error(format!("--inject kind must be deadlock|livelock|panic, got '{kind}'"));
        };
        let mut hit = false;
        for job in jobs.iter_mut().filter(|j| j.label.contains(needle)) {
            job.cfg.engine.fault = fault;
            hit = true;
        }
        if !hit {
            flag_error(format!("--inject '{spec}' matches no job label"));
        }
    }
}

fn cmd_run(args: &Args) -> i32 {
    let arch = arch_arg(args.get_or("arch", "ata"));
    let scale = parsed(args.get_f64("scale", 1.0));
    let cfg = parse_cfg(args, arch);
    let (app_name, wl) = if let Some(path) = args.get("trace") {
        let wl = ata_cache::trace::io::load(path)
            .unwrap_or_else(|e| flag_error(format!("--trace {path}: {e}")));
        (wl.name.clone(), wl)
    } else {
        let name = args.get_or("app", "b+tree").to_string();
        let Some(app) = apps::app(&name) else {
            eprintln!("unknown app '{name}' (see `ata-sim list`)");
            return 2;
        };
        (name, app.scaled(scale).workload(&cfg))
    };
    println!(
        "running {app_name} on {} ({} kernels, {} requests)…",
        arch.name(),
        wl.kernels.len(),
        wl.total_requests()
    );
    let mut eng = Engine::new(&cfg);
    let r = match eng.run(&wl) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {app_name} on {}: {e}", arch.name());
            return 1;
        }
    };
    println!("{}", r.to_json().pretty());
    // Host-performance telemetry of the residency index, on stderr so
    // stdout stays pipeable result JSON (and the result itself stays
    // byte-identical whether the index is on or off).
    let rs = eng.residency_stats();
    if rs.index_probes + rs.scan_probes > 0 {
        eprintln!("residency telemetry: {}", rs.to_json());
    }
    // Same contract for the engine-clock telemetry: stderr only, never
    // part of the result JSON.
    eprintln!("engine telemetry: {}", eng.event_stats().to_json());
    // And for the shard counters, when the sharded loop actually ran.
    let ss = eng.shard_stats();
    if ss.shard_count > 1 {
        eprintln!("shard telemetry: {}", ss.to_json());
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, r.to_json().pretty()).expect("writing --out");
        println!("wrote {path}");
    }
    0
}

/// Co-execute N applications on partitioned cores and report per-app
/// IPC, slowdown vs. solo execution on the same cores, and an
/// interference summary over the shared memory system.
fn cmd_multi(args: &Args) -> i32 {
    let arch = arch_arg(args.get_or("arch", "ata"));
    let scale = parsed(args.get_f64("scale", 0.5));
    let cfg = parse_cfg(args, arch);
    let names = args.get_list("apps");
    if names.len() < 2 {
        eprintln!("multi needs --apps with at least two comma-separated names");
        return 2;
    }
    let mut models = Vec::new();
    for name in &names {
        let Some(app) = apps::app(name) else {
            eprintln!("unknown app '{name}' (see `ata-sim list`)");
            return 2;
        };
        models.push(app.scaled(scale));
    }
    let sizes: Vec<usize> = if args.get("partition").is_some() {
        let parsed: Result<Vec<usize>, _> =
            args.get_list("partition").iter().map(|s| s.parse()).collect();
        match parsed {
            Ok(v) => v,
            Err(_) => {
                eprintln!("--partition expects comma-separated core counts, e.g. 8,8");
                return 2;
            }
        }
    } else {
        // Even split over the whole GPU.
        match CorePartition::even(cfg.cores, models.len()) {
            Ok(parts) => parts.iter().map(|p| p.count).collect(),
            Err(e) => {
                eprintln!("cannot partition cores: {e}");
                return 2;
            }
        }
    };
    let share = args.flag("share-addr");
    let multi = match co_workload(&cfg, &models, &sizes, share) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("cannot build co-workload: {e}");
            return 2;
        }
    };
    println!(
        "co-running {} on {} ({} requests{}) …",
        multi.name,
        arch.name(),
        multi.total_requests(),
        if share { ", shared address space" } else { "" }
    );
    let co = match Engine::new(&cfg).run_multi(&multi) {
        Ok(co) => co,
        Err(e) => {
            eprintln!("error: {} on {}: {e}", multi.name, arch.name());
            return 1;
        }
    };

    // Solo baselines: each lane alone on exactly its cores and address
    // space, the rest of the GPU idle.  One job per lane on the
    // execution layer; results come back in lane order.
    let solo_jobs: Vec<SimJob> = multi
        .lanes
        .iter()
        .enumerate()
        .map(|(i, lane)| {
            SimJob::multi(
                format!("solo/{}", lane.name),
                cfg.clone(),
                job_seed(cfg.seed, i),
                MultiWorkload {
                    name: lane.name.clone(),
                    lanes: vec![lane.clone()],
                },
            )
        })
        .collect();
    let mut solos: Vec<MultiResult> = Vec::with_capacity(solo_jobs.len());
    for out in JobRunner::new(parsed(args.get_threads())).run(&solo_jobs) {
        match out {
            JobOutput::Failed(e) => {
                eprintln!("error: solo baseline '{}' [{}]: {}", e.job, e.kind, e.message);
                return 1;
            }
            other => solos.push(other.into_multi()),
        }
    }

    let mut t = Table::new(&format!("co-execution — {} on {}", multi.name, arch.name()))
        .header(&[
            "app", "cores", "co IPC", "solo IPC", "norm IPC", "slowdown", "load lat", "requests",
        ]);
    for (app, solo) in co.apps.iter().zip(&solos) {
        let solo_ipc = solo.apps[0].ipc();
        let norm = if solo_ipc > 0.0 { app.ipc() / solo_ipc } else { 0.0 };
        let slow = if app.ipc() > 0.0 { solo_ipc / app.ipc() } else { 0.0 };
        t.row(vec![
            app.name.clone(),
            format!("{}..{}", app.first_core, app.first_core + app.cores),
            format!("{:.3}", app.ipc()),
            format!("{solo_ipc:.3}"),
            format!("{norm:.3}"),
            format!("{slow:.3}x"),
            format!("{:.1}", app.mean_load_latency),
            app.requests.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "interference summary: agg IPC {:.3} | L1 hit {:.1}% (local {:.1}%, remote hits {}) | \
         bank-conflict cyc {} | sharing-net cyc {} | probes {} | L2 hit {:.1}% | dram r/w {}/{}",
        co.ipc(),
        co.l1.hit_rate() * 100.0,
        co.l1.local_hit_rate() * 100.0,
        co.l1.remote_hits,
        co.l1.bank_conflict_cycles,
        co.l1.sharing_net_cycles,
        co.l1.probes_sent,
        co.l2_hit_rate * 100.0,
        co.dram_reads,
        co.dram_writes,
    );
    let mut st = Table::new("per-resource stall breakdown (queued cycles per app)")
        .header(&{
            let mut h = vec!["app"];
            h.extend(ResourceClass::ALL.iter().map(|c| c.name()));
            h.push("total");
            h
        });
    for app in &co.apps {
        let mut cells = vec![app.name.clone()];
        cells.extend(ResourceClass::ALL.iter().map(|&c| app.contention.get(c).to_string()));
        cells.push(app.contention.total().to_string());
        st.row(cells);
    }
    println!("{}", st.render());
    if let Some(path) = args.get("out") {
        let json = Json::obj(vec![
            ("co", co.to_json()),
            ("solos", Json::arr(solos.iter().map(MultiResult::to_json).collect())),
        ]);
        std::fs::write(path, json.pretty()).expect("writing --out");
        println!("wrote {path}");
    }
    0
}

/// Per-resource stall-breakdown comparison: where does each registered
/// organization burn its cycles for a given application (the paper's
/// Fig. 3 / Fig. 11 style contention analysis)?
fn cmd_contention(args: &Args) -> i32 {
    let scale = parsed(args.get_f64("scale", 0.25));
    let archs: Vec<L1ArchKind> = {
        let l = args.get_list("archs");
        if l.is_empty() {
            L1ArchKind::ALL.to_vec()
        } else {
            l.iter().map(|a| arch_arg(a)).collect()
        }
    };
    let names: Vec<String> = {
        let l = args.get_list("apps");
        if l.is_empty() {
            vec![args.get_or("app", "b+tree").to_string()]
        } else {
            l
        }
    };
    let mut all_results: Vec<SimResult> = Vec::new();
    for name in &names {
        let Some(app) = apps::app(name) else {
            eprintln!("unknown app '{name}' (see `ata-sim list`)");
            return 2;
        };
        let mut results: Vec<(L1ArchKind, SimResult)> = Vec::with_capacity(archs.len());
        for &arch in &archs {
            let cfg = parse_cfg(args, arch);
            let wl = app.scaled(scale).workload(&cfg);
            match Engine::new(&cfg).run(&wl) {
                Ok(r) => results.push((arch, r)),
                Err(e) => {
                    eprintln!("error: {name} on {}: {e}", arch.name());
                    return 1;
                }
            }
        }

        let mut header: Vec<&str> = vec!["resource"];
        header.extend(archs.iter().map(|a| a.name()));
        let mut t = Table::new(&format!(
            "per-resource stall breakdown — {name} (queued cycles)"
        ))
        .header(&header);
        for class in ResourceClass::ALL {
            let mut cells = vec![class.name().to_string()];
            cells.extend(results.iter().map(|(_, r)| r.contention.get(class).to_string()));
            t.row(cells);
        }
        let mut total = vec!["total".to_string()];
        total.extend(results.iter().map(|(_, r)| r.contention.total().to_string()));
        t.row(total);
        let mut per_kinst = vec!["stall cyc / 1k inst".to_string()];
        per_kinst.extend(results.iter().map(|(_, r)| {
            if r.insts == 0 {
                "0.0".to_string()
            } else {
                format!("{:.1}", r.contention.total() as f64 * 1000.0 / r.insts as f64)
            }
        }));
        t.row(per_kinst);
        let mut ipc = vec!["ipc".to_string()];
        ipc.extend(results.iter().map(|(_, r)| format!("{:.3}", r.ipc())));
        t.row(ipc);
        println!("{}", t.render());
        all_results.extend(results.into_iter().map(|(_, r)| r));
    }
    if let Some(path) = args.get("out") {
        let json = Json::arr(all_results.iter().map(SimResult::to_json).collect());
        std::fs::write(path, json.pretty()).expect("writing --out");
        println!("wrote {path}");
    }
    0
}

/// Perf-trajectory baseline (`BENCH_pr9.json`): run one pinned, seeded
/// workload on every registered L1 organization **five times** — the
/// full-speed engine, the cycle-by-cycle reference (`event_driven`
/// off), the residency scan path (`residency_index` off), the
/// cluster-sharded loop (`engine.shards` = N, default 2), and the
/// slice-parallel memory walk (`engine.mem_workers` = N, default 2),
/// each a [`ConfigVariant`] ablation axis — and report wall seconds,
/// simulated cycles per host second, IPC, and four per-org speedups:
/// the event-driven speedup (reference s / event s), the
/// carried-forward residency-index speedup, the shard speedup
/// (unsharded s / sharded s), and the new memory-walk speedup
/// (serial-walk s / fanned-out s).  All four A/B pairs must produce
/// byte-identical simulated metrics (the determinism contract); any
/// drift exits 1.
/// Also reports the serial-vs-parallel wall-clock speedup of a
/// co-scheduling grid, proving the [`JobRunner`] both helps and stays
/// deterministic.  Future PRs compare against this file to catch
/// host-performance regressions of the simulator itself.
fn cmd_bench(args: &Args) -> i32 {
    let scale = parsed(args.get_f64("scale", 0.25));
    let app_name = args.get_or("app", "b+tree").to_string();
    let Some(app) = apps::app(&app_name) else {
        eprintln!("unknown app '{app_name}' (see `ata-sim list`)");
        return 2;
    };
    let out_path = args.get_or("out", "BENCH_pr9.json").to_string();
    let seed = parsed(args.get_u64("seed", GpuConfig::default().seed));
    let threads = parsed(args.get_threads());
    // The B side of the shards-{1,N} pair; `--shards 1` (or absent)
    // still benches against 2 so the pair is never degenerate.
    let shards = parsed(args.get_shards()).max(2);
    // Same rule for the mem-workers-{1,N} pair.
    let mem_workers = parsed(args.get_mem_workers()).max(2);
    if args.get("residency").is_some() {
        eprintln!("note: bench ignores --residency — its A/B grid always runs both modes");
    }
    if args.get("event-driven").is_some() {
        eprintln!("note: bench ignores --event-driven — its A/B grid always runs both modes");
    }

    // Engine-clock + residency + sharding + memory-walk A/B: the
    // registry as a one-app scenario grid with a five-way variant axis.
    // EV_ON is the production configuration and the baseline every
    // speedup is measured against; EV_OFF ablates only the
    // event-driven clock (cycle-by-cycle reference), RES_OFF ablates
    // only the residency index, SHARD turns only the cluster-sharded
    // loop on, and MEMW turns only the slice-parallel memory walk on.
    // Jobs materialize variant-major, so the results come back as five
    // registry-ordered chunks of `n_orgs`.
    const EV_ON: ConfigVariant = ConfigVariant {
        name: "event-on",
        apply: |c| {
            c.engine.event_driven = true;
            c.sharing.residency_index = true;
        },
    };
    const EV_OFF: ConfigVariant = ConfigVariant {
        name: "event-off",
        apply: |c| {
            c.engine.event_driven = false;
            c.sharing.residency_index = true;
        },
    };
    const RES_OFF: ConfigVariant = ConfigVariant {
        name: "residency-off",
        apply: |c| {
            c.engine.event_driven = true;
            c.sharing.residency_index = false;
        },
    };
    const SHARD: ConfigVariant = ConfigVariant {
        name: "sharded",
        apply: |c| {
            c.engine.event_driven = true;
            c.sharing.residency_index = true;
            c.engine.shards = 2;
        },
    };
    const MEMW: ConfigVariant = ConfigVariant {
        name: "mem-workers",
        apply: |c| {
            c.engine.event_driven = true;
            c.sharing.residency_index = true;
            c.engine.mem_workers = 2;
        },
    };
    let mut base_cfg = GpuConfig::paper(L1ArchKind::Private);
    base_cfg.seed = seed;
    let grid = ScenarioGrid::new(
        base_cfg.clone(),
        ata_cache::l1arch::REGISTRY.iter().map(|s| s.kind).collect(),
        vec![app.clone()],
        scale,
    )
    .with_variants(vec![EV_ON, EV_OFF, RES_OFF, SHARD, MEMW]);
    let n_orgs = ata_cache::l1arch::REGISTRY.len();
    let mut jobs = grid.jobs();
    // `apply` is a plain fn pointer, so the user's `--shards N` /
    // `--mem-workers N` cannot be captured in the SHARD / MEMW
    // variants; patch the materialized chunks (variant-major order:
    // chunk 3 is SHARD, chunk 4 is MEMW) instead.
    for job in jobs.iter_mut().skip(3 * n_orgs).take(n_orgs) {
        job.cfg.engine.shards = shards;
    }
    for job in jobs.iter_mut().skip(4 * n_orgs) {
        job.cfg.engine.mem_workers = mem_workers;
    }
    // The A/B grid runs on ONE worker: per-job `host_seconds` is the
    // timing signal here, and concurrent jobs on a shared pool would
    // contaminate each chunk with whatever co-runner mix it happened to
    // get (the baseline chunk always submits first).  Serial execution
    // makes the speedups measure the ablated feature, not the scheduler;
    // the cosched section below still exercises the parallel runner
    // with --threads.
    let mut results: Vec<SimResult> = Vec::with_capacity(jobs.len());
    for out in JobRunner::new(1).run(&jobs) {
        match out {
            JobOutput::Failed(e) => {
                // The bench grid is a fixed healthy configuration set: a
                // failure here is a simulator bug, not an experiment
                // outcome — hard error, no partial baseline file.
                eprintln!("error: bench job '{}' [{}]: {}", e.job, e.kind, e.message);
                return 1;
            }
            other => results.push(other.into_solo()),
        }
    }
    let (on_chunk, rest) = results.split_at(n_orgs);
    let (ref_chunk, rest) = rest.split_at(n_orgs);
    let (scan_chunk, rest) = rest.split_at(n_orgs);
    let (shard_chunk, memw_chunk) = rest.split_at(n_orgs);

    let mut t = Table::new(&format!(
        "perf baseline — {app_name} @ scale {scale}, seed {seed:#x}, {shards} shards, \
         {mem_workers} mem workers (A/B timed serially)"
    ))
    .header(&[
        "arch", "cycles", "insts", "IPC", "ev s", "ref s", "scan s", "shrd s", "memw s",
        "Mcyc/s", "ev x", "idx x", "sh x", "mw x",
    ]);
    let mut chart = BarChart::new("event-driven speedup per organization (ref s / ev s)");
    let mut rows = Vec::new();
    let mut totals = RunTotals::default();
    let mut ev_identical = true;
    let mut res_identical = true;
    let mut sh_identical = true;
    let mut mw_identical = true;
    let registry = ata_cache::l1arch::REGISTRY.iter();
    for (((((spec, on), reference), scan), sharded), memwalk) in registry
        .zip(on_chunk)
        .zip(ref_chunk)
        .zip(scan_chunk)
        .zip(shard_chunk)
        .zip(memw_chunk)
    {
        totals.absorb_sim(on);
        // The referees: identical simulated metrics against every
        // ablation (result JSON excludes wall clock by the determinism
        // contract).
        let on_json = on.to_json().pretty();
        let identical = on_json == reference.to_json().pretty();
        let r_identical = on_json == scan.to_json().pretty();
        let s_identical = on_json == sharded.to_json().pretty();
        let m_identical = on_json == memwalk.to_json().pretty();
        ev_identical &= identical;
        res_identical &= r_identical;
        sh_identical &= s_identical;
        mw_identical &= m_identical;
        let thru = sim_throughput(on.cycles, on.host_seconds);
        let ratio = |ablated: f64| {
            if on.host_seconds > 0.0 {
                ablated / on.host_seconds
            } else {
                0.0
            }
        };
        let speedup = ratio(reference.host_seconds);
        let res_speedup = ratio(scan.host_seconds);
        // The sharded and memory-walk runs are candidates, not
        // ablations: their speedups are baseline-over-candidate (> 1
        // means the knob paid for its synchronization on this host and
        // workload).
        let shard_speedup = if sharded.host_seconds > 0.0 {
            on.host_seconds / sharded.host_seconds
        } else {
            0.0
        };
        let memwalk_speedup = if memwalk.host_seconds > 0.0 {
            on.host_seconds / memwalk.host_seconds
        } else {
            0.0
        };
        t.row(vec![
            spec.name.to_string(),
            on.cycles.to_string(),
            on.insts.to_string(),
            format!("{:.3}", on.ipc()),
            format!("{:.3}", on.host_seconds),
            format!("{:.3}", reference.host_seconds),
            format!("{:.3}", scan.host_seconds),
            format!("{:.3}", sharded.host_seconds),
            format!("{:.3}", memwalk.host_seconds),
            format!("{:.2}", thru / 1e6),
            format!("{speedup:.2}x"),
            format!("{res_speedup:.2}x"),
            format!("{shard_speedup:.2}x"),
            format!("{memwalk_speedup:.2}x"),
        ]);
        chart.bar(spec.name, speedup);
        rows.push(Json::obj(vec![
            ("arch", spec.name.into()),
            ("cycles", on.cycles.into()),
            ("insts", on.insts.into()),
            ("ipc", on.ipc().into()),
            ("host_seconds", on.host_seconds.into()),
            ("host_seconds_reference", reference.host_seconds.into()),
            ("host_seconds_scan", scan.host_seconds.into()),
            ("host_seconds_sharded", sharded.host_seconds.into()),
            ("cycles_per_sec", thru.into()),
            (
                "cycles_per_sec_reference",
                sim_throughput(reference.cycles, reference.host_seconds).into(),
            ),
            ("speedup", speedup.into()),
            ("identical", identical.into()),
            ("residency_speedup", res_speedup.into()),
            ("residency_identical", r_identical.into()),
            ("shard_speedup", shard_speedup.into()),
            ("shard_identical", s_identical.into()),
            ("host_seconds_memwalk", memwalk.host_seconds.into()),
            ("memwalk_speedup", memwalk_speedup.into()),
            ("memwalk_identical", m_identical.into()),
        ]));
    }
    println!("{}", t.render());
    println!("{}", chart.render());
    println!("event-driven vs reference metrics byte-identical: {ev_identical}");
    println!("index-on vs scan metrics byte-identical: {res_identical}");
    println!("{shards}-shard vs unsharded metrics byte-identical: {sh_identical}");
    println!("{mem_workers}-worker walk vs serial walk metrics byte-identical: {mw_identical}");

    // Serial-vs-parallel wall clock on a co-scheduling grid (the N²
    // surface the execution layer exists for), with the byte-identity
    // check the determinism contract demands.
    let partner_name = if app_name == "streamcluster" { "b+tree" } else { "streamcluster" };
    let partner = apps::app(partner_name).expect("registered partner app");
    let mut cs = CoSchedSweep {
        cfg: base_cfg,
        archs: vec![L1ArchKind::Private, L1ArchKind::Ata],
        apps: vec![app.clone(), partner],
        scale,
        threads: 1,
        share_address_space: false,
    };
    let cs_jobs = cs.job_count();
    let speedup = compare_thread_counts(cs_jobs, threads, |n| {
        cs.threads = n;
        cs.run().to_json().pretty()
    });
    println!(
        "cosched grid ({} jobs: {app_name}+{partner_name} × private/ata): serial {:.2}s → \
         {} threads {:.2}s = {:.2}x speedup | outputs byte-identical: {}",
        speedup.jobs,
        speedup.serial_seconds,
        speedup.threads,
        speedup.parallel_seconds,
        speedup.speedup(),
        speedup.identical,
    );

    let json = Json::obj(vec![
        ("bench", "pr9".into()),
        ("app", app_name.as_str().into()),
        ("scale", scale.into()),
        ("seed", seed.into()),
        ("threads", threads.into()),
        ("shards", shards.into()),
        ("mem_workers", mem_workers.into()),
        ("orgs", Json::arr(rows)),
        ("event_driven_ab_identical", ev_identical.into()),
        ("residency_ab_identical", res_identical.into()),
        ("shard_ab_identical", sh_identical.into()),
        ("memwalk_ab_identical", mw_identical.into()),
        ("totals", totals.to_json()),
        ("cosched_speedup", speedup.to_json()),
    ]);
    std::fs::write(&out_path, json.pretty()).expect("writing bench output");
    println!("wrote {out_path}");
    if !ev_identical {
        eprintln!("error: event-driven run drifted from the cycle-by-cycle reference");
        return 1;
    }
    if !res_identical {
        eprintln!("error: residency-index run drifted from the scan run");
        return 1;
    }
    if !sh_identical {
        eprintln!("error: sharded run drifted from the unsharded engine");
        return 1;
    }
    if !mw_identical {
        eprintln!("error: slice-parallel walk drifted from the serial walk");
        return 1;
    }
    if !speedup.identical {
        eprintln!("error: parallel cosched output drifted from the serial run");
        return 1;
    }
    0
}

/// App-pair × architecture interference sweep (CIAO-style matrix).
fn cmd_cosched(args: &Args) -> i32 {
    let scale = parsed(args.get_f64("scale", 0.25));
    let mut sweep = CoSchedSweep::paper(scale);
    residency_override(args, &mut sweep.cfg);
    event_driven_override(args, &mut sweep.cfg);
    shards_override(args, &mut sweep.cfg);
    mem_workers_override(args, &mut sweep.cfg);
    job_timeout_override(args, &mut sweep.cfg);
    let arch_list = args.get_list("archs");
    if !arch_list.is_empty() {
        sweep.archs = arch_list.iter().map(|a| arch_arg(a)).collect();
    }
    let app_list = args.get_list("apps");
    if !app_list.is_empty() {
        sweep.apps = app_list.iter().map(|n| app_arg(n)).collect();
    }
    sweep.threads = parsed(args.get_threads());
    sweep.share_address_space = args.flag("share-addr");
    let n = sweep.apps.len();
    println!(
        "co-scheduling sweep: {} apps → {} pairs × {} archs ({} sims on {} thread(s))…",
        n,
        n * (n + 1) / 2,
        sweep.archs.len(),
        sweep.job_count(),
        sweep.threads,
    );
    let resume = resume_cache(args);
    let sink = manifest_sink(args);
    let writer = sink.as_ref().map(manifest_writer);
    let observer = writer.as_ref().map(|w| w as &(dyn Fn(&SimJob, &JobOutput) + Sync));
    let results = sweep.run_isolated(resume.as_ref(), observer);
    for &arch in &sweep.archs {
        // Mean slowdown per victim app under this organization.
        let m = results.interference_matrix(arch);
        println!("{}", results.render_matrix_from(arch, &m));
        let means: Vec<String> = results
            .app_names
            .iter()
            .zip(&m)
            .map(|(name, row)| {
                let mean = row.iter().sum::<f64>() / row.len().max(1) as f64;
                format!("{name} {mean:.3}x")
            })
            .collect();
        println!("mean slowdown ({}): {}\n", arch.name(), means.join(" | "));
    }
    if let Some(path) = args.get("out") {
        results.save(path).expect("writing --out");
        println!("wrote {path}");
    }
    failures_exit(&results.failures, &results.degraded)
}

fn sweep_from_args(args: &Args) -> Sweep {
    let scale = parsed(args.get_f64("scale", 0.5));
    let mut sweep = Sweep::paper(scale);
    residency_override(args, &mut sweep.cfg);
    event_driven_override(args, &mut sweep.cfg);
    shards_override(args, &mut sweep.cfg);
    mem_workers_override(args, &mut sweep.cfg);
    job_timeout_override(args, &mut sweep.cfg);
    let arch_list = args.get_list("archs");
    if !arch_list.is_empty() {
        sweep.archs = arch_list.iter().map(|a| arch_arg(a)).collect();
        if !sweep.archs.contains(&L1ArchKind::Private) {
            sweep.archs.insert(0, L1ArchKind::Private); // normalization baseline
        }
    }
    let app_list = args.get_list("apps");
    if !app_list.is_empty() {
        sweep.apps = app_list.iter().map(|n| app_arg(n)).collect();
    }
    sweep.threads = parsed(args.get_threads());
    sweep
}

fn cmd_sweep(args: &Args) -> i32 {
    let sweep = sweep_from_args(args);
    let mut jobs = sweep.grid().jobs();
    apply_injections(args, &mut jobs);
    let resume = resume_cache(args);
    let sink = manifest_sink(args);
    let writer = sink.as_ref().map(manifest_writer);
    let observer = writer.as_ref().map(|w| w as &(dyn Fn(&SimJob, &JobOutput) + Sync));
    let results = sweep.run_jobs(&jobs, resume.as_ref(), observer);

    let mut t = Table::new("normalized IPC (private = 1.0)").header(&[
        "app", "remote", "decoupled", "ata", "ata Δ",
    ]);
    for app in sweep.apps.iter() {
        let g = |a| results.norm_ipc(a, app.name).unwrap_or(0.0);
        t.row(vec![
            app.name.to_string(),
            format!("{:.3}", g(L1ArchKind::RemoteSharing)),
            format!("{:.3}", g(L1ArchKind::DecoupledSharing)),
            format!("{:.3}", g(L1ArchKind::Ata)),
            pct_delta(g(L1ArchKind::Ata)),
        ]);
    }
    println!("{}", t.render());
    for class in [LocalityClass::High, LocalityClass::Low] {
        println!(
            "{class:?}-locality geomean: decoupled {} | ata {}",
            pct_delta(results.class_geomean_ipc(L1ArchKind::DecoupledSharing, class)),
            pct_delta(results.class_geomean_ipc(L1ArchKind::Ata, class)),
        );
    }
    if let Some(path) = args.get("out") {
        results.save(path).expect("writing --out");
        println!("wrote {path}");
    }
    failures_exit(&results.failures, &results.degraded)
}

fn cmd_export_trace(args: &Args) -> i32 {
    let name = args.get_or("app", "b+tree").to_string();
    let scale = parsed(args.get_f64("scale", 1.0));
    let Some(app) = apps::app(&name) else {
        eprintln!("unknown app '{name}'");
        return 2;
    };
    let cfg = parse_cfg(args, L1ArchKind::Private);
    let wl = app.scaled(scale).workload(&cfg);
    let out = args.get_or("out", "trace.json");
    ata_cache::trace::io::save(&wl, out).expect("writing trace");
    println!(
        "wrote {out}: {} kernels, {} requests",
        wl.kernels.len(),
        wl.total_requests()
    );
    0
}

fn cmd_classify(args: &Args) -> i32 {
    let dir = args.get_or("artifacts", "artifacts");
    let analyzer = match LocalityAnalyzer::load(dir) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("cannot load locality artifact: {e:#}");
            return 1;
        }
    };
    let cfg = GpuConfig::paper(L1ArchKind::Private);
    let names = {
        let l = args.get_list("apps");
        if l.is_empty() {
            apps::all_app_names().iter().map(|s| s.to_string()).collect()
        } else {
            l
        }
    };
    let mut t = Table::new("inter-core locality classification (PJRT artifact)").header(&[
        "app", "score", "replication", "class", "paper class", "exact score",
    ]);
    let mut agree = true;
    for name in &names {
        let Some(app) = apps::app(name) else {
            eprintln!("unknown app {name}");
            return 2;
        };
        let wl = app.workload(&cfg);
        let traces = sample_core_traces(&wl, cfg.cores, analyzer.meta().trace_len);
        let report = analyzer.analyze(&traces).expect("artifact execution");
        let (exact, _) = exact_locality(&traces);
        let class = report.class();
        agree &= class == app.class;
        t.row(vec![
            name.clone(),
            format!("{:.3}", report.locality_score),
            format!("{:.2}x", report.replication_factor),
            format!("{:?}", class),
            format!("{:?}", app.class),
            format!("{exact:.3}"),
        ]);
    }
    println!("{}", t.render());
    println!("classification agrees with paper split: {agree}");
    if agree {
        0
    } else {
        1
    }
}

fn cmd_landscape(args: &Args) -> i32 {
    let mut sweep = sweep_from_args(args);
    sweep.archs = L1ArchKind::ALL.to_vec();
    let results = sweep.run();
    let rows = landscape::build(&results, &sweep.archs);
    println!("{}", landscape::render(&rows));
    failures_exit(&results.failures, &results.degraded)
}

fn cmd_overhead(_args: &Args) -> i32 {
    let cfg = GpuConfig::paper(L1ArchKind::Ata);
    let r = area::estimate(&cfg, &area::Tech45::default());
    let mut t = Table::new("ATA-Cache hardware overhead @45nm (§IV-D)").header(&["component", "value"]);
    t.row(vec!["crossbar area".into(), format!("{:.3} mm²", r.crossbar_mm2)]);
    t.row(vec!["comparator area".into(), format!("{:.3} mm²", r.comparator_mm2)]);
    t.row(vec!["total area".into(), format!("{:.3} mm²", r.total_mm2)]);
    t.row(vec!["leakage power".into(), format!("{:.2} mW", r.leakage_mw)]);
    t.row(vec!["comparators".into(), format!("{}", r.comparator_count)]);
    t.row(vec!["die fraction (~500mm²)".into(), format!("{:.3}%", r.die_fraction * 100.0)]);
    println!("{}", t.render());
    0
}

/// `ata-sim lint [--json] [--root DIR]` — run the source-level contract
/// lints (see `rust/src/analysis/`).  Exit 0 when clean, 1 on any
/// finding, 2 when the root cannot be read.
fn cmd_lint(args: &Args) -> i32 {
    let root = std::path::PathBuf::from(args.get_or("root", "."));
    let report = match analysis::run_lint(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: lint walk of {} failed: {e}", root.display());
            return 2;
        }
    };
    if args.flag("json") {
        println!("{}", report.to_json().pretty());
    } else {
        print!("{}", report.render());
    }
    if report.is_clean() {
        0
    } else {
        1
    }
}

fn cmd_list() -> i32 {
    let mut t = Table::new("application models").header(&["app", "suite", "class", "kernels", "notes"]);
    for a in apps::all_apps().into_iter().chain(apps::extra_apps()) {
        t.row(vec![
            a.name.to_string(),
            a.suite.to_string(),
            format!("{:?}", a.class),
            a.kernels.len().to_string(),
            a.notes.chars().take(60).collect::<String>(),
        ]);
    }
    println!("{}", t.render());
    let mut orgs = Table::new("registered L1 organizations").header(&["arch", "summary"]);
    for spec in ata_cache::l1arch::REGISTRY {
        orgs.row(vec![spec.name.to_string(), spec.summary.to_string()]);
    }
    println!("{}", orgs.render());
    0
}

fn cmd_config(args: &Args) -> i32 {
    let cfg = GpuConfig::paper(L1ArchKind::Ata);
    let text = cfg.to_json().pretty();
    if let Some(path) = args.get("out") {
        std::fs::write(path, &text).expect("writing --out");
        println!("wrote {path}");
    } else {
        println!("{text}");
    }
    0
}
