//! Address decode: set/tag/bank/slice/home-cache mapping functions.
//!
//! All structures index with low-order line-address bits (like GPGPU-Sim's
//! default linear mapping) except the L2-slice and home-cache maps, which
//! mix the address first so that strided patterns spread across slices —
//! the same reason real GPUs hash their partition interleave.

use super::LineAddr;

/// Tag/set split for a cache with `sets` (power of two) sets.
#[inline]
pub fn set_index(line: LineAddr, sets: usize) -> usize {
    (line as usize) & (sets - 1)
}

#[inline]
pub fn tag(line: LineAddr, sets: usize) -> u64 {
    line >> sets.trailing_zeros()
}

/// Reconstruct a line address from (tag, set) — inverse of the pair above.
#[inline]
pub fn line_from(tag: u64, set: usize, sets: usize) -> LineAddr {
    (tag << sets.trailing_zeros()) | set as u64
}

/// Data-array bank within an L1: consecutive lines rotate across banks.
#[inline]
pub fn l1_bank(line: LineAddr, banks: usize) -> usize {
    (line as usize) & (banks - 1)
}

/// 64-bit finalizer used for slice/home hashing (splitmix64 mixer).
#[inline]
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// L2 slice (memory sub-partition) for a line.
#[inline]
pub fn l2_slice(line: LineAddr, slices: usize) -> usize {
    (mix64(line) % slices as u64) as usize
}

/// DRAM (controller, bank) for a line.  Hashed at *row* granularity so
/// that consecutive lines in one 2 KiB row stay in one bank (row-buffer
/// locality exists), while rows spread across controllers/banks.
#[inline]
pub fn dram_bank(line: LineAddr, controllers: usize, banks_per: usize) -> (usize, usize) {
    let h = mix64(dram_row(line) ^ 0x9E37_79B9_7F4A_7C15);
    let ctrl = (h % controllers as u64) as usize;
    let bank = ((h >> 32) % banks_per as u64) as usize;
    (ctrl, bank)
}

/// DRAM row for a line (for row-buffer locality): consecutive lines in the
/// same 2 KiB region share a row.
#[inline]
pub fn dram_row(line: LineAddr) -> u64 {
    line >> 4 // 16 lines × 128 B = 2 KiB rows
}

/// Decoupled-sharing home cache: which cluster L1 owns this line.
/// Hash-interleaved so that strided footprints spread across the slices
/// (the paper's decoupled baseline does the same; bank *conflicts* come
/// from simultaneity, not from systematic imbalance).
#[inline]
pub fn home_cache(line: LineAddr, cluster_size: usize) -> usize {
    (mix64(line ^ 0xDEC0_4B1E) % cluster_size as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_tag_roundtrip() {
        for sets in [1usize, 8, 64] {
            for line in [0u64, 1, 7, 8, 12345, u32::MAX as u64] {
                let s = set_index(line, sets);
                let t = tag(line, sets);
                assert_eq!(line_from(t, s, sets), line, "sets={sets} line={line}");
                assert!(s < sets);
            }
        }
    }

    #[test]
    fn consecutive_lines_rotate_banks() {
        let banks = 4;
        let seen: Vec<usize> = (0..8u64).map(|l| l1_bank(l, banks)).collect();
        assert_eq!(seen, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn l2_slices_are_balanced() {
        let slices = 24;
        let mut counts = vec![0usize; slices];
        for line in 0..24_000u64 {
            counts[l2_slice(line, slices)] += 1;
        }
        let min = *counts.iter().min().unwrap();
        let max = *counts.iter().max().unwrap();
        assert!(min > 800 && max < 1200, "imbalanced: min={min} max={max}");
    }

    #[test]
    fn strided_pattern_still_spreads_over_slices() {
        // Stride of 24 lines would alias a modulo map onto one slice.
        let slices = 24;
        let mut counts = vec![0usize; slices];
        for i in 0..2400u64 {
            counts[l2_slice(i * 24, slices)] += 1;
        }
        assert!(counts.iter().all(|&c| c > 50), "{counts:?}");
    }

    #[test]
    fn home_cache_covers_cluster_and_is_stable() {
        let n = 10;
        let mut seen = vec![false; n];
        for line in 0..1000u64 {
            let h = home_cache(line, n);
            assert!(h < n);
            assert_eq!(h, home_cache(line, n), "stable");
            seen[h] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn dram_mapping_in_range_and_row_groups() {
        let (c, b) = dram_bank(12345, 12, 16);
        assert!(c < 12 && b < 16);
        assert_eq!(dram_row(0), dram_row(15));
        assert_ne!(dram_row(15), dram_row(16));
    }
}
