//! Memory request model: addresses, sectors, the request type produced by
//! the SIMT cores, and the first-class [`MemTxn`] transaction that carries
//! one request core → L1 tag probe → (local | peer | L2 → DRAM) with
//! per-hop timestamps and accumulated queueing.

pub mod decode;

use crate::stats::{ContentionBreakdown, ContentionStats, ResourceClass};

/// A 128-byte cache-line address (byte address >> 7).  Line granularity is
/// the unit of tag lookups and sharing; sectors (32 B) are the unit of
/// fills and transfers, per Table II.
pub type LineAddr = u64;

/// Up to 8 sectors per line encoded as a bitmask (Table II uses 4).
pub type SectorMask = u8;

/// Unique id for in-flight requests (monotone per simulation).
pub type ReqId = u64;

/// Memory access kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    Load,
    Store,
}

/// A warp-level memory request after coalescing: one cache line with the
/// set of sectors the warp's active lanes touch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemRequest {
    pub id: ReqId,
    /// Issuing core (global id).
    pub core: u32,
    /// Warp slot within the core (for scoreboard wakeup).
    pub warp: u32,
    /// Load-instruction sequence number within the warp — used to group
    /// the requests of one load for the paper's L1-latency metric (§IV-C).
    pub inst: u64,
    pub line: LineAddr,
    pub sectors: SectorMask,
    pub kind: AccessKind,
    /// Cycle the core handed the request to the L1 organization.
    pub issue_cycle: u64,
}

impl MemRequest {
    pub fn is_write(&self) -> bool {
        self.kind == AccessKind::Store
    }

    pub fn sector_count(&self) -> u32 {
        self.sectors.count_ones()
    }
}

/// Per-hop timestamps of one transaction's walk down the memory
/// hierarchy.  Hops that a transaction never reaches stay 0 (e.g. a local
/// hit never dispatches to L2).  The deltas between consecutive hops are
/// the paper's Fig. 3 latency decomposition: front-end tag wait, L1 stage,
/// and L2/DRAM service.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HopTimes {
    /// Cycle the request was handed to the L1 organization.
    pub issue: u64,
    /// Cycle the front-end tag pipeline resolved (probe outcome known).
    /// Stays `issue` for organizations without a distinct tag front-end.
    pub tag_done: u64,
    /// Cycle the L1 stage of the access completed: data return for any L1
    /// hit (local or remote), or the dispatch-to-L2 point for a miss —
    /// the paper's §IV-C latency boundary.
    pub l1_done: u64,
    /// Cycle a miss was offered to the cores→L2 network (0 = never).
    pub l2_dispatch: u64,
    /// Cycle the fill data arrived back at the L1 (0 = no memory trip).
    pub mem_done: u64,
    /// Cycle the data reached the core (loads) / the write retired.
    pub done: u64,
}

/// How a deferred completion returns the data to the requesting core
/// once the phased memory walk finalizes it (phase B3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetPath {
    /// Complete directly at the owning core.
    Local,
    /// Data crosses back over the cluster crossbar first
    /// (decoupled-sharing home-slice accesses).
    Xbar {
        cluster: usize,
        from_idx: usize,
        to_idx: usize,
    },
}

/// A completion the L1 organization postponed into the phased memory
/// walk: the front-end pass (B1) resolved everything cross-slice and
/// recorded what phase B3 needs to close the transaction once the
/// per-slice walk has produced the fill timing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Deferred {
    /// A miss dispatched to L2: `desc` indexes the fetch descriptor in
    /// [`crate::l2::MemSystem`], `owner` is the L1 cache the fill lands
    /// in, `dispatch` the MSHR-dispatch cycle, `victim` the dirty line
    /// the B1 tag install evicted (written back at fill time).
    Fetch {
        owner: usize,
        desc: usize,
        dispatch: u64,
        victim: Option<crate::cache::Eviction>,
        ret: RetPath,
    },
    /// A merge onto a fetch scheduled earlier in the same epoch: the
    /// ready cycle is only known after the owner's fetch finalizes.
    Merge { owner: usize, t: u64, ret: RetPath },
}

/// One memory request's transaction through the hierarchy.
///
/// Constructed once by the engine (or a test harness) and carried by
/// `&mut` through `l1arch` (tag probe → hit/peer/miss resolution → MSHR
/// dispatch), `noc`, `l2` and `dram`.  Each layer stamps its hop
/// timestamps and charges its [`resource::Grant`](crate::resource::Grant)
/// queueing through [`charge`](MemTxn::charge), so the finished
/// transaction carries both *where the time went* (hops) and *why*
/// (per-resource queued cycles).
#[derive(Debug, Clone)]
pub struct MemTxn {
    /// The immutable request identity (who asked for what).
    pub req: MemRequest,
    /// Physical NoC endpoint below L1: the core whose injection port the
    /// miss leaves through and the fill returns to.  Equals `req.core`
    /// except for decoupled-sharing home-slice misses.
    pub endpoint: u32,
    /// Core charged for every queued cycle along the walk — always the
    /// *suffering* core (the one whose load waits), never a proxy
    /// endpoint, so per-app lane rollups stay honest.
    pub attr_core: u32,
    /// Sectors an L2 fetch should bring in (narrowed on sector misses).
    pub fetch_sectors: SectorMask,
    pub hops: HopTimes,
    /// Grant queueing accumulated along the walk, per resource class.
    pub queued: ContentionBreakdown,
    /// Set when the L1 organization deferred completion into the phased
    /// memory walk; consumed by [`crate::l1arch::L1Arch::finish`].
    pub deferred: Option<Deferred>,
}

impl MemTxn {
    /// Open a transaction for `req` handed to the L1 organization at
    /// `now`.  (`now` equals `req.issue_cycle` in the engine; tests may
    /// replay a request at a later cycle.)
    pub fn new(req: MemRequest, now: u64) -> Self {
        MemTxn {
            req,
            endpoint: req.core,
            attr_core: req.core,
            fetch_sectors: req.sectors,
            hops: HopTimes {
                issue: now,
                tag_done: now,
                ..HopTimes::default()
            },
            queued: ContentionBreakdown::default(),
            deferred: None,
        }
    }

    /// Cycle the L1 organization received this transaction.
    #[inline]
    pub fn now(&self) -> u64 {
        self.hops.issue
    }

    /// Charge `cycles` of queueing on `class`: attributed to
    /// [`attr_core`](Self::attr_core) in `con` *and* accumulated on the
    /// transaction itself.  Zero-cycle charges are free no-ops.
    #[inline]
    pub fn charge(&mut self, con: &mut ContentionStats, class: ResourceClass, cycles: u64) {
        if cycles > 0 {
            con.add(self.attr_core as usize, class, cycles);
            self.queued.add(class, cycles);
        }
    }

    /// Close the transaction: data at core at `done`, L1 stage completed
    /// at `l1_done` (the §IV-C boundary).
    #[inline]
    pub fn complete(&mut self, done: u64, l1_done: u64) {
        self.hops.done = done;
        self.hops.l1_done = l1_done;
    }

    /// Close a transaction fully served at `done` (hit paths: the L1
    /// stage *is* the whole access).
    #[inline]
    pub fn serve(&mut self, done: u64) {
        self.complete(done, done);
    }

    /// Cycle the data reached the core (valid after the L1 organization
    /// returned).
    #[inline]
    pub fn done(&self) -> u64 {
        self.hops.done
    }

    /// The §IV-C L1-stage completion cycle.
    #[inline]
    pub fn l1_stage_done(&self) -> u64 {
        self.hops.l1_done
    }
}

/// A completed-response notification back to the issuing core.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemResponse {
    pub id: ReqId,
    pub core: u32,
    pub warp: u32,
    pub inst: u64,
    pub line: LineAddr,
    /// Cycle the data became available to the core.
    pub complete_cycle: u64,
}

impl MemResponse {
    pub fn for_request(req: &MemRequest, complete_cycle: u64) -> Self {
        MemResponse {
            id: req.id,
            core: req.core,
            warp: req.warp,
            inst: req.inst,
            line: req.line,
            complete_cycle,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(line: LineAddr, sectors: SectorMask, kind: AccessKind) -> MemRequest {
        MemRequest {
            id: 1,
            core: 0,
            warp: 0,
            inst: 0,
            line,
            sectors,
            kind,
            issue_cycle: 0,
        }
    }

    #[test]
    fn sector_count_counts_bits() {
        assert_eq!(req(0, 0b1111, AccessKind::Load).sector_count(), 4);
        assert_eq!(req(0, 0b0101, AccessKind::Load).sector_count(), 2);
        assert_eq!(req(0, 0b0001, AccessKind::Load).sector_count(), 1);
    }

    #[test]
    fn is_write() {
        assert!(!req(0, 1, AccessKind::Load).is_write());
        assert!(req(0, 1, AccessKind::Store).is_write());
    }

    #[test]
    fn txn_opens_at_now_and_charges_both_ledgers() {
        let mut txn = MemTxn::new(req(7, 0b0011, AccessKind::Load), 100);
        assert_eq!(txn.now(), 100);
        assert_eq!(txn.hops.tag_done, 100, "no front-end by default");
        assert_eq!(txn.endpoint, txn.req.core);
        assert_eq!(txn.attr_core, txn.req.core);
        assert_eq!(txn.fetch_sectors, 0b0011);

        let mut con = ContentionStats::new(4);
        txn.charge(&mut con, ResourceClass::Dram, 5);
        txn.charge(&mut con, ResourceClass::Dram, 0); // free no-op
        txn.charge(&mut con, ResourceClass::NocLink, 2);
        assert_eq!(txn.queued.get(ResourceClass::Dram), 5);
        assert_eq!(txn.queued.total(), 7);
        assert_eq!(con.total().total(), 7, "ledgers agree");
        assert_eq!(con.per_core()[0].get(ResourceClass::NocLink), 2);
    }

    #[test]
    fn txn_complete_and_serve_stamp_hops() {
        let mut txn = MemTxn::new(req(7, 0b1111, AccessKind::Load), 10);
        txn.complete(500, 50);
        assert_eq!(txn.done(), 500);
        assert_eq!(txn.l1_stage_done(), 50);
        let mut txn2 = MemTxn::new(req(7, 0b1111, AccessKind::Load), 10);
        txn2.serve(42);
        assert_eq!((txn2.done(), txn2.l1_stage_done()), (42, 42));
    }

    #[test]
    fn response_copies_request_identity() {
        let r = MemRequest {
            id: 7,
            core: 3,
            warp: 5,
            inst: 11,
            line: 0xABC,
            sectors: 0b11,
            kind: AccessKind::Load,
            issue_cycle: 100,
        };
        let resp = MemResponse::for_request(&r, 164);
        assert_eq!(resp.id, 7);
        assert_eq!(resp.core, 3);
        assert_eq!(resp.warp, 5);
        assert_eq!(resp.inst, 11);
        assert_eq!(resp.line, 0xABC);
        assert_eq!(resp.complete_cycle, 164);
    }
}
