//! Memory request model: addresses, sectors, and the request type that
//! flows from SIMT cores through L1 organizations to L2 and DRAM.

pub mod decode;

/// A 128-byte cache-line address (byte address >> 7).  Line granularity is
/// the unit of tag lookups and sharing; sectors (32 B) are the unit of
/// fills and transfers, per Table II.
pub type LineAddr = u64;

/// Up to 8 sectors per line encoded as a bitmask (Table II uses 4).
pub type SectorMask = u8;

/// Unique id for in-flight requests (monotone per simulation).
pub type ReqId = u64;

/// Memory access kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    Load,
    Store,
}

/// A warp-level memory request after coalescing: one cache line with the
/// set of sectors the warp's active lanes touch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemRequest {
    pub id: ReqId,
    /// Issuing core (global id).
    pub core: u32,
    /// Warp slot within the core (for scoreboard wakeup).
    pub warp: u32,
    /// Load-instruction sequence number within the warp — used to group
    /// the requests of one load for the paper's L1-latency metric (§IV-C).
    pub inst: u64,
    pub line: LineAddr,
    pub sectors: SectorMask,
    pub kind: AccessKind,
    /// Cycle the core handed the request to the L1 organization.
    pub issue_cycle: u64,
}

impl MemRequest {
    pub fn is_write(&self) -> bool {
        self.kind == AccessKind::Store
    }

    pub fn sector_count(&self) -> u32 {
        self.sectors.count_ones()
    }
}

/// A completed-response notification back to the issuing core.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemResponse {
    pub id: ReqId,
    pub core: u32,
    pub warp: u32,
    pub inst: u64,
    pub line: LineAddr,
    /// Cycle the data became available to the core.
    pub complete_cycle: u64,
}

impl MemResponse {
    pub fn for_request(req: &MemRequest, complete_cycle: u64) -> Self {
        MemResponse {
            id: req.id,
            core: req.core,
            warp: req.warp,
            inst: req.inst,
            line: req.line,
            complete_cycle,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(line: LineAddr, sectors: SectorMask, kind: AccessKind) -> MemRequest {
        MemRequest {
            id: 1,
            core: 0,
            warp: 0,
            inst: 0,
            line,
            sectors,
            kind,
            issue_cycle: 0,
        }
    }

    #[test]
    fn sector_count_counts_bits() {
        assert_eq!(req(0, 0b1111, AccessKind::Load).sector_count(), 4);
        assert_eq!(req(0, 0b0101, AccessKind::Load).sector_count(), 2);
        assert_eq!(req(0, 0b0001, AccessKind::Load).sector_count(), 1);
    }

    #[test]
    fn is_write() {
        assert!(!req(0, 1, AccessKind::Load).is_write());
        assert!(req(0, 1, AccessKind::Store).is_write());
    }

    #[test]
    fn response_copies_request_identity() {
        let r = MemRequest {
            id: 7,
            core: 3,
            warp: 5,
            inst: 11,
            line: 0xABC,
            sectors: 0b11,
            kind: AccessKind::Load,
            issue_cycle: 100,
        };
        let resp = MemResponse::for_request(&r, 164);
        assert_eq!(resp.id, 7);
        assert_eq!(resp.core, 3);
        assert_eq!(resp.warp, 5);
        assert_eq!(resp.inst, 11);
        assert_eq!(resp.line, 0xABC);
        assert_eq!(resp.complete_cycle, 164);
    }
}
