//! Deterministic parallel experiment execution.
//!
//! Every figure and table in the paper comes from a *grid* of independent
//! simulations — architectures × applications (Fig 8, Table I), app pairs
//! × architectures (the co-scheduling interference matrix), one pinned
//! workload per registered organization (`ata-sim bench`).  This module
//! is the single execution layer all of those surfaces route through,
//! replacing the per-surface serial (or hand-rolled parallel) loops:
//!
//! * [`SimJob`] — one fully-resolved simulation: config + materialized
//!   workload + job seed.  Jobs are built **up front**, before any worker
//!   starts, so they are `Send`, self-contained, and independent of
//!   execution order.
//! * [`JobRunner`] — a `std::thread::scope` worker pool with a
//!   work-stealing index queue.  Results come back **in submission
//!   order**, so downstream aggregation never reorders and output is
//!   byte-identical for any thread count.
//! * [`ScenarioGrid`] — the declarative grid (config variants ×
//!   organizations × applications) that materializes a job list in a
//!   deterministic submission order.
//!
//! # Determinism contract
//!
//! 1. Each simulation is a pure function of its [`SimJob`] — the engine,
//!    workload and all component RNGs derive from the job's own config;
//!    no RNG state is shared between jobs or threaded through the
//!    dispatch loop.
//! 2. Job-local auxiliary randomness derives **solely** from
//!    `(grid_seed, job_index)` via [`job_seed`] — never from worker
//!    identity, completion order, or wall clock.
//! 3. Workload recipes keep the *grid* seed (`SimJob::cfg.seed`), so
//!    every organization in a grid is measured on an identical request
//!    stream — the comparisons behind `norm_ipc` stay apples-to-apples.
//! 4. [`JobRunner::run`] returns results indexed exactly like its input,
//!    regardless of which worker finished which job first.
//!
//! Together these make `--threads N` output byte-identical to
//! `--threads 1` (pinned by `rust/tests/exec_determinism.rs` and the
//! golden-equivalence fixture).

pub mod grid;
pub mod runner;

pub use grid::{ConfigVariant, ScenarioGrid};
pub use runner::JobRunner;

use crate::config::GpuConfig;
use crate::engine::{Engine, MultiWorkload, Workload};
use crate::stats::{MultiResult, SimResult};
use crate::util::rng::{Pcg32, SplitMix64};

/// Derive a job's seed from the grid seed and its submission index —
/// the *only* inputs job-local randomness may depend on (worker count
/// and completion order must never influence results).
pub fn job_seed(grid_seed: u64, job_index: usize) -> u64 {
    let salt = (job_index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut mix = SplitMix64::new(grid_seed ^ salt);
    // Two rounds so consecutive indices share no low-bit structure.
    mix.next_u64();
    mix.next_u64()
}

/// The workload a job runs: one application on the whole GPU, or N
/// co-executing applications on disjoint core partitions.
#[derive(Debug, Clone)]
pub enum JobWork {
    Solo(Workload),
    Multi(MultiWorkload),
}

/// One self-contained simulation: everything a worker needs, resolved at
/// construction time (on the submitting thread) so running the job has
/// no dependency on shared state.
#[derive(Debug, Clone)]
pub struct SimJob {
    /// Stable display label, conventionally `"variant/arch/app"`.
    pub label: String,
    /// Fully-resolved config.  `cfg.seed` is the grid seed (workload
    /// recipes must be identical across the organizations of one grid).
    pub cfg: GpuConfig,
    /// Job-local seed, derived from `(grid_seed, job_index)` only — see
    /// [`job_seed`] and the module-level determinism contract.
    pub seed: u64,
    pub work: JobWork,
}

impl SimJob {
    /// A single-application job.
    pub fn solo(label: impl Into<String>, cfg: GpuConfig, seed: u64, workload: Workload) -> Self {
        SimJob {
            label: label.into(),
            cfg,
            seed,
            work: JobWork::Solo(workload),
        }
    }

    /// A co-execution job.
    pub fn multi(
        label: impl Into<String>,
        cfg: GpuConfig,
        seed: u64,
        workload: MultiWorkload,
    ) -> Self {
        SimJob {
            label: label.into(),
            cfg,
            seed,
            work: JobWork::Multi(workload),
        }
    }

    /// Job-local RNG — the only sanctioned source of auxiliary
    /// randomness inside a job (sampling, jitter studies).  Deriving it
    /// from the job seed keeps it independent of worker scheduling.
    pub fn rng(&self) -> Pcg32 {
        Pcg32::new(self.seed, 0x0B5E_55ED)
    }

    /// Run the simulation on a fresh engine.  Called on a worker thread;
    /// everything the run touches is owned by the job.
    pub fn run(&self) -> JobOutput {
        match &self.work {
            JobWork::Solo(wl) => JobOutput::Solo(Engine::new(&self.cfg).run(wl)),
            JobWork::Multi(m) => JobOutput::Multi(Engine::new(&self.cfg).run_multi(m)),
        }
    }
}

/// A finished job's result, mirroring [`JobWork`].
#[derive(Debug, Clone)]
pub enum JobOutput {
    Solo(SimResult),
    Multi(MultiResult),
}

impl JobOutput {
    /// Unwrap a solo result (panics on a co-execution job — grids are
    /// homogeneous, so a mismatch is a construction bug).
    pub fn into_solo(self) -> SimResult {
        match self {
            JobOutput::Solo(r) => r,
            JobOutput::Multi(r) => panic!("expected a solo result, got co-run '{}'", r.name),
        }
    }

    /// Unwrap a co-execution result (panics on a solo job).
    pub fn into_multi(self) -> MultiResult {
        match self {
            JobOutput::Multi(r) => r,
            JobOutput::Solo(r) => panic!("expected a co-run result, got solo '{}'", r.app),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::L1ArchKind;
    use crate::trace::synth;

    /// Compile-time `Send` audit: jobs and their outputs cross thread
    /// boundaries whole, and a worker-built engine must itself be `Send`
    /// (its `Box<dyn L1Arch>` carries the trait's `Send` bound).
    #[test]
    fn jobs_outputs_and_engine_are_send() {
        fn is_send<T: Send>() {}
        is_send::<SimJob>();
        is_send::<JobWork>();
        is_send::<JobOutput>();
        is_send::<Workload>();
        is_send::<MultiWorkload>();
        is_send::<GpuConfig>();
        is_send::<Engine>();
    }

    #[test]
    fn job_seed_depends_on_grid_seed_and_index_only() {
        // Same inputs → same seed (pure function, no hidden state).
        assert_eq!(job_seed(42, 7), job_seed(42, 7));
        // Distinct indices and distinct grid seeds decorrelate.
        let seeds: Vec<u64> = (0..64).map(|i| job_seed(0xA7A_CACE, i)).collect();
        let mut dedup = seeds.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), seeds.len(), "job seeds must be distinct");
        assert_ne!(job_seed(1, 0), job_seed(2, 0));
    }

    #[test]
    fn solo_job_runs_and_matches_direct_engine() {
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let wl = synth::locality_knob(0.8, 0.25).workload(&cfg);
        let job = SimJob::solo("base/ata/synth", cfg.clone(), job_seed(cfg.seed, 0), wl.clone());
        let r = job.run().into_solo();
        let direct = Engine::new(&cfg).run(&wl);
        assert_eq!(r.cycles, direct.cycles);
        assert_eq!(r.insts, direct.insts);
        assert_eq!(r.l1.local_hits, direct.l1.local_hits);
    }

    #[test]
    fn job_rng_is_reproducible() {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let wl = synth::pure_streaming().scaled(0.25).workload(&cfg);
        let job = SimJob::solo("j", cfg, job_seed(7, 3), wl);
        let a: Vec<u32> = {
            let mut rng = job.rng();
            (0..8).map(|_| rng.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut rng = job.rng();
            (0..8).map(|_| rng.next_u32()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "expected a solo result")]
    fn mismatched_unwrap_panics() {
        let r = MultiResult {
            name: "a+b".into(),
            ..Default::default()
        };
        let _ = JobOutput::Multi(r).into_solo();
    }
}
