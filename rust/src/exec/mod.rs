//! Deterministic parallel experiment execution.
//!
//! Every figure and table in the paper comes from a *grid* of independent
//! simulations — architectures × applications (Fig 8, Table I), app pairs
//! × architectures (the co-scheduling interference matrix), one pinned
//! workload per registered organization (`ata-sim bench`).  This module
//! is the single execution layer all of those surfaces route through,
//! replacing the per-surface serial (or hand-rolled parallel) loops:
//!
//! * [`SimJob`] — one fully-resolved simulation: config + materialized
//!   workload + job seed.  Jobs are built **up front**, before any worker
//!   starts, so they are `Send`, self-contained, and independent of
//!   execution order.
//! * [`JobRunner`] — a `std::thread::scope` worker pool with a
//!   work-stealing index queue.  Results come back **in submission
//!   order**, so downstream aggregation never reorders and output is
//!   byte-identical for any thread count.
//! * [`ScenarioGrid`] — the declarative grid (config variants ×
//!   organizations × applications) that materializes a job list in a
//!   deterministic submission order.
//!
//! # Determinism contract
//!
//! 1. Each simulation is a pure function of its [`SimJob`] — the engine,
//!    workload and all component RNGs derive from the job's own config;
//!    no RNG state is shared between jobs or threaded through the
//!    dispatch loop.
//! 2. Job-local auxiliary randomness derives **solely** from
//!    `(grid_seed, job_index)` via [`job_seed`] — never from worker
//!    identity, completion order, or wall clock.
//! 3. Workload recipes keep the *grid* seed (`SimJob::cfg.seed`), so
//!    every organization in a grid is measured on an identical request
//!    stream — the comparisons behind `norm_ipc` stay apples-to-apples.
//! 4. [`JobRunner::run`] returns results indexed exactly like its input,
//!    regardless of which worker finished which job first.
//!
//! Together these make `--threads N` output byte-identical to
//! `--threads 1` (pinned by `rust/tests/exec_determinism.rs` and the
//! golden-equivalence fixture).
//!
//! # Failure isolation
//!
//! A job that cannot complete — a typed [`SimError`] out of the engine,
//! or a panic anywhere inside the simulation — becomes a
//! [`JobOutput::Failed`] slot carrying a [`JobError`]; the rest of the
//! grid always runs to completion.  Failures are *data* and inherit the
//! determinism contract: [`JobRunner::run_grid`] retries any job that
//! failed under parallel intra-job execution once serially
//! (`shards=1`/`mem-workers=1`), so the serialized error (snapshot
//! included) is always the serial one, byte-identical at any
//! `--threads`/`--shards`/`--mem-workers`.  A job that *succeeds* on
//! that serial retry is reported in [`GridOutcome::degraded`] — a
//! host-level flake indicator, deliberately kept out of the result JSON.

pub mod grid;
pub mod runner;

pub use grid::{ConfigVariant, ScenarioGrid};
pub use runner::{GridOutcome, JobRunner};

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::config::GpuConfig;
use crate::engine::{panic_message, Engine, FailSnapshot, MultiWorkload, SimError, Workload};
use crate::stats::{MultiResult, SimResult};
use crate::util::json::Json;
use crate::util::rng::{Pcg32, SplitMix64};

/// Derive a job's seed from the grid seed and its submission index —
/// the *only* inputs job-local randomness may depend on (worker count
/// and completion order must never influence results).
pub fn job_seed(grid_seed: u64, job_index: usize) -> u64 {
    let salt = (job_index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut mix = SplitMix64::new(grid_seed ^ salt);
    // Two rounds so consecutive indices share no low-bit structure.
    mix.next_u64();
    mix.next_u64()
}

/// The workload a job runs: one application on the whole GPU, or N
/// co-executing applications on disjoint core partitions.
#[derive(Debug, Clone)]
pub enum JobWork {
    Solo(Workload),
    Multi(MultiWorkload),
}

/// One self-contained simulation: everything a worker needs, resolved at
/// construction time (on the submitting thread) so running the job has
/// no dependency on shared state.
#[derive(Debug, Clone)]
pub struct SimJob {
    /// Stable display label, conventionally `"variant/arch/app"`.
    pub label: String,
    /// Fully-resolved config.  `cfg.seed` is the grid seed (workload
    /// recipes must be identical across the organizations of one grid).
    pub cfg: GpuConfig,
    /// Job-local seed, derived from `(grid_seed, job_index)` only — see
    /// [`job_seed`] and the module-level determinism contract.
    pub seed: u64,
    pub work: JobWork,
}

impl SimJob {
    /// A single-application job.
    pub fn solo(label: impl Into<String>, cfg: GpuConfig, seed: u64, workload: Workload) -> Self {
        SimJob {
            label: label.into(),
            cfg,
            seed,
            work: JobWork::Solo(workload),
        }
    }

    /// A co-execution job.
    pub fn multi(
        label: impl Into<String>,
        cfg: GpuConfig,
        seed: u64,
        workload: MultiWorkload,
    ) -> Self {
        SimJob {
            label: label.into(),
            cfg,
            seed,
            work: JobWork::Multi(workload),
        }
    }

    /// Job-local RNG — the only sanctioned source of auxiliary
    /// randomness inside a job (sampling, jitter studies).  Deriving it
    /// from the job seed keeps it independent of worker scheduling.
    pub fn rng(&self) -> Pcg32 {
        Pcg32::new(self.seed, 0x0B5E_55ED)
    }

    /// Run the simulation on a fresh engine.  Called on a worker thread;
    /// everything the run touches is owned by the job.  A typed engine
    /// failure becomes [`JobOutput::Failed`]; a *panic* still unwinds
    /// (contained one level up by [`run_contained`](Self::run_contained)).
    pub fn run(&self) -> JobOutput {
        let res = (|| -> Result<JobOutput, SimError> {
            let mut eng = Engine::try_new(&self.cfg)?;
            match &self.work {
                JobWork::Solo(wl) => Ok(JobOutput::Solo(eng.run(wl)?)),
                JobWork::Multi(m) => Ok(JobOutput::Multi(eng.run_multi(m)?)),
            }
        })();
        res.unwrap_or_else(|e| JobOutput::Failed(JobError::from_sim(&self.label, &e)))
    }

    /// [`run`](Self::run) with panic containment: a panic anywhere inside
    /// the simulation (including one a shard coordinator re-raised) is
    /// converted into a `worker-panic` [`JobError`] instead of unwinding
    /// into the pool.  This is the entry point grid execution uses.
    pub fn run_contained(&self) -> JobOutput {
        match catch_unwind(AssertUnwindSafe(|| self.run())) {
            Ok(out) => out,
            Err(payload) => JobOutput::Failed(JobError {
                job: self.label.clone(),
                kind: "worker-panic".to_string(),
                message: panic_message(payload.as_ref()),
                snapshot: None,
            }),
        }
    }

    /// Does this job fan out across host threads internally?
    pub fn is_parallel(&self) -> bool {
        self.cfg.engine.shards > 1 || self.cfg.engine.mem_workers > 1
    }

    /// The same job pinned to fully serial intra-job execution
    /// (`shards=1`, `mem-workers=1`) — the degradation retry target.
    /// Both knobs are host-parallelism only, so a twin that completes
    /// produces byte-identical results to what the parallel run would
    /// have produced.
    pub fn serial_twin(&self) -> SimJob {
        let mut twin = self.clone();
        twin.cfg.engine.shards = 1;
        twin.cfg.engine.mem_workers = 1;
        twin
    }
}

/// A serialized-ready record of one job's failure.  `kind` is
/// [`SimError::kind`] (or `"worker-panic"` for a contained panic),
/// `snapshot` the deterministic diagnostic picture for the variants that
/// carry one.
#[derive(Debug, Clone, PartialEq)]
pub struct JobError {
    /// The failed job's label.
    pub job: String,
    /// Stable failure class: `deadlock`, `livelock`, `worker-panic`,
    /// `invalid-config`, `host-timeout`.
    pub kind: String,
    /// Human-readable one-liner (the `SimError` display or panic text).
    pub message: String,
    pub snapshot: Option<FailSnapshot>,
}

impl JobError {
    pub fn from_sim(label: &str, e: &SimError) -> JobError {
        JobError {
            job: label.to_string(),
            kind: e.kind().to_string(),
            message: e.to_string(),
            snapshot: e.snapshot().cloned(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("job", self.job.as_str().into()),
            ("kind", self.kind.as_str().into()),
            ("message", self.message.as_str().into()),
            (
                "snapshot",
                match &self.snapshot {
                    Some(s) => s.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> JobError {
        let s = |k: &str| j.get(k).and_then(Json::as_str).unwrap_or_default().to_string();
        JobError {
            job: s("job"),
            kind: s("kind"),
            message: s("message"),
            snapshot: j
                .get("snapshot")
                .filter(|s| !matches!(s, Json::Null))
                .map(FailSnapshot::from_json),
        }
    }
}

/// A finished job's outcome, mirroring [`JobWork`] — plus the
/// fault-isolation slot: a job that could not complete parks its typed
/// [`JobError`] here and the grid keeps going.
#[derive(Debug, Clone)]
pub enum JobOutput {
    Solo(SimResult),
    Multi(MultiResult),
    Failed(JobError),
}

impl JobOutput {
    /// Unwrap a solo result (panics on a co-execution job — grids are
    /// homogeneous, so a mismatch is a construction bug — and on a failed
    /// job; surfaces that tolerate failures match on `Failed` first).
    pub fn into_solo(self) -> SimResult {
        match self {
            JobOutput::Solo(r) => r,
            JobOutput::Multi(r) => panic!("expected a solo result, got co-run '{}'", r.name),
            JobOutput::Failed(e) => panic!("job '{}' failed: {}", e.job, e.message),
        }
    }

    /// Unwrap a co-execution result (panics on a solo or failed job).
    pub fn into_multi(self) -> MultiResult {
        match self {
            JobOutput::Multi(r) => r,
            JobOutput::Solo(r) => panic!("expected a co-run result, got solo '{}'", r.app),
            JobOutput::Failed(e) => panic!("job '{}' failed: {}", e.job, e.message),
        }
    }

    /// The failure record, if this job failed.
    pub fn failure(&self) -> Option<&JobError> {
        match self {
            JobOutput::Failed(e) => Some(e),
            _ => None,
        }
    }

    /// Tagged serialization (`{"kind": "solo"|"multi"|"failed", ...}`) —
    /// one manifest line's `output` value.
    pub fn to_json(&self) -> Json {
        match self {
            JobOutput::Solo(r) => Json::obj(vec![("kind", "solo".into()), ("result", r.to_json())]),
            JobOutput::Multi(r) => Json::obj(vec![("kind", "multi".into()), ("result", r.to_json())]),
            JobOutput::Failed(e) => Json::obj(vec![("kind", "failed".into()), ("error", e.to_json())]),
        }
    }

    /// Inverse of [`to_json`](Self::to_json); `None` on an unknown tag
    /// (a manifest from an incompatible build is skipped, not trusted).
    pub fn from_json(j: &Json) -> Option<JobOutput> {
        match j.get("kind").and_then(Json::as_str)? {
            "solo" => Some(JobOutput::Solo(SimResult::from_json(j.get("result")?))),
            "multi" => Some(JobOutput::Multi(MultiResult::from_json(j.get("result")?))),
            "failed" => Some(JobOutput::Failed(JobError::from_json(j.get("error")?))),
            _ => None,
        }
    }
}

/// Completed jobs keyed by label — what `--resume` loads from a manifest.
/// A `BTreeMap` so any iteration a caller does is ordered.
pub type ResumeCache = BTreeMap<String, JobOutput>;

/// One completed-job manifest line (JSONL):
/// `{"job": <label>, "output": {"kind": ..., ...}}`.
pub fn manifest_line(label: &str, out: &JobOutput) -> String {
    Json::obj(vec![("job", label.into()), ("output", out.to_json())]).to_string()
}

/// Parse a JSONL manifest into a [`ResumeCache`].  Unparseable or
/// unknown-tag lines are skipped (a partial line from an interrupted run
/// must not poison the resume), and a later line for the same label wins.
pub fn parse_manifest(text: &str) -> ResumeCache {
    let mut cache = ResumeCache::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(j) = Json::parse(line) else { continue };
        let (Some(label), Some(out)) = (
            j.get("job").and_then(Json::as_str),
            j.get("output").and_then(JobOutput::from_json),
        ) else {
            continue;
        };
        cache.insert(label.to_string(), out);
    }
    cache
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::L1ArchKind;
    use crate::trace::synth;

    /// Compile-time `Send` audit: jobs and their outputs cross thread
    /// boundaries whole, and a worker-built engine must itself be `Send`
    /// (its `Box<dyn L1Arch>` carries the trait's `Send` bound).
    #[test]
    fn jobs_outputs_and_engine_are_send() {
        fn is_send<T: Send>() {}
        is_send::<SimJob>();
        is_send::<JobWork>();
        is_send::<JobOutput>();
        is_send::<Workload>();
        is_send::<MultiWorkload>();
        is_send::<GpuConfig>();
        is_send::<Engine>();
    }

    #[test]
    fn job_seed_depends_on_grid_seed_and_index_only() {
        // Same inputs → same seed (pure function, no hidden state).
        assert_eq!(job_seed(42, 7), job_seed(42, 7));
        // Distinct indices and distinct grid seeds decorrelate.
        let seeds: Vec<u64> = (0..64).map(|i| job_seed(0xA7A_CACE, i)).collect();
        let mut dedup = seeds.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), seeds.len(), "job seeds must be distinct");
        assert_ne!(job_seed(1, 0), job_seed(2, 0));
    }

    #[test]
    fn solo_job_runs_and_matches_direct_engine() {
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let wl = synth::locality_knob(0.8, 0.25).workload(&cfg);
        let job = SimJob::solo("base/ata/synth", cfg.clone(), job_seed(cfg.seed, 0), wl.clone());
        let r = job.run().into_solo();
        let direct = Engine::new(&cfg).run(&wl).unwrap();
        assert_eq!(r.cycles, direct.cycles);
        assert_eq!(r.insts, direct.insts);
        assert_eq!(r.l1.local_hits, direct.l1.local_hits);
    }

    #[test]
    fn job_rng_is_reproducible() {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let wl = synth::pure_streaming().scaled(0.25).workload(&cfg);
        let job = SimJob::solo("j", cfg, job_seed(7, 3), wl);
        let a: Vec<u32> = {
            let mut rng = job.rng();
            (0..8).map(|_| rng.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut rng = job.rng();
            (0..8).map(|_| rng.next_u32()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "expected a solo result")]
    fn mismatched_unwrap_panics() {
        let r = MultiResult {
            name: "a+b".into(),
            ..Default::default()
        };
        let _ = JobOutput::Multi(r).into_solo();
    }
}
