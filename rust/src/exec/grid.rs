//! Declarative scenario grids: config variants × organizations × apps.

use crate::config::{GpuConfig, L1ArchKind};
use crate::trace::AppModel;

use super::{job_seed, SimJob};

/// One named config mutation of a grid (ablation axis).  A plain
/// function pointer keeps variants `Copy`/`Send` and forces them to be
/// pure config edits — no captured state can leak execution-order
/// dependence into a job.
///
/// Host-performance ablations (`ata-sim bench`'s `event-on` /
/// `event-off` / `residency-off` triple) lean on a second property of
/// this shape: a variant that only flips `engine.event_driven` or
/// `sharing.residency_index` must leave the job's simulated metrics
/// byte-identical, so cross-variant result comparison doubles as a
/// determinism referee.
#[derive(Debug, Clone, Copy)]
pub struct ConfigVariant {
    pub name: &'static str,
    pub apply: fn(&mut GpuConfig),
}

impl ConfigVariant {
    /// The identity variant every plain sweep uses.
    pub const BASE: ConfigVariant = ConfigVariant {
        name: "base",
        apply: |_| {},
    };
}

impl Default for ConfigVariant {
    fn default() -> Self {
        ConfigVariant::BASE
    }
}

/// A declarative experiment grid.  Materializing it ([`Self::jobs`])
/// yields one [`SimJob`] per (variant, organization, application) in a
/// fixed submission order — variant-major, then organization, then
/// application — which is also the order results come back from
/// [`super::JobRunner::run`].
///
/// `cfg.seed` is the grid seed: it seeds every job's workload recipe
/// (identical request streams across organizations) and, mixed with the
/// job index, each job's local seed (see [`job_seed`]).
#[derive(Debug, Clone)]
pub struct ScenarioGrid {
    pub cfg: GpuConfig,
    pub archs: Vec<L1ArchKind>,
    pub apps: Vec<AppModel>,
    pub variants: Vec<ConfigVariant>,
    /// Workload intensity multiplier (1.0 = paper scale).
    pub scale: f64,
}

impl ScenarioGrid {
    /// A single-variant grid (the common case: every figure sweep).
    pub fn new(cfg: GpuConfig, archs: Vec<L1ArchKind>, apps: Vec<AppModel>, scale: f64) -> Self {
        ScenarioGrid {
            cfg,
            archs,
            apps,
            variants: vec![ConfigVariant::BASE],
            scale,
        }
    }

    /// Add ablation variants (the base variant is not implied — pass it
    /// explicitly if the unmodified config should stay in the grid).
    pub fn with_variants(mut self, variants: Vec<ConfigVariant>) -> Self {
        self.variants = variants;
        self
    }

    /// Number of jobs the grid will materialize.
    pub fn len(&self) -> usize {
        self.variants.len() * self.archs.len() * self.apps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize the job list in submission order.  All workload
    /// construction happens here, on the submitting thread — workers
    /// receive finished recipes and share nothing.
    pub fn jobs(&self) -> Vec<SimJob> {
        let grid_seed = self.cfg.seed;
        let mut out = Vec::with_capacity(self.len());
        for variant in &self.variants {
            for &arch in &self.archs {
                for app in &self.apps {
                    let mut cfg = self.cfg.clone();
                    (variant.apply)(&mut cfg);
                    cfg.l1_arch = arch;
                    let scaled = app.scaled(self.scale);
                    let wl = scaled.workload(&cfg);
                    let label = format!("{}/{}/{}", variant.name, arch.name(), app.name);
                    let seed = job_seed(grid_seed, out.len());
                    out.push(SimJob::solo(label, cfg, seed, wl));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;

    fn tiny_grid() -> ScenarioGrid {
        ScenarioGrid::new(
            GpuConfig::tiny(L1ArchKind::Private),
            vec![L1ArchKind::Private, L1ArchKind::Ata],
            vec![synth::locality_knob(0.8, 0.25), synth::pure_streaming()],
            0.25,
        )
    }

    #[test]
    fn submission_order_is_variant_arch_app() {
        let labels: Vec<String> = tiny_grid().jobs().into_iter().map(|j| j.label).collect();
        assert_eq!(
            labels,
            vec![
                "base/private/synth[s=0.80]",
                "base/private/synth[stream]",
                "base/ata/synth[s=0.80]",
                "base/ata/synth[stream]",
            ]
        );
    }

    #[test]
    fn jobs_carry_index_derived_seeds_and_grid_seed_configs() {
        let grid = tiny_grid();
        let jobs = grid.jobs();
        assert_eq!(jobs.len(), grid.len());
        for (i, job) in jobs.iter().enumerate() {
            assert_eq!(job.seed, super::super::job_seed(grid.cfg.seed, i));
            assert_eq!(
                job.cfg.seed, grid.cfg.seed,
                "workload recipes must share the grid seed"
            );
        }
        // Materializing twice yields identical jobs (pure construction).
        let again = grid.jobs();
        for (a, b) in jobs.iter().zip(&again) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.seed, b.seed);
        }
    }

    #[test]
    fn variants_multiply_the_grid_and_mutate_configs() {
        fn half_mshrs(cfg: &mut GpuConfig) {
            cfg.l1.mshr_entries = (cfg.l1.mshr_entries / 2).max(1);
        }
        let base_mshrs = GpuConfig::tiny(L1ArchKind::Private).l1.mshr_entries;
        let grid = tiny_grid().with_variants(vec![
            ConfigVariant::BASE,
            ConfigVariant {
                name: "half-mshr",
                apply: half_mshrs,
            },
        ]);
        let jobs = grid.jobs();
        assert_eq!(jobs.len(), 8);
        assert!(jobs[0].label.starts_with("base/"));
        assert!(jobs[4].label.starts_with("half-mshr/"));
        assert_eq!(jobs[0].cfg.l1.mshr_entries, base_mshrs);
        assert_eq!(jobs[4].cfg.l1.mshr_entries, (base_mshrs / 2).max(1));
    }
}
