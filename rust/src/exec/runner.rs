//! The worker pool: work-stealing by index, results in submission order.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, PoisonError};

use crate::engine::panic_message;

use super::{JobOutput, ResumeCache, SimJob};

/// A bounded worker pool over `std::thread::scope`.
///
/// Dispatch is a single atomic index ("work stealing" in its simplest
/// honest form: whichever worker is free claims the next unclaimed job),
/// so long jobs never convoy short ones behind a fixed pre-partition.
/// Each result is written into the slot of its *submission* index, which
/// makes the output byte-identical for any thread count — the whole
/// determinism story of the execution layer rests on this (see the
/// module docs of [`crate::exec`]).
#[derive(Debug, Clone, Copy)]
pub struct JobRunner {
    threads: usize,
}

impl JobRunner {
    /// A runner with `threads` workers (clamped to at least one).
    pub fn new(threads: usize) -> Self {
        JobRunner {
            threads: threads.max(1),
        }
    }

    /// The host's available parallelism — the default for every
    /// `--threads` flag.
    pub fn available() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every job and return the outputs **in submission order**, each
    /// fault-isolated: a typed engine failure or a panic inside a job
    /// becomes that job's [`JobOutput::Failed`] slot while the rest of the
    /// list completes normally (see [`SimJob::run_contained`]).
    pub fn run(&self, jobs: &[SimJob]) -> Vec<JobOutput> {
        self.run_map(jobs, |_, job| job.run_contained())
    }

    /// Generic deterministic fan-out: apply `f(index, item)` to every
    /// item on the pool, returning results indexed exactly like `items`.
    ///
    /// `f` must be a pure function of its arguments (plus the item's own
    /// self-contained state) — the pool guarantees *ordering* of results,
    /// and only pure jobs extend that to byte-identical *values* across
    /// thread counts.
    ///
    /// Each call runs under `catch_unwind`, so one panicking item never
    /// takes the other workers' completed results with it: the remaining
    /// items all finish, and the *first submitted* failure is then
    /// re-raised whole, carrying the original panic text.  (Callers that
    /// need failures as data wrap them at the item level instead — see
    /// [`SimJob::run_contained`] — so nothing reaches this re-raise.)
    pub fn run_map<I, T, F>(&self, items: &[I], f: F) -> Vec<T>
    where
        I: Sync,
        T: Send,
        F: Fn(usize, &I) -> T + Sync,
    {
        let n = items.len();
        let call = |i: usize| -> Result<T, String> {
            catch_unwind(AssertUnwindSafe(|| f(i, &items[i])))
                .map_err(|payload| panic_message(payload.as_ref()))
        };
        let collected: Vec<Result<T, String>> = if self.threads == 1 || n <= 1 {
            // Serial fast path: same code path workers take, minus the
            // pool — results are identical by construction.
            (0..n).map(call).collect()
        } else {
            let next = AtomicUsize::new(0);
            let slots: Vec<Mutex<Option<Result<T, String>>>> =
                (0..n).map(|_| Mutex::new(None)).collect();
            std::thread::scope(|s| {
                for _ in 0..self.threads.min(n) {
                    s.spawn(|| loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let out = call(i);
                        *slots[i].lock().unwrap_or_else(PoisonError::into_inner) = Some(out);
                    });
                }
            });
            // Failure-proof collection: a poisoned slot mutex yields its
            // value anyway, and a slot a worker never wrote (it cannot
            // happen with the in-loop containment above, but the shape is
            // kept honest) reports as a failure instead of a second panic
            // masking the first.
            slots
                .into_iter()
                .map(|slot| {
                    slot.into_inner()
                        .unwrap_or_else(PoisonError::into_inner)
                        .unwrap_or_else(|| {
                            Err("worker exited before writing its result slot".to_string())
                        })
                })
                .collect()
        };
        collected
            .into_iter()
            .enumerate()
            .map(|(i, r)| match r {
                Ok(v) => v,
                Err(message) => panic!("job {i} panicked: {message}"),
            })
            .collect()
    }

    /// Fault-isolated grid execution with graceful degradation and an
    /// incremental completed-job manifest:
    ///
    /// * every job runs panic-contained ([`SimJob::run_contained`]);
    /// * a job that fails while using intra-job host parallelism
    ///   (`shards > 1` or `mem-workers > 1`) is retried **once** on its
    ///   fully serial twin.  The retry's outcome — success or failure —
    ///   replaces the parallel one, so the serialized result is always
    ///   the serial run's and stays byte-identical at any `--shards`/
    ///   `--mem-workers`.  Jobs that *recover* on the retry are listed in
    ///   [`GridOutcome::degraded`] (a host-flake indicator; deterministic
    ///   failures fail the retry too and land in the results as
    ///   `Failed`, with `degraded` staying empty);
    /// * `resume` short-circuits jobs already present in a loaded
    ///   manifest — the cached output is returned verbatim;
    /// * `observer` is invoked once per *freshly computed* job, on the
    ///   worker that ran it, in completion order (the manifest writer
    ///   appends a line per call; resume is label-keyed, so line order
    ///   is irrelevant).
    pub fn run_grid(
        &self,
        jobs: &[SimJob],
        resume: Option<&ResumeCache>,
        observer: Option<&(dyn Fn(&SimJob, &JobOutput) + Sync)>,
    ) -> GridOutcome {
        let degraded: Mutex<Vec<String>> = Mutex::new(Vec::new());
        let outputs = self.run_map(jobs, |_, job| {
            if let Some(cached) = resume.and_then(|c| c.get(&job.label)) {
                return cached.clone();
            }
            let mut out = job.run_contained();
            if out.failure().is_some() && job.is_parallel() {
                let serial = job.serial_twin().run_contained();
                if serial.failure().is_none() {
                    degraded
                        .lock()
                        .unwrap_or_else(PoisonError::into_inner)
                        .push(job.label.clone());
                }
                out = serial;
            }
            if let Some(obs) = observer {
                obs(job, &out);
            }
            out
        });
        let mut degraded = degraded.into_inner().unwrap_or_else(PoisonError::into_inner);
        degraded.sort_unstable();
        GridOutcome { outputs, degraded }
    }
}

/// What [`JobRunner::run_grid`] hands back: the per-job outputs in
/// submission order, plus the labels of jobs that recovered on the
/// serial degradation retry (sorted; empty in deterministic runs).
#[derive(Debug, Clone)]
pub struct GridOutcome {
    pub outputs: Vec<JobOutput>,
    pub degraded: Vec<String>,
}

impl Default for JobRunner {
    fn default() -> Self {
        JobRunner::new(JobRunner::available())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn empty_and_single_item_lists() {
        let r = JobRunner::new(4);
        let empty: Vec<u32> = r.run_map(&[] as &[u32], |_, &x| x);
        assert!(empty.is_empty());
        assert_eq!(r.run_map(&[7u32], |i, &x| (i, x * 2)), vec![(0, 14)]);
    }

    #[test]
    fn results_are_in_submission_order_for_any_thread_count() {
        let items: Vec<usize> = (0..64).collect();
        for threads in [1, 2, 4, 9] {
            let out = JobRunner::new(threads).run_map(&items, |i, &x| {
                assert_eq!(i, x, "index matches item");
                x * x
            });
            let expect: Vec<usize> = items.iter().map(|x| x * x).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn completion_order_differs_but_output_order_does_not() {
        // Job 0 spin-waits until it *observes* another job's completion,
        // so completion order provably differs from submission order
        // without any timing assumption (another worker will claim job 1
        // the moment it spawns; a bounded wait guards against pathological
        // scheduling) — and the output must still come back in submission
        // order.
        let completion = Mutex::new(Vec::new());
        let items: Vec<usize> = (0..8).collect();
        let out = JobRunner::new(4).run_map(&items, |i, &x| {
            if i == 0 {
                let deadline = std::time::Instant::now() + Duration::from_secs(10); // lint: allow(wall-clock) — bounded test watchdog, no simulated metric depends on it
                while completion.lock().unwrap().is_empty()
                    && std::time::Instant::now() < deadline // lint: allow(wall-clock) — same watchdog poll as above
                {
                    std::thread::yield_now();
                }
            }
            completion.lock().unwrap().push(i);
            x + 100
        });
        assert_eq!(out, (100..108).collect::<Vec<usize>>());
        let completed = completion.into_inner().unwrap();
        assert_eq!(completed.len(), 8);
        assert_ne!(
            completed.first(),
            Some(&0),
            "job 0 waits for another completion, so it cannot finish first"
        );
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let r = JobRunner::new(0);
        assert_eq!(r.threads(), 1);
        assert_eq!(r.run_map(&[1u8, 2, 3], |_, &x| x), vec![1, 2, 3]);
    }

    #[test]
    fn pool_caps_workers_at_job_count() {
        // More threads than jobs must not deadlock or drop results.
        let out = JobRunner::new(16).run_map(&[10u32, 20], |_, &x| x + 1);
        assert_eq!(out, vec![11, 21]);
    }
}
