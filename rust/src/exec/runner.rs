//! The worker pool: work-stealing by index, results in submission order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::{JobOutput, SimJob};

/// A bounded worker pool over `std::thread::scope`.
///
/// Dispatch is a single atomic index ("work stealing" in its simplest
/// honest form: whichever worker is free claims the next unclaimed job),
/// so long jobs never convoy short ones behind a fixed pre-partition.
/// Each result is written into the slot of its *submission* index, which
/// makes the output byte-identical for any thread count — the whole
/// determinism story of the execution layer rests on this (see the
/// module docs of [`crate::exec`]).
#[derive(Debug, Clone, Copy)]
pub struct JobRunner {
    threads: usize,
}

impl JobRunner {
    /// A runner with `threads` workers (clamped to at least one).
    pub fn new(threads: usize) -> Self {
        JobRunner {
            threads: threads.max(1),
        }
    }

    /// The host's available parallelism — the default for every
    /// `--threads` flag.
    pub fn available() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every job and return the outputs **in submission order**.
    pub fn run(&self, jobs: &[SimJob]) -> Vec<JobOutput> {
        self.run_map(jobs, |_, job| job.run())
    }

    /// Generic deterministic fan-out: apply `f(index, item)` to every
    /// item on the pool, returning results indexed exactly like `items`.
    ///
    /// `f` must be a pure function of its arguments (plus the item's own
    /// self-contained state) — the pool guarantees *ordering* of results,
    /// and only pure jobs extend that to byte-identical *values* across
    /// thread counts.
    pub fn run_map<I, T, F>(&self, items: &[I], f: F) -> Vec<T>
    where
        I: Sync,
        T: Send,
        F: Fn(usize, &I) -> T + Sync,
    {
        let n = items.len();
        if self.threads == 1 || n <= 1 {
            // Serial fast path: same code path workers take, minus the
            // pool — results are identical by construction.
            return items.iter().enumerate().map(|(i, item)| f(i, item)).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for _ in 0..self.threads.min(n) {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = f(i, &items[i]);
                    *slots[i].lock().unwrap() = Some(out);
                });
            }
        });
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .unwrap()
                    .expect("every submitted job produced a result")
            })
            .collect()
    }
}

impl Default for JobRunner {
    fn default() -> Self {
        JobRunner::new(JobRunner::available())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn empty_and_single_item_lists() {
        let r = JobRunner::new(4);
        let empty: Vec<u32> = r.run_map(&[] as &[u32], |_, &x| x);
        assert!(empty.is_empty());
        assert_eq!(r.run_map(&[7u32], |i, &x| (i, x * 2)), vec![(0, 14)]);
    }

    #[test]
    fn results_are_in_submission_order_for_any_thread_count() {
        let items: Vec<usize> = (0..64).collect();
        for threads in [1, 2, 4, 9] {
            let out = JobRunner::new(threads).run_map(&items, |i, &x| {
                assert_eq!(i, x, "index matches item");
                x * x
            });
            let expect: Vec<usize> = items.iter().map(|x| x * x).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn completion_order_differs_but_output_order_does_not() {
        // Job 0 spin-waits until it *observes* another job's completion,
        // so completion order provably differs from submission order
        // without any timing assumption (another worker will claim job 1
        // the moment it spawns; a bounded wait guards against pathological
        // scheduling) — and the output must still come back in submission
        // order.
        let completion = Mutex::new(Vec::new());
        let items: Vec<usize> = (0..8).collect();
        let out = JobRunner::new(4).run_map(&items, |i, &x| {
            if i == 0 {
                let deadline = std::time::Instant::now() + Duration::from_secs(10); // lint: allow(wall-clock) — bounded test watchdog, no simulated metric depends on it
                while completion.lock().unwrap().is_empty()
                    && std::time::Instant::now() < deadline // lint: allow(wall-clock) — same watchdog poll as above
                {
                    std::thread::yield_now();
                }
            }
            completion.lock().unwrap().push(i);
            x + 100
        });
        assert_eq!(out, (100..108).collect::<Vec<usize>>());
        let completed = completion.into_inner().unwrap();
        assert_eq!(completed.len(), 8);
        assert_ne!(
            completed.first(),
            Some(&0),
            "job 0 waits for another completion, so it cannot finish first"
        );
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let r = JobRunner::new(0);
        assert_eq!(r.threads(), 1);
        assert_eq!(r.run_map(&[1u8, 2, 3], |_, &x| x), vec![1, 2, 3]);
    }

    #[test]
    fn pool_caps_workers_at_job_count() {
        // More threads than jobs must not deadlock or drop results.
        let out = JobRunner::new(16).run_map(&[10u32, 20], |_, &x| x + 1);
        assert_eq!(out, vec![11, 21]);
    }
}
