//! Contended-resource primitives.
//!
//! The simulator models every shared hardware resource (cache data banks,
//! tag banks, crossbar ports, ring links, DRAM command buses) as a server
//! (or bank of servers) that grants access in *reservation* style: a
//! request arriving at cycle `now` is granted at `max(now, next_free)` and
//! occupies the server for its service time.  Because the engine feeds
//! each resource in non-decreasing time order, this is equivalent to a
//! FIFO queue in front of the server but costs O(1) per request — the
//! queueing delay (`grant - now`) *is* the contention the paper measures.
//!
//! Every reservation returns a typed [`Grant`] carrying both the grant
//! cycle and the queueing delay, so callers can attribute contention to
//! the resource that caused it (see [`crate::stats::ContentionBreakdown`])
//! instead of folding it silently into latency.

/// The outcome of one reservation: when service starts and how long the
/// request queued for it.
///
/// For plain servers `grant - request_time == queued`; for composite
/// resources (crossbar transfers, ring sends) `grant` is the completion
/// cycle of the whole operation and `queued` is the *pure queueing* part —
/// the cycles spent waiting behind other traffic, excluding switch
/// latency and serialization.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Grant {
    /// Cycle the reservation takes effect (service start, or delivery for
    /// composite operations — see the type-level docs).
    pub grant: u64,
    /// Cycles spent queued behind other traffic for this reservation.
    pub queued: u64,
}

impl Grant {
    #[inline]
    pub fn new(grant: u64, queued: u64) -> Self {
        Grant { grant, queued }
    }
}

/// A single server with a backlog horizon.
#[derive(Debug, Clone)]
pub struct Server {
    next_free: u64,
}

impl Server {
    pub fn new() -> Self {
        Server { next_free: 0 }
    }

    /// Reserve `occupancy` cycles starting no earlier than `now`.
    /// Returns the grant (service-start cycle + queueing delay).
    #[inline]
    pub fn reserve(&mut self, now: u64, occupancy: u32) -> Grant {
        let grant = self.next_free.max(now);
        self.next_free = grant + occupancy as u64;
        Grant::new(grant, grant - now)
    }

    /// Cycles of queued work beyond `now` (0 if idle).
    #[inline]
    pub fn backlog(&self, now: u64) -> u64 {
        self.next_free.saturating_sub(now)
    }

    /// Would a reservation at `now` be granted within `limit` cycles?
    /// Used to model finite input buffers: when the backlog exceeds the
    /// buffer horizon the upstream component must stall and retry.
    #[inline]
    pub fn would_accept(&self, now: u64, limit: u64) -> bool {
        self.backlog(now) <= limit
    }

    /// Earliest cycle at-or-after `now` at which the backlog has drained
    /// to `limit` — the retry cycle for a stalled upstream component.
    #[inline]
    pub fn drain_cycle(&self, now: u64, limit: u64) -> u64 {
        now.max(self.next_free.saturating_sub(limit))
    }

    /// Backlog horizon: the cycle this server fully drains — which is also
    /// the grant cycle of the next queued arrival (a `reserve` at any
    /// `t <= next_free` is granted exactly here).  `None` when the server
    /// is already idle at `now` and would grant immediately.
    #[inline]
    pub fn next_event(&self, now: u64) -> Option<u64> {
        (self.next_free > now).then_some(self.next_free)
    }
}

impl Default for Server {
    fn default() -> Self {
        Self::new()
    }
}

/// A bank of independent servers indexed by bank id (cache banks, DRAM
/// banks, per-slice queues).
#[derive(Debug, Clone)]
pub struct Banked {
    banks: Vec<Server>,
}

impl Banked {
    pub fn new(n: usize) -> Self {
        Banked {
            banks: (0..n).map(|_| Server::new()).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.banks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.banks.is_empty()
    }

    #[inline]
    pub fn reserve(&mut self, bank: usize, now: u64, occupancy: u32) -> Grant {
        self.banks[bank].reserve(now, occupancy)
    }

    #[inline]
    pub fn backlog(&self, bank: usize, now: u64) -> u64 {
        self.banks[bank].backlog(now)
    }

    #[inline]
    pub fn would_accept(&self, bank: usize, now: u64, limit: u64) -> bool {
        self.banks[bank].would_accept(now, limit)
    }

    /// Total backlog across banks (a contention pressure metric).
    pub fn total_backlog(&self, now: u64) -> u64 {
        self.banks.iter().map(|b| b.backlog(now)).sum()
    }

    /// Pool-wide backlog horizon: the earliest cycle at which *some* bank
    /// can grant a queued arrival.  `None` when a bank is already idle —
    /// the pool then imposes no wait on a request routed there, so it
    /// cannot gate progress.  A reservation on any specific bank is
    /// granted at-or-after this horizon (per-bank: [`Server::next_event`]).
    pub fn next_event(&self, now: u64) -> Option<u64> {
        let mut horizon: Option<u64> = None;
        for b in &self.banks {
            match b.next_event(now) {
                None => return None,
                Some(t) => horizon = Some(horizon.map_or(t, |h| h.min(t))),
            }
        }
        horizon
    }
}

/// `k` identical interchangeable servers (e.g. a multi-ported array or a
/// pool of comparator groups): a reservation takes the earliest-free port.
#[derive(Debug, Clone)]
pub struct MultiPort {
    ports: Vec<u64>,
}

impl MultiPort {
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        MultiPort { ports: vec![0; k] }
    }

    /// Reserve the earliest-available port.
    #[inline]
    pub fn reserve(&mut self, now: u64, occupancy: u32) -> Grant {
        // Find the port that frees first.
        let (idx, &earliest) = self
            .ports
            .iter()
            .enumerate()
            .min_by_key(|(_, &t)| t)
            .unwrap();
        let grant = earliest.max(now);
        self.ports[idx] = grant + occupancy as u64;
        Grant::new(grant, grant - now)
    }

    #[inline]
    pub fn backlog(&self, now: u64) -> u64 {
        self.ports
            .iter()
            .map(|&t| t.saturating_sub(now))
            .min()
            .unwrap_or(0)
    }

    /// Earliest cycle a port is free at-or-after `now` (without reserving).
    #[inline]
    pub fn earliest(&self, now: u64) -> u64 {
        self.ports.iter().copied().min().unwrap_or(0).max(now)
    }

    /// Backlog horizon: the cycle the earliest port frees — the grant
    /// cycle of the next arrival.  `None` when a port is already free at
    /// `now` ([`MultiPort::earliest`] as an event rather than a clamp).
    #[inline]
    pub fn next_event(&self, now: u64) -> Option<u64> {
        let t = self.ports.iter().copied().min().unwrap_or(0);
        (t > now).then_some(t)
    }

    /// Occupy the earliest-free port until `until` (dynamic-duration
    /// reservation — e.g. an MSHR entry held from allocate to fill).
    /// Returns the grant (the cycle the port became available).
    #[inline]
    pub fn occupy_until(&mut self, now: u64, until: u64) -> Grant {
        let idx = self
            .ports
            .iter()
            .enumerate()
            .min_by_key(|(_, &t)| t)
            .map(|(i, _)| i)
            .unwrap();
        let grant = self.ports[idx].max(now);
        self.ports[idx] = until.max(grant);
        Grant::new(grant, grant - now)
    }
}

/// A gap-filling reservation calendar.
///
/// [`Server`] assumes (near-)monotone arrival times: a reservation made at
/// a *future* time blocks every later-made, earlier-timed request.  Data
/// replies are naturally scheduled at future cycles (after cache/DRAM
/// latency), so resources carrying both request and response traffic —
/// crossbar ports, ring links, L2 slice ports, DRAM buses — must be able
/// to fill the idle gap before a future booking.  `Calendar` keeps the
/// set of busy intervals and grants the first gap at-or-after `now`.
///
/// Intervals older than `now - PRUNE_SLACK` are discarded; arrivals are
/// allowed to be non-monotone by up to that slack (far larger than any
/// simulated round-trip).
#[derive(Debug, Clone, Default)]
pub struct Calendar {
    /// (start, end) busy intervals, disjoint, sorted by start.  A plain
    /// vector: merging keeps the list tiny (usually 1–4 entries), so
    /// linear/binary scans beat tree structures by a wide margin — this
    /// is the simulator's hottest structure (see EXPERIMENTS.md §Perf).
    busy: Vec<(u64, u64)>,
}

const PRUNE_SLACK: u64 = 1 << 14;

/// Gaps shorter than this are fused into the neighbouring busy interval
/// when inserting: sub-FUSE-cycle holes are below the model's timing
/// granularity, and fusing keeps the interval lists short (fragmentation
/// was the top profile entry before this).
const FUSE_GAP: u64 = 2;

impl Calendar {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve `occ` consecutive cycles starting no earlier than `now`;
    /// returns the grant (start cycle + queueing delay), filling the
    /// earliest gap.
    pub fn reserve(&mut self, now: u64, occ: u32) -> Grant {
        let occ = occ.max(1) as u64;
        // Prune intervals that ended far before `now`: arrivals may be
        // non-monotone by up to PRUNE_SLACK, never more.
        if let Some(&(_, first_end)) = self.busy.first() {
            if first_end + PRUNE_SLACK < now {
                let cutoff = now - PRUNE_SLACK;
                let keep_from = self.busy.partition_point(|&(_, e)| e < cutoff);
                self.busy.drain(..keep_from);
            }
        }
        // Find the first interval whose end is after `now`, then walk
        // forward looking for a gap of `occ` cycles.
        let mut idx = self.busy.partition_point(|&(_, e)| e <= now);
        let mut t = now;
        while idx < self.busy.len() {
            let (s, e) = self.busy[idx];
            if t + occ <= s {
                break; // gap before interval idx
            }
            if e > t {
                t = e;
            }
            idx += 1;
        }
        // Insert [t, t+occ) at position idx, merging neighbours (gaps of
        // up to FUSE_GAP cycles are absorbed to bound fragmentation).
        let end = t + occ;
        let merge_prev = idx > 0 && self.busy[idx - 1].1 + FUSE_GAP >= t;
        let merge_next = idx < self.busy.len() && end + FUSE_GAP >= self.busy[idx].0;
        match (merge_prev, merge_next) {
            (true, true) => {
                self.busy[idx - 1].1 = self.busy[idx].1.max(end);
                self.busy.remove(idx);
            }
            (true, false) => self.busy[idx - 1].1 = end,
            (false, true) => self.busy[idx].0 = t,
            (false, false) => self.busy.insert(idx, (t, end)),
        }
        Grant::new(t, t - now)
    }

    /// Pending work at-or-after `now` (buffer-occupancy proxy).
    pub fn backlog(&self, now: u64) -> u64 {
        self.busy
            .iter()
            .map(|&(s, e)| e.saturating_sub(s.max(now)))
            .sum()
    }

    pub fn would_accept(&self, now: u64, limit: u64) -> bool {
        self.backlog(now) <= limit
    }

    /// Earliest cycle at-or-after `now` at which the backlog has drained
    /// to `limit` cycles of pending work.  This is the retry cycle for a
    /// finite-buffer stall: instead of reserving into an unbounded future,
    /// a backpressured upstream component waits until this cycle and then
    /// re-offers its request (see `l2::MemSystem::fetch`).
    pub fn drain_cycle(&self, now: u64, limit: u64) -> u64 {
        if self.backlog(now) <= limit {
            return now;
        }
        // Walk intervals from the tail, accumulating the work that lies
        // strictly after the candidate drain point.
        let mut after = 0u64;
        for &(s, e) in self.busy.iter().rev() {
            let s = s.max(now);
            if e <= s {
                continue; // entirely in the past
            }
            let work = e - s;
            if after + work > limit {
                // The drain point lies inside [s, e): remaining work at t
                // is (e - t) + after, solve (e - t) + after == limit.
                let t = e - (limit - after);
                return t.max(s).max(now);
            }
            after += work;
        }
        now
    }

    /// Grant horizon: the cycle a 1-cycle reservation arriving at `now`
    /// would be granted — the start of the first usable gap in the busy
    /// set.  `None` when the calendar can grant at `now` itself.
    ///
    /// This is a *grant* horizon, not a standalone jump target: a
    /// finite-buffer retry ([`Calendar::drain_cycle`] with a nonzero
    /// `limit`) can land inside the busy window, before this cycle.  Only
    /// the full drain (`drain_cycle(now, 0)`) is guaranteed to land
    /// at-or-after it (see the `horizon_tests` properties) — which is why
    /// the engine resolves retries analytically at reservation time into
    /// its wake heap instead of polling resource horizons
    /// (`docs/ARCHITECTURE.md` §Event-driven core).
    pub fn next_event(&self, now: u64) -> Option<u64> {
        let mut idx = self.busy.partition_point(|&(_, e)| e <= now);
        let mut t = now;
        while idx < self.busy.len() {
            let (s, e) = self.busy[idx];
            if t + 1 <= s {
                break; // a 1-cycle gap before interval idx
            }
            if e > t {
                t = e;
            }
            idx += 1;
        }
        (t > now).then_some(t)
    }
}

/// A bank of independent calendars.
#[derive(Debug, Clone)]
pub struct BankedCalendar {
    banks: Vec<Calendar>,
}

impl BankedCalendar {
    pub fn new(n: usize) -> Self {
        BankedCalendar {
            banks: (0..n).map(|_| Calendar::new()).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.banks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.banks.is_empty()
    }

    #[inline]
    pub fn reserve(&mut self, bank: usize, now: u64, occ: u32) -> Grant {
        self.banks[bank].reserve(now, occ)
    }

    #[inline]
    pub fn backlog(&self, bank: usize, now: u64) -> u64 {
        self.banks[bank].backlog(now)
    }

    /// Pool-wide grant horizon: the earliest cycle at which some bank can
    /// grant (mirrors [`Banked::next_event`]; `None` when a bank can
    /// already grant at `now`).
    pub fn next_event(&self, now: u64) -> Option<u64> {
        let mut horizon: Option<u64> = None;
        for b in &self.banks {
            match b.next_event(now) {
                None => return None,
                Some(t) => horizon = Some(horizon.map_or(t, |h| h.min(t))),
            }
        }
        horizon
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_server_grants_immediately() {
        let mut s = Server::new();
        assert_eq!(s.reserve(100, 4), Grant::new(100, 0));
        assert_eq!(s.backlog(100), 4);
    }

    #[test]
    fn busy_server_serializes() {
        let mut s = Server::new();
        assert_eq!(s.reserve(10, 2).grant, 10); // busy until 12
        assert_eq!(s.reserve(10, 2), Grant::new(12, 2)); // queued behind
        assert_eq!(s.reserve(11, 2), Grant::new(14, 3));
        assert_eq!(s.reserve(100, 2), Grant::new(100, 0)); // idle again later
    }

    #[test]
    fn would_accept_models_finite_buffer() {
        let mut s = Server::new();
        for _ in 0..10 {
            s.reserve(0, 4);
        }
        assert_eq!(s.backlog(0), 40);
        assert!(!s.would_accept(0, 16));
        assert!(s.would_accept(0, 64));
        assert!(s.would_accept(39, 4));
        // Drain cycle: backlog(t) == 16 at t = 40 - 16 = 24.
        assert_eq!(s.drain_cycle(0, 16), 24);
        assert_eq!(s.drain_cycle(30, 16), 30, "already drained");
    }

    #[test]
    fn banked_banks_are_independent() {
        let mut b = Banked::new(4);
        assert_eq!(b.reserve(0, 0, 10).grant, 0);
        assert_eq!(b.reserve(1, 0, 10).grant, 0, "bank 1 idle");
        assert_eq!(b.reserve(0, 0, 10), Grant::new(10, 10), "bank 0 queued");
        assert_eq!(b.total_backlog(0), 30);
    }

    #[test]
    fn multiport_spreads_across_ports() {
        let mut m = MultiPort::new(2);
        assert_eq!(m.reserve(0, 4).grant, 0); // port A busy till 4
        assert_eq!(m.reserve(0, 4).grant, 0); // port B busy till 4
        assert_eq!(m.reserve(0, 4), Grant::new(4, 4)); // back to A
        assert_eq!(m.reserve(0, 4), Grant::new(4, 4)); // back to B
        assert_eq!(m.reserve(0, 4), Grant::new(8, 8));
    }

    #[test]
    fn grants_are_monotone_for_monotone_arrivals() {
        // The engine feeds resources in time order; grants must then be
        // non-decreasing (FIFO equivalence).
        let mut s = Server::new();
        let mut last = 0;
        let mut arrivals = vec![0u64, 0, 1, 3, 3, 3, 10, 11, 50];
        arrivals.sort_unstable();
        for a in arrivals {
            let g = s.reserve(a, 3);
            assert!(g.grant >= last);
            assert_eq!(g.queued, g.grant - a, "queued is the grant delay");
            last = g.grant;
        }
    }
}

impl Banked {
    /// Reserve on bank 0 — convenience for single-bank uses in tests.
    pub fn reserve0(&mut self, now: u64, occupancy: u32) -> Grant {
        self.reserve(0, now, occupancy)
    }
}

#[cfg(test)]
mod calendar_tests {
    use super::*;

    #[test]
    fn grants_gap_before_future_booking() {
        let mut c = Calendar::new();
        assert_eq!(c.reserve(1000, 4).grant, 1000, "future booking");
        // A present-time request must NOT queue behind it.
        assert_eq!(c.reserve(10, 4), Grant::new(10, 0));
        // And the gap between them is usable too.
        assert_eq!(c.reserve(10, 4), Grant::new(14, 4));
    }

    #[test]
    fn respects_existing_intervals() {
        let mut c = Calendar::new();
        c.reserve(10, 10); // [10,20)
        assert_eq!(c.reserve(5, 5).grant, 5, "gap [5,10) exactly fits");
        assert_eq!(c.reserve(5, 5).grant, 20, "now everything before 20 is busy");
        assert_eq!(c.reserve(12, 3).grant, 25, "inside busy -> after [20,25)");
    }

    #[test]
    fn fifo_when_fed_monotonically() {
        // Fed like a Server, Calendar must behave like a Server.
        let mut c = Calendar::new();
        let mut s = Server::new();
        let arrivals = [0u64, 0, 1, 3, 3, 7, 20, 21];
        for &a in &arrivals {
            assert_eq!(c.reserve(a, 3), s.reserve(a, 3), "arrival {a}");
        }
    }

    #[test]
    fn merging_keeps_map_small() {
        let mut c = Calendar::new();
        for i in 0..1000u64 {
            c.reserve(i, 1);
        }
        assert!(c.busy.len() <= 2, "adjacent intervals must merge: {}", c.busy.len());
    }

    #[test]
    fn backlog_counts_future_work() {
        let mut c = Calendar::new();
        c.reserve(100, 10);
        assert_eq!(c.backlog(0), 10);
        assert_eq!(c.backlog(105), 5);
        assert!(c.would_accept(0, 16));
        assert!(!c.would_accept(0, 4));
    }

    #[test]
    fn drain_cycle_finds_retry_point() {
        let mut c = Calendar::new();
        c.reserve(100, 10); // busy [100, 110)
        // Already under the limit now:
        assert_eq!(c.drain_cycle(0, 10), 0);
        // Limit 4: backlog(t) == 4 at t = 106.
        assert_eq!(c.drain_cycle(0, 4), 106);
        assert_eq!(c.backlog(c.drain_cycle(0, 4)), 4);
        // Limit 0: fully drained only at the end of the booking.
        assert_eq!(c.drain_cycle(0, 0), 110);
        // Multiple intervals:
        let mut c2 = Calendar::new();
        c2.reserve(0, 10); // [0, 10)
        c2.reserve(100, 10); // [100, 110)
        let t = c2.drain_cycle(0, 12);
        assert!(c2.backlog(t) <= 12, "backlog at drain point");
        assert!(t == 0 || c2.backlog(t - 1) > 12, "earliest such cycle");
    }

    #[test]
    fn banked_calendar_independent_banks() {
        let mut b = BankedCalendar::new(2);
        assert_eq!(b.reserve(0, 0, 10).grant, 0);
        assert_eq!(b.reserve(1, 0, 10).grant, 0);
        assert_eq!(b.reserve(0, 0, 10), Grant::new(10, 10));
    }

    #[test]
    fn pruning_bounds_memory() {
        let mut c = Calendar::new();
        for i in 0..200_000u64 {
            c.reserve(i * 2, 1); // never adjacent -> no merge
        }
        assert!(
            c.busy.len() < 40_000,
            "old intervals must be pruned: {}",
            c.busy.len()
        );
    }
}

/// Properties of the `next_event()` horizon accessors (the event-driven
/// engine's resource-side contract — see `docs/ARCHITECTURE.md`
/// §Event-driven core).
#[cfg(test)]
mod horizon_tests {
    use super::*;
    use crate::testkit::{check, int_range, vec_of, Gen};

    /// Random monotone (arrival, occupancy) schedules.
    fn schedule() -> Gen<Vec<(u64, u32)>> {
        vec_of(int_range(0, 5 * 8 + 3), int_range(4, 40)).map(|raw| {
            let mut now = 0u64;
            raw.iter()
                .map(|&packed| {
                    now += packed / 8; // gap 0..=5
                    (now, (packed % 8 + 1) as u32) // occupancy 1..=8
                })
                .collect()
        })
    }

    #[test]
    fn property_horizon_is_monotone_under_reservations() {
        // Fed in time order (the engine's contract), the effective grant
        // bound `next_event(now).unwrap_or(now)` never moves backwards.
        check("horizon-monotone", 0xE7E17, 64, &schedule(), |sched| {
            let mut srv = Server::new();
            let mut mp = MultiPort::new(2);
            let mut cal = Calendar::new();
            let mut last = [0u64; 3];
            for &(now, occ) in sched {
                let bounds = [
                    srv.next_event(now).unwrap_or(now),
                    mp.next_event(now).unwrap_or(now),
                    cal.next_event(now).unwrap_or(now),
                ];
                for (i, (&b, &l)) in bounds.iter().zip(last.iter()).enumerate() {
                    if b < l {
                        return Err(format!(
                            "resource {i}: horizon regressed {l} -> {b} at now={now}"
                        ));
                    }
                }
                last = bounds;
                srv.reserve(now, occ);
                mp.reserve(now, occ);
                cal.reserve(now, occ);
            }
            Ok(())
        });
    }

    #[test]
    fn property_horizon_agrees_with_observed_grants() {
        // The reported horizon is exactly the next grant time for Server /
        // MultiPort / a 1-cycle Calendar reservation, the min over banks
        // for the pooled types, and a lower bound for wider reservations.
        check("horizon-grants", 0x6A117, 64, &schedule(), |sched| {
            let mut srv = Server::new();
            let mut mp = MultiPort::new(3);
            let mut cal = Calendar::new();
            let mut bank = Banked::new(2);
            for (i, &(now, occ)) in sched.iter().enumerate() {
                let want = srv.next_event(now).unwrap_or(now);
                let got = srv.reserve(now, occ).grant;
                if got != want {
                    return Err(format!("Server: horizon {want} != grant {got}"));
                }
                let want = mp.next_event(now).unwrap_or(now);
                let got = mp.reserve(now, occ).grant;
                if got != want {
                    return Err(format!("MultiPort: horizon {want} != grant {got}"));
                }
                let want = cal.next_event(now).unwrap_or(now);
                let got1 = cal.clone().reserve(now, 1).grant;
                if got1 != want {
                    return Err(format!("Calendar occ=1: horizon {want} != grant {got1}"));
                }
                let got = cal.reserve(now, occ).grant;
                if got < want {
                    return Err(format!("Calendar: grant {got} before horizon {want}"));
                }
                // Pool horizon = min over banks of the per-bank grant.
                let pool = bank.next_event(now).unwrap_or(now);
                let best = (0..bank.len())
                    .map(|b| bank.clone().reserve(b, now, occ).grant)
                    .min()
                    .unwrap();
                if best != pool {
                    return Err(format!("Banked: pool horizon {pool} != best grant {best}"));
                }
                bank.reserve(i % bank.len(), now, occ);
            }
            Ok(())
        });
    }

    #[test]
    fn property_drain_cycle_respects_horizon() {
        // `drain_cycle` events are exact (backlog meets the limit, and at
        // the earliest such cycle), and a *full* drain never precedes the
        // grant horizon — the guarantees the eager-retry engine design
        // rests on.  Schedules mix past and future bookings so gaps exist.
        let gen = vec_of(int_range(0, 400), int_range(6, 30)).map(|starts| {
            let mut cal = Calendar::new();
            for (i, &s) in starts.iter().enumerate() {
                cal.reserve(s, (i % 7 + 1) as u32);
            }
            cal
        });
        check("drain-vs-horizon", 0xD7A1A, 96, &gen, |cal| {
            for now in [0u64, 3, 50, 120, 399] {
                for limit in [0u64, 1, 4, 13] {
                    let t = cal.drain_cycle(now, limit);
                    if t < now {
                        return Err(format!("drain_cycle({now},{limit}) = {t} < now"));
                    }
                    if cal.backlog(t) > limit {
                        return Err(format!(
                            "drain_cycle({now},{limit}) = {t} fires early: backlog {}",
                            cal.backlog(t)
                        ));
                    }
                    if t > now && cal.backlog(t - 1) <= limit {
                        return Err(format!(
                            "drain_cycle({now},{limit}) = {t} not the earliest event"
                        ));
                    }
                }
                let full = cal.drain_cycle(now, 0);
                let horizon = cal.next_event(now).unwrap_or(now);
                if full < horizon {
                    return Err(format!(
                        "full drain {full} precedes grant horizon {horizon} at now={now}"
                    ));
                }
            }
            Ok(())
        });
    }
}
