//! Hardware overhead model (§IV-D): area and leakage power of ATA-Cache's
//! extra structures at 45 nm (Nangate open cell library class numbers).
//!
//! The paper reports, for the 30-core / 3-cluster configuration:
//!   crossbar area      ≈ 1.02 mm²
//!   comparator area    ≈ 0.02 mm²
//!   total leakage      ≈ 5.55 mW
//!
//! This module reproduces those numbers from first-principles scaling
//! relations (wire-dominated crossbar area ∝ ports², comparator area ∝
//! width × count), calibrated at the paper's design point — so the bench
//! can also report how overhead scales with cluster size, the ablation
//! the paper leaves implicit.

use crate::config::GpuConfig;

/// 45 nm technology constants, calibrated so the paper config lands on
/// the reported values.
#[derive(Debug, Clone, Copy)]
pub struct Tech45 {
    /// mm² per (port × port × bit-lane) of a matrix crossbar at 45 nm.
    /// Calibrated: 3 clusters × 10×10 ports × 256-bit datapath = 1.02 mm².
    pub xbar_mm2_per_port2_bit: f64,
    /// mm² per comparator bit (tag comparators are narrow XOR trees).
    pub comparator_mm2_per_bit: f64,
    /// Leakage: mW per mm² of active logic at 45 nm nominal Vdd.
    pub leakage_mw_per_mm2: f64,
}

impl Default for Tech45 {
    fn default() -> Self {
        Tech45 {
            xbar_mm2_per_port2_bit: 1.02 / (3.0 * 10.0 * 10.0 * 256.0),
            // Calibrated: 3 clusters × 10 groups × 10 arrays × 64 ways =
            // 19 200 comparators × 37 tag bits = 710 400 bits → 0.02 mm².
            comparator_mm2_per_bit: 0.02 / 710_400.0,
            leakage_mw_per_mm2: 5.55 / (1.02 + 0.02),
        }
    }
}

/// Derived overhead report.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverheadReport {
    pub crossbar_mm2: f64,
    pub comparator_mm2: f64,
    pub total_mm2: f64,
    pub leakage_mw: f64,
    /// Fraction of a ~500 mm² GPU die.
    pub die_fraction: f64,
    pub comparator_count: u64,
    pub comparator_bits: u64,
}

/// Tag width for the comparator sizing: 64-bit line address minus set
/// index bits (8 sets → 3 bits) — matching the simulator's decode. Real
/// designs compare ~25 physical bits; we expose the knob.
pub fn tag_bits(cfg: &GpuConfig) -> u64 {
    // 40-bit physical line address space minus set bits.
    40 - (cfg.l1.sets().trailing_zeros() as u64)
}

pub fn estimate(cfg: &GpuConfig, tech: &Tech45) -> OverheadReport {
    let cpc = cfg.cores_per_cluster() as f64;
    let clusters = cfg.clusters as f64;

    // Intra-cluster data crossbar: cpc × cpc ports, line-sector datapath
    // (256 bits = 32 B/cycle), wire-dominated ⇒ area ∝ ports².
    let datapath_bits = (cfg.l1.sector_bytes * 8) as f64;
    let crossbar_mm2 = tech.xbar_mm2_per_port2_bit * clusters * cpc * cpc * datapath_bits;

    // Comparator groups: one group per core; each group compares against
    // every way of every tag array in the cluster in parallel.
    let groups_per_cluster = cfg.sharing.ata_comparator_groups as f64;
    let comparators_per_group = cpc * cfg.l1.assoc as f64;
    let comparator_count = (clusters * groups_per_cluster * comparators_per_group) as u64;
    let bits = tag_bits(cfg);
    let comparator_bits = comparator_count * bits;
    let comparator_mm2 = tech.comparator_mm2_per_bit * comparator_bits as f64;

    let total_mm2 = crossbar_mm2 + comparator_mm2;
    OverheadReport {
        crossbar_mm2,
        comparator_mm2,
        total_mm2,
        leakage_mw: total_mm2 * tech.leakage_mw_per_mm2,
        die_fraction: total_mm2 / 500.0,
        comparator_count,
        comparator_bits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::L1ArchKind;

    #[test]
    fn paper_config_matches_reported_overheads() {
        let cfg = GpuConfig::paper(L1ArchKind::Ata);
        let r = estimate(&cfg, &Tech45::default());
        // §IV-D: 1.02 mm² crossbar, 0.02 mm² comparators, 5.55 mW leakage.
        assert!((r.crossbar_mm2 - 1.02).abs() < 0.01, "{}", r.crossbar_mm2);
        assert!(
            (r.comparator_mm2 - 0.02).abs() < 0.01,
            "{}",
            r.comparator_mm2
        );
        assert!((r.leakage_mw - 5.55).abs() < 0.15, "{}", r.leakage_mw);
        assert!(r.die_fraction < 0.005, "negligible die cost");
    }

    #[test]
    fn crossbar_area_scales_quadratically_with_cluster_size() {
        let mut small = GpuConfig::paper(L1ArchKind::Ata);
        small.cores = 15;
        small.clusters = 3; // 5 per cluster
        small.sharing.ata_comparator_groups = 5;
        let big = GpuConfig::paper(L1ArchKind::Ata);
        let t = Tech45::default();
        let rs = estimate(&small, &t);
        let rb = estimate(&big, &t);
        let ratio = rb.crossbar_mm2 / rs.crossbar_mm2;
        assert!((ratio - 4.0).abs() < 0.01, "10²/5² = 4, got {ratio}");
    }

    #[test]
    fn comparator_count_formula() {
        let cfg = GpuConfig::paper(L1ArchKind::Ata);
        let r = estimate(&cfg, &Tech45::default());
        // 3 clusters × 10 groups × (10 arrays × 64 ways) = 19200.
        assert_eq!(r.comparator_count, 19_200);
    }
}
