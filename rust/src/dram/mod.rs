//! DRAM timing model — Table II row 4.
//!
//! 12 controllers × 16 banks with the paper's GDDR timing parameters
//! (tCL, tRP, tRC, tRAS, tCCD, tRCD, tRRD, tCDLR, tWR), a row buffer per
//! bank, a shared data bus per controller, and a bounded request queue.
//! Timings are specified in 3.5 GHz memory-clock cycles and converted to
//! the 1.365 GHz core-clock domain the engine runs in.
//!
//! The model serves requests in arrival order per controller (FCFS across
//! banks with row-buffer hits naturally faster — the first-order behaviour
//! FR-FCFS converges to under the moderate queue depths the paper's
//! workloads produce).

use crate::config::DramConfig;
use crate::mem::{decode, LineAddr};
use crate::resource::{Calendar, Grant};

/// Outcome class of one DRAM access (for stats).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowOutcome {
    Hit,
    Miss,
    Conflict,
}

#[derive(Debug, Clone, Default)]
struct Bank {
    open_row: Option<u64>,
    /// Earliest core-cycle the bank can issue its next column command.
    ready: u64,
    /// Core-cycle of the last ACT (for tRC/tRRD legality).
    last_act: u64,
    /// Earliest cycle a precharge may start (tRAS from ACT, tWR after a
    /// write burst).
    pre_ok: u64,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct DramStats {
    pub reads: u64,
    pub writes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    pub total_service_cycles: u64,
    pub queue_rejects: u64,
}

/// Timing constants converted to core cycles.
#[derive(Debug, Clone, Copy)]
struct CoreTimings {
    cl: u64,
    rp: u64,
    rc: u64,
    ras: u64,
    ccd: u64,
    rcd: u64,
    rrd: u64,
    cdlr: u64,
    wr: u64,
    burst: u64,
}

#[derive(Debug, Clone)]
pub struct Dram {
    banks: Vec<Vec<Bank>>, // [controller][bank]
    /// Per-controller shared data bus.
    bus: Vec<Calendar>,
    /// Per-controller last-ACT cycle for tRRD (ACT-to-ACT across banks).
    last_act_ctrl: Vec<u64>,
    t: CoreTimings,
    queue_horizon: u64,
    pub stats: DramStats,
    controllers: usize,
    banks_per: usize,
}

impl Dram {
    pub fn new(cfg: &DramConfig, core_clock_ghz: f64) -> Self {
        let ratio = cfg.clock_ghz / core_clock_ghz;
        let cv = |mem_cycles: u32| -> u64 { ((mem_cycles as f64) / ratio).ceil().max(1.0) as u64 };
        let t = CoreTimings {
            cl: cv(cfg.t_cl),
            rp: cv(cfg.t_rp),
            rc: cv(cfg.t_rc),
            ras: cv(cfg.t_ras),
            ccd: cv(cfg.t_ccd),
            rcd: cv(cfg.t_rcd),
            rrd: cv(cfg.t_rrd),
            cdlr: cv(cfg.t_cdlr),
            wr: cv(cfg.t_wr),
            burst: cv(cfg.burst_cycles),
        };
        // A full queue of row-miss requests bounds the backlog horizon.
        let worst_service = t.rp + t.rcd + t.cl + t.burst;
        Dram {
            banks: vec![vec![Bank::default(); cfg.banks_per_controller]; cfg.controllers],
            bus: (0..cfg.controllers).map(|_| Calendar::new()).collect(),
            last_act_ctrl: vec![0; cfg.controllers],
            t,
            queue_horizon: cfg.queue_depth as u64 * worst_service,
            stats: DramStats::default(),
            controllers: cfg.controllers,
            banks_per: cfg.banks_per_controller,
        }
    }

    /// Would the controller's queue admit a request at `now`?  (Finite
    /// queue modeled as a backlog horizon on the data bus.)
    pub fn would_accept(&self, line: LineAddr, now: u64) -> bool {
        let (ctrl, _) = decode::dram_bank(line, self.controllers, self.banks_per);
        self.bus[ctrl].would_accept(now, self.queue_horizon)
    }

    /// Cycles a requester must stall before the controller's finite queue
    /// admits it (0 when `would_accept`) — the backpressure retry point.
    pub fn admission_delay(&self, line: LineAddr, now: u64) -> u64 {
        let (ctrl, _) = decode::dram_bank(line, self.controllers, self.banks_per);
        self.bus[ctrl].drain_cycle(now, self.queue_horizon) - now
    }

    /// Service a line access (`sectors` 32 B bursts).  The returned
    /// [`Grant`] carries the data-transfer completion cycle (`grant`) and
    /// the queueing delay (`queued` = bank-ready wait + data-bus wait,
    /// excluding activation/CAS service time).
    pub fn access(&mut self, line: LineAddr, now: u64, sectors: u32, is_write: bool) -> Grant {
        let (ctrl, bank_idx) = decode::dram_bank(line, self.controllers, self.banks_per);
        let row = decode::dram_row(line);
        let t = self.t;
        let bank = &mut self.banks[ctrl][bank_idx];

        // Column command can start once the bank is ready and the request
        // has arrived.  Waiting for a busy bank is queueing, not service.
        let mut start = now.max(bank.ready);
        let bank_wait = start - now;
        let outcome;
        match bank.open_row {
            Some(r) if r == row => {
                outcome = RowOutcome::Hit;
            }
            Some(_) => {
                outcome = RowOutcome::Conflict;
                // Precharge legality: tRAS since ACT, tWR after writes.
                let pre_start = start.max(bank.pre_ok);
                // ACT legality: tRC since last ACT on this bank, tRRD on ctrl.
                let act_start = (pre_start + t.rp)
                    .max(bank.last_act + t.rc)
                    .max(self.last_act_ctrl[ctrl] + t.rrd);
                bank.last_act = act_start;
                self.last_act_ctrl[ctrl] = act_start;
                bank.pre_ok = act_start + t.ras;
                start = act_start + t.rcd;
                bank.open_row = Some(row);
            }
            None => {
                outcome = RowOutcome::Miss;
                let act_start = start
                    .max(bank.last_act + t.rc)
                    .max(self.last_act_ctrl[ctrl] + t.rrd);
                bank.last_act = act_start;
                self.last_act_ctrl[ctrl] = act_start;
                bank.pre_ok = act_start + t.ras;
                start = act_start + t.rcd;
                bank.open_row = Some(row);
            }
        }

        // Data transfer: one burst per sector on the controller bus,
        // tCCD between column commands on the same bank.
        let n = sectors.max(1) as u64;
        let col_ready = start + t.cl;
        let bus = self.bus[ctrl].reserve(col_ready, (n * t.burst) as u32);
        let done = bus.grant + n * t.burst;
        bank.ready = start + n * t.ccd;
        if is_write {
            // Write recovery gates the next precharge; reads after writes
            // pay tCDLR on the same bank.
            bank.pre_ok = bank.pre_ok.max(done + t.wr);
            bank.ready = bank.ready.max(done + t.cdlr);
            self.stats.writes += 1;
        } else {
            self.stats.reads += 1;
        }
        match outcome {
            RowOutcome::Hit => self.stats.row_hits += 1,
            RowOutcome::Miss => self.stats.row_misses += 1,
            RowOutcome::Conflict => self.stats.row_conflicts += 1,
        }
        self.stats.total_service_cycles += done - now;
        Grant::new(done, bank_wait + bus.queued)
    }

    /// Admission-gated read: wait out the controller queue's backpressure
    /// (counted in `queue_rejects`), then access.  Returns the access
    /// grant plus the admission stall so the caller can attribute the
    /// whole wait; `grant.queued` excludes the stall (bank/bus wait only).
    pub fn read_gated(&mut self, line: LineAddr, now: u64, sectors: u32) -> (Grant, u64) {
        let stall = self.admission_delay(line, now);
        if stall > 0 {
            self.stats.queue_rejects += 1;
        }
        let g = self.access(line, now + stall, sectors, false);
        (g, stall)
    }

    /// Diagnostic horizon: the earliest cycle at-or-after `now` at which
    /// any controller's data bus still has booked transfers — `None` when
    /// all controllers are idle.  Used by the failure snapshot
    /// (`engine::FailSnapshot::mem_horizon`), not by scheduling.
    pub fn next_event(&self, now: u64) -> Option<u64> {
        self.bus.iter().filter_map(|c| c.next_event(now)).min()
    }

    /// Mean service latency in core cycles.
    pub fn mean_latency(&self) -> f64 {
        let n = self.stats.reads + self.stats.writes;
        if n == 0 {
            0.0
        } else {
            self.stats.total_service_cycles as f64 / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dram() -> Dram {
        Dram::new(&DramConfig::default(), 1.365)
    }

    #[test]
    fn first_access_pays_activate() {
        let mut d = dram();
        let g = d.access(0, 0, 1, false);
        // tRCD + tCL + burst, all scaled by 1.365/3.5 ≈ 0.39:
        // ≥ (20+20+4)*0.39 ≈ 17 core cycles.
        assert!(g.grant >= 15, "got {}", g.grant);
        assert_eq!(g.queued, 0, "idle bank and bus: activation is service");
        assert_eq!(d.stats.row_misses, 1);
    }

    #[test]
    fn row_hit_is_faster_than_conflict() {
        let mut d = dram();
        d.access(0, 0, 1, false);
        let t0 = 10_000;
        let hit_done = d.access(1, t0, 1, false).grant - t0; // same 2 KiB row
        assert_eq!(d.stats.row_hits, 1);

        let mut d2 = dram();
        d2.access(0, 0, 1, false);
        // Find a line mapping to the same (ctrl, bank) but another row.
        let (c0, b0) = decode::dram_bank(0, 12, 16);
        let mut other = None;
        for cand in 16u64..100_000 {
            if decode::dram_bank(cand, 12, 16) == (c0, b0) && decode::dram_row(cand) != decode::dram_row(0) {
                other = Some(cand);
                break;
            }
        }
        let other = other.expect("found conflicting line");
        let conf_done = d2.access(other, t0, 1, false).grant - t0;
        assert_eq!(d2.stats.row_conflicts, 1);
        assert!(
            conf_done > hit_done,
            "conflict ({conf_done}) must be slower than row hit ({hit_done})"
        );
    }

    #[test]
    fn bus_serializes_same_controller() {
        let mut d = dram();
        // Two requests to the same controller at the same instant: find two
        // lines on the same ctrl, different banks.
        let (c0, b0) = decode::dram_bank(0, 12, 16);
        let mut sibling = None;
        for cand in 1u64..100_000 {
            let (c, b) = decode::dram_bank(cand, 12, 16);
            if c == c0 && b != b0 {
                sibling = Some(cand);
                break;
            }
        }
        let s = sibling.unwrap();
        let d1 = d.access(0, 0, 4, false);
        let d2 = d.access(s, 0, 4, false);
        assert_ne!(d1.grant, d2.grant, "shared data bus must serialize bursts");
        assert!(d2.queued > 0, "bus wait must be reported as queueing");
    }

    #[test]
    fn different_controllers_are_parallel() {
        let mut d = dram();
        let (c0, _) = decode::dram_bank(0, 12, 16);
        let mut other = None;
        for cand in 1u64..100_000 {
            if decode::dram_bank(cand, 12, 16).0 != c0 {
                other = Some(cand);
                break;
            }
        }
        let o = other.unwrap();
        let d1 = d.access(0, 0, 1, false);
        let d2 = d.access(o, 0, 1, false);
        // Both independent: same service time from time 0, no queueing.
        assert_eq!(d1, d2);
        assert_eq!(d2.queued, 0);
    }

    #[test]
    fn write_recovery_delays_reads() {
        let mut d = dram();
        d.access(0, 0, 1, true);
        let t_after_write = d.access(1, 0, 1, false).grant; // same bank row hit after write
        let mut d2 = dram();
        d2.access(0, 0, 1, false);
        let t_after_read = d2.access(1, 0, 1, false).grant;
        assert!(
            t_after_write > t_after_read,
            "tCDLR must delay read-after-write ({t_after_write} vs {t_after_read})"
        );
        assert_eq!(d.stats.writes, 1);
    }

    #[test]
    fn queue_horizon_backpressures() {
        let mut d = dram();
        assert!(d.would_accept(0, 0));
        assert_eq!(d.admission_delay(0, 0), 0);
        for _ in 0..2000 {
            d.access(0, 0, 4, false);
        }
        assert!(!d.would_accept(0, 0), "saturated controller must reject");
        let delay = d.admission_delay(0, 0);
        assert!(delay > 0);
        assert!(d.would_accept(0, delay), "retry at the drain cycle succeeds");
    }

    #[test]
    fn mean_latency_accumulates() {
        let mut d = dram();
        assert_eq!(d.mean_latency(), 0.0);
        d.access(0, 0, 1, false);
        assert!(d.mean_latency() > 0.0);
    }
}
