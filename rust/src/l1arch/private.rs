//! Private per-core L1 — the conventional organization and the paper's
//! normalization baseline.  Each core's cache maps the entire address
//! space; misses go straight to L2; no inter-core path exists, so
//! replicated lines burn capacity in every requesting core (the
//! inefficiency motivating the paper).
//!
//! As a policy this is the identity distributor: every transaction runs
//! the pipeline's local load/store path at its own core.

use crate::config::{GpuConfig, L1ArchKind};
use crate::l2::MemSystem;
use crate::mem::MemTxn;

use super::pipeline::{PipelineCtx, SharingPolicy};

/// Registry constructor.
pub fn policy(_cfg: &GpuConfig) -> Box<dyn SharingPolicy> {
    Box::new(PrivatePolicy)
}

#[derive(Debug)]
pub struct PrivatePolicy;

impl SharingPolicy for PrivatePolicy {
    fn kind(&self) -> L1ArchKind {
        L1ArchKind::Private
    }

    fn access(&mut self, p: &mut PipelineCtx, txn: &mut MemTxn, mem: &mut MemSystem) {
        let now = txn.now();
        if txn.req.is_write() {
            p.store_local(txn, now, mem);
        } else {
            p.local_load(txn, mem);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::l1arch::{access_once, build, L1Arch};
    use crate::mem::{AccessKind, LineAddr, MemRequest};

    fn setup() -> (Box<dyn L1Arch>, MemSystem) {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        (build(&cfg), MemSystem::new(&cfg))
    }

    fn load(id: u64, core: u32, line: LineAddr) -> MemRequest {
        MemRequest {
            id,
            core,
            warp: 0,
            inst: id,
            line,
            sectors: 0b1111,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    #[test]
    fn cold_miss_then_hit() {
        let (mut p, mut mem) = setup();
        let miss_done = access_once(p.as_mut(), &load(1, 0, 100), 0, &mut mem).done();
        assert_eq!(p.stats().misses, 1);
        assert!(miss_done > 100, "miss pays L2+DRAM");

        let t = miss_done + 10;
        let hit_done = access_once(p.as_mut(), &load(2, 0, 100), t, &mut mem).done() - t;
        assert_eq!(p.stats().local_hits, 1);
        // Hit = tag (1) + bank + 32-cycle array latency.
        assert!(hit_done >= 32 && hit_done < 40, "hit latency {hit_done}");
    }

    #[test]
    fn no_sharing_between_cores() {
        let (mut p, mut mem) = setup();
        let d = access_once(p.as_mut(), &load(1, 0, 100), 0, &mut mem).done();
        // Core 1 misses on the same line (private caches don't share).
        let t = d + 10;
        access_once(p.as_mut(), &load(2, 1, 100), t, &mut mem);
        assert_eq!(p.stats().misses, 2);
        assert_eq!(p.stats().remote_hits, 0);
        // Both cores now hold a replica.
        assert!(p.resident_lines(0).contains(&100));
        assert!(p.resident_lines(1).contains(&100));
    }

    #[test]
    fn inflight_merge_avoids_duplicate_fetch() {
        let (mut p, mut mem) = setup();
        access_once(p.as_mut(), &load(1, 0, 7), 0, &mut mem);
        let before = mem.stats.accesses;
        let d2 = access_once(p.as_mut(), &load(2, 0, 7), 1, &mut mem).done();
        assert_eq!(mem.stats.accesses, before, "merged, no second L2 access");
        assert_eq!(p.stats().mshr_merges, 1);
        assert!(d2 > 1);
    }

    #[test]
    fn bank_conflicts_accumulate() {
        let (mut p, mut mem) = setup();
        // Warm 8 lines that all live in bank 0 (line % 2 == 0 for 2 banks).
        for (i, line) in (0..8u64).map(|k| k * 2).enumerate() {
            access_once(p.as_mut(), &load(i as u64, 0, line), 0, &mut mem);
        }
        let t = 1_000_000;
        for (i, line) in (0..8u64).map(|k| k * 2).enumerate() {
            access_once(p.as_mut(), &load(100 + i as u64, 0, line), t, &mut mem);
        }
        assert!(p.stats().bank_conflict_cycles > 0, "same-bank hits must queue");
    }

    #[test]
    fn sector_miss_fetches_missing_only() {
        let (mut p, mut mem) = setup();
        let mut r = load(1, 0, 50);
        r.sectors = 0b0001;
        let d = access_once(p.as_mut(), &r, 0, &mut mem).done();
        assert_eq!(p.stats().misses, 1);
        let mut r2 = load(2, 0, 50);
        r2.sectors = 0b0010;
        let t = d + 10;
        let txn = access_once(p.as_mut(), &r2, t, &mut mem);
        assert_eq!(p.stats().sector_misses, 1, "line present, sector absent");
        assert_eq!(txn.fetch_sectors, 0b0010, "fetch narrowed to the missing sector");
    }
}
