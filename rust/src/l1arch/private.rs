//! Private per-core L1 — the conventional organization and the paper's
//! normalization baseline.  Each core's cache maps the entire address
//! space; misses go straight to L2; no inter-core path exists, so
//! replicated lines burn capacity in every requesting core (the
//! inefficiency motivating the paper).

use crate::config::{GpuConfig, L1ArchKind};
use crate::l2::MemSystem;
use crate::mem::{LineAddr, MemRequest};
use crate::stats::{ContentionStats, L1Stats};

use super::common::{handle_store, local_load, CoreL1, L1Timing};
use super::{AccessResult, L1Arch};

#[derive(Debug)]
pub struct PrivateL1 {
    cores: Vec<CoreL1>,
    timing: L1Timing,
    stats: L1Stats,
    con: ContentionStats,
}

impl PrivateL1 {
    pub fn new(cfg: &GpuConfig) -> Self {
        PrivateL1 {
            cores: (0..cfg.cores).map(|_| CoreL1::new(cfg)).collect(),
            timing: L1Timing::new(cfg),
            stats: L1Stats::default(),
            con: ContentionStats::new(cfg.cores),
        }
    }
}

impl L1Arch for PrivateL1 {
    fn access(&mut self, req: &MemRequest, now: u64, mem: &mut MemSystem) -> AccessResult {
        self.stats.accesses += 1;
        let l1 = &mut self.cores[req.core as usize];
        if req.is_write() {
            handle_store(l1, req, now, &self.timing, mem, &mut self.stats, &mut self.con)
        } else {
            local_load(l1, req, now, &self.timing, mem, &mut self.stats, &mut self.con)
        }
    }

    fn stats(&self) -> &L1Stats {
        &self.stats
    }

    fn contention(&self) -> &ContentionStats {
        &self.con
    }

    fn kind(&self) -> L1ArchKind {
        L1ArchKind::Private
    }

    fn resident_lines(&self, core: usize) -> Vec<LineAddr> {
        self.cores[core].cache.tags.resident_lines()
    }

    fn sweep(&mut self, now: u64) {
        for c in &mut self.cores {
            c.sweep(now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuConfig;
    use crate::mem::AccessKind;

    fn setup() -> (PrivateL1, MemSystem) {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        (PrivateL1::new(&cfg), MemSystem::new(&cfg))
    }

    fn load(id: u64, core: u32, line: LineAddr) -> MemRequest {
        MemRequest {
            id,
            core,
            warp: 0,
            inst: id,
            line,
            sectors: 0b1111,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    #[test]
    fn cold_miss_then_hit() {
        let (mut p, mut mem) = setup();
        let miss_done = p.access(&load(1, 0, 100), 0, &mut mem).done;
        assert_eq!(p.stats.misses, 1);
        assert!(miss_done > 100, "miss pays L2+DRAM");

        let t = miss_done + 10;
        let hit_done = p.access(&load(2, 0, 100), t, &mut mem).done - t;
        assert_eq!(p.stats.local_hits, 1);
        // Hit = tag (1) + bank + 32-cycle array latency.
        assert!(hit_done >= 32 && hit_done < 40, "hit latency {hit_done}");
    }

    #[test]
    fn no_sharing_between_cores() {
        let (mut p, mut mem) = setup();
        let d = p.access(&load(1, 0, 100), 0, &mut mem).done;
        // Core 1 misses on the same line (private caches don't share).
        let t = d + 10;
        p.access(&load(2, 1, 100), t, &mut mem);
        assert_eq!(p.stats.misses, 2);
        assert_eq!(p.stats.remote_hits, 0);
        // Both cores now hold a replica.
        assert!(p.resident_lines(0).contains(&100));
        assert!(p.resident_lines(1).contains(&100));
    }

    #[test]
    fn inflight_merge_avoids_duplicate_fetch() {
        let (mut p, mut mem) = setup();
        p.access(&load(1, 0, 7), 0, &mut mem);
        let before = mem.stats.accesses;
        let d2 = p.access(&load(2, 0, 7), 1, &mut mem).done;
        assert_eq!(mem.stats.accesses, before, "merged, no second L2 access");
        assert_eq!(p.stats.mshr_merges, 1);
        assert!(d2 > 1);
    }

    #[test]
    fn bank_conflicts_accumulate() {
        let (mut p, mut mem) = setup();
        // Warm 8 lines that all live in bank 0 (line % 2 == 0 for 2 banks).
        for (i, line) in (0..8u64).map(|k| k * 2).enumerate() {
            p.access(&load(i as u64, 0, line), 0, &mut mem);
        }
        let t = 1_000_000;
        for (i, line) in (0..8u64).map(|k| k * 2).enumerate() {
            p.access(&load(100 + i as u64, 0, line), t, &mut mem);
        }
        assert!(p.stats.bank_conflict_cycles > 0, "same-bank hits must queue");
    }

    #[test]
    fn sector_miss_fetches_missing_only() {
        let (mut p, mut mem) = setup();
        let mut r = load(1, 0, 50);
        r.sectors = 0b0001;
        let d = p.access(&r, 0, &mut mem).done;
        assert_eq!(p.stats.misses, 1);
        let mut r2 = load(2, 0, 50);
        r2.sectors = 0b0010;
        let t = d + 10;
        p.access(&r2, t, &mut mem);
        assert_eq!(p.stats.sector_misses, 1, "line present, sector absent");
    }
}
