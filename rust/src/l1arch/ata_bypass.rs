//! `ata-bypass` — ATA probing plus CIAO-style interference-aware bypass
//! of contended peer caches (the fifth organization, and the proof that
//! the shared pipeline + registry make a new organization a policy-sized
//! change).
//!
//! CIAO (Zhang et al., PAPERS.md) observes that when a shared cache
//! resource is contended, redirecting the *interfering* accesses to the
//! under-utilized path (L2/DRAM) beats queueing everyone on the hot
//! resource.  Here the hot resources are a remote holder's data banks and
//! its crossbar ports: a clean remote hit is normally a win, but when the
//! holder is already saturated the requester queues behind the holder's
//! own traffic *and* adds to it.  This policy estimates the holder-side
//! pressure at tag-resolution time (zero extra messages — the aggregated
//! tag array already centralizes cluster state) and falls back to the
//! private-cache miss path when the estimate exceeds
//! `sharing.bypass_backlog_threshold` cycles.
//!
//! Everything except the bypass decision is the ATA distributor shared
//! with [`super::ata`] (`ata::distribute`): this module contributes only
//! the pressure estimate plugged into the distributor's
//! [`BypassCheck`](super::ata::BypassCheck) hook.  Bypassed accesses
//! count in the `misses` outcome class plus the `bypasses` side tally.

use crate::config::{GpuConfig, L1ArchKind};
use crate::l2::MemSystem;
use crate::mem::{decode, MemTxn};

use super::ata::distribute;
use super::pipeline::{FabricNeeds, PipelineCtx, SharingPolicy};

/// Registry constructor.
pub fn policy(cfg: &GpuConfig) -> Box<dyn SharingPolicy> {
    Box::new(AtaBypassPolicy {
        fill_local: cfg.sharing.fill_local_on_remote_hit,
        threshold: cfg.sharing.bypass_backlog_threshold,
    })
}

#[derive(Debug)]
pub struct AtaBypassPolicy {
    fill_local: bool,
    /// Holder-side pressure (cycles) above which a remote hit bypasses.
    threshold: u64,
}

/// Holder-side pressure estimate at `t`: the backlog of the bank the
/// line maps to, plus the holder's crossbar port backlogs (requests
/// converging on it and returns leaving it).  Read-only and
/// deterministic — the decision uses the same reservation state the
/// access would queue on, and needs no extra messages: the aggregated
/// tag array already centralizes cluster state.
fn holder_pressure(
    p: &PipelineCtx,
    cluster: usize,
    holder_idx: usize,
    txn: &MemTxn,
    t: u64,
) -> u64 {
    let holder = p.map.global_core(cluster, holder_idx);
    let bank = decode::l1_bank(txn.req.line, p.timing.banks);
    p.cores[holder].banks.backlog(bank, t)
        + p.xbars[cluster].output_backlog(holder_idx, t)
        + p.xbars[cluster].input_backlog(holder_idx, t)
}

impl SharingPolicy for AtaBypassPolicy {
    fn kind(&self) -> L1ArchKind {
        L1ArchKind::AtaBypass
    }

    fn resources(&self) -> FabricNeeds {
        FabricNeeds {
            xbar: true,
            aggregated_tags: true,
            ..FabricNeeds::default()
        }
    }

    fn access(&mut self, p: &mut PipelineCtx, txn: &mut MemTxn, mem: &mut MemSystem) {
        // Fig 7, with the CIAO twist on case (a): serve a clean remote
        // hit only while the holder is calm; otherwise leave it alone
        // and pay the (uncontended) L2 path instead.
        let threshold = self.threshold;
        let check =
            move |p: &PipelineCtx, cluster: usize, holder_idx: usize, txn: &MemTxn, t: u64| {
                holder_pressure(p, cluster, holder_idx, txn, t) > threshold
            };
        distribute(p, txn, mem, self.fill_local, Some(&check));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::l1arch::{access_once, build, L1Arch};
    use crate::mem::{AccessKind, LineAddr, MemRequest};

    fn cfg_with_threshold(threshold: u64) -> GpuConfig {
        let mut cfg = GpuConfig::tiny(L1ArchKind::AtaBypass);
        cfg.sharing.bypass_backlog_threshold = threshold;
        cfg
    }

    fn load(id: u64, core: u32, line: LineAddr) -> MemRequest {
        MemRequest {
            id,
            core,
            warp: 0,
            inst: id,
            line,
            sectors: 0b1111,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    #[test]
    fn behaves_like_ata_when_uncontended() {
        // A calm holder: the single remote hit must be served remotely,
        // with the same outcome ATA produces.
        let cfg = cfg_with_threshold(8);
        let mut b = build(&cfg);
        let mut mem = MemSystem::new(&cfg);
        let d1 = access_once(b.as_mut(), &load(1, 0, 42), 0, &mut mem).done();
        let t = d1 + 100;
        access_once(b.as_mut(), &load(2, 1, 42), t, &mut mem);
        assert_eq!(b.stats().remote_hits, 1);
        assert_eq!(b.stats().bypasses, 0);

        let cfg_a = GpuConfig::tiny(L1ArchKind::Ata);
        let mut a = build(&cfg_a);
        let mut mem_a = MemSystem::new(&cfg_a);
        let e1 = access_once(a.as_mut(), &load(1, 0, 42), 0, &mut mem_a).done();
        assert_eq!(e1, d1, "identical timing off the contended path");
    }

    #[test]
    fn zero_threshold_bypasses_contended_holder() {
        // Hammer the holder with same-cycle remote hits: with threshold 0
        // the trailing requests find pressure > 0 and divert to L2.
        let cfg = cfg_with_threshold(0);
        let mut b = build(&cfg);
        let mut mem = MemSystem::new(&cfg);
        let d1 = access_once(b.as_mut(), &load(1, 0, 42), 0, &mut mem).done();
        let t = d1 + 100;
        for c in 1..4u32 {
            access_once(b.as_mut(), &load(1 + c as u64, c, 42), t, &mut mem);
        }
        assert!(b.stats().bypasses > 0, "contended holder must be bypassed");
        assert!(
            b.stats().remote_hits >= 1,
            "the first request still hits remotely"
        );
        assert_eq!(
            b.stats().bypasses + b.stats().remote_hits,
            3,
            "every cross-core read either hit remotely or bypassed"
        );
    }

    #[test]
    fn bypass_relieves_holder_bank_pressure() {
        // Same convergent burst, bypass on vs off: bypassing must strictly
        // reduce the queueing charged on L1 data banks + cluster fabric.
        let run = |threshold: Option<u64>| {
            let cfg = match threshold {
                Some(th) => cfg_with_threshold(th),
                None => GpuConfig::tiny(L1ArchKind::Ata),
            };
            let mut l1 = build(&cfg);
            let mut mem = MemSystem::new(&cfg);
            let d1 = access_once(l1.as_mut(), &load(1, 0, 42), 0, &mut mem).done();
            let t = d1 + 100;
            for c in 1..4u32 {
                for k in 0..8u64 {
                    access_once(l1.as_mut(), &load(10 + c as u64 * 8 + k, c, 42), t, &mut mem);
                }
            }
            use crate::stats::ResourceClass;
            l1.contention().total().get(ResourceClass::L1DataBank)
                + l1.contention().total().get(ResourceClass::ClusterXbar)
        };
        let with_bypass = run(Some(0));
        let without = run(None);
        assert!(
            with_bypass < without,
            "bypass must shed holder-side queueing: {with_bypass} vs {without}"
        );
    }

    #[test]
    fn writes_and_local_hits_never_bypass() {
        let cfg = cfg_with_threshold(0);
        let mut b = build(&cfg);
        let mut mem = MemSystem::new(&cfg);
        let mut w = load(1, 0, 42);
        w.kind = AccessKind::Store;
        access_once(b.as_mut(), &w, 0, &mut mem);
        let t = 1000;
        access_once(b.as_mut(), &load(2, 0, 42), t, &mut mem);
        assert_eq!(b.stats().local_hits, 1);
        assert_eq!(b.stats().bypasses, 0, "local traffic is never diverted");
    }
}
