//! The aggregated tag array (§III-B) — the paper's core mechanism.
//!
//! The tag arrays of every L1 in a cluster are decoupled from their data
//! arrays and placed together.  A request is compared against *all* tag
//! arrays in parallel in one pipelined lookup:
//!
//! * **per-set tag banks** — each set lives on its own bank, so requests
//!   to different sets never conflict;
//! * **tag selectors** — route each selected set's tags to the comparator
//!   group serving that request, so several requests can inspect the same
//!   or different sets simultaneously;
//! * **comparator groups** — one group per cluster core; a request holds
//!   a group for one cycle.
//!
//! Functionally the lookup returns the hit vector of Fig 6 (e.g. `[1,0]`),
//! here enriched with dirty-ness so the distributor can apply the §III-C
//! dirty-remote fallback.  The lookup *never* perturbs remote LRU state —
//! only an actual data access does.

use crate::cache::Probe;
use crate::mem::{LineAddr, SectorMask};
use crate::resource::{Grant, MultiPort};

use super::common::CoreL1;

/// Result of comparing one request against the aggregated tag array.
///
/// A plain `Copy` pair of holder bitmasks — the probe path is
/// allocation-free and every query on it is a handful of word
/// operations, independent of cluster size.  Bit `h` refers to the
/// cluster-relative cache index `h` (Fig 6's hit-vector columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AggregateProbe {
    /// The requesting core's own cache result (local column of the hit
    /// vector).
    pub local: Probe,
    /// *Other* caches holding all requested sectors (the requester's own
    /// bit is never set).
    pub holders: u64,
    /// Subset of `holders` with any requested sector dirty.
    pub dirty: u64,
}

impl AggregateProbe {
    /// Fig 6's bit-vector view (bit = cluster-relative cache id).
    pub fn hit_vector(&self, local_idx: usize) -> u64 {
        let mut v = self.holders;
        if matches!(self.local, Probe::Hit { .. }) {
            v |= 1u64 << local_idx;
        }
        v
    }

    /// Number of remote caches with a full hit.
    pub fn remote_holder_count(&self) -> u32 {
        self.holders.count_ones()
    }

    /// Lowest-indexed clean remote holder (the distributor's pick in
    /// Fig 7a — same order the pre-bitmask scan used).
    pub fn clean_remote(&self) -> Option<usize> {
        let clean = self.holders & !self.dirty;
        (clean != 0).then(|| clean.trailing_zeros() as usize)
    }

    /// A remote copy exists but every copy is dirty (§III-C fallback).
    pub fn dirty_remote_only(&self) -> bool {
        self.holders != 0 && self.holders & !self.dirty == 0
    }
}

/// Timing + lookup logic of one cluster's aggregated tag array.
#[derive(Debug)]
pub struct AggregatedTagArray {
    /// Comparator groups (the paper provisions one per core, making the
    /// lookup conflict-free; fewer groups create arbitration delay the
    /// ablation bench can explore).
    comparators: MultiPort,
    /// Pipeline depth of decode + selector + compare.
    pub tag_latency: u32,
}

impl AggregatedTagArray {
    pub fn new(comparator_groups: usize, tag_latency: u32) -> Self {
        AggregatedTagArray {
            comparators: MultiPort::new(comparator_groups),
            tag_latency,
        }
    }

    /// Reserve a comparator group at `now`.  The returned [`Grant`]
    /// carries the cycle the hit vector is available (`grant`) and the
    /// comparator-group arbitration delay (`queued`).
    pub fn lookup_timing(&mut self, now: u64) -> Grant {
        let g = self.comparators.reserve(now, 1);
        Grant::new(g.grant + self.tag_latency as u64, g.queued)
    }

    /// Compare `line` against every cluster cache's tags by brute-force
    /// scan: one `peek` per peer.  `caches` is the cluster's contiguous
    /// CoreL1 slice; `local_idx` is the requester's position within it.
    ///
    /// This is the *reference* probe — O(cluster) but stateless.  The
    /// hot path answers the same question from the O(1)
    /// [`ResidencyIndex`](super::residency::ResidencyIndex) when
    /// `sharing.residency_index` is on (the default); the differential
    /// tests pin the two bit-for-bit against each other.
    pub fn probe(
        caches: &[CoreL1],
        local_idx: usize,
        line: LineAddr,
        sectors: SectorMask,
    ) -> AggregateProbe {
        let local = caches[local_idx].cache.peek(line, sectors);
        let mut holders = 0u64;
        let mut dirty = 0u64;
        for (idx, c) in caches.iter().enumerate() {
            if idx == local_idx {
                continue;
            }
            if let Probe::Hit { dirty: d, .. } = c.cache.peek(line, sectors) {
                holders |= 1u64 << idx;
                if d {
                    dirty |= 1u64 << idx;
                }
            }
        }
        AggregateProbe {
            local,
            holders,
            dirty,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, L1ArchKind};

    fn cluster(n: usize) -> Vec<CoreL1> {
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        (0..n).map(|_| CoreL1::new(&cfg)).collect()
    }


    #[test]
    fn working_example_from_fig6() {
        // Req-1 (from cache 0's core) present only in cache 1 -> [0, 1];
        // Req-2 present in both -> [1, 1].
        let mut cl = cluster(2);
        cl[1].cache.fill(100, 0b1111); // line A in cache 1
        cl[0].cache.fill(200, 0b1111); // line B in both
        cl[1].cache.fill(200, 0b1111);

        let p1 = AggregatedTagArray::probe(&cl, 0, 100, 0b1111);
        assert_eq!(p1.hit_vector(0), 0b10);
        assert_eq!(p1.clean_remote(), Some(1));
        assert_eq!(p1.remote_holder_count(), 1);

        let p2 = AggregatedTagArray::probe(&cl, 0, 200, 0b1111);
        assert_eq!(p2.hit_vector(0), 0b11);
        assert!(matches!(p2.local, Probe::Hit { .. }), "local priority case");
    }

    #[test]
    fn probe_equals_union_of_individual_peeks() {
        // Property: the aggregated result must match probing each cache
        // separately (the aggregation is purely structural).
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(3, 3);
        let mut cl = cluster(4);
        for _ in 0..200 {
            let c = rng.next_below(4) as usize;
            let line = rng.next_below(128) as u64;
            cl[c].cache.fill(line, 0b1111);
        }
        for _ in 0..100 {
            let line = rng.next_below(128) as u64;
            let agg = AggregatedTagArray::probe(&cl, 0, line, 0b1111);
            for idx in 1..4 {
                let individual = matches!(cl[idx].cache.peek(line, 0b1111), Probe::Hit { .. });
                let in_agg = agg.holders & (1 << idx) != 0;
                assert_eq!(individual, in_agg, "cache {idx} line {line}");
            }
        }
    }

    #[test]
    fn probe_does_not_perturb_remote_lru() {
        let mut cl = cluster(2);
        // Cache 1: 1-set-deep scenario — fill two lines in the same set,
        // probe the LRU one from core 0, then fill; the probed line must
        // still be the eviction victim (peek must not touch LRU).
        let sets = cl[1].cache.tags.sets() as u64;
        let assoc = cl[1].cache.tags.assoc() as u64;
        for k in 0..assoc {
            cl[1].cache.fill(k * sets, 0b1111);
        }
        // line 0 is LRU now. Probe it through the aggregated array.
        let _ = AggregatedTagArray::probe(&cl, 0, 0, 0b1111);
        cl[1].cache.fill(assoc * sets, 0b1111); // force eviction
        assert_eq!(
            cl[1].cache.peek(0, 0b1111),
            Probe::Miss,
            "probed line must still have been evicted"
        );
    }

    #[test]
    fn dirty_remote_only_detection() {
        let mut cl = cluster(3);
        cl[1].cache.fill(50, 0b1111);
        cl[1].cache.tags.mark_dirty(50, 0b0001);
        let p = AggregatedTagArray::probe(&cl, 0, 50, 0b1111);
        assert!(p.dirty_remote_only());
        // A clean copy elsewhere rescues it.
        cl[2].cache.fill(50, 0b1111);
        let p2 = AggregatedTagArray::probe(&cl, 0, 50, 0b1111);
        assert!(!p2.dirty_remote_only());
        assert_eq!(p2.clean_remote(), Some(2));
    }

    #[test]
    fn comparator_groups_conflict_free_at_provisioned_width() {
        // One group per core: N simultaneous lookups all start at `now`.
        let mut ata = AggregatedTagArray::new(4, 2);
        let t: Vec<Grant> = (0..4).map(|_| ata.lookup_timing(100)).collect();
        assert!(t.iter().all(|&x| x == Grant::new(102, 0)), "{t:?}");
        // A 5th concurrent request on an under-provisioned array queues.
        let t5 = ata.lookup_timing(100);
        assert_eq!(t5, Grant::new(103, 1));
    }
}
