//! ATA-Cache (§III) — the paper's contribution.
//!
//! Tag arrays are aggregated per cluster ([`ata_tag`]), data stays
//! remote-shared: each L1 data array maps the whole address space and sits
//! next to its core.  The request distributor implements Fig 7's three
//! cases on the hit vector:
//!
//! * **(b) local hit** — priority to the local data array; identical to a
//!   private-cache hit plus the tag pipeline.
//! * **(a) remote-only hit** — the data is fetched from the first clean
//!   holder over the intra-cluster crossbar and (configurably) filled
//!   locally.  No probe messages, no waiting: the tag compare already
//!   localized the line.
//! * **(c) global miss** — straight to L2 with *no* sharing detour; the
//!   critical path matches the private cache (the key advantage over
//!   remote-sharing).
//!
//! Writes are processed only in the source core's local cache with a
//! dirty bit; a remote read that would hit a dirty copy falls back to L2
//! (§III-C).

use crate::cache::Probe;
use crate::config::{GpuConfig, L1ArchKind};
use crate::l2::MemSystem;
use crate::mem::{decode, LineAddr, MemRequest};
use crate::noc::XbarReservation;
use crate::stats::{ContentionStats, L1Stats, ResourceClass};

use super::ata_tag::{AggregatedTagArray, AggregateProbe};
use super::common::{handle_store, install_fill, mshr_dispatch, CoreL1, L1Timing};
use super::{AccessResult, ClusterMap, L1Arch};

#[derive(Debug)]
pub struct AtaCache {
    cores: Vec<CoreL1>,
    /// One aggregated tag array per cluster.
    tag_arrays: Vec<AggregatedTagArray>,
    /// Intra-cluster data crossbars (remote data access path).
    xbars: Vec<XbarReservation>,
    map: ClusterMap,
    timing: L1Timing,
    stats: L1Stats,
    con: ContentionStats,
    xbar_latency: u32,
    fill_local: bool,
}

impl AtaCache {
    pub fn new(cfg: &GpuConfig) -> Self {
        let cpc = cfg.cores_per_cluster();
        AtaCache {
            cores: (0..cfg.cores).map(|_| CoreL1::new(cfg)).collect(),
            tag_arrays: (0..cfg.clusters)
                .map(|_| {
                    AggregatedTagArray::new(
                        cfg.sharing.ata_comparator_groups,
                        cfg.sharing.ata_tag_latency,
                    )
                })
                .collect(),
            xbars: (0..cfg.clusters)
                .map(|_| {
                    XbarReservation::new(
                        cpc,
                        cpc,
                        cfg.sharing.cluster_xbar_latency,
                        cfg.noc.in_buffer_flits as u64,
                    )
                })
                .collect(),
            map: ClusterMap::new(cfg),
            timing: L1Timing::new(cfg),
            stats: L1Stats::default(),
            con: ContentionStats::new(cfg.cores),
            xbar_latency: cfg.sharing.cluster_xbar_latency,
            fill_local: cfg.sharing.fill_local_on_remote_hit,
        }
    }

    /// Aggregated-tag-array probe for `req` (functional part).
    fn probe(&self, req: &MemRequest) -> AggregateProbe {
        let core = req.core as usize;
        let cluster = self.map.cluster_of(core);
        let base = cluster * self.map.cores_per_cluster;
        AggregatedTagArray::probe(
            &self.cores[base..base + self.map.cores_per_cluster],
            self.map.index_in_cluster(core),
            req.line,
            req.sectors,
        )
    }

    fn miss_to_l2(&mut self, req: &MemRequest, start: u64, mem: &mut MemSystem) -> AccessResult {
        let l1 = &mut self.cores[req.core as usize];
        if let Some(ready) = l1.in_flight_ready(req.line, start) {
            self.stats.mshr_merges += 1;
            return AccessResult::new(
                ready.max(start) + 1,
                start + 1 + self.timing.latency as u64,
            );
        }
        let s = mshr_dispatch(l1, req.core, start, &mut self.stats, &mut self.con);
        let fill = mem.fetch(req, s);
        l1.mshr.occupy_until(s, fill);
        let usable = install_fill(
            &mut self.cores[req.core as usize],
            req.core,
            req.core,
            req.line,
            req.sectors,
            fill,
            &self.timing,
            mem,
            &mut self.stats,
        );
        // Fig 7(c): the L1 stage ends at L2 dispatch (+ pipeline depth) —
        // no probe detour, so this matches the private cache's critical
        // path.
        AccessResult::new(usable + 1, s + self.timing.latency as u64)
    }
}

impl L1Arch for AtaCache {
    fn access(&mut self, req: &MemRequest, now: u64, mem: &mut MemSystem) -> AccessResult {
        self.stats.accesses += 1;
        let core = req.core as usize;
        let cluster = self.map.cluster_of(core);
        let my_idx = self.map.index_in_cluster(core);

        // Every request flows through the aggregated tag array first
        // (comparator-group arbitration is the contention knob of §III-B).
        let tag = self.tag_arrays[cluster].lookup_timing(now);
        self.con.add(core, ResourceClass::AtaComparator, tag.queued);
        let t_tag = tag.grant;

        if req.is_write() {
            // §III-C: writes are local-only; the tag pipeline still ran.
            return handle_store(
                &mut self.cores[core],
                req,
                t_tag,
                &self.timing,
                mem,
                &mut self.stats,
                &mut self.con,
            );
        }

        let agg = self.probe(req);

        // Fig 7(b): local hit has priority.
        if matches!(agg.local, Probe::Hit { .. }) {
            // Tags present but fill still in flight → merge, not hit.
            if let Some(ready) = self.cores[core].in_flight_ready(req.line, t_tag) {
                self.stats.mshr_merges += 1;
                return AccessResult::new(
                    ready.max(t_tag) + 1,
                    t_tag + 1 + self.timing.latency as u64,
                );
            }
            self.stats.local_hits += 1;
            // The lookup already identified the way; update LRU and access
            // the local data array.
            self.cores[core].cache.tags.lookup(req.line, req.sectors);
            let bank = decode::l1_bank(req.line, self.timing.banks);
            let g = self.cores[core].banks.reserve(bank, t_tag, 1);
            self.stats.bank_conflict_cycles += g.queued;
            self.con.add(core, ResourceClass::L1DataBank, g.queued);
            return AccessResult::served(g.grant + self.timing.latency as u64);
        }

        // Fig 7(a): remote hit — only clean copies are usable.
        if let Some(holder_idx) = agg.clean_remote() {
            self.stats.remote_hits += 1;
            let holder = self.map.global_core(cluster, holder_idx);
            // Request header crosses to the holder...
            let arrive = {
                let a = self.xbars[cluster].transfer(my_idx, holder_idx, t_tag, 1);
                let uncontended = t_tag + self.xbar_latency as u64 + 2;
                self.stats.sharing_net_cycles += a.grant.saturating_sub(uncontended);
                self.con.add(core, ResourceClass::ClusterXbar, a.queued);
                a.grant
            };
            // ...the holder's data array serves it (bank contention is the
            // residual sharing cost the paper acknowledges)...
            let bank = decode::l1_bank(req.line, self.timing.banks);
            // If the holder's own fill is still in flight, data waits.
            let avail = self.cores[holder]
                .in_flight_ready(req.line, arrive)
                .unwrap_or(arrive);
            let g = self.cores[holder].banks.reserve(bank, avail, 1);
            self.stats.bank_conflict_cycles += g.queued;
            self.con.add(core, ResourceClass::L1DataBank, g.queued);
            self.cores[holder].cache.tags.lookup(req.line, req.sectors); // LRU touch on use
            let data_start = g.grant + self.timing.latency as u64;
            // ...and the data crosses back.
            let flits = self.timing.data_flits(req.sector_count());
            let back = {
                let a = self.xbars[cluster].transfer(holder_idx, my_idx, data_start, flits);
                let uncontended = data_start + self.xbar_latency as u64 + 2 * flits as u64;
                self.stats.sharing_net_cycles += a.grant.saturating_sub(uncontended);
                self.con.add(core, ResourceClass::ClusterXbar, a.queued);
                a.grant
            };
            if self.fill_local {
                let usable = install_fill(
                    &mut self.cores[core],
                    req.core,
                    req.core,
                    req.line,
                    req.sectors,
                    back,
                    &self.timing,
                    mem,
                    &mut self.stats,
                );
                return AccessResult::new(usable + 1, back);
            }
            return AccessResult::served(back + 1);
        }

        if agg.dirty_remote_only() {
            // §III-C: the remote copy was modified — go to L2.
            self.stats.dirty_remote_fallbacks += 1;
        }

        // Local sector-miss: fetch only the missing sectors.
        if let Probe::SectorMiss { missing, .. } = agg.local {
            self.stats.sector_misses += 1;
            let partial = MemRequest {
                sectors: missing,
                ..*req
            };
            return self.miss_to_l2(&partial, t_tag, mem);
        }

        // Fig 7(c): global miss — straight to L2, no probe detour.
        self.stats.misses += 1;
        self.miss_to_l2(req, t_tag, mem)
    }

    fn stats(&self) -> &L1Stats {
        &self.stats
    }

    fn contention(&self) -> &ContentionStats {
        &self.con
    }

    fn kind(&self) -> L1ArchKind {
        L1ArchKind::Ata
    }

    fn resident_lines(&self, core: usize) -> Vec<LineAddr> {
        self.cores[core].cache.tags.resident_lines()
    }

    fn sweep(&mut self, now: u64) {
        for c in &mut self.cores {
            c.sweep(now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::AccessKind;

    fn setup() -> (AtaCache, MemSystem) {
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        (AtaCache::new(&cfg), MemSystem::new(&cfg))
    }

    fn load(id: u64, core: u32, line: LineAddr) -> MemRequest {
        MemRequest {
            id,
            core,
            warp: 0,
            inst: id,
            line,
            sectors: 0b1111,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    #[test]
    fn local_hit_latency_close_to_private() {
        let (mut a, mut mem) = setup();
        let d1 = a.access(&load(1, 0, 42), 0, &mut mem).done;
        let t = d1 + 100;
        let ata_hit = a.access(&load(2, 0, 42), t, &mut mem).done - t;

        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let mut p = super::super::private::PrivateL1::new(&cfg);
        let mut mem2 = MemSystem::new(&cfg);
        let d2 = p.access(&load(1, 0, 42), 0, &mut mem2).done;
        let t2 = d2 + 100;
        let priv_hit = p.access(&load(2, 0, 42), t2, &mut mem2).done - t2;

        // ATA pays only the aggregated-tag pipeline (2 cycles by default).
        assert!(
            ata_hit <= priv_hit + 3,
            "ATA local hit {ata_hit} vs private {priv_hit}"
        );
        assert_eq!(a.stats.local_hits, 1);
    }

    #[test]
    fn remote_hit_without_probe_and_no_l2() {
        let (mut a, mut mem) = setup();
        let d1 = a.access(&load(1, 0, 42), 0, &mut mem).done;
        let l2_before = mem.stats.accesses;
        let t = d1 + 100;
        let d2 = a.access(&load(2, 1, 42), t, &mut mem).done;
        assert_eq!(a.stats.remote_hits, 1);
        assert_eq!(mem.stats.accesses, l2_before, "no L2 traffic");
        assert_eq!(a.stats.probes_sent, 0, "ATA never sends probes");
        assert!(d2 > t);
    }

    #[test]
    fn remote_hit_faster_than_remote_sharing() {
        // The same cross-core read at the paper's cluster size (10 cores):
        // ATA (tag-compare already localized the line) must beat
        // remote-sharing (full probe broadcast before the data moves).
        let cluster10 = |arch| {
            let mut c = GpuConfig::tiny(arch);
            c.cores = 10;
            c.clusters = 1;
            c.sharing.ata_comparator_groups = 10;
            c
        };
        let cfg_a = cluster10(L1ArchKind::Ata);
        let mut a = AtaCache::new(&cfg_a);
        let mut mem_a = MemSystem::new(&cfg_a);
        let d = a.access(&load(1, 0, 42), 0, &mut mem_a).done;
        let t = d + 100;
        let ata_remote = a.access(&load(2, 9, 42), t, &mut mem_a).done - t;

        let cfg_r = cluster10(L1ArchKind::RemoteSharing);
        let mut r = super::super::remote::RemoteSharingL1::new(&cfg_r);
        let mut mem_r = MemSystem::new(&cfg_r);
        let d2 = r.access(&load(1, 0, 42), 0, &mut mem_r).done;
        let t2 = d2 + 100;
        let rs_remote = r.access(&load(2, 9, 42), t2, &mut mem_r).done - t2;

        assert!(
            ata_remote < rs_remote,
            "ATA remote hit {ata_remote} must beat remote-sharing {rs_remote}"
        );
    }

    #[test]
    fn global_miss_critical_path_matches_private() {
        let (mut a, mut mem_a) = setup();
        let ata_miss = a.access(&load(1, 0, 42), 0, &mut mem_a).done;

        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let mut p = super::super::private::PrivateL1::new(&cfg);
        let mut mem_p = MemSystem::new(&cfg);
        let priv_miss = p.access(&load(1, 0, 42), 0, &mut mem_p).done;

        // Identical L2 path; ATA adds only the tag pipeline.
        assert!(
            ata_miss <= priv_miss + 3,
            "ATA miss {ata_miss} vs private {priv_miss}"
        );
    }

    #[test]
    fn dirty_remote_copy_falls_back_to_l2() {
        let (mut a, mut mem) = setup();
        let mut w = load(1, 0, 42);
        w.kind = AccessKind::Store;
        a.access(&w, 0, &mut mem);
        let t = 1000;
        a.access(&load(2, 1, 42), t, &mut mem);
        assert_eq!(a.stats.dirty_remote_fallbacks, 1);
        assert_eq!(a.stats.remote_hits, 0);
        assert_eq!(a.stats.misses, 1);
    }

    #[test]
    fn remote_hit_fills_local_for_future_hits() {
        let (mut a, mut mem) = setup();
        let d1 = a.access(&load(1, 0, 42), 0, &mut mem).done;
        let d2 = a.access(&load(2, 1, 42), d1 + 100, &mut mem).done;
        let t = d2 + 100;
        a.access(&load(3, 1, 42), t, &mut mem);
        assert_eq!(a.stats.local_hits, 1, "second read is a local hit");
        assert!(a.resident_lines(1).contains(&42));
    }

    #[test]
    fn writes_stay_local() {
        let (mut a, mut mem) = setup();
        let mut w = load(1, 2, 42);
        w.kind = AccessKind::Store;
        a.access(&w, 0, &mut mem);
        assert!(a.resident_lines(2).contains(&42));
        assert_eq!(mem.stats.writes, 0, "write-back-local: no L2 traffic yet");
        assert_eq!(a.stats.writes, 1);
    }

    #[test]
    fn cross_cluster_does_not_share() {
        let (mut a, mut mem) = setup();
        let d = a.access(&load(1, 0, 42), 0, &mut mem).done;
        // Core 4 is in the other cluster of the tiny config.
        a.access(&load(2, 4, 42), d + 100, &mut mem);
        assert_eq!(a.stats.remote_hits, 0);
        assert_eq!(a.stats.misses, 2);
    }
}
