//! ATA-Cache (§III) — the paper's contribution, as a policy.
//!
//! Tag arrays are aggregated per cluster ([`super::ata_tag`]), data stays
//! remote-shared: each L1 data array maps the whole address space and sits
//! next to its core.  The request distributor implements Fig 7's three
//! cases on the hit vector:
//!
//! * **(b) local hit** — priority to the local data array; identical to a
//!   private-cache hit plus the tag pipeline.
//! * **(a) remote-only hit** — the data is fetched from the first clean
//!   holder over the intra-cluster crossbar and (configurably) filled
//!   locally.  No probe messages, no waiting: the tag compare already
//!   localized the line.
//! * **(c) global miss** — straight to L2 with *no* sharing detour; the
//!   critical path matches the private cache (the key advantage over
//!   remote-sharing).
//!
//! Writes are processed only in the source core's local cache with a
//! dirty bit; a remote read that would hit a dirty copy falls back to L2
//! (§III-C).  The mechanism steps (front end, crossbar hit, miss) live in
//! the shared pipeline so `ata-bypass` can reuse them verbatim.

use crate::cache::Probe;
use crate::config::{GpuConfig, L1ArchKind};
use crate::l2::MemSystem;
use crate::mem::{MemTxn, RetPath};

use super::pipeline::{FabricNeeds, PipelineCtx, SharingPolicy};

/// Registry constructor.
pub fn policy(cfg: &GpuConfig) -> Box<dyn SharingPolicy> {
    Box::new(AtaPolicy {
        fill_local: cfg.sharing.fill_local_on_remote_hit,
    })
}

/// Interference hook consulted on a clean remote hit:
/// `(ctx, cluster, holder_idx, txn, t_tag) -> divert-to-L2?`.  The ATA
/// paper never diverts (`None`); `ata-bypass` plugs its holder-pressure
/// check in here — the *only* place the two organizations differ.
pub type BypassCheck = dyn Fn(&PipelineCtx, usize, usize, &MemTxn, u64) -> bool;

/// The Fig 7 request distributor, shared verbatim by `ata` and
/// `ata-bypass`: aggregated front end, then the three cases on the hit
/// vector, with the optional bypass hook on case (a).
pub fn distribute(
    p: &mut PipelineCtx,
    txn: &mut MemTxn,
    mem: &mut MemSystem,
    fill_local: bool,
    bypass: Option<&BypassCheck>,
) {
    let core = txn.req.core as usize;
    let cluster = p.map.cluster_of(core);

    // Every request flows through the aggregated tag array first
    // (comparator-group arbitration is the contention knob of §III-B).
    let t_tag = p.ata_front_end(cluster, txn);

    if txn.req.is_write() {
        // §III-C: writes are local-only; the tag pipeline still ran.
        p.store_local(txn, t_tag, mem);
        return;
    }

    let agg = p.ata_probe(txn);

    // Fig 7(b): local hit has priority — never diverted.
    if matches!(agg.local, Probe::Hit { .. }) {
        // Tags present but fill still in flight → merge, not hit.
        if p.merge_or_defer(core, txn, t_tag, RetPath::Local) {
            return;
        }
        p.stats.local_hits += 1;
        // The lookup already identified the way; update LRU and access
        // the local data array.
        p.cores[core].cache.tags.lookup(txn.req.line, txn.req.sectors);
        let done = p.hit_data_access(core, txn, t_tag);
        txn.serve(done);
        return;
    }

    // Fig 7(a): remote hit — only clean copies are usable, and the
    // bypass hook may redirect a contended holder's hit to L2.
    if let Some(holder_idx) = agg.clean_remote() {
        if bypass.is_some_and(|check| check(p, cluster, holder_idx, txn, t_tag)) {
            p.stats.bypasses += 1;
            p.stats.misses += 1;
            let sectors = txn.req.sectors;
            p.ata_miss(txn, sectors, t_tag, mem);
            return;
        }
        p.ata_remote_hit(holder_idx, t_tag, fill_local, txn, mem);
        return;
    }

    if agg.dirty_remote_only() {
        // §III-C: the remote copy was modified — go to L2.
        p.stats.dirty_remote_fallbacks += 1;
    }

    // Local sector-miss: fetch only the missing sectors.
    if let Probe::SectorMiss { missing, .. } = agg.local {
        p.stats.sector_misses += 1;
        p.ata_miss(txn, missing, t_tag, mem);
        return;
    }

    // Fig 7(c): global miss — straight to L2, no probe detour.
    p.stats.misses += 1;
    let sectors = txn.req.sectors;
    p.ata_miss(txn, sectors, t_tag, mem);
}

#[derive(Debug)]
pub struct AtaPolicy {
    fill_local: bool,
}

impl SharingPolicy for AtaPolicy {
    fn kind(&self) -> L1ArchKind {
        L1ArchKind::Ata
    }

    fn resources(&self) -> FabricNeeds {
        FabricNeeds {
            xbar: true,
            aggregated_tags: true,
            ..FabricNeeds::default()
        }
    }

    fn access(&mut self, p: &mut PipelineCtx, txn: &mut MemTxn, mem: &mut MemSystem) {
        distribute(p, txn, mem, self.fill_local, None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::l1arch::{access_once, build, L1Arch};
    use crate::mem::{AccessKind, LineAddr, MemRequest};

    fn setup() -> (Box<dyn L1Arch>, MemSystem) {
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        (build(&cfg), MemSystem::new(&cfg))
    }

    fn load(id: u64, core: u32, line: LineAddr) -> MemRequest {
        MemRequest {
            id,
            core,
            warp: 0,
            inst: id,
            line,
            sectors: 0b1111,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    #[test]
    fn local_hit_latency_close_to_private() {
        let (mut a, mut mem) = setup();
        let d1 = access_once(a.as_mut(), &load(1, 0, 42), 0, &mut mem).done();
        let t = d1 + 100;
        let ata_hit = access_once(a.as_mut(), &load(2, 0, 42), t, &mut mem).done() - t;

        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let mut p = build(&cfg);
        let mut mem2 = MemSystem::new(&cfg);
        let d2 = access_once(p.as_mut(), &load(1, 0, 42), 0, &mut mem2).done();
        let t2 = d2 + 100;
        let priv_hit = access_once(p.as_mut(), &load(2, 0, 42), t2, &mut mem2).done() - t2;

        // ATA pays only the aggregated-tag pipeline (2 cycles by default).
        assert!(
            ata_hit <= priv_hit + 3,
            "ATA local hit {ata_hit} vs private {priv_hit}"
        );
        assert_eq!(a.stats().local_hits, 1);
    }

    #[test]
    fn remote_hit_without_probe_and_no_l2() {
        let (mut a, mut mem) = setup();
        let d1 = access_once(a.as_mut(), &load(1, 0, 42), 0, &mut mem).done();
        let l2_before = mem.stats.accesses;
        let t = d1 + 100;
        let d2 = access_once(a.as_mut(), &load(2, 1, 42), t, &mut mem).done();
        assert_eq!(a.stats().remote_hits, 1);
        assert_eq!(mem.stats.accesses, l2_before, "no L2 traffic");
        assert_eq!(a.stats().probes_sent, 0, "ATA never sends probes");
        assert!(d2 > t);
    }

    #[test]
    fn remote_hit_faster_than_remote_sharing() {
        // The same cross-core read at the paper's cluster size (10 cores):
        // ATA (tag-compare already localized the line) must beat
        // remote-sharing (full probe broadcast before the data moves).
        let cluster10 = |arch| {
            let mut c = GpuConfig::tiny(arch);
            c.cores = 10;
            c.clusters = 1;
            c.sharing.ata_comparator_groups = 10;
            c
        };
        let cfg_a = cluster10(L1ArchKind::Ata);
        let mut a = build(&cfg_a);
        let mut mem_a = MemSystem::new(&cfg_a);
        let d = access_once(a.as_mut(), &load(1, 0, 42), 0, &mut mem_a).done();
        let t = d + 100;
        let ata_remote = access_once(a.as_mut(), &load(2, 9, 42), t, &mut mem_a).done() - t;

        let cfg_r = cluster10(L1ArchKind::RemoteSharing);
        let mut r = build(&cfg_r);
        let mut mem_r = MemSystem::new(&cfg_r);
        let d2 = access_once(r.as_mut(), &load(1, 0, 42), 0, &mut mem_r).done();
        let t2 = d2 + 100;
        let rs_remote = access_once(r.as_mut(), &load(2, 9, 42), t2, &mut mem_r).done() - t2;

        assert!(
            ata_remote < rs_remote,
            "ATA remote hit {ata_remote} must beat remote-sharing {rs_remote}"
        );
    }

    #[test]
    fn global_miss_critical_path_matches_private() {
        let (mut a, mut mem_a) = setup();
        let ata_miss = access_once(a.as_mut(), &load(1, 0, 42), 0, &mut mem_a).done();

        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let mut p = build(&cfg);
        let mut mem_p = MemSystem::new(&cfg);
        let priv_miss = access_once(p.as_mut(), &load(1, 0, 42), 0, &mut mem_p).done();

        // Identical L2 path; ATA adds only the tag pipeline.
        assert!(
            ata_miss <= priv_miss + 3,
            "ATA miss {ata_miss} vs private {priv_miss}"
        );
    }

    #[test]
    fn dirty_remote_copy_falls_back_to_l2() {
        let (mut a, mut mem) = setup();
        let mut w = load(1, 0, 42);
        w.kind = AccessKind::Store;
        access_once(a.as_mut(), &w, 0, &mut mem);
        let t = 1000;
        access_once(a.as_mut(), &load(2, 1, 42), t, &mut mem);
        assert_eq!(a.stats().dirty_remote_fallbacks, 1);
        assert_eq!(a.stats().remote_hits, 0);
        assert_eq!(a.stats().misses, 1);
    }

    #[test]
    fn remote_hit_fills_local_for_future_hits() {
        let (mut a, mut mem) = setup();
        let d1 = access_once(a.as_mut(), &load(1, 0, 42), 0, &mut mem).done();
        let d2 = access_once(a.as_mut(), &load(2, 1, 42), d1 + 100, &mut mem).done();
        let t = d2 + 100;
        access_once(a.as_mut(), &load(3, 1, 42), t, &mut mem);
        assert_eq!(a.stats().local_hits, 1, "second read is a local hit");
        assert!(a.resident_lines(1).contains(&42));
    }

    #[test]
    fn writes_stay_local() {
        let (mut a, mut mem) = setup();
        let mut w = load(1, 2, 42);
        w.kind = AccessKind::Store;
        access_once(a.as_mut(), &w, 0, &mut mem);
        assert!(a.resident_lines(2).contains(&42));
        assert_eq!(mem.stats.writes, 0, "write-back-local: no L2 traffic yet");
        assert_eq!(a.stats().writes, 1);
    }

    #[test]
    fn cross_cluster_does_not_share() {
        let (mut a, mut mem) = setup();
        let d = access_once(a.as_mut(), &load(1, 0, 42), 0, &mut mem).done();
        // Core 4 is in the other cluster of the tiny config.
        access_once(a.as_mut(), &load(2, 4, 42), d + 100, &mut mem);
        assert_eq!(a.stats().remote_hits, 0);
        assert_eq!(a.stats().misses, 2);
    }
}
