//! Decoupled-sharing L1 (Ibrahim et al. PACT'20 / HPCA'21) — baseline #3.
//!
//! The cluster's L1s are address-sliced: every line has exactly one home
//! cache, and *every* access — local or not — is routed to the home slice.
//! Replication disappears (higher effective capacity → higher hit rate),
//! but requests from all ten cores converge on the same slice's four data
//! banks, and the paper's Fig 3 pathology emerges: bank-conflict
//! serialization inflates L1 latency far beyond the private cache's.

use crate::cache::Probe;
use crate::config::{GpuConfig, L1ArchKind};
use crate::l2::MemSystem;
use crate::mem::{decode, LineAddr, MemRequest};
use crate::noc::XbarReservation;
use crate::stats::{ContentionStats, L1Stats, ResourceClass};

use super::common::{install_fill, mshr_dispatch, CoreL1, L1Timing};
use super::{AccessResult, ClusterMap, L1Arch};

#[derive(Debug)]
pub struct DecoupledSharingL1 {
    caches: Vec<CoreL1>,
    /// Intra-cluster request/response crossbars (one pair per cluster).
    xbars: Vec<XbarReservation>,
    map: ClusterMap,
    timing: L1Timing,
    stats: L1Stats,
    con: ContentionStats,
    xbar_latency: u32,
}

impl DecoupledSharingL1 {
    pub fn new(cfg: &GpuConfig) -> Self {
        let cpc = cfg.cores_per_cluster();
        DecoupledSharingL1 {
            caches: (0..cfg.cores).map(|_| CoreL1::new(cfg)).collect(),
            xbars: (0..cfg.clusters)
                .map(|_| {
                    XbarReservation::new(
                        cpc,
                        cpc,
                        cfg.sharing.cluster_xbar_latency,
                        cfg.noc.in_buffer_flits as u64,
                    )
                })
                .collect(),
            map: ClusterMap::new(cfg),
            timing: L1Timing::new(cfg),
            stats: L1Stats::default(),
            con: ContentionStats::new(cfg.cores),
            xbar_latency: cfg.sharing.cluster_xbar_latency,
        }
    }

    /// Global core id of the home slice for `line` in `core`'s cluster.
    fn home_of(&self, core: usize, line: LineAddr) -> usize {
        let cluster = self.map.cluster_of(core);
        let idx = decode::home_cache(line, self.map.cores_per_cluster);
        self.map.global_core(cluster, idx)
    }

    /// Route a packet from `core` to `home` over the cluster crossbar;
    /// returns the arrival cycle and charges queueing to `attr_core` (the
    /// requesting core, which may differ from the sending endpoint on the
    /// data-return hop).
    fn route(&mut self, core: usize, home: usize, now: u64, flits: u32, attr_core: usize) -> u64 {
        let cluster = self.map.cluster_of(core);
        let src = self.map.index_in_cluster(core);
        let dst = self.map.index_in_cluster(home);
        let g = self.xbars[cluster].transfer(src, dst, now, flits);
        let uncontended = now + self.xbar_latency as u64 + 2 * flits as u64;
        self.stats.sharing_net_cycles += g.grant.saturating_sub(uncontended);
        self.con.add(attr_core, ResourceClass::ClusterXbar, g.queued);
        g.grant
    }
}

impl L1Arch for DecoupledSharingL1 {
    fn access(&mut self, req: &MemRequest, now: u64, mem: &mut MemSystem) -> AccessResult {
        self.stats.accesses += 1;
        let core = req.core as usize;
        let home = self.home_of(core, req.line);
        let is_local_slice = home == core;

        // Writes also go to the home slice (there is only one copy).
        if req.is_write() {
            self.stats.writes += 1;
            let t_arrive = if is_local_slice {
                now
            } else {
                let flits = self.timing.data_flits(req.sector_count());
                self.route(core, home, now, flits, core)
            };
            let l1 = &mut self.caches[home];
            let bank = decode::l1_bank(req.line, self.timing.banks);
            let g = l1.banks.reserve(bank, t_arrive, 1);
            self.stats.bank_conflict_cycles += g.queued;
            self.con.add(core, ResourceClass::L1DataBank, g.queued);
            let (_, evicted) = l1.cache.fill(req.line, req.sectors);
            l1.cache.tags.mark_dirty(req.line, req.sectors);
            if let Some(ev) = evicted {
                debug_assert!(ev.dirty_sectors != 0, "clean victims are not reported");
                if ev.dirty_sectors != 0 {
                    // Routed through the home port, charged to the writer.
                    mem.write_for(home, ev.line, ev.dirty_sectors.count_ones(), g.grant, core);
                }
            }
            return AccessResult::served(g.grant + 1);
        }

        // Load: route to home, access the slice, route the data back.
        let t_arrive = if is_local_slice {
            now
        } else {
            self.route(core, home, now, 1, core)
        };

        let l1 = &mut self.caches[home];
        let bank = decode::l1_bank(req.line, self.timing.banks);
        // (data_ready, l1_stage_done at the slice)
        let (data_ready, stage) = match l1.cache.tags.lookup(req.line, req.sectors) {
            Probe::Hit { .. } if l1.in_flight_ready(req.line, t_arrive).is_some() => {
                // Tags installed at miss-schedule time; fill not landed yet.
                self.stats.mshr_merges += 1;
                let d = l1.in_flight_ready(req.line, t_arrive).unwrap().max(t_arrive) + 1;
                (d, t_arrive + 1 + self.timing.latency as u64)
            }
            Probe::Hit { .. } => {
                if is_local_slice {
                    self.stats.local_hits += 1;
                } else {
                    self.stats.remote_hits += 1;
                }
                let g = l1.banks.reserve(bank, t_arrive, 1);
                self.stats.bank_conflict_cycles += g.queued;
                self.con.add(core, ResourceClass::L1DataBank, g.queued);
                let d = g.grant + self.timing.latency as u64;
                (d, d)
            }
            probe => {
                if let Some(ready) = l1.in_flight_ready(req.line, t_arrive) {
                    self.stats.mshr_merges += 1;
                    (ready.max(t_arrive) + 1, t_arrive + 1 + self.timing.latency as u64)
                } else {
                    // Tag probe costs one bank cycle on a miss too.
                    let g = l1.banks.reserve(bank, t_arrive, 1);
                    self.con.add(core, ResourceClass::L1TagBank, g.queued);
                    let t_tag = g.grant + 1;
                    let fetch_sectors = match probe {
                        Probe::SectorMiss { missing, .. } => {
                            self.stats.sector_misses += 1;
                            missing
                        }
                        _ => {
                            self.stats.misses += 1;
                            req.sectors
                        }
                    };
                    // The home slice owns the miss: its NoC port issues the
                    // L2 fetch and the fill lands in the home cache.  All
                    // stalls (MSHR-full and the memory side) are still
                    // charged to the *requesting* core — it is the one
                    // whose access waits (`fetch_for`).
                    let s = mshr_dispatch(l1, req.core, t_tag, &mut self.stats, &mut self.con);
                    let fetch_req = MemRequest {
                        core: home as u32,
                        sectors: fetch_sectors,
                        ..*req
                    };
                    let fill = mem.fetch_for(&fetch_req, s, core);
                    self.caches[home].mshr.occupy_until(s, fill);
                    let usable = install_fill(
                        &mut self.caches[home],
                        home as u32,
                        req.core,
                        req.line,
                        fetch_sectors,
                        fill,
                        &self.timing,
                        mem,
                        &mut self.stats,
                    );
                    // Stage ends when the home slice dispatches to L2
                    // (+ pipeline depth, matching the other archs).
                    (usable + 1, s + self.timing.latency as u64)
                }
            }
        };

        if is_local_slice {
            AccessResult::new(data_ready, stage)
        } else {
            // Data crosses back to the requesting core.  For a slice hit
            // the return crossing is part of the L1 access (the paper's
            // decoupled latency includes it); for a miss the stage already
            // ended at L2 dispatch.
            let flits = self.timing.data_flits(req.sector_count());
            let back = self.route(home, core, data_ready, flits, core);
            let stage_back = if stage == data_ready { back } else { stage };
            AccessResult::new(back, stage_back)
        }
    }

    fn stats(&self) -> &L1Stats {
        &self.stats
    }

    fn contention(&self) -> &ContentionStats {
        &self.con
    }

    fn kind(&self) -> L1ArchKind {
        L1ArchKind::DecoupledSharing
    }

    fn resident_lines(&self, core: usize) -> Vec<LineAddr> {
        self.caches[core].cache.tags.resident_lines()
    }

    fn sweep(&mut self, now: u64) {
        for c in &mut self.caches {
            c.sweep(now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::AccessKind;

    fn setup() -> (DecoupledSharingL1, MemSystem) {
        let cfg = GpuConfig::tiny(L1ArchKind::DecoupledSharing);
        (DecoupledSharingL1::new(&cfg), MemSystem::new(&cfg))
    }

    fn load(id: u64, core: u32, line: LineAddr) -> MemRequest {
        MemRequest {
            id,
            core,
            warp: 0,
            inst: id,
            line,
            sectors: 0b1111,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    #[test]
    fn single_copy_no_replication() {
        let (mut d, mut mem) = setup();
        let t1 = d.access(&load(1, 0, 42), 0, &mut mem).done;
        d.access(&load(2, 1, 42), t1 + 100, &mut mem);
        d.access(&load(3, 2, 42), t1 + 200, &mut mem);
        // Exactly one cluster cache holds the line.
        let holders = (0..4)
            .filter(|&c| d.resident_lines(c).contains(&42))
            .count();
        assert_eq!(holders, 1, "decoupled keeps a single copy");
        assert_eq!(d.stats.misses, 1, "only the first access misses");
    }

    #[test]
    fn second_core_hits_home_slice() {
        let (mut d, mut mem) = setup();
        let t1 = d.access(&load(1, 0, 42), 0, &mut mem).done;
        let before = mem.stats.accesses;
        let t = t1 + 100;
        let done = d.access(&load(2, 1, 42), t, &mut mem).done;
        assert_eq!(mem.stats.accesses, before, "hit in home slice, no L2");
        assert_eq!(d.stats.local_hits + d.stats.remote_hits, 1);
        assert!(done > t);
    }

    #[test]
    fn remote_slice_access_pays_crossbar() {
        let (mut d, mut mem) = setup();
        // Find a line homed at core 0 and warm it from core 0 (local),
        // then read from core 1 (remote): remote must be slower.
        let mut line_home0 = None;
        for l in 0..1000u64 {
            if d.home_of(0, l) == 0 {
                line_home0 = Some(l);
                break;
            }
        }
        let line = line_home0.unwrap();
        let t1 = d.access(&load(1, 0, line), 0, &mut mem).done;
        let t = t1 + 1000;
        let local_hit = d.access(&load(2, 0, line), t, &mut mem).done - t;
        let t2 = t + 1000;
        let remote_hit = d.access(&load(3, 1, line), t2, &mut mem).done - t2;
        assert!(
            remote_hit > local_hit,
            "crossbar hop must cost: remote={remote_hit} local={local_hit}"
        );
    }

    #[test]
    fn convergent_access_serializes_on_home_banks() {
        let (mut d, mut mem) = setup();
        // Warm a line, then have every core hit it at the same instant.
        let t1 = d.access(&load(1, 0, 42), 0, &mut mem).done;
        let t = t1 + 10_000;
        let mut lats = vec![];
        for c in 0..4u32 {
            lats.push(d.access(&load(10 + c as u64, c, 42), t, &mut mem).done - t);
        }
        let max = *lats.iter().max().unwrap();
        let min = *lats.iter().min().unwrap();
        assert!(
            max > min,
            "simultaneous same-line hits must serialize: {lats:?}"
        );
        // Serialization shows up at the home slice: either on its banks or
        // on its crossbar port, depending on arrival stagger.
        assert!(d.stats.bank_conflict_cycles + d.stats.sharing_net_cycles > 0);
    }

    #[test]
    fn writes_route_to_home_slice() {
        let (mut d, mut mem) = setup();
        let mut w = load(1, 1, 42);
        w.kind = AccessKind::Store;
        d.access(&w, 0, &mut mem);
        let home = d.home_of(1, 42);
        assert!(d.resident_lines(home).contains(&42));
        assert!(d.caches[home].cache.tags.is_dirty(42, 0b1111));
    }
}
