//! Decoupled-sharing L1 (Ibrahim et al. PACT'20 / HPCA'21) — baseline #3,
//! as a policy.
//!
//! The cluster's L1s are address-sliced: every line has exactly one home
//! cache, and *every* access — local or not — is routed to the home slice.
//! Replication disappears (higher effective capacity → higher hit rate),
//! but requests from all ten cores converge on the same slice's four data
//! banks, and the paper's Fig 3 pathology emerges: bank-conflict
//! serialization inflates L1 latency far beyond the private cache's.
//!
//! This is the policy that exercises the transaction's endpoint/attr
//! split: the home slice is the NoC endpoint for misses and victim
//! writebacks, while every queued cycle stays charged to the requesting
//! core (the sufferer).

use crate::cache::Probe;
use crate::config::{GpuConfig, L1ArchKind};
use crate::l2::MemSystem;
use crate::mem::{decode, LineAddr, MemTxn, RetPath};
use crate::stats::ResourceClass;

use super::pipeline::{FabricNeeds, PipelineCtx, SharingPolicy};

/// Registry constructor.
pub fn policy(_cfg: &GpuConfig) -> Box<dyn SharingPolicy> {
    Box::new(DecoupledPolicy)
}

#[derive(Debug)]
pub struct DecoupledPolicy;

impl DecoupledPolicy {
    /// Global core id of the home slice for `line` in `core`'s cluster.
    pub fn home_of(p: &PipelineCtx, core: usize, line: LineAddr) -> usize {
        let cluster = p.map.cluster_of(core);
        let idx = decode::home_cache(line, p.map.cores_per_cluster);
        p.map.global_core(cluster, idx)
    }
}

impl SharingPolicy for DecoupledPolicy {
    fn kind(&self) -> L1ArchKind {
        L1ArchKind::DecoupledSharing
    }

    fn resources(&self) -> FabricNeeds {
        FabricNeeds {
            xbar: true,
            ..FabricNeeds::default()
        }
    }

    fn access(&mut self, p: &mut PipelineCtx, txn: &mut MemTxn, mem: &mut MemSystem) {
        let core = txn.req.core as usize;
        let line = txn.req.line;
        let home = Self::home_of(p, core, line);
        let is_local_slice = home == core;
        let now = txn.now();
        let cluster = p.map.cluster_of(core);
        let my_idx = p.map.index_in_cluster(core);
        let home_idx = p.map.index_in_cluster(home);

        // Writes also go to the home slice (there is only one copy).
        if txn.req.is_write() {
            p.stats.writes += 1;
            let t_arrive = if is_local_slice {
                now
            } else {
                let flits = p.timing.data_flits(txn.req.sector_count());
                p.xbar_route(cluster, my_idx, home_idx, now, flits, txn)
            };
            let bank = decode::l1_bank(line, p.timing.banks);
            let g = p.cores[home].banks.reserve(bank, t_arrive, 1);
            p.stats.bank_conflict_cycles += g.queued;
            txn.charge(&mut p.con, ResourceClass::L1DataBank, g.queued);
            let evicted = p.fill_tags(home, line, txn.req.sectors);
            p.mark_dirty_tags(home, line, txn.req.sectors);
            if let Some(ev) = evicted {
                if ev.needs_writeback() {
                    // Routed through the home port, charged to the writer.
                    mem.write_for(home, ev.line, ev.dirty_sectors.count_ones(), g.grant, core);
                }
            }
            txn.serve(g.grant + 1);
            return;
        }

        // Load: route to home, access the slice, route the data back.
        let t_arrive = if is_local_slice {
            now
        } else {
            p.xbar_route(cluster, my_idx, home_idx, now, 1, txn)
        };

        // How the data gets back to the requesting core once ready: for a
        // slice hit the return crossing is part of the L1 access (the
        // paper's decoupled latency includes it); for a miss the stage
        // already ended at L2 dispatch — `complete_ret` encodes both.
        let ret = if is_local_slice {
            RetPath::Local
        } else {
            RetPath::Xbar {
                cluster,
                from_idx: home_idx,
                to_idx: my_idx,
            }
        };

        match p.cores[home].cache.tags.lookup(line, txn.req.sectors) {
            Probe::Hit { .. } => {
                // Tags install at miss-schedule time, so a probe hit may be
                // an in-flight (or same-epoch deferred) fill — merge first.
                if p.merge_or_defer(home, txn, t_arrive, ret) {
                    return;
                }
                if is_local_slice {
                    p.stats.local_hits += 1;
                } else {
                    p.stats.remote_hits += 1;
                }
                let d = p.hit_data_access(home, txn, t_arrive);
                p.complete_ret(txn, d, d, ret);
            }
            probe => {
                if p.merge_or_defer(home, txn, t_arrive, ret) {
                    return;
                }
                // Tag probe costs one bank cycle on a miss too.
                let t_tag = p.miss_tag_probe(home, txn, t_arrive);
                let fetch_sectors = p.classify_miss(probe, txn.req.sectors);
                // The home slice owns the miss: its NoC port issues the L2
                // fetch and the fill lands in the home cache.  All stalls
                // (MSHR-full and the memory side) are still charged to the
                // *requesting* core — it is the one whose access waits
                // (`txn.attr_core`).
                p.miss_to_l2(home, txn, fetch_sectors, t_tag, mem, ret);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::l1arch::{access_once, build, L1Arch, PipelineL1};
    use crate::mem::{AccessKind, MemRequest};

    fn setup() -> (Box<dyn L1Arch>, MemSystem, GpuConfig) {
        let cfg = GpuConfig::tiny(L1ArchKind::DecoupledSharing);
        (build(&cfg), MemSystem::new(&cfg), cfg)
    }

    fn home_of(cfg: &GpuConfig, core: usize, line: LineAddr) -> usize {
        let p = PipelineCtx::new(cfg, FabricNeeds::default());
        DecoupledPolicy::home_of(&p, core, line)
    }

    fn load(id: u64, core: u32, line: LineAddr) -> MemRequest {
        MemRequest {
            id,
            core,
            warp: 0,
            inst: id,
            line,
            sectors: 0b1111,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    #[test]
    fn single_copy_no_replication() {
        let (mut d, mut mem, _) = setup();
        let t1 = access_once(d.as_mut(), &load(1, 0, 42), 0, &mut mem).done();
        access_once(d.as_mut(), &load(2, 1, 42), t1 + 100, &mut mem);
        access_once(d.as_mut(), &load(3, 2, 42), t1 + 200, &mut mem);
        // Exactly one cluster cache holds the line.
        let holders = (0..4)
            .filter(|&c| d.resident_lines(c).contains(&42))
            .count();
        assert_eq!(holders, 1, "decoupled keeps a single copy");
        assert_eq!(d.stats().misses, 1, "only the first access misses");
    }

    #[test]
    fn second_core_hits_home_slice() {
        let (mut d, mut mem, _) = setup();
        let t1 = access_once(d.as_mut(), &load(1, 0, 42), 0, &mut mem).done();
        let before = mem.stats.accesses;
        let t = t1 + 100;
        let done = access_once(d.as_mut(), &load(2, 1, 42), t, &mut mem).done();
        assert_eq!(mem.stats.accesses, before, "hit in home slice, no L2");
        assert_eq!(d.stats().local_hits + d.stats().remote_hits, 1);
        assert!(done > t);
    }

    #[test]
    fn remote_slice_access_pays_crossbar() {
        let (mut d, mut mem, cfg) = setup();
        // Find a line homed at core 0 and warm it from core 0 (local),
        // then read from core 1 (remote): remote must be slower.
        let line = (0..1000u64).find(|&l| home_of(&cfg, 0, l) == 0).unwrap();
        let t1 = access_once(d.as_mut(), &load(1, 0, line), 0, &mut mem).done();
        let t = t1 + 1000;
        let local_hit = access_once(d.as_mut(), &load(2, 0, line), t, &mut mem).done() - t;
        let t2 = t + 1000;
        let remote_hit = access_once(d.as_mut(), &load(3, 1, line), t2, &mut mem).done() - t2;
        assert!(
            remote_hit > local_hit,
            "crossbar hop must cost: remote={remote_hit} local={local_hit}"
        );
    }

    #[test]
    fn convergent_access_serializes_on_home_banks() {
        let (mut d, mut mem, _) = setup();
        // Warm a line, then have every core hit it at the same instant.
        let t1 = access_once(d.as_mut(), &load(1, 0, 42), 0, &mut mem).done();
        let t = t1 + 10_000;
        let mut lats = vec![];
        for c in 0..4u32 {
            lats.push(access_once(d.as_mut(), &load(10 + c as u64, c, 42), t, &mut mem).done() - t);
        }
        let max = *lats.iter().max().unwrap();
        let min = *lats.iter().min().unwrap();
        assert!(
            max > min,
            "simultaneous same-line hits must serialize: {lats:?}"
        );
        // Serialization shows up at the home slice: either on its banks or
        // on its crossbar port, depending on arrival stagger.
        assert!(d.stats().bank_conflict_cycles + d.stats().sharing_net_cycles > 0);
    }

    #[test]
    fn writes_route_to_home_slice() {
        let cfg = GpuConfig::tiny(L1ArchKind::DecoupledSharing);
        let mut d = PipelineL1::new(&cfg, policy(&cfg));
        let mut mem = MemSystem::new(&cfg);
        let mut w = load(1, 1, 42);
        w.kind = AccessKind::Store;
        access_once(&mut d, &w, 0, &mut mem);
        let home = home_of(&cfg, 1, 42);
        assert!(d.resident_lines(home).contains(&42));
        // The dirty bit lives at the home slice.
        assert!(d.ctx().cores[home].cache.tags.is_dirty(42, 0b1111));
    }
}
