//! State and helpers shared by all four L1 organizations.
//!
//! Each GPU core owns one [`CoreL1`]: a sectored cache plus the timing
//! resources in front of it (tag port, data-array banks, MSHR pool).  The
//! organizations differ in *who is allowed to reach which CoreL1 and how*
//! — which is exactly the paper's design space.


use crate::cache::SectoredCache;
use crate::config::{GpuConfig, WritePolicy};
use crate::mem::{decode, LineAddr, MemRequest, SectorMask};
use crate::util::fxhash::FxHashMap;
use crate::resource::{BankedCalendar, MultiPort};
use crate::stats::{ContentionStats, L1Stats, ResourceClass};

use super::AccessResult;

/// One core's L1 storage and timing resources.
///
/// The tag and data pipelines are banked together (GPGPU-Sim style): each
/// bank accepts one operation per cycle, so accesses to different banks
/// proceed in parallel and same-bank accesses serialize — the conflict
/// mechanism the paper's decoupled baseline suffers from.
#[derive(Debug)]
pub struct CoreL1 {
    pub cache: SectoredCache,
    /// Tag+data banks (Table II: 4 banks/L1).
    pub banks: BankedCalendar,
    /// MSHR entries held from allocation until the fill lands.
    pub mshr: MultiPort,
    /// Line → fill-ready cycle for in-flight misses (merge target).
    pub in_flight: FxHashMap<LineAddr, u64>,
}

impl CoreL1 {
    pub fn new(cfg: &GpuConfig) -> Self {
        CoreL1 {
            cache: SectoredCache::from_l1(&cfg.l1),
            banks: BankedCalendar::new(cfg.l1.banks),
            mshr: MultiPort::new(cfg.l1.mshr_entries),
            in_flight: FxHashMap::default(),
        }
    }

    /// Is `line` still being fetched at `now`? Returns its ready cycle.
    pub fn in_flight_ready(&self, line: LineAddr, now: u64) -> Option<u64> {
        self.in_flight.get(&line).copied().filter(|&r| r > now)
    }

    /// Periodic cleanup of landed fills.
    pub fn sweep(&mut self, now: u64) {
        self.in_flight.retain(|_, &mut r| r > now);
    }
}

/// Timing constants every organization needs, pre-extracted from config.
#[derive(Debug, Clone, Copy)]
pub struct L1Timing {
    pub latency: u32,
    pub line_bytes: usize,
    pub sector_bytes: usize,
    pub flit_bytes: usize,
    pub banks: usize,
    pub write_policy: WritePolicy,
}

impl L1Timing {
    pub fn new(cfg: &GpuConfig) -> Self {
        L1Timing {
            latency: cfg.l1.latency,
            line_bytes: cfg.l1.line_bytes,
            sector_bytes: cfg.l1.sector_bytes,
            flit_bytes: cfg.noc.flit_bytes,
            banks: cfg.l1.banks,
            write_policy: cfg.l1.write_policy,
        }
    }

    /// Flits for a data payload of `sectors` sectors (+1 header flit).
    pub fn data_flits(&self, sectors: u32) -> u32 {
        let bytes = sectors as usize * self.sector_bytes;
        bytes.div_ceil(self.flit_bytes) as u32 + 1
    }
}

/// Install a fill into `l1` at `fill_cycle`: updates tags, forwards a
/// dirty victim to L2, records the in-flight entry.  Returns the cycle the
/// fill is usable.
///
/// Fills use a dedicated write port rather than the read banks: a fill's
/// timestamp lies in the future relative to the requests currently being
/// scheduled, and the reservation timeline of a read bank must only be fed
/// in (near-)monotone time order (see `resource::Server`).  Read/probe
/// contention - the conflict mechanism the paper studies - is unaffected.
/// `core_global` is the core whose NoC port carries the victim writeback
/// (the cache's owner); `attr_core` is the core charged for the
/// writeback's queueing (the requester whose fill caused the eviction).
/// They differ only for decoupled-sharing home slices.
#[allow(clippy::too_many_arguments)]
pub fn install_fill(
    l1: &mut CoreL1,
    core_global: u32,
    attr_core: u32,
    line: LineAddr,
    sectors: SectorMask,
    fill_cycle: u64,
    _timing: &L1Timing,
    mem: &mut crate::l2::MemSystem,
    stats: &mut L1Stats,
) -> u64 {
    let (_, evicted) = l1.cache.fill(line, sectors);
    stats.fills += 1;
    if let Some(ev) = evicted {
        // Only dirty victims generate L2 write traffic; clean victims are
        // dropped silently.  `TagArray::fill` reports dirty victims only —
        // the guard makes the invariant explicit and local.  (No policy
        // check here: decoupled-sharing's home slices hold the only copy
        // and mark it dirty regardless of the configured L1 policy.)
        debug_assert!(ev.dirty_sectors != 0, "clean victims are not reported");
        if ev.dirty_sectors != 0 {
            mem.write_for(
                core_global as usize,
                ev.line,
                ev.dirty_sectors.count_ones(),
                fill_cycle,
                attr_core as usize,
            );
        }
    }
    l1.in_flight.insert(line, fill_cycle);
    fill_cycle
}

/// Dispatch point of a miss through the finite MSHR pool: when every
/// entry is occupied the miss stalls until one frees, the stall is
/// attributed to [`ResourceClass::MshrFull`], and the request counts as a
/// structural-hazard reject.  Both the private/common path and the ATA
/// path go through this helper so a full pool delays dispatch identically
/// everywhere.  Returns the dispatch cycle; the caller must
/// `occupy_until(start, fill)` once the fill time is known.
pub fn mshr_dispatch(
    l1: &mut CoreL1,
    core_global: u32,
    t_ready: u64,
    stats: &mut L1Stats,
    con: &mut ContentionStats,
) -> u64 {
    let start = l1.mshr.earliest(t_ready);
    let stall = start - t_ready;
    if stall > 0 {
        stats.rejects += 1;
        con.add(core_global as usize, ResourceClass::MshrFull, stall);
    }
    start
}

/// The private-cache load path: tag lookup, bank access on a hit, MSHR +
/// L2 fetch on a miss.  This is the baseline organization's entire
/// behaviour and the "local cache" half of remote-sharing and ATA-Cache.
pub fn local_load(
    l1: &mut CoreL1,
    req: &MemRequest,
    now: u64,
    timing: &L1Timing,
    mem: &mut crate::l2::MemSystem,
    stats: &mut L1Stats,
    con: &mut ContentionStats,
) -> AccessResult {
    let core = req.core as usize;
    let bank = decode::l1_bank(req.line, timing.banks);
    match l1.cache.tags.lookup(req.line, req.sectors) {
        crate::cache::Probe::Hit { .. } => {
            // The tags were installed when the miss was *scheduled*; if the
            // fill has not landed yet this is really a merge on the
            // in-flight fetch, not a hit.
            if let Some(ready) = l1.in_flight_ready(req.line, now) {
                stats.mshr_merges += 1;
                return AccessResult::new(
                    ready.max(now) + 1,
                    now + 1 + timing.latency as u64,
                );
            }
            stats.local_hits += 1;
            // Tag+data bank: one (line-wide) operation per cycle; accesses
            // to the same bank in the same cycle serialize — the paper's
            // bank-conflict mechanism.
            let g = l1.banks.reserve(bank, now, 1);
            stats.bank_conflict_cycles += g.queued;
            con.add(core, ResourceClass::L1DataBank, g.queued);
            AccessResult::served(g.grant + timing.latency as u64)
        }
        probe => {
            // Merge onto an in-flight fetch of this line if possible.
            if let Some(ready) = l1.in_flight_ready(req.line, now) {
                stats.mshr_merges += 1;
                return AccessResult::new(
                    ready.max(now) + 1,
                    now + 1 + timing.latency as u64,
                );
            }
            // The tag probe costs one bank cycle even on a miss.
            let g = l1.banks.reserve(bank, now, 1);
            con.add(core, ResourceClass::L1TagBank, g.queued);
            let t_tag = g.grant + 1;
            let fetch_sectors = match probe {
                crate::cache::Probe::SectorMiss { missing, .. } => {
                    stats.sector_misses += 1;
                    missing
                }
                _ => {
                    stats.misses += 1;
                    // Sector cache: fetch only the requested sectors
                    // (Table II: 32 B sector fills, GPGPU-Sim behaviour).
                    req.sectors
                }
            };
            // MSHR entry held from allocation to fill (full pool stalls
            // dispatch — see `mshr_dispatch`).
            let start = mshr_dispatch(l1, req.core, t_tag, stats, con);
            let fetch_req = MemRequest {
                sectors: fetch_sectors,
                ..*req
            };
            let fill = mem.fetch(&fetch_req, start);
            l1.mshr.occupy_until(start, fill);
            let usable = install_fill(
                l1,
                req.core,
                req.core,
                req.line,
                fetch_sectors,
                fill,
                timing,
                mem,
                stats,
            );
            // L1 stage = miss detection + forward, charged one pipeline
            // depth past the dispatch point so hit/miss stages compare.
            AccessResult::new(usable + 1, start + timing.latency as u64)
        }
    }
}

/// Handle a store according to the configured write policy, entirely
/// within the request's local cache (§III-C: "for write requests we only
/// process them in the local cache of the request's source core").
pub fn handle_store(
    l1: &mut CoreL1,
    req: &MemRequest,
    now: u64,
    timing: &L1Timing,
    mem: &mut crate::l2::MemSystem,
    stats: &mut L1Stats,
    con: &mut ContentionStats,
) -> AccessResult {
    stats.writes += 1;
    let core = req.core as usize;
    let bank = decode::l1_bank(req.line, timing.banks);
    let t_tag = now;
    match timing.write_policy {
        WritePolicy::WriteThrough => {
            // Update the line if present, and always send the data to L2.
            if l1.cache.tags.mark_dirty(req.line, 0) {
                // Present: data-array write (dirty bits stay clear in WT —
                // mark_dirty(.., 0) only touches LRU).
                let g = l1.banks.reserve(bank, t_tag, 1);
                stats.bank_conflict_cycles += g.queued;
                con.add(core, ResourceClass::L1DataBank, g.queued);
            }
            mem.write(core, req.line, req.sector_count(), t_tag);
            AccessResult::served(t_tag + 1)
        }
        WritePolicy::WriteBackLocal => {
            let g = l1.banks.reserve(bank, t_tag, 1);
            stats.bank_conflict_cycles += g.queued;
            con.add(core, ResourceClass::L1DataBank, g.queued);
            // Write-allocate: written sectors become valid + dirty.
            let (_, evicted) = l1.cache.fill(req.line, req.sectors);
            l1.cache.tags.mark_dirty(req.line, req.sectors);
            if let Some(ev) = evicted {
                debug_assert!(ev.dirty_sectors != 0, "clean victims are not reported");
                if ev.dirty_sectors != 0 {
                    mem.write(core, ev.line, ev.dirty_sectors.count_ones(), g.grant);
                }
            }
            AccessResult::served(g.grant + 1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::L1ArchKind;
    use crate::l2::MemSystem;
    use crate::mem::AccessKind;

    fn setup() -> (CoreL1, L1Timing, MemSystem, L1Stats, ContentionStats) {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        (
            CoreL1::new(&cfg),
            L1Timing::new(&cfg),
            MemSystem::new(&cfg),
            L1Stats::default(),
            ContentionStats::new(cfg.cores),
        )
    }

    fn store(line: LineAddr) -> MemRequest {
        MemRequest {
            id: 1,
            core: 0,
            warp: 0,
            inst: 0,
            line,
            sectors: 0b0011,
            kind: AccessKind::Store,
            issue_cycle: 0,
        }
    }

    fn load(id: u64, line: LineAddr) -> MemRequest {
        MemRequest {
            id,
            core: 0,
            warp: 0,
            inst: id,
            line,
            sectors: 0b1111,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    #[test]
    fn install_fill_tracks_in_flight_and_evicts() {
        let (mut l1, t, mut mem, mut stats, _) = setup();
        let g = install_fill(&mut l1, 0, 0, 42, 0b1111, 100, &t, &mut mem, &mut stats);
        assert!(g >= 100);
        assert_eq!(stats.fills, 1);
        assert_eq!(l1.in_flight_ready(42, 50), Some(g));
        assert_eq!(l1.in_flight_ready(42, g + 1), None, "landed");
        l1.sweep(g + 1);
        assert!(l1.in_flight.is_empty());
    }

    #[test]
    fn writeback_local_allocates_and_dirties() {
        let (mut l1, t, mut mem, mut stats, mut con) = setup();
        handle_store(&mut l1, &store(9), 0, &t, &mut mem, &mut stats, &mut con);
        assert!(l1.cache.tags.is_dirty(9, 0b0011));
        assert_eq!(mem.stats.writes, 0, "no L2 traffic on local write");
        assert_eq!(stats.writes, 1);
    }

    #[test]
    fn writethrough_sends_to_l2() {
        let cfg = {
            let mut c = GpuConfig::tiny(L1ArchKind::Private);
            c.l1.write_policy = WritePolicy::WriteThrough;
            c
        };
        let mut l1 = CoreL1::new(&cfg);
        let t = L1Timing::new(&cfg);
        let mut mem = MemSystem::new(&cfg);
        let mut stats = L1Stats::default();
        let mut con = ContentionStats::new(cfg.cores);
        handle_store(&mut l1, &store(9), 0, &t, &mut mem, &mut stats, &mut con);
        assert_eq!(mem.stats.writes, 1, "write-through reaches L2");
        assert!(!l1.cache.tags.is_dirty(9, 0b0011));
    }

    #[test]
    fn dirty_eviction_writes_back() {
        let (mut l1, t, mut mem, mut stats, mut con) = setup();
        // Dirty a line, then force enough fills into its set to evict it.
        handle_store(&mut l1, &store(0), 0, &t, &mut mem, &mut stats, &mut con);
        let sets = l1.cache.tags.sets() as u64;
        let assoc = l1.cache.tags.assoc() as u64;
        for k in 1..=assoc {
            install_fill(&mut l1, 0, 0, k * sets, 0b1111, 1000, &t, &mut mem, &mut stats);
        }
        assert!(mem.stats.writes >= 1, "dirty victim written back to L2");
    }

    #[test]
    fn clean_evictions_send_no_l2_writes() {
        // Pin the L2 write count: evicting *clean* lines must generate
        // zero write traffic under write-back-local…
        let (mut l1, t, mut mem, mut stats, _) = setup();
        let sets = l1.cache.tags.sets() as u64;
        let assoc = l1.cache.tags.assoc() as u64;
        for k in 0..assoc * 3 {
            install_fill(&mut l1, 0, 0, k * sets, 0b1111, 1000, &t, &mut mem, &mut stats);
        }
        assert_eq!(mem.stats.writes, 0, "clean victims must not reach L2");

        // …and under write-through the only L2 writes are the stores
        // themselves (lines are never dirty, so evictions add nothing).
        let cfg = {
            let mut c = GpuConfig::tiny(L1ArchKind::Private);
            c.l1.write_policy = WritePolicy::WriteThrough;
            c
        };
        let mut l1 = CoreL1::new(&cfg);
        let t = L1Timing::new(&cfg);
        let mut mem = MemSystem::new(&cfg);
        let mut stats = L1Stats::default();
        let mut con = ContentionStats::new(cfg.cores);
        let n_stores = 5u64;
        for i in 0..n_stores {
            handle_store(&mut l1, &store(i), i * 10, &t, &mut mem, &mut stats, &mut con);
        }
        let sets = l1.cache.tags.sets() as u64;
        let assoc = l1.cache.tags.assoc() as u64;
        for k in 0..assoc * 3 {
            install_fill(&mut l1, 0, 0, 1 + k * sets, 0b1111, 5000, &t, &mut mem, &mut stats);
        }
        assert_eq!(
            mem.stats.writes, n_stores,
            "write-through L2 writes == stores, evictions add none"
        );
    }

    #[test]
    fn full_mshr_pool_delays_dispatch_and_counts_rejects() {
        // Saturate the MSHR pool with same-cycle misses to distinct lines:
        // dispatch must serialize once the pool is full, each stalled miss
        // must count a reject, and the stall must land in the breakdown.
        let cfg = {
            let mut c = GpuConfig::tiny(L1ArchKind::Private);
            c.l1.mshr_entries = 2;
            c
        };
        c_assert_mshr(&cfg);
    }

    fn c_assert_mshr(cfg: &GpuConfig) {
        let mut l1 = CoreL1::new(cfg);
        let t = L1Timing::new(cfg);
        let mut mem = MemSystem::new(cfg);
        let mut stats = L1Stats::default();
        let mut con = ContentionStats::new(cfg.cores);
        let n = 8u64;
        let mut dispatches = Vec::new();
        for i in 0..n {
            // Distinct lines, same arrival cycle → no merges, pure pool
            // pressure.
            local_load(&mut l1, &load(i, i * 64), 0, &t, &mut mem, &mut stats, &mut con);
            dispatches.push(l1.mshr.earliest(0));
        }
        assert_eq!(stats.misses, n);
        assert!(
            stats.rejects >= n - cfg.l1.mshr_entries as u64,
            "misses beyond the pool must reject: {} rejects",
            stats.rejects
        );
        assert!(
            con.total().get(ResourceClass::MshrFull) > 0,
            "MSHR-full stalls must be attributed: {:?}",
            con.total()
        );
        // The pool's earliest-free horizon must move out as misses pile up.
        assert!(dispatches.windows(2).all(|w| w[0] <= w[1]));
        assert!(dispatches[n as usize - 1] > 0, "a full pool delays dispatch");
    }

    #[test]
    fn data_flits_include_header() {
        let (_, t, _, _, _) = setup();
        assert_eq!(t.data_flits(1), 1 + 1); // 32B / 40B flit = 1 + hdr
        assert_eq!(t.data_flits(4), 4 + 1); // 128B -> 4 flits + hdr
    }
}
