//! State and helpers shared by all four L1 organizations.
//!
//! Each GPU core owns one [`CoreL1`]: a sectored cache plus the timing
//! resources in front of it (tag port, data-array banks, MSHR pool).  The
//! organizations differ in *who is allowed to reach which CoreL1 and how*
//! — which is exactly the paper's design space.


use crate::cache::SectoredCache;
use crate::config::{GpuConfig, WritePolicy};
use crate::mem::{decode, LineAddr, MemRequest, SectorMask};
use crate::util::fxhash::FxHashMap;
use crate::resource::{BankedCalendar, MultiPort};
use crate::stats::L1Stats;

use super::AccessResult;

/// One core's L1 storage and timing resources.
///
/// The tag and data pipelines are banked together (GPGPU-Sim style): each
/// bank accepts one operation per cycle, so accesses to different banks
/// proceed in parallel and same-bank accesses serialize — the conflict
/// mechanism the paper's decoupled baseline suffers from.
#[derive(Debug)]
pub struct CoreL1 {
    pub cache: SectoredCache,
    /// Tag+data banks (Table II: 4 banks/L1).
    pub banks: BankedCalendar,
    /// MSHR entries held from allocation until the fill lands.
    pub mshr: MultiPort,
    /// Line → fill-ready cycle for in-flight misses (merge target).
    pub in_flight: FxHashMap<LineAddr, u64>,
}

impl CoreL1 {
    pub fn new(cfg: &GpuConfig) -> Self {
        CoreL1 {
            cache: SectoredCache::from_l1(&cfg.l1),
            banks: BankedCalendar::new(cfg.l1.banks),
            mshr: MultiPort::new(cfg.l1.mshr_entries),
            in_flight: FxHashMap::default(),
        }
    }

    /// Is `line` still being fetched at `now`? Returns its ready cycle.
    pub fn in_flight_ready(&self, line: LineAddr, now: u64) -> Option<u64> {
        self.in_flight.get(&line).copied().filter(|&r| r > now)
    }

    /// Periodic cleanup of landed fills.
    pub fn sweep(&mut self, now: u64) {
        self.in_flight.retain(|_, &mut r| r > now);
    }
}

/// Timing constants every organization needs, pre-extracted from config.
#[derive(Debug, Clone, Copy)]
pub struct L1Timing {
    pub latency: u32,
    pub line_bytes: usize,
    pub sector_bytes: usize,
    pub flit_bytes: usize,
    pub banks: usize,
    pub write_policy: WritePolicy,
}

impl L1Timing {
    pub fn new(cfg: &GpuConfig) -> Self {
        L1Timing {
            latency: cfg.l1.latency,
            line_bytes: cfg.l1.line_bytes,
            sector_bytes: cfg.l1.sector_bytes,
            flit_bytes: cfg.noc.flit_bytes,
            banks: cfg.l1.banks,
            write_policy: cfg.l1.write_policy,
        }
    }

    /// Flits for a data payload of `sectors` sectors (+1 header flit).
    pub fn data_flits(&self, sectors: u32) -> u32 {
        let bytes = sectors as usize * self.sector_bytes;
        bytes.div_ceil(self.flit_bytes) as u32 + 1
    }
}

/// Install a fill into `l1` at `fill_cycle`: updates tags, forwards a
/// dirty victim to L2, records the in-flight entry.  Returns the cycle the
/// fill is usable.
///
/// Fills use a dedicated write port rather than the read banks: a fill's
/// timestamp lies in the future relative to the requests currently being
/// scheduled, and the reservation timeline of a read bank must only be fed
/// in (near-)monotone time order (see `resource::Server`).  Read/probe
/// contention - the conflict mechanism the paper studies - is unaffected.
pub fn install_fill(
    l1: &mut CoreL1,
    core_global: u32,
    line: LineAddr,
    sectors: SectorMask,
    fill_cycle: u64,
    _timing: &L1Timing,
    mem: &mut crate::l2::MemSystem,
    stats: &mut L1Stats,
) -> u64 {
    let (_, evicted) = l1.cache.fill(line, sectors);
    stats.fills += 1;
    if let Some(ev) = evicted {
        // Dirty victim: write back to L2 (fire-and-forget).
        mem.write(
            core_global as usize,
            ev.line,
            ev.dirty_sectors.count_ones(),
            fill_cycle,
        );
    }
    l1.in_flight.insert(line, fill_cycle);
    fill_cycle
}

/// The private-cache load path: tag lookup, bank access on a hit, MSHR +
/// L2 fetch on a miss.  This is the baseline organization's entire
/// behaviour and the "local cache" half of remote-sharing and ATA-Cache.
pub fn local_load(
    l1: &mut CoreL1,
    req: &MemRequest,
    now: u64,
    timing: &L1Timing,
    mem: &mut crate::l2::MemSystem,
    stats: &mut L1Stats,
) -> AccessResult {
    let bank = decode::l1_bank(req.line, timing.banks);
    match l1.cache.tags.lookup(req.line, req.sectors) {
        crate::cache::Probe::Hit { .. } => {
            // The tags were installed when the miss was *scheduled*; if the
            // fill has not landed yet this is really a merge on the
            // in-flight fetch, not a hit.
            if let Some(ready) = l1.in_flight_ready(req.line, now) {
                stats.mshr_merges += 1;
                return AccessResult::new(
                    ready.max(now) + 1,
                    now + 1 + timing.latency as u64,
                );
            }
            stats.local_hits += 1;
            // Tag+data bank: one (line-wide) operation per cycle; accesses
            // to the same bank in the same cycle serialize — the paper's
            // bank-conflict mechanism.
            let grant = l1.banks.reserve(bank, now, 1);
            stats.bank_conflict_cycles += grant - now;
            AccessResult::served(grant + timing.latency as u64)
        }
        probe => {
            // Merge onto an in-flight fetch of this line if possible.
            if let Some(ready) = l1.in_flight_ready(req.line, now) {
                stats.mshr_merges += 1;
                return AccessResult::new(
                    ready.max(now) + 1,
                    now + 1 + timing.latency as u64,
                );
            }
            // The tag probe costs one bank cycle even on a miss.
            let t_tag = l1.banks.reserve(bank, now, 1) + 1;
            let fetch_sectors = match probe {
                crate::cache::Probe::SectorMiss { missing, .. } => {
                    stats.sector_misses += 1;
                    missing
                }
                _ => {
                    stats.misses += 1;
                    // Sector cache: fetch only the requested sectors
                    // (Table II: 32 B sector fills, GPGPU-Sim behaviour).
                    req.sectors
                }
            };
            // MSHR entry held from allocation to fill (full pool stalls).
            let start = l1.mshr.earliest(t_tag);
            let fetch_req = MemRequest {
                sectors: fetch_sectors,
                ..*req
            };
            let fill = mem.fetch(&fetch_req, start);
            l1.mshr.occupy_until(t_tag, fill);
            let usable = install_fill(
                l1,
                req.core,
                req.line,
                fetch_sectors,
                fill,
                timing,
                mem,
                stats,
            );
            // L1 stage = miss detection + forward, charged one pipeline
            // depth past the dispatch point so hit/miss stages compare.
            AccessResult::new(usable + 1, start + timing.latency as u64)
        }
    }
}

/// Handle a store according to the configured write policy, entirely
/// within the request's local cache (§III-C: "for write requests we only
/// process them in the local cache of the request's source core").
pub fn handle_store(
    l1: &mut CoreL1,
    req: &MemRequest,
    now: u64,
    timing: &L1Timing,
    mem: &mut crate::l2::MemSystem,
    stats: &mut L1Stats,
) -> AccessResult {
    stats.writes += 1;
    let bank = decode::l1_bank(req.line, timing.banks);
    let t_tag = now;
    match timing.write_policy {
        WritePolicy::WriteThrough => {
            // Update the line if present, and always send the data to L2.
            if l1.cache.tags.mark_dirty(req.line, 0) {
                // Present: data-array write (dirty bits stay clear in WT —
                // mark_dirty(.., 0) only touches LRU).
                let g = l1.banks.reserve(bank, t_tag, 1);
                stats.bank_conflict_cycles += g - t_tag;
            }
            mem.write(req.core as usize, req.line, req.sector_count(), t_tag);
            AccessResult::served(t_tag + 1)
        }
        WritePolicy::WriteBackLocal => {
            let g = l1.banks.reserve(bank, t_tag, 1);
            stats.bank_conflict_cycles += g - t_tag;
            // Write-allocate: written sectors become valid + dirty.
            let (_, evicted) = l1.cache.fill(req.line, req.sectors);
            l1.cache.tags.mark_dirty(req.line, req.sectors);
            if let Some(ev) = evicted {
                mem.write(
                    req.core as usize,
                    ev.line,
                    ev.dirty_sectors.count_ones(),
                    g,
                );
            }
            AccessResult::served(g + 1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::L1ArchKind;
    use crate::l2::MemSystem;
    use crate::mem::AccessKind;

    fn setup() -> (CoreL1, L1Timing, MemSystem, L1Stats) {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        (
            CoreL1::new(&cfg),
            L1Timing::new(&cfg),
            MemSystem::new(&cfg),
            L1Stats::default(),
        )
    }

    fn store(line: LineAddr) -> MemRequest {
        MemRequest {
            id: 1,
            core: 0,
            warp: 0,
            inst: 0,
            line,
            sectors: 0b0011,
            kind: AccessKind::Store,
            issue_cycle: 0,
        }
    }

    #[test]
    fn install_fill_tracks_in_flight_and_evicts() {
        let (mut l1, t, mut mem, mut stats) = setup();
        let g = install_fill(&mut l1, 0, 42, 0b1111, 100, &t, &mut mem, &mut stats);
        assert!(g >= 100);
        assert_eq!(stats.fills, 1);
        assert_eq!(l1.in_flight_ready(42, 50), Some(g));
        assert_eq!(l1.in_flight_ready(42, g + 1), None, "landed");
        l1.sweep(g + 1);
        assert!(l1.in_flight.is_empty());
    }

    #[test]
    fn writeback_local_allocates_and_dirties() {
        let (mut l1, t, mut mem, mut stats) = setup();
        handle_store(&mut l1, &store(9), 0, &t, &mut mem, &mut stats);
        assert!(l1.cache.tags.is_dirty(9, 0b0011));
        assert_eq!(mem.stats.writes, 0, "no L2 traffic on local write");
        assert_eq!(stats.writes, 1);
    }

    #[test]
    fn writethrough_sends_to_l2() {
        let cfg = {
            let mut c = GpuConfig::tiny(L1ArchKind::Private);
            c.l1.write_policy = WritePolicy::WriteThrough;
            c
        };
        let mut l1 = CoreL1::new(&cfg);
        let t = L1Timing::new(&cfg);
        let mut mem = MemSystem::new(&cfg);
        let mut stats = L1Stats::default();
        handle_store(&mut l1, &store(9), 0, &t, &mut mem, &mut stats);
        assert_eq!(mem.stats.writes, 1, "write-through reaches L2");
        assert!(!l1.cache.tags.is_dirty(9, 0b0011));
    }

    #[test]
    fn dirty_eviction_writes_back() {
        let (mut l1, t, mut mem, mut stats) = setup();
        // Dirty a line, then force enough fills into its set to evict it.
        handle_store(&mut l1, &store(0), 0, &t, &mut mem, &mut stats);
        let sets = l1.cache.tags.sets() as u64;
        let assoc = l1.cache.tags.assoc() as u64;
        for k in 1..=assoc {
            install_fill(&mut l1, 0, k * sets, 0b1111, 1000, &t, &mut mem, &mut stats);
        }
        assert!(mem.stats.writes >= 1, "dirty victim written back to L2");
    }

    #[test]
    fn data_flits_include_header() {
        let (_, t, _, _) = setup();
        assert_eq!(t.data_flits(1), 1 + 1); // 32B / 40B flit = 1 + hdr
        assert_eq!(t.data_flits(4), 4 + 1); // 128B -> 4 flits + hdr
    }
}
