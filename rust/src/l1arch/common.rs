//! Per-core state shared by every L1 organization.
//!
//! Each GPU core owns one [`CoreL1`]: a sectored cache plus the timing
//! resources in front of it (tag port, data-array banks, MSHR pool).  The
//! organizations differ in *who is allowed to reach which CoreL1 and how*
//! — which is exactly the paper's design space, and exactly what a
//! [`SharingPolicy`](super::SharingPolicy) decides on top of the shared
//! [`pipeline`](super::pipeline).

use crate::cache::SectoredCache;
use crate::config::{GpuConfig, WritePolicy};
use crate::mem::LineAddr;
use crate::resource::{BankedCalendar, MultiPort};
use crate::util::fxhash::FxHashMap;

/// One core's L1 storage and timing resources.
///
/// The tag and data pipelines are banked together (GPGPU-Sim style): each
/// bank accepts one operation per cycle, so accesses to different banks
/// proceed in parallel and same-bank accesses serialize — the conflict
/// mechanism the paper's decoupled baseline suffers from.
#[derive(Debug)]
pub struct CoreL1 {
    pub cache: SectoredCache,
    /// Tag+data banks (Table II: 4 banks/L1).
    pub banks: BankedCalendar,
    /// MSHR entries held from allocation until the fill lands.
    pub mshr: MultiPort,
    /// Line → fill-ready cycle for in-flight misses (merge target).
    pub in_flight: FxHashMap<LineAddr, u64>,
    /// Line → MSHR-dispatch cycle for misses deferred into the phased
    /// memory walk *this epoch* (B1 installed the tags but the fill
    /// cycle isn't known until B3).  Kept separate from `in_flight` so
    /// merge timing is unchanged in the synchronous path; provably empty
    /// between epochs.
    pub pending: FxHashMap<LineAddr, u64>,
}

impl CoreL1 {
    pub fn new(cfg: &GpuConfig) -> Self {
        CoreL1 {
            cache: SectoredCache::from_l1(&cfg.l1),
            banks: BankedCalendar::new(cfg.l1.banks),
            mshr: MultiPort::new(cfg.l1.mshr_entries),
            in_flight: FxHashMap::default(),
            pending: FxHashMap::default(),
        }
    }

    /// Is `line` still being fetched at `now`? Returns its ready cycle.
    pub fn in_flight_ready(&self, line: LineAddr, now: u64) -> Option<u64> {
        self.in_flight.get(&line).copied().filter(|&r| r > now)
    }

    /// Periodic cleanup of landed fills.
    pub fn sweep(&mut self, now: u64) {
        self.in_flight.retain(|_, &mut r| r > now);
    }
}

/// Timing constants every organization needs, pre-extracted from config.
#[derive(Debug, Clone, Copy)]
pub struct L1Timing {
    pub latency: u32,
    pub line_bytes: usize,
    pub sector_bytes: usize,
    pub flit_bytes: usize,
    pub banks: usize,
    pub write_policy: WritePolicy,
}

impl L1Timing {
    pub fn new(cfg: &GpuConfig) -> Self {
        L1Timing {
            latency: cfg.l1.latency,
            line_bytes: cfg.l1.line_bytes,
            sector_bytes: cfg.l1.sector_bytes,
            flit_bytes: cfg.noc.flit_bytes,
            banks: cfg.l1.banks,
            write_policy: cfg.l1.write_policy,
        }
    }

    /// Flits for a data payload of `sectors` sectors (+1 header flit).
    pub fn data_flits(&self, sectors: u32) -> u32 {
        let bytes = sectors as usize * self.sector_bytes;
        bytes.div_ceil(self.flit_bytes) as u32 + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::L1ArchKind;

    #[test]
    fn in_flight_tracking_and_sweep() {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let mut l1 = CoreL1::new(&cfg);
        l1.in_flight.insert(42, 100);
        assert_eq!(l1.in_flight_ready(42, 50), Some(100));
        assert_eq!(l1.in_flight_ready(42, 100), None, "landed");
        assert_eq!(l1.in_flight_ready(7, 50), None, "unknown line");
        l1.sweep(101);
        assert!(l1.in_flight.is_empty());
    }

    #[test]
    fn data_flits_include_header() {
        let t = L1Timing::new(&GpuConfig::tiny(L1ArchKind::Private));
        assert_eq!(t.data_flits(1), 1 + 1); // 32B / 40B flit = 1 + hdr
        assert_eq!(t.data_flits(4), 4 + 1); // 128B -> 4 flits + hdr
    }
}
