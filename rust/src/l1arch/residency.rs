//! The cluster residency index: "who holds this line?" in O(1).
//!
//! The aggregated tag array answers that question in hardware with one
//! parallel compare (§III-B); the simulator used to answer it in software
//! with an O(cluster) scan over every peer cache's tag array, heap-
//! allocating a holder list per request.  This index precomputes presence
//! once — exactly the aggregated-tag idea applied to the simulator
//! itself: a per-cluster hash map from [`LineAddr`] to per-sector holder
//! bitmasks, updated incrementally at the three
//! [`TagArray`](crate::cache::TagArray) mutation points (fill, eviction
//! — clean victims included — and dirty marking) and consulted by the
//! probe path as a single lookup.
//!
//! Bit `h` of a mask refers to the cluster-relative cache index `h`, so
//! a probe is independent of cluster size: a full-hit holder set is the
//! AND of the requested sectors' `valid` masks and a dirty check is the
//! OR of their `dirty` masks — at most [`MAX_SECTORS`] word operations.
//!
//! # The mutation-point invariant
//!
//! The index is only correct if **every** tag-array mutation in a
//! cluster goes through it.  The shared pipeline therefore routes all
//! tag mutations through [`PipelineCtx`](super::pipeline::PipelineCtx)
//! helpers (`fill_tags` / `mark_dirty_tags` / `invalidate_tags`) that
//! update both structures; policies must never call `cache.fill`,
//! `tags.mark_dirty`, or `tags.invalidate` directly on a cluster cache.
//! LRU-only operations (`lookup`, `touch`) never change validity or
//! dirtiness and stay index-free.  The invariant is enforced by
//! [`ResidencyIndex::rebuilt_from`] audits and the differential fuzz test in
//! `rust/tests/residency_differential.rs`, which must agree with the
//! brute-force union-of-peeks probe on arbitrary mutation sequences.

use crate::cache::Probe;
use crate::mem::{LineAddr, SectorMask};
use crate::util::fxhash::FxHashMap;

use super::common::CoreL1;

/// Holder masks are `u64`: at most 64 caches per cluster (validated by
/// `GpuConfig::validate`; the paper clusters 10).
pub const MAX_CLUSTER: usize = 64;

/// Sector masks are `u8`: at most 8 sectors per line (Table II uses 4).
pub const MAX_SECTORS: usize = 8;

/// Per-line residency state: for each sector, which cluster caches hold
/// it valid and which hold it dirty.  `dirty[s]` is always a subset of
/// `valid[s]` (mirroring `TagArray`, where only valid sectors can be
/// dirty).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LineResidency {
    valid: [u64; MAX_SECTORS],
    dirty: [u64; MAX_SECTORS],
}

impl LineResidency {
    /// No cache holds any sector of the line any more.
    fn is_empty(&self) -> bool {
        self.valid.iter().all(|&v| v == 0)
    }
}

/// Iterate the set sector indices of a mask.
#[inline]
fn sectors_of(mask: SectorMask) -> impl Iterator<Item = usize> {
    let mut m = mask;
    std::iter::from_fn(move || {
        if m == 0 {
            return None;
        }
        let s = m.trailing_zeros() as usize;
        m &= m - 1;
        Some(s)
    })
}

/// One cluster's residency index.  All `holder` arguments are
/// cluster-relative cache indices (`< MAX_CLUSTER`).
#[derive(Debug, Clone, Default)]
pub struct ResidencyIndex {
    map: FxHashMap<LineAddr, LineResidency>,
    /// High-water mark of resident-line entries (occupancy telemetry).
    peak_lines: usize,
}

impl ResidencyIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lines currently tracked (= lines resident in ≥ 1 cluster cache).
    pub fn lines(&self) -> usize {
        self.map.len()
    }

    /// High-water mark of [`Self::lines`] over the index's lifetime.
    pub fn peak_lines(&self) -> usize {
        self.peak_lines
    }

    /// A fill installed or extended `line` at `holder` with `sectors`.
    /// Dirty bits are untouched: a fresh install starts clean (the holder
    /// had no bits for the line) and a sector extension preserves the
    /// existing dirty sectors — exactly `TagArray::fill`.
    pub fn record_fill(&mut self, holder: usize, line: LineAddr, sectors: SectorMask) {
        debug_assert!(holder < MAX_CLUSTER);
        let bit = 1u64 << holder;
        let e = self.map.entry(line).or_default();
        for s in sectors_of(sectors) {
            e.valid[s] |= bit;
        }
        self.peak_lines = self.peak_lines.max(self.map.len());
    }

    /// `holder` no longer holds `line` (eviction or invalidation — clean
    /// victims included, which is why `TagArray::fill` reports them).
    pub fn record_evict(&mut self, holder: usize, line: LineAddr) {
        debug_assert!(holder < MAX_CLUSTER);
        let bit = 1u64 << holder;
        if let Some(e) = self.map.get_mut(&line) {
            for s in 0..MAX_SECTORS {
                e.valid[s] &= !bit;
                e.dirty[s] &= !bit;
            }
            if e.is_empty() {
                self.map.remove(&line);
            }
        }
    }

    /// A write hit marked `sectors` of `line` dirty at `holder` — only
    /// sectors the holder actually has become dirty, mirroring
    /// `TagArray::mark_dirty`'s `sectors & sector_valid`.
    pub fn record_mark_dirty(&mut self, holder: usize, line: LineAddr, sectors: SectorMask) {
        debug_assert!(holder < MAX_CLUSTER);
        let bit = 1u64 << holder;
        if let Some(e) = self.map.get_mut(&line) {
            for s in sectors_of(sectors) {
                if e.valid[s] & bit != 0 {
                    e.dirty[s] |= bit;
                }
            }
        }
    }

    /// Answer the aggregated probe for `(line, sectors)` in O(sectors):
    /// `(holders, dirty)` where `holders` has a bit per cluster cache
    /// holding **all** requested sectors (the requester's own bit
    /// cleared) and `dirty ⊆ holders` marks holders with any requested
    /// sector dirty — bit-for-bit what the union of `TagArray::peek`
    /// calls over the cluster reports.
    #[inline]
    pub fn probe(&self, line: LineAddr, sectors: SectorMask, local_idx: usize) -> (u64, u64) {
        let Some(e) = self.map.get(&line) else {
            return (0, 0);
        };
        // Coalesced requests always touch ≥ 1 sector (an empty mask would
        // make the AND identity below claim every cache holds the line —
        // the request model excludes it, so assert rather than handle).
        debug_assert!(sectors != 0, "probe with an empty sector mask");
        let mut full = u64::MAX;
        let mut dirty = 0u64;
        for s in sectors_of(sectors) {
            full &= e.valid[s];
            dirty |= e.dirty[s];
        }
        let holders = full & !(1u64 << local_idx);
        (holders, dirty & holders)
    }

    /// Reconstruct the index a cluster's caches *should* have, by
    /// exhaustive per-sector peeks (the audit oracle of the differential
    /// tests — O(lines × sectors), never on a hot path).
    pub fn rebuilt_from(caches: &[CoreL1], sectors_per_line: usize) -> Self {
        assert!(caches.len() <= MAX_CLUSTER && sectors_per_line <= MAX_SECTORS);
        let mut idx = ResidencyIndex::new();
        for (h, c) in caches.iter().enumerate() {
            let bit = 1u64 << h;
            for line in c.cache.tags.resident_lines() {
                let e = idx.map.entry(line).or_default();
                for s in 0..sectors_per_line {
                    match c.cache.peek(line, 1 << s) {
                        Probe::Hit { dirty, .. } => {
                            e.valid[s] |= bit;
                            if dirty {
                                e.dirty[s] |= bit;
                            }
                        }
                        Probe::SectorMiss { .. } => {}
                        Probe::Miss => unreachable!("resident line cannot line-miss"),
                    }
                }
            }
        }
        idx.peak_lines = idx.map.len();
        idx
    }

    /// Structural equality with another index (audit check; ignores the
    /// peak-occupancy telemetry).
    pub fn same_residency(&self, other: &ResidencyIndex) -> bool {
        self.map == other.map
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, L1ArchKind};

    #[test]
    fn fill_probe_evict_roundtrip() {
        let mut idx = ResidencyIndex::new();
        idx.record_fill(2, 42, 0b1111);
        assert_eq!(idx.probe(42, 0b1111, 0), (0b100, 0));
        assert_eq!(idx.probe(42, 0b0011, 0), (0b100, 0));
        // The requester's own copy is masked out.
        assert_eq!(idx.probe(42, 0b1111, 2), (0, 0));
        // An absent line reports nothing.
        assert_eq!(idx.probe(7, 0b1111, 0), (0, 0));
        idx.record_evict(2, 42);
        assert_eq!(idx.probe(42, 0b1111, 0), (0, 0));
        assert_eq!(idx.lines(), 0, "empty entries are dropped");
        assert_eq!(idx.peak_lines(), 1);
    }

    #[test]
    fn partial_sector_holders_only_match_covered_requests() {
        let mut idx = ResidencyIndex::new();
        idx.record_fill(1, 9, 0b0011);
        idx.record_fill(3, 9, 0b1111);
        // Holder 1 covers sectors {0,1} only; holder 3 covers all.
        assert_eq!(idx.probe(9, 0b0011, 0).0, 0b1010);
        assert_eq!(idx.probe(9, 0b1111, 0).0, 0b1000);
        assert_eq!(idx.probe(9, 0b0100, 0).0, 0b1000);
    }

    #[test]
    fn dirty_tracks_valid_sectors_and_requested_mask() {
        let mut idx = ResidencyIndex::new();
        idx.record_fill(1, 5, 0b0011);
        // Marking sectors the holder lacks is a no-op (mirrors mark_dirty).
        idx.record_mark_dirty(1, 5, 0b1100);
        assert_eq!(idx.probe(5, 0b0011, 0), (0b10, 0));
        idx.record_mark_dirty(1, 5, 0b0001);
        assert_eq!(idx.probe(5, 0b0011, 0), (0b10, 0b10), "dirty flagged");
        // A request not touching the dirty sector sees a clean holder.
        assert_eq!(idx.probe(5, 0b0010, 0), (0b10, 0));
    }

    #[test]
    fn sector_extension_preserves_dirty() {
        let mut idx = ResidencyIndex::new();
        idx.record_fill(0, 5, 0b0001);
        idx.record_mark_dirty(0, 5, 0b0001);
        idx.record_fill(0, 5, 0b0110); // extend with more sectors
        assert_eq!(idx.probe(5, 0b0111, 1), (0b1, 0b1), "still dirty");
    }

    #[test]
    fn rebuild_audit_matches_incremental_updates() {
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let mut cluster: Vec<CoreL1> = (0..4).map(|_| CoreL1::new(&cfg)).collect();
        let mut idx = ResidencyIndex::new();
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(7, 7);
        for _ in 0..500 {
            let h = rng.next_below(4) as usize;
            let line = rng.next_below(200) as u64;
            let sectors = (rng.next_below(15) + 1) as SectorMask;
            let (_, ev) = cluster[h].cache.fill(line, sectors);
            if let Some(ev) = ev {
                idx.record_evict(h, ev.line);
            }
            idx.record_fill(h, line, sectors);
            if rng.chance(0.3) {
                let d = rng.next_below(200) as u64;
                let m = (rng.next_below(15) + 1) as SectorMask;
                if cluster[h].cache.tags.mark_dirty(d, m) {
                    idx.record_mark_dirty(h, d, m);
                }
            }
            if rng.chance(0.05) {
                let v = rng.next_below(200) as u64;
                if cluster[h].cache.tags.invalidate(v) {
                    idx.record_evict(h, v);
                }
            }
        }
        let audit = ResidencyIndex::rebuilt_from(&cluster, 4);
        assert!(idx.same_residency(&audit), "incremental index drifted");
    }
}
