//! The four L1 cache organizations (§II–III of the paper).
//!
//! | Organization        | Tag lookup              | Data placement        | Sharing path            |
//! |---------------------|-------------------------|-----------------------|-------------------------|
//! | Private             | local                   | per-core, replicated  | none                    |
//! | Remote-sharing      | local, then ring probes | per-core, replicated  | probe ring (post-miss)  |
//! | Decoupled-sharing   | at home slice           | address-sliced        | cluster crossbar (all)  |
//! | **ATA-Cache**       | aggregated (pre-access) | per-core, replicated  | cluster crossbar (hits) |
//!
//! All organizations implement [`L1Arch`]; the engine is organization-
//! agnostic.

pub mod ata;
pub mod ata_tag;
pub mod common;
pub mod decoupled;
pub mod private;
pub mod remote;

use crate::config::{GpuConfig, L1ArchKind};
use crate::l2::MemSystem;
use crate::mem::{LineAddr, MemRequest};
use crate::stats::{ContentionStats, L1Stats};

/// Outcome of one request through an L1 organization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AccessResult {
    /// Cycle the data reaches the core (loads) / the write retires.
    pub done: u64,
    /// Cycle the *L1 stage* of the access completed: data return for any
    /// L1 hit (local or remote), or the dispatch-to-L2 point for a miss.
    /// This is the paper's §IV-C latency metric — it isolates the
    /// contention added by the L1 organization from L2/DRAM service time.
    pub l1_stage_done: u64,
}

impl AccessResult {
    pub fn new(done: u64, l1_stage_done: u64) -> Self {
        AccessResult { done, l1_stage_done }
    }

    /// An access fully served at `done` (hit paths).
    pub fn served(done: u64) -> Self {
        AccessResult { done, l1_stage_done: done }
    }
}

/// A full-GPU L1 organization: receives every core's coalesced requests
/// and returns each request's completion cycle.
///
/// # Contract
///
/// **Access ordering.**  The engine calls [`access`](L1Arch::access) with
/// `now` non-decreasing across calls; within one cycle, requests arrive
/// in a fixed deterministic order (per-core program order is preserved;
/// cores are visited in a stable order chosen by the execution mode, not
/// necessarily ascending core id).  Implementations may rely on this
/// monotonicity for their reservation calendars, and they must be
/// deterministic: the same request sequence must produce the same
/// results, regardless of wall clock or thread placement (each engine
/// owns its organization exclusively — `Send` but not `Sync`).
///
/// **Completion cycles.**  Every access returns an [`AccessResult`] with
/// `done >= now`; the engine never re-submits a request.  Structural
/// hazards (MSHR full, bank queue full) are modeled as added latency and
/// counted in [`L1Stats::rejects`], not surfaced as failures.
///
/// **Sweep semantics.**  [`sweep`](L1Arch::sweep) is pure housekeeping:
/// the engine calls it at coarse intervals (≈ every 64 k cycles) with the
/// current cycle so implementations can drop landed in-flight entries and
/// bound memory growth.  It must not change any future access's timing or
/// any statistic — results must be identical whether or not sweeps run.
///
/// **Stats invariants.**  [`stats`](L1Arch::stats) counters are
/// monotonically non-decreasing; `accesses` increments exactly once per
/// [`access`](L1Arch::access) call, and each access lands in exactly one
/// outcome class (`local_hits`, `remote_hits`, `sector_misses`, `misses`,
/// `mshr_merges`, or `writes`).  `rejects`, conflict-cycle counters and
/// `probes_sent` are side tallies, not outcome classes.  With multiple
/// co-executing applications the counters aggregate over all of them —
/// per-app attribution happens in the engine, which knows the core→app
/// mapping.
pub trait L1Arch: std::fmt::Debug + Send {
    /// Process one request issued at `now`.  For loads `done` is the cycle
    /// the data reaches the core; for stores it is the retire cycle of the
    /// write pipeline (cores do not block on it).
    fn access(&mut self, req: &MemRequest, now: u64, mem: &mut MemSystem) -> AccessResult;

    /// Aggregated counters (see the trait-level stats invariants).
    fn stats(&self) -> &L1Stats;

    /// Per-core, per-resource queueing attribution for the L1-side
    /// resources this organization owns (tag/data banks, comparator
    /// groups, the intra-cluster fabric, MSHR-full stalls).  Charged to
    /// the requesting core; monotone like the scalar counters.  The
    /// engine combines this with the memory system's share
    /// ([`MemSystem::contention`]) into the end-to-end breakdown.
    fn contention(&self) -> &ContentionStats;

    /// Which organization this is (matches the config that built it).
    fn kind(&self) -> L1ArchKind;

    /// Lines currently resident on behalf of `core` (replication audits).
    fn resident_lines(&self, core: usize) -> Vec<LineAddr>;

    /// Periodic housekeeping (drop landed in-flight entries).  Must not
    /// affect timing or statistics — see the trait-level sweep semantics.
    fn sweep(&mut self, now: u64);
}

/// Build the organization selected by `cfg.l1_arch`.
pub fn build(cfg: &GpuConfig) -> Box<dyn L1Arch> {
    match cfg.l1_arch {
        L1ArchKind::Private => Box::new(private::PrivateL1::new(cfg)),
        L1ArchKind::RemoteSharing => Box::new(remote::RemoteSharingL1::new(cfg)),
        L1ArchKind::DecoupledSharing => Box::new(decoupled::DecoupledSharingL1::new(cfg)),
        L1ArchKind::Ata => Box::new(ata::AtaCache::new(cfg)),
    }
}

/// Cluster geometry helper shared by the shared organizations.
#[derive(Debug, Clone, Copy)]
pub struct ClusterMap {
    pub cores: usize,
    pub cores_per_cluster: usize,
}

impl ClusterMap {
    pub fn new(cfg: &GpuConfig) -> Self {
        ClusterMap {
            cores: cfg.cores,
            cores_per_cluster: cfg.cores_per_cluster(),
        }
    }

    #[inline]
    pub fn cluster_of(&self, core: usize) -> usize {
        core / self.cores_per_cluster
    }

    #[inline]
    pub fn index_in_cluster(&self, core: usize) -> usize {
        core % self.cores_per_cluster
    }

    #[inline]
    pub fn global_core(&self, cluster: usize, idx: usize) -> usize {
        cluster * self.cores_per_cluster + idx
    }

    /// Iterate the other cores in `core`'s cluster (global ids).
    pub fn peers(&self, core: usize) -> impl Iterator<Item = usize> + '_ {
        let cluster = self.cluster_of(core);
        let base = cluster * self.cores_per_cluster;
        (base..base + self.cores_per_cluster).filter(move |&c| c != core)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_map_partitions_cores() {
        let cfg = GpuConfig::paper(L1ArchKind::Ata);
        let m = ClusterMap::new(&cfg);
        assert_eq!(m.cluster_of(0), 0);
        assert_eq!(m.cluster_of(9), 0);
        assert_eq!(m.cluster_of(10), 1);
        assert_eq!(m.cluster_of(29), 2);
        assert_eq!(m.index_in_cluster(23), 3);
        assert_eq!(m.global_core(2, 3), 23);
        let peers: Vec<usize> = m.peers(12).collect();
        assert_eq!(peers.len(), 9);
        assert!(peers.iter().all(|&c| (10..20).contains(&c) && c != 12));
    }

    #[test]
    fn factory_builds_every_kind() {
        for kind in L1ArchKind::ALL {
            let cfg = GpuConfig::tiny(kind);
            let arch = build(&cfg);
            assert_eq!(arch.kind(), kind);
        }
    }
}
