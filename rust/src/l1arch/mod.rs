//! The L1 cache organizations (§II–III of the paper) as policies over one
//! shared request pipeline.
//!
//! | Organization        | Tag lookup              | Data placement        | Sharing path            |
//! |---------------------|-------------------------|-----------------------|-------------------------|
//! | Private             | local                   | per-core, replicated  | none                    |
//! | Remote-sharing      | local, then ring probes | per-core, replicated  | probe ring (post-miss)  |
//! | Decoupled-sharing   | at home slice           | address-sliced        | cluster crossbar (all)  |
//! | **ATA-Cache**       | aggregated (pre-access) | per-core, replicated  | cluster crossbar (hits) |
//! | ATA-bypass          | aggregated (pre-access) | per-core, replicated  | crossbar, CIAO bypass   |
//!
//! Mechanism lives in [`pipeline`] (tag probes, bank reservations, MSHR
//! dispatch, fills, fabric crossings — all keyed off the
//! [`MemTxn`](crate::mem::MemTxn) transaction); each organization is a
//! [`SharingPolicy`] module registered in [`REGISTRY`].  The engine is
//! organization-agnostic: it opens a transaction per request and hands it
//! to [`L1Arch::access`].

pub mod ata;
pub mod ata_bypass;
pub mod ata_tag;
pub mod common;
pub mod decoupled;
pub mod pipeline;
pub mod private;
pub mod remote;
pub mod residency;

pub use pipeline::{FabricNeeds, PipelineCtx, PipelineL1, SharingPolicy};
pub use residency::ResidencyIndex;

use crate::config::{GpuConfig, L1ArchKind};
use crate::l2::MemSystem;
use crate::mem::{LineAddr, MemRequest, MemTxn};
use crate::stats::{ContentionStats, L1Stats, ResidencyStats};

/// A full-GPU L1 organization: receives every core's coalesced requests
/// as open [`MemTxn`] transactions and completes them.
///
/// # Contract
///
/// **Access ordering.**  The engine calls [`access`](L1Arch::access) with
/// `txn.now()` non-decreasing across calls; within one cycle, requests
/// arrive in a fixed deterministic order (per-core program order is
/// preserved; cores are visited in a stable order chosen by the execution
/// mode, not necessarily ascending core id).  Implementations may rely on
/// this monotonicity for their reservation calendars, and they must be
/// deterministic: the same request sequence must produce the same
/// results, regardless of wall clock or thread placement (each engine
/// owns its organization exclusively — `Send` but not `Sync`).
///
/// **Completion.**  Every access completes its transaction
/// (`txn.done() >= txn.now()`) — or, inside a phased memory-walk epoch
/// ([`MemSystem::phased`]), defers it by setting `txn.deferred`, in
/// which case the engine calls [`finish`](L1Arch::finish) on the same
/// transaction after the walk and *that* completes it.  The engine never
/// re-submits a request.  Structural hazards (MSHR full, bank queue
/// full) are modeled as added latency and counted in
/// [`L1Stats::rejects`], not surfaced as failures.
///
/// **Sweep semantics.**  [`sweep`](L1Arch::sweep) is pure housekeeping:
/// the engine calls it at coarse intervals (≈ every 64 k cycles) with the
/// current cycle so implementations can drop landed in-flight entries and
/// bound memory growth.  It must not change any future access's timing or
/// any statistic — results must be identical whether or not sweeps run.
///
/// **Stats invariants.**  [`stats`](L1Arch::stats) counters are
/// monotonically non-decreasing; `accesses` increments exactly once per
/// [`access`](L1Arch::access) call, and each access lands in exactly one
/// outcome class (`local_hits`, `remote_hits`, `sector_misses`, `misses`,
/// `mshr_merges`, or `writes`).  `rejects`, `bypasses`, conflict-cycle
/// counters and `probes_sent` are side tallies, not outcome classes.
/// With multiple co-executing applications the counters aggregate over
/// all of them — per-app attribution happens in the engine, which knows
/// the core→app mapping.
pub trait L1Arch: std::fmt::Debug + Send {
    /// Process one transaction opened at `txn.now()`.  For loads
    /// `txn.done()` is the cycle the data reaches the core; for stores it
    /// is the retire cycle of the write pipeline (cores do not block on
    /// it).  The organization stamps the transaction's hop timestamps and
    /// charges its queueing as it goes.
    fn access(&mut self, txn: &mut MemTxn, mem: &mut MemSystem);

    /// Phase B3 of a phased memory-walk epoch: finalize a transaction
    /// that [`access`](L1Arch::access) deferred.  Called in canonical
    /// request order after [`MemSystem::run_walk`]; a no-op for
    /// transactions that completed inline.
    fn finish(&mut self, txn: &mut MemTxn, mem: &mut MemSystem) {
        let _ = (txn, mem);
    }

    /// Aggregated counters (see the trait-level stats invariants).
    fn stats(&self) -> &L1Stats;

    /// Per-core, per-resource queueing attribution for the L1-side
    /// resources this organization owns (tag/data banks, comparator
    /// groups, the intra-cluster fabric, MSHR-full stalls).  Charged to
    /// the requesting core; monotone like the scalar counters.  The
    /// engine combines this with the memory system's share
    /// ([`MemSystem::contention`]) into the end-to-end breakdown.
    fn contention(&self) -> &ContentionStats;

    /// Residency-index telemetry (probe fast-path counts, occupancy).
    /// Host-performance data only — never part of result JSON, which
    /// must stay byte-identical whether the index is on or off.
    /// Defaults to zeros for organizations without an index.
    fn residency_stats(&self) -> ResidencyStats {
        ResidencyStats::default()
    }

    /// Which organization this is (matches the config that built it).
    fn kind(&self) -> L1ArchKind;

    /// Lines currently resident on behalf of `core` (replication audits).
    fn resident_lines(&self, core: usize) -> Vec<LineAddr>;

    /// Periodic housekeeping (drop landed in-flight entries).  Must not
    /// affect timing or statistics — see the trait-level sweep semantics.
    fn sweep(&mut self, now: u64);
}

/// Open a transaction for `req` at `now`, run it through `l1`, and return
/// the completed transaction (tests and tools; the engine manages its own
/// transactions).
pub fn access_once(
    l1: &mut dyn L1Arch,
    req: &MemRequest,
    now: u64,
    mem: &mut MemSystem,
) -> MemTxn {
    let mut txn = MemTxn::new(*req, now);
    l1.access(&mut txn, mem);
    txn
}

/// One registered L1 organization: its kind, CLI name, a one-line
/// summary, and the policy constructor the shared pipeline wraps.
pub struct OrgSpec {
    pub kind: L1ArchKind,
    pub name: &'static str,
    pub summary: &'static str,
    pub build: fn(&GpuConfig) -> Box<dyn SharingPolicy>,
}

/// The organization registry: every L1 organization the simulator knows,
/// in presentation order.  `build` consults it; tools iterate it so a new
/// organization shows up everywhere (run/sweep/contention/bench) by
/// adding one entry here plus its policy module.
pub const REGISTRY: &[OrgSpec] = &[
    OrgSpec {
        kind: L1ArchKind::Private,
        name: "private",
        summary: "per-core private L1 (normalization baseline)",
        build: private::policy,
    },
    OrgSpec {
        kind: L1ArchKind::RemoteSharing,
        name: "remote",
        summary: "private L1s + post-miss probe ring (TACO'16/PACT'19)",
        build: remote::policy,
    },
    OrgSpec {
        kind: L1ArchKind::DecoupledSharing,
        name: "decoupled",
        summary: "address-sliced cluster L1s, all accesses via home slice (PACT'20)",
        build: decoupled::policy,
    },
    OrgSpec {
        kind: L1ArchKind::Ata,
        name: "ata",
        summary: "aggregated tag array + remote-shared data (the paper)",
        build: ata::policy,
    },
    OrgSpec {
        kind: L1ArchKind::AtaBypass,
        name: "ata-bypass",
        summary: "ATA probing + CIAO-style interference-aware peer bypass",
        build: ata_bypass::policy,
    },
];

/// Look up a registry entry by kind.
pub fn org_spec(kind: L1ArchKind) -> &'static OrgSpec {
    REGISTRY
        .iter()
        .find(|s| s.kind == kind)
        // lint: allow(sim-panic) — the static registry is total over L1ArchKind by construction
        .expect("every L1ArchKind has a registry entry")
}

/// Build the organization selected by `cfg.l1_arch`: the shared pipeline
/// wrapped around the registered policy.
pub fn build(cfg: &GpuConfig) -> Box<dyn L1Arch> {
    Box::new(PipelineL1::new(cfg, (org_spec(cfg.l1_arch).build)(cfg)))
}

/// Cluster geometry helper shared by the shared organizations.
#[derive(Debug, Clone, Copy)]
pub struct ClusterMap {
    pub cores: usize,
    pub cores_per_cluster: usize,
}

impl ClusterMap {
    pub fn new(cfg: &GpuConfig) -> Self {
        ClusterMap {
            cores: cfg.cores,
            cores_per_cluster: cfg.cores_per_cluster(),
        }
    }

    #[inline]
    pub fn cluster_of(&self, core: usize) -> usize {
        core / self.cores_per_cluster
    }

    #[inline]
    pub fn index_in_cluster(&self, core: usize) -> usize {
        core % self.cores_per_cluster
    }

    #[inline]
    pub fn global_core(&self, cluster: usize, idx: usize) -> usize {
        cluster * self.cores_per_cluster + idx
    }

    /// Iterate the other cores in `core`'s cluster (global ids).
    pub fn peers(&self, core: usize) -> impl Iterator<Item = usize> + '_ {
        let cluster = self.cluster_of(core);
        let base = cluster * self.cores_per_cluster;
        (base..base + self.cores_per_cluster).filter(move |&c| c != core)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_map_partitions_cores() {
        let cfg = GpuConfig::paper(L1ArchKind::Ata);
        let m = ClusterMap::new(&cfg);
        assert_eq!(m.cluster_of(0), 0);
        assert_eq!(m.cluster_of(9), 0);
        assert_eq!(m.cluster_of(10), 1);
        assert_eq!(m.cluster_of(29), 2);
        assert_eq!(m.index_in_cluster(23), 3);
        assert_eq!(m.global_core(2, 3), 23);
        let peers: Vec<usize> = m.peers(12).collect();
        assert_eq!(peers.len(), 9);
        assert!(peers.iter().all(|&c| (10..20).contains(&c) && c != 12));
    }

    #[test]
    fn registry_builds_every_kind() {
        for kind in L1ArchKind::ALL {
            let cfg = GpuConfig::tiny(kind);
            let arch = build(&cfg);
            assert_eq!(arch.kind(), kind);
        }
    }

    #[test]
    fn registry_names_match_kind_names() {
        assert_eq!(REGISTRY.len(), L1ArchKind::ALL.len());
        for spec in REGISTRY {
            assert_eq!(spec.name, spec.kind.name(), "registry/CLI name drift");
            assert_eq!(
                L1ArchKind::from_name(spec.name),
                Some(spec.kind),
                "registry name must parse back"
            );
        }
    }
}
