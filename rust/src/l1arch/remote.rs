//! Remote-sharing L1 (Dublish et al. TACO'16 cooperative caching; Ibrahim
//! et al. PACT'19 prediction) — baseline #2, as a policy.
//!
//! Caches stay private and map the whole address space, but a miss first
//! probes the other cluster caches over a ring before going to L2 (Fig 2
//! of the paper).  The pathologies the paper calls out are modeled
//! directly:
//!
//! * the L2 access waits for the probe round trip even when every remote
//!   cache misses (longer L2 critical path),
//! * probes occupy ring links *and* remote tag ports (NoC + tag resource
//!   contention),
//! * remote data returns serialize on ring links.
//!
//! With `sharing.probe_predictor = true`, a PACT'19-style presence
//! predictor skips the probe round trip on (a configurable fraction of)
//! true global misses.

use crate::cache::Probe;
use crate::config::{GpuConfig, L1ArchKind};
use crate::l2::MemSystem;
use crate::mem::{decode, MemTxn, RetPath};
use crate::stats::ResourceClass;
use crate::util::rng::Pcg32;

use super::pipeline::{FabricNeeds, PipelineCtx, SharingPolicy};

/// Registry constructor.
pub fn policy(cfg: &GpuConfig) -> Box<dyn SharingPolicy> {
    Box::new(RemotePolicy {
        predictor: cfg.sharing.probe_predictor,
        predictor_accuracy: cfg.sharing.predictor_accuracy,
        fill_local: cfg.sharing.fill_local_on_remote_hit,
        rng: Pcg32::new(cfg.seed ^ 0x5EAF_00D, 17),
        probe_bytes: 8,
    })
}

#[derive(Debug)]
pub struct RemotePolicy {
    predictor: bool,
    predictor_accuracy: f64,
    fill_local: bool,
    rng: Pcg32,
    /// Probe metadata payload (request header) in bytes.
    probe_bytes: usize,
}

impl RemotePolicy {
    /// Find a clean remote holder with all requested sectors.
    fn find_holder(&self, p: &PipelineCtx, txn: &MemTxn) -> Option<usize> {
        for peer in p.map.peers(txn.req.core as usize) {
            if let Probe::Hit { dirty: false, .. } =
                p.cores[peer].cache.peek(txn.req.line, txn.req.sectors)
            {
                return Some(peer);
            }
        }
        None
    }

    /// Does any remote cache hold the line dirty (forcing L2 fallback)?
    fn dirty_holder_exists(&self, p: &PipelineCtx, txn: &MemTxn) -> bool {
        p.map.peers(txn.req.core as usize).any(|peer| {
            matches!(
                p.cores[peer].cache.peek(txn.req.line, txn.req.sectors),
                Probe::Hit { dirty: true, .. }
            )
        })
    }

    /// Miss dispatch (remote-sharing never narrows to missing sectors —
    /// the probe path already classified the access as a full miss).  The
    /// L1 stage ends when the miss finally dispatches to L2 — for
    /// remote-sharing that is *after* the probe round trip, the
    /// critical-path penalty of Fig 2.
    fn miss_to_l2(&self, p: &mut PipelineCtx, txn: &mut MemTxn, start: u64, mem: &mut MemSystem) {
        p.stats.misses += 1;
        let core = txn.req.core as usize;
        let sectors = txn.req.sectors;
        p.miss_to_l2(core, txn, sectors, start, mem, RetPath::Local);
    }
}

impl SharingPolicy for RemotePolicy {
    fn kind(&self) -> L1ArchKind {
        L1ArchKind::RemoteSharing
    }

    fn resources(&self) -> FabricNeeds {
        FabricNeeds {
            ring: true,
            ..FabricNeeds::default()
        }
    }

    fn access(&mut self, p: &mut PipelineCtx, txn: &mut MemTxn, mem: &mut MemSystem) {
        let now = txn.now();
        if txn.req.is_write() {
            p.store_local(txn, now, mem);
            return;
        }

        let core = txn.req.core as usize;
        let cluster = p.map.cluster_of(core);
        let my_stop = p.map.index_in_cluster(core);

        // Local tag lookup first (same as private).
        let t_tag;
        match p.cores[core].cache.tags.lookup(txn.req.line, txn.req.sectors) {
            Probe::Hit { .. } => {
                if p.merge_or_defer(core, txn, now, RetPath::Local) {
                    return;
                }
                p.stats.local_hits += 1;
                let done = p.hit_data_access(core, txn, now);
                txn.serve(done);
                return;
            }
            _ => {
                // In-flight merge check before probing.
                if p.merge_or_defer(core, txn, now, RetPath::Local) {
                    return;
                }
                // The local tag probe costs one bank cycle.
                t_tag = p.miss_tag_probe(core, txn, now);
            }
        }

        let holder = self.find_holder(p, txn);
        let dirty_remote = holder.is_none() && self.dirty_holder_exists(p, txn);
        if dirty_remote {
            p.stats.dirty_remote_fallbacks += 1;
        }

        // PACT'19 predictor: on a true global miss, skip the probe round
        // trip with probability `predictor_accuracy`.
        if self.predictor && holder.is_none() && self.rng.chance(self.predictor_accuracy) {
            // Straight to L2 — the predictor saved the probe.
            self.miss_to_l2(p, txn, t_tag, mem);
            return;
        }

        // Probe the ring: metadata visits every peer (the CCN push).
        p.stats.probes_sent += 1;
        let ring = &mut p.rings[cluster];
        let uncontended = (p.map.cores_per_cluster - 1) as u64
            * (ring.ser_cycles(self.probe_bytes) as u64 + 1);
        let probe = ring.broadcast(my_stop, t_tag, self.probe_bytes);
        let probe_done = probe.grant;
        p.stats.sharing_net_cycles += probe_done.saturating_sub(t_tag + uncontended);
        txn.charge(&mut p.con, ResourceClass::ClusterXbar, probe.queued);

        // Remote caches process the probe: one cycle on the probed line's
        // bank at every peer (the extra tag-resource cost of probing).
        // The occupancy is what matters — the probe itself does not wait
        // for the peer banks, so its own grant delay is *not* charged to
        // the breakdown (the delayed peer accesses charge theirs).
        // `ClusterMap` is `Copy`, so iterating a copy keeps the per-
        // request path allocation-free (no collected peer list).
        let bank = decode::l1_bank(txn.req.line, p.timing.banks);
        let map = p.map;
        for peer in map.peers(core) {
            // lint: allow(grant-discipline) — occupancy-only: the delay is charged by the delayed peer accesses, not the prober (see above)
            p.cores[peer].banks.reserve(bank, probe_done, 1);
        }

        match holder {
            Some(peer) => {
                p.stats.remote_hits += 1;
                // Remote data array access, then data rides the ring back.
                let peer_stop = p.map.index_in_cluster(peer);
                // If the holder's fill is still in flight, data waits for
                // it (historically without a bank-conflict tally — see
                // `remote_data_access`).
                let data_start = p.remote_data_access(peer, txn, probe_done, false, false);
                let bytes = txn.req.sector_count() as usize * p.timing.sector_bytes + 8;
                let back = p.rings[cluster].send(peer_stop, my_stop, data_start, bytes);
                txn.charge(&mut p.con, ResourceClass::ClusterXbar, back.queued);
                let arrive = back.grant;
                if self.fill_local {
                    let usable = p.install_fill(core, txn, txn.req.sectors, arrive, mem);
                    txn.complete(usable + 1, arrive);
                } else {
                    txn.serve(arrive + 1);
                }
            }
            None => {
                // All remote caches missed: the probe round trip has
                // already delayed us (the paper's critical-path complaint)
                // — only now does the request go to L2.
                let t_miss_known = probe_done
                    + (p.map.cores_per_cluster - 1) as u64
                        * p.rings[cluster].ser_cycles(self.probe_bytes) as u64;
                self.miss_to_l2(p, txn, t_miss_known, mem);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::l1arch::{access_once, build, L1Arch};
    use crate::mem::{AccessKind, LineAddr, MemRequest};

    fn setup(predictor: bool) -> (Box<dyn L1Arch>, MemSystem) {
        let mut cfg = GpuConfig::tiny(L1ArchKind::RemoteSharing);
        cfg.sharing.probe_predictor = predictor;
        cfg.sharing.predictor_accuracy = 1.0;
        (build(&cfg), MemSystem::new(&cfg))
    }

    fn load(id: u64, core: u32, line: LineAddr) -> MemRequest {
        MemRequest {
            id,
            core,
            warp: 0,
            inst: id,
            line,
            sectors: 0b1111,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    #[test]
    fn remote_hit_avoids_l2() {
        let (mut r, mut mem) = setup(false);
        // Core 0 warms line 42.
        let d = access_once(r.as_mut(), &load(1, 0, 42), 0, &mut mem).done();
        let l2_before = mem.stats.accesses;
        // Core 1 (same cluster of 4 in tiny cfg) reads it: remote hit.
        let t = d + 100;
        let d2 = access_once(r.as_mut(), &load(2, 1, 42), t, &mut mem).done();
        assert_eq!(r.stats().remote_hits, 1);
        assert_eq!(mem.stats.accesses, l2_before, "no L2 traffic on remote hit");
        assert!(d2 > t, "remote hit still costs ring + remote array time");
    }

    #[test]
    fn global_miss_pays_probe_before_l2() {
        let (mut r, mut mem) = setup(false);
        let d_remote = access_once(r.as_mut(), &load(1, 0, 42), 0, &mut mem).done();
        // Compare with a private cache's miss time for the same access.
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let mut p = build(&cfg);
        let mut mem2 = MemSystem::new(&cfg);
        let d_private = access_once(p.as_mut(), &load(1, 0, 42), 0, &mut mem2).done();
        assert!(
            d_remote > d_private,
            "probe round trip must lengthen the L2 critical path ({d_remote} vs {d_private})"
        );
        assert_eq!(r.stats().probes_sent, 1);
    }

    #[test]
    fn predictor_skips_probe_on_global_miss() {
        let (mut r, mut mem) = setup(true);
        access_once(r.as_mut(), &load(1, 0, 42), 0, &mut mem);
        assert_eq!(r.stats().probes_sent, 0, "predictor (accuracy=1.0) skips probe");
        assert_eq!(r.stats().misses, 1);
    }

    #[test]
    fn different_clusters_do_not_share() {
        let (mut r, mut mem) = setup(false);
        // tiny cfg: 8 cores, 2 clusters → cores 0..4 and 4..8.
        let d = access_once(r.as_mut(), &load(1, 0, 42), 0, &mut mem).done();
        let t = d + 100;
        access_once(r.as_mut(), &load(2, 4, 42), t, &mut mem);
        assert_eq!(r.stats().remote_hits, 0, "cross-cluster probes don't happen");
        assert_eq!(r.stats().misses, 2);
    }

    #[test]
    fn dirty_remote_copy_forces_l2() {
        let (mut r, mut mem) = setup(false);
        // Core 0 writes line 42 (write-back-local → dirty in core 0).
        let mut w = load(1, 0, 42);
        w.kind = AccessKind::Store;
        access_once(r.as_mut(), &w, 0, &mut mem);
        // Core 1 reads it: remote copy is dirty → L2 fallback.
        let d = access_once(r.as_mut(), &load(2, 1, 42), 1000, &mut mem).done();
        assert_eq!(r.stats().dirty_remote_fallbacks, 1);
        assert_eq!(r.stats().remote_hits, 0);
        assert_eq!(r.stats().misses, 1);
        assert!(d > 1000);
    }

    #[test]
    fn local_hit_after_remote_fill() {
        let (mut r, mut mem) = setup(false);
        let d1 = access_once(r.as_mut(), &load(1, 0, 42), 0, &mut mem).done();
        let d2 = access_once(r.as_mut(), &load(2, 1, 42), d1 + 100, &mut mem).done();
        // Core 1 filled locally; a re-read is now a local hit.
        let t = d2 + 100;
        let d3 = access_once(r.as_mut(), &load(3, 1, 42), t, &mut mem).done() - t;
        assert_eq!(r.stats().local_hits, 1);
        assert!(d3 <= 40, "local hit fast path after fill: {d3}");
    }
}
