//! Remote-sharing L1 (Dublish et al. TACO'16 cooperative caching; Ibrahim
//! et al. PACT'19 prediction) — baseline #2.
//!
//! Caches stay private and map the whole address space, but a miss first
//! probes the other cluster caches over a ring before going to L2 (Fig 2
//! of the paper).  The pathologies the paper calls out are modeled
//! directly:
//!
//! * the L2 access waits for the probe round trip even when every remote
//!   cache misses (longer L2 critical path),
//! * probes occupy ring links *and* remote tag ports (NoC + tag resource
//!   contention),
//! * remote data returns serialize on ring links.
//!
//! With `sharing.probe_predictor = true`, a PACT'19-style presence
//! predictor skips the probe round trip on (a configurable fraction of)
//! true global misses.

use crate::cache::Probe;
use crate::config::{GpuConfig, L1ArchKind};
use crate::l2::MemSystem;
use crate::mem::{decode, LineAddr, MemRequest};
use crate::noc::Ring;
use crate::stats::{ContentionStats, L1Stats, ResourceClass};
use crate::util::rng::Pcg32;

use super::common::{handle_store, install_fill, mshr_dispatch, CoreL1, L1Timing};
use super::{AccessResult, ClusterMap, L1Arch};

#[derive(Debug)]
pub struct RemoteSharingL1 {
    cores: Vec<CoreL1>,
    rings: Vec<Ring>, // one per cluster
    map: ClusterMap,
    timing: L1Timing,
    stats: L1Stats,
    con: ContentionStats,
    predictor: bool,
    predictor_accuracy: f64,
    fill_local: bool,
    rng: Pcg32,
    /// Probe metadata payload (request header) in bytes.
    probe_bytes: usize,
}

impl RemoteSharingL1 {
    pub fn new(cfg: &GpuConfig) -> Self {
        RemoteSharingL1 {
            cores: (0..cfg.cores).map(|_| CoreL1::new(cfg)).collect(),
            rings: (0..cfg.clusters)
                .map(|_| {
                    Ring::new(
                        cfg.cores_per_cluster(),
                        cfg.sharing.ring_hop_latency,
                        cfg.sharing.ring_width_bytes,
                    )
                })
                .collect(),
            map: ClusterMap::new(cfg),
            timing: L1Timing::new(cfg),
            stats: L1Stats::default(),
            con: ContentionStats::new(cfg.cores),
            predictor: cfg.sharing.probe_predictor,
            predictor_accuracy: cfg.sharing.predictor_accuracy,
            fill_local: cfg.sharing.fill_local_on_remote_hit,
            rng: Pcg32::new(cfg.seed ^ 0x5EAF_00D, 17),
            probe_bytes: 8,
        }
    }

    /// Find a clean remote holder with all requested sectors.
    fn find_holder(&self, req: &MemRequest) -> Option<usize> {
        for peer in self.map.peers(req.core as usize) {
            match self.cores[peer].cache.peek(req.line, req.sectors) {
                Probe::Hit { dirty: false, .. } => return Some(peer),
                _ => {}
            }
        }
        None
    }

    /// Does any remote cache hold the line dirty (forcing L2 fallback)?
    fn dirty_holder_exists(&self, req: &MemRequest) -> bool {
        self.map.peers(req.core as usize).any(|peer| {
            matches!(
                self.cores[peer].cache.peek(req.line, req.sectors),
                Probe::Hit { dirty: true, .. }
            )
        })
    }
}

impl L1Arch for RemoteSharingL1 {
    fn access(&mut self, req: &MemRequest, now: u64, mem: &mut MemSystem) -> AccessResult {
        self.stats.accesses += 1;
        if req.is_write() {
            let l1 = &mut self.cores[req.core as usize];
            return handle_store(l1, req, now, &self.timing, mem, &mut self.stats, &mut self.con);
        }

        let core = req.core as usize;
        let cluster = self.map.cluster_of(core);
        let my_stop = self.map.index_in_cluster(core);

        // Local tag lookup first (same as private).
        let bank = decode::l1_bank(req.line, self.timing.banks);
        let t_tag;
        match self.cores[core].cache.tags.lookup(req.line, req.sectors) {
            Probe::Hit { .. } => {
                if let Some(ready) = self.cores[core].in_flight_ready(req.line, now) {
                    self.stats.mshr_merges += 1;
                    return AccessResult::new(
                        ready.max(now) + 1,
                        now + 1 + self.timing.latency as u64,
                    );
                }
                self.stats.local_hits += 1;
                let g = self.cores[core].banks.reserve(bank, now, 1);
                self.stats.bank_conflict_cycles += g.queued;
                self.con.add(core, ResourceClass::L1DataBank, g.queued);
                return AccessResult::served(g.grant + self.timing.latency as u64);
            }
            _ => {
                // In-flight merge check before probing.
                if let Some(ready) = self.cores[core].in_flight_ready(req.line, now) {
                    self.stats.mshr_merges += 1;
                    return AccessResult::new(
                        ready.max(now) + 1,
                        now + 1 + self.timing.latency as u64,
                    );
                }
                // The local tag probe costs one bank cycle.
                let g = self.cores[core].banks.reserve(bank, now, 1);
                self.con.add(core, ResourceClass::L1TagBank, g.queued);
                t_tag = g.grant + 1;
            }
        }

        let holder = self.find_holder(req);
        let dirty_remote = holder.is_none() && self.dirty_holder_exists(req);
        if dirty_remote {
            self.stats.dirty_remote_fallbacks += 1;
        }

        // PACT'19 predictor: on a true global miss, skip the probe round
        // trip with probability `predictor_accuracy`.
        if self.predictor && holder.is_none() && self.rng.chance(self.predictor_accuracy) {
            // Straight to L2 — the predictor saved the probe.
            return self.miss_to_l2(req, t_tag, mem);
        }

        // Probe the ring: metadata visits every peer (the CCN push).
        self.stats.probes_sent += 1;
        let ring = &mut self.rings[cluster];
        let uncontended = (self.map.cores_per_cluster - 1) as u64
            * (ring.ser_cycles(self.probe_bytes) as u64 + 1);
        let probe = ring.broadcast(my_stop, t_tag, self.probe_bytes);
        let probe_done = probe.grant;
        self.stats.sharing_net_cycles += probe_done.saturating_sub(t_tag + uncontended);
        self.con.add(core, ResourceClass::ClusterXbar, probe.queued);

        // Remote caches process the probe: one cycle on the probed line's
        // bank at every peer (the extra tag-resource cost of probing).
        // The occupancy is what matters — the probe itself does not wait
        // for the peer banks, so its own grant delay is *not* charged to
        // the breakdown (the delayed peer accesses charge theirs).
        let peer_ids: Vec<usize> = self.map.peers(core).collect();
        for peer in peer_ids {
            self.cores[peer].banks.reserve(bank, probe_done, 1);
        }

        match holder {
            Some(peer) => {
                self.stats.remote_hits += 1;
                // Remote data array access, then data rides the ring back.
                let bank = decode::l1_bank(req.line, self.timing.banks);
                let peer_stop = self.map.index_in_cluster(peer);
                // If the holder's fill is still in flight, data waits for it.
                let avail = self
                    .cores[peer]
                    .in_flight_ready(req.line, probe_done)
                    .unwrap_or(probe_done);
                let g = self.cores[peer].banks.reserve(bank, avail, 1);
                self.con.add(core, ResourceClass::L1DataBank, g.queued);
                let data_start = g.grant + self.timing.latency as u64;
                let bytes = req.sector_count() as usize * self.timing.sector_bytes + 8;
                let back = self.rings[cluster].send(peer_stop, my_stop, data_start, bytes);
                self.con.add(core, ResourceClass::ClusterXbar, back.queued);
                let arrive = back.grant;
                if self.fill_local {
                    let usable = install_fill(
                        &mut self.cores[core],
                        req.core,
                        req.core,
                        req.line,
                        req.sectors,
                        arrive,
                        &self.timing,
                        mem,
                        &mut self.stats,
                    );
                    AccessResult::new(usable + 1, arrive)
                } else {
                    AccessResult::served(arrive + 1)
                }
            }
            None => {
                // All remote caches missed: the probe round trip has already
                // delayed us (the paper's critical-path complaint) — only
                // now does the request go to L2.
                let t_miss_known = probe_done
                    + (self.map.cores_per_cluster - 1) as u64
                        * self.rings[cluster].ser_cycles(self.probe_bytes) as u64;
                self.miss_to_l2(req, t_miss_known, mem)
            }
        }
    }

    fn stats(&self) -> &L1Stats {
        &self.stats
    }

    fn contention(&self) -> &ContentionStats {
        &self.con
    }

    fn kind(&self) -> L1ArchKind {
        L1ArchKind::RemoteSharing
    }

    fn resident_lines(&self, core: usize) -> Vec<LineAddr> {
        self.cores[core].cache.tags.resident_lines()
    }

    fn sweep(&mut self, now: u64) {
        for c in &mut self.cores {
            c.sweep(now);
        }
    }
}

impl RemoteSharingL1 {
    fn miss_to_l2(&mut self, req: &MemRequest, start: u64, mem: &mut MemSystem) -> AccessResult {
        self.stats.misses += 1;
        let l1 = &mut self.cores[req.core as usize];
        let s = mshr_dispatch(l1, req.core, start, &mut self.stats, &mut self.con);
        let fill = mem.fetch(req, s);
        l1.mshr.occupy_until(s, fill);
        let usable = install_fill(
            &mut self.cores[req.core as usize],
            req.core,
            req.core,
            req.line,
            req.sectors,
            fill,
            &self.timing,
            mem,
            &mut self.stats,
        );
        // The L1 stage ends when the miss finally dispatches to L2 — for
        // remote-sharing that is *after* the probe round trip, the
        // critical-path penalty of Fig 2 — plus the pipeline depth.
        AccessResult::new(usable + 1, s + self.timing.latency as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::AccessKind;

    fn setup(predictor: bool) -> (RemoteSharingL1, MemSystem) {
        let mut cfg = GpuConfig::tiny(L1ArchKind::RemoteSharing);
        cfg.sharing.probe_predictor = predictor;
        cfg.sharing.predictor_accuracy = 1.0;
        (RemoteSharingL1::new(&cfg), MemSystem::new(&cfg))
    }

    fn load(id: u64, core: u32, line: LineAddr) -> MemRequest {
        MemRequest {
            id,
            core,
            warp: 0,
            inst: id,
            line,
            sectors: 0b1111,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    #[test]
    fn remote_hit_avoids_l2() {
        let (mut r, mut mem) = setup(false);
        // Core 0 warms line 42.
        let d = r.access(&load(1, 0, 42), 0, &mut mem).done;
        let l2_before = mem.stats.accesses;
        // Core 1 (same cluster of 4 in tiny cfg) reads it: remote hit.
        let t = d + 100;
        let d2 = r.access(&load(2, 1, 42), t, &mut mem).done;
        assert_eq!(r.stats.remote_hits, 1);
        assert_eq!(mem.stats.accesses, l2_before, "no L2 traffic on remote hit");
        assert!(d2 > t, "remote hit still costs ring + remote array time");
    }

    #[test]
    fn global_miss_pays_probe_before_l2() {
        let (mut r, mut mem) = setup(false);
        let d_remote = r.access(&load(1, 0, 42), 0, &mut mem).done;
        // Compare with a private cache's miss time for the same access.
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let mut p = super::super::private::PrivateL1::new(&cfg);
        let mut mem2 = MemSystem::new(&cfg);
        let d_private = p.access(&load(1, 0, 42), 0, &mut mem2).done;
        assert!(
            d_remote > d_private,
            "probe round trip must lengthen the L2 critical path ({d_remote} vs {d_private})"
        );
        assert_eq!(r.stats.probes_sent, 1);
    }

    #[test]
    fn predictor_skips_probe_on_global_miss() {
        let (mut r, mut mem) = setup(true);
        r.access(&load(1, 0, 42), 0, &mut mem);
        assert_eq!(r.stats.probes_sent, 0, "predictor (accuracy=1.0) skips probe");
        assert_eq!(r.stats.misses, 1);
    }

    #[test]
    fn different_clusters_do_not_share() {
        let (mut r, mut mem) = setup(false);
        // tiny cfg: 8 cores, 2 clusters → cores 0..4 and 4..8.
        let d = r.access(&load(1, 0, 42), 0, &mut mem).done;
        let t = d + 100;
        r.access(&load(2, 4, 42), t, &mut mem);
        assert_eq!(r.stats.remote_hits, 0, "cross-cluster probes don't happen");
        assert_eq!(r.stats.misses, 2);
    }

    #[test]
    fn dirty_remote_copy_forces_l2() {
        let (mut r, mut mem) = setup(false);
        // Core 0 writes line 42 (write-back-local → dirty in core 0).
        let mut w = load(1, 0, 42);
        w.kind = AccessKind::Store;
        r.access(&w, 0, &mut mem);
        // Core 1 reads it: remote copy is dirty → L2 fallback.
        let d = r.access(&load(2, 1, 42), 1000, &mut mem).done;
        assert_eq!(r.stats.dirty_remote_fallbacks, 1);
        assert_eq!(r.stats.remote_hits, 0);
        assert_eq!(r.stats.misses, 1);
        assert!(d > 1000);
    }

    #[test]
    fn local_hit_after_remote_fill() {
        let (mut r, mut mem) = setup(false);
        let d1 = r.access(&load(1, 0, 42), 0, &mut mem).done;
        let d2 = r.access(&load(2, 1, 42), d1 + 100, &mut mem).done;
        // Core 1 filled locally; a re-read is now a local hit.
        let t = d2 + 100;
        let d3 = r.access(&load(3, 1, 42), t, &mut mem).done - t;
        assert_eq!(r.stats.local_hits, 1);
        assert!(d3 <= 40, "local hit fast path after fill: {d3}");
    }
}
