//! The shared L1 request pipeline.
//!
//! Every organization used to re-implement the same mechanisms — tag
//! probe, bank reservation, MSHR dispatch, fill installation, victim
//! writeback, fabric crossings — threading ~10 loose parameters through
//! free functions.  This module owns those mechanisms once, keyed off the
//! [`MemTxn`] transaction, and delegates only the *decisions* (where to
//! probe, where to fill, whether to bypass a contended peer) to a
//! [`SharingPolicy`].  Adding an organization is now a policy module plus
//! a registry entry — see `ata_bypass` for the proof.

use crate::cache::{Eviction, Probe};
use crate::config::{GpuConfig, L1ArchKind, WritePolicy};
use crate::l2::MemSystem;
use crate::mem::{decode, Deferred, LineAddr, MemTxn, RetPath, SectorMask};
use crate::noc::{Ring, XbarReservation};
use crate::stats::{ContentionStats, L1Stats, ResidencyStats, ResourceClass};

use super::ata_tag::AggregatedTagArray;
use super::common::{CoreL1, L1Timing};
use super::residency::ResidencyIndex;
use super::{ClusterMap, L1Arch};

/// Cluster-level resources a policy needs the pipeline to provision.
#[derive(Debug, Clone, Copy, Default)]
pub struct FabricNeeds {
    /// Intra-cluster data crossbars (decoupled, ATA variants).
    pub xbar: bool,
    /// Probe/data rings (remote-sharing).
    pub ring: bool,
    /// Aggregated tag arrays (ATA variants).
    pub aggregated_tags: bool,
}

/// The per-organization request distributor: decides how one transaction
/// routes through the shared pipeline (where to probe, where to fill, who
/// pays queueing — the paper's design space as a trait).
///
/// Policies receive the full [`PipelineCtx`] so they can compose its
/// mechanism steps and, where an organization is genuinely idiosyncratic,
/// touch the resources directly.  They must uphold the [`L1Arch`]
/// contract (determinism, monotone counters, one outcome class per
/// access) and must [`complete`](MemTxn::complete) every transaction —
/// or, inside a phased epoch, defer it (`txn.deferred`) for the B3
/// finish pass.
pub trait SharingPolicy: std::fmt::Debug + Send {
    /// Which organization this policy implements (matches the registry).
    fn kind(&self) -> L1ArchKind;

    /// Cluster resources the pipeline must build for this policy.
    fn resources(&self) -> FabricNeeds {
        FabricNeeds::default()
    }

    /// Drive one transaction through the pipeline.
    fn access(&mut self, p: &mut PipelineCtx, txn: &mut MemTxn, mem: &mut MemSystem);
}

/// The shared machinery every policy composes: per-core caches, cluster
/// fabrics, timing, and the statistics ledgers.  Methods are the
/// pipeline's mechanism steps; each preserves the exact reservation and
/// accounting order of the pre-refactor organizations (pinned by the
/// golden-equivalence fixtures in `rust/tests/`).
#[derive(Debug)]
pub struct PipelineCtx {
    pub cores: Vec<CoreL1>,
    /// One aggregated tag array per cluster (empty unless requested).
    pub tags: Vec<AggregatedTagArray>,
    /// One probe/data ring per cluster (empty unless requested).
    pub rings: Vec<Ring>,
    /// One data crossbar per cluster (empty unless requested).
    pub xbars: Vec<XbarReservation>,
    /// One residency index per cluster (empty unless the policy uses
    /// aggregated tags AND `sharing.residency_index` is on).  Kept
    /// coherent by the `*_tags` mutation helpers — see the
    /// mutation-point invariant in [`super::residency`].
    pub residency: Vec<ResidencyIndex>,
    /// Whether `residency` is live (probes take the O(1) fast path).
    use_residency: bool,
    /// Index telemetry (never part of result JSON — see
    /// [`ResidencyStats`]).
    res_stats: ResidencyStats,
    pub map: ClusterMap,
    pub timing: L1Timing,
    pub xbar_latency: u32,
    pub stats: L1Stats,
    pub con: ContentionStats,
}

impl PipelineCtx {
    pub fn new(cfg: &GpuConfig, needs: FabricNeeds) -> Self {
        let cpc = cfg.cores_per_cluster();
        let use_residency = needs.aggregated_tags && cfg.sharing.residency_index;
        PipelineCtx {
            cores: (0..cfg.cores).map(|_| CoreL1::new(cfg)).collect(),
            residency: if use_residency {
                (0..cfg.clusters).map(|_| ResidencyIndex::new()).collect()
            } else {
                Vec::new()
            },
            use_residency,
            res_stats: ResidencyStats::default(),
            tags: if needs.aggregated_tags {
                (0..cfg.clusters)
                    .map(|_| {
                        AggregatedTagArray::new(
                            cfg.sharing.ata_comparator_groups,
                            cfg.sharing.ata_tag_latency,
                        )
                    })
                    .collect()
            } else {
                Vec::new()
            },
            rings: if needs.ring {
                (0..cfg.clusters)
                    .map(|_| {
                        Ring::new(
                            cpc,
                            cfg.sharing.ring_hop_latency,
                            cfg.sharing.ring_width_bytes,
                        )
                    })
                    .collect()
            } else {
                Vec::new()
            },
            xbars: if needs.xbar {
                (0..cfg.clusters)
                    .map(|_| {
                        XbarReservation::new(
                            cpc,
                            cpc,
                            cfg.sharing.cluster_xbar_latency,
                            cfg.noc.in_buffer_flits as u64,
                        )
                    })
                    .collect()
            } else {
                Vec::new()
            },
            map: ClusterMap::new(cfg),
            timing: L1Timing::new(cfg),
            xbar_latency: cfg.sharing.cluster_xbar_latency,
            stats: L1Stats::default(),
            con: ContentionStats::new(cfg.cores),
        }
    }

    // -- tag mutation helpers ------------------------------------------------
    //
    // Every change to a cluster cache's tag state MUST go through these
    // three helpers so the residency index stays coherent (the
    // mutation-point invariant of `l1arch::residency`).  LRU-only
    // operations (`lookup`, `touch`) are exempt: they never change
    // validity or dirtiness.

    /// Install (or extend) `line` at `owner`'s cache and mirror the
    /// mutation — eviction included, clean victims too — into the
    /// cluster's residency index.  Returns the eviction, if any; the
    /// caller decides whether it generates write-back traffic
    /// ([`Eviction::needs_writeback`]).
    pub fn fill_tags(
        &mut self,
        owner: usize,
        line: LineAddr,
        sectors: SectorMask,
    ) -> Option<Eviction> {
        let (_, evicted) = self.cores[owner].cache.fill(line, sectors);
        if self.use_residency {
            let idx = self.map.index_in_cluster(owner);
            let r = &mut self.residency[self.map.cluster_of(owner)];
            if let Some(ev) = evicted {
                r.record_evict(idx, ev.line);
                self.res_stats.index_ops += 1;
            }
            r.record_fill(idx, line, sectors);
            self.res_stats.index_ops += 1;
        }
        evicted
    }

    /// Mark `sectors` of `line` dirty at `owner` (and in the residency
    /// index).  Returns whether the line was present, like
    /// `TagArray::mark_dirty`.
    pub fn mark_dirty_tags(&mut self, owner: usize, line: LineAddr, sectors: SectorMask) -> bool {
        let present = self.cores[owner].cache.tags.mark_dirty(line, sectors);
        if present && sectors != 0 && self.use_residency {
            let idx = self.map.index_in_cluster(owner);
            self.residency[self.map.cluster_of(owner)].record_mark_dirty(idx, line, sectors);
            self.res_stats.index_ops += 1;
        }
        present
    }

    /// Invalidate `line` at `owner` (coherence probes and tests).
    pub fn invalidate_tags(&mut self, owner: usize, line: LineAddr) -> bool {
        let removed = self.cores[owner].cache.tags.invalidate(line);
        if removed && self.use_residency {
            let idx = self.map.index_in_cluster(owner);
            self.residency[self.map.cluster_of(owner)].record_evict(idx, line);
            self.res_stats.index_ops += 1;
        }
        removed
    }

    /// Index telemetry with the occupancy gauges filled in (the counter
    /// half accumulates in `res_stats`; occupancy is read off the
    /// per-cluster indexes on demand).
    pub fn residency_stats(&self) -> ResidencyStats {
        let mut s = self.res_stats;
        s.index_lines = self.residency.iter().map(|r| r.lines() as u64).sum();
        s.peak_lines = self.residency.iter().map(|r| r.peak_lines() as u64).sum();
        s
    }

    // -- mechanism steps -----------------------------------------------------

    /// Merge onto an in-flight fetch of the transaction's line at cache
    /// `c`, if one is pending at `t`.  Returns the `(done, l1_stage)`
    /// pair of the merged access (tags were installed when the miss was
    /// *scheduled*; a pending fill means this is a merge, not a hit).
    pub fn try_merge(&mut self, c: usize, line: LineAddr, t: u64) -> Option<(u64, u64)> {
        let ready = self.cores[c].in_flight_ready(line, t)?;
        self.stats.mshr_merges += 1;
        Some((ready.max(t) + 1, t + 1 + self.timing.latency as u64))
    }

    /// Data-array access for a hit at cache `c` starting at `t`: one
    /// (line-wide) bank operation; same-bank same-cycle accesses
    /// serialize — the paper's bank-conflict mechanism.  Returns the
    /// data-ready cycle.
    pub fn hit_data_access(&mut self, c: usize, txn: &mut MemTxn, t: u64) -> u64 {
        let bank = decode::l1_bank(txn.req.line, self.timing.banks);
        let g = self.cores[c].banks.reserve(bank, t, 1);
        self.stats.bank_conflict_cycles += g.queued;
        txn.charge(&mut self.con, ResourceClass::L1DataBank, g.queued);
        g.grant + self.timing.latency as u64
    }

    /// The tag probe a miss pays at cache `c`: one bank cycle, charged to
    /// the tag class.  Returns `t_tag` (probe outcome known) and stamps
    /// the transaction's tag hop.
    pub fn miss_tag_probe(&mut self, c: usize, txn: &mut MemTxn, now: u64) -> u64 {
        let bank = decode::l1_bank(txn.req.line, self.timing.banks);
        let g = self.cores[c].banks.reserve(bank, now, 1);
        txn.charge(&mut self.con, ResourceClass::L1TagBank, g.queued);
        let t_tag = g.grant + 1;
        txn.hops.tag_done = t_tag;
        t_tag
    }

    /// Classify a non-hit probe into the miss outcome classes, returning
    /// the sectors an L2 fetch must bring in (sector cache: fetch only
    /// what is missing — Table II 32 B sector fills).
    pub fn classify_miss(&mut self, probe: Probe, req_sectors: SectorMask) -> SectorMask {
        match probe {
            Probe::SectorMiss { missing, .. } => {
                self.stats.sector_misses += 1;
                missing
            }
            _ => {
                self.stats.misses += 1;
                req_sectors
            }
        }
    }

    /// Dispatch gate of a miss through cache `owner`'s finite MSHR pool:
    /// a full pool stalls dispatch until an entry frees, the stall lands
    /// in [`ResourceClass::MshrFull`], and the request counts as a
    /// structural-hazard reject.  Every miss path goes through this gate,
    /// so a full pool delays dispatch identically everywhere.
    pub fn mshr_dispatch(&mut self, owner: usize, txn: &mut MemTxn, t_ready: u64) -> u64 {
        let start = self.cores[owner].mshr.earliest(t_ready);
        let stall = start - t_ready;
        if stall > 0 {
            self.stats.rejects += 1;
            txn.charge(&mut self.con, ResourceClass::MshrFull, stall);
        }
        start
    }

    /// Install a fill into cache `owner` at `fill_cycle`: updates tags,
    /// forwards a dirty victim to L2 through `owner`'s NoC port (charged
    /// to the transaction's `attr_core` — the requester whose fill caused
    /// the eviction), records the in-flight entry.  Returns the cycle the
    /// fill is usable.
    ///
    /// Fills use a dedicated write port rather than the read banks: a
    /// fill's timestamp lies in the future relative to the requests
    /// currently being scheduled, and a read bank's reservation timeline
    /// must only be fed in (near-)monotone time order (see
    /// `resource::Server`).  Read/probe contention — the conflict
    /// mechanism the paper studies — is unaffected.
    pub fn install_fill(
        &mut self,
        owner: usize,
        txn: &MemTxn,
        sectors: SectorMask,
        fill_cycle: u64,
        mem: &mut MemSystem,
    ) -> u64 {
        let evicted = self.fill_tags(owner, txn.req.line, sectors);
        self.stats.fills += 1;
        if let Some(ev) = evicted {
            // Only dirty victims generate L2 write traffic; clean victims
            // are dropped silently (every victim is *reported* so the
            // residency index stays coherent).  (No policy check here:
            // decoupled-sharing's home slices hold the only copy and mark
            // it dirty regardless of the configured L1 policy.)
            if ev.needs_writeback() {
                mem.write_for(
                    owner,
                    ev.line,
                    ev.dirty_sectors.count_ones(),
                    fill_cycle,
                    txn.attr_core as usize,
                );
            }
        }
        self.cores[owner].in_flight.insert(txn.req.line, fill_cycle);
        fill_cycle
    }

    /// Close a transaction whose data is ready at `data_ready` with L1
    /// stage `stage`, routing the data home per `ret`: directly
    /// ([`RetPath::Local`]) or back across the cluster crossbar first
    /// (decoupled-sharing home-slice accesses).
    pub fn complete_ret(&mut self, txn: &mut MemTxn, data_ready: u64, stage: u64, ret: RetPath) {
        match ret {
            RetPath::Local => txn.complete(data_ready, stage),
            RetPath::Xbar {
                cluster,
                from_idx,
                to_idx,
            } => {
                let flits = self.timing.data_flits(txn.req.sector_count());
                let back = self.xbar_route(cluster, from_idx, to_idx, data_ready, flits, txn);
                // A stage equal to the data-ready cycle means the access
                // was served entirely by the L1 stage — the back-crossing
                // is still part of it.
                let stage_back = if stage == data_ready { back } else { stage };
                txn.complete(back, stage_back);
            }
        }
    }

    /// Merge onto an in-flight *or same-epoch deferred* fetch of the
    /// transaction's line at cache `c`.  Returns whether the access was
    /// disposed of: completed via `ret` for a concrete in-flight fill, or
    /// parked as [`Deferred::Merge`] when the fill cycle is only known
    /// after the phased walk (B3 resolves it in canonical order).
    pub fn merge_or_defer(&mut self, c: usize, txn: &mut MemTxn, t: u64, ret: RetPath) -> bool {
        if let Some((d, s)) = self.try_merge(c, txn.req.line, t) {
            self.complete_ret(txn, d, s, ret);
            return true;
        }
        if self.cores[c].pending.contains_key(&txn.req.line) {
            self.stats.mshr_merges += 1;
            txn.deferred = Some(Deferred::Merge { owner: c, t, ret });
            return true;
        }
        false
    }

    /// The classic miss walk: MSHR gate at `owner` → fetch below L1
    /// (`owner` is the NoC endpoint) → fill installed at `owner` → data
    /// routed home per `ret`.  The stage ends one pipeline depth past the
    /// dispatch point so hit and miss stages compare.
    ///
    /// Inside a phased epoch this is the B1 half only: the fetch
    /// descriptor is dispatched and the tags installed now, and the
    /// fill-timing half (MSHR occupancy, victim writeback, in-flight
    /// entry, completion) runs in [`finish_deferred`](Self::finish_deferred)
    /// once the walk has produced the fill cycle.
    pub fn miss_to_l2(
        &mut self,
        owner: usize,
        txn: &mut MemTxn,
        sectors: SectorMask,
        start: u64,
        mem: &mut MemSystem,
        ret: RetPath,
    ) {
        let s = self.mshr_dispatch(owner, txn, start);
        txn.endpoint = owner as u32;
        txn.fetch_sectors = sectors;
        if mem.phased() {
            let desc = mem.begin_fetch(txn, s);
            let evicted = self.fill_tags(owner, txn.req.line, sectors);
            self.stats.fills += 1;
            let victim = evicted.filter(Eviction::needs_writeback);
            self.cores[owner].pending.insert(txn.req.line, s);
            txn.deferred = Some(Deferred::Fetch {
                owner,
                desc,
                dispatch: s,
                victim,
                ret,
            });
            return;
        }
        let fill = mem.fetch(txn, s);
        // lint: allow(grant-discipline) — occupancy-only: mshr_dispatch already charged the wait via earliest(), queued is 0 at `s`
        self.cores[owner].mshr.occupy_until(s, fill);
        let usable = self.install_fill(owner, txn, sectors, fill, mem);
        self.complete_ret(txn, usable + 1, s + self.timing.latency as u64, ret);
    }

    /// Phase B3 of the phased walk: consume the transaction's deferred
    /// completion in canonical order.  For a fetch, finalize it through
    /// [`MemSystem::finish_fetch`], hold the MSHR entry to the fill,
    /// write back the B1 victim, record the in-flight entry and complete;
    /// for a same-epoch merge, the owner's fetch finished earlier in this
    /// pass, so its in-flight entry carries the ready cycle.
    pub fn finish_deferred(&mut self, txn: &mut MemTxn, mem: &mut MemSystem) {
        let Some(deferred) = txn.deferred.take() else {
            return;
        };
        match deferred {
            Deferred::Fetch {
                owner,
                desc,
                dispatch,
                victim,
                ret,
            } => {
                let fill = mem.finish_fetch(desc, txn);
                // lint: allow(grant-discipline) — occupancy-only: mshr_dispatch already charged the wait via earliest(), queued is 0 at dispatch
                self.cores[owner].mshr.occupy_until(dispatch, fill);
                if let Some(ev) = victim {
                    mem.write_for(
                        owner,
                        ev.line,
                        ev.dirty_sectors.count_ones(),
                        fill,
                        txn.attr_core as usize,
                    );
                }
                self.cores[owner].in_flight.insert(txn.req.line, fill);
                self.cores[owner].pending.remove(&txn.req.line);
                self.complete_ret(txn, fill + 1, dispatch + self.timing.latency as u64, ret);
            }
            Deferred::Merge { owner, t, ret } => {
                let ready = *self.cores[owner]
                    .in_flight
                    .get(&txn.req.line)
                    // lint: allow(sim-panic) — canonical order records the owner's fetch before any merge completes; a miss is a bug, contained at the job boundary
                    .expect("merge owner's fetch finishes earlier in canonical order");
                self.complete_ret(txn, ready.max(t) + 1, t + 1 + self.timing.latency as u64, ret);
            }
        }
    }

    /// The private-cache load path: tag lookup, bank access on a hit,
    /// MSHR + L2 fetch on a miss.  This is the baseline organization's
    /// entire behaviour and the "local cache" half of remote-sharing.
    pub fn local_load(&mut self, txn: &mut MemTxn, mem: &mut MemSystem) {
        let c = txn.req.core as usize;
        let now = txn.now();
        match self.cores[c].cache.tags.lookup(txn.req.line, txn.req.sectors) {
            Probe::Hit { .. } => {
                if self.merge_or_defer(c, txn, now, RetPath::Local) {
                    return;
                }
                self.stats.local_hits += 1;
                let done = self.hit_data_access(c, txn, now);
                txn.serve(done);
            }
            probe => {
                if self.merge_or_defer(c, txn, now, RetPath::Local) {
                    return;
                }
                let t_tag = self.miss_tag_probe(c, txn, now);
                let sectors = self.classify_miss(probe, txn.req.sectors);
                self.miss_to_l2(c, txn, sectors, t_tag, mem, RetPath::Local);
            }
        }
    }

    /// Handle a store according to the configured write policy, entirely
    /// within the request's local cache (§III-C: "for write requests we
    /// only process them in the local cache of the request's source
    /// core").  `t` is the cycle the store reaches the cache (after any
    /// organization front-end, e.g. the ATA tag pipeline).
    pub fn store_local(&mut self, txn: &mut MemTxn, t: u64, mem: &mut MemSystem) {
        self.stats.writes += 1;
        let c = txn.req.core as usize;
        let line = txn.req.line;
        let bank = decode::l1_bank(line, self.timing.banks);
        match self.timing.write_policy {
            WritePolicy::WriteThrough => {
                // Update the line if present, and always send the data to
                // L2.  (mark_dirty(.., 0) only touches LRU — dirty bits
                // stay clear in WT.)
                if self.mark_dirty_tags(c, line, 0) {
                    let g = self.cores[c].banks.reserve(bank, t, 1);
                    self.stats.bank_conflict_cycles += g.queued;
                    txn.charge(&mut self.con, ResourceClass::L1DataBank, g.queued);
                }
                mem.write(c, line, txn.req.sector_count(), t);
                txn.serve(t + 1);
            }
            WritePolicy::WriteBackLocal => {
                let g = self.cores[c].banks.reserve(bank, t, 1);
                self.stats.bank_conflict_cycles += g.queued;
                txn.charge(&mut self.con, ResourceClass::L1DataBank, g.queued);
                // Write-allocate: written sectors become valid + dirty.
                let evicted = self.fill_tags(c, line, txn.req.sectors);
                self.mark_dirty_tags(c, line, txn.req.sectors);
                if let Some(ev) = evicted {
                    if ev.needs_writeback() {
                        mem.write(c, ev.line, ev.dirty_sectors.count_ones(), g.grant);
                    }
                }
                txn.serve(g.grant + 1);
            }
        }
    }

    /// A remote holder's data array serves the transaction arriving at
    /// `arrive` — waiting for the holder's own in-flight fill first, then
    /// one bank operation.  `count_conflict` controls whether the bank
    /// wait also lands in `bank_conflict_cycles` (ATA counts it; the
    /// remote-sharing baseline historically only attributes it);
    /// `touch_lru` performs the use-time LRU update ATA's distributor
    /// does.  Returns the cycle the data leaves the holder's array.
    pub fn remote_data_access(
        &mut self,
        holder: usize,
        txn: &mut MemTxn,
        arrive: u64,
        count_conflict: bool,
        touch_lru: bool,
    ) -> u64 {
        let bank = decode::l1_bank(txn.req.line, self.timing.banks);
        let avail = self.cores[holder]
            .in_flight_ready(txn.req.line, arrive)
            .unwrap_or(arrive);
        let g = self.cores[holder].banks.reserve(bank, avail, 1);
        if count_conflict {
            self.stats.bank_conflict_cycles += g.queued;
        }
        txn.charge(&mut self.con, ResourceClass::L1DataBank, g.queued);
        if touch_lru {
            self.cores[holder].cache.tags.lookup(txn.req.line, txn.req.sectors);
        }
        g.grant + self.timing.latency as u64
    }

    /// Route `flits` over cluster `cluster`'s crossbar from stop `src` to
    /// stop `dst` starting at `now`.  Pure fabric queueing (beyond the
    /// uncontended switch latency + serialization) is counted in
    /// `sharing_net_cycles` and charged to the transaction's core on the
    /// [`ResourceClass::ClusterXbar`] class.  Returns the arrival cycle.
    pub fn xbar_route(
        &mut self,
        cluster: usize,
        src: usize,
        dst: usize,
        now: u64,
        flits: u32,
        txn: &mut MemTxn,
    ) -> u64 {
        let g = self.xbars[cluster].transfer(src, dst, now, flits);
        let uncontended = now + self.xbar_latency as u64 + 2 * flits as u64;
        self.stats.sharing_net_cycles += g.grant.saturating_sub(uncontended);
        txn.charge(&mut self.con, ResourceClass::ClusterXbar, g.queued);
        g.grant
    }

    // -- ATA-family steps (shared by `ata` and `ata-bypass`) -----------------

    /// The aggregated-tag front end (§III-B): reserve a comparator group,
    /// charge arbitration delay, stamp the tag hop.  Returns `t_tag`, the
    /// cycle the hit vector is available.
    pub fn ata_front_end(&mut self, cluster: usize, txn: &mut MemTxn) -> u64 {
        let tag = self.tags[cluster].lookup_timing(txn.now());
        txn.charge(&mut self.con, ResourceClass::AtaComparator, tag.queued);
        txn.hops.tag_done = tag.grant;
        tag.grant
    }

    /// Aggregated-tag-array probe for the transaction (functional part).
    ///
    /// With the residency index on (the default) this is one hash lookup
    /// plus the local peek — O(1) in cluster size and allocation-free.
    /// With it off, the O(cluster) brute-force scan answers instead; the
    /// two are bit-identical (pinned by the differential tests), so only
    /// wall clock differs.
    pub fn ata_probe(&mut self, txn: &MemTxn) -> super::ata_tag::AggregateProbe {
        let core = txn.req.core as usize;
        let cluster = self.map.cluster_of(core);
        let local_idx = self.map.index_in_cluster(core);
        if self.use_residency {
            self.res_stats.index_probes += 1;
            let local = self.cores[core].cache.peek(txn.req.line, txn.req.sectors);
            let (holders, dirty) =
                self.residency[cluster].probe(txn.req.line, txn.req.sectors, local_idx);
            super::ata_tag::AggregateProbe {
                local,
                holders,
                dirty,
            }
        } else {
            self.res_stats.scan_probes += 1;
            let base = cluster * self.map.cores_per_cluster;
            AggregatedTagArray::probe(
                &self.cores[base..base + self.map.cores_per_cluster],
                local_idx,
                txn.req.line,
                txn.req.sectors,
            )
        }
    }

    /// Fig 7(a): serve a clean remote hit over the cluster crossbar —
    /// request header to the holder, holder's data array, data back,
    /// optional local fill.  Completes the transaction.
    pub fn ata_remote_hit(
        &mut self,
        holder_idx: usize,
        t_tag: u64,
        fill_local: bool,
        txn: &mut MemTxn,
        mem: &mut MemSystem,
    ) {
        let core = txn.req.core as usize;
        let cluster = self.map.cluster_of(core);
        let my_idx = self.map.index_in_cluster(core);
        let holder = self.map.global_core(cluster, holder_idx);
        self.stats.remote_hits += 1;
        // Request header crosses to the holder...
        let arrive = self.xbar_route(cluster, my_idx, holder_idx, t_tag, 1, txn);
        // ...the holder's data array serves it (bank contention is the
        // residual sharing cost the paper acknowledges)...
        let data_start = self.remote_data_access(holder, txn, arrive, true, true);
        // ...and the data crosses back.
        let flits = self.timing.data_flits(txn.req.sector_count());
        let back = self.xbar_route(cluster, holder_idx, my_idx, data_start, flits, txn);
        if fill_local {
            let usable = self.install_fill(core, txn, txn.req.sectors, back, mem);
            txn.complete(usable + 1, back);
        } else {
            txn.serve(back + 1);
        }
    }

    /// Fig 7(c): the ATA miss — straight to L2 with no sharing detour
    /// (merge check first: tags may be mid-fill).  The critical path
    /// matches the private cache.  Completes the transaction.
    pub fn ata_miss(
        &mut self,
        txn: &mut MemTxn,
        sectors: SectorMask,
        start: u64,
        mem: &mut MemSystem,
    ) {
        let c = txn.req.core as usize;
        if self.merge_or_defer(c, txn, start, RetPath::Local) {
            return;
        }
        self.miss_to_l2(c, txn, sectors, start, mem, RetPath::Local);
    }
}

/// The single `L1Arch` implementation: shared pipeline machinery plus a
/// boxed policy from the organization registry (`l1arch::build`).
#[derive(Debug)]
pub struct PipelineL1 {
    ctx: PipelineCtx,
    policy: Box<dyn SharingPolicy>,
}

impl PipelineL1 {
    pub fn new(cfg: &GpuConfig, policy: Box<dyn SharingPolicy>) -> Self {
        PipelineL1 {
            ctx: PipelineCtx::new(cfg, policy.resources()),
            policy,
        }
    }

    /// The shared machinery (white-box inspection in tests and tools).
    pub fn ctx(&self) -> &PipelineCtx {
        &self.ctx
    }
}

impl L1Arch for PipelineL1 {
    fn access(&mut self, txn: &mut MemTxn, mem: &mut MemSystem) {
        self.ctx.stats.accesses += 1;
        self.policy.access(&mut self.ctx, txn, mem);
        debug_assert!(
            txn.hops.done >= txn.now() || txn.deferred.is_some(),
            "policy must complete or defer the transaction"
        );
    }

    fn finish(&mut self, txn: &mut MemTxn, mem: &mut MemSystem) {
        self.ctx.finish_deferred(txn, mem);
    }

    fn stats(&self) -> &L1Stats {
        &self.ctx.stats
    }

    fn contention(&self) -> &ContentionStats {
        &self.ctx.con
    }

    fn residency_stats(&self) -> ResidencyStats {
        self.ctx.residency_stats()
    }

    fn kind(&self) -> L1ArchKind {
        self.policy.kind()
    }

    fn resident_lines(&self, core: usize) -> Vec<LineAddr> {
        self.ctx.cores[core].cache.tags.resident_lines()
    }

    fn sweep(&mut self, now: u64) {
        for c in &mut self.ctx.cores {
            c.sweep(now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{AccessKind, MemRequest};

    fn setup() -> (PipelineCtx, MemSystem, GpuConfig) {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        (
            PipelineCtx::new(&cfg, FabricNeeds::default()),
            MemSystem::new(&cfg),
            cfg,
        )
    }

    fn store(line: LineAddr) -> MemRequest {
        MemRequest {
            id: 1,
            core: 0,
            warp: 0,
            inst: 0,
            line,
            sectors: 0b0011,
            kind: AccessKind::Store,
            issue_cycle: 0,
        }
    }

    fn load(id: u64, line: LineAddr) -> MemRequest {
        MemRequest {
            id,
            core: 0,
            warp: 0,
            inst: id,
            line,
            sectors: 0b1111,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    #[test]
    fn install_fill_tracks_in_flight_and_evicts() {
        let (mut p, mut mem, _) = setup();
        let txn = MemTxn::new(load(1, 42), 0);
        let g = p.install_fill(0, &txn, 0b1111, 100, &mut mem);
        assert!(g >= 100);
        assert_eq!(p.stats.fills, 1);
        assert_eq!(p.cores[0].in_flight_ready(42, 50), Some(g));
        assert_eq!(p.cores[0].in_flight_ready(42, g + 1), None, "landed");
        p.cores[0].sweep(g + 1);
        assert!(p.cores[0].in_flight.is_empty());
    }

    #[test]
    fn writeback_local_allocates_and_dirties() {
        let (mut p, mut mem, _) = setup();
        let mut txn = MemTxn::new(store(9), 0);
        p.store_local(&mut txn, 0, &mut mem);
        assert!(p.cores[0].cache.tags.is_dirty(9, 0b0011));
        assert_eq!(mem.stats.writes, 0, "no L2 traffic on local write");
        assert_eq!(p.stats.writes, 1);
        assert!(txn.done() > 0);
    }

    #[test]
    fn writethrough_sends_to_l2() {
        let cfg = {
            let mut c = GpuConfig::tiny(L1ArchKind::Private);
            c.l1.write_policy = WritePolicy::WriteThrough;
            c
        };
        let mut p = PipelineCtx::new(&cfg, FabricNeeds::default());
        let mut mem = MemSystem::new(&cfg);
        let mut txn = MemTxn::new(store(9), 0);
        p.store_local(&mut txn, 0, &mut mem);
        assert_eq!(mem.stats.writes, 1, "write-through reaches L2");
        assert!(!p.cores[0].cache.tags.is_dirty(9, 0b0011));
    }

    #[test]
    fn dirty_eviction_writes_back() {
        let (mut p, mut mem, _) = setup();
        // Dirty a line, then force enough fills into its set to evict it.
        let mut txn = MemTxn::new(store(0), 0);
        p.store_local(&mut txn, 0, &mut mem);
        let sets = p.cores[0].cache.tags.sets() as u64;
        let assoc = p.cores[0].cache.tags.assoc() as u64;
        for k in 1..=assoc {
            let t = MemTxn::new(load(k, k * sets), 0);
            p.install_fill(0, &t, 0b1111, 1000, &mut mem);
        }
        assert!(mem.stats.writes >= 1, "dirty victim written back to L2");
    }

    #[test]
    fn clean_evictions_send_no_l2_writes() {
        // Pin the L2 write count: evicting *clean* lines must generate
        // zero write traffic under write-back-local…
        let (mut p, mut mem, _) = setup();
        let sets = p.cores[0].cache.tags.sets() as u64;
        let assoc = p.cores[0].cache.tags.assoc() as u64;
        for k in 0..assoc * 3 {
            let t = MemTxn::new(load(k, k * sets), 0);
            p.install_fill(0, &t, 0b1111, 1000, &mut mem);
        }
        assert_eq!(mem.stats.writes, 0, "clean victims must not reach L2");

        // …and under write-through the only L2 writes are the stores
        // themselves (lines are never dirty, so evictions add nothing).
        let cfg = {
            let mut c = GpuConfig::tiny(L1ArchKind::Private);
            c.l1.write_policy = WritePolicy::WriteThrough;
            c
        };
        let mut p = PipelineCtx::new(&cfg, FabricNeeds::default());
        let mut mem = MemSystem::new(&cfg);
        let n_stores = 5u64;
        for i in 0..n_stores {
            let mut t = MemTxn::new(store(i), i * 10);
            p.store_local(&mut t, i * 10, &mut mem);
        }
        let sets = p.cores[0].cache.tags.sets() as u64;
        let assoc = p.cores[0].cache.tags.assoc() as u64;
        for k in 0..assoc * 3 {
            let t = MemTxn::new(load(k, 1 + k * sets), 5000);
            p.install_fill(0, &t, 0b1111, 5000, &mut mem);
        }
        assert_eq!(
            mem.stats.writes, n_stores,
            "write-through L2 writes == stores, evictions add none"
        );
    }

    #[test]
    fn full_mshr_pool_delays_dispatch_and_counts_rejects() {
        // Saturate the MSHR pool with same-cycle misses to distinct lines:
        // dispatch must serialize once the pool is full, each stalled miss
        // must count a reject, and the stall must land in the breakdown.
        let cfg = {
            let mut c = GpuConfig::tiny(L1ArchKind::Private);
            c.l1.mshr_entries = 2;
            c
        };
        let mut p = PipelineCtx::new(&cfg, FabricNeeds::default());
        let mut mem = MemSystem::new(&cfg);
        let n = 8u64;
        let mut dispatches = Vec::new();
        for i in 0..n {
            // Distinct lines, same arrival cycle → no merges, pure pool
            // pressure.
            let mut txn = MemTxn::new(load(i, i * 64), 0);
            p.local_load(&mut txn, &mut mem);
            dispatches.push(p.cores[0].mshr.earliest(0));
        }
        assert_eq!(p.stats.misses, n);
        assert!(
            p.stats.rejects >= n - cfg.l1.mshr_entries as u64,
            "misses beyond the pool must reject: {} rejects",
            p.stats.rejects
        );
        assert!(
            p.con.total().get(ResourceClass::MshrFull) > 0,
            "MSHR-full stalls must be attributed: {:?}",
            p.con.total()
        );
        // The pool's earliest-free horizon must move out as misses pile up.
        assert!(dispatches.windows(2).all(|w| w[0] <= w[1]));
        assert!(dispatches[n as usize - 1] > 0, "a full pool delays dispatch");
    }

    #[test]
    fn miss_transactions_carry_hops_and_queueing() {
        let (mut p, mut mem, _) = setup();
        let mut txn = MemTxn::new(load(1, 7), 0);
        p.local_load(&mut txn, &mut mem);
        assert!(txn.hops.tag_done > 0, "miss pays the tag probe");
        assert!(txn.hops.l2_dispatch >= txn.hops.tag_done);
        assert!(txn.hops.mem_done > txn.hops.l2_dispatch, "DRAM trip recorded");
        assert!(txn.done() > txn.hops.mem_done, "usable after the fill");
        assert_eq!(txn.l1_stage_done(), txn.hops.l2_dispatch + 32);

        // A later hit to the same line is served entirely in the L1 stage.
        let t = txn.done() + 100;
        let mut hit = MemTxn::new(load(2, 7), t);
        p.local_load(&mut hit, &mut mem);
        assert_eq!(hit.hops.l2_dispatch, 0, "no memory trip on a hit");
        assert_eq!(hit.done(), hit.l1_stage_done());
        assert_eq!(p.stats.local_hits, 1);
    }
}
