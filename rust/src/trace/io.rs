//! Workload (de)serialization: save generated workloads and replay
//! external traces through the simulator.
//!
//! The JSON schema is compact and stable:
//!
//! ```json
//! {
//!   "name": "b+tree",
//!   "kernels": [{
//!     "name": "findK",
//!     "programs": [              // one entry per core
//!       [                        // one entry per warp
//!         {"a": 4},              // 4 ALU issue slots
//!         {"l": [[12, 15]]},     // load: line 12, sector mask 0b1111
//!         {"s": [[40, 3]]}       // store: line 40, sectors 0b0011
//!       ]
//!     ]
//!   }]
//! }
//! ```
//!
//! This is also the interchange point for users who want to drive the
//! simulator from real GPU traces (e.g. converted GPGPU-Sim/Accel-Sim
//! memory traces): produce this JSON and `ata-sim run --trace file`.

use crate::core::{WarpInst, WarpProgram};
use crate::engine::{KernelSpec, Workload};
use crate::util::json::{Json, JsonError};

/// Failure loading or saving a workload trace file.
#[derive(Debug)]
pub enum TraceIoError {
    Json(JsonError),
    Io(std::io::Error),
    Schema(String),
}

impl std::fmt::Display for TraceIoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceIoError::Json(e) => write!(f, "json: {e}"),
            TraceIoError::Io(e) => write!(f, "io: {e}"),
            TraceIoError::Schema(m) => write!(f, "schema: {m}"),
        }
    }
}

impl std::error::Error for TraceIoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TraceIoError::Json(e) => Some(e),
            TraceIoError::Io(e) => Some(e),
            TraceIoError::Schema(_) => None,
        }
    }
}

impl From<JsonError> for TraceIoError {
    fn from(e: JsonError) -> Self {
        TraceIoError::Json(e)
    }
}

impl From<std::io::Error> for TraceIoError {
    fn from(e: std::io::Error) -> Self {
        TraceIoError::Io(e)
    }
}

fn inst_to_json(inst: &WarpInst) -> Json {
    let reqs = |v: &Vec<(u64, u8)>| {
        Json::Arr(
            v.iter()
                .map(|&(line, sectors)| {
                    Json::Arr(vec![Json::Num(line as f64), Json::Num(sectors as f64)])
                })
                .collect(),
        )
    };
    match inst {
        WarpInst::Alu(n) => Json::obj(vec![("a", (*n as u64).into())]),
        WarpInst::Load(v) => Json::obj(vec![("l", reqs(v))]),
        WarpInst::Store(v) => Json::obj(vec![("s", reqs(v))]),
    }
}

fn inst_from_json(j: &Json) -> Result<WarpInst, TraceIoError> {
    let bad = |m: &str| TraceIoError::Schema(m.to_string());
    if let Some(n) = j.get("a") {
        let n = n.as_u64().ok_or_else(|| bad("'a' must be an integer"))?;
        return Ok(WarpInst::Alu(n.min(u16::MAX as u64) as u16));
    }
    let parse_reqs = |arr: &Json| -> Result<Vec<(u64, u8)>, TraceIoError> {
        arr.as_arr()
            .ok_or_else(|| bad("requests must be an array"))?
            .iter()
            .map(|pair| {
                let p = pair.as_arr().filter(|p| p.len() == 2).ok_or_else(|| {
                    bad("request must be [line, sectors]")
                })?;
                let line = p[0].as_u64().ok_or_else(|| bad("line must be u64"))?;
                let sectors =
                    p[1].as_u64().filter(|&s| s > 0 && s < 256).ok_or_else(|| {
                        bad("sectors must be 1..=255")
                    })? as u8;
                Ok((line, sectors))
            })
            .collect()
    };
    if let Some(l) = j.get("l") {
        let reqs = parse_reqs(l)?;
        if reqs.is_empty() {
            return Err(bad("load must carry at least one request"));
        }
        return Ok(WarpInst::Load(reqs));
    }
    if let Some(s) = j.get("s") {
        return Ok(WarpInst::Store(parse_reqs(s)?));
    }
    Err(bad("instruction must be one of {a, l, s}"))
}

pub fn workload_to_json(wl: &Workload) -> Json {
    Json::obj(vec![
        ("name", wl.name.as_str().into()),
        (
            "kernels",
            Json::Arr(
                wl.kernels
                    .iter()
                    .map(|k| {
                        Json::obj(vec![
                            ("name", k.name.as_str().into()),
                            (
                                "programs",
                                Json::Arr(
                                    k.programs
                                        .iter()
                                        .map(|core| {
                                            Json::Arr(
                                                core.iter()
                                                    .map(|p| {
                                                        Json::Arr(
                                                            p.insts()
                                                                .iter()
                                                                .map(inst_to_json)
                                                                .collect(),
                                                        )
                                                    })
                                                    .collect(),
                                            )
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

pub fn workload_from_json(j: &Json) -> Result<Workload, TraceIoError> {
    let bad = |m: &str| TraceIoError::Schema(m.to_string());
    let name = j
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| bad("missing workload name"))?
        .to_string();
    let mut kernels = Vec::new();
    for kj in j
        .get("kernels")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("missing kernels array"))?
    {
        let kname = kj
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("missing kernel name"))?
            .to_string();
        let mut programs = Vec::new();
        for core in kj
            .get("programs")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("missing programs array"))?
        {
            let mut warps = Vec::new();
            for warp in core.as_arr().ok_or_else(|| bad("core entry must be array"))? {
                let insts: Result<Vec<WarpInst>, _> = warp
                    .as_arr()
                    .ok_or_else(|| bad("warp entry must be array"))?
                    .iter()
                    .map(inst_from_json)
                    .collect();
                warps.push(WarpProgram::new(insts?));
            }
            programs.push(warps);
        }
        kernels.push(KernelSpec {
            name: kname,
            programs,
        });
    }
    Ok(Workload { name, kernels })
}

pub fn save(wl: &Workload, path: &str) -> Result<(), TraceIoError> {
    std::fs::write(path, workload_to_json(wl).to_string())?;
    Ok(())
}

pub fn load(path: &str) -> Result<Workload, TraceIoError> {
    let text = std::fs::read_to_string(path)?;
    workload_from_json(&Json::parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, L1ArchKind};
    use crate::trace::synth;

    #[test]
    fn roundtrip_preserves_generated_workload() {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let wl = synth::locality_knob(0.6, 0.25).workload(&cfg);
        let j = workload_to_json(&wl);
        let back = workload_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(wl.name, back.name);
        assert_eq!(wl.kernels.len(), back.kernels.len());
        for (a, b) in wl.kernels.iter().zip(&back.kernels) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.programs, b.programs);
        }
    }

    #[test]
    fn file_roundtrip_and_replay_determinism() {
        use crate::engine::run_workload;
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let wl = synth::locality_knob(0.7, 0.25).workload(&cfg);
        let path = std::env::temp_dir().join("ata_trace_test.json");
        let path = path.to_str().unwrap();
        save(&wl, path).unwrap();
        let loaded = load(path).unwrap();
        std::fs::remove_file(path).ok();
        // Replaying the serialized workload must give identical results.
        let a = run_workload(&cfg, &wl);
        let b = run_workload(&cfg, &loaded);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.insts, b.insts);
    }

    #[test]
    fn schema_errors_are_reported() {
        let bad = |text: &str| {
            workload_from_json(&Json::parse(text).unwrap())
                .expect_err("must reject malformed trace")
        };
        bad(r#"{"kernels": []}"#); // missing name
        bad(r#"{"name": "x"}"#); // missing kernels
        bad(r#"{"name":"x","kernels":[{"name":"k","programs":[[[{"z":1}]]]}]}"#);
        bad(r#"{"name":"x","kernels":[{"name":"k","programs":[[[{"l":[]}]]]}]}"#);
        bad(r#"{"name":"x","kernels":[{"name":"k","programs":[[[{"l":[[5,0]]}]]]}]}"#);
    }

    #[test]
    fn hand_written_trace_runs() {
        let text = r#"{
          "name": "hand",
          "kernels": [{
            "name": "k0",
            "programs": [
              [[{"a": 2}, {"l": [[100, 15], [101, 15]]}, {"s": [[100, 3]]}]],
              [[{"l": [[100, 15]]}]],
              [[{"a": 1}]],
              [[{"a": 1}]],
              [[{"a": 1}]],
              [[{"a": 1}]],
              [[{"a": 1}]],
              [[{"a": 1}]]
            ]
          }]
        }"#;
        let wl = workload_from_json(&Json::parse(text).unwrap()).unwrap();
        let cfg = GpuConfig::tiny(L1ArchKind::Ata);
        let r = crate::engine::run_workload(&cfg, &wl);
        assert!(r.insts >= 10);
        assert!(r.l1.accesses == 4);
    }
}
