//! Workload models.
//!
//! The paper evaluates ten applications from Rodinia 3.1, Tango and
//! Polybench on GPGPU-Sim.  Those CUDA binaries and the simulator's
//! front-end are not available here, so this module generates
//! *statistical access-pattern models*: per-warp instruction streams
//! whose inter-core replication, footprint, reuse skew, stride pattern,
//! coalescing and intensity are set per application to match the paper's
//! classification (high vs low inter-core locality, §IV) and per-kernel
//! diversity (§IV-B).  DESIGN.md §5 documents the substitution.

pub mod apps;
pub mod io;
pub mod signature;
pub mod synth;

use crate::config::GpuConfig;
use crate::core::{CorePartition, WarpInst, WarpProgram};
use crate::engine::{AppLane, KernelSpec, MultiWorkload, Workload};
use crate::mem::{LineAddr, SectorMask};
use crate::util::rng::{Pcg32, SplitMix64, Zipf};

/// Spatial/temporal pattern of a region's accesses.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pattern {
    /// Warp walks the region sequentially (streaming: stencil rows,
    /// matrix tiles). High row-buffer + sector locality.
    Sequential,
    /// Fixed stride in lines (column walks, plane hops).
    Strided(u32),
    /// Zipf-skewed reuse with the given exponent (pointer chasing over a
    /// hot index, shared filter weights).
    Zipf(f64),
}

/// One kernel's statistical model.
#[derive(Debug, Clone)]
pub struct KernelModel {
    pub name: &'static str,
    /// Warps launched per core.
    pub warps_per_core: usize,
    /// Load instructions per warp.
    pub loads_per_warp: usize,
    /// Mean ALU instructions between loads (compute intensity).
    pub alu_per_load: u16,
    /// Cache lines per coalesced load (1 = fully coalesced, 4 = scattered).
    pub lines_per_load: u32,
    /// Fraction of accesses that touch only one 32 B sector (vs the full
    /// 128 B line).
    pub narrow_fraction: f64,
    /// Size of the region shared by all cores (lines).
    pub shared_lines: u32,
    /// Probability a load targets the shared region.
    pub shared_fraction: f64,
    pub shared_pattern: Pattern,
    /// Size of each core's private region (lines).
    pub private_lines: u32,
    pub private_pattern: Pattern,
    /// Fraction of memory instructions that are stores.
    pub write_fraction: f64,
}

impl Default for KernelModel {
    fn default() -> Self {
        KernelModel {
            name: "kernel",
            warps_per_core: 16,
            loads_per_warp: 32,
            alu_per_load: 4,
            lines_per_load: 2,
            narrow_fraction: 0.25,
            shared_lines: 1024,
            shared_fraction: 0.5,
            shared_pattern: Pattern::Zipf(0.8),
            private_lines: 512,
            private_pattern: Pattern::Sequential,
            write_fraction: 0.1,
        }
    }
}

/// Locality class per the paper's §IV classification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LocalityClass {
    High,
    Low,
}

/// A full application model.
#[derive(Debug, Clone)]
pub struct AppModel {
    pub name: &'static str,
    pub suite: &'static str,
    pub class: LocalityClass,
    pub kernels: Vec<KernelModel>,
    /// What the real application does and why the knobs are set this way.
    pub notes: &'static str,
}

/// Region bases: all cores share [SHARED_BASE, ...); each core's private
/// region starts at PRIVATE_STRIDE * (core+1) so regions never collide.
pub const SHARED_BASE: LineAddr = 0;
pub const PRIVATE_STRIDE: LineAddr = 1 << 24;

/// Stateful per-warp address cursor.
struct RegionCursor {
    base: LineAddr,
    size: u32,
    pattern: Pattern,
    cursor: u32,
    zipf: Option<Zipf>,
}

impl RegionCursor {
    fn new(base: LineAddr, size: u32, pattern: Pattern, start: u32) -> Self {
        let zipf = match pattern {
            Pattern::Zipf(e) => Some(Zipf::new(size.max(1), e)),
            _ => None,
        };
        RegionCursor {
            base,
            size: size.max(1),
            pattern,
            cursor: start,
            zipf,
        }
    }

    fn next(&mut self, rng: &mut Pcg32) -> LineAddr {
        let off = match self.pattern {
            Pattern::Sequential => {
                let o = self.cursor % self.size;
                self.cursor = self.cursor.wrapping_add(1);
                o
            }
            Pattern::Strided(s) => {
                let o = self.cursor % self.size;
                self.cursor = self.cursor.wrapping_add(s.max(1));
                o
            }
            Pattern::Zipf(_) => self.zipf.as_ref().unwrap().sample(rng),
        };
        self.base + off as LineAddr
    }
}

impl KernelModel {
    /// Generate this kernel's per-core warp programs.
    ///
    /// Determinism: the stream is a pure function of (cfg.seed, app_salt,
    /// kernel_idx, core, warp).
    pub fn build(&self, cfg: &GpuConfig, app_salt: u64, kernel_idx: usize) -> KernelSpec {
        let warps = self.warps_per_core.min(cfg.max_warps_per_core);
        let programs = (0..cfg.cores)
            .map(|core| {
                (0..warps)
                    .map(|warp| self.build_warp(cfg, app_salt, kernel_idx, core, warp))
                    .collect()
            })
            .collect();
        KernelSpec {
            name: self.name.to_string(),
            programs,
        }
    }

    fn build_warp(
        &self,
        cfg: &GpuConfig,
        app_salt: u64,
        kernel_idx: usize,
        core: usize,
        warp: usize,
    ) -> WarpProgram {
        let mut mix = SplitMix64::new(
            cfg.seed
                ^ app_salt
                ^ ((kernel_idx as u64) << 48)
                ^ ((core as u64) << 32)
                ^ ((warp as u64) << 16),
        );
        let mut rng = Pcg32::new(mix.next_u64(), mix.next_u64());

        // Warps start at spread-out offsets so sequential warps cover the
        // region cooperatively (CUDA blocks striping over the data).
        let shared_start =
            (warp as u32).wrapping_mul(self.shared_lines / self.warps_per_core.max(1) as u32);
        let private_start =
            (warp as u32).wrapping_mul(self.private_lines / self.warps_per_core.max(1) as u32);
        let mut shared = RegionCursor::new(
            SHARED_BASE,
            self.shared_lines,
            self.shared_pattern,
            shared_start,
        );
        let mut private = RegionCursor::new(
            PRIVATE_STRIDE * (core as LineAddr + 1),
            self.private_lines,
            self.private_pattern,
            private_start,
        );

        let mut insts = Vec::with_capacity(self.loads_per_warp * 2);
        for _ in 0..self.loads_per_warp {
            if self.alu_per_load > 0 {
                let gap = rng.geometric(1.0 / (self.alu_per_load as f64 + 1.0), 64) as u16;
                if gap > 0 {
                    insts.push(WarpInst::Alu(gap));
                }
            }
            let mut reqs: Vec<(LineAddr, SectorMask)> =
                Vec::with_capacity(self.lines_per_load as usize);
            for _ in 0..self.lines_per_load.max(1) {
                let use_shared = rng.chance(self.shared_fraction) && self.shared_lines > 0;
                let line = if use_shared {
                    shared.next(&mut rng)
                } else {
                    private.next(&mut rng)
                };
                let sectors: SectorMask = if rng.chance(self.narrow_fraction) {
                    1 << rng.next_below(4)
                } else {
                    0b1111
                };
                if let Some(r) = reqs.iter_mut().find(|(l, _)| *l == line) {
                    r.1 |= sectors; // coalesce duplicate lines
                } else {
                    reqs.push((line, sectors));
                }
            }
            if rng.chance(self.write_fraction) {
                insts.push(WarpInst::Store(reqs));
            } else {
                insts.push(WarpInst::Load(reqs));
            }
        }
        WarpProgram::new(insts)
    }
}

impl AppModel {
    /// Build the multi-kernel workload for this app on `cfg`.
    pub fn workload(&self, cfg: &GpuConfig) -> Workload {
        let salt = self.name.bytes().fold(0xCBF2_9CE4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x1000_0000_01B3)
        });
        Workload {
            name: self.name.to_string(),
            kernels: self
                .kernels
                .iter()
                .enumerate()
                .map(|(i, k)| k.build(cfg, salt, i))
                .collect(),
        }
    }

    /// Scale intensity (warps × loads) by `factor` for quick test runs.
    pub fn scaled(&self, factor: f64) -> AppModel {
        let mut out = self.clone();
        for k in &mut out.kernels {
            k.warps_per_core = ((k.warps_per_core as f64 * factor).round() as usize).max(1);
            k.loads_per_warp = ((k.loads_per_warp as f64 * factor).round() as usize).max(2);
        }
        out
    }
}

/// Address-space stride between co-executed applications: each lane's
/// lines are shifted by `lane_index * APP_SPACE_STRIDE` so separate
/// processes never false-share (their private regions top out well below
/// this at `PRIVATE_STRIDE * (cores+1) + footprint` ≈ 2³⁰ lines).
pub const APP_SPACE_STRIDE: LineAddr = 1 << 34;

/// Build a co-execution workload: `apps[i]` runs on a partition of
/// `sizes[i]` cores (partitions are carved consecutively from core 0).
///
/// Each lane's workload is generated exactly as a solo run on a
/// `sizes[i]`-core GPU would generate it, then (unless
/// `share_address_space`) shifted into a disjoint address space.  With
/// `share_address_space = true` all lanes keep their generated addresses,
/// modeling co-executed applications that read-share data (same input
/// replicated, shared libraries/filters) — the scenario where ATA's
/// cross-app remote hits appear.
pub fn co_workload(
    cfg: &GpuConfig,
    apps: &[AppModel],
    sizes: &[usize],
    share_address_space: bool,
) -> Result<MultiWorkload, String> {
    if apps.is_empty() {
        return Err("co-workload needs at least one app".into());
    }
    if apps.len() != sizes.len() {
        return Err(format!(
            "{} apps but {} partition sizes",
            apps.len(),
            sizes.len()
        ));
    }
    let parts = CorePartition::split(cfg.cores, sizes)?;
    co_workload_parts(cfg, apps, &parts, share_address_space)
}

/// [`co_workload`] with explicit partition placement — used by the
/// co-scheduling sweep to run solo baselines on the *same* cores the app
/// occupies in the co-run.  Address slots default to lane order.
pub fn co_workload_parts(
    cfg: &GpuConfig,
    apps: &[AppModel],
    parts: &[CorePartition],
    share_address_space: bool,
) -> Result<MultiWorkload, String> {
    let slots: Vec<usize> = (0..apps.len()).collect();
    co_workload_placed(cfg, apps, parts, &slots, share_address_space)
}

/// The fully explicit builder: partition placement *and* address-space
/// slot per lane.  A lane's lines are shifted by
/// `addr_slots[i] * APP_SPACE_STRIDE` (unless sharing), so a solo
/// baseline can replay the exact address stream an app had at a given
/// position of a co-run — keeping `ata-sim multi` and
/// [`crate::coordinator::CoSchedSweep`] byte-comparable.
pub fn co_workload_placed(
    cfg: &GpuConfig,
    apps: &[AppModel],
    parts: &[CorePartition],
    addr_slots: &[usize],
    share_address_space: bool,
) -> Result<MultiWorkload, String> {
    if apps.len() != parts.len() || apps.len() != addr_slots.len() {
        return Err(format!(
            "{} apps but {} partitions / {} address slots",
            apps.len(),
            parts.len(),
            addr_slots.len()
        ));
    }
    let mut lanes = Vec::with_capacity(apps.len());
    for ((app, part), &slot) in apps.iter().zip(parts).zip(addr_slots) {
        let mut sub = cfg.clone();
        sub.cores = part.count;
        let mut wl = app.workload(&sub);
        if !share_address_space {
            wl.offset_lines(APP_SPACE_STRIDE * slot as LineAddr);
        }
        lanes.push(AppLane {
            name: app.name.to_string(),
            kernels: wl.kernels,
            partition: *part,
        });
    }
    Ok(MultiWorkload {
        name: apps.iter().map(|a| a.name).collect::<Vec<_>>().join("+"),
        lanes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::L1ArchKind;
    use crate::trace::signature::{exact_locality, sample_core_traces};

    fn cfg() -> GpuConfig {
        GpuConfig::tiny(L1ArchKind::Private)
    }

    #[test]
    fn generation_is_deterministic() {
        let m = KernelModel::default();
        let a = m.build(&cfg(), 1, 0);
        let b = m.build(&cfg(), 1, 0);
        assert_eq!(a.programs, b.programs);
        let c = m.build(&cfg(), 2, 0);
        assert_ne!(a.programs, c.programs, "different app salt differs");
    }

    #[test]
    fn shared_fraction_controls_intercore_locality() {
        let mk = |sf: f64| KernelModel {
            shared_fraction: sf,
            shared_lines: 256,
            private_lines: 256,
            ..Default::default()
        };
        let cfg = cfg();
        let hi = AppModel {
            name: "hi",
            suite: "synthetic",
            class: LocalityClass::High,
            kernels: vec![mk(0.9)],
            notes: "",
        };
        let lo = AppModel {
            name: "lo",
            suite: "synthetic",
            class: LocalityClass::Low,
            kernels: vec![mk(0.0)],
            notes: "",
        };
        let t_hi = sample_core_traces(&hi.workload(&cfg), cfg.cores, 4096);
        let t_lo = sample_core_traces(&lo.workload(&cfg), cfg.cores, 4096);
        let (s_hi, r_hi) = exact_locality(&t_hi);
        let (s_lo, r_lo) = exact_locality(&t_lo);
        assert!(s_hi > 0.3, "high sharing score {s_hi}");
        assert!(s_lo < 0.05, "low sharing score {s_lo}");
        assert!(r_hi > r_lo, "replication {r_hi} vs {r_lo}");
    }

    #[test]
    fn private_regions_never_collide_across_cores() {
        let m = KernelModel {
            shared_fraction: 0.0,
            ..Default::default()
        };
        let spec = m.build(&cfg(), 7, 0);
        let traces = sample_core_traces(
            &Workload {
                name: "x".into(),
                kernels: vec![spec],
            },
            cfg().cores,
            100_000,
        );
        use std::collections::HashSet;
        let mut all: HashSet<u64> = HashSet::new();
        for t in &traces {
            for &l in t {
                assert!(all.insert(l), "line {l} appears in two cores' private regions");
            }
        }
    }

    #[test]
    fn patterns_produce_expected_shapes() {
        let mut rng = Pcg32::new(1, 1);
        let mut seq = RegionCursor::new(100, 8, Pattern::Sequential, 0);
        let lines: Vec<u64> = (0..10).map(|_| seq.next(&mut rng)).collect();
        assert_eq!(lines[..8], [100, 101, 102, 103, 104, 105, 106, 107]);
        assert_eq!(lines[8], 100, "wraps");

        let mut strided = RegionCursor::new(0, 100, Pattern::Strided(10), 0);
        let s: Vec<u64> = (0..3).map(|_| strided.next(&mut rng)).collect();
        assert_eq!(s, [0, 10, 20]);

        let mut z = RegionCursor::new(0, 1000, Pattern::Zipf(1.0), 0);
        let mut head = 0;
        for _ in 0..1000 {
            if z.next(&mut rng) < 10 {
                head += 1;
            }
        }
        assert!(head > 250, "zipf cursor skews to the head: {head}");
    }

    #[test]
    fn write_fraction_generates_stores() {
        let m = KernelModel {
            write_fraction: 0.5,
            ..Default::default()
        };
        let spec = m.build(&cfg(), 3, 0);
        let (mut loads, mut stores) = (0, 0);
        for p in spec.programs.iter().flatten() {
            for i in p.insts() {
                match i {
                    WarpInst::Load(_) => loads += 1,
                    WarpInst::Store(_) => stores += 1,
                    _ => {}
                }
            }
        }
        let frac = stores as f64 / (loads + stores) as f64;
        assert!((0.4..0.6).contains(&frac), "store fraction {frac}");
    }

    #[test]
    fn scaled_reduces_work() {
        let app = AppModel {
            name: "x",
            suite: "s",
            class: LocalityClass::High,
            kernels: vec![KernelModel::default()],
            notes: "",
        };
        let small = app.scaled(0.25);
        assert_eq!(small.kernels[0].warps_per_core, 4);
        assert_eq!(small.kernels[0].loads_per_warp, 8);
        let wl = small.workload(&cfg());
        assert!(wl.total_requests() < app.workload(&cfg()).total_requests());
    }

    #[test]
    fn coalescing_merges_duplicate_lines() {
        // With one shared hot line, duplicate lines in one load must merge.
        let m = KernelModel {
            shared_lines: 1,
            shared_fraction: 1.0,
            lines_per_load: 4,
            narrow_fraction: 0.0,
            ..Default::default()
        };
        let spec = m.build(&cfg(), 9, 0);
        for p in spec.programs.iter().flatten() {
            for i in p.insts() {
                if let WarpInst::Load(reqs) | WarpInst::Store(reqs) = i {
                    assert_eq!(reqs.len(), 1, "all 4 lines coalesce into one");
                    assert_eq!(reqs[0].0, SHARED_BASE);
                }
            }
        }
    }

    #[test]
    fn co_workload_partitions_and_isolates_address_spaces() {
        let cfg = cfg(); // 8 cores
        let a = apps::app("b+tree").unwrap().scaled(0.25);
        let b = apps::app("doitgen").unwrap().scaled(0.25);
        let multi = co_workload(&cfg, &[a.clone(), b.clone()], &[4, 4], false).unwrap();
        assert_eq!(multi.lanes.len(), 2);
        assert_eq!(multi.name, "b+tree+doitgen");
        assert_eq!(multi.lanes[0].partition, CorePartition { first: 0, count: 4 });
        assert_eq!(multi.lanes[1].partition, CorePartition { first: 4, count: 4 });
        multi.validate(&cfg).unwrap();
        // Disjoint address spaces: lane 1's lines all sit above the stride.
        let lane_lines = |lane: &AppLane| -> Vec<LineAddr> {
            lane.kernels
                .iter()
                .flat_map(|k| k.programs.iter().flatten())
                .flat_map(|p| p.touched_lines())
                .collect()
        };
        assert!(lane_lines(&multi.lanes[0]).iter().all(|&l| l < APP_SPACE_STRIDE));
        assert!(lane_lines(&multi.lanes[1]).iter().all(|&l| l >= APP_SPACE_STRIDE));

        // Shared address space: two instances of one app overlap heavily.
        let shared = co_workload(&cfg, &[a.clone(), a.clone()], &[4, 4], true).unwrap();
        let s0: std::collections::HashSet<LineAddr> =
            lane_lines(&shared.lanes[0]).into_iter().collect();
        let s1: std::collections::HashSet<LineAddr> =
            lane_lines(&shared.lanes[1]).into_iter().collect();
        assert!(s0.intersection(&s1).count() > 0, "same app must share lines");
    }

    #[test]
    fn co_workload_rejects_bad_shapes() {
        let cfg = cfg();
        let a = apps::app("b+tree").unwrap();
        assert!(co_workload(&cfg, &[], &[], false).is_err());
        assert!(co_workload(&cfg, &[a.clone()], &[4, 4], false).is_err());
        assert!(co_workload(&cfg, &[a.clone(), a.clone()], &[6, 6], false).is_err());
    }
}
