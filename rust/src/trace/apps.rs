//! The ten benchmark application models (§IV: Rodinia 3.1, Tango,
//! Polybench), classified by inter-core locality exactly as the paper
//! classifies them.
//!
//! Each model is a statistical twin of the real application's memory
//! behaviour: kernel count and per-kernel footprints, shared-region sizes
//! and reuse skews are chosen so the generated traces land in the paper's
//! locality class and reproduce the per-kernel diversity Fig 9 relies on.
//! The `notes` field documents the reasoning per app (the substitution
//! record DESIGN.md §5 points at).

use super::{AppModel, KernelModel, LocalityClass, Pattern};

/// Paper order: five high inter-core locality apps…
pub const HIGH_LOCALITY_APPS: [&str; 5] = ["b+tree", "cfd", "hotspot", "SN", "conv3d"];
/// …and five low inter-core locality apps.
pub const LOW_LOCALITY_APPS: [&str; 5] = ["doitgen", "HS3D", "sradv1", "backprop", "lud"];

/// All ten, high-locality first (Fig 8's x-axis order).
pub fn all_app_names() -> Vec<&'static str> {
    HIGH_LOCALITY_APPS
        .iter()
        .chain(LOW_LOCALITY_APPS.iter())
        .copied()
        .collect()
}

/// Look up an application model by name.  Resolves the paper's ten
/// figure apps plus the [`extra_apps`] used by co-execution studies.
pub fn app(name: &str) -> Option<AppModel> {
    all_apps()
        .into_iter()
        .chain(extra_apps())
        .find(|a| a.name == name)
}

/// The full registry of the paper's ten evaluated applications (the set
/// every figure/table sweep iterates).
pub fn all_apps() -> Vec<AppModel> {
    vec![
        btree(),
        cfd(),
        hotspot(),
        squeezenet(),
        conv3d(),
        doitgen(),
        hs3d(),
        sradv1(),
        backprop(),
        lud(),
    ]
}

/// Additional models available by name (e.g. for `ata-sim multi`) but
/// deliberately *not* part of the ten-app figure sweeps, so the paper's
/// tables keep their exact population.
pub fn extra_apps() -> Vec<AppModel> {
    vec![streamcluster()]
}

// ---------------------------------------------------------------------------
// High inter-core locality
// ---------------------------------------------------------------------------

fn btree() -> AppModel {
    // Rodinia b+tree: batched key lookups walk a B+ tree. The root and
    // inner levels are touched by every warp on every core — a textbook
    // shared hot set with Zipf-like level reuse; leaves are effectively
    // private. The paper's decoupled baseline *wins* on b+tree (Fig 8):
    // sharing gains dominate because accesses spread over many distinct
    // hot lines (large tree) so home-slice bank pressure stays moderate.
    AppModel {
        name: "b+tree",
        suite: "rodinia",
        class: LocalityClass::High,
        notes: "shared upper tree levels (hot, zipf); large shared footprint \
                spreads over home slices, so decoupled-sharing also profits",
        kernels: vec![
            KernelModel {
                name: "findK",
                warps_per_core: 16,
                loads_per_warp: 48,
                alu_per_load: 3,
                lines_per_load: 2,
                narrow_fraction: 0.5,
                shared_lines: 4608,
                shared_fraction: 0.88,
                shared_pattern: Pattern::Zipf(0.5),
                private_lines: 256,
                private_pattern: Pattern::Sequential,
                write_fraction: 0.02,
            },
            KernelModel {
                name: "findRangeK",
                warps_per_core: 16,
                loads_per_warp: 44,
                alu_per_load: 3,
                lines_per_load: 2,
                narrow_fraction: 0.5,
                shared_lines: 4608,
                shared_fraction: 0.85,
                shared_pattern: Pattern::Zipf(0.5),
                private_lines: 384,
                private_pattern: Pattern::Sequential,
                write_fraction: 0.05,
            },
        ],
    }
}

fn cfd() -> AppModel {
    // Rodinia cfd (Euler3D): unstructured-mesh flux computation; ghost
    // cells / face neighbours are read by the cores owning adjacent mesh
    // partitions. Decoupled also wins here per Fig 8.
    AppModel {
        name: "cfd",
        suite: "rodinia",
        class: LocalityClass::High,
        notes: "ghost-cell faces shared between adjacent partitions; \
                wide shared region with mild skew",
        kernels: vec![
            KernelModel {
                name: "compute_flux",
                warps_per_core: 16,
                loads_per_warp: 48,
                alu_per_load: 4,
                lines_per_load: 2,
                narrow_fraction: 0.3,
                shared_lines: 3072,
                shared_fraction: 0.7,
                shared_pattern: Pattern::Zipf(0.6),
                private_lines: 512,
                private_pattern: Pattern::Sequential,
                write_fraction: 0.08,
            },
            KernelModel {
                name: "time_step",
                warps_per_core: 12,
                loads_per_warp: 32,
                alu_per_load: 3,
                lines_per_load: 1,
                narrow_fraction: 0.3,
                shared_lines: 2048,
                shared_fraction: 0.65,
                shared_pattern: Pattern::Sequential,
                private_lines: 512,
                private_pattern: Pattern::Sequential,
                write_fraction: 0.15,
            },
            KernelModel {
                name: "compute_step_factor",
                warps_per_core: 12,
                loads_per_warp: 28,
                alu_per_load: 3,
                lines_per_load: 1,
                narrow_fraction: 0.4,
                shared_lines: 2048,
                shared_fraction: 0.6,
                shared_pattern: Pattern::Zipf(0.5),
                private_lines: 384,
                private_pattern: Pattern::Sequential,
                write_fraction: 0.1,
            },
        ],
    }
}

fn hotspot() -> AppModel {
    // Rodinia hotspot: 2D thermal stencil; halo rows at tile borders are
    // read by both neighbouring cores' blocks each iteration.
    AppModel {
        name: "hotspot",
        suite: "rodinia",
        class: LocalityClass::High,
        notes: "halo rows shared by neighbouring tiles; sequential sweeps",
        kernels: vec![
            KernelModel {
                name: "calculate_temp",
                warps_per_core: 16,
                loads_per_warp: 44,
                alu_per_load: 3,
                lines_per_load: 2,
                narrow_fraction: 0.2,
                shared_lines: 768,
                shared_fraction: 0.55,
                shared_pattern: Pattern::Zipf(0.9),
                private_lines: 448,
                private_pattern: Pattern::Sequential,
                write_fraction: 0.12,
            },
            KernelModel {
                name: "calculate_temp_iter2",
                warps_per_core: 16,
                loads_per_warp: 44,
                alu_per_load: 3,
                lines_per_load: 2,
                narrow_fraction: 0.2,
                shared_lines: 768,
                shared_fraction: 0.6,
                shared_pattern: Pattern::Zipf(0.9),
                private_lines: 448,
                private_pattern: Pattern::Sequential,
                write_fraction: 0.12,
            },
        ],
    }
}

fn squeezenet() -> AppModel {
    // Tango SN (SqueezeNet inference): a deep stack of conv layers. The
    // filter weights of each layer are *small and red-hot* — every core
    // reads the same few hundred lines while streaming its own feature-map
    // slice. That concentration is poison for decoupled-sharing (all
    // cores converge on the few home slices holding the weights → Fig 8
    // shows SN *below* private for decoupled) and ideal for ATA (each
    // core ends up with a local replica after one remote fetch).
    // Kernel sizes alternate squeeze (1x1, tiny weights) / expand (3x3).
    let squeeze = |name: &'static str, weights: u32, fmap: u32| KernelModel {
        name,
        warps_per_core: 14,
        loads_per_warp: 34,
        alu_per_load: 2,
        lines_per_load: 2,
        narrow_fraction: 0.3,
        shared_lines: weights,
        shared_fraction: 0.75,
        shared_pattern: Pattern::Zipf(1.1),
        private_lines: fmap,
        private_pattern: Pattern::Sequential,
        write_fraction: 0.1,
    };
    AppModel {
        name: "SN",
        suite: "tango",
        class: LocalityClass::High,
        notes: "small red-hot shared filter weights per layer; convergence \
                on few lines crushes decoupled-sharing on several kernels",
        kernels: vec![
            squeeze("conv1", 96, 640),
            squeeze("fire2_squeeze", 48, 512),
            squeeze("fire2_expand", 160, 512),
            squeeze("fire3_squeeze", 48, 512),
            squeeze("fire3_expand", 160, 512),
            squeeze("fire4_squeeze", 96, 448),
            squeeze("fire4_expand", 320, 448),
            squeeze("fire5_squeeze", 96, 384),
            squeeze("fire5_expand", 320, 384),
            squeeze("conv10", 640, 320),
        ],
    }
}

fn conv3d() -> AppModel {
    // Polybench conv3d: 3D convolution; every core reads the same small
    // filter and overlapping input planes. Like SN, the shared set is
    // narrow → decoupled-sharing underperforms private (Fig 8).
    let k = |name: &'static str, shared: u32, shared_frac: f64| KernelModel {
        name,
        warps_per_core: 14,
        loads_per_warp: 40,
        alu_per_load: 2,
        lines_per_load: 2,
        narrow_fraction: 0.2,
        shared_lines: shared,
        shared_fraction: shared_frac,
        shared_pattern: Pattern::Zipf(1.0),
        private_lines: 640,
        private_pattern: Pattern::Strided(4),
        write_fraction: 0.1,
    };
    AppModel {
        name: "conv3d",
        suite: "polybench",
        class: LocalityClass::High,
        notes: "tiny shared filter + overlapped input planes; narrow hot set",
        kernels: vec![
            k("conv3d_k1", 128, 0.7),
            k("conv3d_k2", 192, 0.65),
            k("conv3d_k3", 128, 0.75),
            k("conv3d_k4", 256, 0.6),
        ],
    }
}

// ---------------------------------------------------------------------------
// Low inter-core locality
// ---------------------------------------------------------------------------

fn doitgen() -> AppModel {
    // Polybench doitgen: per-core tile GEMM-like kernel; each core works
    // a disjoint tile. Almost nothing is shared, so sharing architectures
    // can only lose — decoupled scatters every private line to a remote
    // home slice and pays crossbar + bank conflicts on *every* access
    // (Fig 8 shows doitgen among decoupled's worst).
    AppModel {
        name: "doitgen",
        suite: "polybench",
        class: LocalityClass::Low,
        notes: "disjoint per-core GEMM tiles; decoupled pays the crossbar on \
                every access for zero sharing benefit",
        kernels: vec![
            KernelModel {
                name: "doitgen_main",
                warps_per_core: 12,
                loads_per_warp: 48,
                alu_per_load: 6,
                lines_per_load: 2,
                narrow_fraction: 0.15,
                shared_lines: 64,
                shared_fraction: 0.04,
                shared_pattern: Pattern::Zipf(0.8),
                private_lines: 1280,
                private_pattern: Pattern::Sequential,
                write_fraction: 0.12,
            },
            KernelModel {
                name: "doitgen_sum",
                warps_per_core: 10,
                loads_per_warp: 28,
                alu_per_load: 4,
                lines_per_load: 1,
                narrow_fraction: 0.2,
                shared_lines: 64,
                shared_fraction: 0.05,
                shared_pattern: Pattern::Zipf(0.8),
                private_lines: 896,
                private_pattern: Pattern::Sequential,
                write_fraction: 0.2,
            },
        ],
    }
}

fn hs3d() -> AppModel {
    // Rodinia hotspot3D: 3D stencil over a large grid; each core sweeps
    // its own z-slab with strided plane hops. Shared halos are a tiny
    // fraction of traffic. Fig 9(b): ATA beats decoupled on all kernels.
    let k = |name: &'static str, stride: u32| KernelModel {
        name,
        warps_per_core: 12,
        loads_per_warp: 40,
        alu_per_load: 5,
        lines_per_load: 2,
        narrow_fraction: 0.2,
        shared_lines: 256,
        shared_fraction: 0.08,
        shared_pattern: Pattern::Sequential,
        private_lines: 1536,
        private_pattern: Pattern::Strided(stride),
        write_fraction: 0.12,
    };
    AppModel {
        name: "HS3D",
        suite: "rodinia",
        class: LocalityClass::Low,
        notes: "large private z-slabs, strided plane walks, thin halos",
        kernels: vec![
            k("hotspotOpt_k1", 1),
            k("hotspotOpt_k2", 8),
            k("hotspotOpt_k3", 1),
            k("hotspotOpt_k4", 16),
            k("hotspotOpt_k5", 8),
            k("hotspotOpt_k6", 1),
        ],
    }
}

fn sradv1() -> AppModel {
    // Rodinia srad_v1: ~16 tiny kernels (reduction, prepare, srad, srad2,
    // compress...). Mostly disjoint tiles, but kernels 4, 9 and 14
    // (reduction-flavoured) hammer a *small* region — under decoupled
    // those collapse onto one or two home slices and serialize (the
    // paper's Fig 9(d) shows exactly k4/k9/k14 cratering).
    let streaming = |name: &'static str| KernelModel {
        name,
        warps_per_core: 12,
        loads_per_warp: 20,
        alu_per_load: 5,
        lines_per_load: 1,
        narrow_fraction: 0.25,
        shared_lines: 96,
        shared_fraction: 0.06,
        shared_pattern: Pattern::Zipf(0.7),
        private_lines: 768,
        private_pattern: Pattern::Sequential,
        write_fraction: 0.15,
    };
    let reduction = |name: &'static str| KernelModel {
        name,
        warps_per_core: 16,
        loads_per_warp: 26,
        alu_per_load: 1,
        lines_per_load: 2,
        narrow_fraction: 0.6,
        shared_lines: 24, // tiny convergent region
        shared_fraction: 0.45,
        shared_pattern: Pattern::Zipf(1.2),
        private_lines: 512,
        private_pattern: Pattern::Sequential,
        write_fraction: 0.25,
    };
    let mut kernels = Vec::new();
    for i in 0..16 {
        let name: &'static str = Box::leak(format!("srad_k{i}").into_boxed_str());
        if i == 4 || i == 9 || i == 14 {
            kernels.push(reduction(name));
        } else {
            kernels.push(streaming(name));
        }
    }
    AppModel {
        name: "sradv1",
        suite: "rodinia",
        class: LocalityClass::Low,
        notes: "16 small kernels; k4/k9/k14 are reduction-like and converge \
                on a tiny region — decoupled's home slices serialize there",
        kernels,
    }
}

fn backprop() -> AppModel {
    // Rodinia backprop: NN training; each core updates its own weight
    // slice, with a small shared bias/output vector.
    AppModel {
        name: "backprop",
        suite: "rodinia",
        class: LocalityClass::Low,
        notes: "private weight slices, small shared bias vector",
        kernels: vec![
            KernelModel {
                name: "layerforward",
                warps_per_core: 12,
                loads_per_warp: 36,
                alu_per_load: 4,
                lines_per_load: 2,
                narrow_fraction: 0.25,
                shared_lines: 160,
                shared_fraction: 0.12,
                shared_pattern: Pattern::Zipf(0.9),
                private_lines: 1024,
                private_pattern: Pattern::Sequential,
                write_fraction: 0.1,
            },
            KernelModel {
                name: "adjust_weights",
                warps_per_core: 12,
                loads_per_warp: 32,
                alu_per_load: 4,
                lines_per_load: 2,
                narrow_fraction: 0.25,
                shared_lines: 160,
                shared_fraction: 0.1,
                shared_pattern: Pattern::Zipf(0.9),
                private_lines: 1024,
                private_pattern: Pattern::Sequential,
                write_fraction: 0.3,
            },
        ],
    }
}

fn lud() -> AppModel {
    // Rodinia lud: blocked LU decomposition; diagonal/perimeter/internal
    // kernels work mostly disjoint blocks, with the diagonal block mildly
    // shared during the perimeter phase.
    AppModel {
        name: "lud",
        suite: "rodinia",
        class: LocalityClass::Low,
        notes: "blocked LU; mild diagonal-block sharing, strided walks",
        kernels: vec![
            KernelModel {
                name: "lud_diagonal",
                warps_per_core: 8,
                loads_per_warp: 24,
                alu_per_load: 6,
                lines_per_load: 1,
                narrow_fraction: 0.3,
                shared_lines: 128,
                shared_fraction: 0.2,
                shared_pattern: Pattern::Sequential,
                private_lines: 512,
                private_pattern: Pattern::Strided(8),
                write_fraction: 0.18,
            },
            KernelModel {
                name: "lud_perimeter",
                warps_per_core: 12,
                loads_per_warp: 32,
                alu_per_load: 5,
                lines_per_load: 2,
                narrow_fraction: 0.25,
                shared_lines: 128,
                shared_fraction: 0.15,
                shared_pattern: Pattern::Sequential,
                private_lines: 896,
                private_pattern: Pattern::Strided(8),
                write_fraction: 0.15,
            },
            KernelModel {
                name: "lud_internal",
                warps_per_core: 14,
                loads_per_warp: 40,
                alu_per_load: 6,
                lines_per_load: 2,
                narrow_fraction: 0.2,
                shared_lines: 96,
                shared_fraction: 0.08,
                shared_pattern: Pattern::Sequential,
                private_lines: 1152,
                private_pattern: Pattern::Sequential,
                write_fraction: 0.12,
            },
        ],
    }
}

fn streamcluster() -> AppModel {
    // Rodinia streamcluster: online k-median clustering. Every core's
    // warps compare streamed points against the *same* small set of
    // candidate centers — a red-hot shared structure like SN's filter
    // weights — while the point stream itself is private and read once.
    // Not one of the paper's ten evaluated apps; modeled for the
    // co-execution studies (its hot shared centers make cross-application
    // sharing visible when two instances co-run).
    AppModel {
        name: "streamcluster",
        suite: "rodinia",
        class: LocalityClass::High,
        notes: "hot shared cluster centers + private streamed points; \
                extra model for co-execution studies (not in Fig 8's ten)",
        kernels: vec![
            KernelModel {
                name: "pgain_dist",
                warps_per_core: 16,
                loads_per_warp: 40,
                alu_per_load: 4,
                lines_per_load: 2,
                narrow_fraction: 0.3,
                shared_lines: 512,
                shared_fraction: 0.7,
                shared_pattern: Pattern::Zipf(0.7),
                private_lines: 768,
                private_pattern: Pattern::Sequential,
                write_fraction: 0.05,
            },
            KernelModel {
                name: "pgain_assign",
                warps_per_core: 12,
                loads_per_warp: 32,
                alu_per_load: 3,
                lines_per_load: 1,
                narrow_fraction: 0.4,
                shared_lines: 512,
                shared_fraction: 0.65,
                shared_pattern: Pattern::Zipf(0.7),
                private_lines: 640,
                private_pattern: Pattern::Sequential,
                write_fraction: 0.12,
            },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, L1ArchKind};
    use crate::trace::signature::{exact_locality, sample_core_traces};

    #[test]
    fn registry_has_all_ten_apps() {
        let apps = all_apps();
        assert_eq!(apps.len(), 10);
        for name in all_app_names() {
            assert!(app(name).is_some(), "missing app {name}");
        }
        // Extra co-execution models resolve by name without joining the
        // figure registry.
        assert!(app("streamcluster").is_some());
        assert!(!all_app_names().contains(&"streamcluster"));
        assert!(app("nonexistent").is_none());
    }

    #[test]
    fn classes_match_paper_split() {
        for name in HIGH_LOCALITY_APPS {
            assert_eq!(app(name).unwrap().class, LocalityClass::High, "{name}");
        }
        for name in LOW_LOCALITY_APPS {
            assert_eq!(app(name).unwrap().class, LocalityClass::Low, "{name}");
        }
    }

    #[test]
    fn kernel_counts_support_fig9() {
        assert!(app("SN").unwrap().kernels.len() >= 8, "SN is a deep net");
        assert_eq!(app("sradv1").unwrap().kernels.len(), 16);
        assert!(app("conv3d").unwrap().kernels.len() >= 4);
        assert!(app("HS3D").unwrap().kernels.len() >= 4);
    }

    #[test]
    fn measured_locality_respects_classes() {
        // The generated traces must actually separate the two classes —
        // this is the property the whole evaluation hangs on.
        let cfg = GpuConfig::paper(L1ArchKind::Private);
        let mut high_scores = vec![];
        let mut low_scores = vec![];
        for a in all_apps() {
            // Full paper scale: scaled-down variants shrink footprints and
            // distort the set-intersection metric.
            let wl = a.workload(&cfg);
            let traces = sample_core_traces(&wl, cfg.cores, 16_384);
            let (score, _) = exact_locality(&traces);
            match a.class {
                LocalityClass::High => high_scores.push((a.name, score)),
                LocalityClass::Low => low_scores.push((a.name, score)),
            }
        }
        let min_high = high_scores
            .iter()
            .cloned()
            .fold(("", f64::MAX), |m, x| if x.1 < m.1 { x } else { m });
        let max_low = low_scores
            .iter()
            .cloned()
            .fold(("", f64::MIN), |m, x| if x.1 > m.1 { x } else { m });
        assert!(
            min_high.1 > max_low.1,
            "locality classes must separate: weakest high {min_high:?} vs strongest low {max_low:?}"
        );
    }

    #[test]
    fn srad_reduction_kernels_are_convergent() {
        let a = app("sradv1").unwrap();
        for (i, k) in a.kernels.iter().enumerate() {
            if i == 4 || i == 9 || i == 14 {
                assert!(k.shared_lines < 64, "k{i} must converge on a tiny region");
                assert!(k.shared_fraction > 0.3);
            }
        }
    }
}
