//! Parameterized synthetic workloads: a single locality knob for
//! integration tests, ablation benches and the locality-sweep example.

use super::{AppModel, KernelModel, LocalityClass, Pattern};

/// A one-kernel workload whose inter-core locality is exactly the knob:
/// `sharing` ∈ [0, 1] is the probability an access targets the common
/// region.  Everything else is held fixed so architecture deltas are
/// attributable to sharing alone.
pub fn locality_knob(sharing: f64, intensity: f64) -> AppModel {
    let class = if sharing >= 0.5 {
        LocalityClass::High
    } else {
        LocalityClass::Low
    };
    AppModel {
        name: Box::leak(format!("synth[s={sharing:.2}]").into_boxed_str()),
        suite: "synthetic",
        class,
        notes: "single-knob synthetic workload",
        kernels: vec![KernelModel {
            name: "synth_kernel",
            warps_per_core: ((16.0 * intensity).round() as usize).max(1),
            loads_per_warp: ((32.0 * intensity).round() as usize).max(2),
            alu_per_load: 4,
            lines_per_load: 2,
            narrow_fraction: 0.25,
            shared_lines: 1024,
            shared_fraction: sharing,
            shared_pattern: Pattern::Zipf(0.8),
            private_lines: 768,
            private_pattern: Pattern::Sequential,
            write_fraction: 0.1,
        }],
    }
}

/// A bank-conflict torture test: every core hammers the same tiny region
/// (the decoupled-sharing worst case — all traffic lands on one or two
/// home slices).
pub fn convergent_hammer() -> AppModel {
    AppModel {
        name: "synth[hammer]",
        suite: "synthetic",
        class: LocalityClass::High,
        notes: "all cores hammer 16 lines — decoupled worst case",
        kernels: vec![KernelModel {
            name: "hammer",
            warps_per_core: 16,
            loads_per_warp: 32,
            alu_per_load: 1,
            lines_per_load: 2,
            narrow_fraction: 0.0,
            shared_lines: 16,
            shared_fraction: 0.95,
            shared_pattern: Pattern::Zipf(1.0),
            private_lines: 64,
            private_pattern: Pattern::Sequential,
            write_fraction: 0.0,
        }],
    }
}

/// A pure-streaming workload (zero sharing, perfect spatial locality):
/// the private-cache best case, used to verify "no performance impairment
/// due to sharing" on ATA.
pub fn pure_streaming() -> AppModel {
    AppModel {
        name: "synth[stream]",
        suite: "synthetic",
        class: LocalityClass::Low,
        notes: "disjoint sequential streams, zero sharing",
        kernels: vec![KernelModel {
            name: "stream",
            warps_per_core: 16,
            loads_per_warp: 32,
            alu_per_load: 4,
            lines_per_load: 1,
            narrow_fraction: 0.0,
            shared_lines: 0,
            shared_fraction: 0.0,
            shared_pattern: Pattern::Sequential,
            private_lines: 1024,
            private_pattern: Pattern::Sequential,
            write_fraction: 0.05,
        }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, L1ArchKind};
    use crate::trace::signature::{exact_locality, sample_core_traces};

    #[test]
    fn knob_is_monotone_in_measured_locality() {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let mut last = -1.0;
        for sharing in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let wl = locality_knob(sharing, 0.5).workload(&cfg);
            let (score, _) = exact_locality(&sample_core_traces(&wl, cfg.cores, 4096));
            assert!(
                score >= last,
                "locality must grow with the knob: {sharing} -> {score} (prev {last})"
            );
            last = score;
        }
    }

    #[test]
    fn hammer_has_tiny_shared_footprint() {
        let a = convergent_hammer();
        assert!(a.kernels[0].shared_lines <= 16);
    }

    #[test]
    fn streaming_has_zero_shared_traffic() {
        let cfg = GpuConfig::tiny(L1ArchKind::Private);
        let wl = pure_streaming().workload(&cfg);
        let (score, repl) = exact_locality(&sample_core_traces(&wl, cfg.cores, 8192));
        assert_eq!(score, 0.0);
        assert!((repl - 1.0).abs() < 1e-9);
    }
}
