//! Minimal JSON parser / writer (the offline crate set has no `serde`).
//!
//! Covers the full JSON grammar the repo needs: config files, artifact
//! metadata sidecars, and experiment result dumps.  Numbers are held as
//! f64 with an i64 fast path preserved on output for integral values.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.  Objects use BTreeMap so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset where scanning stopped.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: `obj.path("a.b.c")`.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    /// Pretty-print with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    out.push_str(&"  ".repeat(indent + 1));
                    x.write_pretty(out, indent + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, x)) in m.iter().enumerate() {
                    out.push_str(&"  ".repeat(indent + 1));
                    out.push_str(&format!("{}: ", Json::Str(k.clone())));
                    x.write_pretty(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            other => out.push_str(&other.to_string()),
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), x)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 in place.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path("a").unwrap().as_arr().unwrap()[2].path("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = Json::Str("line\nwith \"quotes\" and \\ tab\t".into());
        let text = original.to_string();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip_complex() {
        let text = r#"{"cfg":{"cores":30,"lat":32.5},"apps":["b+tree","cfd"],"ok":true}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn integral_numbers_print_without_decimal() {
        assert_eq!(Json::Num(30.0).to_string(), "30");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 3, "s": "x", "b": false, "f": 1.5}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("f").unwrap().as_u64(), None);
        assert_eq!(j.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(false));
        assert!(j.get("missing").is_none());
    }

    #[test]
    fn parses_real_artifact_metadata() {
        // The exact shape compile/aot.py emits.
        let text = r#"{
          "artifact": "locality", "num_cores": 30, "padded_cores": 32,
          "trace_len": 4096, "nbits": 8192,
          "outputs": [{"name": "sharing_matrix", "dtype": "f32", "shape": [32, 32]}]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("num_cores").unwrap().as_usize(), Some(30));
        assert_eq!(
            j.get("outputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }
}
