//! FxHash (the rustc hasher): a fast non-cryptographic hasher for the
//! simulator's hot maps (in-flight lines, load trackers, MSHRs).  SipHash
//! (std's default) showed up at ~8% of the engine profile; these maps are
//! keyed by line addresses and small tuples where DoS resistance is
//! irrelevant.

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7C_C1_B7_27_22_0A_95;

/// The rustc-FxHash word-at-a-time multiply-rotate hasher.
#[derive(Debug, Clone, Copy, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add_to_hash(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add_to_hash(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add_to_hash(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add_to_hash(n as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<K> = std::collections::HashSet<K, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_basics() {
        let mut m: FxHashMap<u64, u64> = FxHashMap::default();
        for i in 0..1000u64 {
            m.insert(i * 7, i);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u64 {
            assert_eq!(m.get(&(i * 7)), Some(&i));
        }
    }

    #[test]
    fn tuple_keys_hash_distinctly() {
        let mut m: FxHashMap<(u32, u32, u64), u64> = FxHashMap::default();
        for a in 0..20u32 {
            for b in 0..20u32 {
                m.insert((a, b, (a + b) as u64), (a * b) as u64);
            }
        }
        assert_eq!(m.len(), 400);
        assert_eq!(m[&(3, 4, 7)], 12);
    }

    #[test]
    fn hash_distribution_is_reasonable() {
        use std::hash::BuildHasher;
        // Sequential line addresses must not collide into few buckets.
        let bh = FxBuildHasher::default();
        let mut buckets = [0usize; 64];
        for line in 0..64_000u64 {
            let h = bh.hash_one(line);
            buckets[(h % 64) as usize] += 1;
        }
        let min = *buckets.iter().min().unwrap();
        let max = *buckets.iter().max().unwrap();
        assert!(min > 500 && max < 1500, "min={min} max={max}");
    }
}
